//! Preempt queue for real-time workloads (the paper's Future Work item,
//! implemented).
//!
//! "Checkpoint/restart … provides scheduling flexibility to support diverse
//! workloads with different priority levels, e.g., making space for
//! high-priority, real-time workloads by preempting low-priority jobs."
//!
//! The scenario: a low-priority job occupies the nodes; a real-time job
//! arrives; the scheduler checkpoints the low-priority job with MANA,
//! kills it, runs the real-time job to completion, then restarts the
//! low-priority job from its images — no work is lost beyond the steps
//! since the checkpoint (zero, since the checkpoint is taken at
//! preemption time).

use std::sync::Arc;

use anyhow::Result;

use crate::cluster::{Cluster, ClusterReport, JobSpec};
use crate::config::RunConfig;
use crate::log_info;
use crate::runtime::Engine;
use crate::sim::JobSim;
use crate::util::prng::Xoshiro256;

/// Timeline of one preemption cycle (virtual seconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct PreemptReport {
    /// Low-priority progress when the real-time job arrived (steps).
    pub lowpri_steps_at_preempt: u64,
    /// Checkpoint duration (the real-time job's launch delay).
    pub ckpt_secs: f64,
    /// Real-time job makespan.
    pub realtime_secs: f64,
    /// Low-priority restart duration.
    pub restart_secs: f64,
    /// Total low-priority steps completed by scenario end.
    pub lowpri_steps_final: u64,
    /// The determinism check: restarted low-pri state fingerprint equals an
    /// uninterrupted run of the same length.
    pub deterministic: bool,
}

/// Run the full preemption scenario.
///
/// `lowpri` runs `preempt_after` supersteps, is checkpointed and killed;
/// `realtime` then runs `realtime_steps`; finally `lowpri` restarts and
/// completes `remaining_steps`.
pub fn run_preemption_scenario(
    lowpri: RunConfig,
    realtime: RunConfig,
    engine: Option<Arc<Engine>>,
    preempt_after: u64,
    realtime_steps: u64,
    remaining_steps: u64,
) -> Result<PreemptReport> {
    let mut report = PreemptReport::default();

    // Reference: the same low-pri work, uninterrupted.
    let mut reference = JobSim::launch(lowpri.clone(), engine.clone())?;
    reference.run_steps(preempt_after + remaining_steps)?;
    let want = reference.fingerprint();

    // 1. Low-priority job runs until the real-time job arrives.
    let mut low = JobSim::launch(lowpri.clone(), engine.clone())?;
    low.run_steps(preempt_after)?;
    report.lowpri_steps_at_preempt = low.step;

    // 2. Preemption: checkpoint + kill.
    let ckpt = low
        .checkpoint()
        .map_err(|e| anyhow::anyhow!("preemption checkpoint failed: {e}"))?;
    report.ckpt_secs = ckpt.total_secs;
    let fs = low.kill();
    log_info!(
        "preempt",
        "low-priority job checkpointed in {:.2}s, nodes released",
        ckpt.total_secs
    );

    // 3. Real-time job gets the nodes.
    let mut rt = JobSim::launch(realtime, engine.clone())?;
    let rt_t0 = rt.now();
    rt.run_steps(realtime_steps)?;
    report.realtime_secs = rt.now().as_secs() - rt_t0.as_secs();
    let _ = rt.kill();

    // 4. Low-priority job restarts from its images.
    let (mut resumed, rrep) = JobSim::restart_from(lowpri, engine, fs)
        .map_err(|e| anyhow::anyhow!("low-priority restart failed: {e}"))?;
    report.restart_secs = rrep.total_secs;
    resumed.run_steps(remaining_steps)?;
    report.lowpri_steps_final = resumed.step;
    report.deterministic = resumed.fingerprint() == want && !resumed.any_corruption();
    Ok(report)
}

// ---------------------------------------------------------------- storms

/// One scheduler decision in a preemption storm: kill tenant `job` at
/// virtual time `at_secs`, give the nodes back `down_secs` later.
#[derive(Clone, Copy, Debug)]
pub struct StormHit {
    pub job: usize,
    pub at_secs: f64,
    pub down_secs: f64,
}

/// A batch of preemptions aimed at a multi-job [`Cluster`].
#[derive(Clone, Debug, Default)]
pub struct StormPlan {
    pub hits: Vec<StormHit>,
}

/// Draw a deterministic storm: `hits` preemptions spread over the first
/// `window_secs` of the run, each taking a uniformly-chosen tenant down
/// for `down_secs`. Same seed, same storm — the cluster run it drives is
/// reproducible end to end.
pub fn storm_plan(jobs: usize, hits: u32, window_secs: f64, down_secs: f64, seed: u64) -> StormPlan {
    let mut rng = Xoshiro256::stream(seed, 0x5702);
    let mut plan = StormPlan::default();
    for _ in 0..hits {
        plan.hits.push(StormHit {
            job: rng.next_below(jobs.max(1) as u64) as usize,
            at_secs: rng.next_f64() * window_secs,
            down_secs,
        });
    }
    plan
}

/// Run a preemption storm against a shared-store cluster: every hit is a
/// checkpoint-and-kill through the victim's own checkpoint path, the
/// victim's queued drains keep shipping while it is down, and each victim
/// restarts from the shared tier. The single-job scenario above is the
/// `jobs == 1` special case of this.
pub fn run_preemption_storm(specs: Vec<JobSpec>, plan: &StormPlan) -> Result<ClusterReport> {
    let mut cluster = Cluster::launch(specs)?;
    for h in &plan.hits {
        cluster.schedule_preemption(h.job, h.at_secs, h.down_secs);
    }
    let report = cluster.run()?;
    log_info!(
        "preempt",
        "storm done: {} preemptions, {} restarts, cross-job dedup {:.1}%",
        report.preemptions,
        report.restarts,
        report.cross_job_dedup_ratio * 100.0
    );
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AppKind;

    #[test]
    fn preemption_cycle_preserves_low_priority_work() {
        let mut low = RunConfig::new(AppKind::Synthetic, 4);
        low.job = "lowpri".into();
        low.mem_per_rank = Some(1 << 20);
        let mut rt = RunConfig::new(AppKind::Synthetic, 4);
        rt.job = "realtime".into();
        rt.mem_per_rank = Some(1 << 20);

        let rep = run_preemption_scenario(low, rt, None, 3, 2, 4).unwrap();
        assert_eq!(rep.lowpri_steps_at_preempt, 3);
        assert_eq!(rep.lowpri_steps_final, 7);
        assert!(rep.ckpt_secs > 0.0);
        assert!(rep.realtime_secs > 0.0);
        assert!(rep.restart_secs > 0.0);
        assert!(
            rep.deterministic,
            "preempted job must resume bitwise-identically"
        );
    }

    fn storm_spec(name: &str, steps: u64) -> JobSpec {
        let mut cfg = RunConfig::new(AppKind::Synthetic, 4).with_staging();
        cfg.job = name.to_string();
        cfg.steps = steps;
        cfg.mem_per_rank = Some(1 << 20);
        JobSpec::new(cfg).ckpt_every(4)
    }

    #[test]
    fn storm_plan_is_deterministic() {
        let a = storm_plan(3, 8, 30.0, 10.0, 7);
        let b = storm_plan(3, 8, 30.0, 10.0, 7);
        assert_eq!(a.hits.len(), 8);
        for (x, y) in a.hits.iter().zip(&b.hits) {
            assert_eq!(x.job, y.job);
            assert_eq!(x.at_secs, y.at_secs);
            assert!(x.job < 3);
            assert!(x.at_secs <= 30.0);
        }
    }

    #[test]
    fn storm_against_shared_store_completes_every_tenant() {
        // Hits at t=0 are guaranteed to land (later draws may race job
        // completion and no-op, which the cluster tolerates by design).
        let plan = StormPlan {
            hits: vec![
                StormHit {
                    job: 0,
                    at_secs: 0.0,
                    down_secs: 3.0,
                },
                StormHit {
                    job: 1,
                    at_secs: 0.0,
                    down_secs: 6.0,
                },
            ],
        };
        let rep = run_preemption_storm(
            vec![storm_spec("stormA", 8), storm_spec("stormB", 8)],
            &plan,
        )
        .unwrap();
        assert_eq!(rep.preemptions, 2);
        assert_eq!(rep.restarts, 2);
        for j in &rep.per_job {
            assert_eq!(j.steps, 8, "{} must finish despite the storm", j.job);
            assert_ne!(j.fingerprint, 0);
        }
    }
}
