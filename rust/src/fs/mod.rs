//! Simulated parallel file systems: Burst Buffer vs. Lustre (CSCRATCH).
//!
//! The paper evaluates MANA's checkpoint overhead on Cori's two storage
//! tiers and finds Burst Buffers "superior … and also scales better"
//! (Fig. 2, and HPCG at 512 ranks: ~30 s vs >600 s checkpoint, >20x; restart
//! speedup ~2.5x). These models reproduce those *shapes*:
//!
//! * [`FsConfig::burst_buffer`] — DataWarp-like: per-node SSD allocations,
//!   bandwidth scales linearly with the node count, low metadata latency.
//! * [`FsConfig::cscratch`] — Lustre-like: one shared pool whose effective
//!   write bandwidth saturates with writer count (`peak * N / (N + K)`),
//!   slow metadata; reads contend much less than writes (hence the modest
//!   restart speedup).
//!
//! Calibration (unit-tested below):
//!   HPCG 512 ranks / 64 nodes / 5.8 TB →  BB ≈ 30 s, Lustre ≈ 650 s (>20x)
//!   restart → BB ≈ 26 s, Lustre ≈ 65 s (≈2.5x)
//!
//! File *data* is held in memory (images are real bytes at MB scale), while
//! transfer time is charged on the **virtual** byte counts, so paper-scale
//! TB checkpoints run on a laptop. Capacity accounting is on virtual bytes;
//! exceeding it produces the explicit warning the paper asks for
//! ("Applications with a large memory footprint may fail to checkpoint if
//! there is insufficient storage space … a system warning is needed").

pub mod chunkstore;
pub mod redundancy;
pub mod tiered;

use std::collections::BTreeMap;
use std::fmt;

use crate::ckpt::chunk::ChunkRecipe;
use crate::topology::NodeId;
use crate::{log_debug, log_warn};

pub use chunkstore::{job_of, ChunkStore};
pub use redundancy::{RedundancyConfig, RedundancyScheme, DEFAULT_SET_SIZE};
pub use tiered::{DrainStats, DrainTick, StagedIo, TieredStore};

const GB: f64 = 1e9;

/// The storage-tier abstraction extracted from [`FileSystem`]: everything
/// the checkpoint engine needs from a mounted tier — parallel write/read
/// waves, capacity accounting, namespace ops, and fault injection. Both a
/// single mounted file system and the composite [`TieredStore`] implement
/// it, which is what makes the engine pluggable.
pub trait StorageTier {
    /// Write a wave of checkpoint images in parallel.
    fn write_parallel(&mut self, reqs: Vec<WriteReq>) -> Result<IoReport, FsError>;
    /// Read a wave of images in parallel (restart path).
    fn read_parallel(
        &self,
        paths: &[(NodeId, String)],
    ) -> Result<(Vec<Vec<u8>>, IoReport), FsError>;
    fn exists(&self, path: &str) -> bool;
    fn delete(&mut self, path: &str) -> Result<(), FsError>;
    fn free_bytes(&self) -> u64;
    fn used_bytes(&self) -> u64;
    fn file_count(&self) -> usize;
    /// Fault injection: flip one byte of a stored file.
    fn corrupt_byte(&mut self, path: &str, offset: usize) -> bool;
    /// Human-readable tier description for logs.
    fn describe(&self) -> String;
}

/// Which storage tier.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FsKind {
    BurstBuffer,
    Lustre,
}

impl fmt::Display for FsKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FsKind::BurstBuffer => write!(f, "burst-buffer"),
            FsKind::Lustre => write!(f, "cscratch(lustre)"),
        }
    }
}

/// Bandwidth/latency/capacity parameters of one tier.
#[derive(Clone, Debug)]
pub struct FsConfig {
    pub kind: FsKind,
    /// Per-node write/read bandwidth (BB tier), bytes/s.
    pub per_node_write_bw: f64,
    pub per_node_read_bw: f64,
    /// Shared-pool peak write/read bandwidth (Lustre tier), bytes/s.
    pub peak_write_bw: f64,
    pub peak_read_bw: f64,
    /// Writer-count at which Lustre write bandwidth reaches half its peak.
    pub contention_k_write: f64,
    pub contention_k_read: f64,
    /// Metadata (open/create) latency per wave of writers, seconds.
    pub meta_latency: f64,
    /// Capacity in (virtual) bytes.
    pub capacity: u64,
}

impl FsConfig {
    /// DataWarp-like burst buffer striped over the job's nodes.
    pub fn burst_buffer(nodes: u32) -> Self {
        FsConfig {
            kind: FsKind::BurstBuffer,
            per_node_write_bw: 3.0 * GB,
            per_node_read_bw: 3.5 * GB,
            peak_write_bw: f64::INFINITY, // not pool-limited
            peak_read_bw: f64::INFINITY,
            contention_k_write: 0.0,
            contention_k_read: 0.0,
            meta_latency: 0.005,
            capacity: (nodes as u64) * 1_600_000_000_000, // 1.6 TB/node
        }
    }

    /// Cori's Lustre scratch (CSCRATCH)-like shared file system.
    pub fn cscratch() -> Self {
        FsConfig {
            kind: FsKind::Lustre,
            per_node_write_bw: f64::INFINITY,
            per_node_read_bw: f64::INFINITY,
            peak_write_bw: 10.0 * GB, // effective many-writer ckpt bandwidth
            peak_read_bw: 100.0 * GB, // reads contend far less
            contention_k_write: 64.0,
            contention_k_read: 64.0,
            meta_latency: 0.050,
            capacity: 28_000_000_000_000_000, // 28 PB
        }
    }
}

/// One parallel write request (a rank writing its checkpoint image).
#[derive(Clone, Debug)]
pub struct WriteReq {
    pub node: NodeId,
    pub path: String,
    /// Bytes charged against bandwidth and capacity.
    pub virtual_bytes: u64,
    /// Real serialized bytes retained for later reads.
    pub data: Vec<u8>,
    /// Content-addressed chunk recipe of `data` (staged checkpoints).
    /// With a recipe, the tiered engine's background drain dedups against
    /// the durable chunk store and restart can reassemble the file from
    /// chunks alone; without one the file stages byte-for-byte as before.
    pub recipe: Option<ChunkRecipe>,
}

/// Outcome of a parallel write/read wave.
#[derive(Clone, Copy, Debug)]
pub struct IoReport {
    /// Virtual seconds until the slowest participant finished.
    pub duration: f64,
    pub total_virtual_bytes: u64,
    pub writers: usize,
}

/// Failure modes of the storage tier.
#[derive(Clone, Debug)]
pub enum FsError {
    /// The paper's "insufficient storage space" case.
    InsufficientSpace { needed: u64, free: u64 },
    NotFound(String),
    /// A recipe-backed read found a chunk object missing or not matching
    /// its recorded content digest (corrupted/swapped chunk store).
    Corrupt(String),
}

impl fmt::Display for FsError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FsError::InsufficientSpace { needed, free } => write!(
                f,
                "insufficient storage space: need {}, only {} free",
                crate::util::bytes::human(*needed),
                crate::util::bytes::human(*free)
            ),
            FsError::NotFound(p) => write!(f, "no such file: {p}"),
            FsError::Corrupt(what) => write!(f, "chunk store corruption: {what}"),
        }
    }
}

impl std::error::Error for FsError {}

#[derive(Clone, Debug)]
struct StoredFile {
    virtual_bytes: u64,
    data: Vec<u8>,
}

/// A mounted file system instance.
#[derive(Clone, Debug)]
pub struct FileSystem {
    pub cfg: FsConfig,
    used: u64,
    files: BTreeMap<String, StoredFile>,
}

impl FileSystem {
    pub fn new(cfg: FsConfig) -> Self {
        FileSystem {
            cfg,
            used: 0,
            files: BTreeMap::new(),
        }
    }

    pub fn free_bytes(&self) -> u64 {
        self.cfg.capacity.saturating_sub(self.used)
    }

    pub fn used_bytes(&self) -> u64 {
        self.used
    }

    /// Effective aggregate write bandwidth for `writers` concurrent
    /// writers spread over `nodes` nodes.
    pub fn write_bandwidth(&self, writers: usize, nodes: u32) -> f64 {
        match self.cfg.kind {
            FsKind::BurstBuffer => self.cfg.per_node_write_bw * nodes as f64,
            FsKind::Lustre => {
                let n = writers as f64;
                self.cfg.peak_write_bw * n / (n + self.cfg.contention_k_write)
            }
        }
    }

    pub fn read_bandwidth(&self, readers: usize, nodes: u32) -> f64 {
        match self.cfg.kind {
            FsKind::BurstBuffer => self.cfg.per_node_read_bw * nodes as f64,
            FsKind::Lustre => {
                let n = readers as f64;
                self.cfg.peak_read_bw * n / (n + self.cfg.contention_k_read)
            }
        }
    }

    /// Write a wave of checkpoint images in parallel.
    ///
    /// Capacity is checked up front; on shortfall the warning the paper
    /// calls for is logged and nothing is written.
    pub fn write_parallel(&mut self, reqs: Vec<WriteReq>) -> Result<IoReport, FsError> {
        let total: u64 = reqs.iter().map(|r| r.virtual_bytes).sum();
        // Replacing existing files frees their old space first.
        let replaced: u64 = reqs
            .iter()
            .filter_map(|r| self.files.get(&r.path).map(|f| f.virtual_bytes))
            .sum();
        let free = self.free_bytes() + replaced;
        if total > free {
            log_warn!(
                "fs",
                "{}: insufficient storage space for checkpoint: need {}, free {} — aborting wave",
                self.cfg.kind,
                crate::util::bytes::human(total),
                crate::util::bytes::human(free)
            );
            return Err(FsError::InsufficientSpace {
                needed: total,
                free,
            });
        }

        let writers = reqs.len();
        let nodes = distinct_nodes(&reqs);
        let duration = match self.cfg.kind {
            FsKind::BurstBuffer => {
                // Each node drains its local ranks' images at node bandwidth.
                let mut per_node: BTreeMap<NodeId, u64> = BTreeMap::new();
                for r in &reqs {
                    *per_node.entry(r.node).or_insert(0) += r.virtual_bytes;
                }
                per_node
                    .values()
                    .map(|&b| b as f64 / self.cfg.per_node_write_bw)
                    .fold(0.0, f64::max)
                    + self.cfg.meta_latency
            }
            FsKind::Lustre => {
                let bw = self.write_bandwidth(writers, nodes);
                total as f64 / bw + self.cfg.meta_latency
            }
        };

        for r in reqs {
            if let Some(old) = self.files.remove(&r.path) {
                self.used -= old.virtual_bytes;
            }
            self.used += r.virtual_bytes;
            self.files.insert(
                r.path,
                StoredFile {
                    virtual_bytes: r.virtual_bytes,
                    data: r.data,
                },
            );
        }
        log_debug!(
            "fs",
            "{}: wrote {} from {} writers in {:.2}s",
            self.cfg.kind,
            crate::util::bytes::human(total),
            writers,
            duration
        );
        Ok(IoReport {
            duration,
            total_virtual_bytes: total,
            writers,
        })
    }

    /// Read a wave of images in parallel (restart path). Returns the data
    /// in request order plus the IO report.
    pub fn read_parallel(
        &self,
        paths: &[(NodeId, String)],
    ) -> Result<(Vec<Vec<u8>>, IoReport), FsError> {
        let mut datas = Vec::with_capacity(paths.len());
        let mut total = 0u64;
        let mut per_node: BTreeMap<NodeId, u64> = BTreeMap::new();
        for (node, p) in paths {
            let f = self
                .files
                .get(p)
                .ok_or_else(|| FsError::NotFound(p.clone()))?;
            datas.push(f.data.clone());
            total += f.virtual_bytes;
            *per_node.entry(*node).or_insert(0) += f.virtual_bytes;
        }
        let nodes = per_node.len().max(1) as u32;
        let duration = match self.cfg.kind {
            FsKind::BurstBuffer => {
                per_node
                    .values()
                    .map(|&b| b as f64 / self.cfg.per_node_read_bw)
                    .fold(0.0, f64::max)
                    + self.cfg.meta_latency
            }
            FsKind::Lustre => {
                let bw = self.read_bandwidth(paths.len(), nodes);
                total as f64 / bw + self.cfg.meta_latency
            }
        };
        Ok((
            datas,
            IoReport {
                duration,
                total_virtual_bytes: total,
                writers: paths.len(),
            },
        ))
    }

    pub fn delete(&mut self, path: &str) -> Result<(), FsError> {
        let f = self
            .files
            .remove(path)
            .ok_or_else(|| FsError::NotFound(path.to_string()))?;
        self.used -= f.virtual_bytes;
        Ok(())
    }

    pub fn exists(&self, path: &str) -> bool {
        self.files.contains_key(path)
    }

    /// Fault injection: flip one byte of a stored file (torn/corrupt image).
    /// Returns false if the path or offset doesn't exist.
    pub fn corrupt_byte(&mut self, path: &str, offset: usize) -> bool {
        match self.files.get_mut(path) {
            Some(f) if offset < f.data.len() => {
                f.data[offset] ^= 0x5a;
                true
            }
            _ => false,
        }
    }

    pub fn file_count(&self) -> usize {
        self.files.len()
    }

    /// Virtual size of a stored file, if present.
    pub fn virtual_size(&self, path: &str) -> Option<u64> {
        self.files.get(path).map(|f| f.virtual_bytes)
    }

    /// Borrow a stored file's (virtual size, real bytes) without charging
    /// any transfer time — the tiered engine's drain path copies through
    /// this and charges time on its own clock.
    pub fn peek(&self, path: &str) -> Option<(u64, &[u8])> {
        self.files
            .get(path)
            .map(|f| (f.virtual_bytes, f.data.as_slice()))
    }

    /// Insert a file directly (no wave, no transfer time). Capacity is
    /// still enforced; replacing an existing file frees its space first.
    pub fn insert_raw(
        &mut self,
        path: &str,
        virtual_bytes: u64,
        data: Vec<u8>,
    ) -> Result<(), FsError> {
        let replaced = self.virtual_size(path).unwrap_or(0);
        let free = self.free_bytes() + replaced;
        if virtual_bytes > free {
            return Err(FsError::InsufficientSpace {
                needed: virtual_bytes,
                free,
            });
        }
        if let Some(old) = self.files.remove(path) {
            self.used -= old.virtual_bytes;
        }
        self.used += virtual_bytes;
        self.files.insert(
            path.to_string(),
            StoredFile {
                virtual_bytes,
                data,
            },
        );
        Ok(())
    }

    /// All stored paths (sorted — BTreeMap order).
    pub fn paths(&self) -> Vec<String> {
        self.files.keys().cloned().collect()
    }
}

impl StorageTier for FileSystem {
    fn write_parallel(&mut self, reqs: Vec<WriteReq>) -> Result<IoReport, FsError> {
        FileSystem::write_parallel(self, reqs)
    }
    fn read_parallel(
        &self,
        paths: &[(NodeId, String)],
    ) -> Result<(Vec<Vec<u8>>, IoReport), FsError> {
        FileSystem::read_parallel(self, paths)
    }
    fn exists(&self, path: &str) -> bool {
        FileSystem::exists(self, path)
    }
    fn delete(&mut self, path: &str) -> Result<(), FsError> {
        FileSystem::delete(self, path)
    }
    fn free_bytes(&self) -> u64 {
        FileSystem::free_bytes(self)
    }
    fn used_bytes(&self) -> u64 {
        FileSystem::used_bytes(self)
    }
    fn file_count(&self) -> usize {
        FileSystem::file_count(self)
    }
    fn corrupt_byte(&mut self, path: &str, offset: usize) -> bool {
        FileSystem::corrupt_byte(self, path, offset)
    }
    fn describe(&self) -> String {
        self.cfg.kind.to_string()
    }
}

/// The job's storage handle: one mounted tier, or the staged BB→Lustre
/// tiered engine. This is what survives [`crate::sim::JobSim::kill`] and
/// what a restart reads from.
#[derive(Clone, Debug)]
pub enum Store {
    /// One mounted file system (`--fs bb` / `--fs lustre`).
    Single(FileSystem),
    /// Fast tier + durable tier with asynchronous staging (`--fs staged`).
    Tiered(TieredStore),
}

impl Store {
    pub fn is_staged(&self) -> bool {
        matches!(self, Store::Tiered(_))
    }

    pub fn tiered(&self) -> Option<&TieredStore> {
        match self {
            Store::Tiered(t) => Some(t),
            Store::Single(_) => None,
        }
    }

    pub fn tiered_mut(&mut self) -> Option<&mut TieredStore> {
        match self {
            Store::Tiered(t) => Some(t),
            Store::Single(_) => None,
        }
    }

    /// Hand the owning job's tracer to the staged engine (drain ticks and
    /// fault events join the job timeline). No-op for a single tier.
    pub fn set_tracer(&mut self, tracer: crate::trace::Tracer) {
        if let Store::Tiered(t) = self {
            t.set_tracer(tracer);
        }
    }

    /// The active tier, viewed through the [`StorageTier`] trait — every
    /// generic operation below dispatches through this single point.
    fn tier(&self) -> &dyn StorageTier {
        match self {
            Store::Single(f) => f,
            Store::Tiered(t) => t,
        }
    }

    fn tier_mut(&mut self) -> &mut dyn StorageTier {
        match self {
            Store::Single(f) => f,
            Store::Tiered(t) => t,
        }
    }

    pub fn write_parallel(&mut self, reqs: Vec<WriteReq>) -> Result<IoReport, FsError> {
        self.tier_mut().write_parallel(reqs)
    }

    pub fn read_parallel(
        &self,
        paths: &[(NodeId, String)],
    ) -> Result<(Vec<Vec<u8>>, IoReport), FsError> {
        self.tier().read_parallel(paths)
    }

    pub fn exists(&self, path: &str) -> bool {
        self.tier().exists(path)
    }

    pub fn delete(&mut self, path: &str) -> Result<(), FsError> {
        self.tier_mut().delete(path)
    }

    pub fn free_bytes(&self) -> u64 {
        self.tier().free_bytes()
    }

    pub fn used_bytes(&self) -> u64 {
        self.tier().used_bytes()
    }

    pub fn file_count(&self) -> usize {
        self.tier().file_count()
    }

    pub fn corrupt_byte(&mut self, path: &str, offset: usize) -> bool {
        self.tier_mut().corrupt_byte(path, offset)
    }

    pub fn describe(&self) -> String {
        self.tier().describe()
    }
}

fn distinct_nodes(reqs: &[WriteReq]) -> u32 {
    let mut nodes: Vec<u32> = reqs.iter().map(|r| r.node.0).collect();
    nodes.sort_unstable();
    nodes.dedup();
    nodes.len().max(1) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    const GIB: u64 = 1 << 30;

    fn hpcg_wave(ranks: u32, nodes: u32, total_bytes: u64) -> Vec<WriteReq> {
        let per_rank = total_bytes / ranks as u64;
        (0..ranks)
            .map(|r| WriteReq {
                node: NodeId(r / (ranks / nodes)),
                path: format!("ckpt_rank{r}.mana"),
                virtual_bytes: per_rank,
                data: vec![],
                recipe: None,
            })
            .collect()
    }

    /// The paper's HPCG headline: 512 ranks, 5.8 TB aggregate; BB ≈ 30 s,
    /// Lustre > 600 s, speedup > 20x.
    #[test]
    fn hpcg_checkpoint_calibration() {
        let total = 5_800_000_000_000u64; // 5.8 TB
        let mut bb = FileSystem::new(FsConfig::burst_buffer(64));
        let mut lustre = FileSystem::new(FsConfig::cscratch());
        let bb_t = bb
            .write_parallel(hpcg_wave(512, 64, total))
            .unwrap()
            .duration;
        let lu_t = lustre
            .write_parallel(hpcg_wave(512, 64, total))
            .unwrap()
            .duration;
        assert!((25.0..40.0).contains(&bb_t), "BB ckpt {bb_t}s (paper ~30s)");
        assert!(lu_t > 600.0, "Lustre ckpt {lu_t}s (paper >600s)");
        assert!(lu_t / bb_t > 20.0, "speedup {} (paper >20x)", lu_t / bb_t);
    }

    /// The paper's restart claim: BB/Lustre speedup "more modest, ~2.5x".
    #[test]
    fn hpcg_restart_calibration() {
        let total = 5_800_000_000_000u64;
        let mut bb = FileSystem::new(FsConfig::burst_buffer(64));
        let mut lustre = FileSystem::new(FsConfig::cscratch());
        bb.write_parallel(hpcg_wave(512, 64, total)).unwrap();
        lustre.write_parallel(hpcg_wave(512, 64, total)).unwrap();
        let paths: Vec<(NodeId, String)> = (0..512u32)
            .map(|r| (NodeId(r / 8), format!("ckpt_rank{r}.mana")))
            .collect();
        let bb_t = bb.read_parallel(&paths).unwrap().1.duration;
        let lu_t = lustre.read_parallel(&paths).unwrap().1.duration;
        let speedup = lu_t / bb_t;
        assert!(
            (1.8..3.5).contains(&speedup),
            "restart speedup {speedup} (paper ~2.5x)"
        );
    }

    /// Fig. 2 shape: BB stays near-flat with rank count, Lustre grows.
    #[test]
    fn fig2_scaling_shape() {
        let per_rank = 3 * GIB / 2; // 1.5 GiB/rank ADH-analog footprint
        let mut bb_times = Vec::new();
        let mut lu_times = Vec::new();
        for &ranks in &[4u32, 8, 16, 32, 64] {
            let nodes = ranks.div_ceil(8);
            let total = per_rank * ranks as u64;
            let mut bb = FileSystem::new(FsConfig::burst_buffer(nodes));
            let mut lu = FileSystem::new(FsConfig::cscratch());
            bb_times.push(bb.write_parallel(hpcg_wave(ranks, nodes, total)).unwrap().duration);
            lu_times.push(lu.write_parallel(hpcg_wave(ranks, nodes, total)).unwrap().duration);
        }
        // BB must beat Lustre everywhere.
        for (b, l) in bb_times.iter().zip(&lu_times) {
            assert!(b < l, "BB {b} >= Lustre {l}");
        }
        // BB near-flat: max/min < 3; Lustre grows: last > first.
        let bmax = bb_times.iter().cloned().fold(0.0, f64::max);
        let bmin = bb_times.iter().cloned().fold(f64::MAX, f64::min);
        assert!(bmax / bmin < 3.0, "BB not flat: {bb_times:?}");
        assert!(
            lu_times.last().unwrap() > lu_times.first().unwrap(),
            "Lustre did not grow: {lu_times:?}"
        );
    }

    #[test]
    fn insufficient_space_warns_and_errors() {
        let mut cfg = FsConfig::burst_buffer(1);
        cfg.capacity = 10 * GIB;
        let mut fs = FileSystem::new(cfg);
        crate::util::logging::capture_start();
        let err = fs
            .write_parallel(vec![WriteReq {
                node: NodeId(0),
                path: "big.mana".into(),
                virtual_bytes: 11 * GIB,
                data: vec![],
                recipe: None,
            }])
            .unwrap_err();
        let recs = crate::util::logging::capture_take();
        assert!(matches!(err, FsError::InsufficientSpace { .. }));
        assert!(recs
            .iter()
            .any(|r| r.message.contains("insufficient storage space")));
        assert_eq!(fs.used_bytes(), 0, "nothing written on failure");
    }

    #[test]
    fn overwrite_frees_old_space() {
        let mut fs = FileSystem::new(FsConfig::burst_buffer(1));
        let w = |bytes| {
            vec![WriteReq {
                node: NodeId(0),
                path: "x.mana".into(),
                virtual_bytes: bytes,
                data: vec![1, 2, 3],
                recipe: None,
            }]
        };
        fs.write_parallel(w(100 * GIB / 64)).unwrap();
        let used1 = fs.used_bytes();
        fs.write_parallel(w(100 * GIB / 64)).unwrap();
        assert_eq!(fs.used_bytes(), used1, "overwrite must not leak space");
    }

    #[test]
    fn read_roundtrips_data() {
        let mut fs = FileSystem::new(FsConfig::cscratch());
        fs.write_parallel(vec![WriteReq {
            node: NodeId(0),
            path: "img".into(),
            virtual_bytes: 123,
            data: vec![9, 8, 7],
            recipe: None,
        }])
        .unwrap();
        let (datas, rep) = fs.read_parallel(&[(NodeId(0), "img".into())]).unwrap();
        assert_eq!(datas[0], vec![9, 8, 7]);
        assert_eq!(rep.total_virtual_bytes, 123);
    }

    #[test]
    fn read_missing_file_errors() {
        let fs = FileSystem::new(FsConfig::cscratch());
        assert!(matches!(
            fs.read_parallel(&[(NodeId(0), "nope".into())]),
            Err(FsError::NotFound(_))
        ));
    }

    #[test]
    fn delete_frees_space() {
        let mut fs = FileSystem::new(FsConfig::burst_buffer(1));
        fs.write_parallel(vec![WriteReq {
            node: NodeId(0),
            path: "a".into(),
            virtual_bytes: 1000,
            data: vec![],
            recipe: None,
        }])
        .unwrap();
        assert_eq!(fs.used_bytes(), 1000);
        fs.delete("a").unwrap();
        assert_eq!(fs.used_bytes(), 0);
        assert!(fs.delete("a").is_err());
    }

    #[test]
    fn lustre_write_bw_saturates() {
        let fs = FileSystem::new(FsConfig::cscratch());
        let b4 = fs.write_bandwidth(4, 1);
        let b512 = fs.write_bandwidth(512, 64);
        assert!(b512 > b4);
        assert!(b512 < fs.cfg.peak_write_bw);
        // Monotone saturation towards the peak.
        assert!(fs.write_bandwidth(2048, 64) > b512);
    }
}
