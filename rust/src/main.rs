//! `mana` — CLI for the MANA@NERSC reproduction.
//!
//! Subcommands:
//!   run       launch a job on the simulated Cori, optionally C/R mid-run
//!   usage     print the Fig. 1 application census
//!   mapping   print the rank-to-node/pid table for a topology
//!   preempt   run the preempt-queue scenario (Future Work feature)
//!   artifacts list the loaded AOT artifacts (verifies the PJRT path)
//!
//! Arg parsing is hand-rolled: the image's offline crate set has no clap.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use mana::config::{AppKind, ComputeMode, Fixes, LinkMode, RunConfig, StagingConfig};
use mana::fs::FsKind;
use mana::preempt;
use mana::runtime::{default_artifact_dir, Engine};
use mana::sim::JobSim;
use mana::topology::Topology;
use mana::usage;
use mana::util::json::Json;
use mana::util::logging::{self, Level};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

struct Args {
    flags: Vec<(String, String)>,
    #[allow(dead_code)]
    positional: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let (k, v) = if let Some((k, v)) = name.split_once('=') {
                    (k.to_string(), v.to_string())
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    i += 1;
                    (name.to_string(), argv[i].clone())
                } else {
                    (name.to_string(), "true".to_string())
                };
                flags.push((k, v));
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Args { flags, positional }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key}={v}")),
            None => Ok(default),
        }
    }

    fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("on"))
    }
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_help();
        return Ok(());
    }
    let cmd = argv[0].as_str();
    let args = Args::parse(&argv[1..]);

    logging::set_level(match args.get("log") {
        Some("trace") => Level::Trace,
        Some("debug") => Level::Debug,
        Some("info") => Level::Info,
        Some("warn") | None => Level::Warn,
        Some("error") => Level::Error,
        Some(other) => bail!("unknown log level {other}"),
    });

    match cmd {
        "run" => cmd_run(&args),
        "cluster" => cmd_cluster(&args),
        "usage" => cmd_usage(&args),
        "mapping" => cmd_mapping(&args),
        "preempt" => cmd_preempt(&args),
        "advise" => cmd_advise(&args),
        "console" => cmd_console(&args),
        "artifacts" => cmd_artifacts(),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command {other} (try `mana help`)"),
    }
}

fn print_help() {
    println!(
        "mana — MPI-agnostic transparent checkpointing (NERSC reproduction)

USAGE: mana <command> [--flags]

COMMANDS:
  run        --app gromacs|hpcg|vasp|synthetic|colheavy --ranks N [--steps S]
             [--threads T] [--fs bb|lustre|staged] [--keep-fulls N]
             [--chunk-bytes N] [--chunking fixed|cdc] [--coord-fanout F]
             [--drain-strategy counter|topo] [--encode-threads N]
             [--pipeline on|off] [--ckpt-at STEP]
             [--redundancy none|partner|xor] [--redundancy-set-size N]
             [--restart] [--real-compute] [--fixes on|off]
             [--link static|dynamic] [--trace] [--trace-out FILE]
             --drain-strategy: counter reduces per-rank byte counters to
             convergence (the paper's DRAIN); topo checkpoints inside a
             pending collective, ordering ranks by round cursor (the
             cursor rides the manifest and resumes on restart).
             --trace records virtual-time spans; the run JSON gains a
             critical_path breakdown and the structured event log.
             --trace-out (implies --trace) also writes a Perfetto /
             chrome://tracing JSON file.
             [--event-core on|off] toggles the event-driven virtual-time
             driver (on by default; off = concrete per-rank loop).
  cluster    --jobs N [--drain-qos w1,w2,..] [--ckpt-every S]
             [--preempt-storm H] [--storm-window SECS] [--storm-down SECS]
             [--seed N] (plus usual run flags) run N tenants against ONE
             shared BB+Lustre pair: cross-job chunk dedup, per-job drain
             QoS, and an optional preemption storm through the shared
             event queue.
  usage      [--jobs N] print the Fig. 1 application census
  mapping    --ranks N [--threads T] print rank→node/pid mapping
  preempt    [--ranks N] run the preempt-queue scenario
  advise     --ckpt-secs C [--restart-secs R] [--mtbf-hours H]
             recommend a checkpoint interval (Young/Daly + numeric)
  console    --script \"r 3; s; c; k\" drive a job via dmtcp_command-style
             console commands (plus usual run flags)
  artifacts  list loaded AOT artifacts (PJRT smoke test)

GLOBAL: --log trace|debug|info|warn|error"
    );
}

fn build_config(args: &Args) -> Result<RunConfig> {
    let app = AppKind::parse(args.get("app").unwrap_or("synthetic"))
        .context("unknown --app")?;
    let ranks = args.get_u64("ranks", 8)? as u32;
    let mut cfg = RunConfig::new(app, ranks);
    cfg.threads_per_rank = args.get_u64("threads", 8)? as u32;
    cfg.steps = args.get_u64("steps", 8)?;
    match args.get("fs") {
        Some("bb") | Some("burst-buffer") | None => cfg.fs = FsKind::BurstBuffer,
        Some("lustre") | Some("cscratch") => cfg.fs = FsKind::Lustre,
        Some("staged") | Some("bb+lustre") => {
            // Tiered engine: BB fast tier, Lustre durable tier, async drain.
            cfg.fs = FsKind::BurstBuffer;
            cfg.staging = Some(StagingConfig::default());
        }
        Some(other) => bail!("unknown --fs {other}"),
    }
    if let Some(n) = args.get("keep-fulls") {
        let keep: usize = n.parse().with_context(|| format!("--keep-fulls={n}"))?;
        match cfg.staging.as_mut() {
            Some(s) => s.keep_fulls = keep,
            None => bail!("--keep-fulls requires --fs staged"),
        }
    }
    if let Some(fstr) = args.get("coord-fanout") {
        // Hierarchical coordination plane: per-node sub-coordinators in a
        // fanout-F tree; omit the flag for the flat DMTCP root.
        let f: u32 = fstr
            .parse()
            .with_context(|| format!("--coord-fanout={fstr}"))?;
        if f < 2 {
            bail!("--coord-fanout must be >= 2 (got {f})");
        }
        cfg.coord_fanout = Some(f);
    }
    if let Some(cb) = args.get("chunk-bytes") {
        let n = mana::util::bytes::parse(cb)
            .with_context(|| format!("bad --chunk-bytes {cb}"))? as usize;
        if !n.is_power_of_two() || n > mana::ckpt::chunk::MAX_CHUNK_BYTES {
            bail!(
                "--chunk-bytes must be a power of two <= {} (got {n})",
                mana::ckpt::chunk::MAX_CHUNK_BYTES
            );
        }
        cfg.chunk_bytes = n;
    }
    if let Some(m) = args.get("chunking") {
        // Chunk-boundary strategy: fixed stride, or content-defined (gear
        // rolling hash) boundaries whose expected size is --chunk-bytes.
        cfg.chunking = mana::config::ChunkingMode::parse(m)
            .with_context(|| format!("unknown --chunking {m} (fixed|cdc)"))?;
    }
    if let Some(m) = args.get("drain-strategy") {
        // DRAIN-phase coordinator strategy, orthogonal to the plane:
        // counter convergence (the paper's protocol) or topological-sort
        // ordering over a pending collective's round cursors.
        cfg.drain_strategy = mana::config::DrainStrategy::parse(m)
            .with_context(|| format!("unknown --drain-strategy {m} (counter|topo)"))?;
    }
    if let Some(v) = args.get("pipeline") {
        // Fully pipelined checkpoint path (streamed encode→write
        // admission, overlapped INTENT/SAFE-POINT): on by default;
        // `--pipeline off` forces the serial phase-by-phase path.
        match v {
            "on" | "true" | "1" => cfg.pipeline = true,
            "off" | "false" | "0" => cfg.pipeline = false,
            other => bail!("unknown --pipeline {other} (on|off)"),
        }
    }
    if let Some(v) = args.get("encode-threads") {
        // Checkpoint WRITE-path worker count; omit for the host's
        // available parallelism, 1 forces the serial data path.
        let n: usize = v
            .parse()
            .with_context(|| format!("--encode-threads={v}"))?;
        if n == 0 {
            bail!("--encode-threads must be >= 1");
        }
        cfg.encode_threads = Some(n);
    }
    if let Some(r) = args.get("redundancy") {
        // Fast-tier peer redundancy: after each checkpoint's write wave
        // the redundancy sets exchange partner copies or XOR parity, so a
        // lost BB blade rebuilds from surviving peers on restart instead
        // of falling back to Lustre.
        let scheme = mana::fs::RedundancyScheme::parse(r)
            .with_context(|| format!("unknown --redundancy {r} (none|partner|xor)"))?;
        if scheme != mana::fs::RedundancyScheme::None && cfg.staging.is_none() {
            bail!("--redundancy {r} requires --fs staged");
        }
        cfg.redundancy = scheme;
    }
    if let Some(n) = args.get("redundancy-set-size") {
        let size: u32 = n
            .parse()
            .with_context(|| format!("--redundancy-set-size={n}"))?;
        if size < 2 {
            bail!("--redundancy-set-size must be >= 2 (got {size})");
        }
        cfg.redundancy_set_size = size;
    }
    cfg.link = match args.get("link") {
        Some("dynamic") => LinkMode::Dynamic,
        _ => LinkMode::Static,
    };
    if args.get("fixes") == Some("off") {
        cfg.fixes = Fixes::all_off();
    }
    if args.get_bool("real-compute") {
        cfg.compute = ComputeMode::Real;
    }
    if let Some(job) = args.get("job") {
        cfg.job = job.to_string();
    }
    if let Some(mem) = args.get("mem-per-rank") {
        cfg.mem_per_rank =
            Some(mana::util::bytes::parse(mem).context("bad --mem-per-rank")?);
    }
    // Span tracing on the virtual clock; --trace-out implies --trace since
    // there is nothing to export otherwise.
    if args.get_bool("trace") || args.get("trace-out").is_some() {
        cfg.trace = true;
    }
    if let Some(v) = args.get("event-core") {
        // Event-driven virtual-time driver: bulk-advance steady-state
        // supersteps in O(1) host work each. `off` forces the concrete
        // per-rank loop for every step (the historical driver).
        match v {
            "on" | "true" | "1" => cfg.event_driven = true,
            "off" | "false" | "0" => cfg.event_driven = false,
            other => bail!("unknown --event-core {other} (on|off)"),
        }
    }
    Ok(cfg)
}

fn load_engine_if(cfg: &RunConfig) -> Result<Option<Arc<Engine>>> {
    if cfg.compute == ComputeMode::Real {
        let engine = Engine::load(&default_artifact_dir())
            .context("loading AOT artifacts (run `make artifacts`?)")?;
        Ok(Some(Arc::new(engine)))
    } else {
        Ok(None)
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = build_config(args)?;
    let engine = load_engine_if(&cfg)?;
    let ckpt_at = args.get("ckpt-at").map(|v| v.parse::<u64>()).transpose()?;
    let do_restart = args.get_bool("restart");

    let mut sim = JobSim::launch(cfg.clone(), engine.clone())?;
    let mut ckpt_report = None;
    let mut restart_report = None;

    match ckpt_at {
        Some(at) if at <= cfg.steps => {
            sim.run_steps(at)?;
            let rep = sim
                .checkpoint()
                .map_err(|e| anyhow::anyhow!("checkpoint failed: {e}"))?;
            ckpt_report = Some(rep);
            if do_restart {
                // The restarted job gets a fresh tracer; adopt the pre-kill
                // spans/events so the exported trace covers the whole run.
                let pre = sim.tracer.clone();
                let fs = sim.kill();
                let (resumed, rrep) = JobSim::restart_from(cfg.clone(), engine, fs)
                    .map_err(|e| anyhow::anyhow!("restart failed: {e}"))?;
                restart_report = Some(rrep);
                sim = resumed;
                sim.tracer.adopt(&pre);
            }
            sim.run_steps(cfg.steps - at)?;
        }
        _ => sim.run_steps(cfg.steps)?,
    }

    let mut out = Json::obj()
        .set("job", cfg.job.as_str())
        .set("app", cfg.app.name())
        .set("chunking", cfg.chunking.name())
        .set("ranks", cfg.ranks as u64)
        .set("steps", sim.step)
        .set("virtual_secs", sim.now().as_secs())
        .set(
            "aggregate_memory",
            mana::util::bytes::human(sim.aggregate_memory()),
        )
        .set("fingerprint", format!("{:016x}", sim.fingerprint()))
        .set("corruption", sim.any_corruption());
    if let Some(c) = ckpt_report {
        out = out.set(
            "checkpoint",
            Json::obj()
                .set("total_secs", c.total_secs)
                .set("write_secs", c.write_secs)
                .set("fast_write_secs", c.fast_write_secs)
                .set("durable_write_secs", c.durable_write_secs)
                .set("intent_secs", c.intent_secs)
                .set("safepoint_secs", c.safepoint_secs)
                .set("drain_secs", c.drain_secs)
                .set("quiesce_secs", c.quiesce_secs)
                .set("resume_secs", c.resume_secs)
                .set("ctrl_secs", c.ctrl_secs)
                .set("ctrl_msgs", c.ctrl_msgs)
                .set("root_ctrl_msgs", c.root_ctrl_msgs)
                .set("coord_depth", c.coord_depth as u64)
                .set("reparents", c.reparents as u64)
                .set("image_bytes", c.image_bytes)
                .set("encode_host_secs", c.encode_host_secs)
                .set("encode_threads", c.encode_threads as u64)
                .set("pipelined", c.pipelined)
                .set("stall_secs", c.stall_secs)
                .set("encode_stall_secs", c.encode_stall_secs)
                .set("overlap_saved_secs", c.overlap_saved_secs)
                .set("stale_acks", c.stale_acks)
                .set("digest_cache_hit_bytes", c.digest_cache_hit_bytes)
                .set("fresh_hash_bytes", c.fresh_hash_bytes)
                .set("cache_partial_regions", c.cache_partial_regions)
                .set("redundancy_scheme", c.redundancy_scheme.name())
                .set("exchange_secs", c.exchange_secs)
                .set("parity_bytes", c.parity_bytes)
                .set("drain_pending_bytes", c.drain_pending_bytes)
                .set("deduped_bytes", c.deduped_bytes)
                .set("dedup_ratio", c.dedup_ratio())
                .set("buffered_msgs", c.buffered_msgs)
                .set("lost_messages", c.lost_messages)
                .set("drain_strategy", c.drain_strategy.name())
                .set("topo_waves", c.topo_waves as u64)
                .set("collectives_interrupted", c.collectives_interrupted as u64)
                .set("collective_drain_secs", c.collective_drain_secs),
        );
    }
    out = out.set(
        "coord",
        Json::obj()
            .set("plane", sim.coord.plane.describe().as_str())
            .set("depth", sim.coord.plane.depth() as u64)
            .set("ctrl_msgs", sim.coord.stats.ctrl_msgs)
            .set("root_ctrl_msgs", sim.coord.stats.root_msgs)
            .set("reparents", sim.coord.stats.reparents)
            .set("phase_retries", sim.coord.stats.phase_retries)
            .set("stale_acks", sim.coord.stats.stale_acks),
    );
    if let Some(r) = restart_report {
        out = out.set(
            "restart",
            Json::obj()
                .set("total_secs", r.total_secs)
                .set("read_secs", r.read_secs)
                .set("startup_secs", r.startup_secs)
                .set("tier_fallbacks", r.tier_fallbacks as u64)
                .set("rebuilt_nodes", r.rebuilt_nodes as u64)
                .set("rebuilt_files", r.rebuilt_files as u64)
                .set("rebuild_secs", r.rebuild_secs)
                .set("durable_read_files", r.durable_read_files as u64)
                .set("generation_rewound", r.generation_rewound),
        );
    }
    if let Some(ts) = sim.fs.tiered() {
        out = out.set(
            "staging",
            Json::obj()
                .set("pending_bytes", ts.pending_bytes())
                .set("staged_bytes", ts.stats.drained_bytes)
                .set("staged_files", ts.stats.drained_files)
                .set("deduped_bytes", ts.stats.deduped_bytes)
                .set("dedup_ratio", ts.stats.dedup_ratio())
                .set("unique_chunks", ts.chunk_store().chunk_count() as u64)
                .set(
                    "chunk_store_vbytes",
                    ts.chunk_store().stored_vbytes(),
                )
                .set("gc_chunks", ts.stats.gc_chunks)
                .set("evicted_generations", ts.stats.evicted_generations)
                .set("lost_files", ts.stats.lost_files)
                .set("backpressure_secs", ts.stats.forced_secs)
                .set("cross_job_deduped_bytes", ts.stats.cross_job_deduped_bytes)
                .set("cross_job_dedup_ratio", ts.stats.cross_job_dedup_ratio()),
        );
    }
    if cfg.trace {
        let spans = sim.tracer.spans();
        // Critical path of the most recent checkpoint generation: which
        // spans the stall actually waited on, as [{span, secs, pct}].
        if let Some(last_gen) = spans.iter().filter_map(|s| s.gen).max() {
            let path = mana::trace::critical_path::critical_path(&spans, last_gen);
            let mut arr = Json::Arr(vec![]);
            for e in &path {
                arr.push(
                    Json::obj()
                        .set("span", e.span.as_str())
                        .set("count", e.count as u64)
                        .set("secs", e.secs)
                        .set("pct", e.pct),
                );
            }
            out = out.set("critical_path", arr);
        }
        out = out.set("events", sim.tracer.events_json());
        if let Some(path) = args.get("trace-out") {
            let j = mana::trace::perfetto::export(&spans, &sim.tracer.counters());
            std::fs::write(path, j.to_string())
                .with_context(|| format!("writing --trace-out {path}"))?;
        }
    }
    println!("{}", out.to_string());
    Ok(())
}

fn cmd_cluster(args: &Args) -> Result<()> {
    use mana::cluster::JobSpec;

    let mut base = build_config(args)?;
    if base.staging.is_none() {
        // Multi-job tenancy IS the shared tiered store; staging is implied.
        base.staging = Some(StagingConfig::default());
    }
    let n = args.get_u64("jobs", 2)? as usize;
    if n == 0 {
        bail!("--jobs must be >= 1");
    }
    let ckpt_every = args.get_u64("ckpt-every", 4)?;
    let weights: Vec<f64> = match args.get("drain-qos") {
        Some(spec) => {
            let ws: Vec<f64> = spec
                .split(',')
                .map(|w| w.trim().parse::<f64>())
                .collect::<Result<_, _>>()
                .with_context(|| format!("--drain-qos={spec}"))?;
            if ws.len() != n {
                bail!("--drain-qos lists {} weights for --jobs {n}", ws.len());
            }
            if ws.iter().any(|w| *w <= 0.0) {
                bail!("--drain-qos weights must be > 0");
            }
            ws
        }
        None => vec![1.0; n],
    };

    let mut specs = Vec::with_capacity(n);
    for (i, w) in weights.iter().enumerate() {
        let mut cfg = base.clone();
        cfg.job = format!("{}-t{i}", base.job);
        specs.push(JobSpec::new(cfg).weight(*w).ckpt_every(ckpt_every));
    }

    let hits = args.get_u64("preempt-storm", 0)? as u32;
    let window: f64 = args.get("storm-window").unwrap_or("30").parse()?;
    let down: f64 = args.get("storm-down").unwrap_or("10").parse()?;
    let seed = args.get_u64("seed", 42)?;
    let plan = preempt::storm_plan(n, hits, window, down, seed);
    let report = preempt::run_preemption_storm(specs, &plan)?;
    println!("{}", report.to_json().to_string());
    Ok(())
}

fn cmd_usage(args: &Args) -> Result<()> {
    let n = args.get_u64("jobs", 200_000)? as usize;
    let jobs = usage::sample_jobs(n, 2020);
    let rows = usage::census(&jobs);
    println!("NERSC 2020 application usage (synthetic census, {n} jobs)");
    println!("{:<16} {:>8}  cumulative", "app", "share%");
    let mut cum = 0.0;
    for (i, (app, share)) in rows.iter().take(20).enumerate() {
        cum += share;
        println!("{app:<16} {share:>7.2}%  {cum:>6.2}%  #{}", i + 1);
    }
    println!(
        "top-20 = {:.1}% of cycles (paper: ~70%); vasp = {:.1}% (paper: >20%)",
        usage::top_k_share(&rows, 20),
        rows[0].1
    );
    Ok(())
}

fn cmd_mapping(args: &Args) -> Result<()> {
    let ranks = args.get_u64("ranks", 8)? as u32;
    let threads = args.get_u64("threads", 8)? as u32;
    let topo = Topology::new(ranks, threads);
    print!("{}", topo.mapping_table());
    println!("{} ranks x {} threads = {} nodes", ranks, threads, topo.nodes());
    Ok(())
}

fn cmd_preempt(args: &Args) -> Result<()> {
    let ranks = args.get_u64("ranks", 8)? as u32;
    let mut low = RunConfig::new(AppKind::VaspRpa, ranks);
    low.job = "lowpri-rpa".into();
    low.mem_per_rank = Some(64 << 20);
    let mut rt = RunConfig::new(AppKind::Gromacs, ranks);
    rt.job = "realtime-md".into();
    rt.mem_per_rank = Some(64 << 20);
    let rep = preempt::run_preemption_scenario(low, rt, None, 3, 4, 5)?;
    println!(
        "{}",
        Json::obj()
            .set("ckpt_secs", rep.ckpt_secs)
            .set("realtime_secs", rep.realtime_secs)
            .set("restart_secs", rep.restart_secs)
            .set("lowpri_steps_final", rep.lowpri_steps_final)
            .set("deterministic", rep.deterministic)
            .to_string()
    );
    Ok(())
}

fn cmd_console(args: &Args) -> Result<()> {
    use mana::coordinator::console::run_script;
    let cfg = build_config(args)?;
    let engine = load_engine_if(&cfg)?;
    let script = args.get("script").unwrap_or("h; s");
    let sim = JobSim::launch(cfg, engine)?;
    let (replies, fs) = run_script(sim, script);
    for r in &replies {
        println!("{r}");
    }
    if let Some(fs) = fs {
        println!(
            "[storage tier survives: {} files, {} used]",
            fs.file_count(),
            mana::util::bytes::human(fs.used_bytes())
        );
    }
    Ok(())
}

fn cmd_advise(args: &Args) -> Result<()> {
    use mana::ckpt::interval::{daly_interval, efficiency, optimal_interval, young_interval};
    let c: f64 = args.get("ckpt-secs").unwrap_or("30").parse()?;
    let r: f64 = args.get("restart-secs").unwrap_or("26").parse()?;
    let mtbf: f64 = args.get("mtbf-hours").unwrap_or("24").parse::<f64>()? * 3600.0;
    let young = young_interval(c, mtbf);
    let daly = daly_interval(c, mtbf);
    let num = optimal_interval(c, r, mtbf);
    println!(
        "{}",
        Json::obj()
            .set("ckpt_secs", c)
            .set("restart_secs", r)
            .set("mtbf_hours", mtbf / 3600.0)
            .set("young_interval_secs", young)
            .set("daly_interval_secs", daly)
            .set("numeric_optimal_secs", num)
            .set("efficiency_at_optimum", efficiency(num, c, r, mtbf))
            .to_string()
    );
    Ok(())
}

fn cmd_artifacts() -> Result<()> {
    let engine = Engine::load(&default_artifact_dir())
        .context("loading AOT artifacts (run `make artifacts`?)")?;
    println!("platform: {}", engine.platform());
    for name in engine.artifact_names() {
        let spec = engine.spec(name).unwrap();
        println!(
            "  {name}: {} inputs, {} outputs ({})",
            spec.inputs.len(),
            spec.outputs.len(),
            spec.file
        );
    }
    Ok(())
}
