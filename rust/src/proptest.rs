//! Minimal property-based testing framework (proptest is unavailable
//! offline).
//!
//! Deterministic: every case derives from a fixed seed + case index, so a
//! failure report ("case #k, seed s") reproduces exactly. On failure the
//! runner retries with "smaller" cases generated from the same sub-seed
//! (shrinking-lite: generators are asked for progressively smaller sizes).
//!
//! ```no_run
//! use mana::proptest::run;
//! run("addition commutes", 100, |g| {
//!     let a = g.u64_below(1000);
//!     let b = g.u64_below(1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::prng::Xoshiro256;

/// Per-case random source with a size budget (shrinks on failure).
pub struct Gen {
    rng: Xoshiro256,
    /// Size multiplier in (0, 1]; generators scale their ranges by it.
    size: f64,
}

impl Gen {
    fn new(seed: u64, case: u64, size: f64) -> Self {
        Gen {
            rng: Xoshiro256::stream(seed, case),
            size,
        }
    }

    /// Uniform u64 in [0, n) scaled down when shrinking. Always < n.
    pub fn u64_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let eff = ((n as f64 * self.size).ceil() as u64).clamp(1, n);
        self.rng.next_below(eff)
    }

    /// Uniform in [lo, hi] (inclusive), biased toward lo when shrinking.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi >= lo);
        lo + self.u64_below(hi - lo + 1)
    }

    pub fn f64_unit(&mut self) -> f64 {
        self.rng.next_f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn bytes(&mut self, max_len: usize) -> Vec<u8> {
        let len = self.u64_below(max_len.max(1) as u64) as usize;
        (0..len).map(|_| (self.rng.next_u64() & 0xff) as u8).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.u64_below(items.len() as u64) as usize]
    }
}

/// Run `cases` random cases of `prop`. Panics with the reproducing case
/// number on failure, after attempting three shrunk re-runs to find a
/// smaller witness.
pub fn run(name: &str, cases: u64, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    let seed = crate::util::fnv1a(name.as_bytes());
    for case in 0..cases {
        let failed = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed, case, 1.0);
            prop(&mut g);
        })
        .is_err();
        if failed {
            // Shrinking-lite: re-run the failing case at smaller sizes to
            // report the smallest size that still fails.
            let mut smallest = 1.0;
            for &size in &[0.1, 0.25, 0.5] {
                let fails = std::panic::catch_unwind(|| {
                    let mut g = Gen::new(seed, case, size);
                    prop(&mut g);
                })
                .is_err();
                if fails {
                    smallest = size;
                    break;
                }
            }
            panic!(
                "property '{name}' failed at case #{case} (seed {seed:#x}, smallest failing size {smallest})"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        run("u64_below in range", 200, |g| {
            let n = g.range(1, 1000);
            assert!(g.u64_below(n) < n);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports_case() {
        run("always fails", 5, |_| panic!("nope"));
    }

    #[test]
    fn deterministic_cases() {
        // Same name + case index -> same values.
        let mut a = Gen::new(42, 7, 1.0);
        let mut b = Gen::new(42, 7, 1.0);
        for _ in 0..50 {
            assert_eq!(a.u64_below(1_000_000), b.u64_below(1_000_000));
        }
    }

    #[test]
    fn choose_covers_all() {
        let items = [1, 2, 3, 4];
        let mut g = Gen::new(1, 1, 1.0);
        let mut seen = [false; 4];
        for _ in 0..200 {
            seen[*g.choose(&items) as usize - 1] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
