//! Byte-size arithmetic and human-readable formatting.
//!
//! The simulator tracks *virtual* byte counts (checkpoint image sizes,
//! aggregate application memory) that reach terabytes; these helpers keep
//! the call sites and reports readable.

pub const KIB: u64 = 1 << 10;
pub const MIB: u64 = 1 << 20;
pub const GIB: u64 = 1 << 30;
pub const TIB: u64 = 1 << 40;

/// Format a byte count with a binary-unit suffix ("5.80 TiB").
pub fn human(bytes: u64) -> String {
    let b = bytes as f64;
    if bytes >= TIB {
        format!("{:.2} TiB", b / TIB as f64)
    } else if bytes >= GIB {
        format!("{:.2} GiB", b / GIB as f64)
    } else if bytes >= MIB {
        format!("{:.2} MiB", b / MIB as f64)
    } else if bytes >= KIB {
        format!("{:.2} KiB", b / KIB as f64)
    } else {
        format!("{bytes} B")
    }
}

/// Parse sizes like "512MiB", "1.5GiB", "2TiB", "800" (bytes).
pub fn parse(s: &str) -> Option<u64> {
    let s = s.trim();
    let (num, unit): (&str, u64) = if let Some(p) = s.strip_suffix("TiB") {
        (p, TIB)
    } else if let Some(p) = s.strip_suffix("GiB") {
        (p, GIB)
    } else if let Some(p) = s.strip_suffix("MiB") {
        (p, MIB)
    } else if let Some(p) = s.strip_suffix("KiB") {
        (p, KIB)
    } else if let Some(p) = s.strip_suffix('B') {
        (p, 1)
    } else {
        (s, 1)
    };
    let v: f64 = num.trim().parse().ok()?;
    if v < 0.0 {
        return None;
    }
    Some((v * unit as f64).round() as u64)
}

/// GB/s-style bandwidth applied to a byte count -> seconds.
pub fn transfer_secs(bytes: u64, bytes_per_sec: f64) -> f64 {
    debug_assert!(bytes_per_sec > 0.0);
    bytes as f64 / bytes_per_sec
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_units() {
        assert_eq!(human(512), "512 B");
        assert_eq!(human(2 * KIB), "2.00 KiB");
        assert_eq!(human(3 * MIB), "3.00 MiB");
        assert_eq!(human(GIB + GIB / 2), "1.50 GiB");
        assert_eq!(human(58 * TIB / 10), "5.80 TiB");
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(parse("512MiB"), Some(512 * MIB));
        assert_eq!(parse("1.5GiB"), Some(GIB + GIB / 2));
        assert_eq!(parse("2TiB"), Some(2 * TIB));
        assert_eq!(parse("800"), Some(800));
        assert_eq!(parse(" 4 KiB "), Some(4 * KIB));
        assert_eq!(parse("-1"), None);
        assert_eq!(parse("junk"), None);
    }

    #[test]
    fn transfer_time() {
        // 6 GiB at 6 GiB/s is one second.
        let t = transfer_secs(6 * GIB, 6.0 * GIB as f64);
        assert!((t - 1.0).abs() < 1e-12);
    }
}
