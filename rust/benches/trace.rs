//! TRACE — observability acceptance: tracing overhead + reconciliation.
//!
//! The span recorder is only trustworthy if (a) it is cheap enough to
//! leave on for production-sized jobs and (b) the timeline it records is
//! the timeline the checkpoint actually took. Asserted here:
//!
//!   * **overhead**: a 512-rank staged checkpoint with `cfg.trace` on
//!     must stay within 3% of the untraced wall-clock (min-of-N);
//!   * **reconciliation**: every `CkptReport` timing field re-derives
//!     from the span record within `RECONCILE_EPS` across
//!     flat/tree x serial/pipelined shapes at 512 ranks, and the
//!     recorder's own self-check emitted no `trace.reconcile` events;
//!   * **critical path**: the extracted chain's charges sum to the
//!     checkpoint wall time (the walk telescopes, nothing is dropped);
//!   * a Perfetto/chrome://tracing export of the traced run is written
//!     to `trace.json` for the CI artifact upload.
//!
//! Results land in BENCH_trace.json; the CI bench-report job gates on
//! `trace_overhead_512` and `trace_reconcile_mismatches`.

use mana::benchkit::{fsecs, time, Report};
use mana::config::{AppKind, RunConfig};
use mana::coordinator::CkptReport;
use mana::sim::JobSim;
use mana::trace;
use mana::trace::critical_path::{critical_path, top_k_summary};
use mana::util::json::Json;

const RANKS: u32 = 512;
/// ~32 GB aggregate: big enough that the encode/write model dominates,
/// small enough for a min-of-N wall-clock loop.
const MEM_PER_RANK: u64 = 64 << 20;

fn base_cfg(tag: &str, traced: bool) -> RunConfig {
    let mut cfg = RunConfig::new(AppKind::Synthetic, RANKS).with_staging();
    cfg.job = format!("trace-{tag}");
    cfg.mem_per_rank = Some(MEM_PER_RANK);
    cfg.trace = traced;
    cfg
}

/// Launch, run one superstep, checkpoint. Returns the sim (for its
/// tracer) and the checkpoint report; the overhead loop discards both.
fn one_run(cfg: &RunConfig) -> (JobSim, CkptReport) {
    let mut sim = JobSim::launch(cfg.clone(), None).expect("launch");
    sim.run_steps(1).expect("steps");
    let rep = sim.checkpoint().expect("ckpt");
    (sim, rep)
}

/// Traced-vs-untraced host wall-clock at 512 ranks. Min-of-N on both
/// sides so scheduler noise cancels; the ratio is the gated overhead.
fn overhead_512(rep: &mut Report) -> f64 {
    let off = base_cfg("overhead-off", false);
    let on = base_cfg("overhead-on", true);
    let (off_mean, off_min) = time(1, 5, || {
        let _ = one_run(&off);
    });
    let (on_mean, on_min) = time(1, 5, || {
        let _ = one_run(&on);
    });
    let ratio = on_min / off_min;
    rep.row(vec![
        "untraced".into(),
        fsecs(off_min),
        fsecs(off_mean),
        "1.00x".into(),
    ]);
    rep.row(vec![
        "traced".into(),
        fsecs(on_min),
        fsecs(on_mean),
        format!("{ratio:.3}x"),
    ]);
    ratio
}

/// One traced checkpoint per coordination/pipeline shape; returns the
/// number of report fields the span record failed to reproduce, plus any
/// self-check events the recorder logged during the run.
fn reconcile_shapes() -> (u64, Json) {
    let shapes: [(&str, Option<u32>, bool); 4] = [
        ("flat-serial", None, false),
        ("flat-pipelined", None, true),
        ("tree4-serial", Some(4), false),
        ("tree4-pipelined", Some(4), true),
    ];
    let mut mismatches = 0u64;
    let mut rows = Json::Arr(vec![]);
    for (tag, fanout, pipelined) in shapes {
        let mut cfg = base_cfg(tag, true);
        cfg.pipeline = pipelined;
        if let Some(f) = fanout {
            cfg = cfg.with_coord_tree(f);
        }
        let (sim, rep) = one_run(&cfg);
        let spans = sim.tracer.spans();
        // Re-derive the report from spans; the checkpoint path also runs
        // this check itself and logs trace.reconcile events on failure.
        let errs = trace::reconcile(&spans, 0, &rep);
        for e in &errs {
            eprintln!("{tag}: reconcile mismatch: {e}");
        }
        mismatches += errs.len() as u64;
        mismatches += sim.tracer.event_count("trace.reconcile:g0");

        // The critical path must telescope to the checkpoint wall time.
        let path = critical_path(&spans, 0);
        assert!(!path.is_empty(), "{tag}: traced ckpt has no critical path");
        let sum: f64 = path.iter().map(|e| e.secs).sum();
        if (sum - rep.total_secs).abs() > 1e-6 * rep.total_secs.max(1.0) {
            eprintln!(
                "{tag}: critical path sums to {sum:.6}s, report says {:.6}s",
                rep.total_secs
            );
            mismatches += 1;
        }
        rows.push(
            Json::obj()
                .set("shape", tag)
                .set("spans", spans.len() as u64)
                .set("total_secs", rep.total_secs)
                .set("critical_path_secs", sum)
                .set("report_mismatches", errs.len() as u64)
                .set("critical_path_top3", top_k_summary(&path, 3).as_str()),
        );
        println!(
            "{tag}: {} spans, critical path: {}",
            spans.len(),
            top_k_summary(&path, 3)
        );
    }
    (mismatches, rows)
}

fn main() {
    let mut rep = Report::new(
        "TRACE: 512-rank staged checkpoint, traced vs untraced wall-clock",
        vec!["mode", "wall_min", "wall_mean", "overhead"],
    );
    let overhead = overhead_512(&mut rep);
    let overhead_table = rep.finish_json();

    let (mismatches, shape_rows) = reconcile_shapes();

    // Perfetto export of a full traced run (checkpoint + restart) for the
    // CI artifact: open in https://ui.perfetto.dev or chrome://tracing.
    let cfg = base_cfg("export", true);
    let (mut sim, _) = one_run(&cfg);
    sim.run_steps(1).expect("post-ckpt step");
    let pre = sim.tracer.clone();
    let fs = sim.kill();
    let (resumed, _rrep) =
        JobSim::restart_from(cfg, None, fs).expect("traced restart");
    resumed.tracer.adopt(&pre);
    let spans = resumed.tracer.spans();
    let counters = resumed.tracer.counters();
    let json = trace::perfetto::export(&spans, &counters);
    std::fs::write("trace.json", json.to_string()).expect("write trace.json");
    println!(
        "perfetto export: {} spans, {} counter samples -> trace.json",
        spans.len(),
        counters.len()
    );

    assert!(
        mismatches == 0,
        "span record failed to reproduce the checkpoint report \
         ({mismatches} mismatches; see stderr)"
    );
    assert!(
        overhead <= 1.03,
        "tracing overhead {overhead:.3}x exceeds the 3% budget"
    );

    let out = Json::obj()
        .set("bench", "trace")
        .set(
            "gates",
            Json::obj()
                .set("trace_overhead_512", overhead)
                .set("trace_reconcile_mismatches", mismatches),
        )
        .set("rows", shape_rows)
        .set("series", Json::Arr(vec![overhead_table]));
    std::fs::write("BENCH_trace.json", out.to_string())
        .expect("write BENCH_trace.json");
    println!(
        "TRACE OK: {overhead:.3}x overhead at 512 ranks, every report field \
         re-derived from spans (results in BENCH_trace.json)"
    );
}
