//! Content-addressed chunk index for the durable tier.
//!
//! The tiered engine's background drain used to re-stage every byte of
//! every checkpoint generation to the PFS, even though successive
//! checkpoints of MANA-style workloads are mostly identical memory. The
//! chunk store turns that drain near-incremental:
//!
//! * every chunk of an encoded image carries a 128-bit content digest
//!   ([`crate::ckpt::chunk::ChunkRecipe`], emitted by the image encoder);
//! * the durable tier stores **one object per unique digest**
//!   (`.chunkstore/<digest>` in the durable namespace) plus, per file, a
//!   *recipe* — the ordered digest list reassembly concatenates;
//! * a drain ships only chunks whose digest the index does not yet hold;
//!   everything else is "drained" by reference in zero simulated seconds;
//! * chunks are **refcounted**: each live recipe (queued or committed)
//!   holds one reference per occurrence, and an object is reclaimed only
//!   when the last referencing recipe is released — deleting or evicting a
//!   generation can never orphan a chunk a newer generation still needs.
//!
//! This module owns the pure bookkeeping (index + recipes + refcounts);
//! [`crate::fs::TieredStore`] drives the actual durable-tier object IO.

use std::collections::BTreeMap;

use crate::ckpt::chunk::ChunkRecipe;

/// Durable-namespace prefix for chunk objects (kept out of the logical
/// file listing).
pub const OBJECT_PREFIX: &str = ".chunkstore/";

/// Durable-tier path of the persisted chunk index itself. Written after
/// every commit-mutating operation so a durable-only restart can rebuild
/// the index without the in-memory store surviving.
pub const INDEX_PATH: &str = ".chunkstore/INDEX";

/// Magic prefix of the persisted index (framing sanity before the digest).
const INDEX_MAGIC: &[u8; 8] = b"MANACIDX";

/// Durable-tier path of a chunk object.
pub fn object_path(digest: u128) -> String {
    format!("{OBJECT_PREFIX}{digest:032x}")
}

/// One indexed chunk.
#[derive(Clone, Copy, Debug)]
pub struct ChunkEntry {
    /// Live references: one per occurrence in every queued or committed
    /// recipe.
    pub refs: u64,
    /// Virtual bytes the chunk accounts for (capacity/bandwidth charge).
    pub vbytes: u64,
    /// Whether the object's bytes are durable yet (a referenced chunk may
    /// still be in flight on the drain queue).
    pub stored: bool,
    /// Digest of the *stored object bytes*, recorded at store time;
    /// reassembly re-derives it to reject corrupted or swapped objects.
    pub content: u128,
}

/// Outcome of referencing one recipe into the index.
#[derive(Clone, Copy, Debug, Default)]
pub struct RefOutcome {
    /// Virtual bytes of chunks this recipe must physically ship.
    pub ship_vbytes: u64,
    /// Virtual bytes satisfied by reference to already-indexed chunks.
    pub deduped_vbytes: u64,
    /// Subset of `deduped_vbytes` satisfied by chunks the referencing job
    /// held no reference to — the dedup credit one tenant earns from
    /// another tenant's checkpoints (multi-job shared chunk store).
    pub cross_job_vbytes: u64,
}

/// A chunk whose last reference was just dropped (GC candidate).
#[derive(Clone, Copy, Debug)]
pub struct DeadChunk {
    pub digest: u128,
    /// Whether object bytes were durable (the caller deletes them).
    pub stored: bool,
    pub vbytes: u64,
}

/// The index + recipe table. Rides [`crate::fs::TieredStore`] (and so
/// survives a job kill alongside the file systems).
///
/// Multi-job tenancy: references are attributed to the owning *job* (the
/// first path component of the referencing file). `job_refs` tracks how
/// many of each chunk's references each job holds, so (a) a dedup hit
/// against a chunk only *other* jobs hold is reported as cross-job
/// dedup, and (b) one job releasing its last reference can never reclaim
/// an object another job still needs — the total refcount stays the
/// single source of GC truth and only hits zero when every job let go.
#[derive(Clone, Debug, Default)]
pub struct ChunkStore {
    index: BTreeMap<u128, ChunkEntry>,
    recipes: BTreeMap<String, ChunkRecipe>,
    /// Per-chunk, per-job reference counts (GC-isolation observability;
    /// rebuilt from recipe paths on index decode).
    job_refs: BTreeMap<u128, BTreeMap<String, u64>>,
}

/// Job a path belongs to: its first `/`-separated component (the run
/// config's job name prefixes every path a job writes).
pub fn job_of(path: &str) -> &str {
    match path.find('/') {
        Some(i) => &path[..i],
        None => path,
    }
}

impl ChunkStore {
    /// Take one reference per chunk occurrence in `recipe`, unattributed
    /// (single-tenant callers and unit tests; equivalent to
    /// [`ChunkStore::reference_for`] with an empty job name).
    pub fn reference(&mut self, recipe: &ChunkRecipe) -> RefOutcome {
        self.reference_for("", recipe)
    }

    /// Take one reference per chunk occurrence in `recipe` on behalf of
    /// `job`. Chunks seen for the first time are the caller's to ship;
    /// the rest dedup — and a hit against a chunk `job` itself holds no
    /// reference to is additionally counted as cross-job dedup.
    pub fn reference_for(&mut self, job: &str, recipe: &ChunkRecipe) -> RefOutcome {
        let mut out = RefOutcome::default();
        for c in &recipe.chunks {
            match self.index.get_mut(&c.digest) {
                Some(e) => {
                    e.refs += 1;
                    out.deduped_vbytes += c.vbytes;
                    let holders = self.job_refs.entry(c.digest).or_default();
                    if !holders.contains_key(job) {
                        out.cross_job_vbytes += c.vbytes;
                    }
                    *holders.entry(job.to_string()).or_insert(0) += 1;
                }
                None => {
                    self.index.insert(
                        c.digest,
                        ChunkEntry {
                            refs: 1,
                            vbytes: c.vbytes,
                            stored: false,
                            content: 0,
                        },
                    );
                    self.job_refs
                        .entry(c.digest)
                        .or_default()
                        .insert(job.to_string(), 1);
                    out.ship_vbytes += c.vbytes;
                }
            }
        }
        out
    }

    /// Drop one reference per chunk occurrence in `recipe`, unattributed
    /// (see [`ChunkStore::release_for`]).
    pub fn release(&mut self, recipe: &ChunkRecipe) -> Vec<DeadChunk> {
        self.release_for("", recipe)
    }

    /// Drop one of `job`'s references per chunk occurrence in `recipe`.
    /// Returns every chunk whose *total* refcount hit zero — the caller
    /// deletes the stored objects from the durable tier. A chunk another
    /// job still references survives regardless of what `job` releases.
    pub fn release_for(&mut self, job: &str, recipe: &ChunkRecipe) -> Vec<DeadChunk> {
        let mut dead = Vec::new();
        for c in &recipe.chunks {
            if let Some(e) = self.index.get_mut(&c.digest) {
                e.refs = e.refs.saturating_sub(1);
                if let Some(holders) = self.job_refs.get_mut(&c.digest) {
                    if let Some(n) = holders.get_mut(job) {
                        *n = n.saturating_sub(1);
                        if *n == 0 {
                            holders.remove(job);
                        }
                    }
                    if holders.is_empty() {
                        self.job_refs.remove(&c.digest);
                    }
                }
                if e.refs == 0 {
                    let stored = e.stored;
                    let vbytes = e.vbytes;
                    self.index.remove(&c.digest);
                    self.job_refs.remove(&c.digest);
                    dead.push(DeadChunk {
                        digest: c.digest,
                        stored,
                        vbytes,
                    });
                }
            }
        }
        dead
    }

    /// References `job` holds on `digest` (GC-isolation observability).
    pub fn job_refs(&self, digest: u128, job: &str) -> u64 {
        self.job_refs
            .get(&digest)
            .and_then(|h| h.get(job))
            .copied()
            .unwrap_or(0)
    }

    /// Record that a chunk's object bytes are durable, with the content
    /// digest reassembly will verify against.
    pub fn mark_stored(&mut self, digest: u128, content: u128) {
        if let Some(e) = self.index.get_mut(&digest) {
            e.stored = true;
            e.content = content;
        }
    }

    pub fn is_stored(&self, digest: u128) -> bool {
        self.index.get(&digest).is_some_and(|e| e.stored)
    }

    pub fn entry(&self, digest: u128) -> Option<ChunkEntry> {
        self.index.get(&digest).copied()
    }

    /// Persist `recipe` as the durable description of `path`, returning
    /// the replaced recipe (whose references the caller must release).
    pub fn commit(&mut self, path: &str, recipe: ChunkRecipe) -> Option<ChunkRecipe> {
        self.recipes.insert(path.to_string(), recipe)
    }

    pub fn recipe(&self, path: &str) -> Option<&ChunkRecipe> {
        self.recipes.get(path)
    }

    pub fn remove_recipe(&mut self, path: &str) -> Option<ChunkRecipe> {
        self.recipes.remove(path)
    }

    /// Logical (recipe-backed) durable paths.
    pub fn recipe_paths(&self) -> Vec<String> {
        self.recipes.keys().cloned().collect()
    }

    pub fn recipe_count(&self) -> usize {
        self.recipes.len()
    }

    /// Unique chunks currently indexed (stored + in flight).
    pub fn chunk_count(&self) -> usize {
        self.index.len()
    }

    /// Virtual bytes of unique stored chunks (the physical durable
    /// footprint the dedup saves against).
    pub fn stored_vbytes(&self) -> u64 {
        self.index
            .values()
            .filter(|e| e.stored)
            .map(|e| e.vbytes)
            .sum()
    }

    /// Digests whose object bytes are recorded durable (reload
    /// verification walks these against the durable tier).
    pub fn stored_digests(&self) -> Vec<u128> {
        self.index
            .iter()
            .filter(|(_, e)| e.stored)
            .map(|(d, _)| *d)
            .collect()
    }

    // ------------------------------------------------ persisted index

    /// Serialize the *committed* durable state — the recipe table plus
    /// every chunk entry a committed recipe references — with digest
    /// framing: `MAGIC | payload | digest128(MAGIC | payload)`.
    ///
    /// Queued-but-uncommitted references are deliberately excluded: they
    /// describe in-flight drain state, and the drain queue re-takes them
    /// on reload ([`crate::fs::TieredStore::reload_index`]). Refcounts are
    /// therefore not serialized either — they are recomputed from the
    /// decoded recipes.
    pub fn encode_index(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(INDEX_MAGIC);
        out.extend_from_slice(&(self.recipes.len() as u32).to_le_bytes());
        for (path, rec) in &self.recipes {
            let pb = path.as_bytes();
            out.extend_from_slice(&(pb.len() as u32).to_le_bytes());
            out.extend_from_slice(pb);
            out.extend_from_slice(&rec.chunk_bytes.to_le_bytes());
            out.extend_from_slice(&rec.file_vbytes.to_le_bytes());
            out.extend_from_slice(&(rec.chunks.len() as u32).to_le_bytes());
            for c in &rec.chunks {
                out.extend_from_slice(&c.digest.to_le_bytes());
                out.extend_from_slice(&c.vbytes.to_le_bytes());
                out.extend_from_slice(&c.real_off.to_le_bytes());
                out.extend_from_slice(&c.real_len.to_le_bytes());
            }
        }
        let mut committed: BTreeMap<u128, &ChunkEntry> = BTreeMap::new();
        for rec in self.recipes.values() {
            for c in &rec.chunks {
                if let Some(e) = self.index.get(&c.digest) {
                    committed.insert(c.digest, e);
                }
            }
        }
        out.extend_from_slice(&(committed.len() as u32).to_le_bytes());
        for (digest, e) in &committed {
            out.extend_from_slice(&digest.to_le_bytes());
            out.extend_from_slice(&e.vbytes.to_le_bytes());
            out.push(e.stored as u8);
            out.extend_from_slice(&e.content.to_le_bytes());
        }
        let d = crate::util::digest::digest128(&out);
        out.extend_from_slice(&d.to_le_bytes());
        out
    }

    /// Decode and verify a persisted index: framing digest, magic, and
    /// recipe/entry cross-consistency (every recipe chunk must be
    /// described by the entry table). Returns `None` on any mismatch.
    /// Refcounts come back as the committed-recipe occurrence counts.
    pub fn decode_index(bytes: &[u8]) -> Option<ChunkStore> {
        fn take<'a>(b: &'a [u8], pos: &mut usize, n: usize) -> Option<&'a [u8]> {
            let s = b.get(*pos..*pos + n)?;
            *pos += n;
            Some(s)
        }
        fn r_u32(b: &[u8], pos: &mut usize) -> Option<u32> {
            Some(u32::from_le_bytes(take(b, pos, 4)?.try_into().ok()?))
        }
        fn r_u64(b: &[u8], pos: &mut usize) -> Option<u64> {
            Some(u64::from_le_bytes(take(b, pos, 8)?.try_into().ok()?))
        }
        fn r_u128(b: &[u8], pos: &mut usize) -> Option<u128> {
            Some(u128::from_le_bytes(take(b, pos, 16)?.try_into().ok()?))
        }

        if bytes.len() < INDEX_MAGIC.len() + 16 {
            return None;
        }
        let (payload, trailer) = bytes.split_at(bytes.len() - 16);
        let want = u128::from_le_bytes(trailer.try_into().ok()?);
        if crate::util::digest::digest128(payload) != want {
            return None;
        }
        if &payload[..INDEX_MAGIC.len()] != INDEX_MAGIC {
            return None;
        }
        let mut pos = INDEX_MAGIC.len();
        let n_recipes = r_u32(payload, &mut pos)?;
        let mut recipes = BTreeMap::new();
        for _ in 0..n_recipes {
            let plen = r_u32(payload, &mut pos)? as usize;
            let path = std::str::from_utf8(take(payload, &mut pos, plen)?)
                .ok()?
                .to_string();
            let chunk_bytes = r_u64(payload, &mut pos)?;
            let file_vbytes = r_u64(payload, &mut pos)?;
            let n_chunks = r_u32(payload, &mut pos)? as usize;
            let mut chunks = Vec::with_capacity(n_chunks.min(1 << 20));
            for _ in 0..n_chunks {
                chunks.push(crate::ckpt::chunk::RecipeChunk {
                    digest: r_u128(payload, &mut pos)?,
                    vbytes: r_u64(payload, &mut pos)?,
                    real_off: r_u64(payload, &mut pos)?,
                    real_len: r_u64(payload, &mut pos)?,
                });
            }
            recipes.insert(
                path,
                ChunkRecipe {
                    chunk_bytes,
                    file_vbytes,
                    chunks,
                },
            );
        }
        let n_entries = r_u32(payload, &mut pos)?;
        let mut index: BTreeMap<u128, ChunkEntry> = BTreeMap::new();
        for _ in 0..n_entries {
            let digest = r_u128(payload, &mut pos)?;
            let vbytes = r_u64(payload, &mut pos)?;
            let stored = take(payload, &mut pos, 1)?[0] != 0;
            let content = r_u128(payload, &mut pos)?;
            index.insert(
                digest,
                ChunkEntry {
                    refs: 0,
                    vbytes,
                    stored,
                    content,
                },
            );
        }
        if pos != payload.len() {
            return None; // trailing garbage under a somehow-valid digest
        }
        // Recompute committed refcounts; a recipe chunk the entry table
        // does not describe is an inconsistency, not a zero-ref chunk.
        // Job attribution comes back from the recipe paths (job = first
        // path component), so per-job GC isolation survives a restart.
        let mut job_refs: BTreeMap<u128, BTreeMap<String, u64>> = BTreeMap::new();
        for (path, rec) in &recipes {
            let job = job_of(path);
            for c in &rec.chunks {
                index.get_mut(&c.digest)?.refs += 1;
                *job_refs
                    .entry(c.digest)
                    .or_default()
                    .entry(job.to_string())
                    .or_insert(0) += 1;
            }
        }
        index.retain(|_, e| e.refs > 0);
        Some(ChunkStore {
            index,
            recipes,
            job_refs,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckpt::chunk::ChunkRecipe;

    fn recipe(data: &[u8]) -> ChunkRecipe {
        ChunkRecipe::from_data(data, 4, data.len() as u64)
    }

    #[test]
    fn first_reference_ships_second_dedups() {
        let mut cs = ChunkStore::default();
        let r = recipe(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let first = cs.reference(&r);
        assert_eq!(first.ship_vbytes, 8);
        assert_eq!(first.deduped_vbytes, 0);
        let second = cs.reference(&r);
        assert_eq!(second.ship_vbytes, 0);
        assert_eq!(second.deduped_vbytes, 8);
        assert_eq!(cs.chunk_count(), 2);
    }

    #[test]
    fn release_reclaims_only_at_zero_refs() {
        let mut cs = ChunkStore::default();
        let r = recipe(&[1, 2, 3, 4, 5, 6, 7, 8]);
        cs.reference(&r);
        cs.reference(&r);
        assert!(cs.release(&r).is_empty(), "one live reference remains");
        let dead = cs.release(&r);
        assert_eq!(dead.len(), 2, "both chunks reclaimed at zero refs");
        assert_eq!(cs.chunk_count(), 0);
    }

    #[test]
    fn intra_recipe_duplicates_count_per_occurrence() {
        // A recipe with two identical chunks (e.g. an all-zero region)
        // takes two references; releasing it reclaims cleanly.
        let mut cs = ChunkStore::default();
        let r = recipe(&[7, 7, 7, 7, 7, 7, 7, 7]); // two chunks, same digest
        assert_eq!(r.chunks[0].digest, r.chunks[1].digest);
        let out = cs.reference(&r);
        assert_eq!(out.ship_vbytes, 4, "first occurrence ships");
        assert_eq!(out.deduped_vbytes, 4, "second occurrence dedups");
        assert_eq!(cs.chunk_count(), 1);
        assert_eq!(cs.entry(r.chunks[0].digest).unwrap().refs, 2);
        assert_eq!(cs.release(&r).len(), 1);
        assert_eq!(cs.chunk_count(), 0);
    }

    #[test]
    fn commit_replaces_and_returns_old_recipe() {
        let mut cs = ChunkStore::default();
        let r1 = recipe(&[1, 1, 1, 1]);
        let r2 = recipe(&[2, 2, 2, 2]);
        cs.reference(&r1);
        assert!(cs.commit("f", r1.clone()).is_none());
        cs.reference(&r2);
        let old = cs.commit("f", r2).expect("old recipe returned");
        assert_eq!(old, r1);
        assert_eq!(cs.recipe_count(), 1);
    }

    #[test]
    fn index_roundtrips_committed_state() {
        let mut cs = ChunkStore::default();
        let r1 = recipe(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let r2 = recipe(&[9, 9, 9, 9]);
        cs.reference(&r1);
        cs.reference(&r2);
        cs.mark_stored(r1.chunks[0].digest, 0x11);
        cs.mark_stored(r1.chunks[1].digest, 0x22);
        cs.mark_stored(r2.chunks[0].digest, 0x33);
        cs.commit("a", r1.clone());
        cs.commit("b", r2.clone());
        let enc = cs.encode_index();
        let back = ChunkStore::decode_index(&enc).expect("framing verifies");
        assert_eq!(back.recipe_count(), 2);
        assert_eq!(back.recipe("a"), Some(&r1));
        assert_eq!(back.recipe("b"), Some(&r2));
        assert_eq!(back.chunk_count(), 3);
        assert!(back.is_stored(r2.chunks[0].digest));
        let e = back.entry(r1.chunks[0].digest).unwrap();
        assert_eq!(e.content, 0x11);
        assert_eq!(e.refs, 1, "refs recomputed from committed recipes");
        assert_eq!(back.encode_index(), enc, "re-encode is stable");
    }

    #[test]
    fn index_excludes_uncommitted_references() {
        let mut cs = ChunkStore::default();
        let queued = recipe(&[1, 1, 1, 1]);
        let done = recipe(&[2, 2, 2, 2]);
        cs.reference(&queued); // still on the drain queue — not persisted
        cs.reference(&done);
        cs.mark_stored(done.chunks[0].digest, 7);
        cs.commit("done", done);
        let back = ChunkStore::decode_index(&cs.encode_index()).unwrap();
        assert_eq!(back.recipe_count(), 1);
        assert_eq!(back.chunk_count(), 1, "queued-only chunk not persisted");
    }

    #[test]
    fn index_decode_rejects_corruption() {
        let mut cs = ChunkStore::default();
        let r = recipe(&[5, 6, 7, 8]);
        cs.reference(&r);
        cs.mark_stored(r.chunks[0].digest, 1);
        cs.commit("f", r);
        let enc = cs.encode_index();
        assert!(ChunkStore::decode_index(&enc).is_some());
        // Payload bit flip -> digest mismatch.
        let mut bad = enc.clone();
        bad[10] ^= 0x40;
        assert!(ChunkStore::decode_index(&bad).is_none());
        // Trailer flip -> digest mismatch.
        let mut bad = enc.clone();
        let last = bad.len() - 1;
        bad[last] ^= 1;
        assert!(ChunkStore::decode_index(&bad).is_none());
        // Truncation -> framing failure.
        assert!(ChunkStore::decode_index(&enc[..enc.len() - 5]).is_none());
        assert!(ChunkStore::decode_index(b"short").is_none());
    }

    #[test]
    fn cross_job_dedup_and_gc_isolation() {
        let mut cs = ChunkStore::default();
        let r = recipe(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let a = cs.reference_for("jobA", &r);
        assert_eq!(a.ship_vbytes, 8);
        assert_eq!(a.cross_job_vbytes, 0);
        // Same job referencing again: dedup, but not cross-job dedup.
        let a2 = cs.reference_for("jobA", &r);
        assert_eq!(a2.deduped_vbytes, 8);
        assert_eq!(a2.cross_job_vbytes, 0);
        // Another tenant hits jobA's chunks: full cross-job credit.
        let b = cs.reference_for("jobB", &r);
        assert_eq!(b.deduped_vbytes, 8);
        assert_eq!(b.cross_job_vbytes, 8);
        assert_eq!(cs.job_refs(r.chunks[0].digest, "jobA"), 2);
        assert_eq!(cs.job_refs(r.chunks[0].digest, "jobB"), 1);
        // jobA releasing everything it holds reclaims nothing while
        // jobB's reference is live.
        assert!(cs.release_for("jobA", &r).is_empty());
        assert!(cs.release_for("jobA", &r).is_empty());
        assert_eq!(cs.job_refs(r.chunks[0].digest, "jobA"), 0);
        assert_eq!(cs.chunk_count(), 2, "jobB keeps the chunks alive");
        let dead = cs.release_for("jobB", &r);
        assert_eq!(dead.len(), 2, "last job out reclaims");
        assert_eq!(cs.chunk_count(), 0);
    }

    #[test]
    fn decode_rebuilds_job_attribution_from_recipe_paths() {
        let mut cs = ChunkStore::default();
        let r = recipe(&[1, 2, 3, 4, 5, 6, 7, 8]);
        cs.reference_for("j1", &r);
        cs.reference_for("j2", &r);
        cs.mark_stored(r.chunks[0].digest, 1);
        cs.mark_stored(r.chunks[1].digest, 2);
        cs.commit("j1/ckpt/g0/f", r.clone());
        cs.commit("j2/ckpt/g0/f", r.clone());
        let back = ChunkStore::decode_index(&cs.encode_index()).unwrap();
        assert_eq!(back.job_refs(r.chunks[0].digest, "j1"), 1);
        assert_eq!(back.job_refs(r.chunks[0].digest, "j2"), 1);
        // A third job hitting the rebuilt index earns cross-job credit.
        let mut back = back;
        let o = back.reference_for("j3", &r);
        assert_eq!(o.cross_job_vbytes, 8);
    }

    #[test]
    fn stored_tracking() {
        let mut cs = ChunkStore::default();
        let r = recipe(&[9, 9, 9, 9]);
        cs.reference(&r);
        let d = r.chunks[0].digest;
        assert!(!cs.is_stored(d));
        cs.mark_stored(d, 0xABCD);
        assert!(cs.is_stored(d));
        assert_eq!(cs.entry(d).unwrap().content, 0xABCD);
        assert_eq!(cs.stored_vbytes(), 4);
    }
}
