//! Tiered storage engine: fast tier (Burst Buffer) + durable tier
//! (Lustre) with asynchronous BB→PFS staging.
//!
//! The paper's scalability result is that checkpoint overhead is dominated
//! by the storage tier: at 512 ranks, Burst Buffers beat Lustre by >20x on
//! write. Its future work asks for "reducing the checkpoint overhead for
//! large-scale applications". Multi-level checkpointing (SCR-style) is the
//! standard answer, modeled here:
//!
//! * A checkpoint **completes when the fast-tier write lands** — that is
//!   the only stall the ranks observe.
//! * Every written file is queued for a **background drain** to the
//!   durable tier; node-local drain agents move bytes on the simulated
//!   clock across subsequent supersteps ([`TieredStore::drain_to`]), at
//!   chunk granularity (see [`crate::ckpt::chunk`]).
//! * **Eviction** keeps the last `keep_fulls` checkpoint generations
//!   resident on the fast tier; when a new wave doesn't fit, older
//!   *drained* generations are deleted from the fast tier (their durable
//!   copies remain restartable).
//! * **Backpressure**: if an undrained older generation must be evicted
//!   to make room, it is force-drained synchronously first and the time
//!   is charged to the checkpoint stall — the engine never drops the only
//!   copy of an image.
//!
//! Restart reads prefer the fast tier per file and fall back to the
//! durable tier ([`TieredStore::read_preferred`]); CRC-level fallback
//! across tiers lives in the restart engine (`sim::restart_from`), which
//! re-reads a corrupt fast-tier image from the durable tier.

use std::collections::VecDeque;

use super::{FileSystem, FsError, IoReport, StorageTier, WriteReq};
use crate::ckpt::chunk::CHUNK_BYTES;
use crate::topology::NodeId;
use crate::{log_debug, log_info, log_warn};

/// Aggregate drain/eviction counters (reported by benches and `mana run`).
#[derive(Clone, Copy, Debug, Default)]
pub struct DrainStats {
    /// Bytes staged to the durable tier (background + forced).
    pub drained_bytes: u64,
    /// Files whose durable copy completed.
    pub drained_files: u64,
    /// Durable-tier seconds spent draining (background + forced).
    pub busy_secs: f64,
    /// Subset of `busy_secs` charged synchronously as backpressure.
    pub forced_secs: f64,
    pub evicted_generations: u64,
    pub evicted_files: u64,
    /// Drain completions that failed (source vanished, durable tier full).
    pub drain_errors: u64,
}

/// One file queued for staging to the durable tier.
#[derive(Clone, Debug)]
struct DrainItem {
    path: String,
    remaining: u64,
}

/// One checkpoint generation's fast-tier footprint (for eviction).
#[derive(Clone, Debug, Default)]
struct Generation {
    paths: Vec<String>,
}

/// Outcome of one checkpoint write wave on the tiered store.
#[derive(Clone, Copy, Debug, Default)]
pub struct StagedIo {
    /// Fast-tier wave time — the rank-visible checkpoint stall.
    pub fast_secs: f64,
    pub fast_bytes: u64,
    /// Synchronous durable-tier seconds forced by backpressure.
    pub backpressure_secs: f64,
    /// Bytes the backpressure force-drain moved to the durable tier.
    pub durable_bytes: u64,
    pub evicted_files: usize,
    /// Bytes queued for background drain after this wave.
    pub pending_bytes: u64,
    pub writers: usize,
}

impl StagedIo {
    /// Collapse into the generic wave report (duration = total stall).
    pub fn io(&self) -> IoReport {
        IoReport {
            duration: self.fast_secs + self.backpressure_secs,
            total_virtual_bytes: self.fast_bytes,
            writers: self.writers,
        }
    }
}

/// Outcome of one background drain tick.
#[derive(Clone, Copy, Debug, Default)]
pub struct DrainTick {
    pub drained_bytes: u64,
    pub completed_files: usize,
    pub queue_empty: bool,
}

/// Fast tier + durable tier + drain queue. See the module docs.
#[derive(Clone, Debug)]
pub struct TieredStore {
    fast: FileSystem,
    durable: FileSystem,
    queue: VecDeque<DrainItem>,
    generations: VecDeque<Generation>,
    /// Checkpoint generations kept resident on the fast tier (including
    /// the one currently being written).
    pub keep_fulls: usize,
    /// Node count backing the drain agents (one agent per node).
    nodes: u32,
    /// Virtual time up to which the background drain has already worked.
    clock: f64,
    /// Fractional-byte credit carried between ticks (chunk-granular
    /// draining would otherwise lose sub-chunk budgets).
    credit: f64,
    pub stats: DrainStats,
}

impl TieredStore {
    pub fn new(fast: FileSystem, durable: FileSystem, keep_fulls: usize, nodes: u32) -> Self {
        TieredStore {
            fast,
            durable,
            queue: VecDeque::new(),
            generations: VecDeque::new(),
            keep_fulls: keep_fulls.max(1),
            nodes: nodes.max(1),
            clock: 0.0,
            credit: 0.0,
            stats: DrainStats::default(),
        }
    }

    pub fn fast(&self) -> &FileSystem {
        &self.fast
    }

    pub fn durable(&self) -> &FileSystem {
        &self.durable
    }

    pub fn fast_mut(&mut self) -> &mut FileSystem {
        &mut self.fast
    }

    pub fn durable_mut(&mut self) -> &mut FileSystem {
        &mut self.durable
    }

    /// Bytes still queued for staging to the durable tier.
    pub fn pending_bytes(&self) -> u64 {
        self.queue.iter().map(|i| i.remaining).sum()
    }

    pub fn pending_files(&self) -> usize {
        self.queue.len()
    }

    /// Effective durable-tier drain bandwidth: one drain agent per node
    /// (the SCR model — few well-behaved writers, not a 512-rank storm).
    pub fn drain_bandwidth(&self) -> f64 {
        self.durable
            .write_bandwidth(self.nodes as usize, self.nodes)
    }

    /// Open a new checkpoint generation and sync the drain clock (drain
    /// credit earned before `now` was already granted via `drain_to`).
    pub fn begin_ckpt(&mut self, now_secs: f64) {
        self.clock = self.clock.max(now_secs);
        self.generations.push_back(Generation::default());
    }

    /// Advance the drain clock without granting drain credit (e.g. across
    /// the synchronous checkpoint stall, during which the agents hold off).
    pub fn sync_clock(&mut self, now_secs: f64) {
        self.clock = self.clock.max(now_secs);
    }

    /// Rebase the drain clock onto a fresh timeline (restart: the store
    /// survives the kill, but the restarted job's virtual clock starts
    /// over — without the rebase the background drain would stall until
    /// the new clock caught up with the dead job's).
    pub fn rebase_clock(&mut self, now_secs: f64) {
        self.clock = now_secs;
    }

    /// Write one wave to the fast tier and queue it for background drain.
    ///
    /// Evicts old drained generations (keeping the newest `keep_fulls`)
    /// when the wave doesn't fit; force-drains undrained evictees first
    /// and reports that time as backpressure. Errors with
    /// [`FsError::InsufficientSpace`] only when eviction cannot help.
    pub fn write_wave(&mut self, reqs: Vec<WriteReq>) -> Result<StagedIo, FsError> {
        if self.generations.is_empty() {
            self.generations.push_back(Generation::default());
        }
        let total: u64 = reqs.iter().map(|r| r.virtual_bytes).sum();
        let mut backpressure = 0.0;
        let mut backpressure_bytes = 0u64;
        let mut evicted_files = 0usize;
        loop {
            // Recomputed every pass: eviction may delete a file this wave
            // replaces, shrinking `replaced` — the loop exit must agree
            // with write_parallel's own capacity check at that instant.
            let replaced: u64 = reqs
                .iter()
                .filter_map(|r| self.fast.virtual_size(&r.path))
                .sum();
            let needed = total.saturating_sub(replaced);
            if self.fast.free_bytes() >= needed {
                break;
            }
            if !self.evict_oldest(&mut backpressure, &mut backpressure_bytes, &mut evicted_files)
            {
                // Failure leaves prior staging state intact; only the
                // just-opened (still empty) generation is rolled back so
                // it doesn't count against keep_fulls.
                if self
                    .generations
                    .back()
                    .is_some_and(|g| g.paths.is_empty())
                {
                    self.generations.pop_back();
                }
                log_warn!(
                    "fs",
                    "staged: insufficient fast-tier space even after eviction: \
                     need {}, free {}",
                    crate::util::bytes::human(needed),
                    crate::util::bytes::human(self.fast.free_bytes())
                );
                return Err(FsError::InsufficientSpace {
                    needed,
                    free: self.fast.free_bytes(),
                });
            }
        }

        // The wave fits: only now do these paths change hands — stale
        // claims (an older generation's copy, a queued drain of the old
        // version) are dropped and replaced below.
        for r in &reqs {
            self.unclaim(&r.path);
        }
        let meta: Vec<(String, u64)> = reqs
            .iter()
            .map(|r| (r.path.clone(), r.virtual_bytes))
            .collect();
        let io = self.fast.write_parallel(reqs)?;

        let gen = self
            .generations
            .back_mut()
            .expect("current generation exists");
        for (path, virtual_bytes) in meta {
            gen.paths.push(path.clone());
            self.queue.push_back(DrainItem {
                path,
                remaining: virtual_bytes,
            });
        }
        let pending = self.pending_bytes();
        log_debug!(
            "fs",
            "staged: wave of {} landed on {} in {:.2}s; {} queued for drain",
            crate::util::bytes::human(total),
            self.fast.cfg.kind,
            io.duration,
            crate::util::bytes::human(pending)
        );
        Ok(StagedIo {
            fast_secs: io.duration,
            fast_bytes: total,
            backpressure_secs: backpressure,
            durable_bytes: backpressure_bytes,
            evicted_files,
            pending_bytes: pending,
            writers: io.writers,
        })
    }

    /// Advance the background drain to virtual time `now`: node-local
    /// agents move queued bytes to the durable tier at chunk granularity.
    pub fn drain_to(&mut self, now_secs: f64) -> DrainTick {
        let budget = (now_secs - self.clock).max(0.0);
        self.clock = self.clock.max(now_secs);
        if self.queue.is_empty() {
            self.credit = 0.0;
            return DrainTick {
                queue_empty: true,
                ..DrainTick::default()
            };
        }
        let bw = self.drain_bandwidth();
        self.credit += budget * bw;
        let mut tick = DrainTick::default();
        let mut failed: Vec<DrainItem> = Vec::new();
        loop {
            let Some(item) = self.queue.front_mut() else {
                break;
            };
            // (Zero-byte items — e.g. a fully-clean incremental rank —
            // skip straight to completion below.)
            if item.remaining > 0 {
                let whole = item.remaining as f64;
                let take = if self.credit >= whole {
                    whole
                } else {
                    // Partial drains stop on a chunk boundary.
                    (self.credit / CHUNK_BYTES as f64).floor() * CHUNK_BYTES as f64
                };
                if take <= 0.0 {
                    break;
                }
                item.remaining -= take as u64;
                self.credit -= take;
                tick.drained_bytes += take as u64;
            }
            if item.remaining == 0 {
                let done = self.queue.pop_front().expect("front exists");
                if self.complete_drain(&done.path) {
                    tick.completed_files += 1;
                } else {
                    // Staging failed (durable-tier shortfall): keep the
                    // item queued so a later tick retries it, but set it
                    // aside for this tick to avoid a hot retry loop.
                    failed.push(done);
                }
            } else {
                break;
            }
        }
        self.queue.extend(failed);
        self.stats.drained_bytes += tick.drained_bytes;
        self.stats.busy_secs += tick.drained_bytes as f64 / bw;
        tick.queue_empty = self.queue.is_empty();
        if tick.queue_empty {
            self.credit = 0.0;
            if tick.completed_files > 0 {
                log_info!(
                    "fs",
                    "staged: drain queue empty at t={now_secs:.2}s — all images durable"
                );
            }
        }
        tick
    }

    /// Drain everything now; returns the durable-tier busy seconds.
    /// Items whose staging fails (pathological durable-tier shortfall)
    /// stay queued for retry and are not counted as drained.
    pub fn drain_sync(&mut self) -> f64 {
        let bw = self.drain_bandwidth();
        let mut secs = 0.0;
        let mut failed = Vec::new();
        while let Some(item) = self.queue.pop_front() {
            if !self.complete_drain(&item.path) {
                failed.push(item);
                continue;
            }
            secs += item.remaining as f64 / bw;
            self.stats.drained_bytes += item.remaining;
        }
        self.queue.extend(failed);
        self.credit = 0.0;
        self.stats.busy_secs += secs;
        secs
    }

    /// Copy a fully-drained file from the fast tier into the durable
    /// tier. Returns whether a durable copy now exists.
    fn complete_drain(&mut self, path: &str) -> bool {
        let Some((virtual_bytes, data)) = self.fast.peek(path) else {
            log_warn!("fs", "staged: drain source {path} vanished — skipped");
            self.stats.drain_errors += 1;
            return false;
        };
        let data = data.to_vec();
        match self.durable.insert_raw(path, virtual_bytes, data) {
            Ok(()) => {
                self.stats.drained_files += 1;
                true
            }
            Err(e) => {
                log_warn!("fs", "staged: drain of {path} failed: {e}");
                self.stats.drain_errors += 1;
                false
            }
        }
    }

    /// Force-drain one queued path immediately (eviction backpressure).
    /// Returns the synchronous (seconds, bytes) charged — zero when the
    /// staging failed (the item is re-queued for a later retry rather
    /// than reported as durable).
    fn drain_path_now(&mut self, path: &str) -> (f64, u64) {
        let Some(pos) = self.queue.iter().position(|i| i.path == path) else {
            return (0.0, 0);
        };
        let item = self.queue.remove(pos).expect("position valid");
        if !self.complete_drain(&item.path) {
            self.queue.push_back(item);
            return (0.0, 0);
        }
        let secs = item.remaining as f64 / self.drain_bandwidth();
        self.stats.drained_bytes += item.remaining;
        self.stats.busy_secs += secs;
        self.stats.forced_secs += secs;
        (secs, item.remaining)
    }

    /// Evict the oldest generation beyond `keep_fulls` from the fast tier.
    /// Undrained files are force-drained first, and a file is deleted from
    /// the fast tier only once a durable copy actually exists — the engine
    /// never drops the only copy of an image. Returns false when nothing
    /// is evictable.
    fn evict_oldest(
        &mut self,
        backpressure: &mut f64,
        backpressure_bytes: &mut u64,
        evicted_files: &mut usize,
    ) -> bool {
        if self.generations.len() <= self.keep_fulls {
            return false;
        }
        let gen = self.generations.pop_front().expect("non-empty");
        for path in &gen.paths {
            let (secs, bytes) = self.drain_path_now(path);
            *backpressure += secs;
            *backpressure_bytes += bytes;
        }
        let mut deleted = 0usize;
        let mut kept = Vec::new();
        for path in &gen.paths {
            if !self.durable.exists(path) {
                // Forced drain failed (durable tier full / source gone):
                // keep the fast copy rather than drop the only one.
                log_warn!(
                    "fs",
                    "staged: evictee {path} has no durable copy — kept on the fast tier"
                );
                kept.push(path.clone());
                continue;
            }
            if self.fast.delete(path).is_ok() {
                deleted += 1;
            }
        }
        *evicted_files += deleted;
        self.stats.evicted_files += deleted as u64;
        if !kept.is_empty() {
            // Keep the survivors claimed (still the oldest generation) so
            // a later pass can evict them once their drain succeeds.
            self.generations.push_front(Generation { paths: kept });
        } else {
            self.stats.evicted_generations += 1;
        }
        log_info!(
            "fs",
            "staged: evicted generation ({deleted} files) from the fast tier \
             (durable copies retained){}",
            if *backpressure > 0.0 {
                format!(", {backpressure:.2}s forced-drain backpressure")
            } else {
                String::new()
            }
        );
        // Progress = space was freed, or an already-empty generation was
        // retired; a generation that could not be freed at all ends the
        // caller's eviction loop (no progress is possible right now).
        deleted > 0 || gen.paths.is_empty()
    }

    /// Drop every claim on `path`: older generations' lists and any queued
    /// drain of a stale version.
    fn unclaim(&mut self, path: &str) {
        for gen in &mut self.generations {
            gen.paths.retain(|p| p != path);
        }
        self.queue.retain(|i| i.path != path);
    }

    // ------------------------------------------------- namespace ops

    /// Read a wave preferring the fast tier per file, falling back to the
    /// durable tier; the two tier waves proceed in parallel.
    pub fn read_preferred(
        &self,
        paths: &[(NodeId, String)],
    ) -> Result<(Vec<Vec<u8>>, IoReport), FsError> {
        let mut fast_wave = Vec::new();
        let mut durable_wave = Vec::new();
        for (i, (node, path)) in paths.iter().enumerate() {
            if self.fast.exists(path) {
                fast_wave.push((i, (*node, path.clone())));
            } else {
                durable_wave.push((i, (*node, path.clone())));
            }
        }
        let mut datas: Vec<Vec<u8>> = vec![Vec::new(); paths.len()];
        let mut duration = 0.0f64;
        let mut total = 0u64;
        for (tier, wave) in [(&self.fast, fast_wave), (&self.durable, durable_wave)] {
            if wave.is_empty() {
                continue;
            }
            let reqs: Vec<(NodeId, String)> =
                wave.iter().map(|(_, np)| np.clone()).collect();
            let (tier_datas, io) = tier.read_parallel(&reqs)?;
            for ((i, _), d) in wave.into_iter().zip(tier_datas) {
                datas[i] = d;
            }
            duration = duration.max(io.duration);
            total += io.total_virtual_bytes;
        }
        Ok((
            datas,
            IoReport {
                duration,
                total_virtual_bytes: total,
                writers: paths.len(),
            },
        ))
    }

    /// Read a wave from the durable tier only (CRC-fallback path).
    pub fn read_durable(
        &self,
        paths: &[(NodeId, String)],
    ) -> Result<(Vec<Vec<u8>>, IoReport), FsError> {
        self.durable.read_parallel(paths)
    }

    pub fn exists(&self, path: &str) -> bool {
        self.fast.exists(path) || self.durable.exists(path)
    }

    pub fn delete(&mut self, path: &str) -> Result<(), FsError> {
        self.unclaim(path);
        let a = self.fast.delete(path);
        let b = self.durable.delete(path);
        match (a, b) {
            (Err(e), Err(_)) => Err(e),
            _ => Ok(()),
        }
    }

    /// Fast-tier occupancy (the operationally scarce resource).
    pub fn used_bytes(&self) -> u64 {
        self.fast.used_bytes()
    }

    pub fn free_bytes(&self) -> u64 {
        self.fast.free_bytes()
    }

    /// Distinct paths across both tiers.
    pub fn file_count(&self) -> usize {
        let mut paths = self.fast.paths();
        paths.extend(self.durable.paths());
        paths.sort_unstable();
        paths.dedup();
        paths.len()
    }

    /// Corrupt the fast-tier copy if present, else the durable copy.
    pub fn corrupt_byte(&mut self, path: &str, offset: usize) -> bool {
        self.fast.corrupt_byte(path, offset) || self.durable.corrupt_byte(path, offset)
    }

    pub fn describe(&self) -> String {
        format!(
            "staged({} → {}, {} pending)",
            self.fast.cfg.kind,
            self.durable.cfg.kind,
            crate::util::bytes::human(self.pending_bytes())
        )
    }
}

impl StorageTier for TieredStore {
    fn write_parallel(&mut self, reqs: Vec<WriteReq>) -> Result<IoReport, FsError> {
        self.write_wave(reqs).map(|s| s.io())
    }
    fn read_parallel(
        &self,
        paths: &[(NodeId, String)],
    ) -> Result<(Vec<Vec<u8>>, IoReport), FsError> {
        self.read_preferred(paths)
    }
    fn exists(&self, path: &str) -> bool {
        TieredStore::exists(self, path)
    }
    fn delete(&mut self, path: &str) -> Result<(), FsError> {
        TieredStore::delete(self, path)
    }
    fn free_bytes(&self) -> u64 {
        TieredStore::free_bytes(self)
    }
    fn used_bytes(&self) -> u64 {
        TieredStore::used_bytes(self)
    }
    fn file_count(&self) -> usize {
        TieredStore::file_count(self)
    }
    fn corrupt_byte(&mut self, path: &str, offset: usize) -> bool {
        TieredStore::corrupt_byte(self, path, offset)
    }
    fn describe(&self) -> String {
        TieredStore::describe(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fs::FsConfig;

    const MIB: u64 = 1 << 20;

    fn store(fast_cap: u64, keep: usize) -> TieredStore {
        let mut bb = FsConfig::burst_buffer(2);
        bb.capacity = fast_cap;
        TieredStore::new(
            FileSystem::new(bb),
            FileSystem::new(FsConfig::cscratch()),
            keep,
            2,
        )
    }

    fn wave(tag: &str, files: u32, bytes_each: u64) -> Vec<WriteReq> {
        (0..files)
            .map(|i| WriteReq {
                node: NodeId(i % 2),
                path: format!("{tag}/f{i}"),
                virtual_bytes: bytes_each,
                data: vec![i as u8; 8],
            })
            .collect()
    }

    #[test]
    fn checkpoint_completes_on_fast_tier_and_drains_later() {
        let mut ts = store(1024 * MIB, 2);
        ts.begin_ckpt(0.0);
        let io = ts.write_wave(wave("g0", 4, 64 * MIB)).unwrap();
        assert!(io.fast_secs > 0.0);
        assert_eq!(io.backpressure_secs, 0.0);
        assert_eq!(io.pending_bytes, 4 * 64 * MIB);
        // Nothing durable yet.
        assert_eq!(ts.durable().file_count(), 0);
        assert!(ts.fast().exists("g0/f0"));
        // Generous clock advance drains everything.
        let tick = ts.drain_to(1000.0);
        assert!(tick.queue_empty);
        assert_eq!(tick.completed_files, 4);
        assert_eq!(ts.durable().file_count(), 4);
        assert_eq!(ts.pending_bytes(), 0);
        // Fast copies stay resident (within keep_fulls).
        assert!(ts.fast().exists("g0/f0"));
    }

    #[test]
    fn drain_progresses_incrementally_on_the_clock() {
        let mut ts = store(1024 * MIB, 2);
        ts.begin_ckpt(0.0);
        ts.write_wave(wave("g0", 1, 512 * MIB)).unwrap();
        let bw = ts.drain_bandwidth();
        let half = 256.0 * MIB as f64 / bw;
        let tick = ts.drain_to(half);
        assert!(!tick.queue_empty, "half the budget must not finish");
        assert!(tick.drained_bytes > 0);
        // Chunk-granular progress.
        assert_eq!(tick.drained_bytes % CHUNK_BYTES as u64, 0);
        let tick2 = ts.drain_to(half * 2.5);
        assert!(tick2.queue_empty, "full budget finishes the drain");
        assert!(ts.durable().exists("g0/f0"));
    }

    #[test]
    fn eviction_keeps_last_n_fulls_on_fast_tier() {
        // Fast tier fits two 4x64 MiB generations, not three.
        let mut ts = store(600 * MIB, 2);
        for g in 0..3u32 {
            ts.begin_ckpt(g as f64 * 10.0);
            ts.write_wave(wave(&format!("g{g}"), 4, 64 * MIB)).unwrap();
            ts.drain_to(g as f64 * 10.0 + 1000.0); // fully drained between ckpts
        }
        // g0 evicted from fast, still durable; g1/g2 resident.
        assert!(!ts.fast().exists("g0/f0"), "oldest gen evicted from BB");
        assert!(ts.durable().exists("g0/f0"), "durable copy retained");
        assert!(ts.fast().exists("g1/f0"));
        assert!(ts.fast().exists("g2/f0"));
        assert_eq!(ts.stats.evicted_generations, 1);
        assert_eq!(ts.stats.forced_secs, 0.0, "drained evictee costs nothing");
    }

    #[test]
    fn undrained_eviction_charges_backpressure() {
        let mut ts = store(600 * MIB, 1); // keep only the current gen
        ts.begin_ckpt(0.0);
        ts.write_wave(wave("g0", 4, 64 * MIB)).unwrap();
        // No drain time elapses before the next checkpoint.
        ts.begin_ckpt(0.0);
        let io = ts.write_wave(wave("g1", 4, 120 * MIB)).unwrap();
        assert!(
            io.backpressure_secs > 0.0,
            "evicting an undrained gen must force-drain it synchronously"
        );
        assert_eq!(
            io.durable_bytes,
            4 * 64 * MIB,
            "backpressure bytes must be reported per tier"
        );
        assert!(ts.durable().exists("g0/f0"), "forced drain made g0 durable");
        assert!(!ts.fast().exists("g0/f0"));
        assert!(ts.stats.forced_secs > 0.0);
    }

    #[test]
    fn failed_wave_leaves_staging_state_intact() {
        let mut ts = store(600 * MIB, 2);
        ts.begin_ckpt(0.0);
        ts.write_wave(wave("g0", 4, 64 * MIB)).unwrap();
        let pending_before = ts.pending_bytes();
        // A wave that cannot fit even after eviction must not disturb the
        // queued drain or the existing generation bookkeeping.
        ts.begin_ckpt(1.0);
        let err = ts.write_wave(wave("g1", 4, 200 * MIB)).unwrap_err();
        assert!(matches!(err, FsError::InsufficientSpace { .. }));
        assert_eq!(ts.pending_bytes(), pending_before, "queue untouched");
        assert!(ts.fast().exists("g0/f0"));
        // The empty just-opened generation was rolled back: a later
        // eviction pass still sees exactly one (real) generation.
        ts.begin_ckpt(2.0);
        ts.write_wave(wave("g2", 4, 64 * MIB)).unwrap();
        assert!(ts.fast().exists("g0/f0"), "g0 still within keep_fulls");
    }

    #[test]
    fn restart_rebase_resumes_a_stalled_drain() {
        let mut ts = store(1024 * MIB, 2);
        ts.begin_ckpt(100.0); // killed job's timeline
        ts.write_wave(wave("g0", 2, 64 * MIB)).unwrap();
        ts.sync_clock(130.0);
        // Restarted job's clock starts near zero: without a rebase this
        // tick would get zero budget.
        ts.rebase_clock(2.0);
        let tick = ts.drain_to(1000.0);
        assert!(tick.queue_empty, "rebased drain must make progress");
        assert!(ts.durable().exists("g0/f0"));
    }

    #[test]
    fn insufficient_space_when_eviction_cannot_help() {
        let mut ts = store(100 * MIB, 2);
        ts.begin_ckpt(0.0);
        let err = ts.write_wave(wave("g0", 4, 64 * MIB)).unwrap_err();
        assert!(matches!(err, FsError::InsufficientSpace { .. }));
        assert_eq!(ts.fast().used_bytes(), 0, "nothing written on failure");
        assert_eq!(ts.pending_bytes(), 0);
    }

    #[test]
    fn overwrite_dedupes_queue_and_generation_claims() {
        let mut ts = store(1024 * MIB, 2);
        ts.begin_ckpt(0.0);
        ts.write_wave(wave("same", 2, 32 * MIB)).unwrap();
        ts.begin_ckpt(0.0);
        ts.write_wave(wave("same", 2, 32 * MIB)).unwrap();
        // The rewritten paths are claimed once, queued once.
        assert_eq!(ts.pending_files(), 2);
        assert_eq!(ts.pending_bytes(), 2 * 32 * MIB);
        let tick = ts.drain_to(1000.0);
        assert!(tick.queue_empty);
        assert_eq!(ts.durable().file_count(), 2);
    }

    #[test]
    fn read_preferred_falls_back_to_durable_per_file() {
        let mut ts = store(1024 * MIB, 2);
        ts.begin_ckpt(0.0);
        ts.write_wave(wave("g0", 2, 16 * MIB)).unwrap();
        ts.drain_sync();
        // Drop one file from the fast tier only.
        ts.fast_mut().delete("g0/f1").unwrap();
        let paths = vec![
            (NodeId(0), "g0/f0".to_string()),
            (NodeId(1), "g0/f1".to_string()),
        ];
        let (datas, io) = ts.read_preferred(&paths).unwrap();
        assert_eq!(datas[0], vec![0u8; 8]);
        assert_eq!(datas[1], vec![1u8; 8]);
        assert!(io.duration > 0.0);
        assert_eq!(io.total_virtual_bytes, 2 * 16 * MIB);
    }

    #[test]
    fn drain_sync_moves_everything_and_reports_busy_secs() {
        let mut ts = store(1024 * MIB, 2);
        ts.begin_ckpt(0.0);
        ts.write_wave(wave("g0", 3, 32 * MIB)).unwrap();
        let secs = ts.drain_sync();
        assert!(secs > 0.0);
        assert_eq!(ts.pending_bytes(), 0);
        assert_eq!(ts.durable().file_count(), 3);
        assert_eq!(ts.stats.drained_files, 3);
    }

    #[test]
    fn delete_unclaims_everywhere() {
        let mut ts = store(1024 * MIB, 2);
        ts.begin_ckpt(0.0);
        ts.write_wave(wave("g0", 2, 16 * MIB)).unwrap();
        ts.delete("g0/f0").unwrap();
        assert!(!ts.exists("g0/f0"));
        assert_eq!(ts.pending_files(), 1, "queued drain dropped with the file");
        assert!(ts.delete("nope").is_err());
    }
}
