//! `CHANGES_PENDING` guard — Lesson 3 from the paper.
//!
//! > "Improved design for atomic data structures even for single-threaded
//! >  code. Each data structure should include a field `CHANGES_PENDING`,
//! >  which would act as a lock."
//!
//! The paper's race conditions came from data structures left in an
//! inconsistent state across interruption points (signal handlers, the
//! checkpoint hook firing mid-update). [`Guarded`] wraps a value with that
//! pending flag: mutations must happen inside [`Guarded::update`], and any
//! read that observes `changes_pending == true` is a detected consistency
//! violation — exactly the invariant the authors wished the research code
//! had asserted from day one.

use std::fmt;

/// Error: a reader observed a structure mid-mutation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct InconsistentRead {
    pub what: &'static str,
}

impl fmt::Display for InconsistentRead {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "CHANGES_PENDING set while reading {}", self.what)
    }
}

impl std::error::Error for InconsistentRead {}

/// A value with a `CHANGES_PENDING` consistency flag.
#[derive(Clone, Debug)]
pub struct Guarded<T> {
    name: &'static str,
    changes_pending: bool,
    value: T,
}

impl<T> Guarded<T> {
    pub fn new(name: &'static str, value: T) -> Self {
        Guarded {
            name,
            changes_pending: false,
            value,
        }
    }

    /// Consistent read. Fails if an update was interrupted mid-flight.
    pub fn read(&self) -> Result<&T, InconsistentRead> {
        if self.changes_pending {
            Err(InconsistentRead { what: self.name })
        } else {
            Ok(&self.value)
        }
    }

    /// Atomic update: sets `CHANGES_PENDING`, runs the mutation, clears it.
    pub fn update<R>(&mut self, f: impl FnOnce(&mut T) -> R) -> R {
        self.changes_pending = true;
        let out = f(&mut self.value);
        self.changes_pending = false;
        out
    }

    /// Begin an update and *leave it open* — models the legacy missing-lock
    /// bug where an interruption lands mid-mutation. Used by the fault
    /// injector; a subsequent `read` will detect the inconsistency.
    pub fn update_interrupted(&mut self, f: impl FnOnce(&mut T)) {
        self.changes_pending = true;
        f(&mut self.value);
        // changes_pending intentionally left set.
    }

    /// Repair after an interrupted update (restart path).
    pub fn reset_pending(&mut self) {
        self.changes_pending = false;
    }

    pub fn is_pending(&self) -> bool {
        self.changes_pending
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_after_update_ok() {
        let mut g = Guarded::new("table", vec![1, 2]);
        g.update(|v| v.push(3));
        assert_eq!(g.read().unwrap(), &vec![1, 2, 3]);
    }

    #[test]
    fn interrupted_update_detected() {
        let mut g = Guarded::new("msg_counts", 0u64);
        g.update_interrupted(|v| *v = 41);
        let err = g.read().unwrap_err();
        assert!(err.to_string().contains("msg_counts"));
        g.reset_pending();
        assert_eq!(*g.read().unwrap(), 41);
    }

    #[test]
    fn update_returns_value() {
        let mut g = Guarded::new("x", 10i32);
        let doubled = g.update(|v| {
            *v *= 2;
            *v
        });
        assert_eq!(doubled, 20);
        assert!(!g.is_pending());
    }
}
