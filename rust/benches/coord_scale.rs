//! COORD — control-plane scalability: flat DMTCP root vs the hierarchical
//! sub-coordinator tree.
//!
//! The flat coordinator exchanges one message with every rank in every
//! protocol phase: O(ranks) serialized traffic at a single endpoint, the
//! first bottleneck a production deployment hits. The tree plane
//! (per-node sub-coordinators, fanout 8, broadcast-down + reduce-up per
//! phase, DRAIN counters summed up the tree) caps the root at O(fanout)
//! messages per phase and turns protocol wall-clock growth from linear in
//! ranks to logarithmic (tree depth).
//!
//! Asserted (the PR's acceptance criteria), at >= 512 ranks:
//!   * tree root control messages per checkpoint <= 2 x fanout x phases
//!     (flat stays >= ranks);
//!   * tree protocol wall-clock strictly below flat at the largest swept
//!     size, growing sublinearly across the sweep;
//!   * flat and tree checkpoints restart byte-identically (fingerprint
//!     equality) at every size.

use mana::benchkit::Report;
use mana::config::{AppKind, RunConfig};
use mana::coordinator::Phase;
use mana::sim::JobSim;
use mana::util::json::Json;

const FANOUT: u32 = 8;

fn cfg_for(ranks: u32, tree: bool) -> RunConfig {
    let mut cfg = RunConfig::new(AppKind::Synthetic, ranks);
    cfg.job = format!("coord-{ranks}-{}", if tree { "tree" } else { "flat" });
    cfg.mem_per_rank = Some(1 << 20);
    if tree {
        cfg = cfg.with_coord_tree(FANOUT);
    }
    cfg
}

struct Point {
    ctrl_secs: f64,
    ctrl_msgs: u64,
    root_msgs: u64,
    depth: u32,
    fingerprint: u64,
}

/// One full C/R cycle; the protocol numbers come from the checkpoint
/// report, the fingerprint from the resumed run.
fn measure(ranks: u32, tree: bool) -> Point {
    let cfg = cfg_for(ranks, tree);
    let mut sim = JobSim::launch(cfg.clone(), None).expect("launch");
    sim.run_steps(2).expect("steps");
    let rep = sim.checkpoint().expect("ckpt");
    let fs = sim.kill();
    let (mut resumed, _) = JobSim::restart_from(cfg, None, fs).expect("restart");
    resumed.run_steps(2).expect("resume");
    Point {
        ctrl_secs: rep.ctrl_secs,
        ctrl_msgs: rep.ctrl_msgs,
        root_msgs: rep.root_ctrl_msgs,
        depth: rep.coord_depth,
        fingerprint: resumed.fingerprint(),
    }
}

fn main() {
    let phases = Phase::ALL.len() as u64;
    let mut rep = Report::new(
        "COORD: control-plane scalability, flat vs tree (fanout 8)",
        vec![
            "ranks",
            "plane",
            "depth",
            "root_msgs",
            "ctrl_msgs",
            "ctrl_secs",
        ],
    );
    let sweep = [64u32, 128, 256, 512];
    let mut flat_secs = Vec::new();
    let mut tree_secs = Vec::new();
    let mut jrows = Json::Arr(vec![]);
    for &ranks in &sweep {
        let f = measure(ranks, false);
        let t = measure(ranks, true);
        assert_eq!(
            f.fingerprint, t.fingerprint,
            "{ranks} ranks: flat and tree checkpoints must restart byte-identically"
        );
        for (tag, p) in [("flat", &f), ("tree", &t)] {
            rep.row(vec![
                ranks.to_string(),
                tag.to_string(),
                p.depth.to_string(),
                p.root_msgs.to_string(),
                p.ctrl_msgs.to_string(),
                format!("{:.4}", p.ctrl_secs),
            ]);
            jrows.push(
                Json::obj()
                    .set("ranks", ranks as u64)
                    .set("plane", tag)
                    .set("depth", p.depth as u64)
                    .set("root_msgs", p.root_msgs)
                    .set("ctrl_msgs", p.ctrl_msgs)
                    .set("ctrl_secs", p.ctrl_secs),
            );
        }
        assert!(
            f.root_msgs >= ranks as u64,
            "{ranks} ranks: flat root load {} must be O(ranks)",
            f.root_msgs
        );
        assert!(
            t.root_msgs <= 2 * FANOUT as u64 * phases,
            "{ranks} ranks: tree root load {} exceeds 2 x fanout x phases ({})",
            t.root_msgs,
            2 * FANOUT as u64 * phases
        );
        flat_secs.push(f.ctrl_secs);
        tree_secs.push(t.ctrl_secs);
    }
    rep.finish();

    let (flat_last, tree_last) = (flat_secs.last().unwrap(), tree_secs.last().unwrap());
    assert!(
        tree_last < flat_last,
        "tree protocol wall-clock {tree_last}s must be strictly below flat {flat_last}s \
         at the largest swept size"
    );
    // Sublinear growth: 8x the ranks must cost well under 8x the time
    // (depth grows by one level over this sweep).
    let growth = tree_secs.last().unwrap() / tree_secs.first().unwrap();
    assert!(
        growth < 4.0,
        "tree protocol wall-clock must grow sublinearly across 64->512 ranks: {growth:.2}x"
    );

    // Machine-readable trajectory + the CI bench-report gate value: the
    // tree/flat control wall-clock ratio at the largest swept size (the
    // baseline requires it strictly below 1.0).
    let out = Json::obj()
        .set("bench", "coord_scale")
        .set("fanout", FANOUT as u64)
        .set(
            "gates",
            Json::obj()
                .set("coord_tree_over_flat_ctrl_512", tree_last / flat_last)
                .set("coord_tree_growth_64_to_512", growth),
        )
        .set("rows", jrows);
    std::fs::write("BENCH_coord_scale.json", out.to_string())
        .expect("write BENCH_coord_scale.json");
    println!("COORD OK (results in BENCH_coord_scale.json)");
}
