//! Production observability: counters, gauges, timing summaries.
//!
//! Lesson 4 of the paper ("better attention to warnings and error messages
//! from the beginning") extends naturally to metrics: a production C/R
//! service must expose what it is doing. Every [`crate::sim::JobSim`]
//! carries a [`Metrics`] registry; the CLI and the console's `s` command
//! surface the snapshot as JSON.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Summary statistics of a repeatedly-observed duration/size.
#[derive(Clone, Copy, Debug, Default)]
pub struct Summary {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    fn observe(&mut self, v: f64) {
        if self.count == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.count += 1;
        self.sum += v;
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

/// The registry. Keys are dotted names ("ckpt.write_secs").
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    counters: BTreeMap<&'static str, u64>,
    gauges: BTreeMap<&'static str, f64>,
    summaries: BTreeMap<&'static str, Summary>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&mut self, name: &'static str, delta: u64) {
        *self.counters.entry(name).or_insert(0) += delta;
    }

    pub fn gauge(&mut self, name: &'static str, value: f64) {
        self.gauges.insert(name, value);
    }

    pub fn observe(&mut self, name: &'static str, value: f64) {
        self.summaries.entry(name).or_default().observe(value);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn summary(&self, name: &str) -> Summary {
        self.summaries.get(name).copied().unwrap_or_default()
    }

    /// Snapshot as stable-ordered JSON.
    pub fn snapshot(&self) -> Json {
        let mut counters = Json::obj();
        for (k, v) in &self.counters {
            counters = counters.set(k, *v);
        }
        let mut gauges = Json::obj();
        for (k, v) in &self.gauges {
            gauges = gauges.set(k, *v);
        }
        let mut summaries = Json::obj();
        for (k, s) in &self.summaries {
            summaries = summaries.set(
                k,
                Json::obj()
                    .set("count", s.count)
                    .set("mean", s.mean())
                    .set("min", s.min)
                    .set("max", s.max),
            );
        }
        Json::obj()
            .set("counters", counters)
            .set("gauges", gauges)
            .set("summaries", summaries)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut m = Metrics::new();
        m.inc("steps", 1);
        m.inc("steps", 2);
        assert_eq!(m.counter("steps"), 3);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn summary_tracks_min_max_mean() {
        let mut m = Metrics::new();
        for v in [2.0, 8.0, 5.0] {
            m.observe("ckpt.secs", v);
        }
        let s = m.summary("ckpt.secs");
        assert_eq!(s.count, 3);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 8.0);
        assert!((s.mean() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn snapshot_is_stable_json() {
        let mut m = Metrics::new();
        m.inc("b", 1);
        m.inc("a", 1);
        m.gauge("g", 1.5);
        m.observe("t", 3.0);
        let s = m.snapshot().to_string();
        assert!(s.contains(r#""a":1"#) && s.contains(r#""g":1.5"#));
        assert!(s.find(r#""a""#).unwrap() < s.find(r#""b""#).unwrap());
        assert!(s.contains(r#""count":1"#));
    }
}
