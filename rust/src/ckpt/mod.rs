//! Checkpoint image format (per-rank `.mana` images).
//!
//! The split-process model checkpoints *only* the upper half: app memory
//! regions, upper-half file descriptors, the application step counter and
//! PRNG state. Everything is CRC32-protected per section plus a whole-image
//! trailer so restart can detect torn or corrupted images (the disk-space
//! and injection tests rely on this).
//!
//! Layout (little-endian, format v4):
//! ```text
//! magic "MANAIMG1" | version u32 | rank u32 | step u64 | rng[32]
//! | parent: len u32 + bytes (len 0 = full image)
//! | n_fds u32 | { fd u32, name: len u32 + bytes }*
//! | n_regions u32 | { addr u64, vlen u64, name, payload_kind u8,
//!                     payload (seed u64
//!                              | chunked data: n_chunks u32,
//!                                { len u32, bytes, chunk_crc u32 }*
//!                              | parent-ref fingerprint u64),
//!                     section_crc u32 }*
//! | image_crc u32
//! ```
//!
//! v4 (this version) frames `Real` payloads in fixed-size CRC'd chunks
//! (see [`chunk`]) and the encoder streams straight into the destination
//! buffer ([`CkptImage::encode_into`]) — the write path never materializes
//! an image twice, and storage engines charge/drain per chunk. Every byte
//! is CRC-covered exactly once: chunk bytes by their chunk CRC, chunk
//! metadata by the section CRC, section CRCs by the whole-image trailer.
//!
//! **Incremental checkpoints** (the paper's "reducing the checkpoint
//! overhead for large-scale applications" future work): an image may name
//! a `parent` full image; regions unchanged since that full checkpoint are
//! stored as `ParentRef { fingerprint }` — only their identity and content
//! fingerprint ride the incremental image, and restore resolves them from
//! the parent (verifying the fingerprint).

pub mod chunk;
pub mod datapath;
pub mod interval;
pub mod manifest;
pub mod pipeline;

use std::fmt;

use crate::mem::{Half, MemRegion, Payload, RegionTable};
use crate::topology::RankId;
use crate::util::{cdc, crc32};

use self::datapath::{CacheSlot, CacheStats, RegionDigestCache};

pub use chunk::{ChunkRecipe, Chunking};

const MAGIC: &[u8; 8] = b"MANAIMG1";
const VERSION: u32 = 4;

/// Everything a rank needs to resume: the upper half, frozen.
#[derive(Clone, Debug, PartialEq)]
pub struct CkptImage {
    pub rank: RankId,
    pub step: u64,
    pub rng_state: [u8; 32],
    /// Path of the parent full image this incremental refers to (None for
    /// a full image).
    pub parent: Option<String>,
    /// Upper-half descriptors to re-claim at restart.
    pub upper_fds: Vec<(u32, String)>,
    /// Upper-half regions (with virtual lengths and payloads).
    pub regions: Vec<SavedRegion>,
}

/// How a region's contents are stored in this image.
#[derive(Clone, Debug, PartialEq)]
pub enum SavedPayload {
    /// Contents materialized in this image.
    Full(Payload),
    /// Unchanged since the parent full image: resolve there, verify the
    /// content fingerprint.
    ParentRef { fingerprint: u64 },
}

/// A serialized upper-half region.
#[derive(Clone, Debug, PartialEq)]
pub struct SavedRegion {
    pub addr: u64,
    pub vlen: u64,
    pub name: String,
    pub payload: SavedPayload,
}

impl SavedRegion {
    /// Materialize a live region. Panics on an unresolved ParentRef —
    /// callers must run [`resolve_incremental`] first.
    pub fn to_region(&self) -> MemRegion {
        match &self.payload {
            SavedPayload::Full(p) => {
                MemRegion::new(self.addr, self.vlen, Half::Upper, &self.name, p.clone())
            }
            SavedPayload::ParentRef { .. } => {
                panic!("unresolved ParentRef region {}", self.name)
            }
        }
    }

    /// Borrowed view of this record for the streaming encoder.
    pub fn as_src(&self) -> RegionSrc<'_> {
        RegionSrc {
            addr: self.addr,
            vlen: self.vlen,
            name: &self.name,
            payload: PayloadSrc::of_saved(&self.payload),
        }
    }
}

// --------------------------------------------------- encoder source views
//
// The write hot path captures by reference (Cow-style): the sim's live
// region table is the backing store until the bytes land in the write
// buffer, so serializing a rank never clones its payloads. Both
// [`CkptImage::encode_into`] (owned regions) and the rank-parallel
// [`datapath`] (live tables) funnel into the same [`encode_stream`]
// engine, which is what guarantees the two paths are byte-identical.

/// Borrowed payload contents for the streaming encoder.
#[derive(Clone, Copy, Debug)]
pub enum PayloadSrc<'a> {
    Zero,
    Pattern(u64),
    Real(&'a [u8]),
    ParentRef { fingerprint: u64 },
}

impl<'a> PayloadSrc<'a> {
    /// View a live region payload (full capture).
    pub fn of(p: &'a Payload) -> Self {
        match p {
            Payload::Zero => PayloadSrc::Zero,
            Payload::Pattern(seed) => PayloadSrc::Pattern(*seed),
            Payload::Real(data) => PayloadSrc::Real(data),
        }
    }

    fn of_saved(p: &'a SavedPayload) -> Self {
        match p {
            SavedPayload::Full(p) => Self::of(p),
            SavedPayload::ParentRef { fingerprint } => PayloadSrc::ParentRef {
                fingerprint: *fingerprint,
            },
        }
    }

    /// Encoded payload-kind tag (part of the digest-cache validity key).
    pub(crate) fn kind(&self) -> u8 {
        match self {
            PayloadSrc::Zero => 0,
            PayloadSrc::Pattern(_) => 1,
            PayloadSrc::Real(_) => 2,
            PayloadSrc::ParentRef { .. } => 3,
        }
    }

    /// Resident (real) payload bytes.
    pub(crate) fn resident(&self) -> u64 {
        match self {
            PayloadSrc::Real(data) => data.len() as u64,
            _ => 0,
        }
    }
}

/// Borrowed region record (one table row or extra pseudo-region).
#[derive(Clone, Copy, Debug)]
pub struct RegionSrc<'a> {
    pub addr: u64,
    pub vlen: u64,
    pub name: &'a str,
    pub payload: PayloadSrc<'a>,
}

/// Image header fields the encoder needs besides the regions.
#[derive(Clone, Copy, Debug)]
pub struct ImageMeta<'a> {
    pub rank: RankId,
    pub step: u64,
    pub rng_state: &'a [u8; 32],
    /// Parent full-image path (`Some` marks an incremental image).
    pub parent: Option<&'a str>,
    pub upper_fds: &'a [(u32, String)],
}

/// Encoded size of an image built from `regions` — exact under fixed
/// tiling (the write path reserves once and never reallocates mid-encode);
/// an upper bound under CDC, whose chunk count depends on content.
fn encoded_size_src(
    meta: &ImageMeta<'_>,
    regions: &[RegionSrc<'_>],
    chunking: Chunking,
) -> usize {
    let mut n = 8 + 4 + 4 + 8 + 32; // magic..rng
    n += 4 + meta.parent.map_or(0, str::len);
    n += 4;
    for (_, name) in meta.upper_fds {
        n += 4 + 4 + name.len();
    }
    n += 4;
    for r in regions {
        n += 8 + 8 + 4 + r.name.len() + 1;
        n += match r.payload {
            PayloadSrc::Zero => 0,
            PayloadSrc::Pattern(_) => 8,
            PayloadSrc::Real(data) => chunk::encoded_len_bound(data.len(), &chunking),
            PayloadSrc::ParentRef { .. } => 8,
        };
        n += 4; // section crc
    }
    n + 4 // trailer
}

/// The streaming image encoder every write path funnels through: append
/// the image described by (`meta`, `regions`) to `out`, optionally
/// recording the content-addressed [`ChunkRecipe`] as encoding proceeds.
///
/// `slots` are the per-region digest-memoization slots, parallel to the
/// *first* `slots.len()` entries of `regions` (extra pseudo-regions carry
/// no slot and always encode fresh; an empty slice disables memoization).
/// A usable slot whose cached section still matches the region replays
/// its encoded bytes, section CRC and chunk digests without re-hashing a
/// single payload byte. An entry downgraded to chunk granularity by
/// tracked writes ([`crate::mem::RegionTable::write_range`]) takes the
/// partial path instead: only chunks intersecting the recorded stale
/// spans re-hash, and a fresh entry is replanted. A miss re-encodes and —
/// for regions that were clean at harvest time — repopulates the slot (an
/// entry built for a dirty region could never be consulted, so none is
/// made).
pub(crate) fn encode_stream(
    out: &mut Vec<u8>,
    meta: &ImageMeta<'_>,
    regions: &[RegionSrc<'_>],
    chunking: Chunking,
    mut recipe: Option<&mut ChunkRecipe>,
    slots: &mut [CacheSlot],
    stats: &mut CacheStats,
) {
    assert!(chunking.is_valid(), "invalid chunking {chunking:?}");
    let base = out.len();
    out.reserve(encoded_size_src(meta, regions, chunking));
    out.extend_from_slice(MAGIC);
    put_u32(out, VERSION);
    put_u32(out, meta.rank.0);
    put_u64(out, meta.step);
    out.extend_from_slice(meta.rng_state);
    put_str(out, meta.parent.unwrap_or(""));
    put_u32(out, meta.upper_fds.len() as u32);
    for (fd, name) in meta.upper_fds {
        put_u32(out, *fd);
        put_str(out, name);
    }
    put_u32(out, regions.len() as u32);
    // Trailer covers header + every section CRC (perf: payload bytes
    // are hashed exactly once — by their chunk or section CRC — and
    // any corruption still lands in some CRC).
    let mut trailer = crc32::Hasher::new();
    trailer.update(&out[base..]);
    if let Some(rec) = recipe.as_deref_mut() {
        // Header chunk: zero virtual bytes, re-ships every generation
        // (step/rng change), but it is ~100 real bytes.
        push_meta_chunk(rec, base, base, out);
    }
    for (i, r) in regions.iter().enumerate() {
        let start = out.len();
        let want_recipe = recipe.is_some();
        // Digest memoization: a clean region whose cached section still
        // matches replays bytes + CRC + digests with zero hash work. An
        // entry populated by a recipe-less encode has no chunk digests
        // and must not serve a recipe encode.
        let hit = slots.get(i).and_then(|slot| {
            if !slot.usable {
                return None;
            }
            let c = slot.entry.as_deref()?;
            (c.matches(r, chunking)
                && c.stale_ranges.is_empty()
                && (!want_recipe || !c.rel_chunks.is_empty()))
            .then_some(c)
        });
        if let Some(c) = hit {
            out.extend_from_slice(&c.encoded);
            trailer.update(&c.section_crc.to_le_bytes());
            if let Some(rec) = recipe.as_deref_mut() {
                let delta = (start - base) as u64;
                for ch in &c.rel_chunks {
                    rec.chunks.push(ch.shifted_by(delta));
                }
            }
            stats.hit_vbytes += r.vlen;
            stats.hit_regions += 1;
            continue;
        }
        // Chunk-granular partial hit: the entry was downgraded by tracked
        // in-place writes (`RegionTable::write_range` recorded the spans).
        // Re-frame the record reusing the memoized CRC and digest of every
        // chunk outside the stale spans — one hot page re-hashes one
        // chunk, not the region. Usability is irrelevant here: the entry
        // plus its spans describe the live bytes whether or not the dirty
        // bit is set.
        let partial = slots.get(i).and_then(|slot| {
            let c = slot.entry.as_deref()?;
            let PayloadSrc::Real(data) = r.payload else {
                return None;
            };
            (!c.stale_ranges.is_empty()
                && c.matches(r, chunking)
                // No virtual tail: tail digests hash the whole payload,
                // which would defeat the chunk-granular accounting.
                && r.vlen == data.len() as u64
                && !c.payload_cuts.is_empty()
                && c.payload_cuts.len() == c.chunk_crcs.len()
                && (!want_recipe || c.rel_chunks.len() == c.payload_cuts.len()))
            .then_some(())
        });
        if partial.is_some() {
            let slot = &mut slots[i];
            let entry = slot.entry.take().expect("checked above");
            let PayloadSrc::Real(data) = r.payload else {
                unreachable!("checked above");
            };
            let k0 = recipe.as_deref().map(|rec| rec.chunks.len());
            let part = encode_region_partial(
                out,
                r,
                data,
                &entry,
                chunking,
                base,
                start,
                recipe.as_deref_mut(),
            );
            trailer.update(&part.section_crc.to_le_bytes());
            let rel_chunks: Vec<chunk::RecipeChunk> = match (k0, recipe.as_deref()) {
                (Some(k0), Some(rec)) => {
                    let delta = (start - base) as u64;
                    rec.chunks[k0..]
                        .iter()
                        .map(|ch| ch.shifted_back(delta))
                        .collect()
                }
                _ => Vec::new(),
            };
            stats.hit_vbytes += r.vlen.saturating_sub(part.fresh_hash_vbytes);
            stats.fresh_hash_vbytes += part.fresh_hash_vbytes;
            stats.partial_regions += 1;
            // Replant a fresh entry (valid for the bytes just encoded, no
            // stale spans) so the next generation starts warm again.
            slot.entry = Some(Box::new(RegionDigestCache {
                chunking,
                vlen: r.vlen,
                kind: r.payload.kind(),
                resident: r.payload.resident(),
                section_crc: part.section_crc,
                encoded: out[start..].to_vec(),
                rel_chunks,
                payload_cuts: part.payload_cuts,
                chunk_crcs: part.chunk_crcs,
                stale_ranges: Vec::new(),
            }));
            continue;
        }
        let chunks_before = recipe.as_deref().map(|rec| rec.chunks.len());
        put_u64(out, r.addr);
        put_u64(out, r.vlen);
        put_str(out, r.name);
        // Real payloads derive their cut layout once; framing and recipe
        // emission both walk it, which is what keeps them in agreement for
        // content-defined boundaries.
        let mut real_cuts: Vec<usize> = Vec::new();
        let mut real_crcs: Vec<u32> = Vec::new();
        let crc = match r.payload {
            PayloadSrc::Zero => {
                out.push(0);
                crc32::hash(&out[start..])
            }
            PayloadSrc::Pattern(seed) => {
                out.push(1);
                put_u64(out, seed);
                crc32::hash(&out[start..])
            }
            PayloadSrc::Real(data) => {
                // Chunk-framed: the section CRC covers the record
                // metadata and every chunk CRC; chunk bytes are
                // covered by their own CRCs.
                out.push(2);
                let mut sec = crc32::Hasher::new();
                sec.update(&out[start..]);
                real_cuts = chunking.cut_lengths(data);
                real_crcs = chunk::write_chunked(out, data, &real_cuts, &mut sec);
                stats.fresh_hash_vbytes += data.len() as u64;
                sec.finalize()
            }
            PayloadSrc::ParentRef { fingerprint } => {
                out.push(3);
                put_u64(out, fingerprint);
                crc32::hash(&out[start..])
            }
        };
        put_u32(out, crc);
        trailer.update(&crc.to_le_bytes());
        if let Some(rec) = recipe.as_deref_mut() {
            push_region_chunks(rec, r, base, start, out, chunking, &real_cuts);
        }
        // Populate the slot for the next generation — but only for a
        // region that was *clean* at harvest time: an entry built while
        // dirty has no record of which bytes may still change before the
        // next harvest, so it could never be consulted and cloning the
        // section for it would be pure dead work. (Regions dirtied through
        // `write_range` keep their previous entry with stale spans and are
        // served by the partial path above instead of landing here.)
        // ParentRef records never clobber a cached Full section either:
        // the full cache stays valid while the region stays clean, so it
        // serves the next *full* checkpoint warm even across incremental
        // ones.
        if !matches!(r.payload, PayloadSrc::ParentRef { .. }) {
            if let Some(slot) = slots.get_mut(i).filter(|s| s.usable) {
                let rel_chunks: Vec<chunk::RecipeChunk> =
                    match (chunks_before, recipe.as_deref()) {
                        (Some(k0), Some(rec)) => {
                            let delta = (start - base) as u64;
                            rec.chunks[k0..]
                                .iter()
                                .map(|ch| ch.shifted_back(delta))
                                .collect()
                        }
                        _ => Vec::new(),
                    };
                slot.entry = Some(Box::new(RegionDigestCache {
                    chunking,
                    vlen: r.vlen,
                    kind: r.payload.kind(),
                    resident: r.payload.resident(),
                    section_crc: crc,
                    encoded: out[start..].to_vec(),
                    rel_chunks,
                    payload_cuts: real_cuts.iter().map(|&c| c as u32).collect(),
                    chunk_crcs: real_crcs,
                    stale_ranges: Vec::new(),
                }));
                stats.filled_regions += 1;
            }
        }
    }
    let tstart = out.len();
    put_u32(out, trailer.finalize());
    if let Some(rec) = recipe.as_deref_mut() {
        push_meta_chunk(rec, base, tstart, out);
    }
}

/// One region's chunk-granular partial re-encode: the pieces the caller
/// needs to fold the record into the image trailer and replant the slot.
struct PartialEncode {
    section_crc: u32,
    payload_cuts: Vec<u32>,
    chunk_crcs: Vec<u32>,
    /// Payload bytes whose CRC or digest had to be recomputed (the
    /// chunk-proportional hash cost of this record).
    fresh_hash_vbytes: u64,
}

/// Re-frame one fully-resident Real region from a digest-cache entry that
/// was downgraded to chunk granularity by tracked in-place writes.
///
/// The chunk grid is re-derived so the emitted record is bitwise identical
/// to a cold encode of the live bytes:
///
/// * `Fixed` — the grid is positional and the length is unchanged, so the
///   tiling is unchanged; a chunk is recomputed iff its span intersects a
///   stale range.
/// * `Cdc` — cuts at or before the first stale byte are provably identical
///   (every window the scanner judged lies strictly below the stale span).
///   From the last such cut the scan resumes via [`cdc::next_cut`] — which
///   uses full-buffer warm-up windows, so resuming mid-buffer is exact —
///   until it lands on an old cut at least [`cdc::WINDOW`] bytes past the
///   last stale byte. Beyond that point every window the old scan judged
///   reads only unchanged bytes, so the old cut tail is spliced back
///   verbatim and its chunks reused.
///
/// Reused chunks replay their memoized CRC32 (and recipe digest); only
/// rescanned chunks re-hash payload bytes. Two framing subtleties force a
/// digest recompute even for byte-identical payload chunks: the last framed
/// chunk's digest span includes the section CRC (which changes whenever any
/// chunk changed), and chunk 0's span includes the record header with the
/// chunk count (which may change under CDC).
#[allow(clippy::too_many_arguments)]
fn encode_region_partial(
    out: &mut Vec<u8>,
    r: &RegionSrc<'_>,
    data: &[u8],
    c: &RegionDigestCache,
    chunking: Chunking,
    base: usize,
    start: usize,
    rec: Option<&mut ChunkRecipe>,
) -> PartialEncode {
    let n = data.len();
    let mut old_ends: Vec<usize> = Vec::with_capacity(c.payload_cuts.len());
    let mut acc = 0usize;
    for &l in &c.payload_cuts {
        acc += l as usize;
        old_ends.push(acc);
    }
    debug_assert_eq!(acc, n, "cached cut layout must tile the payload");
    let first_stale = c.stale_ranges[0].0 as usize;
    let last_stale_end = c.stale_ranges[c.stale_ranges.len() - 1].1 as usize;
    // New cut layout (as end offsets) plus, per new chunk, the old chunk
    // index whose bytes and span it provably matches (None → recompute).
    let (ends, reuse): (Vec<usize>, Vec<Option<usize>>) = match chunking {
        Chunking::Fixed(_) => {
            let reuse = old_ends
                .iter()
                .enumerate()
                .map(|(i, &e)| {
                    let s = if i == 0 { 0 } else { old_ends[i - 1] };
                    let clean = !c
                        .stale_ranges
                        .iter()
                        .any(|&(lo, hi)| (lo as usize) < e && (hi as usize) > s);
                    clean.then_some(i)
                })
                .collect();
            (old_ends.clone(), reuse)
        }
        Chunking::Cdc(p) => {
            let mut ends = Vec::new();
            let mut reuse = Vec::new();
            let mut pi = 0;
            while pi < old_ends.len() && old_ends[pi] <= first_stale {
                ends.push(old_ends[pi]);
                reuse.push(Some(pi));
                pi += 1;
            }
            let resync_floor = last_stale_end + cdc::WINDOW;
            let mut q = ends.last().copied().unwrap_or(0);
            let mut spliced = None;
            while q < n {
                let cut = cdc::next_cut(data, &p, q);
                ends.push(cut);
                reuse.push(None);
                q = cut;
                if cut >= resync_floor {
                    if let Ok(j) = old_ends.binary_search(&cut) {
                        spliced = Some(j);
                        break;
                    }
                }
            }
            if let Some(j) = spliced {
                for (k, &e) in old_ends.iter().enumerate().skip(j + 1) {
                    ends.push(e);
                    reuse.push(Some(k));
                }
            }
            (ends, reuse)
        }
    };
    debug_assert_eq!(ends.last().copied().unwrap_or(0), n);
    // Emit the record with the exact frame write_chunked produces.
    let n_new = ends.len();
    put_u64(out, r.addr);
    put_u64(out, r.vlen);
    put_str(out, r.name);
    out.push(2);
    let mut sec = crc32::Hasher::new();
    sec.update(&out[start..]);
    let nb = (n_new as u32).to_le_bytes();
    out.extend_from_slice(&nb);
    sec.update(&nb);
    let mut hashed = vec![false; n_new];
    let mut fresh = 0u64;
    let mut chunk_crcs = Vec::with_capacity(n_new);
    let mut payload_cuts = Vec::with_capacity(n_new);
    let mut prev = 0usize;
    for (k, &e) in ends.iter().enumerate() {
        let bytes = &data[prev..e];
        let lenb = (bytes.len() as u32).to_le_bytes();
        out.extend_from_slice(&lenb);
        sec.update(&lenb);
        out.extend_from_slice(bytes);
        let crc_val = match reuse[k] {
            Some(j) => c.chunk_crcs[j],
            None => {
                hashed[k] = true;
                fresh += bytes.len() as u64;
                crc32::hash(bytes)
            }
        };
        let crcb = crc_val.to_le_bytes();
        out.extend_from_slice(&crcb);
        sec.update(&crcb);
        chunk_crcs.push(crc_val);
        payload_cuts.push(bytes.len() as u32);
        prev = e;
    }
    let section_crc = sec.finalize();
    put_u32(out, section_crc);
    if let Some(rec) = rec {
        let end = out.len();
        let meta_end = start + 8 + 8 + 4 + r.name.len() + 1 + 4;
        let mut cursor = meta_end;
        let mut prev = 0usize;
        let same_grid = n_new == c.payload_cuts.len();
        for (k, &e) in ends.iter().enumerate() {
            let clen = e - prev;
            let mut cend = cursor + 4 + clen + 4;
            if k + 1 == n_new {
                // Last chunk absorbs the section CRC.
                cend += 4;
                debug_assert_eq!(cend, end);
            }
            let cstart = if k == 0 { start } else { cursor };
            let vb = clen as u64;
            // Interior reused chunks map to interior old chunks with the
            // same frame shape; chunk 0 additionally needs the header
            // (chunk count included) unchanged; the last chunk never
            // reuses (section CRC in its span).
            let frame_stable = k + 1 < n_new && (k != 0 || same_grid);
            let digest = match reuse[k] {
                Some(j) if frame_stable => c.rel_chunks[j].digest,
                _ => {
                    if !hashed[k] {
                        hashed[k] = true;
                        fresh += clen as u64;
                    }
                    chunk::chunk_digest(chunk::TAG_REAL, vb, &[], &out[cstart..cend])
                }
            };
            rec.chunks.push(chunk::RecipeChunk {
                digest,
                vbytes: vb,
                real_off: (cstart - base) as u64,
                real_len: (cend - cstart) as u64,
            });
            cursor = cend;
            prev = e;
        }
    }
    PartialEncode {
        section_crc,
        payload_cuts,
        chunk_crcs,
        fresh_hash_vbytes: fresh,
    }
}

/// Resolve an incremental image against its parent full image, producing a
/// fully-materialized image. Both images are consumed: the incremental's
/// own dirty payloads stay in place and referenced payloads are *moved*
/// out of the parent, so resolving a ParentRef-heavy image duplicates no
/// payload bytes (the restart path used to clone the whole image first).
/// Fingerprints of referenced regions are verified (a mismatch means the
/// parent is not the image this incremental was taken against).
pub fn resolve_incremental(
    mut img: CkptImage,
    parent: CkptImage,
) -> Result<CkptImage, ImageError> {
    img.parent = None;
    let mut parent_regions = parent.regions;
    for r in &mut img.regions {
        if let SavedPayload::ParentRef { fingerprint } = r.payload {
            let src = parent_regions
                .iter_mut()
                .find(|p| p.name == r.name)
                .ok_or_else(|| ImageError::CrcMismatch {
                    section: format!("{}: missing in parent", r.name),
                })?;
            // Move the payload out, leaving a consumed marker behind — a
            // duplicate reference to the same parent region would then
            // fail the materialization check instead of silently aliasing.
            let taken =
                std::mem::replace(&mut src.payload, SavedPayload::ParentRef { fingerprint: 0 });
            let SavedPayload::Full(payload) = taken else {
                return Err(ImageError::CrcMismatch {
                    section: format!("{}: parent not materialized", r.name),
                });
            };
            if payload.fingerprint(src.vlen) != fingerprint {
                return Err(ImageError::CrcMismatch {
                    section: format!("{}: parent content drifted", r.name),
                });
            }
            r.payload = SavedPayload::Full(payload);
        }
    }
    Ok(img)
}

/// Image decode/validate failures.
#[derive(Clone, Debug, PartialEq)]
pub enum ImageError {
    BadMagic,
    BadVersion(u32),
    Truncated(&'static str),
    CrcMismatch { section: String },
}

impl fmt::Display for ImageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ImageError::BadMagic => write!(f, "not a MANA image (bad magic)"),
            ImageError::BadVersion(v) => write!(f, "unsupported image version {v}"),
            ImageError::Truncated(what) => write!(f, "image truncated at {what}"),
            ImageError::CrcMismatch { section } => {
                write!(f, "CRC mismatch in section {section}")
            }
        }
    }
}

impl std::error::Error for ImageError {}

impl CkptImage {
    /// Capture the upper half of a region table (full image).
    pub fn capture(
        rank: RankId,
        step: u64,
        rng_state: [u8; 32],
        upper_fds: Vec<(u32, String)>,
        table: &RegionTable,
    ) -> Self {
        let regions = table
            .half_iter(Half::Upper)
            .map(|r| SavedRegion {
                addr: r.addr,
                vlen: r.len,
                name: r.name.clone(),
                payload: SavedPayload::Full(r.payload.clone()),
            })
            .collect();
        CkptImage {
            rank,
            step,
            rng_state,
            parent: None,
            upper_fds,
            regions,
        }
    }

    /// Capture an incremental image against `parent_path`: regions dirty
    /// since the last full checkpoint are materialized; clean regions
    /// become fingerprinted parent references.
    pub fn capture_incremental(
        rank: RankId,
        step: u64,
        rng_state: [u8; 32],
        upper_fds: Vec<(u32, String)>,
        table: &RegionTable,
        parent_path: &str,
    ) -> Self {
        let regions = table
            .half_iter(Half::Upper)
            .map(|r| SavedRegion {
                addr: r.addr,
                vlen: r.len,
                name: r.name.clone(),
                payload: if r.dirty {
                    SavedPayload::Full(r.payload.clone())
                } else {
                    SavedPayload::ParentRef {
                        fingerprint: r.payload.fingerprint(r.len),
                    }
                },
            })
            .collect();
        CkptImage {
            rank,
            step,
            rng_state,
            parent: Some(parent_path.to_string()),
            upper_fds,
            regions,
        }
    }

    /// Total *virtual* bytes of application state this image represents.
    pub fn virtual_bytes(&self) -> u64 {
        self.regions.iter().map(|r| r.vlen).sum()
    }

    /// Bytes this image actually carries to storage (ParentRefs are free).
    pub fn write_bytes(&self) -> u64 {
        self.regions
            .iter()
            .filter(|r| matches!(r.payload, SavedPayload::Full(_)))
            .map(|r| r.vlen)
            .sum()
    }

    // ------------------------------------------------------------- encode

    /// Exact encoded size under fixed tiling (avoids reallocation in the
    /// write hot path). Delegates to the view-based [`encoded_size_src`]
    /// so the size math and the encoder share one definition of the wire
    /// format.
    fn encoded_size(&self, chunk_bytes: usize) -> usize {
        let meta = ImageMeta {
            rank: self.rank,
            step: self.step,
            rng_state: &self.rng_state,
            parent: self.parent.as_deref(),
            upper_fds: &self.upper_fds,
        };
        let srcs: Vec<RegionSrc<'_>> = self.regions.iter().map(SavedRegion::as_src).collect();
        encoded_size_src(&meta, &srcs, Chunking::Fixed(chunk_bytes))
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.encoded_size(chunk::DEFAULT_CHUNK_BYTES));
        self.encode_into(&mut out);
        out
    }

    /// Streaming encoder at the default chunk granularity.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        self.encode_impl(out, Chunking::Fixed(chunk::DEFAULT_CHUNK_BYTES), None);
    }

    /// Streaming encoder with explicit fixed chunk granularity
    /// (`RunConfig::chunk_bytes` / `--chunk-bytes`).
    pub fn encode_into_sized(&self, out: &mut Vec<u8>, chunk_bytes: usize) {
        self.encode_impl(out, Chunking::Fixed(chunk_bytes), None);
    }

    /// Streaming encoder with an explicit chunking strategy
    /// (`RunConfig::chunking_strategy()` / `--chunking fixed|cdc`).
    pub fn encode_into_chunked(&self, out: &mut Vec<u8>, chunking: Chunking) {
        self.encode_impl(out, chunking, None);
    }

    /// Streaming encoder that also emits the image's [`ChunkRecipe`]: the
    /// ordered per-chunk content digests the dedup-aware drain consumes,
    /// with each chunk's virtual size and the encoded-byte span it carries.
    /// Concatenating the real spans in order reproduces `out`'s new bytes
    /// exactly (checked by a debug assertion). Fixed tiling at
    /// `chunk_bytes`; see [`Self::encode_with_recipe_chunked`] for CDC.
    pub fn encode_with_recipe(&self, out: &mut Vec<u8>, chunk_bytes: usize) -> ChunkRecipe {
        self.encode_with_recipe_chunked(out, Chunking::Fixed(chunk_bytes))
    }

    /// [`Self::encode_with_recipe`] generalized over the chunking
    /// strategy: under `Chunking::Cdc` the recipe tiles Real payloads on
    /// content-defined boundaries.
    pub fn encode_with_recipe_chunked(
        &self,
        out: &mut Vec<u8>,
        chunking: Chunking,
    ) -> ChunkRecipe {
        let mut recipe = ChunkRecipe {
            chunk_bytes: chunking.avg_bytes() as u64,
            file_vbytes: self.write_bytes(),
            chunks: Vec::new(),
        };
        let base = out.len();
        self.encode_impl(out, chunking, Some(&mut recipe));
        debug_assert!(
            recipe.covers((out.len() - base) as u64),
            "recipe real spans must tile the encoded image"
        );
        debug_assert_eq!(
            recipe.chunks.iter().map(|c| c.vbytes).sum::<u64>(),
            recipe.file_vbytes,
            "recipe virtual bytes must sum to write_bytes"
        );
        recipe
    }

    /// Streaming encoder: append the image to `out` (callers pre-reserve
    /// via [`Self::encoded_size`] math or reuse one buffer across ranks).
    /// `Real` payload bytes flow from the region straight into `out` in
    /// CRC'd fixed-size chunks — no intermediate whole-image buffer.
    /// With `recipe`, per-chunk content digests are recorded as encoding
    /// proceeds (payload bytes are digested exactly once, in place).
    /// Delegates to [`encode_stream`], the same engine the rank-parallel
    /// [`datapath`] drives from live region tables — the serial/parallel
    /// byte-identity guarantee rests on this shared implementation.
    fn encode_impl(
        &self,
        out: &mut Vec<u8>,
        chunking: Chunking,
        recipe: Option<&mut ChunkRecipe>,
    ) {
        let meta = ImageMeta {
            rank: self.rank,
            step: self.step,
            rng_state: &self.rng_state,
            parent: self.parent.as_deref(),
            upper_fds: &self.upper_fds,
        };
        let srcs: Vec<RegionSrc<'_>> = self.regions.iter().map(SavedRegion::as_src).collect();
        encode_stream(
            out,
            &meta,
            &srcs,
            chunking,
            recipe,
            &mut [],
            &mut CacheStats::default(),
        );
    }

    // ------------------------------------------------------------- decode

    pub fn decode(bytes: &[u8]) -> Result<Self, ImageError> {
        let mut c = Cursor { buf: bytes, pos: 0 };
        if bytes.len() < 12 || &bytes[..8] != MAGIC {
            return Err(ImageError::BadMagic);
        }
        // Whole-image CRC first: trailer covers everything before it.
        if bytes.len() < 4 {
            return Err(ImageError::Truncated("trailer"));
        }
        let trailer_want = u32::from_le_bytes(
            bytes[bytes.len() - 4..].try_into().unwrap(),
        );
        let mut trailer = crc32::Hasher::new();
        c.pos = 8;
        let version = c.u32()?;
        if version != VERSION {
            return Err(ImageError::BadVersion(version));
        }
        let rank = RankId(c.u32()?);
        let step = c.u64()?;
        let rng_state: [u8; 32] = c
            .take(32)?
            .try_into()
            .map_err(|_| ImageError::Truncated("rng"))?;
        let parent_s = c.string()?;
        let parent = if parent_s.is_empty() {
            None
        } else {
            Some(parent_s)
        };
        // Counts are parsed *before* any CRC validates them (the trailer
        // is single-pass now), so never trust them for allocation: bound
        // capacities by what the remaining bytes could possibly hold.
        let n_fds = c.u32()?;
        let remaining = bytes.len().saturating_sub(c.pos);
        let mut upper_fds = Vec::with_capacity((n_fds as usize).min(remaining / 8));
        for _ in 0..n_fds {
            let fd = c.u32()?;
            let name = c.string()?;
            upper_fds.push((fd, name));
        }
        let n_regions = c.u32()?;
        // Trailer = CRC(header .. n_regions) + each section's CRC field.
        trailer.update(&c.buf[..c.pos]);
        let remaining = bytes.len().saturating_sub(c.pos);
        let mut regions = Vec::with_capacity((n_regions as usize).min(remaining / 25));
        for _ in 0..n_regions {
            let start = c.pos;
            let addr = c.u64()?;
            let vlen = c.u64()?;
            let name = c.string()?;
            let kind = c.u8()?;
            let (payload, section_crc) = match kind {
                0 => (
                    SavedPayload::Full(Payload::Zero),
                    crc32::hash(&c.buf[start..c.pos]),
                ),
                1 => {
                    let seed = c.u64()?;
                    (
                        SavedPayload::Full(Payload::Pattern(seed)),
                        crc32::hash(&c.buf[start..c.pos]),
                    )
                }
                2 => {
                    // Chunk-framed Real payload (v4): verify per-chunk
                    // CRCs, fold the frame metadata into the section CRC.
                    let mut sec = crc32::Hasher::new();
                    sec.update(&c.buf[start..c.pos]);
                    let data = chunk::read_chunked(&mut c, &mut sec, &name)?;
                    (SavedPayload::Full(Payload::Real(data)), sec.finalize())
                }
                3 => {
                    let fingerprint = c.u64()?;
                    (
                        SavedPayload::ParentRef { fingerprint },
                        crc32::hash(&c.buf[start..c.pos]),
                    )
                }
                _ => return Err(ImageError::Truncated("payload kind")),
            };
            let crc = c.u32()?;
            if section_crc != crc {
                return Err(ImageError::CrcMismatch { section: name });
            }
            trailer.update(&crc.to_le_bytes());
            regions.push(SavedRegion {
                addr,
                vlen,
                name,
                payload,
            });
        }
        if c.pos != bytes.len() - 4 {
            return Err(ImageError::Truncated("trailing bytes"));
        }
        if trailer.finalize() != trailer_want {
            return Err(ImageError::CrcMismatch {
                section: "image".into(),
            });
        }
        Ok(CkptImage {
            rank,
            step,
            rng_state,
            parent,
            upper_fds,
            regions,
        })
    }
}

// ------------------------------------------------------- recipe building

/// Virtual bytes chunk `i` of a `vlen`-byte region accounts for.
fn chunk_vb(vlen: u64, i: usize, chunk_bytes: usize) -> u64 {
    let cb = chunk_bytes as u64;
    let off = (i as u64).saturating_mul(cb);
    if off >= vlen {
        0
    } else {
        (vlen - off).min(cb)
    }
}

/// Record a zero-virtual-byte metadata chunk covering `out[span_start..]`
/// (the image header, or the whole-image trailer).
fn push_meta_chunk(rec: &mut ChunkRecipe, base: usize, span_start: usize, out: &[u8]) {
    let real = &out[span_start..];
    rec.chunks.push(chunk::RecipeChunk {
        digest: chunk::chunk_digest(chunk::TAG_META, 0, &[], real),
        vbytes: 0,
        real_off: (span_start - base) as u64,
        real_len: real.len() as u64,
    });
}

/// Record the recipe chunks of one just-encoded region record
/// (`out[start..]`, section CRC included).
///
/// Layout rules (the reassembly soundness contract):
/// * every encoded byte of the record is carried by exactly one chunk's
///   real span, in order — chunk 0 picks up the record metadata, the last
///   real-carrying chunk picks up the section CRC;
/// * virtual-only chunks (pattern/zero tails whose encoding is just a
///   seed) carry no real bytes and dedup purely on semantic content;
/// * a chunk's digest covers any real bytes it carries, so equal digests
///   always reproduce equal stored bytes.
///
/// `real_cuts` are the framed cut lengths of a Real payload (the same
/// layout [`chunk::write_chunked`] just emitted); other payload kinds
/// ignore it. Pattern/Zero virtual tiles and Real virtual tails always
/// sit on the *average*-granularity grid — content-defined boundaries
/// apply only to real payload bytes, so those domains chunk identically
/// in both modes.
fn push_region_chunks(
    rec: &mut ChunkRecipe,
    r: &RegionSrc<'_>,
    base: usize,
    start: usize,
    out: &[u8],
    chunking: Chunking,
    real_cuts: &[usize],
) {
    let end = out.len();
    let chunk_bytes = chunking.avg_bytes();
    let span = |a: usize, b: usize| ((a - base) as u64, (b - a) as u64);
    match r.payload {
        PayloadSrc::Zero => {
            let n = chunk_count_virtual(r.vlen, chunk_bytes);
            for i in 0..n {
                let vb = chunk_vb(r.vlen, i, chunk_bytes);
                // Chunk 0 carries the encoded record; the rest are pure
                // virtual zero chunks that dedup globally by size.
                let (real_off, real_len, real): (u64, u64, &[u8]) = if i == 0 {
                    let (o, l) = span(start, end);
                    (o, l, &out[start..end])
                } else {
                    (0, 0, &[])
                };
                rec.chunks.push(chunk::RecipeChunk {
                    digest: chunk::chunk_digest(chunk::TAG_ZERO, vb, &[], real),
                    vbytes: vb,
                    real_off,
                    real_len,
                });
            }
        }
        PayloadSrc::Pattern(seed) => {
            let n = chunk_count_virtual(r.vlen, chunk_bytes);
            for i in 0..n {
                let vb = chunk_vb(r.vlen, i, chunk_bytes);
                let mut extra = [0u8; 16];
                extra[..8].copy_from_slice(&seed.to_le_bytes());
                extra[8..].copy_from_slice(&(i as u64).to_le_bytes());
                let (real_off, real_len, real): (u64, u64, &[u8]) = if i == 0 {
                    let (o, l) = span(start, end);
                    (o, l, &out[start..end])
                } else {
                    (0, 0, &[])
                };
                rec.chunks.push(chunk::RecipeChunk {
                    digest: chunk::chunk_digest(chunk::TAG_PATTERN, vb, &extra, real),
                    vbytes: vb,
                    real_off,
                    real_len,
                });
            }
        }
        PayloadSrc::Real(data) => match chunking {
            Chunking::Fixed(chunk_bytes) => {
                // Framed data chunks align with the recipe chunks; the
                // framing after the record metadata is: n_chunks u32, then
                // per chunk [len u32][bytes][crc u32], then the section
                // CRC u32. This arm is the historical fixed-grid layout,
                // preserved bit-exactly (digests included) so fixed-mode
                // images and recipes stay identical to pre-CDC output.
                let nd = chunk::chunk_count(data.len(), chunk_bytes);
                let nv = chunk_count_virtual(r.vlen, chunk_bytes);
                let n = nd.max(nv);
                let meta_end = start + 8 + 8 + 4 + r.name.len() + 1 + 4; // ..n_chunks
                // Payload fingerprint, needed only by virtual-tail chunks —
                // computed lazily so a fully-resident region (the common
                // case) never hashes its bytes a second time.
                let fp = if n > nd { crate::util::fnv1a(data) } else { 0 };
                let mut cursor = meta_end;
                for i in 0..n {
                    let vb = chunk_vb(r.vlen, i, chunk_bytes);
                    if i < nd {
                        let clen = chunk_bytes.min(data.len() - i * chunk_bytes);
                        let mut cend = cursor + 4 + clen + 4;
                        if i + 1 == nd {
                            cend += 4; // the last framed chunk carries the section CRC
                            debug_assert_eq!(cend, end);
                        }
                        let cstart = if i == 0 { start } else { cursor };
                        let (real_off, real_len) = span(cstart, cend);
                        rec.chunks.push(chunk::RecipeChunk {
                            digest: chunk::chunk_digest(
                                chunk::TAG_REAL,
                                vb,
                                &[],
                                &out[cstart..cend],
                            ),
                            vbytes: vb,
                            real_off,
                            real_len,
                        });
                        cursor = cend;
                    } else if nd == 0 && i == 0 {
                        // Empty data: chunk 0 still carries the whole record.
                        let (real_off, real_len) = span(start, end);
                        rec.chunks.push(chunk::RecipeChunk {
                            digest: chunk::chunk_digest(
                                chunk::TAG_REAL,
                                vb,
                                &[],
                                &out[start..end],
                            ),
                            vbytes: vb,
                            real_off,
                            real_len,
                        });
                    } else {
                        // Purely virtual tail (vlen exceeds the resident
                        // bytes): dedup on the payload fingerprint + position.
                        let mut extra = [0u8; 16];
                        extra[..8].copy_from_slice(&fp.to_le_bytes());
                        extra[8..].copy_from_slice(&(i as u64).to_le_bytes());
                        rec.chunks.push(chunk::RecipeChunk {
                            digest: chunk::chunk_digest(chunk::TAG_REAL, vb, &extra, &[]),
                            vbytes: vb,
                            real_off: 0,
                            real_len: 0,
                        });
                    }
                }
            }
            Chunking::Cdc(_) => {
                // Content-defined layout: walk the cut lengths the framing
                // just emitted. A chunk is charged the virtual bytes it
                // carries (capped by what remains of `vlen`), so for the
                // fully-resident common case a downstream chunk's
                // (vbytes, frame bytes) pair — and therefore its digest —
                // is a pure function of its content, which is exactly the
                // shift invariance the fixed grid cannot provide.
                let nd = real_cuts.len();
                let meta_end = start + 8 + 8 + 4 + r.name.len() + 1 + 4; // ..n_chunks
                let mut remaining_vb = r.vlen;
                let mut cursor = meta_end;
                if nd == 0 {
                    // Empty data: one chunk carries the whole record.
                    let vb = remaining_vb.min(chunk_bytes as u64);
                    remaining_vb -= vb;
                    let (real_off, real_len) = span(start, end);
                    rec.chunks.push(chunk::RecipeChunk {
                        digest: chunk::chunk_digest(chunk::TAG_REAL, vb, &[], &out[start..end]),
                        vbytes: vb,
                        real_off,
                        real_len,
                    });
                }
                for (i, &clen) in real_cuts.iter().enumerate() {
                    let mut cend = cursor + 4 + clen + 4;
                    if i + 1 == nd {
                        cend += 4; // the last framed chunk carries the section CRC
                        debug_assert_eq!(cend, end);
                    }
                    let cstart = if i == 0 { start } else { cursor };
                    let vb = remaining_vb.min(clen as u64);
                    remaining_vb -= vb;
                    let (real_off, real_len) = span(cstart, cend);
                    rec.chunks.push(chunk::RecipeChunk {
                        digest: chunk::chunk_digest(
                            chunk::TAG_REAL,
                            vb,
                            &[],
                            &out[cstart..cend],
                        ),
                        vbytes: vb,
                        real_off,
                        real_len,
                    });
                    cursor = cend;
                }
                // Purely virtual tail (vlen exceeds the resident bytes):
                // no content to cut, so it tiles on the average grid and
                // dedups on the payload fingerprint + position, exactly as
                // under fixed tiling.
                if remaining_vb > 0 {
                    let fp = crate::util::fnv1a(data);
                    let mut i = nd.max(1);
                    while remaining_vb > 0 {
                        let vb = remaining_vb.min(chunk_bytes as u64);
                        remaining_vb -= vb;
                        let mut extra = [0u8; 16];
                        extra[..8].copy_from_slice(&fp.to_le_bytes());
                        extra[8..].copy_from_slice(&(i as u64).to_le_bytes());
                        rec.chunks.push(chunk::RecipeChunk {
                            digest: chunk::chunk_digest(chunk::TAG_REAL, vb, &extra, &[]),
                            vbytes: vb,
                            real_off: 0,
                            real_len: 0,
                        });
                        i += 1;
                    }
                }
            }
        },
        PayloadSrc::ParentRef { fingerprint } => {
            // Zero virtual bytes (write_bytes excludes ParentRefs); one
            // chunk carrying the ~30-byte reference record.
            let (real_off, real_len) = span(start, end);
            rec.chunks.push(chunk::RecipeChunk {
                digest: chunk::chunk_digest(
                    chunk::TAG_PARENT,
                    0,
                    &fingerprint.to_le_bytes(),
                    &out[start..end],
                ),
                vbytes: 0,
                real_off,
                real_len,
            });
        }
    }
}

/// Number of recipe chunks a `vlen`-byte virtual region occupies (≥ 1 so
/// the encoded record always has a carrier).
fn chunk_count_virtual(vlen: u64, chunk_bytes: usize) -> usize {
    (vlen.div_ceil(chunk_bytes as u64) as usize).max(1)
}

// ----------------------------------------------------------------- helpers

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

pub(crate) struct Cursor<'a> {
    pub(crate) buf: &'a [u8],
    pub(crate) pos: usize,
}

impl<'a> Cursor<'a> {
    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8], ImageError> {
        if self.pos + n > self.buf.len() {
            return Err(ImageError::Truncated("buffer"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    pub(crate) fn u8(&mut self) -> Result<u8, ImageError> {
        Ok(self.take(1)?[0])
    }
    pub(crate) fn u32(&mut self) -> Result<u32, ImageError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub(crate) fn u64(&mut self) -> Result<u64, ImageError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub(crate) fn string(&mut self) -> Result<String, ImageError> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| ImageError::Truncated("utf8"))
    }
}

/// Canonical image path for a rank within a job.
pub fn image_path(job: &str, rank: RankId) -> String {
    format!("{job}/ckpt_rank{:05}.mana", rank.0)
}

/// Generation-qualified full-image path. Staged (tiered) checkpoints keep
/// several generations resident at once, so paths carry the generation.
pub fn gen_image_path(job: &str, gen: u64, rank: RankId) -> String {
    format!("{job}/gen{gen:04}/ckpt_rank{:05}.mana", rank.0)
}

/// Generation-qualified incremental-image path.
pub fn gen_incr_image_path(job: &str, gen: u64, rank: RankId) -> String {
    format!("{job}/gen{gen:04}/ckpt_rank{:05}.inc.mana", rank.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::{AllocPolicy, AddressSpace, OsVersion};

    fn sample_image() -> CkptImage {
        CkptImage {
            rank: RankId(3),
            step: 1234,
            rng_state: [7u8; 32],
            parent: None,
            upper_fds: vec![(3, "traj.xtc".into()), (4, "ener.edr".into())],
            regions: vec![
                SavedRegion {
                    addr: 0x1000_0000_0000,
                    vlen: 1 << 30,
                    name: "mana.app_heap".into(),
                    payload: SavedPayload::Full(Payload::Pattern(99)),
                },
                SavedRegion {
                    addr: 0x1000_4000_0000,
                    vlen: 4096,
                    name: "mana.app_state".into(),
                    payload: SavedPayload::Full(Payload::Real(vec![1, 2, 3, 4, 5])),
                },
                SavedRegion {
                    addr: 0x1000_8000_0000,
                    vlen: 1 << 20,
                    name: "mana.bss".into(),
                    payload: SavedPayload::Full(Payload::Zero),
                },
            ],
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let img = sample_image();
        let bytes = img.encode();
        let back = CkptImage::decode(&bytes).unwrap();
        assert_eq!(img, back);
    }

    #[test]
    fn virtual_bytes_sums_regions() {
        let img = sample_image();
        assert_eq!(img.virtual_bytes(), (1 << 30) + 4096 + (1 << 20));
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = sample_image().encode();
        bytes[0] = b'X';
        assert_eq!(CkptImage::decode(&bytes), Err(ImageError::BadMagic));
    }

    #[test]
    fn bitflip_detected_by_crc() {
        let img = sample_image();
        let clean = img.encode();
        // Flip every byte position one at a time in the payload area and
        // expect a CRC failure (never a silent wrong decode).
        for pos in [20usize, 60, 100, clean.len() - 10] {
            let mut corrupt = clean.clone();
            corrupt[pos] ^= 0x40;
            match CkptImage::decode(&corrupt) {
                Err(_) => {}
                Ok(decoded) => {
                    assert_eq!(decoded, img, "silent corruption at byte {pos}")
                }
            }
        }
        // And a targeted flip inside the Real payload must be caught.
        let marker = clean
            .windows(5)
            .position(|w| w == [1, 2, 3, 4, 5])
            .expect("payload present");
        let mut corrupt = clean.clone();
        corrupt[marker] = 9;
        assert!(matches!(
            CkptImage::decode(&corrupt),
            Err(ImageError::CrcMismatch { .. })
        ));
    }

    #[test]
    fn corrupted_count_fields_do_not_abort() {
        // Regression: after the single-pass-CRC change, counts are parsed
        // before any CRC validates them; a bit-flipped count must produce
        // a clean error, never a capacity-overflow abort.
        let clean = sample_image().encode();
        // n_fds lives right after the (empty) parent string.
        for offset in 0..clean.len() {
            let mut bad = clean.clone();
            bad[offset] ^= 0xff;
            let _ = CkptImage::decode(&bad); // must not panic/abort
        }
    }

    #[test]
    fn truncated_image_rejected() {
        let bytes = sample_image().encode();
        for cut in [4usize, 16, bytes.len() / 2, bytes.len() - 1] {
            assert!(CkptImage::decode(&bytes[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn capture_takes_only_upper_half() {
        let mut aspace = AddressSpace::new(OsVersion::Cle6, AllocPolicy::NoReplace);
        aspace
            .alloc(4096, Half::Upper, "state", Payload::Real(vec![9]))
            .unwrap();
        aspace
            .alloc(1 << 20, Half::Lower, "mpi_pool", Payload::Zero)
            .unwrap();
        let img = CkptImage::capture(RankId(0), 7, [0; 32], vec![], &aspace.table);
        assert_eq!(img.regions.len(), 1);
        assert_eq!(img.regions[0].name, "mana.state");
    }

    #[test]
    fn image_path_stable() {
        assert_eq!(image_path("job42", RankId(9)), "job42/ckpt_rank00009.mana");
        assert_eq!(
            gen_image_path("job42", 7, RankId(9)),
            "job42/gen0007/ckpt_rank00009.mana"
        );
        assert_eq!(
            gen_incr_image_path("job42", 7, RankId(9)),
            "job42/gen0007/ckpt_rank00009.inc.mana"
        );
    }

    // ------------------------------------------------ incremental images

    fn table_with_dirty_state() -> RegionTable {
        let mut t = RegionTable::new();
        t.insert(MemRegion::new(
            0x1000,
            1 << 30,
            Half::Upper,
            "heap",
            Payload::Pattern(9),
        ))
        .unwrap();
        t.insert(MemRegion::new(
            0x5000_0000_0000,
            64,
            Half::Upper,
            "state",
            Payload::Real(vec![1; 64]),
        ))
        .unwrap();
        t
    }

    #[test]
    fn incremental_capture_references_clean_regions() {
        let mut table = table_with_dirty_state();
        // Full checkpoint happened: everything clean.
        table.clear_dirty(Half::Upper);
        // Then only the small state region changed.
        let r = table.get_mut("state").unwrap();
        r.payload = Payload::Real(vec![2; 64]);
        r.dirty = true;

        let inc = CkptImage::capture_incremental(
            RankId(0),
            10,
            [0; 32],
            vec![],
            &table,
            "job/full.mana",
        );
        assert_eq!(inc.parent.as_deref(), Some("job/full.mana"));
        // Only the 64-byte state is materialized; the 1 GiB heap is a ref.
        assert_eq!(inc.write_bytes(), 64);
        assert_eq!(inc.virtual_bytes(), (1 << 30) + 64);
        assert!(matches!(
            inc.regions.iter().find(|r| r.name == "heap").unwrap().payload,
            SavedPayload::ParentRef { .. }
        ));
    }

    #[test]
    fn incremental_roundtrip_and_resolve() {
        let mut table = table_with_dirty_state();
        let full = CkptImage::capture(RankId(0), 5, [0; 32], vec![], &table);
        table.clear_dirty(Half::Upper);
        let r = table.get_mut("state").unwrap();
        r.payload = Payload::Real(vec![3; 64]);
        r.dirty = true;
        let inc =
            CkptImage::capture_incremental(RankId(0), 9, [0; 32], vec![], &table, "p");
        // Bytes round-trip (including ParentRef sections + parent path).
        let decoded = CkptImage::decode(&inc.encode()).unwrap();
        assert_eq!(decoded, inc);

        let resolved = resolve_incremental(decoded, full).unwrap();
        assert!(resolved.parent.is_none());
        let heap = resolved.regions.iter().find(|r| r.name == "heap").unwrap();
        assert_eq!(heap.payload, SavedPayload::Full(Payload::Pattern(9)));
        let state = resolved.regions.iter().find(|r| r.name == "state").unwrap();
        assert_eq!(state.payload, SavedPayload::Full(Payload::Real(vec![3; 64])));
    }

    #[test]
    fn resolve_detects_parent_drift() {
        let mut table = table_with_dirty_state();
        let mut full = CkptImage::capture(RankId(0), 5, [0; 32], vec![], &table);
        table.clear_dirty(Half::Upper);
        let inc =
            CkptImage::capture_incremental(RankId(0), 9, [0; 32], vec![], &table, "p");
        // Parent heap content changes out from under the incremental.
        full.regions
            .iter_mut()
            .find(|r| r.name == "heap")
            .unwrap()
            .payload = SavedPayload::Full(Payload::Pattern(1234));
        let err = resolve_incremental(inc, full).unwrap_err();
        assert!(err.to_string().contains("drifted"), "{err}");
    }

    #[test]
    fn resolve_detects_unmaterialized_parent() {
        let mut table = table_with_dirty_state();
        let full = CkptImage::capture(RankId(0), 5, [0; 32], vec![], &table);
        table.clear_dirty(Half::Upper);
        let inc =
            CkptImage::capture_incremental(RankId(0), 9, [0; 32], vec![], &table, "p");
        // A parent whose heap is itself an unresolved reference (e.g. an
        // incremental wrongly used as a parent) must be rejected.
        let mut bad_parent = full.clone();
        bad_parent
            .regions
            .iter_mut()
            .find(|r| r.name == "heap")
            .unwrap()
            .payload = SavedPayload::ParentRef { fingerprint: 1 };
        let err = resolve_incremental(inc, bad_parent).unwrap_err();
        assert!(err.to_string().contains("not materialized"), "{err}");
    }

    #[test]
    fn multi_chunk_real_payload_roundtrips() {
        let data: Vec<u8> = (0..chunk::DEFAULT_CHUNK_BYTES * 2 + 123)
            .map(|i| (i * 31 % 251) as u8)
            .collect();
        let img = CkptImage {
            rank: RankId(1),
            step: 7,
            rng_state: [9u8; 32],
            parent: None,
            upper_fds: vec![],
            regions: vec![SavedRegion {
                addr: 0x2000_0000_0000,
                vlen: data.len() as u64,
                name: "mana.big".into(),
                payload: SavedPayload::Full(Payload::Real(data)),
            }],
        };
        let bytes = img.encode();
        assert_eq!(
            bytes.len(),
            img.encoded_size(chunk::DEFAULT_CHUNK_BYTES),
            "size precomputation exact"
        );
        assert_eq!(CkptImage::decode(&bytes).unwrap(), img);
        // A flip deep inside the second chunk is caught by its chunk CRC.
        let mut corrupt = bytes.clone();
        let p = bytes.len() - chunk::DEFAULT_CHUNK_BYTES / 2;
        corrupt[p] ^= 1;
        assert!(matches!(
            CkptImage::decode(&corrupt),
            Err(ImageError::CrcMismatch { .. })
        ));
    }

    #[test]
    fn configurable_chunk_size_roundtrips() {
        // A non-default granularity must decode with the same reader
        // (frames are self-describing) and keep the size math exact.
        let img = sample_image();
        for cb in [4096usize, 64 << 10, chunk::DEFAULT_CHUNK_BYTES] {
            let mut bytes = Vec::new();
            img.encode_into_sized(&mut bytes, cb);
            assert_eq!(bytes.len(), img.encoded_size(cb), "cb={cb}");
            assert_eq!(CkptImage::decode(&bytes).unwrap(), img, "cb={cb}");
        }
    }

    // ------------------------------------------------------------ recipes

    #[test]
    fn recipe_tiles_the_encoded_image() {
        let img = sample_image();
        let mut bytes = Vec::new();
        let recipe = img.encode_with_recipe(&mut bytes, 4096);
        assert!(recipe.covers(bytes.len() as u64));
        assert_eq!(
            recipe.chunks.iter().map(|c| c.vbytes).sum::<u64>(),
            img.write_bytes()
        );
        // Reassembly from real spans is byte-identical.
        let mut rebuilt = Vec::new();
        for c in &recipe.chunks {
            rebuilt.extend_from_slice(
                &bytes[c.real_off as usize..(c.real_off + c.real_len) as usize],
            );
        }
        assert_eq!(rebuilt, bytes);
        assert_eq!(CkptImage::decode(&rebuilt).unwrap(), img);
    }

    #[test]
    fn unchanged_regions_dedup_across_generations() {
        // Two generations of the same image content, differing only in
        // step/rng (the mostly-clean address space case): every region
        // chunk digest must match; only the header/trailer metadata chunks
        // (zero virtual bytes) may differ.
        let mut gen0 = sample_image();
        let mut gen1 = sample_image();
        gen0.step = 100;
        gen1.step = 200;
        gen1.rng_state = [8u8; 32];
        let (mut b0, mut b1) = (Vec::new(), Vec::new());
        let r0 = gen0.encode_with_recipe(&mut b0, 4096);
        let r1 = gen1.encode_with_recipe(&mut b1, 4096);
        assert_eq!(r0.chunks.len(), r1.chunks.len());
        let mut shared_vb = 0u64;
        for (a, b) in r0.chunks.iter().zip(&r1.chunks) {
            if a.digest == b.digest {
                shared_vb += a.vbytes;
            } else {
                assert_eq!(a.vbytes, 0, "only metadata chunks may change");
            }
        }
        assert_eq!(
            shared_vb,
            gen0.write_bytes(),
            "every virtual byte dedups when regions are unchanged"
        );
    }

    #[test]
    fn dirty_region_changes_only_its_chunks() {
        let gen0 = sample_image();
        let mut gen1 = sample_image();
        // Dirty the small Real region's content.
        gen1.regions[1].payload = SavedPayload::Full(Payload::Real(vec![9, 9, 9, 9, 9]));
        let (mut b0, mut b1) = (Vec::new(), Vec::new());
        let r0 = gen0.encode_with_recipe(&mut b0, 4096);
        let r1 = gen1.encode_with_recipe(&mut b1, 4096);
        let changed_vb: u64 = r0
            .chunks
            .iter()
            .zip(&r1.chunks)
            .filter(|(a, b)| a.digest != b.digest)
            .map(|(a, _)| a.vbytes)
            .sum();
        // Only the 4096-vbyte state region re-ships; the 1 GiB pattern
        // heap and the zero bss dedup.
        assert_eq!(changed_vb, 4096);
    }

    #[test]
    fn pattern_chunks_dedup_by_position_not_globally() {
        // Two pattern heaps with the same seed share chunks; different
        // positions within one heap do not alias each other.
        let img = sample_image();
        let mut bytes = Vec::new();
        let rec = img.encode_with_recipe(&mut bytes, 4096);
        let heap_chunks: Vec<_> = rec
            .chunks
            .iter()
            .filter(|c| c.vbytes == 4096 && c.real_len == 0)
            .take(16)
            .collect();
        assert!(heap_chunks.len() >= 2, "heap must span many chunks");
        assert_ne!(
            heap_chunks[0].digest, heap_chunks[1].digest,
            "pattern chunks at different offsets must differ"
        );
    }

    #[test]
    fn incremental_recipe_has_zero_vbytes_for_parent_refs() {
        let mut table = table_with_dirty_state();
        table.clear_dirty(Half::Upper);
        let inc =
            CkptImage::capture_incremental(RankId(0), 9, [0; 32], vec![], &table, "p");
        let mut bytes = Vec::new();
        let rec = inc.encode_with_recipe(&mut bytes, 4096);
        assert_eq!(rec.file_vbytes, inc.write_bytes());
        assert!(rec.covers(bytes.len() as u64));
    }

    #[test]
    fn resolve_detects_missing_parent_region() {
        let mut table = table_with_dirty_state();
        let mut full = CkptImage::capture(RankId(0), 5, [0; 32], vec![], &table);
        table.clear_dirty(Half::Upper);
        let inc =
            CkptImage::capture_incremental(RankId(0), 9, [0; 32], vec![], &table, "p");
        full.regions.retain(|r| r.name != "heap");
        assert!(resolve_incremental(inc, full).is_err());
    }

    #[test]
    fn resolve_moves_payloads_without_duplication() {
        // ParentRef-heavy incremental: the big clean region rides as a
        // reference. Resolving must *move* buffers (parent payloads lift
        // out of the parent, dirty payloads stay in place) — asserted by
        // heap-pointer identity, which a clone-based resolve cannot keep.
        let mut t = RegionTable::new();
        t.insert(MemRegion::new(
            0x1000,
            1 << 20,
            Half::Upper,
            "big",
            Payload::Real(vec![5u8; 1 << 20]),
        ))
        .unwrap();
        t.insert(MemRegion::new(
            0x9000_0000,
            64,
            Half::Upper,
            "state",
            Payload::Real(vec![1; 64]),
        ))
        .unwrap();
        let full = CkptImage::capture(RankId(0), 5, [0; 32], vec![], &t);
        t.clear_dirty(Half::Upper);
        let r = t.get_mut("state").unwrap();
        r.payload = Payload::Real(vec![2; 64]);
        r.dirty = true;
        let inc =
            CkptImage::capture_incremental(RankId(0), 9, [0; 32], vec![], &t, "p");

        let payload_ptr = |img: &CkptImage, name: &str| match &img
            .regions
            .iter()
            .find(|r| r.name == name)
            .unwrap()
            .payload
        {
            SavedPayload::Full(Payload::Real(v)) => v.as_ptr(),
            other => panic!("{name}: expected materialized Real payload, got {other:?}"),
        };
        let big_ptr = payload_ptr(&full, "big");
        let state_ptr = payload_ptr(&inc, "state");

        let resolved = resolve_incremental(inc, full).unwrap();
        assert_eq!(
            payload_ptr(&resolved, "big"),
            big_ptr,
            "referenced parent payload must move, not copy"
        );
        assert_eq!(
            payload_ptr(&resolved, "state"),
            state_ptr,
            "the incremental's own dirty payload must stay in place"
        );
    }

    // ------------------------------------------ content-defined chunking

    fn noisy(seed: u64, len: usize) -> Vec<u8> {
        crate::util::prng::test_bytes(seed, len)
    }

    fn image_with_state(data: Vec<u8>) -> CkptImage {
        CkptImage {
            rank: RankId(2),
            step: 9,
            rng_state: [4u8; 32],
            parent: None,
            upper_fds: vec![(3, "traj.xtc".into())],
            regions: vec![
                SavedRegion {
                    addr: 0x1000_0000_0000,
                    vlen: data.len() as u64,
                    name: "mana.state".into(),
                    payload: SavedPayload::Full(Payload::Real(data)),
                },
                SavedRegion {
                    addr: 0x2000_0000_0000,
                    vlen: 1 << 20,
                    name: "mana.heap".into(),
                    payload: SavedPayload::Full(Payload::Pattern(77)),
                },
            ],
        }
    }

    #[test]
    fn cdc_image_roundtrips_and_recipe_covers() {
        // CDC-framed images decode with the unchanged reader (frames are
        // self-describing), and the recipe tiles the encoded bytes.
        let img = image_with_state(noisy(1, 48 << 10));
        let chunking = Chunking::cdc(4096);
        let mut bytes = Vec::new();
        let rec = img.encode_with_recipe_chunked(&mut bytes, chunking);
        assert_eq!(CkptImage::decode(&bytes).unwrap(), img);
        assert!(rec.covers(bytes.len() as u64));
        assert_eq!(
            rec.chunks.iter().map(|c| c.vbytes).sum::<u64>(),
            img.write_bytes()
        );
        assert_eq!(rec.chunk_bytes, 4096);
        // Reassembly from real spans is byte-identical.
        let mut rebuilt = Vec::new();
        for c in &rec.chunks {
            rebuilt.extend_from_slice(
                &bytes[c.real_off as usize..(c.real_off + c.real_len) as usize],
            );
        }
        assert_eq!(rebuilt, bytes);
    }

    #[test]
    fn cdc_recipe_reuses_digests_across_a_region_insertion() {
        // The tentpole claim at the image level: grow a Real region by a
        // mid-region insertion; under CDC the recipe re-uses the digests
        // of everything outside the edit window, while fixed tiling loses
        // every downstream chunk.
        let base = noisy(2, 96 << 10);
        let ins_at = 16 << 10;
        // Deliberately NOT a multiple of the chunk size: a stride-aligned
        // insertion would let the fixed grid re-align by accident.
        let mut edited = base[..ins_at].to_vec();
        edited.extend_from_slice(&noisy(3, 3333));
        edited.extend_from_slice(&base[ins_at..]);
        let shared_fraction = |chunking: Chunking| {
            let g0 = image_with_state(base.clone());
            let g1 = image_with_state(edited.clone());
            let (mut b0, mut b1) = (Vec::new(), Vec::new());
            let r0 = g0.encode_with_recipe_chunked(&mut b0, chunking);
            let r1 = g1.encode_with_recipe_chunked(&mut b1, chunking);
            let old: std::collections::BTreeSet<u128> =
                r0.chunks.iter().map(|c| c.digest).collect();
            let shared: u64 = r1
                .chunks
                .iter()
                .filter(|c| old.contains(&c.digest))
                .map(|c| c.vbytes)
                .sum();
            shared as f64 / r1.file_vbytes as f64
        };
        let cdc = shared_fraction(Chunking::cdc(2048));
        let fixed = shared_fraction(Chunking::Fixed(2048));
        assert!(
            cdc >= 0.7,
            "CDC must re-use >= 70% of virtual bytes after an insertion (got {cdc:.2})"
        );
        assert!(
            fixed < cdc,
            "fixed tiling ({fixed:.2}) must lose more than CDC ({cdc:.2})"
        );
    }

    #[test]
    fn fixed_mode_recipe_is_unchanged_by_the_strategy_plumbing() {
        // encode_with_recipe (fixed) and the strategy-generalized call
        // with Chunking::Fixed must be bit-identical in bytes and recipe —
        // the fixed-mode compatibility guarantee.
        let img = sample_image();
        let (mut a, mut b) = (Vec::new(), Vec::new());
        let ra = img.encode_with_recipe(&mut a, 4096);
        let rb = img.encode_with_recipe_chunked(&mut b, Chunking::Fixed(4096));
        assert_eq!(a, b);
        assert_eq!(ra, rb);
    }

    #[test]
    fn pattern_and_zero_domains_are_chunking_mode_independent() {
        // Pattern/Zero/meta chunks keep their avg-grid domains: an image
        // with no Real payload must produce the *identical* recipe under
        // fixed and CDC — only Real payload bytes get content boundaries.
        let img = CkptImage {
            rank: RankId(1),
            step: 4,
            rng_state: [6u8; 32],
            parent: None,
            upper_fds: vec![],
            regions: vec![
                SavedRegion {
                    addr: 0x1000_0000_0000,
                    vlen: 1 << 20,
                    name: "mana.heap".into(),
                    payload: SavedPayload::Full(Payload::Pattern(99)),
                },
                SavedRegion {
                    addr: 0x2000_0000_0000,
                    vlen: (1 << 18) + 100,
                    name: "mana.bss".into(),
                    payload: SavedPayload::Full(Payload::Zero),
                },
            ],
        };
        let (mut a, mut b) = (Vec::new(), Vec::new());
        let rf = img.encode_with_recipe(&mut a, 4096);
        let rc = img.encode_with_recipe_chunked(&mut b, Chunking::cdc(4096));
        assert_eq!(a, b, "pattern/zero encodings are chunking-independent");
        assert_eq!(rf, rc, "pattern/zero recipes are chunking-independent");
    }
}
