//! FIG2 — Gromacs/ADH checkpoint time on Burst Buffers vs CSCRATCH
//! (paper Fig. 2).
//!
//! Regenerates the figure's series for 4→64 ranks x 8 threads: aggregate
//! memory, BB checkpoint time, Lustre checkpoint time, plus restart times.
//! The paper's qualitative claims are asserted: BB superior everywhere,
//! BB near-flat while Lustre grows with scale.

use mana::benchkit::{fsecs, Report};
use mana::config::{AppKind, RunConfig};
use mana::fs::FsKind;
use mana::sim::JobSim;
use mana::util::bytes::human;

struct Point {
    agg: u64,
    ckpt: f64,
    restart: f64,
}

fn measure(ranks: u32, fs: FsKind) -> Point {
    let mut cfg = RunConfig::new(AppKind::Gromacs, ranks);
    cfg.job = format!("fig2-{ranks}-{fs:?}");
    cfg.fs = fs;
    let mut sim = JobSim::launch(cfg, None).expect("launch");
    sim.run_steps(3).expect("steps");
    let agg = sim.aggregate_memory();
    let rep = sim.checkpoint().expect("ckpt");
    let cfg = sim.cfg.clone();
    let fsim = sim.kill();
    let (_, rrep) = JobSim::restart_from(cfg, None, fsim).expect("restart");
    Point {
        agg,
        ckpt: rep.write_secs,
        restart: rrep.read_secs,
    }
}

fn main() {
    let mut rep = Report::new(
        "FIG2: Gromacs(ADH) C/R time, 4-64 ranks x 8 threads",
        vec![
            "ranks",
            "nodes",
            "agg_memory",
            "bb_ckpt_s",
            "lustre_ckpt_s",
            "ckpt_speedup",
            "bb_restart_s",
            "lustre_restart_s",
        ],
    );
    let mut bb_ckpts = Vec::new();
    let mut lu_ckpts = Vec::new();
    for &ranks in &[4u32, 8, 16, 32, 64] {
        let bb = measure(ranks, FsKind::BurstBuffer);
        let lu = measure(ranks, FsKind::Lustre);
        bb_ckpts.push(bb.ckpt);
        lu_ckpts.push(lu.ckpt);
        rep.row(vec![
            ranks.to_string(),
            ranks.div_ceil(8).to_string(),
            human(bb.agg),
            fsecs(bb.ckpt),
            fsecs(lu.ckpt),
            format!("{:.1}x", lu.ckpt / bb.ckpt),
            fsecs(bb.restart),
            fsecs(lu.restart),
        ]);
    }
    rep.finish();

    // Paper: "performance on the Burst Buffers is superior to that on the
    // CSCRATCH and also scales better."
    assert!(bb_ckpts.iter().zip(&lu_ckpts).all(|(b, l)| b < l));
    let bb_spread = bb_ckpts.iter().cloned().fold(0.0, f64::max)
        / bb_ckpts.iter().cloned().fold(f64::MAX, f64::min);
    let lu_growth = lu_ckpts.last().unwrap() / lu_ckpts.first().unwrap();
    println!("\nBB spread (max/min) = {bb_spread:.2}; Lustre growth (64r/4r) = {lu_growth:.2}");
    assert!(bb_spread < 3.0, "BB should be near-flat");
    assert!(lu_growth > 1.2, "Lustre should grow with scale");
    println!("FIG2 OK");
}
