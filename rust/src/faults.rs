//! Fault-injection plans.
//!
//! Every production failure the paper reports is injectable, so the
//! reliability benches can show: *fault + fix off → deterministic failure;
//! fault + fix on → success*. Faults are declarative — the subsystems read
//! their knobs from the plan at construction time.

use crate::coordinator::Phase;
use crate::topology::NodeId;

/// What to break during a run.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// Control-plane packet loss probability (congestion).
    pub ctrl_loss_prob: f64,
    /// Control-plane idle-disconnect probability.
    pub ctrl_disconnect_prob: f64,
    /// Kill a sub-coordinator mid-phase (tree coordination plane):
    /// `(sub-coordinator index, phase it dies in)`. One-shot — consumed
    /// when the phase reaches the victim; its subtree is re-parented and
    /// the phase retried.
    pub subcoord_death: Option<(u32, Phase)>,
    /// GNI quiescence windows (start, end) in virtual seconds.
    pub gni_quiescence: Vec<(f64, f64)>,
    /// Flip one byte of one rank's stored checkpoint image
    /// (rank, byte offset) — the torn/corrupt image case.
    pub image_bitflip: Option<(u32, usize)>,
    /// Override the file system capacity (bytes) to force the
    /// insufficient-space path.
    pub fs_capacity_override: Option<u64>,
    /// Interrupt the coordinator's status-table update mid-flight
    /// (the missing-locks race).
    pub interrupt_status_update: bool,
    /// MPI library maps extra eager pools during the run (the lower-half
    /// growth that corrupts memory under the legacy allocator). Count of
    /// growth events.
    pub lower_half_growth_events: u32,
    /// Lose a node's *entire* fast tier (Burst Buffer blade failure) at a
    /// virtual time: `(node, at_secs)`. Applied declaratively by
    /// `TieredStore` on its sim clock, so a loss can land mid-drain and
    /// exercise partially-drained generations; losses scheduled at or
    /// before a restart fire before the rebuild pass.
    pub bb_node_loss: Vec<(NodeId, f64)>,
    /// Lose a whole redundancy set's fast tiers at a virtual time:
    /// `(set index, at_secs)`. The deterministic unrecoverable case.
    pub bb_set_loss: Vec<(u32, f64)>,
}

impl FaultPlan {
    pub fn none() -> Self {
        Self::default()
    }

    /// The paper's production-congestion scenario.
    pub fn congested_network() -> Self {
        FaultPlan {
            ctrl_loss_prob: 0.15,
            ctrl_disconnect_prob: 0.05,
            ..Self::default()
        }
    }

    /// Cray GNI reconfiguration during the checkpoint window.
    pub fn gni_reconfig(at: f64, dur: f64) -> Self {
        FaultPlan {
            gni_quiescence: vec![(at, at + dur)],
            ..Self::default()
        }
    }

    pub fn any_active(&self) -> bool {
        self.ctrl_loss_prob > 0.0
            || self.ctrl_disconnect_prob > 0.0
            || self.subcoord_death.is_some()
            || !self.gni_quiescence.is_empty()
            || self.image_bitflip.is_some()
            || self.fs_capacity_override.is_some()
            || self.interrupt_status_update
            || self.lower_half_growth_events > 0
            || !self.bb_node_loss.is_empty()
            || !self.bb_set_loss.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_clean() {
        assert!(!FaultPlan::none().any_active());
    }

    #[test]
    fn node_loss_marks_plan_active() {
        let p = FaultPlan {
            bb_node_loss: vec![(NodeId(3), 0.0)],
            ..FaultPlan::none()
        };
        assert!(p.any_active());
        let s = FaultPlan {
            bb_set_loss: vec![(0, 12.5)],
            ..FaultPlan::none()
        };
        assert!(s.any_active());
    }

    #[test]
    fn presets_are_active() {
        assert!(FaultPlan::congested_network().any_active());
        let g = FaultPlan::gni_reconfig(10.0, 2.0);
        assert_eq!(g.gni_quiescence, vec![(10.0, 12.0)]);
        assert!(g.any_active());
    }
}
