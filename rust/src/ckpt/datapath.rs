//! Rank-parallel checkpoint data path with memoized region digests.
//!
//! The WRITE phase used to serialize every rank's capture→encode→recipe
//! pipeline on one host thread — the simulator's own wall-clock bottleneck
//! past ~512 ranks, even though per-rank capture is embarrassingly
//! parallel (each rank owns its region table). This module fans the
//! pipeline across `std::thread::scope` workers:
//!
//! * **Capture by reference** — a [`RankSource`] borrows the rank's live
//!   [`RegionTable`]; the encoder streams payload bytes straight from the
//!   table into the write buffer (no per-region payload clones, which is
//!   what the old `CkptImage::capture` path paid).
//! * **Rank-parallel encode** — ranks are split into contiguous chunks,
//!   one worker per chunk, and the resulting [`WriteReq`]s concatenate
//!   back in rank order: the wave handed to the storage engine is
//!   byte-for-byte the serial wave (`benches/ckpt_datapath.rs` and the
//!   `prop_parallel_datapath_*` property pin this).
//! * **Digest memoization** — each region's encoded section (bytes,
//!   section CRC, recipe chunk digests) is cached in the table
//!   ([`RegionDigestCache`]), keyed by the dirty bit: a generation that
//!   dirties 10% of its regions re-hashes ~10% of the bytes and splices
//!   the rest. Invalidation lives in `mem`: any `get_mut` access and any
//!   dirty-bit transition drop the entry.
//!
//! Worker count comes from `RunConfig::encode_threads`
//! (`--encode-threads`), defaulting to the host's available parallelism.

use std::time::Instant;

use crate::ckpt::chunk::{Chunking, RecipeChunk};
use crate::ckpt::{encode_stream, ChunkRecipe, ImageMeta, PayloadSrc, RegionSrc, SavedRegion};
use crate::fs::WriteReq;
use crate::mem::{Half, RegionTable};
use crate::topology::{NodeId, RankId};

/// Memoized encode of one region: the exact section bytes, the section
/// CRC, and the recipe chunks with real offsets relative to the section
/// start. Validity is keyed by the table's dirty bits — any mutable access
/// to the region or dirty-bit transition drops the entry (see
/// `RegionTable::get_mut` / `RegionTable::clear_dirty`).
#[derive(Clone, Debug)]
pub struct RegionDigestCache {
    /// Chunking strategy (mode + granularity/CDC cut parameters) the entry
    /// was built with. Part of the validity key: an entry built under one
    /// strategy must never splice into an encode using another — the cut
    /// points, and therefore the cached chunk digests, would not match.
    pub chunking: Chunking,
    /// Region virtual length at populate time.
    pub vlen: u64,
    /// Encoded payload-kind tag at populate time.
    pub kind: u8,
    /// Resident payload bytes at populate time.
    pub resident: u64,
    /// Section CRC (folded into the whole-image trailer on a hit).
    pub section_crc: u32,
    /// The full encoded section record (metadata + framed payload +
    /// section CRC) — spliced verbatim on a hit.
    pub encoded: Vec<u8>,
    /// Recipe chunks, real offsets relative to the section start. Empty
    /// when populated by a recipe-less encode; a recipe encode then
    /// treats the entry as a miss.
    pub rel_chunks: Vec<RecipeChunk>,
    /// Payload cut lengths (chunk framing boundaries) at populate time.
    /// Empty for non-Real payloads. The partial re-encode path keys chunk
    /// reuse on these.
    pub payload_cuts: Vec<u32>,
    /// Per-chunk CRC32s matching `payload_cuts` — reused verbatim for
    /// chunks no stale range touches.
    pub chunk_crcs: Vec<u32>,
    /// Coalesced, sorted `[off, off+len)` payload spans mutated since
    /// populate time (recorded by `RegionTable::write_range`). Empty means
    /// the entry describes the live bytes exactly; non-empty downgrades
    /// the entry to chunk granularity: only chunks overlapping a stale
    /// span are re-hashed.
    pub stale_ranges: Vec<(u64, u64)>,
}

impl RegionDigestCache {
    /// Does this entry still describe region `r` under `chunking`?
    /// (Content equality is what the dirty-bit keying guarantees; this
    /// only rules out structural drift. Keying on the full strategy means
    /// clean regions keep splicing their memoized CDC cut points without
    /// re-running the boundary scan.)
    pub(crate) fn matches(&self, r: &RegionSrc<'_>, chunking: Chunking) -> bool {
        self.chunking == chunking
            && self.vlen == r.vlen
            && self.kind == r.payload.kind()
            && self.resident == r.payload.resident()
    }

    /// Record that payload bytes `[off, off+len)` were overwritten in
    /// place: the entry stays alive at chunk granularity instead of being
    /// discarded wholesale. Ranges are kept sorted and coalesced (touching
    /// ranges merge) so the partial re-encode walks them in one pass.
    pub fn note_stale(&mut self, off: u64, len: u64) {
        if len == 0 {
            return;
        }
        let (mut lo, mut hi) = (off, off + len);
        let mut merged = Vec::with_capacity(self.stale_ranges.len() + 1);
        for &(a, b) in &self.stale_ranges {
            if b < lo || a > hi {
                merged.push((a, b));
            } else {
                lo = lo.min(a);
                hi = hi.max(b);
            }
        }
        merged.push((lo, hi));
        merged.sort_unstable();
        self.stale_ranges = merged;
    }
}

/// One region's memoization slot, harvested from the table for the
/// duration of an encode (`RegionTable::take_cache_slots`) and put back
/// afterwards (`RegionTable::put_cache_slots`).
#[derive(Debug, Default)]
pub struct CacheSlot {
    /// The entry may be consulted: the region was clean at harvest time.
    pub usable: bool,
    pub entry: Option<Box<RegionDigestCache>>,
}

/// Digest-cache counters of one encode.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    /// Virtual bytes whose CRC/digest work was served from cache.
    pub hit_vbytes: u64,
    pub hit_regions: u64,
    /// Regions hashed fresh with their slots (re)populated.
    pub filled_regions: u64,
    /// Virtual bytes actually run through the CRC/digest hash this encode
    /// (misses charge the whole region; partial hits charge only the
    /// chunks a stale range touched). The warm-generation bench gates on
    /// this scaling with dirty *chunks*, not dirty regions.
    pub fresh_hash_vbytes: u64,
    /// Regions served at chunk granularity: clean chunks spliced from the
    /// entry, stale chunks re-hashed.
    pub partial_regions: u64,
}

/// Everything the encoder needs from one rank's live process state. The
/// table is borrowed mutably only to harvest and re-plant cache slots;
/// payload bytes stream out of it by reference.
pub struct RankSource<'a> {
    pub table: &'a mut RegionTable,
    pub step: u64,
    pub rng_state: [u8; 32],
    pub upper_fds: Vec<(u32, String)>,
}

/// Per-rank job description: where the image goes and what rides along.
pub struct RankJob {
    pub rank: RankId,
    pub node: NodeId,
    /// Destination path of this rank's image.
    pub path: String,
    /// Parent full-image path — `Some` captures an incremental image
    /// (clean regions become fingerprinted parent references).
    pub parent: Option<String>,
    /// Owned pseudo-regions appended after the table's upper half (the
    /// wrapper drain buffer, rank 0's communicator log). Never memoized:
    /// they change every generation.
    pub extra_regions: Vec<SavedRegion>,
}

/// Encode-wave knobs.
#[derive(Clone, Copy, Debug)]
pub struct EncodeOpts {
    /// Chunking strategy (`RunConfig::chunking_strategy()`): fixed stride
    /// or content-defined boundaries, with their size parameters.
    pub chunking: Chunking,
    /// Worker threads to fan ranks across (1 = the serial path).
    pub threads: usize,
    /// Emit the content-addressed [`ChunkRecipe`] per image (staged mode).
    pub with_recipe: bool,
}

/// Host-side accounting of one encode wave.
#[derive(Clone, Copy, Debug, Default)]
pub struct DatapathStats {
    /// Wall-clock seconds of the whole wave (capture + encode + recipes).
    pub host_secs: f64,
    /// Worker threads actually used.
    pub threads: usize,
    /// Virtual bytes whose hash/CRC work was served from the digest
    /// cache — "didn't re-hash", as opposed to the drain's deduped_bytes
    /// "didn't re-ship".
    pub cache_hit_bytes: u64,
    pub cache_hit_regions: u64,
    pub cache_filled_regions: u64,
    /// Regions encoded at chunk granularity (partial digest-cache hits).
    pub cache_partial_regions: u64,
    /// Virtual bytes hashed fresh across all ranks (see
    /// [`CacheStats::fresh_hash_vbytes`]).
    pub fresh_hash_bytes: u64,
    /// Encoded bytes produced across all ranks.
    pub encoded_bytes: u64,
}

/// Resolve the configured worker count: explicit setting, else the host's
/// available parallelism, never below 1.
pub fn resolve_threads(requested: Option<usize>) -> usize {
    requested
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
        .max(1)
}

/// Capture and encode one rank's image from its live table. This is the
/// per-rank unit of work the wave fans out; it must stay deterministic in
/// the rank's own state only (no cross-rank reads) so any worker layout
/// produces identical bytes.
fn encode_rank(
    src: &mut RankSource<'_>,
    job: &RankJob,
    opts: &EncodeOpts,
) -> (WriteReq, CacheStats) {
    let incremental = job.parent.is_some();
    let mut slots = src.table.take_cache_slots(Half::Upper);
    let table: &RegionTable = &*src.table;
    let mut srcs: Vec<RegionSrc<'_>> = table
        .half_iter(Half::Upper)
        .map(|r| RegionSrc {
            addr: r.addr,
            vlen: r.len,
            name: &r.name,
            payload: if incremental && !r.dirty {
                PayloadSrc::ParentRef {
                    fingerprint: r.payload.fingerprint(r.len),
                }
            } else {
                PayloadSrc::of(&r.payload)
            },
        })
        .collect();
    srcs.extend(job.extra_regions.iter().map(SavedRegion::as_src));
    let meta = ImageMeta {
        rank: job.rank,
        step: src.step,
        rng_state: &src.rng_state,
        parent: job.parent.as_deref(),
        upper_fds: &src.upper_fds,
    };
    // Bytes this image carries to storage (ParentRefs are free) — the
    // virtual size the storage model charges.
    let write_bytes: u64 = srcs
        .iter()
        .filter(|r| !matches!(r.payload, PayloadSrc::ParentRef { .. }))
        .map(|r| r.vlen)
        .sum();
    let mut data = Vec::new();
    let mut stats = CacheStats::default();
    let recipe = if opts.with_recipe {
        let mut rec = ChunkRecipe {
            chunk_bytes: opts.chunking.avg_bytes() as u64,
            file_vbytes: write_bytes,
            chunks: Vec::new(),
        };
        encode_stream(
            &mut data,
            &meta,
            &srcs,
            opts.chunking,
            Some(&mut rec),
            &mut slots,
            &mut stats,
        );
        debug_assert!(
            rec.covers(data.len() as u64),
            "recipe real spans must tile the encoded image"
        );
        debug_assert_eq!(
            rec.chunks.iter().map(|c| c.vbytes).sum::<u64>(),
            write_bytes,
            "recipe virtual bytes must sum to write_bytes"
        );
        Some(rec)
    } else {
        encode_stream(
            &mut data,
            &meta,
            &srcs,
            opts.chunking,
            None,
            &mut slots,
            &mut stats,
        );
        None
    };
    drop(srcs);
    src.table.put_cache_slots(Half::Upper, slots);
    (
        WriteReq {
            node: job.node,
            path: job.path.clone(),
            virtual_bytes: write_bytes,
            data,
            recipe,
        },
        stats,
    )
}

fn absorb(stats: &mut DatapathStats, req: &WriteReq, cs: CacheStats) {
    stats.cache_hit_bytes += cs.hit_vbytes;
    stats.cache_hit_regions += cs.hit_regions;
    stats.cache_filled_regions += cs.filled_regions;
    stats.cache_partial_regions += cs.partial_regions;
    stats.fresh_hash_bytes += cs.fresh_hash_vbytes;
    stats.encoded_bytes += req.data.len() as u64;
}

/// One finished rank delivered over the pipelined encode channel.
/// `index` is the rank's position in the wave (its manifest-level order);
/// delivery order is *completion* order.
pub struct RankEncode {
    pub index: usize,
    pub req: WriteReq,
    pub stats: CacheStats,
}

/// Encode every rank's image, delivering each finished rank to `sink` in
/// **completion order** through a bounded channel while later ranks are
/// still encoding — the host-side half of the pipelined write path: BB
/// writes for early ranks can start while late ranks still encode.
///
/// The sink runs on the calling thread and receives every rank exactly
/// once; placing results by `RankEncode::index` reproduces the rank-ordered
/// wave byte-for-byte (the ordered-wave contract holds at the manifest
/// level, not the transport level). The channel is bounded at two entries
/// per worker so a slow consumer backpressures the encoders instead of
/// buffering the whole wave.
pub fn encode_wave_streaming(
    sources: &mut [RankSource<'_>],
    jobs: &[RankJob],
    opts: &EncodeOpts,
    sink: &mut dyn FnMut(RankEncode),
) -> DatapathStats {
    assert_eq!(sources.len(), jobs.len(), "one source per job");
    let t0 = Instant::now();
    let n = jobs.len();
    let threads = opts.threads.clamp(1, n.max(1));
    let mut stats = DatapathStats {
        threads,
        ..DatapathStats::default()
    };
    if threads <= 1 {
        for (i, (src, job)) in sources.iter_mut().zip(jobs).enumerate() {
            let (req, cs) = encode_rank(src, job, opts);
            absorb(&mut stats, &req, cs);
            sink(RankEncode {
                index: i,
                req,
                stats: cs,
            });
        }
    } else {
        let per = n.div_ceil(threads);
        let (tx, rx) = std::sync::mpsc::sync_channel::<RankEncode>(threads * 2);
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(threads);
            let mut rest_src: &mut [RankSource<'_>] = sources;
            let mut rest_jobs: &[RankJob] = jobs;
            let mut base = 0usize;
            while !rest_jobs.is_empty() {
                let take = per.min(rest_jobs.len());
                let (src_chunk, src_tail) = rest_src.split_at_mut(take);
                let (job_chunk, job_tail) = rest_jobs.split_at(take);
                rest_src = src_tail;
                rest_jobs = job_tail;
                let tx = tx.clone();
                handles.push(scope.spawn(move || {
                    for (k, (src, job)) in src_chunk.iter_mut().zip(job_chunk).enumerate() {
                        let (req, cs) = encode_rank(src, job, opts);
                        // A send only fails when the receiver is gone,
                        // which means the consumer side already panicked.
                        if tx
                            .send(RankEncode {
                                index: base + k,
                                req,
                                stats: cs,
                            })
                            .is_err()
                        {
                            return;
                        }
                    }
                }));
                base += take;
            }
            drop(tx); // workers hold the only remaining senders
            let mut delivered = 0usize;
            for enc in rx {
                absorb(&mut stats, &enc.req, enc.stats);
                sink(enc);
                delivered += 1;
            }
            for h in handles {
                h.join().expect("encode worker panicked");
            }
            assert_eq!(delivered, n, "every rank must be delivered exactly once");
        });
    }
    stats.host_secs = t0.elapsed().as_secs_f64();
    stats
}

/// Encode every rank's image, fanning ranks across worker threads, and
/// return the write wave **in rank order** — byte-for-byte identical to
/// the serial path regardless of thread count. A thin reassembly wrapper
/// over [`encode_wave_streaming`]: results arrive in completion order and
/// are placed by index, so the ordered-wave contract costs nothing extra.
pub fn encode_wave(
    sources: &mut [RankSource<'_>],
    jobs: &[RankJob],
    opts: &EncodeOpts,
) -> (Vec<WriteReq>, DatapathStats) {
    let n = jobs.len();
    let mut slots: Vec<Option<WriteReq>> = (0..n).map(|_| None).collect();
    let stats = encode_wave_streaming(sources, jobs, opts, &mut |enc| {
        slots[enc.index] = Some(enc.req);
    });
    let reqs = slots
        .into_iter()
        .map(|s| s.expect("every rank delivered"))
        .collect();
    (reqs, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckpt::CkptImage;
    use crate::mem::{MemRegion, Payload};

    const CB: usize = 4096;

    fn mk_table(state: Vec<u8>) -> RegionTable {
        let mut t = RegionTable::new();
        t.insert(MemRegion::new(
            0x1000_0000_0000,
            state.len() as u64,
            Half::Upper,
            "state",
            Payload::Real(state),
        ))
        .unwrap();
        t.insert(MemRegion::new(
            0x2000_0000_0000,
            1 << 20,
            Half::Upper,
            "heap",
            Payload::Pattern(42),
        ))
        .unwrap();
        t
    }

    fn mk_jobs(n: usize, parent: Option<&str>) -> Vec<RankJob> {
        (0..n)
            .map(|i| RankJob {
                rank: RankId(i as u32),
                node: NodeId((i / 4) as u32),
                path: format!("job/r{i:05}.mana"),
                parent: parent.map(str::to_string),
                extra_regions: Vec::new(),
            })
            .collect()
    }

    fn wave(
        tables: &mut [RegionTable],
        jobs: &[RankJob],
        threads: usize,
        with_recipe: bool,
    ) -> (Vec<WriteReq>, DatapathStats) {
        let mut sources: Vec<RankSource<'_>> = tables
            .iter_mut()
            .map(|t| RankSource {
                table: t,
                step: 7,
                rng_state: [3u8; 32],
                upper_fds: vec![(5, "out.log".into())],
            })
            .collect();
        encode_wave(
            &mut sources,
            jobs,
            &EncodeOpts {
                chunking: Chunking::Fixed(CB),
                threads,
                with_recipe,
            },
        )
    }

    #[test]
    fn parallel_wave_is_byte_identical_to_serial() {
        let mk = || -> Vec<RegionTable> {
            (0..9)
                .map(|i| mk_table(vec![i as u8 + 1; 3000 + 17 * i]))
                .collect()
        };
        let jobs = mk_jobs(9, None);
        let (serial, _) = wave(&mut mk(), &jobs, 1, true);
        let (par, pstats) = wave(&mut mk(), &jobs, 4, true);
        assert_eq!(pstats.threads, 4);
        assert_eq!(serial.len(), par.len());
        for (a, b) in serial.iter().zip(&par) {
            assert_eq!(a.path, b.path, "wave order must be rank order");
            assert_eq!(a.data, b.data, "parallel encode must be byte-identical");
            assert_eq!(a.recipe, b.recipe, "recipes must be identical");
            assert_eq!(a.virtual_bytes, b.virtual_bytes);
        }
    }

    #[test]
    fn wave_matches_legacy_capture_encode() {
        // The view-based path must reproduce CkptImage::capture +
        // encode_with_recipe byte-for-byte, extras included.
        let mut table = mk_table(vec![9u8; 5000]);
        let extra = SavedRegion {
            addr: 0x6f00_0000_0000,
            vlen: 64,
            name: "mana.msg_buffer".into(),
            payload: crate::ckpt::SavedPayload::Full(Payload::Real(vec![7u8; 64])),
        };
        let mut img =
            CkptImage::capture(RankId(0), 7, [3u8; 32], vec![(5, "out.log".into())], &table);
        img.regions.push(extra.clone());
        let mut want = Vec::new();
        let want_rec = img.encode_with_recipe(&mut want, CB);

        let mut jobs = mk_jobs(1, None);
        jobs[0].extra_regions.push(extra);
        let (reqs, _) = wave(std::slice::from_mut(&mut table), &jobs, 1, true);
        assert_eq!(reqs[0].data, want);
        assert_eq!(reqs[0].recipe.as_ref(), Some(&want_rec));
        assert_eq!(reqs[0].virtual_bytes, img.write_bytes());
    }

    #[test]
    fn warm_cache_encode_equals_cold_and_hits() {
        let mut tables = vec![mk_table(vec![4u8; 4096]), mk_table(vec![5u8; 600])];
        let jobs = mk_jobs(2, None);
        let (cold, cstats) = wave(&mut tables, &jobs, 1, true);
        assert_eq!(cstats.cache_hit_regions, 0, "first encode is all misses");
        assert_eq!(
            cstats.cache_filled_regions, 0,
            "dirty regions must not populate entries they could never use"
        );
        // Mark everything clean; the next encode populates the slots...
        for t in tables.iter_mut() {
            t.clear_dirty(Half::Upper);
        }
        let (repop, rstats) = wave(&mut tables, &jobs, 1, true);
        assert_eq!(rstats.cache_hit_regions, 0, "no entries existed yet");
        assert_eq!(rstats.cache_filled_regions, 4, "clean regions populate");
        // ...and the third encode runs fully warm.
        let (warm, wstats) = wave(&mut tables, &jobs, 2, true);
        assert_eq!(
            wstats.cache_hit_regions, 4,
            "every clean region must be served from cache"
        );
        assert!(wstats.cache_hit_bytes > 0);
        for ((a, b), c) in cold.iter().zip(&repop).zip(&warm) {
            assert_eq!(a.data, b.data);
            assert_eq!(b.data, c.data, "warm encode must equal cold bitwise");
            assert_eq!(a.recipe, c.recipe);
        }
    }

    #[test]
    fn dirty_region_is_rehashed_clean_region_is_not() {
        let mut tables = vec![mk_table(vec![1u8; 2048])];
        let jobs = mk_jobs(1, None);
        wave(&mut tables, &jobs, 1, true);
        for t in tables.iter_mut() {
            t.clear_dirty(Half::Upper);
        }
        wave(&mut tables, &jobs, 1, true); // repopulate clean
        // Dirty the state region only.
        {
            let r = tables[0].get_mut("state").unwrap();
            r.payload = Payload::Real(vec![2u8; 2048]);
            r.dirty = true;
        }
        let (reqs, stats) = wave(&mut tables, &jobs, 1, true);
        assert_eq!(stats.cache_hit_regions, 1, "only the clean heap hits");
        // The fresh bytes must reflect the new content.
        let img = CkptImage::decode(&reqs[0].data).unwrap();
        let state = img.regions.iter().find(|r| r.name == "state").unwrap();
        assert_eq!(
            state.payload,
            crate::ckpt::SavedPayload::Full(Payload::Real(vec![2u8; 2048]))
        );
    }

    #[test]
    fn incremental_wave_matches_legacy_capture_incremental() {
        let mut table = mk_table(vec![8u8; 1500]);
        table.clear_dirty(Half::Upper);
        {
            let r = table.get_mut("state").unwrap();
            r.payload = Payload::Real(vec![9u8; 1500]);
            r.dirty = true;
        }
        let img = CkptImage::capture_incremental(
            RankId(0),
            7,
            [3u8; 32],
            vec![(5, "out.log".into())],
            &table,
            "job/parent.mana",
        );
        let mut want = Vec::new();
        img.encode_into_sized(&mut want, CB);

        let jobs = mk_jobs(1, Some("job/parent.mana"));
        let (reqs, _) = wave(std::slice::from_mut(&mut table), &jobs, 1, false);
        assert_eq!(reqs[0].data, want, "incremental capture must match legacy");
        assert_eq!(reqs[0].virtual_bytes, img.write_bytes());
        // And a cached full section must not leak into the ParentRef
        // encode of a later incremental generation.
        let (again, stats) = wave(std::slice::from_mut(&mut table), &jobs, 1, false);
        assert_eq!(again[0].data, want);
        assert_eq!(stats.cache_hit_regions, 0, "ParentRefs never hit the cache");
    }

    #[test]
    fn full_cache_survives_incremental_generations() {
        // full (populate) -> clear -> full (populate clean) -> incremental
        // (ParentRefs, cache untouched) -> full again must run warm.
        let mut tables = vec![mk_table(vec![6u8; 2222])];
        let full_jobs = mk_jobs(1, None);
        let inc_jobs = mk_jobs(1, Some("job/parent.mana"));
        wave(&mut tables, &full_jobs, 1, true);
        for t in tables.iter_mut() {
            t.clear_dirty(Half::Upper);
        }
        let (full_a, _) = wave(&mut tables, &full_jobs, 1, true);
        wave(&mut tables, &inc_jobs, 1, true);
        let (full_b, stats) = wave(&mut tables, &full_jobs, 1, true);
        assert_eq!(stats.cache_hit_regions, 2, "full encode after incremental is warm");
        assert_eq!(full_a[0].data, full_b[0].data);
    }

    #[test]
    fn stale_digest_cache_is_not_silent() {
        // Model a broken invalidation path: plant table A's cache entry
        // into table B (same shape, different content) and encode B. The
        // stale bytes must surface as the wrong region content — which a
        // fingerprint-identical-restart test catches — never as a quietly
        // self-healed encode.
        let mut ta = mk_table(vec![1u8; 256]);
        let mut tb = mk_table(vec![2u8; 256]);
        let jobs = mk_jobs(1, None);
        ta.clear_dirty(Half::Upper); // clean, so the encode populates caches
        wave(std::slice::from_mut(&mut ta), &jobs, 1, true);
        let stale = ta.get("state").unwrap().digest_cache().unwrap().clone();
        tb.clear_dirty(Half::Upper);
        tb.inject_digest_cache("state", stale);
        let (reqs, stats) = wave(std::slice::from_mut(&mut tb), &jobs, 1, true);
        assert!(stats.cache_hit_regions >= 1, "the stale entry must be consulted");
        let img = CkptImage::decode(&reqs[0].data).unwrap();
        let state = img.regions.iter().find(|r| r.name == "state").unwrap();
        assert_eq!(
            state.payload,
            crate::ckpt::SavedPayload::Full(Payload::Real(vec![1u8; 256])),
            "a stale cache serves stale bytes — detectably wrong, not silent"
        );
        // The restored table would fingerprint differently from the live
        // one: exactly the mismatch the C/R determinism tests assert on.
        assert_ne!(
            state.to_region().fingerprint(),
            tb.get("state").unwrap().fingerprint()
        );
    }

    fn wave_chunked(
        tables: &mut [RegionTable],
        jobs: &[RankJob],
        threads: usize,
        chunking: Chunking,
    ) -> (Vec<WriteReq>, DatapathStats) {
        let mut sources: Vec<RankSource<'_>> = tables
            .iter_mut()
            .map(|t| RankSource {
                table: t,
                step: 7,
                rng_state: [3u8; 32],
                upper_fds: vec![(5, "out.log".into())],
            })
            .collect();
        encode_wave(
            &mut sources,
            jobs,
            &EncodeOpts {
                chunking,
                threads,
                with_recipe: true,
            },
        )
    }

    #[test]
    fn cdc_parallel_wave_is_byte_identical_to_serial() {
        let mk = || -> Vec<RegionTable> {
            (0..7)
                .map(|i| {
                    let data: Vec<u8> = (0..9000 + 31 * i)
                        .map(|j| ((j * 31 + i * 7) % 251) as u8)
                        .collect();
                    mk_table(data)
                })
                .collect()
        };
        let jobs = mk_jobs(7, None);
        let cdc = Chunking::cdc(512);
        let (serial, _) = wave_chunked(&mut mk(), &jobs, 1, cdc);
        let (par, _) = wave_chunked(&mut mk(), &jobs, 4, cdc);
        assert_eq!(serial.len(), par.len());
        for (a, b) in serial.iter().zip(&par) {
            assert_eq!(a.data, b.data, "CDC parallel encode must be byte-identical");
            assert_eq!(a.recipe, b.recipe, "CDC recipes must be identical");
        }
    }

    #[test]
    fn digest_cache_never_crosses_chunking_modes() {
        // A warm entry built under one strategy must be a miss under the
        // other — and the cross-mode encode must still be byte-identical
        // to a cold encode of that mode.
        let fixed = Chunking::Fixed(CB);
        let cdc = Chunking::cdc(CB);
        let jobs = mk_jobs(1, None);

        let mut tables = vec![mk_table(vec![5u8; 4096])];
        tables[0].clear_dirty(Half::Upper);
        wave_chunked(&mut tables, &jobs, 1, fixed); // populate fixed entries
        let (warm_fixed, wstats) = wave_chunked(&mut tables, &jobs, 1, fixed);
        assert!(wstats.cache_hit_regions > 0, "fixed entries must be warm");

        // Same table, CDC encode: the fixed entries must not serve it.
        let (cdc_out, cstats) = wave_chunked(&mut tables, &jobs, 1, cdc);
        assert_eq!(
            cstats.cache_hit_regions, 0,
            "a fixed-mode entry must never splice into a CDC encode"
        );
        let mut fresh = vec![mk_table(vec![5u8; 4096])];
        fresh[0].clear_dirty(Half::Upper);
        let (cdc_cold, _) = wave_chunked(&mut fresh, &jobs, 1, cdc);
        assert_eq!(cdc_out[0].data, cdc_cold[0].data);
        assert_eq!(cdc_out[0].recipe, cdc_cold[0].recipe);

        // And the CDC encode repopulated the slots: a CDC re-encode runs
        // warm and still matches, while a fixed encode now misses.
        let (cdc_warm, cwstats) = wave_chunked(&mut tables, &jobs, 1, cdc);
        assert!(cwstats.cache_hit_regions > 0, "CDC entries must be warm now");
        assert_eq!(cdc_warm[0].data, cdc_cold[0].data);
        let (fixed_again, fstats) = wave_chunked(&mut tables, &jobs, 1, fixed);
        assert_eq!(fstats.cache_hit_regions, 0, "mode flip invalidates again");
        assert_eq!(fixed_again[0].data, warm_fixed[0].data);
    }

    #[test]
    fn streaming_sink_reassembles_the_rank_ordered_wave() {
        // The pipelined transport delivers ranks in completion order; the
        // manifest-level contract is that placing them by index rebuilds
        // the rank-ordered wave bitwise.
        let mk = || -> Vec<RegionTable> {
            (0..11)
                .map(|i| mk_table(vec![i as u8 + 1; 2000 + 13 * i]))
                .collect()
        };
        let jobs = mk_jobs(11, None);
        let (ordered, _) = wave(&mut mk(), &jobs, 1, true);

        let mut tables = mk();
        let mut sources: Vec<RankSource<'_>> = tables
            .iter_mut()
            .map(|t| RankSource {
                table: t,
                step: 7,
                rng_state: [3u8; 32],
                upper_fds: vec![(5, "out.log".into())],
            })
            .collect();
        let mut slots: Vec<Option<WriteReq>> = (0..11).map(|_| None).collect();
        let stats = encode_wave_streaming(
            &mut sources,
            &jobs,
            &EncodeOpts {
                chunking: Chunking::Fixed(CB),
                threads: 4,
                with_recipe: true,
            },
            &mut |enc| {
                assert!(
                    slots[enc.index].is_none(),
                    "rank {} delivered twice",
                    enc.index
                );
                slots[enc.index] = Some(enc.req);
            },
        );
        assert_eq!(stats.threads, 4);
        for (slot, want) in slots.into_iter().zip(&ordered) {
            let got = slot.expect("every rank delivered");
            assert_eq!(got.path, want.path);
            assert_eq!(got.data, want.data, "reassembled wave must be bitwise");
            assert_eq!(got.recipe, want.recipe);
        }
    }

    #[test]
    fn partial_hit_fixed_is_bitwise_and_chunk_proportional() {
        // One hot page inside a multi-chunk region: the partial path must
        // produce the cold encode bitwise while re-hashing only the
        // touched chunk (plus the framing-forced last-chunk digest).
        let state_len = 20000usize; // 5 fixed chunks at CB = 4096
        let jobs = mk_jobs(1, None);
        let mut tables = vec![mk_table(vec![1u8; state_len])];
        wave(&mut tables, &jobs, 1, true); // cold (dirty, no populate)
        tables[0].clear_dirty(Half::Upper);
        wave(&mut tables, &jobs, 1, true); // populate clean entries

        let patch = vec![9u8; 64];
        assert!(tables[0].write_range("state", 4096 + 10, &patch));
        let (got, stats) = wave(&mut tables, &jobs, 1, true);
        assert_eq!(stats.cache_partial_regions, 1, "state must partial-hit");
        assert_eq!(stats.cache_hit_regions, 1, "heap still fully hits");
        assert!(
            stats.fresh_hash_bytes >= 4096 && stats.fresh_hash_bytes < state_len as u64,
            "re-hash must be chunk-proportional, got {}",
            stats.fresh_hash_bytes
        );

        let mut want_state = vec![1u8; state_len];
        want_state[4096 + 10..4096 + 10 + 64].copy_from_slice(&patch);
        let mut fresh = vec![mk_table(want_state)];
        let (want, _) = wave(&mut fresh, &jobs, 1, true);
        assert_eq!(got[0].data, want[0].data, "partial encode must be bitwise");
        assert_eq!(got[0].recipe, want[0].recipe, "recipes must be identical");

        // The replanted entry serves the next clean generation fully warm.
        tables[0].clear_dirty(Half::Upper);
        let (again, wstats) = wave(&mut tables, &jobs, 1, true);
        assert_eq!(wstats.cache_hit_regions, 2, "replant must run warm");
        assert_eq!(wstats.fresh_hash_bytes, 0);
        assert_eq!(again[0].data, want[0].data);
    }

    #[test]
    fn partial_hit_cdc_is_bitwise_and_resyncs() {
        // Content-defined grid: the rescan must resume with full-buffer
        // windows and splice the old cut tail back once past the stale
        // span, staying bitwise with a cold encode of the live bytes.
        let state_len = 50_000usize;
        let mk_data = || -> Vec<u8> { (0..state_len).map(|j| ((j * 131) % 251) as u8).collect() };
        let cdc = Chunking::cdc(512);
        let jobs = mk_jobs(1, None);
        let mut tables = vec![mk_table(mk_data())];
        tables[0].clear_dirty(Half::Upper);
        wave_chunked(&mut tables, &jobs, 1, cdc); // populate clean entries

        let patch: Vec<u8> = (0..100).map(|j| (j * 7 % 256) as u8).collect();
        assert!(tables[0].write_range("state", 25_000, &patch));
        let (got, stats) = wave_chunked(&mut tables, &jobs, 1, cdc);
        assert_eq!(stats.cache_partial_regions, 1, "state must partial-hit");
        assert!(
            stats.fresh_hash_bytes < state_len as u64 / 2,
            "rescan must resync instead of re-hashing the region, got {}",
            stats.fresh_hash_bytes
        );

        let mut want_data = mk_data();
        want_data[25_000..25_100].copy_from_slice(&patch);
        let mut fresh = vec![mk_table(want_data)];
        fresh[0].clear_dirty(Half::Upper);
        let (want, _) = wave_chunked(&mut fresh, &jobs, 1, cdc);
        assert_eq!(got[0].data, want[0].data, "CDC partial must be bitwise");
        assert_eq!(got[0].recipe, want[0].recipe, "CDC recipes must match");
    }

    #[test]
    fn partial_hit_survives_the_recipe_toggle() {
        // A recipe-bearing entry must also serve a recipe-less encode, and
        // both flavors must stay bitwise with their cold counterparts.
        let state_len = 3 * 4096usize;
        let jobs = mk_jobs(1, None);
        let mut tables = vec![mk_table(vec![7u8; state_len])];
        tables[0].clear_dirty(Half::Upper);
        wave(&mut tables, &jobs, 1, true); // populate with recipe digests

        assert!(tables[0].write_range("state", 100, &[0xEE; 32]));
        let (got, stats) = wave(&mut tables, &jobs, 1, false);
        assert_eq!(stats.cache_partial_regions, 1);

        let mut want_state = vec![7u8; state_len];
        want_state[100..132].copy_from_slice(&[0xEE; 32]);
        let (want, _) = wave(&mut vec![mk_table(want_state)], &jobs, 1, false);
        assert_eq!(got[0].data, want[0].data);
    }
}
