//! Preempt queue for real-time workloads (the paper's Future Work,
//! implemented): a real-time job preempts a low-priority VASP job via MANA
//! checkpoint, runs to completion, and the VASP job resumes with zero lost
//! work.
//!
//! Run: cargo run --release --example preempt_queue

use anyhow::Result;

use mana::config::{AppKind, RunConfig};
use mana::preempt::run_preemption_scenario;

fn main() -> Result<()> {
    println!("=== Preempt queue: real-time job displaces a low-priority job ===\n");

    let mut low = RunConfig::new(AppKind::VaspRpa, 8);
    low.job = "lowpri-vasp".into();
    low.mem_per_rank = Some(128 << 20);

    let mut rt = RunConfig::new(AppKind::Gromacs, 8);
    rt.job = "realtime-md".into();
    rt.mem_per_rank = Some(64 << 20);

    let rep = run_preemption_scenario(low, rt, None, 4, 6, 8)?;

    println!("low-priority job preempted at step {}", rep.lowpri_steps_at_preempt);
    println!("  MANA checkpoint (realtime launch delay): {:>8.2}s", rep.ckpt_secs);
    println!("  real-time job makespan:                  {:>8.2}s", rep.realtime_secs);
    println!("  low-priority restart:                    {:>8.2}s", rep.restart_secs);
    println!("  low-priority final step:                 {:>8}", rep.lowpri_steps_final);
    println!("  deterministic resume:                    {:>8}", rep.deterministic);

    assert!(rep.deterministic, "preempted job lost work or corrupted state");
    assert_eq!(rep.lowpri_steps_final, 12);
    println!("\nOK: preemption cycle complete, zero work lost beyond the checkpoint.");
    Ok(())
}
