//! Job simulation driver: launch → supersteps → checkpoint → kill →
//! restart, on the full simulated Cori substrate.
//!
//! [`JobSim`] wires everything together: topology, split processes, the
//! MPI world over the GNI-like fabric, MANA wrappers, the DMTCP-style
//! coordinator over the control network, the storage tier, and the PJRT
//! engine for real application compute. Ranks are stepped deterministically
//! in bulk-synchronous supersteps:
//!
//! ```text
//! superstep k (per rank): recv halos of step k-1 → compute → send halos of k
//! ```
//!
//! Checkpoints land *between* supersteps (MANA's wrapper-boundary safe
//! points), with halo messages of step k still in flight — which is exactly
//! what the drain protocol must handle.

use std::sync::Arc;

use anyhow::Result;

use crate::apps::{self, App, StepCtx, HALO_VIRTUAL_BYTES};
use crate::ckpt::manifest::CkptManifest;
use crate::ckpt::{
    datapath, gen_image_path, gen_incr_image_path, image_path, pipeline, CkptImage, ImageError,
    SavedPayload, SavedRegion,
};
use crate::config::{ComputeMode, DrainStrategy, RunConfig};
use crate::coordinator::tree::TreePlane;
use crate::coordinator::{
    CkptFailure, CkptReport, CoordPlane, Coordinator, FlatPlane, OverlapIo, Phase, PhaseIo,
    RankState,
};
use crate::fs::{
    FileSystem, FsConfig, FsError, FsKind, RedundancyConfig, RedundancyScheme, Store,
    TieredStore, WriteReq,
};
use crate::launcher::{self, LaunchError};
use crate::mem::Payload;
use crate::mpi::collectives::{self, InflightCollective};
use crate::mpi::comm::{CommRegistry, COMM_WORLD};
use crate::mpi::{Message, MpiWorld, RankCounters};
use crate::runtime::Engine;
use crate::simnet::control::{ControlNet, CtrlConfig};
use crate::simnet::fabric::{Fabric, FabricConfig};
use crate::splitproc::{SplitConfig, SplitProcess};
use crate::topology::{NodeId, RankId, Topology};
use crate::trace::{self, EventCtx, Lane, Span, SpanId, Tracer};
use crate::util::hash_combine;
use crate::util::simclock::SimTime;
use crate::wrappers::{ManaWrappers, WrapperConfig};
use crate::log_info;

/// Synthetic high address where the drained-message buffer region lives.
const MSG_BUFFER_BASE: u64 = 0x6f00_0000_0000;
/// Address of the communicator replay log pseudo-region (rank 0 only).
const COMM_LOG_ADDR: u64 = 0x6e00_0000_0000;

/// Path of a rank's *incremental* image (full images use
/// [`crate::ckpt::image_path`]).
pub fn incr_image_path(job: &str, rank: RankId) -> String {
    format!("{job}/ckpt_rank{:05}.inc.mana", rank.0)
}

/// Restart failure taxonomy (mirrors the paper's restart bugs).
#[derive(Debug)]
pub enum RestartError {
    /// srun argv-packet overflow (no manifest fix).
    Launch(LaunchError),
    /// Image failed CRC / decode.
    CorruptImage(RankId, ImageError),
    /// Split-process restore failed (fd conflict, region overlap).
    Proc(RankId, String),
    /// Storage error (missing image).
    Fs(String),
}

impl std::fmt::Display for RestartError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RestartError::Launch(e) => write!(f, "launch: {e}"),
            RestartError::CorruptImage(r, e) => write!(f, "{r}: corrupt image: {e}"),
            RestartError::Proc(r, e) => write!(f, "{r}: restore failed: {e}"),
            RestartError::Fs(e) => write!(f, "storage: {e}"),
        }
    }
}

impl std::error::Error for RestartError {}

/// Timing breakdown of a restart (the paper's restart-speedup numbers).
#[derive(Clone, Copy, Debug, Default)]
pub struct RestartReport {
    pub startup_secs: f64,
    pub read_secs: f64,
    pub total_secs: f64,
    /// Images whose fast-tier copy failed CRC and were re-read from the
    /// durable tier (staged mode).
    pub tier_fallbacks: u32,
    /// Nodes whose fast-tier images were rebuilt from redundancy-set
    /// peers before any image was read (staged mode with `--redundancy`).
    pub rebuilt_nodes: u32,
    pub rebuilt_files: u32,
    /// Virtual seconds of peer-rebuild fabric traffic (charged to the
    /// restart's total).
    pub rebuild_secs: f64,
    /// Image files that had to be read from the durable tier (no fast
    /// copy after the rebuild pass). Zero = the restart was served
    /// entirely from the fast tier.
    pub durable_read_files: u32,
    /// How many generations the restart rewound past an unrecoverable
    /// newest generation (the SCR `complete_restart(valid)` loop);
    /// 0 = the newest generation restarted.
    pub generation_rewound: u64,
}

/// Deferred steady-state supersteps (the event core's bulk advance).
///
/// While a window is open, `times`, `procs`, the in-flight queues, and the
/// wrapper request sets are **stale**: the window holds the analytically
/// advanced uniform rank clock plus the wire shape of the *last* deferred
/// step, and [`JobSim::materialize`] replays the application state and
/// rebuilds the wire bit-exactly before any observer looks. The recurrence
/// repeats the concrete superstep's exact f64 operation sequence (f64
/// addition is non-associative, so no closed form is possible for times —
/// only for the u64 counters), which is what makes the equivalence bar
/// bitwise rather than approximate.
struct LazyWindow {
    /// `procs[r].step` at window open (the first deferred superstep).
    start_step: u64,
    /// Deferred supersteps accumulated so far.
    steps: u64,
    /// Uniform post-allreduce rank clock after the last deferred step.
    t_cur: SimTime,
    /// Arrival times of the current in-flight halo pair (every rank's
    /// inbound queue holds exactly two messages with these stamps).
    d0: SimTime,
    d1: SimTime,
    /// Last deferred step's send chronology: post-compute time (chunk 0's
    /// `sent_at`), chunk 1's send time after the careful-nonblocking wait,
    /// and the two delivery stamps — everything materialize needs to
    /// reconstruct the in-flight messages and the outstanding request.
    c_final: SimTime,
    t_sent_final: SimTime,
    d0_final: SimTime,
    d1_final: SimTime,
    /// Per-rank MPI counter delta across the whole window (halo sends and
    /// receives plus allreduce wire traffic; identical on every rank).
    delta: RankCounters,
}

/// The live job.
pub struct JobSim {
    pub cfg: RunConfig,
    pub topo: Topology,
    pub app: Box<dyn App>,
    pub procs: Vec<SplitProcess>,
    pub world: MpiWorld,
    pub wrappers: ManaWrappers,
    pub times: Vec<SimTime>,
    pub fs: Store,
    pub coord: Coordinator,
    pub engine: Option<Arc<Engine>>,
    /// Communicators: record-and-replay log survives C/R.
    pub comms: CommRegistry,
    /// Observability registry (counters/gauges/summaries).
    pub metrics: crate::metrics::Metrics,
    /// Span recorder + structured event log on the virtual clock. Events
    /// are always captured; spans/counters only when `cfg.trace` is on.
    pub tracer: Tracer,
    /// Supersteps completed (all ranks agree outside a superstep).
    pub step: u64,
    /// Halo messages that were expected but lost (undrained checkpoint).
    pub lost_halo_events: u64,
    pub launch_startup_secs: f64,
    /// Next checkpoint generation (staged mode stamps paths with it).
    ckpt_gen: u64,
    /// Generation of the last full checkpoint (the incremental parent).
    last_full_gen: Option<u64>,
    /// Open bulk-advance window (event-driven driver), if any.
    lazy: Option<LazyWindow>,
}

impl JobSim {
    // ------------------------------------------------------------- launch

    /// Fresh job launch (not a restart).
    pub fn launch(cfg: RunConfig, engine: Option<Arc<Engine>>) -> Result<JobSim> {
        let topo = Topology::new(cfg.ranks, cfg.threads_per_rank);
        let fs = Self::make_fs(&cfg, &topo);
        Self::launch_with_fs(cfg, engine, fs)
    }

    /// Launch against an existing storage tier (preemption flows reuse it).
    pub fn launch_with_fs(
        cfg: RunConfig,
        engine: Option<Arc<Engine>>,
        mut fs: Store,
    ) -> Result<JobSim> {
        if cfg.compute == ComputeMode::Real {
            anyhow::ensure!(
                engine.is_some(),
                "Real compute mode requires a loaded Engine"
            );
        }
        let topo = Topology::new(cfg.ranks, cfg.threads_per_rank);
        let argv = vec!["mana_launch".into(), cfg.app.name().into()];
        let launch = launcher::launch(&topo, cfg.link, &argv)
            .map_err(|e| anyhow::anyhow!("launch: {e}"))?;
        log_info!(
            "sim",
            "launch {}: {} ranks x {} threads on {} nodes ({:.2}s startup)",
            cfg.job,
            cfg.ranks,
            cfg.threads_per_rank,
            launch.nodes,
            launch.startup_secs
        );
        log_info!("sim", "{}", topo.mapping_table());

        let app = apps::make_app(cfg.app);
        let mem_per_rank = cfg.mem_per_rank.unwrap_or(app.default_mem_per_rank());
        let split_cfg = SplitConfig {
            os: cfg.os,
            alloc_policy: cfg.fixes.alloc_policy(),
            fd_policy: cfg.fixes.fd_policy(),
            ..SplitConfig::default()
        };
        let mut procs = Vec::with_capacity(cfg.ranks as usize);
        for r in 0..cfg.ranks {
            let mut p = SplitProcess::launch(RankId(r), split_cfg, cfg.seed)?;
            app.init(&mut p, cfg.ranks, mem_per_rank)?;
            procs.push(p);
        }

        let world = MpiWorld::new(cfg.ranks, Self::make_fabric(&cfg));
        let wrappers = ManaWrappers::new(
            WrapperConfig {
                careful_nonblocking: cfg.fixes.careful_nonblocking,
            },
            cfg.ranks,
        );
        let tracer = Tracer::new(cfg.trace);
        tracer.set_job(&cfg.job);
        fs.set_tracer(tracer.clone());
        let mut coord = Self::make_coordinator(&cfg, &topo);
        coord.set_tracer(tracer.clone());
        let times = vec![SimTime::secs(launch.startup_secs); cfg.ranks as usize];

        // Applications dup WORLD and split node-local communicators at
        // MPI_Init time; MANA records the calls for restart replay.
        let mut comms = CommRegistry::new(cfg.ranks);
        comms.dup(COMM_WORLD).expect("dup WORLD");
        let node_colors: Vec<i32> = (0..cfg.ranks)
            .map(|r| topo.node_of(RankId(r)).0 as i32)
            .collect();
        comms
            .split(COMM_WORLD, &node_colors)
            .expect("node-local split");

        Ok(JobSim {
            cfg,
            topo,
            app,
            procs,
            world,
            wrappers,
            times,
            fs,
            coord,
            engine,
            comms,
            metrics: crate::metrics::Metrics::new(),
            tracer,
            step: 0,
            lost_halo_events: 0,
            launch_startup_secs: launch.startup_secs,
            ckpt_gen: 0,
            last_full_gen: None,
            lazy: None,
        })
    }

    fn make_fs(cfg: &RunConfig, topo: &Topology) -> Store {
        if let Some(staging) = cfg.staging {
            // Staged mode: BB fast tier + Lustre durable tier. A capacity
            // override squeezes the *fast* tier (forcing eviction paths).
            let mut bb = FsConfig::burst_buffer(topo.nodes());
            if let Some(cap) = cfg.faults.fs_capacity_override {
                bb.capacity = cap;
            }
            let mut ts = TieredStore::new(
                FileSystem::new(bb),
                FileSystem::new(FsConfig::cscratch()),
                staging.keep_fulls,
                topo.nodes(),
            );
            ts.set_redundancy(RedundancyConfig::new(
                cfg.redundancy,
                cfg.redundancy_set_size,
            ));
            ts.set_early_admission(staging.early_admission);
            Self::schedule_fs_losses(cfg, &mut ts);
            return Store::Tiered(ts);
        }
        let mut fscfg = match cfg.fs {
            FsKind::BurstBuffer => FsConfig::burst_buffer(topo.nodes()),
            FsKind::Lustre => FsConfig::cscratch(),
        };
        if let Some(cap) = cfg.faults.fs_capacity_override {
            fscfg.capacity = cap;
        }
        Store::Single(FileSystem::new(fscfg))
    }

    /// Wire the fault plan's declarative fast-tier losses into the store
    /// (same pattern as `image_bitflip`: the subsystem reads its knobs at
    /// construction time and fires them on its own clock).
    fn schedule_fs_losses(cfg: &RunConfig, ts: &mut TieredStore) {
        for (node, at) in &cfg.faults.bb_node_loss {
            ts.schedule_node_loss(*node, *at);
        }
        for (set, at) in &cfg.faults.bb_set_loss {
            ts.schedule_set_loss(*set, *at);
        }
    }

    fn make_fabric(cfg: &RunConfig) -> Fabric {
        Fabric::new(FabricConfig {
            quiescence: cfg.faults.gni_quiescence.clone(),
            ..FabricConfig::default()
        })
    }

    /// Build the coordinator with the configured coordination plane: the
    /// flat DMTCP root by default, or the per-node sub-coordinator tree
    /// (`--coord-fanout`), whose depth derives from the job topology.
    fn make_coordinator(cfg: &RunConfig, topo: &Topology) -> Coordinator {
        let ctrl = ControlNet::new(
            CtrlConfig {
                keepalive: cfg.fixes.keepalive,
                loss_prob: cfg.faults.ctrl_loss_prob,
                disconnect_prob: cfg.faults.ctrl_disconnect_prob,
                ..CtrlConfig::default()
            },
            cfg.seed ^ 0xC00D,
        );
        let plane: Box<dyn CoordPlane> = match cfg.coord_fanout {
            Some(f) => Box::new(TreePlane::new(topo, f, cfg.faults.subcoord_death)),
            None => Box::new(FlatPlane::new(cfg.ranks)),
        };
        Coordinator::new(ctrl, plane, cfg.ranks, cfg.fixes.locks)
    }

    // -------------------------------------------------------------- steps

    /// Run `n` supersteps. With the event-driven driver (default),
    /// steady-state steps between interesting boundaries collapse into the
    /// bulk-advance recurrence — O(1) host work per step instead of
    /// O(ranks) — and the concrete loop only runs when the wire shape is
    /// not steady (step 0, post-restart replays, lower-half growth).
    pub fn run_steps(&mut self, n: u64) -> Result<()> {
        for _ in 0..n {
            if self.bulk_step()? {
                continue;
            }
            self.materialize()?;
            self.superstep()?;
        }
        Ok(())
    }

    /// Advance one superstep analytically if the job is in (or can enter)
    /// the steady-state window. Returns `false` when the step must run
    /// through the concrete per-rank loop instead.
    fn bulk_step(&mut self) -> Result<bool> {
        if !self.cfg.event_driven || self.cfg.ranks == 0 {
            return Ok(false);
        }
        // Lower-half growth events mutate address spaces per step; run
        // those steps concretely.
        if self.step < self.cfg.faults.lower_half_growth_events as u64 {
            return Ok(false);
        }
        if self.lazy.is_none() {
            if !self.window_eligible() {
                return Ok(false);
            }
            self.open_window();
        }
        let ranks = self.cfg.ranks;
        let compute_secs = self.app.compute_secs();
        // The recurrence folds the app's blocking allreduce cadence; the
        // default (4 KiB) reproduces the historical hardcoded reduction
        // bit-for-bit. Nonblocking cadences never reach here — a pending
        // collective makes the window ineligible.
        let coll_bytes = self.app.collective_cadence().bytes;
        let t_now = {
            let w = self.lazy.as_mut().expect("window just ensured");
            if ranks > 1 {
                // Exact f64 op sequence of the concrete superstep on the
                // uniform rank clock: recv chunk 0/1, compute, send chunk 0
                // (no wait), send chunk 1 (careful-nonblocking wait for
                // chunk 0), then the wrapped allreduce.
                let t1 = w.t_cur.max(w.d0);
                let t2 = t1.max(w.d1);
                let mut c = t2;
                c.advance(compute_secs);
                let d0n = self.world.fabric.delivery_time(c, HALO_VIRTUAL_BYTES);
                let ts = c.max(d0n);
                let d1n = self.world.fabric.delivery_time(ts, HALO_VIRTUAL_BYTES);
                // collectives::allreduce folds the (uniform) clocks from
                // SimTime::ZERO; replicate that fold bit-for-bit.
                let enter = SimTime::ZERO.max(ts);
                let (wire, dur) = collectives::allreduce_cost(&self.world, coll_bytes);
                let msgs = collectives::allreduce_msgs(ranks);
                w.t_cur = enter.after(dur);
                w.d0 = d0n;
                w.d1 = d1n;
                w.c_final = c;
                w.t_sent_final = ts;
                w.d0_final = d0n;
                w.d1_final = d1n;
                w.delta.sent_bytes += 2 * HALO_VIRTUAL_BYTES + wire;
                w.delta.recv_bytes += 2 * HALO_VIRTUAL_BYTES + wire;
                w.delta.sent_msgs += 2 + msgs;
                w.delta.recv_msgs += 2 + msgs;
            } else {
                w.t_cur.advance(compute_secs);
            }
            w.steps += 1;
            w.t_cur
        };
        self.step += 1;
        self.metrics.inc("supersteps", 1);
        self.metrics.gauge("virtual_secs", t_now.as_secs());

        // Same background-drain tick as the concrete superstep (identical
        // `now`, so DrainStats and drain spans stay bitwise-identical).
        let now = t_now.as_secs();
        if let Store::Tiered(ts) = &mut self.fs {
            let tick = ts.drain_to(now);
            let backlog = ts.pending_bytes();
            let depth = ts.pending_files();
            self.metrics.gauge("drain.backlog_bytes", backlog as f64);
            self.metrics.gauge("drain.queue_depth", depth as f64);
            if tick.drained_bytes > 0 {
                self.coord.stats.staged_bytes += tick.drained_bytes;
                self.metrics.inc("drain.bytes", tick.drained_bytes);
            }
            if tick.queue_empty && tick.completed_files > 0 {
                for r in 0..self.cfg.ranks {
                    self.coord
                        .set_rank_state(RankId(r), RankState::Resumed, false);
                }
            }
        }
        Ok(true)
    }

    /// Is the job in the steady-state shape the bulk recurrence models?
    /// One O(ranks) scan, run once per window (not per step): uniform
    /// clocks, every rank one step past its sends, exactly one outstanding
    /// converted send and exactly two in-flight halo chunks per rank, all
    /// with uniform timestamps.
    fn window_eligible(&self) -> bool {
        let ranks = self.cfg.ranks;
        if ranks == 1 {
            // Single rank: compute-only supersteps, trivially steady.
            return true;
        }
        // The recurrence models the careful-nonblocking wait; the buggy
        // clobber path must keep running concretely.
        if !self.cfg.fixes.careful_nonblocking {
            return false;
        }
        // A pending nonblocking collective straddles the boundary the
        // window would open on, and the recurrence folds the *blocking*
        // allreduce only — the per-rank `in_collective` scan below would
        // also veto, but the pending record is the authoritative guard.
        if self.wrappers.pending_collective().is_some() {
            return false;
        }
        let step0 = self.procs[0].step;
        if step0 == 0 || step0 != self.step {
            return false;
        }
        let tag = (step0 - 1) as u32;
        let t0 = self.times[0];
        let mut shape: Option<(SimTime, SimTime)> = None;
        for r in 0..ranks {
            let rank = RankId(r);
            let prev = RankId((r + ranks - 1) % ranks);
            let next = RankId((r + 1) % ranks);
            if self.procs[r as usize].step != step0 {
                return false;
            }
            if self.times[r as usize] != t0 {
                return false;
            }
            if self.wrappers.in_collective(rank) {
                return false;
            }
            if self.wrappers.buffered_count(rank) != 0 {
                return false;
            }
            let Some((odst, otag, od)) = self.wrappers.steady_outstanding(rank) else {
                return false;
            };
            if odst != next || otag != tag {
                return false;
            }
            let q = self.world.inflight_for(rank);
            if q.len() != 2 {
                return false;
            }
            let (m0, m1) = (&q[0], &q[1]);
            if m0.src != prev
                || m1.src != prev
                || m0.tag != tag
                || m1.tag != tag
                || m0.bytes != HALO_VIRTUAL_BYTES
                || m1.bytes != HALO_VIRTUAL_BYTES
            {
                return false;
            }
            match shape {
                None => shape = Some((m0.deliver_at, m1.deliver_at)),
                Some((d0, d1)) => {
                    if m0.deliver_at != d0 || m1.deliver_at != d1 {
                        return false;
                    }
                }
            }
            // Symmetry: the rank's outstanding send completes exactly when
            // its inbound chunk 1 arrives (uniform state).
            if od != m1.deliver_at {
                return false;
            }
        }
        true
    }

    /// Open a bulk-advance window over the current (verified-steady) state.
    fn open_window(&mut self) {
        let t = self.times[0];
        let (d0, d1) = if self.cfg.ranks > 1 {
            let q = self.world.inflight_for(RankId(0));
            (q[0].deliver_at, q[1].deliver_at)
        } else {
            (SimTime::ZERO, SimTime::ZERO)
        };
        self.lazy = Some(LazyWindow {
            start_step: self.procs[0].step,
            steps: 0,
            t_cur: t,
            d0,
            d1,
            c_final: t,
            t_sent_final: t,
            d0_final: d0,
            d1_final: d1,
            delta: RankCounters::default(),
        });
    }

    /// Close the bulk-advance window: replay the deferred supersteps'
    /// application state (folds + computes, payloads regenerated from the
    /// sender's state hash exactly as the concrete loop builds them),
    /// rebuild the last step's in-flight messages and outstanding
    /// requests, apply the counter delta, and land every rank clock on the
    /// analytically advanced time. After this, the job state is
    /// bitwise-indistinguishable from having run every superstep
    /// concretely. No-op when no window is open. Public because external
    /// observers that reach into `procs`/`times`/`world` directly (tests,
    /// the console, benches) must close the window first.
    pub fn materialize(&mut self) -> Result<()> {
        let Some(w) = self.lazy.take() else {
            return Ok(());
        };
        if w.steps == 0 {
            return Ok(());
        }
        let ranks = self.cfg.ranks;
        if ranks == 1 {
            for _ in 0..w.steps {
                let proc = &mut self.procs[0];
                let mut ctx = StepCtx {
                    rank: RankId(0),
                    ranks,
                    proc,
                    engine: self.engine.as_deref(),
                    mode: self.cfg.compute,
                };
                self.app.compute(&mut ctx)?;
                self.procs[0].step += 1;
            }
            self.times[0] = w.t_cur;
            return Ok(());
        }

        // The first replayed step folds the real in-flight payloads (they
        // were on the wire when the window opened); later steps regenerate
        // them from the sender's previous-step state hash — the same
        // construction the concrete sender used.
        let mut first_msgs: Vec<[Vec<u8>; 2]> = Vec::with_capacity(ranks as usize);
        for r in 0..ranks {
            let rank = RankId(r);
            let m0 = self
                .world
                .pop_inflight_raw(rank)
                .expect("window invariant: two in-flight halos");
            let m1 = self
                .world
                .pop_inflight_raw(rank)
                .expect("window invariant: two in-flight halos");
            first_msgs.push([m0.payload, m1.payload]);
        }
        let mut prev_hash = vec![0u64; ranks as usize];
        for k in 0..w.steps {
            let step = w.start_step + k;
            for r in 0..ranks {
                let prev = RankId((r + ranks - 1) % ranks);
                if k == 0 {
                    let [p0, p1] = std::mem::take(&mut first_msgs[r as usize]);
                    apps::fold_halo(&mut self.procs[r as usize], &p0)?;
                    apps::fold_halo(&mut self.procs[r as usize], &p1)?;
                } else {
                    for chunk in 0..2u8 {
                        let payload = apps::halo_payload_from_hash(
                            prev_hash[prev.0 as usize],
                            step - 1,
                            chunk,
                        );
                        apps::fold_halo(&mut self.procs[r as usize], &payload)?;
                    }
                }
                let proc = &mut self.procs[r as usize];
                let mut ctx = StepCtx {
                    rank: RankId(r),
                    ranks,
                    proc,
                    engine: self.engine.as_deref(),
                    mode: self.cfg.compute,
                };
                self.app.compute(&mut ctx)?;
                self.procs[r as usize].step += 1;
            }
            // A rank's primary state only mutates in its own iteration, so
            // hashing after the loop equals hashing at each rank's send.
            for r in 0..ranks {
                prev_hash[r as usize] = self.primary_state_hash(r);
            }
        }

        // Rebuild the wire: the last deferred step's two halo chunks per
        // rank, with the analytically derived chronology, plus the single
        // outstanding converted send and the counter delta.
        let last_step = w.start_step + w.steps - 1;
        let last_tag = last_step as u32;
        for r in 0..ranks {
            let rank = RankId(r);
            let next = RankId((r + 1) % ranks);
            let h = prev_hash[r as usize];
            for (chunk, sent_at, deliver_at) in [
                (0u8, w.c_final, w.d0_final),
                (1u8, w.t_sent_final, w.d1_final),
            ] {
                self.world.push_inflight_raw(Message {
                    src: rank,
                    dst: next,
                    tag: last_tag,
                    bytes: HALO_VIRTUAL_BYTES,
                    payload: apps::halo_payload_from_hash(h, last_step, chunk),
                    sent_at,
                    deliver_at,
                });
            }
            self.wrappers
                .set_steady_outstanding(rank, next, last_tag, w.d1_final);
            self.world.add_counters(rank, w.delta);
            self.times[r as usize] = w.t_cur;
        }
        Ok(())
    }

    fn superstep(&mut self) -> Result<()> {
        let ranks = self.cfg.ranks;
        // Wait on the previous boundary's nonblocking allreduce first (the
        // MPI_Wait of an MPI_Iallreduce): the remaining rounds charge their
        // counters and every rank lands on the op's completion time.
        if ranks > 1 {
            let _ = self
                .wrappers
                .finish_pending_collective(&mut self.world, &mut self.times);
        }
        for r in 0..ranks {
            let rank = RankId(r);
            let prev = RankId((r + ranks - 1) % ranks);
            let next = RankId((r + 1) % ranks);
            let step = self.procs[r as usize].step;

            // 1. Receive the two halo chunks of the previous superstep.
            if step > 0 && ranks > 1 {
                let tag = (step - 1) as u32;
                for _chunk in 0..2 {
                    let mut t = self.times[r as usize];
                    let got = self.wrappers.recv_or_lost(
                        &mut self.world,
                        rank,
                        Some(prev),
                        Some(tag),
                        &mut t,
                    );
                    self.times[r as usize] = t;
                    match got {
                        Some(payload) => {
                            apps::fold_halo(&mut self.procs[r as usize], &payload)?
                        }
                        None => {
                            self.lost_halo_events += 1;
                            self.procs[r as usize].corrupted = true;
                            self.tracer.warn(
                                "sim",
                                "sim.halo_lost",
                                EventCtx::rank(r).with_t(self.times[r as usize].as_secs()),
                                format!(
                                    "{rank}: halo of step {} lost (undrained checkpoint?) — data loss",
                                    step - 1
                                ),
                            );
                        }
                    }
                }
            }

            // 2. Compute.
            {
                let proc = &mut self.procs[r as usize];
                let mut ctx = StepCtx {
                    rank,
                    ranks,
                    proc,
                    engine: self.engine.as_deref(),
                    mode: self.cfg.compute,
                };
                self.app.compute(&mut ctx)?;
            }
            self.times[r as usize].advance(self.app.compute_secs());

            // 3. Send this superstep's two halo chunks (same tag — the
            //    pattern that trips careless Isend conversion).
            if ranks > 1 {
                // Hash the state in place (perf: no clone per rank-step).
                let state_hash = self.primary_state_hash(r);
                for chunk in 0..2u8 {
                    let payload = apps::halo_payload_from_hash(state_hash, step, chunk);
                    let mut t = self.times[r as usize];
                    self.wrappers.send(
                        &mut self.world,
                        rank,
                        next,
                        step as u32,
                        HALO_VIRTUAL_BYTES,
                        payload,
                        &mut t,
                    );
                    self.times[r as usize] = t;
                }
            }
            self.procs[r as usize].step += 1;
        }

        // Every superstep ends with the application's wrapped global
        // reduction (energy / dot product) — a two-phase collective the
        // checkpoint protocol must respect. The app's cadence picks the
        // shape: blocking completes in place (the historical behavior);
        // nonblocking posts the op staggered and leaves it pending across
        // the superstep boundary — where checkpoint requests land — to be
        // waited on at the top of the next superstep.
        if ranks > 1 {
            let cad = self.app.collective_cadence();
            if cad.nonblocking {
                self.wrappers
                    .begin_allreduce_staggered(&mut self.world, &mut self.times, cad.bytes);
            } else {
                self.wrappers
                    .allreduce(&mut self.world, &mut self.times, cad.bytes);
            }
        }

        // Injected lower-half growth events (the large-scale MPI-library
        // mmap bug) fire on the first K supersteps.
        if self.step < self.cfg.faults.lower_half_growth_events as u64 {
            for p in &mut self.procs {
                p.lower_half_growth()?;
            }
        }
        self.step += 1;
        self.metrics.inc("supersteps", 1);
        self.metrics
            .gauge("virtual_secs", self.now().as_secs());

        // Asynchronous Drain-to-PFS phase: while ranks were computing,
        // node-local drain agents staged queued checkpoint bytes to the
        // durable tier on the same virtual clock.
        let now = self.now().as_secs();
        if let Store::Tiered(ts) = &mut self.fs {
            let tick = ts.drain_to(now);
            let backlog = ts.pending_bytes();
            let depth = ts.pending_files();
            self.metrics.gauge("drain.backlog_bytes", backlog as f64);
            self.metrics.gauge("drain.queue_depth", depth as f64);
            if tick.drained_bytes > 0 {
                self.coord.stats.staged_bytes += tick.drained_bytes;
                self.metrics.inc("drain.bytes", tick.drained_bytes);
            }
            if tick.queue_empty && tick.completed_files > 0 {
                // The last image went durable: the async phase is over.
                for r in 0..self.cfg.ranks {
                    self.coord
                        .set_rank_state(RankId(r), RankState::Resumed, false);
                }
            }
        }
        Ok(())
    }

    /// Force the background BB→PFS drain to completion (single-tier jobs
    /// are a no-op). Returns the durable-tier busy seconds the drain
    /// agents spent; rank clocks are NOT advanced — this is the
    /// *background* half the staged engine overlaps with compute.
    pub fn finish_drain(&mut self) -> f64 {
        let ranks = self.cfg.ranks;
        match &mut self.fs {
            Store::Tiered(ts) => {
                if ts.pending_files() == 0 {
                    return 0.0;
                }
                let secs = ts.drain_sync();
                for r in 0..ranks {
                    self.coord
                        .set_rank_state(RankId(r), RankState::Resumed, false);
                }
                secs
            }
            Store::Single(_) => 0.0,
        }
    }

    // ----------------------------------------------------- ckpt paths

    /// Full-image path for the generation currently being written.
    fn full_path(&self, rank: RankId) -> String {
        if self.cfg.staging.is_some() {
            gen_image_path(&self.cfg.job, self.ckpt_gen, rank)
        } else {
            image_path(&self.cfg.job, rank)
        }
    }

    /// Incremental-image path for the current generation.
    fn incr_path(&self, rank: RankId) -> String {
        if self.cfg.staging.is_some() {
            gen_incr_image_path(&self.cfg.job, self.ckpt_gen, rank)
        } else {
            incr_image_path(&self.cfg.job, rank)
        }
    }

    /// Path of the last full image (the incremental parent).
    fn parent_path(&self, rank: RankId) -> String {
        match (self.cfg.staging.is_some(), self.last_full_gen) {
            (true, Some(gen)) => gen_image_path(&self.cfg.job, gen, rank),
            _ => image_path(&self.cfg.job, rank),
        }
    }

    fn primary_state_hash(&self, r: u32) -> u64 {
        let proc = &self.procs[r as usize];
        for name in ["pos", "x", "chi", "state"] {
            if let Some(s) = proc.app_state(name) {
                return crate::util::fnv1a(s);
            }
        }
        crate::util::fnv1a(&[])
    }

    // --------------------------------------------------------- checkpoint

    /// Run the full MANA checkpoint protocol. Every phase's control
    /// traffic moves through the configured coordination plane (flat root
    /// or sub-coordinator tree) as a broadcast-down + reduce-up.
    pub fn checkpoint(&mut self) -> Result<CkptReport, CkptFailure> {
        // A checkpoint observes everything: close any bulk-advance window
        // so rank clocks, wire state, and app state are concrete.
        self.materialize()
            .expect("deferred superstep replay failed");
        let mut report = CkptReport {
            coord_depth: self.coord.plane.depth(),
            ..CkptReport::default()
        };
        let t0 = self.now();
        let pipelined = self.cfg.pipeline;
        report.pipelined = pipelined;
        let gen = self.ckpt_gen;
        let tr = self.tracer.clone();
        // Span chain: each protocol step depends on the previous one, so
        // the critical-path walk can telescope the whole checkpoint stall.
        // Assigned on both arms of the intent/safepoint split below.
        let mut prev: Option<SpanId>;

        // Phases 1+2: INTENT and SAFE-POINT. Pipelined, the SAFE-POINT
        // broadcast starts down the tree while the INTENT reduce is still
        // converging (the plane fuses the sweeps and the epoch rule keeps
        // retries honest); serial, the two exchanges run back to back.
        // Either way the rank-side work — the status-table update, the
        // consistency check, and retiring outstanding converted requests —
        // happens before the SAFE-POINT acks can flow, so it is hoisted in
        // front of whichever exchange shape runs.
        let interrupt = self.cfg.faults.interrupt_status_update;
        let mut t;
        if pipelined {
            for r in 0..self.cfg.ranks {
                self.coord
                    .set_rank_state(RankId(r), RankState::SafePoint, interrupt);
            }
            self.coord.check_status_consistent()?;
            for r in 0..self.cfg.ranks {
                let rank = RankId(r);
                if !self.wrappers.at_safe_point(rank, self.times[r as usize]) {
                    if let Some(done) = self.wrappers.next_completion(rank) {
                        self.times[r as usize] = self.times[r as usize].max(done);
                    }
                    self.wrappers.retire_completed(rank, self.times[r as usize]);
                }
            }
            let o = self
                .coord
                .phase_exchange_overlapped(Phase::Intent, Phase::SafePoint, t0)?;
            absorb_overlap(&mut report, &o);
            report.intent_secs = o.first.secs;
            report.safepoint_secs = o.second.secs;
            report.stale_acks = o.stale_acks;
            report.overlap_saved_secs += (o.first.secs + o.second.secs) - o.secs;
            t = t0.after(o.secs);
            let intent_id = tr.record(
                Span::new("intent", Lane::Ctrl, t0.as_secs(), t0.as_secs() + o.first.secs)
                    .gen(gen)
                    .attr("fused", true),
            );
            prev = tr
                .record(
                    Span::new(
                        "safepoint",
                        Lane::Ctrl,
                        t.as_secs() - o.second.secs,
                        t.as_secs(),
                    )
                    .gen(gen)
                    .dep_opt(intent_id)
                    .attr("fused", true),
                )
                .or(intent_id);
        } else {
            // Phase 1: INTENT over the coordination plane.
            let pio = self.coord.phase_exchange(Phase::Intent, t0)?;
            absorb_phase(&mut report, pio);
            report.intent_secs = pio.secs;
            t = t0.after(pio.secs);
            let intent_id = tr.record(
                Span::new("intent", Lane::Ctrl, t0.as_secs(), t.as_secs()).gen(gen),
            );

            // Fault window: a status update lands right here; without the
            // locks fix it is interruptible.
            for r in 0..self.cfg.ranks {
                self.coord
                    .set_rank_state(RankId(r), RankState::SafePoint, interrupt);
            }
            self.coord.check_status_consistent()?;

            // Phase 2: safe points (no outstanding converted requests),
            // confirmed over the plane.
            for r in 0..self.cfg.ranks {
                let rank = RankId(r);
                if !self.wrappers.at_safe_point(rank, self.times[r as usize]) {
                    if let Some(done) = self.wrappers.next_completion(rank) {
                        self.times[r as usize] = self.times[r as usize].max(done);
                    }
                    self.wrappers.retire_completed(rank, self.times[r as usize]);
                }
            }
            let sp_t0 = t.as_secs();
            let pio = self.coord.phase_exchange(Phase::SafePoint, t)?;
            absorb_phase(&mut report, pio);
            report.safepoint_secs = pio.secs;
            t = t.after(pio.secs);
            prev = tr
                .record(
                    Span::new("safepoint", Lane::Ctrl, sp_t0, t.as_secs())
                        .gen(gen)
                        .dep_opt(intent_id),
                )
                .or(intent_id);
        }

        // Phase 3: DRAIN (or the legacy drop).
        let drain_t0 = self.now();
        report.drain_strategy = self.cfg.drain_strategy;
        let topo = self.cfg.drain_strategy == DrainStrategy::Topo;
        // A checkpoint request that lands inside a pending collective:
        // counter drain completes the op first (MANA's trivial barrier —
        // the remaining rounds are charged to drain time); topo drain
        // checkpoints *inside* the op, carrying each rank's round cursor
        // into the manifest so restart resumes from the recorded round.
        let mut pending_collective: Option<InflightCollective> = None;
        if self.wrappers.pending_collective().is_some() {
            report.collectives_interrupted = 1;
            if topo {
                pending_collective = self.wrappers.pending_collective().cloned();
            } else {
                let _ = self
                    .wrappers
                    .finish_pending_collective(&mut self.world, &mut self.times);
                report.collective_drain_secs =
                    self.now().as_secs() - drain_t0.as_secs();
            }
        }
        if self.cfg.fixes.drain {
            let drep = self.wrappers.drain_all(&mut self.world, &mut self.times);
            report.drain_rounds = drep.rounds;
            report.buffered_msgs = drep.buffered_msgs;
            debug_assert!(self.world.drained(), "drain postcondition");
            // The coordinator's own table keeps the per-rank rows (console
            // and race-model view) — no extra control traffic is charged
            // for them; the protocol-path convergence check below moves
            // only aggregates.
            for r in 0..self.cfg.ranks {
                let c = self.world.counters[r as usize];
                self.coord.record_rank_counts(
                    RankId(r),
                    self.procs[r as usize].step,
                    c.sent_bytes,
                    c.recv_bytes,
                );
            }
        } else {
            let lost = self.world.drop_inflight();
            report.lost_messages = lost;
            self.coord.stats.lost_messages += lost as u64;
            if lost > 0 {
                tr.warn(
                    "coordinator",
                    "ckpt.undrained_drop",
                    EventCtx::default().with_gen(gen).with_t(t.as_secs()),
                    format!("checkpoint without drain dropped {lost} in-flight messages"),
                );
            }
        }
        // Drain is a barrier.
        let t_sync = self.now();
        for tt in &mut self.times {
            *tt = t_sync;
        }
        t = t.max(t_sync);
        prev = tr
            .record(
                Span::new("drain.msgs", Lane::Phase, drain_t0.as_secs(), t_sync.as_secs())
                    .gen(gen)
                    .dep_opt(prev)
                    .attr("rounds", report.drain_rounds)
                    .attr("buffered_msgs", report.buffered_msgs),
            )
            .or(prev);
        let mut drain_secs = t_sync.as_secs() - drain_t0.as_secs();
        if self.cfg.fixes.drain && topo {
            // Topological-sort drain: no counter convergence reduce. The
            // ranks are ordered by their round cursor in the pending
            // collective (deepest first) and the wave schedule ships down
            // the plane as one bounded object — per-hop cost, flat in the
            // fan-in where the counter reduce pays O(ranks) at the root.
            let cursors: Vec<u32> = pending_collective
                .as_ref()
                .map(|c| c.cursor.clone())
                .unwrap_or_default();
            let t_topo0 = t.as_secs();
            let (waves, pio) = self.coord.topo_drain(&cursors, t)?;
            absorb_phase(&mut report, pio);
            report.topo_waves = waves;
            t = t.after(pio.secs);
            for tt in &mut self.times {
                *tt = t;
            }
            drain_secs += pio.secs;
            prev = tr
                .record(
                    Span::new("drain.topo", Lane::Ctrl, t_topo0, t.as_secs())
                        .gen(gen)
                        .dep_opt(prev)
                        .attr("waves", waves),
                )
                .or(prev);
        } else if self.cfg.fixes.drain {
            // The paper's convergence test over the plane: Σsent == Σrecv,
            // with the counters summed up the tree — the root sees one
            // aggregate per child, never one row per rank.
            let counts: Vec<(u64, u64)> = self
                .world
                .counters
                .iter()
                .map(|c| (c.sent_bytes, c.recv_bytes))
                .collect();
            let t_red0 = t.as_secs();
            let (balanced, pio) = self.coord.drain_reduce(&counts, t)?;
            absorb_phase(&mut report, pio);
            if !balanced {
                // Should be impossible with the drain fix on.
                return Err(CkptFailure::LostMessages(usize::MAX));
            }
            t = t.after(pio.secs);
            for tt in &mut self.times {
                *tt = t;
            }
            drain_secs += pio.secs;
            prev = tr
                .record(
                    Span::new("drain.reduce", Lane::Ctrl, t_red0, t.as_secs())
                        .gen(gen)
                        .dep_opt(prev),
                )
                .or(prev);
        }
        report.drain_secs = drain_secs;

        // Phase 4: GNI quiescence wait, then the all-clear over the plane.
        if let Some(end) = self.world.fabric.quiescence_end(t) {
            report.quiesce_secs = end.as_secs() - t.as_secs();
            prev = tr
                .record(
                    Span::new("quiesce.fabric", Lane::Phase, t.as_secs(), end.as_secs())
                        .gen(gen)
                        .dep_opt(prev),
                )
                .or(prev);
            t = end;
            for tt in &mut self.times {
                *tt = t;
            }
        }
        let t_q0 = t.as_secs();
        let pio = self.coord.phase_exchange(Phase::Quiesce, t)?;
        absorb_phase(&mut report, pio);
        report.quiesce_secs += pio.secs;
        t = t.after(pio.secs);
        prev = tr
            .record(
                Span::new("quiesce", Lane::Ctrl, t_q0, t.as_secs())
                    .gen(gen)
                    .dep_opt(prev),
            )
            .or(prev);

        // Phase 5: WRITE the image wave. Incremental mode: once a full
        // image exists, write only dirty regions (ParentRef the rest) to a
        // side file; the manifest tracks which file is current per rank.
        // Staged mode: the wave lands on the fast tier only (that is the
        // whole stall) and is queued for the async Drain-to-PFS phase.
        for r in 0..self.cfg.ranks {
            self.coord
                .set_rank_state(RankId(r), RankState::Writing, false);
        }
        let t_w0 = t.as_secs();
        let write_pio = self.coord.phase_exchange(Phase::Write, t)?;
        absorb_phase(&mut report, write_pio);
        let ack_up = (write_pio.secs - write_pio.down_secs).max(0.0);
        if pipelined {
            // Only the broadcast's down-sweep gates the wave; the ack
            // reduce climbs back up while the ranks are already writing,
            // so its cost is settled against the stall after the wave.
            t = t.after(write_pio.down_secs);
        } else {
            t = t.after(write_pio.secs);
        }
        // Virtual instant the write wave opens (and, pipelined, the ack
        // up-sweep starts climbing concurrently with it).
        let t_wave = t.as_secs();
        let (wctrl_id, ack_id) = if pipelined {
            let bcast = tr.record(
                Span::new("write.bcast", Lane::Ctrl, t_w0, t_wave)
                    .gen(gen)
                    .dep_opt(prev),
            );
            let ack = tr.record(
                Span::new("write.ack", Lane::Ctrl, t_wave, t_wave + ack_up)
                    .gen(gen)
                    .dep_opt(bcast),
            );
            (bcast, ack)
        } else {
            (
                tr.record(
                    Span::new("write.ctrl", Lane::Ctrl, t_w0, t_wave)
                        .gen(gen)
                        .dep_opt(prev),
                ),
                None,
            )
        };
        let incremental = self.cfg.incremental
            && (self.last_full_gen.is_some()
                || (self.cfg.staging.is_none()
                    && self.fs.exists(&image_path(&self.cfg.job, RankId(0)))));
        let staged = self.cfg.staging.is_some();
        // Build the per-rank jobs (paths + the wrapper drain buffer and
        // communicator-log pseudo-regions), then fan the per-rank
        // capture→encode→recipe pipeline across the data-path workers.
        // The encoder streams straight out of each rank's live region
        // table (no payload clones, no intermediate whole-image buffer);
        // in staged mode it also emits the content-addressed chunk recipe
        // the dedup-aware drain consumes. Clean regions replay memoized
        // section digests instead of re-hashing. The wave comes back in
        // rank order, byte-for-byte the serial wave.
        let mut jobs = Vec::with_capacity(self.cfg.ranks as usize);
        for r in 0..self.cfg.ranks {
            let rank = RankId(r);
            let path = if incremental {
                self.incr_path(rank)
            } else {
                self.full_path(rank)
            };
            let parent = incremental.then(|| self.parent_path(rank));
            let mut extra_regions = Vec::with_capacity(2);
            let buf = self.wrappers.encode_buffers(rank);
            extra_regions.push(SavedRegion {
                addr: MSG_BUFFER_BASE + (r as u64) * 0x1000_0000,
                vlen: buf.len() as u64,
                name: "mana.msg_buffer".into(),
                payload: SavedPayload::Full(Payload::Real(buf)),
            });
            // Rank 0 carries the communicator record-and-replay log.
            if r == 0 {
                let log = self.comms.encode_log();
                extra_regions.push(SavedRegion {
                    addr: COMM_LOG_ADDR,
                    vlen: log.len() as u64,
                    name: "mana.comm_log".into(),
                    payload: SavedPayload::Full(Payload::Real(log)),
                });
            }
            jobs.push(datapath::RankJob {
                rank,
                node: self.topo.node_of(rank),
                path,
                parent,
                extra_regions,
            });
        }
        let mut sources: Vec<datapath::RankSource<'_>> = self
            .procs
            .iter_mut()
            .map(|p| datapath::RankSource {
                step: p.step,
                rng_state: p.rng.state_bytes(),
                upper_fds: p.fds.fds_of(crate::mem::Half::Upper),
                table: &mut p.aspace.table,
            })
            .collect();
        let opts = datapath::EncodeOpts {
            chunking: self.cfg.chunking_strategy(),
            threads: datapath::resolve_threads(self.cfg.encode_threads),
            with_recipe: staged,
        };
        // The encoders deliver finished ranks in completion order over a
        // bounded channel; each delivery is tagged with its wave index and
        // costed for the stall model. Virtual time is charged from the
        // *model* (deterministic), never from host completion order, so
        // the report is reproducible across machines and schedules.
        let n_jobs = jobs.len();
        let mut costs = vec![pipeline::EncodeCost::default(); n_jobs];
        let mut tagged: Vec<(usize, WriteReq)> = Vec::with_capacity(n_jobs);
        let dstats = datapath::encode_wave_streaming(&mut sources, &jobs, &opts, &mut |enc| {
            costs[enc.index] = pipeline::EncodeCost {
                hash_vbytes: enc.stats.fresh_hash_vbytes,
                copy_bytes: enc.req.data.len() as u64,
            };
            tagged.push((enc.index, enc.req));
        });
        drop(sources);
        let total_virtual: u64 = tagged.iter().map(|(_, q)| q.virtual_bytes).sum();
        let mut weights = vec![0u64; n_jobs];
        for (i, q) in &tagged {
            weights[*i] = q.virtual_bytes;
        }
        report.encode_host_secs = dstats.host_secs;
        report.encode_threads = dstats.threads as u32;
        report.digest_cache_hit_bytes = dstats.cache_hit_bytes;
        report.fresh_hash_bytes = dstats.fresh_hash_bytes;
        report.cache_partial_regions = dstats.cache_partial_regions;
        let io = match &mut self.fs {
            Store::Single(fs) => {
                // Single-tier stores model one aggregate wave; admission
                // order does not change its duration, so both paths hand
                // over the wave in rank order.
                tagged.sort_by_key(|(i, _)| *i);
                let reqs: Vec<WriteReq> = tagged.into_iter().map(|(_, q)| q).collect();
                let io = match fs.write_parallel(reqs) {
                    Ok(io) => io,
                    Err(e @ FsError::InsufficientSpace { .. }) => {
                        return Err(CkptFailure::DiskFull(e.to_string()));
                    }
                    Err(e) => return Err(CkptFailure::DiskFull(e.to_string())),
                };
                match fs.cfg.kind {
                    FsKind::BurstBuffer => {
                        report.fast_write_secs = io.duration;
                        report.fast_bytes = io.total_virtual_bytes;
                    }
                    FsKind::Lustre => {
                        report.durable_write_secs = io.duration;
                        report.durable_bytes = io.total_virtual_bytes;
                    }
                }
                io
            }
            Store::Tiered(ts) => {
                ts.begin_ckpt(t.as_secs());
                let sio = if pipelined {
                    // Streamed admission: ranks enter the wave as their
                    // encodes finish. The tier re-anchors the manifest
                    // order internally, so the stored generation is
                    // bitwise the rank-order wave.
                    match ts.write_wave_unordered(tagged) {
                        Ok(sio) => sio,
                        Err(e) => return Err(CkptFailure::DiskFull(e.to_string())),
                    }
                } else {
                    tagged.sort_by_key(|(i, _)| *i);
                    let reqs: Vec<WriteReq> = tagged.into_iter().map(|(_, q)| q).collect();
                    match ts.write_wave(reqs) {
                        Ok(sio) => sio,
                        Err(e) => return Err(CkptFailure::DiskFull(e.to_string())),
                    }
                };
                report.fast_write_secs = sio.fast_secs;
                report.fast_bytes = sio.fast_bytes;
                report.durable_write_secs = sio.backpressure_secs;
                report.durable_bytes = sio.durable_bytes;
                report.deduped_bytes = sio.deduped_bytes;
                sio.io()
            }
        };
        report.write_secs = io.duration;
        report.image_bytes = total_virtual;
        // Charge the stall from the model: serial pays encode-then-write;
        // pipelined pays the streamed-admission stall, clamped into
        // [max(encode, write), encode + write]. The WRITE ack reduce's
        // up-sweep also hides under the pipelined stall.
        let plan = pipeline::plan(&costs, &weights, dstats.threads.max(1), io.duration);
        report.encode_stall_secs = plan.encode_secs;
        // Early drain admission: resolve the wave's per-file ready stamps
        // against its position on the virtual timeline (the same placement
        // the trace uses) — each file may start draining the moment its
        // own fast-tier write lands, not when the whole stall ends.
        if let Store::Tiered(ts) = &mut self.fs {
            let wave_t0 = if pipelined {
                t_wave
            } else {
                t_wave + plan.encode_secs
            };
            ts.admit_wave(wave_t0 + io.duration);
        }
        if pipelined {
            report.stall_secs = plan.pipelined_stall;
            report.overlap_saved_secs += plan.overlap_saved();
            let hidden = ack_up.min(plan.pipelined_stall);
            report.overlap_saved_secs += hidden;
            t = t.after(plan.pipelined_stall + (ack_up - hidden));
        } else {
            report.stall_secs = plan.serial_stall;
            t = t.after(plan.serial_stall);
        }
        for tt in &mut self.times {
            *tt = t;
        }

        // Trace the data path: per-rank encode slots and the write-queue
        // service timeline come from the same deterministic schedule that
        // charged the stall, so spans and report agree to within a few
        // ulps of float re-association (absorbed by RECONCILE_EPS).
        let mut wtail: Vec<SpanId> = Vec::new();
        if tr.spans_on() {
            let sched =
                pipeline::schedule(&costs, &weights, dstats.threads.max(1), io.duration);
            let mut enc_ids: Vec<Option<SpanId>> = vec![None; n_jobs];
            let mut enc_last: Option<SpanId> = None;
            let mut enc_end = f64::NEG_INFINITY;
            for (i, &(s, f)) in sched.encode.iter().enumerate() {
                let rank = RankId(i as u32);
                let id = tr.record(
                    Span::new("encode", Lane::Encode, t_wave + s, t_wave + f)
                        .gen(gen)
                        .rank(i as u32)
                        .node(self.topo.node_of(rank).0)
                        .dep_opt(wctrl_id),
                );
                enc_ids[i] = id;
                if f >= enc_end {
                    enc_end = f;
                    enc_last = id;
                }
            }
            // Serial mode, the wave only opens once every encode is done.
            let wave_t0 = if pipelined {
                t_wave
            } else {
                t_wave + plan.encode_secs
            };
            let wave_id = tr.record(
                Span::new("write.wave", Lane::Storage, wave_t0, wave_t0 + io.duration)
                    .gen(gen)
                    .dep_opt(if pipelined { wctrl_id } else { enc_last })
                    .attr("bytes", total_virtual),
            );
            if staged {
                let _ = tr.record(
                    Span::new(
                        "write.wave.fast",
                        Lane::Storage,
                        wave_t0,
                        wave_t0 + report.fast_write_secs,
                    )
                    .gen(gen)
                    .dep_opt(wave_id),
                );
                if report.durable_write_secs > 0.0 {
                    let _ = tr.record(
                        Span::new(
                            "write.wave.backpressure",
                            Lane::Storage,
                            wave_t0 + report.fast_write_secs,
                            wave_t0 + report.fast_write_secs + report.durable_write_secs,
                        )
                        .gen(gen)
                        .dep_opt(wave_id),
                    );
                }
            } else if report.durable_write_secs > 0.0 {
                let _ = tr.record(
                    Span::new("write.wave.durable", Lane::Storage, wave_t0, wave_t0 + io.duration)
                        .gen(gen)
                        .dep_opt(wave_id),
                );
            } else {
                let _ = tr.record(
                    Span::new("write.wave.fast", Lane::Storage, wave_t0, wave_t0 + io.duration)
                        .gen(gen)
                        .dep_opt(wave_id),
                );
            }
            let stall_dep = if pipelined {
                // Write-queue service slots in admission order; the last
                // slot's end snaps onto the stall envelope's clamp so the
                // queue timeline and the charged stall meet exactly.
                let mut q_prev = wctrl_id;
                let n_srv = sched.service.len();
                for (j, &(ri, s, e)) in sched.service.iter().enumerate() {
                    let t1 = if j + 1 == n_srv {
                        t_wave + plan.pipelined_stall
                    } else {
                        t_wave + e
                    };
                    q_prev = tr
                        .record(
                            Span::new("write.q", Lane::WriteQueue, t_wave + s, t1)
                                .gen(gen)
                                .rank(ri as u32)
                                .dep_opt(enc_ids[ri])
                                .dep_opt(q_prev),
                        )
                        .or(q_prev);
                }
                q_prev
            } else {
                wave_id
            };
            let stall_id = tr.record(
                Span::new("write.stall", Lane::Phase, t_wave, t_wave + report.stall_secs)
                    .gen(gen)
                    .dep_opt(stall_dep)
                    .dep_opt(if pipelined { enc_last } else { None }),
            );
            wtail = ack_id.into_iter().chain(stall_id).collect();
        }

        // Full checkpoints reset the dirty tracking (incrementals are
        // always relative to the last FULL image, so they keep the bits).
        if !incremental {
            for p in &mut self.procs {
                p.aspace.table.clear_dirty(crate::mem::Half::Upper);
            }
        }

        // The restart manifest rides the same storage tier (and, in staged
        // mode, joins the drain queue so it goes durable with its images).
        let mut manifest = CkptManifest::new(&self.cfg.job, self.step);
        manifest.gen = self.ckpt_gen;
        manifest.chunk_bytes = self.cfg.chunk_bytes as u64;
        // Record the boundary strategy (mode + derived CDC parameters):
        // restart must keep writing with the boundaries this set's chunk
        // index was built from, or dedup collapses across the restart.
        manifest.chunking = Some(self.cfg.chunking_strategy());
        // Collective-aware drain: stamp the strategy, and — topo only —
        // the interrupted collective's record (kind, schedule, per-rank
        // round cursors) so restart resumes the op from the recorded
        // round instead of replaying it.
        manifest.drain_strategy = Some(self.cfg.drain_strategy);
        manifest.collective = pending_collective;
        manifest.full_gen = if incremental {
            self.last_full_gen
        } else {
            Some(self.ckpt_gen)
        };
        for r in 0..self.cfg.ranks {
            let rank = RankId(r);
            let path = if incremental {
                self.incr_path(rank)
            } else {
                self.full_path(rank)
            };
            manifest.add(rank, path);
        }
        if self.cfg.redundancy != RedundancyScheme::None {
            manifest.redundancy = Some((self.cfg.redundancy, self.cfg.redundancy_set_size));
        }
        let mdata = manifest.encode();
        let mreq = WriteReq {
            node: self.topo.node_of(RankId(0)),
            path: CkptManifest::manifest_path(&self.cfg.job),
            virtual_bytes: mdata.len() as u64,
            data: mdata,
            // The manifest changes every generation (step/gen stamps), so
            // it stages byte-for-byte rather than through the chunk store.
            recipe: None,
        };
        match &mut self.fs {
            Store::Single(fs) => {
                fs.write_parallel(vec![mreq])
                    .map_err(|e| CkptFailure::DiskFull(e.to_string()))?;
            }
            Store::Tiered(ts) => {
                // The manifest is tiny, but its wave can still trigger
                // eviction backpressure on a packed fast tier — that is
                // synchronous work the ranks must wait out.
                let msio = ts
                    .write_wave(vec![mreq])
                    .map_err(|e| CkptFailure::DiskFull(e.to_string()))?;
                if msio.backpressure_secs > 0.0 {
                    report.durable_write_secs += msio.backpressure_secs;
                    report.durable_bytes += msio.durable_bytes;
                    report.write_secs += msio.backpressure_secs;
                    let tm0 = t.as_secs();
                    t = t.after(msio.backpressure_secs);
                    for tt in &mut self.times {
                        *tt = t;
                    }
                    if let Some(id) = tr.record(
                        Span::new("write.manifest", Lane::Storage, tm0, t.as_secs())
                            .gen(gen)
                            .deps(&wtail),
                    ) {
                        wtail = vec![id];
                    }
                }
                // The manifest's wave lands here on the timeline (its BB
                // write hides under the rank stall already charged).
                ts.admit_wave(t.as_secs());
                // Redundancy exchange: after the manifest wave, so the
                // manifest itself is in the generation's protected set. The
                // exchange pipelines behind the BB write wave — only the
                // residual (fill one chunk, plus whatever the fabric could
                // not hide under the wave) lands on the rank critical path.
                report.redundancy_scheme = ts.redundancy().scheme;
                if ts.redundancy().active() {
                    let fabric = Self::make_fabric(&self.cfg);
                    let ex = ts.exchange_wave(&fabric, report.fast_write_secs);
                    report.exchange_secs = ex.exchange_secs;
                    report.parity_bytes = ex.parity_bytes;
                    report.write_secs += ex.exchange_secs;
                    let tx0 = t.as_secs();
                    t = t.after(ex.exchange_secs);
                    for tt in &mut self.times {
                        *tt = t;
                    }
                    if let Some(id) = tr.record(
                        Span::new("write.exchange", Lane::Exchange, tx0, t.as_secs())
                            .gen(gen)
                            .deps(&wtail)
                            .attr("parity_bytes", ex.parity_bytes),
                    ) {
                        wtail = vec![id];
                    }
                }
            }
        }
        if !incremental {
            self.last_full_gen = Some(self.ckpt_gen);
        }
        self.ckpt_gen += 1;

        // Phase 6: RESUME — in staged mode, into the async Drain-to-PFS
        // phase: ranks compute again while their images go durable.
        let t_r0 = t.as_secs();
        let pio = self.coord.phase_exchange(Phase::Resume, t)?;
        absorb_phase(&mut report, pio);
        report.resume_secs = pio.secs;
        t = t.after(pio.secs);
        let _ = tr.record(
            Span::new("resume", Lane::Ctrl, t_r0, t.as_secs())
                .gen(gen)
                .deps(&wtail),
        );
        let pending = self.fs.tiered().map_or(0, |ts| ts.pending_bytes());
        report.drain_pending_bytes = pending;
        // A fully-deduped generation can have zero pending *bytes* while
        // its recipe commits are still queued — gate the phase on files.
        let pending_files = self.fs.tiered().map_or(0, |ts| ts.pending_files());
        let resumed_state = if pending_files > 0 {
            RankState::Draining
        } else {
            RankState::Resumed
        };
        for r in 0..self.cfg.ranks {
            self.coord.set_rank_state(RankId(r), resumed_state, false);
        }
        for tt in &mut self.times {
            *tt = t;
        }
        // The background drain's budget starts at resume time.
        if let Store::Tiered(ts) = &mut self.fs {
            ts.sync_clock(t.as_secs());
        }

        self.coord.stats.checkpoints += 1;
        self.coord.stats.drain_rounds += report.drain_rounds as u64;
        self.coord.stats.buffered_msgs += report.buffered_msgs as u64;
        self.coord.stats.deduped_bytes += report.deduped_bytes;
        report.total_secs = t.as_secs() - t0.as_secs();
        let _ = tr.record(
            Span::new("ckpt", Lane::Phase, t0.as_secs(), t.as_secs())
                .gen(gen)
                .attr("ranks", self.cfg.ranks)
                .attr("pipelined", pipelined),
        );
        // Reconcile the report against its own trace; a mismatch is an
        // accounting bug and surfaces as a structured error event.
        if tr.spans_on() {
            for m in trace::reconcile(&tr.spans(), gen, &report) {
                tr.error(
                    "trace",
                    format!("trace.reconcile:g{gen}"),
                    EventCtx::default().with_gen(gen),
                    m,
                );
            }
        }
        self.metrics.inc("checkpoints", 1);
        self.metrics.observe("ckpt.total_secs", report.total_secs);
        self.metrics.observe("ckpt.write_secs", report.write_secs);
        self.metrics
            .observe("ckpt.encode_host_secs", report.encode_host_secs);
        self.metrics
            .observe("ckpt.fast_write_secs", report.fast_write_secs);
        self.metrics
            .observe("ckpt.image_bytes", report.image_bytes as f64);
        self.metrics
            .inc("ckpt.buffered_msgs", report.buffered_msgs as u64);
        self.metrics
            .inc("ckpt.deduped_bytes", report.deduped_bytes);
        log_info!(
            "coordinator",
            "checkpoint {} at step {}: {} in {:.2}s (drain {:.3}s, write {:.2}s{}{})",
            self.cfg.job,
            self.step,
            crate::util::bytes::human(report.image_bytes),
            report.total_secs,
            report.drain_secs,
            report.write_secs,
            if report.drain_pending_bytes > 0 {
                format!(
                    ", {} staging to PFS in the background",
                    crate::util::bytes::human(report.drain_pending_bytes)
                )
            } else {
                String::new()
            },
            if report.deduped_bytes > 0 {
                format!(
                    ", {} deduped ({:.0}%)",
                    crate::util::bytes::human(report.deduped_bytes),
                    report.dedup_ratio() * 100.0
                )
            } else {
                String::new()
            }
        );
        Ok(report)
    }

    // ------------------------------------------------------ kill / restart

    /// Kill the job (scheduler preemption / walltime / failure). The
    /// storage tier survives; everything else dies with the processes.
    pub fn kill(self) -> Store {
        log_info!(
            "sim",
            "job {} killed at step {} (storage: {})",
            self.cfg.job,
            self.step,
            self.fs.describe()
        );
        self.fs
    }

    /// Restart a job from its checkpoint set on `fs`. In staged mode the
    /// newest valid image is located on *either* tier: reads prefer the
    /// fast tier per file and fall back to the durable tier, including on
    /// CRC failure of a fast-tier copy.
    pub fn restart_from(
        mut cfg: RunConfig,
        engine: Option<Arc<Engine>>,
        mut fs: Store,
    ) -> Result<(JobSim, RestartReport), RestartError> {
        let topo = Topology::new(cfg.ranks, cfg.threads_per_rank);
        let mut report = RestartReport::default();
        // The tracer goes onto the store before the loss/rebuild pass so
        // restart-time fault events land in the job's event log.
        let tracer = Tracer::new(cfg.trace);
        tracer.set_job(&cfg.job);
        fs.set_tracer(tracer.clone());

        // Staged mode: reload + verify the persisted durable-tier chunk
        // index before any recipe-backed read — durable-only restart must
        // not depend on the in-memory index having survived the kill.
        if let Store::Tiered(ts) = &mut fs {
            ts.reload_index()
                .map_err(|e| RestartError::Fs(e.to_string()))?;
            // Future checkpoints of the resumed job keep the configured
            // scheme; the rebuild below works off the per-generation
            // exchange records, which carry their own.
            ts.set_redundancy(RedundancyConfig::new(
                cfg.redundancy,
                cfg.redundancy_set_size,
            ));
            // Fast-tier losses in a restart's fault plan happened while
            // the job was down — all of them fire before the rebuild pass
            // surveys what survived.
            for (node, _) in &cfg.faults.bb_node_loss {
                ts.lose_node_now(*node);
            }
            for (set, _) in &cfg.faults.bb_set_loss {
                ts.lose_set_now(*set);
            }
            // Peer rebuild: restore lost fast-tier images from partner
            // copies / XOR parity before any read goes looking for them.
            // The restart preference order is fast -> peer rebuild ->
            // durable -> older generation; this step never touches the
            // durable tier.
            let fabric = Self::make_fabric(&cfg);
            let rb = ts.rebuild_missing(&fabric);
            report.rebuilt_nodes = rb.rebuilt_nodes;
            report.rebuilt_files = rb.rebuilt_files;
            report.rebuild_secs = rb.rebuild_secs;
        }

        // srun with the restart argv — the packet-limit crash lives here.
        let argv = launcher::restart_argv(&cfg.job, cfg.ranks, cfg.fixes.manifest_filenames);
        let launch = launcher::launch(&topo, cfg.link, &argv).map_err(RestartError::Launch)?;
        report.startup_secs = launch.startup_secs;

        // Resolve image paths (manifest fix reads one file; legacy argv
        // carried them directly). Staged checkpoints stamp paths with a
        // generation, so they are only reachable through the manifest.
        let mut ckpt_gen = 0u64;
        let mut last_full_gen = None;
        // Topo-drain checkpoints land inside a collective; the manifest
        // carries its record so the resumed job can finish the op from
        // each rank's recorded round cursor.
        let mut restored_collective: Option<InflightCollective> = None;
        let paths: Vec<(NodeId, String)> = if cfg.fixes.manifest_filenames {
            let (datas, _) = fs
                .read_parallel(&[(
                    topo.node_of(RankId(0)),
                    CkptManifest::manifest_path(&cfg.job),
                )])
                .map_err(|e| RestartError::Fs(e.to_string()))?;
            let manifest = CkptManifest::decode(&datas[0])
                .ok_or_else(|| RestartError::Fs("bad manifest".into()))?;
            ckpt_gen = manifest.gen + 1;
            last_full_gen = manifest.full_gen;
            restored_collective = manifest.collective.clone();
            // Keep the dedup granularity the checkpoint set was written
            // with: mixing chunk sizes across a job's lifetime would stop
            // unchanged regions from deduping against older generations.
            // Validated like --chunk-bytes (the manifest is plain text
            // with no CRC — a corrupt value must not poison the encoder).
            let mb = manifest.chunk_bytes as usize;
            if mb > 0 && mb != cfg.chunk_bytes {
                if mb.is_power_of_two() && mb <= crate::ckpt::chunk::MAX_CHUNK_BYTES {
                    log_info!(
                        "sim",
                        "restart {}: adopting manifest chunk granularity {} (cfg had {})",
                        cfg.job,
                        crate::util::bytes::human(mb as u64),
                        crate::util::bytes::human(cfg.chunk_bytes as u64)
                    );
                    cfg.chunk_bytes = mb;
                } else {
                    tracer.warn(
                        "sim",
                        "restart.bad_manifest_chunk",
                        EventCtx::default(),
                        format!(
                            "restart {}: ignoring invalid manifest chunk granularity {}",
                            cfg.job, manifest.chunk_bytes
                        ),
                    );
                }
            }
            // Adopt the writer's chunk-boundary strategy the same way: a
            // config defaulting to `fixed` must not re-tile a CDC-written
            // set (or vice versa) — the durable chunk index was built on
            // the writer's boundaries, and later generations only dedup
            // against it if restart keeps cutting the same way. Validated
            // like --chunk-bytes: the manifest is plain text with no CRC,
            // so a corrupt value must not poison the encoder.
            if manifest.chunking.is_none()
                && cfg.chunking != crate::config::ChunkingMode::Fixed
            {
                // Pre-CDC manifest: the set was written by a build that
                // only knew fixed tiling. A cdc-configured restart must
                // not re-tile against its fixed-grid chunk index.
                log_info!(
                    "sim",
                    "restart {}: manifest predates content-defined chunking; \
                     forcing fixed tiling",
                    cfg.job
                );
                cfg.chunking = crate::config::ChunkingMode::Fixed;
            }
            if let Some(mc) = manifest.chunking {
                let want = cfg.chunking_strategy();
                if mc != want {
                    let avg = mc.avg_bytes();
                    if mc.is_valid() && avg.is_power_of_two() {
                        log_info!(
                            "sim",
                            "restart {}: adopting manifest chunking {} (cfg had {})",
                            cfg.job,
                            mc.describe(),
                            want.describe()
                        );
                        cfg.chunk_bytes = avg;
                        cfg.chunking = match mc {
                            crate::ckpt::chunk::Chunking::Fixed(_) => {
                                crate::config::ChunkingMode::Fixed
                            }
                            crate::ckpt::chunk::Chunking::Cdc(_) => {
                                crate::config::ChunkingMode::Cdc
                            }
                        };
                        // Parameters are re-derived from the average; a
                        // manifest carrying a non-canonical triple is
                        // honored in mode and granularity but normalized.
                        if cfg.chunking_strategy() != mc {
                            tracer.warn(
                                "sim",
                                "restart.noncanonical_cdc",
                                EventCtx::default(),
                                format!(
                                    "restart {}: manifest CDC parameters were \
                                     non-canonical; normalized to {}",
                                    cfg.job,
                                    cfg.chunking_strategy().describe()
                                ),
                            );
                        }
                    } else {
                        tracer.warn(
                            "sim",
                            "restart.bad_manifest_chunking",
                            EventCtx::default(),
                            format!(
                                "restart {}: ignoring invalid manifest chunking {}",
                                cfg.job,
                                mc.describe()
                            ),
                        );
                    }
                }
            }
            // Adopt the writer's redundancy scheme when the restart config
            // leaves it unset, so a resumed job keeps protecting its
            // checkpoints the way the surviving set was written. An
            // explicit config wins (the per-generation exchange records
            // keep their own scheme either way).
            if let Some((scheme, size)) = manifest.redundancy {
                if cfg.redundancy == RedundancyScheme::None
                    && scheme != RedundancyScheme::None
                {
                    log_info!(
                        "sim",
                        "restart {}: adopting manifest redundancy {scheme}/{size}",
                        cfg.job
                    );
                    cfg.redundancy = scheme;
                    cfg.redundancy_set_size = size;
                    if let Store::Tiered(ts) = &mut fs {
                        ts.set_redundancy(RedundancyConfig::new(scheme, size));
                    }
                }
            }
            (0..cfg.ranks)
                .map(|r| {
                    let rank = RankId(r);
                    (
                        topo.node_of(rank),
                        manifest
                            .path_for(rank)
                            .unwrap_or(&image_path(&cfg.job, rank))
                            .to_string(),
                    )
                })
                .collect()
        } else {
            if cfg.staging.is_some() {
                return Err(RestartError::Fs(
                    "staged restart requires the manifest-filenames fix".into(),
                ));
            }
            (0..cfg.ranks)
                .map(|r| (topo.node_of(RankId(r)), image_path(&cfg.job, RankId(r))))
                .collect()
        };

        // Injected image corruption (targets the resolved image path).
        if let Some((rank, offset)) = cfg.faults.image_bitflip {
            if let Some((_, path)) = paths.get(rank as usize) {
                fs.corrupt_byte(path, offset);
            }
        }

        // Load the newest generation; if it is unrecoverable on *every*
        // tier, walk back to the newest older generation that still fully
        // decodes — SCR's `complete_restart(valid)` rewind. Only full
        // (gen-stamped) image sets are candidates, so a rewound restart
        // never resumes from a parentless incremental.
        let images = match load_generation(&mut fs, &topo, &cfg, &paths, &mut report) {
            Ok(imgs) => imgs,
            Err(first_err) => {
                let newest = ckpt_gen.saturating_sub(1);
                let mut found = None;
                if cfg.staging.is_some() && cfg.fixes.manifest_filenames {
                    for g in (0..newest).rev() {
                        let pg: Vec<(NodeId, String)> = (0..cfg.ranks)
                            .map(|r| {
                                let rank = RankId(r);
                                (topo.node_of(rank), gen_image_path(&cfg.job, g, rank))
                            })
                            .collect();
                        if let Ok(imgs) =
                            load_generation(&mut fs, &topo, &cfg, &pg, &mut report)
                        {
                            report.generation_rewound = newest - g;
                            ckpt_gen = g + 1;
                            // The rewound set is a full checkpoint; newer
                            // parents are not to be trusted.
                            last_full_gen = Some(g);
                            tracer.error(
                                "sim",
                                "restart.gen_rewind",
                                EventCtx::default().with_gen(g),
                                format!(
                                    "restart {}: generation {newest} unrecoverable on \
                                     every tier — rewound {} generation(s) to {g}",
                                    cfg.job, report.generation_rewound
                                ),
                            );
                            found = Some(imgs);
                            break;
                        }
                    }
                }
                match found {
                    Some(imgs) => imgs,
                    None => return Err(first_err),
                }
            }
        };

        let split_cfg = SplitConfig {
            os: cfg.os,
            alloc_policy: cfg.fixes.alloc_policy(),
            fd_policy: cfg.fixes.fd_policy(),
            ..SplitConfig::default()
        };
        let mut procs = Vec::with_capacity(cfg.ranks as usize);
        let mut wrappers = ManaWrappers::new(
            WrapperConfig {
                careful_nonblocking: cfg.fixes.careful_nonblocking,
            },
            cfg.ranks,
        );
        let mut job_step = 0u64;
        let mut comms = CommRegistry::new(cfg.ranks);
        for (r, img) in images.into_iter().enumerate() {
            let rank = RankId(r as u32);
            let mut proc = SplitProcess::restart(&img, split_cfg, cfg.seed)
                .map_err(|e| RestartError::Proc(rank, e.to_string()))?;
            // Re-inflate the drain buffer and drop its pseudo-region.
            if let Some(region) = proc.aspace.table.remove_named("mana.msg_buffer") {
                if let Payload::Real(bytes) = region.payload {
                    wrappers
                        .decode_buffers(rank, &bytes)
                        .ok_or_else(|| {
                            RestartError::CorruptImage(
                                rank,
                                ImageError::Truncated("msg_buffer"),
                            )
                        })?;
                }
            }
            // Rank 0's image carries the communicator log: replay it
            // against the fresh lower-half MPI library.
            if let Some(region) = proc.aspace.table.remove_named("mana.comm_log") {
                if let Payload::Real(bytes) = region.payload {
                    let log = CommRegistry::decode_log(&bytes).ok_or_else(|| {
                        RestartError::CorruptImage(rank, ImageError::Truncated("comm_log"))
                    })?;
                    comms = CommRegistry::replay(cfg.ranks, &log);
                }
            }
            job_step = proc.step;
            procs.push(proc);
        }

        let app = apps::make_app(cfg.app);
        let world = MpiWorld::new(cfg.ranks, Self::make_fabric(&cfg));
        let mut coord = Self::make_coordinator(&cfg, &topo);
        coord.set_tracer(tracer.clone());
        coord.stats.restarts += 1;
        report.total_secs = report.startup_secs + report.read_secs + report.rebuild_secs;
        // Restart timeline spans: rebuild → startup → read, summing to the
        // restart's total (the virtual clock starts at 0 for a fresh job).
        if tracer.spans_on() {
            let rb = tracer.record(
                Span::new("restart.rebuild", Lane::Restart, 0.0, report.rebuild_secs)
                    .attr("files", report.rebuilt_files),
            );
            let st = tracer.record(
                Span::new(
                    "restart.startup",
                    Lane::Restart,
                    report.rebuild_secs,
                    report.rebuild_secs + report.startup_secs,
                )
                .dep_opt(rb),
            );
            let rd = tracer.record(
                Span::new(
                    "restart.read",
                    Lane::Restart,
                    report.rebuild_secs + report.startup_secs,
                    report.total_secs,
                )
                .dep_opt(st)
                .attr("tier_fallbacks", report.tier_fallbacks),
            );
            let _ = tracer.record(
                Span::new("restart", Lane::Restart, 0.0, report.total_secs).dep_opt(rd),
            );
        }
        let t0 = SimTime::secs(report.total_secs);
        // Resume the interrupted collective (topo-drain checkpoint): the
        // schedule is re-anchored on the fresh clock with the recorded
        // per-rank progress preserved; the first superstep's wait then
        // completes it — charging exactly the remaining rounds — before
        // any new communication. Validated like the other manifest fields
        // (plain text, no CRC): a record whose shape does not match the
        // job is dropped with a warning, not trusted.
        if let Some(infl) = restored_collective {
            if infl.size == cfg.ranks
                && infl.cursor.len() == cfg.ranks as usize
                && infl.rounds >= 1
            {
                wrappers.restore_pending_collective(infl, t0);
            } else {
                tracer.warn(
                    "sim",
                    "restart.bad_manifest_collective",
                    EventCtx::default(),
                    format!(
                        "restart {}: ignoring collective record sized for {} ranks \
                         (job has {})",
                        cfg.job, infl.size, cfg.ranks
                    ),
                );
            }
        }
        // The surviving store's drain clock sits on the killed job's
        // timeline; rebase it to the restarted clock so an interrupted
        // background drain resumes instead of waiting for the new clock
        // to catch up with the dead one's.
        if let Store::Tiered(ts) = &mut fs {
            ts.rebase_clock(t0.as_secs());
            if ts.pending_files() > 0 {
                for r in 0..cfg.ranks {
                    coord.set_rank_state(RankId(r), RankState::Draining, false);
                }
            }
        }
        log_info!(
            "sim",
            "restart {}: {} ranks at step {job_step} in {:.2}s (read {:.2}s)",
            cfg.job,
            cfg.ranks,
            report.total_secs,
            report.read_secs
        );
        let times = vec![t0; cfg.ranks as usize];
        Ok((
            JobSim {
                topo,
                app,
                procs,
                world,
                wrappers,
                times,
                fs,
                coord,
                engine,
                comms,
                metrics: {
                    let mut m = crate::metrics::Metrics::new();
                    m.inc("restarts", 1);
                    m.observe("restart.read_secs", report.read_secs);
                    m
                },
                tracer,
                step: job_step,
                lost_halo_events: 0,
                launch_startup_secs: report.startup_secs,
                ckpt_gen,
                last_full_gen,
                lazy: None,
                cfg,
            },
            report,
        ))
    }

    // ------------------------------------------------------------ queries

    /// Global virtual time (slowest rank). Inside a bulk-advance window
    /// the rank clocks are uniform at `t_cur`, so the fold collapses.
    pub fn now(&self) -> SimTime {
        if let Some(w) = &self.lazy {
            return w.t_cur;
        }
        self.times
            .iter()
            .fold(SimTime::ZERO, |a, &t| a.max(t))
    }

    /// Combined checkpointable-state fingerprint (C/R determinism checks).
    /// An observation: closes any open bulk-advance window first.
    pub fn fingerprint(&mut self) -> u64 {
        self.materialize()
            .expect("deferred superstep replay failed");
        let mut h = 0x4d414e41u64; // "MANA"
        for p in &self.procs {
            h = hash_combine(h, p.fingerprint());
        }
        h
    }

    /// Did any rank detect memory/data corruption?
    pub fn any_corruption(&self) -> bool {
        self.procs.iter().any(|p| p.corrupted)
            || self.wrappers.corrupted_sends > 0
            || self.lost_halo_events > 0
    }

    /// Aggregate upper-half memory across ranks (the Fig. 2 blue line).
    pub fn aggregate_memory(&self) -> u64 {
        self.procs.iter().map(|p| p.upper_bytes()).sum()
    }
}

/// Fold one phase exchange's control-plane accounting into the report.
fn absorb_phase(report: &mut CkptReport, io: PhaseIo) {
    report.ctrl_secs += io.secs;
    report.ctrl_msgs += io.msgs;
    report.root_ctrl_msgs += io.root_msgs;
    report.reparents += io.reparents;
}

/// Fold an overlapped phase pair into the report: control seconds are
/// the fused sweep, traffic is the per-phase sum — overlap buys time,
/// never messages.
fn absorb_overlap(report: &mut CkptReport, o: &OverlapIo) {
    report.ctrl_secs += o.secs;
    report.ctrl_msgs += o.first.msgs + o.second.msgs;
    report.root_ctrl_msgs += o.first.root_msgs + o.second.root_msgs;
    report.reparents += o.first.reparents + o.second.reparents;
}

/// Count the reads of `paths` that are about to miss the fast tier and go
/// durable (staged mode). The acceptance telemetry for peer redundancy:
/// a rebuilt restart shows zero of these for the lost node.
fn count_durable_reads(fs: &Store, paths: &[(NodeId, String)], report: &mut RestartReport) {
    if let Store::Tiered(ts) = fs {
        report.durable_read_files += paths
            .iter()
            .filter(|(_, p)| !ts.fast().exists(p))
            .count() as u32;
    }
}

/// Read and decode one generation's images, resolving incremental parents.
/// Reads prefer the fast tier per file; a file that fails validation walks
/// the preference order fast -> peer rebuild -> durable inside
/// [`decode_with_tier_fallback`]. Fails if any rank's image is
/// unrecoverable on every tier (the caller may then rewind a generation).
fn load_generation(
    fs: &mut Store,
    topo: &Topology,
    cfg: &RunConfig,
    paths: &[(NodeId, String)],
    report: &mut RestartReport,
) -> Result<Vec<CkptImage>, RestartError> {
    let fabric = JobSim::make_fabric(cfg);
    count_durable_reads(fs, paths, report);
    let (datas, io) = fs
        .read_parallel(paths)
        .map_err(|e| RestartError::Fs(e.to_string()))?;
    report.read_secs += io.duration;
    let mut images = Vec::with_capacity(paths.len());
    for (r, data) in datas.iter().enumerate() {
        let rank = RankId(r as u32);
        let (node, path) = &paths[r];
        let mut img =
            decode_with_tier_fallback(fs, *node, path, data, rank, &fabric, report)?;
        // Incremental image: pull and resolve its parent full image.
        if let Some(parent_path) = img.parent.clone() {
            let ppaths = [(topo.node_of(rank), parent_path.clone())];
            count_durable_reads(fs, &ppaths, report);
            let (pdatas, _) = fs
                .read_parallel(&ppaths)
                .map_err(|e| RestartError::Fs(e.to_string()))?;
            let parent = decode_with_tier_fallback(
                fs,
                topo.node_of(rank),
                &parent_path,
                &pdatas[0],
                rank,
                &fabric,
                report,
            )?;
            img = crate::ckpt::resolve_incremental(img, parent)
                .map_err(|e| RestartError::CorruptImage(rank, e))?;
        }
        images.push(img);
    }
    Ok(images)
}

/// Decode an image; on CRC/decode failure of a fast-tier copy, mark that
/// copy invalid for the rest of the restart (no per-region re-reads of
/// known-bad data), attempt a peer rebuild of the path, and only then fall
/// back to the durable tier — staged mode's preference order. Charges the
/// extra reads to the report.
fn decode_with_tier_fallback(
    fs: &mut Store,
    node: NodeId,
    path: &str,
    data: &[u8],
    rank: RankId,
    fabric: &Fabric,
    report: &mut RestartReport,
) -> Result<CkptImage, RestartError> {
    let e = match CkptImage::decode(data) {
        Ok(img) => return Ok(img),
        Err(e) => e,
    };
    let Store::Tiered(ts) = fs else {
        return Err(RestartError::CorruptImage(rank, e));
    };
    if !ts.mark_fast_invalid(path) {
        // No fast-tier copy was involved: the failing bytes came from the
        // durable tier (or nowhere), so there is nothing left to try.
        return Err(RestartError::CorruptImage(rank, e));
    }
    ts.tracer().warn(
        "sim",
        format!("restart.crc_fallback:r{}", rank.0),
        EventCtx::rank(rank.0),
        format!(
            "{rank}: fast-tier image {path} failed validation ({e}) — \
             attempting peer rebuild, then the durable tier"
        ),
    );
    // Peer rebuild first: a partner copy or XOR reconstruction restores
    // the invalidated file without touching the durable tier.
    let rb = ts.rebuild_missing(fabric);
    report.rebuilt_nodes += rb.rebuilt_nodes;
    report.rebuilt_files += rb.rebuilt_files;
    report.rebuild_secs += rb.rebuild_secs;
    if ts.fast().exists(path) {
        let (datas, io) = ts
            .read_preferred(&[(node, path.to_string())])
            .map_err(|e2| RestartError::Fs(e2.to_string()))?;
        report.read_secs += io.duration;
        match CkptImage::decode(&datas[0]) {
            Ok(img) => return Ok(img),
            // A rebuilt copy that still fails decode is invalid too.
            Err(_) => {
                ts.mark_fast_invalid(path);
            }
        }
    }
    if ts.is_durable(path) {
        let (datas, io) = ts
            .read_durable(&[(node, path.to_string())])
            .map_err(|e2| RestartError::Fs(e2.to_string()))?;
        report.read_secs += io.duration;
        report.tier_fallbacks += 1;
        report.durable_read_files += 1;
        return CkptImage::decode(&datas[0])
            .map_err(|e2| RestartError::CorruptImage(rank, e2));
    }
    Err(RestartError::CorruptImage(rank, e))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AppKind;

    fn quick_cfg(ranks: u32, steps: u64) -> RunConfig {
        let mut cfg = RunConfig::new(AppKind::Synthetic, ranks);
        cfg.steps = steps;
        cfg.mem_per_rank = Some(1 << 20); // keep tests light
        cfg
    }

    #[test]
    fn run_steps_advances_state_and_time() {
        let mut sim = JobSim::launch(quick_cfg(4, 0), None).unwrap();
        let f0 = sim.fingerprint();
        let t0 = sim.now();
        sim.run_steps(3).unwrap();
        assert_ne!(sim.fingerprint(), f0);
        assert!(sim.now() > t0);
        assert_eq!(sim.step, 3);
        assert!(!sim.any_corruption());
    }

    #[test]
    fn checkpoint_between_steps_succeeds() {
        let mut sim = JobSim::launch(quick_cfg(4, 0), None).unwrap();
        sim.run_steps(2).unwrap();
        let rep = sim.checkpoint().unwrap();
        assert!(rep.total_secs > 0.0);
        assert!(rep.image_bytes > 0);
        // Step-2 halos were in flight: the drain must have buffered them.
        assert!(rep.buffered_msgs > 0, "expected in-flight halos drained");
        assert_eq!(rep.lost_messages, 0);
        assert!(sim.fs.exists("synthetic-4r/ckpt_rank00000.mana"));
    }

    #[test]
    fn ckpt_restart_resumes_bitwise_identical() {
        // Continuous run.
        let mut cont = JobSim::launch(quick_cfg(4, 0), None).unwrap();
        cont.run_steps(6).unwrap();
        let want = cont.fingerprint();

        // Interrupted run: 3 steps, ckpt, kill, restart, 3 more.
        let mut sim = JobSim::launch(quick_cfg(4, 0), None).unwrap();
        sim.run_steps(3).unwrap();
        sim.checkpoint().unwrap();
        let cfg = sim.cfg.clone();
        let fs = sim.kill();
        let (mut resumed, rep) = JobSim::restart_from(cfg, None, fs).unwrap();
        assert_eq!(resumed.step, 3);
        assert!(rep.total_secs > 0.0);
        resumed.run_steps(3).unwrap();
        assert_eq!(
            resumed.fingerprint(),
            want,
            "paper claim: resumed run generates exactly the same results"
        );
        assert!(!resumed.any_corruption());
    }

    #[test]
    fn undrained_checkpoint_loses_messages_and_corrupts_restart() {
        let mut cfg = quick_cfg(4, 0);
        cfg.fixes.drain = false;
        let mut sim = JobSim::launch(cfg, None).unwrap();
        sim.run_steps(3).unwrap();
        let rep = sim.checkpoint().unwrap();
        assert!(rep.lost_messages > 0, "in-flight halos must be dropped");
        let cfg = sim.cfg.clone();
        let fs = sim.kill();
        let (mut resumed, _) = JobSim::restart_from(cfg, None, fs).unwrap();
        resumed.run_steps(2).unwrap();
        assert!(
            resumed.lost_halo_events > 0,
            "lost in-flight messages surface as data loss after restart"
        );
        assert!(resumed.any_corruption());
    }

    #[test]
    fn single_rank_job_has_no_halo_traffic() {
        let mut sim = JobSim::launch(quick_cfg(1, 0), None).unwrap();
        sim.run_steps(4).unwrap();
        assert_eq!(sim.world.total_sent_bytes(), 0);
        let rep = sim.checkpoint().unwrap();
        assert_eq!(rep.buffered_msgs, 0);
    }

    #[test]
    fn incremental_checkpoint_writes_only_dirty_bytes() {
        let mut cfg = quick_cfg(4, 0);
        cfg.incremental = true;
        cfg.mem_per_rank = Some(64 << 20); // 64 MiB heap, tiny live state
        let mut sim = JobSim::launch(cfg, None).unwrap();
        sim.run_steps(1).unwrap();
        let full = sim.checkpoint().unwrap();
        sim.run_steps(1).unwrap();
        let inc = sim.checkpoint().unwrap();
        assert!(
            inc.image_bytes < full.image_bytes / 100,
            "incremental ({}) should be tiny vs full ({})",
            inc.image_bytes,
            full.image_bytes
        );
        assert!(inc.write_secs < full.write_secs);
    }

    #[test]
    fn incremental_restart_is_bitwise_identical() {
        let mut cfg = quick_cfg(4, 0);
        cfg.incremental = true;
        let mut cont = JobSim::launch(cfg.clone(), None).unwrap();
        cont.run_steps(6).unwrap();
        let want = cont.fingerprint();

        let mut sim = JobSim::launch(cfg.clone(), None).unwrap();
        sim.run_steps(2).unwrap();
        sim.checkpoint().unwrap(); // full
        sim.run_steps(2).unwrap();
        sim.checkpoint().unwrap(); // incremental
        let fs = sim.kill();
        let (mut resumed, _) = JobSim::restart_from(cfg, None, fs).unwrap();
        assert_eq!(resumed.step, 4, "must resume from the incremental");
        resumed.run_steps(2).unwrap();
        assert_eq!(resumed.fingerprint(), want);
        assert!(!resumed.any_corruption());
    }

    #[test]
    fn warm_digest_cache_checkpoints_restart_bitwise_identical() {
        // Continuous control run.
        let mut cont = JobSim::launch(quick_cfg(4, 0), None).unwrap();
        cont.run_steps(12).unwrap();
        let want = cont.fingerprint();

        // Three checkpoint generations. Gen 1 populates caches (dropped by
        // its own clear_dirty transitions), gen 2 repopulates them clean,
        // gen 3 must encode the untouched bulk regions from cache — and
        // the image must still restart bitwise-identical.
        let mut sim = JobSim::launch(quick_cfg(4, 0), None).unwrap();
        sim.run_steps(3).unwrap();
        let g1 = sim.checkpoint().unwrap();
        assert_eq!(g1.digest_cache_hit_bytes, 0, "first generation is cold");
        sim.run_steps(3).unwrap();
        sim.checkpoint().unwrap();
        sim.run_steps(3).unwrap();
        let g3 = sim.checkpoint().unwrap();
        assert!(
            g3.digest_cache_hit_bytes > 0,
            "generation 3 must serve clean regions from the digest cache"
        );
        assert!(g3.encode_threads >= 1);
        let cfg = sim.cfg.clone();
        let fs = sim.kill();
        let (mut resumed, _) = JobSim::restart_from(cfg, None, fs).unwrap();
        assert_eq!(resumed.step, 9);
        resumed.run_steps(3).unwrap();
        assert_eq!(
            resumed.fingerprint(),
            want,
            "warm-cache images must restart bitwise-identical"
        );
        assert!(!resumed.any_corruption());
    }

    #[test]
    fn serial_and_parallel_encode_produce_identical_images() {
        // Same job, --encode-threads 1 vs 4: the stored images (and hence
        // the restart fingerprints) must match byte-for-byte.
        let read_wave = |threads: usize| -> (Vec<Vec<u8>>, u64) {
            let mut cfg = quick_cfg(4, 0);
            cfg.encode_threads = Some(threads);
            let mut sim = JobSim::launch(cfg, None).unwrap();
            sim.run_steps(2).unwrap();
            let rep = sim.checkpoint().unwrap();
            assert_eq!(rep.encode_threads, threads as u32);
            let images = (0..4)
                .map(|r| {
                    sim.fs
                        .read_parallel(&[(
                            sim.topo.node_of(RankId(r)),
                            image_path(&sim.cfg.job, RankId(r)),
                        )])
                        .unwrap()
                        .0
                        .remove(0)
                })
                .collect();
            (images, rep.image_bytes)
        };
        let (serial, sbytes) = read_wave(1);
        let (parallel, pbytes) = read_wave(4);
        assert_eq!(sbytes, pbytes);
        for (r, (a, b)) in serial.iter().zip(&parallel).enumerate() {
            assert_eq!(a, b, "rank {r}: parallel image differs from serial");
        }
    }

    #[test]
    fn metrics_record_steps_and_checkpoints() {
        let mut sim = JobSim::launch(quick_cfg(4, 0), None).unwrap();
        sim.run_steps(3).unwrap();
        sim.checkpoint().unwrap();
        assert_eq!(sim.metrics.counter("supersteps"), 3);
        assert_eq!(sim.metrics.counter("checkpoints"), 1);
        let s = sim.metrics.summary("ckpt.total_secs");
        assert_eq!(s.count, 1);
        assert!(s.mean() > 0.0);
        let snap = sim.metrics.snapshot().to_string();
        assert!(snap.contains("\"supersteps\":3"), "{snap}");
    }

    #[test]
    fn restart_on_different_node_layout_is_identical() {
        // MANA is network/topology-agnostic: the same 8 ranks can restart
        // packed differently (8 threads/rank -> 8 ranks/node vs 32
        // threads/rank -> 2 ranks/node) and still resume bitwise.
        let mut cfg = quick_cfg(8, 0);
        let mut cont = JobSim::launch(cfg.clone(), None).unwrap();
        cont.run_steps(6).unwrap();
        let want = cont.fingerprint();

        let mut sim = JobSim::launch(cfg.clone(), None).unwrap();
        sim.run_steps(3).unwrap();
        sim.checkpoint().unwrap();
        let fs = sim.kill();
        // Restart with a different rank-per-node packing.
        cfg.threads_per_rank = 32;
        let (mut resumed, _) = JobSim::restart_from(cfg, None, fs).unwrap();
        assert_eq!(resumed.topo.ranks_per_node(), 2);
        resumed.run_steps(3).unwrap();
        assert_eq!(resumed.fingerprint(), want);
        assert!(!resumed.any_corruption());
    }

    #[test]
    fn communicators_survive_restart_via_replay() {
        let mut sim = JobSim::launch(quick_cfg(8, 0), None).unwrap();
        let fp = sim.comms.fingerprint();
        assert!(sim.comms.len() >= 3, "WORLD + dup + node comm(s)");
        sim.run_steps(2).unwrap();
        sim.checkpoint().unwrap();
        let cfg = sim.cfg.clone();
        let fs = sim.kill();
        let (resumed, _) = JobSim::restart_from(cfg, None, fs).unwrap();
        assert_eq!(
            resumed.comms.fingerprint(),
            fp,
            "record-and-replay must rebuild an isomorphic communicator set"
        );
    }

    #[test]
    fn aggregate_memory_counts_all_ranks() {
        let sim = JobSim::launch(quick_cfg(8, 0), None).unwrap();
        let agg = sim.aggregate_memory();
        assert!(agg >= 8 * (1 << 20));
    }

    // -------------------------------------------- collective-aware drain

    fn colheavy_cfg(job: &str, ranks: u32) -> RunConfig {
        let mut cfg = RunConfig::new(AppKind::CollectiveHeavy, ranks);
        cfg.steps = 0;
        cfg.mem_per_rank = Some(1 << 20);
        cfg.job = job.into();
        cfg
    }

    #[test]
    fn counter_drain_completes_the_pending_collective_first() {
        let mut sim = JobSim::launch(colheavy_cfg("cd-counter", 8), None).unwrap();
        sim.run_steps(2).unwrap();
        assert!(
            sim.wrappers.pending_collective().is_some(),
            "colheavy leaves an allreduce pending across the boundary"
        );
        let sent_before = sim.world.total_sent_bytes();
        let rep = sim.checkpoint().unwrap();
        assert_eq!(rep.drain_strategy, DrainStrategy::Counter);
        assert_eq!(rep.collectives_interrupted, 1);
        // The trivial barrier charged the op's remaining rounds (its time
        // may hide under the safe-point advance, but never its bytes).
        assert!(sim.world.total_sent_bytes() > sent_before);
        assert!(rep.collective_drain_secs >= 0.0);
        assert_eq!(rep.topo_waves, 0);
        assert!(
            sim.wrappers.pending_collective().is_none(),
            "counter drain completed the op before the image was cut"
        );
    }

    #[test]
    fn topo_manifest_records_and_restores_the_collective() {
        let mut cfg = colheavy_cfg("cd-manifest", 8);
        cfg.drain_strategy = DrainStrategy::Topo;
        let mut sim = JobSim::launch(cfg, None).unwrap();
        sim.run_steps(2).unwrap();
        let saved = sim.wrappers.pending_collective().unwrap().clone();
        let rep = sim.checkpoint().unwrap();
        assert_eq!(rep.drain_strategy, DrainStrategy::Topo);
        assert_eq!(rep.collectives_interrupted, 1);
        assert!(rep.topo_waves >= 2, "stagger spreads the round cursors");
        assert!(
            sim.wrappers.pending_collective().is_some(),
            "topo drain checkpoints inside the op"
        );
        let bytes = match &sim.fs {
            Store::Single(f) => f
                .peek(&CkptManifest::manifest_path(&sim.cfg.job))
                .expect("manifest written")
                .1
                .to_vec(),
            Store::Tiered(_) => unreachable!(),
        };
        let m = CkptManifest::decode(&bytes).unwrap();
        assert_eq!(m.drain_strategy, Some(DrainStrategy::Topo));
        let rec = m.collective.expect("interrupted collective recorded");
        assert_eq!(rec, saved, "progress cursors survive the manifest");
        assert_eq!(rec.cursor.len(), 8);
    }

    #[test]
    fn topo_drain_cr_matches_counter_across_planes() {
        // The acceptance property: for the same collective-heavy job, a
        // counter-drain C/R and a topo-drain C/R — on the flat plane and
        // the sub-coordinator tree — all resume to the same final
        // fingerprint as the uninterrupted run.
        let mut cont = JobSim::launch(colheavy_cfg("cd-cont", 16), None).unwrap();
        cont.run_steps(6).unwrap();
        let want = cont.fingerprint();

        let run = |cfg: RunConfig| {
            let mut sim = JobSim::launch(cfg, None).unwrap();
            sim.run_steps(3).unwrap();
            let rep = sim.checkpoint().unwrap();
            let cfg = sim.cfg.clone();
            let fs = sim.kill();
            let (mut resumed, _) = JobSim::restart_from(cfg, None, fs).unwrap();
            let resumed_pending = resumed.wrappers.pending_collective().is_some();
            resumed.run_steps(3).unwrap();
            assert!(!resumed.any_corruption());
            (rep, resumed_pending, resumed.fingerprint())
        };
        for (job, strategy, tree) in [
            ("cd-ctr-flat", DrainStrategy::Counter, false),
            ("cd-ctr-tree", DrainStrategy::Counter, true),
            ("cd-topo-flat", DrainStrategy::Topo, false),
            ("cd-topo-tree", DrainStrategy::Topo, true),
        ] {
            let mut cfg = colheavy_cfg(job, 16);
            cfg.drain_strategy = strategy;
            if tree {
                cfg = cfg.with_coord_tree(4);
            }
            let (rep, resumed_pending, fp) = run(cfg);
            assert_eq!(fp, want, "{job}: C/R must be bitwise-identical");
            assert_eq!(rep.drain_strategy, strategy, "{job}");
            assert_eq!(rep.collectives_interrupted, 1, "{job}");
            if strategy == DrainStrategy::Topo {
                assert!(rep.topo_waves >= 2, "{job}: cursors form multiple waves");
                assert!(
                    resumed_pending,
                    "{job}: the interrupted op must resume from its cursors"
                );
            } else {
                assert_eq!(rep.topo_waves, 0, "{job}");
                assert!(!resumed_pending, "{job}");
            }
        }
    }

    // ------------------------------------------- coordination plane

    #[test]
    fn tree_plane_cr_bitwise_and_byte_identical_to_flat() {
        let mut cont = JobSim::launch(quick_cfg(16, 0), None).unwrap();
        cont.run_steps(6).unwrap();
        let want = cont.fingerprint();

        let run = |cfg: RunConfig| {
            let mut sim = JobSim::launch(cfg.clone(), None).unwrap();
            sim.run_steps(3).unwrap();
            let rep = sim.checkpoint().unwrap();
            let img = match &sim.fs {
                Store::Single(f) => f
                    .peek(&image_path(&cfg.job, RankId(0)))
                    .expect("image written")
                    .1
                    .to_vec(),
                Store::Tiered(_) => unreachable!(),
            };
            let fs = sim.kill();
            let (mut resumed, _) = JobSim::restart_from(cfg, None, fs).unwrap();
            resumed.run_steps(3).unwrap();
            (rep, img, resumed.fingerprint())
        };
        let mut flat_cfg = quick_cfg(16, 0);
        flat_cfg.job = "plane-flat".into();
        let mut tree_cfg = quick_cfg(16, 0).with_coord_tree(2);
        tree_cfg.job = "plane-tree".into();
        let (frep, fimg, ffp) = run(flat_cfg);
        let (trep, timg, tfp) = run(tree_cfg);
        assert_eq!(ffp, want, "flat C/R bitwise");
        assert_eq!(tfp, want, "tree plane must not change checkpoint contents");
        assert_eq!(fimg, timg, "identical image bytes across planes");
        assert!(trep.coord_depth > frep.coord_depth);
        assert!(
            trep.root_ctrl_msgs < frep.root_ctrl_msgs,
            "tree root load {} must undercut flat {}",
            trep.root_ctrl_msgs,
            frep.root_ctrl_msgs
        );
    }

    #[test]
    fn subcoord_death_mid_drain_reparents_and_checkpoint_succeeds() {
        let mut cont = JobSim::launch(quick_cfg(16, 0), None).unwrap();
        cont.run_steps(6).unwrap();
        let want = cont.fingerprint();

        let mut cfg = quick_cfg(16, 0).with_coord_tree(2);
        cfg.job = "tree-death".into();
        cfg.faults.subcoord_death = Some((0, Phase::Drain));
        let mut sim = JobSim::launch(cfg.clone(), None).unwrap();
        sim.run_steps(3).unwrap();
        let rep = sim.checkpoint().unwrap();
        assert_eq!(rep.reparents, 1, "death mid-DRAIN must re-parent once");
        assert_eq!(sim.coord.stats.reparents, 1);
        assert!(sim.coord.stats.phase_retries >= 1);
        let fs = sim.kill();
        cfg.faults.subcoord_death = None; // the dead node stays gone
        let (mut resumed, _) = JobSim::restart_from(cfg, None, fs).unwrap();
        resumed.run_steps(3).unwrap();
        assert_eq!(resumed.fingerprint(), want, "re-parented ckpt restores bitwise");
        assert!(!resumed.any_corruption());
    }

    #[test]
    fn unreachable_rank_fails_checkpoint_cleanly_and_fast() {
        let mut cfg = quick_cfg(8, 0);
        cfg.faults.ctrl_loss_prob = 1.0; // KeepAlive exhausts max_retries
        let mut sim = JobSim::launch(cfg, None).unwrap();
        sim.run_steps(1).unwrap();
        match sim.checkpoint().unwrap_err() {
            CkptFailure::Unreachable { rank, phase } => {
                assert_eq!(rank, RankId(0));
                assert_eq!(phase, Phase::Intent);
            }
            other => panic!("expected clean Unreachable, got {other}"),
        }
        // A second attempt fails fast on the record — no re-timeout.
        let sent = sim.coord.ctrl.stats.sent;
        let retries = sim.coord.ctrl.stats.retries;
        assert!(matches!(
            sim.checkpoint().unwrap_err(),
            CkptFailure::Unreachable { .. }
        ));
        assert_eq!(sim.coord.ctrl.stats.sent, sent, "dead link not re-probed");
        assert_eq!(sim.coord.ctrl.stats.retries, retries, "no re-timeout");
    }

    #[test]
    fn tree_plane_root_messages_bounded_by_fanout() {
        let mut cfg = quick_cfg(64, 0).with_coord_tree(4);
        cfg.job = "tree-bound".into();
        let mut sim = JobSim::launch(cfg, None).unwrap();
        sim.run_steps(2).unwrap();
        let rep = sim.checkpoint().unwrap();
        let bound = 2 * 4 * Phase::ALL.len() as u64;
        assert!(
            rep.root_ctrl_msgs <= bound,
            "root handled {} msgs, bound {bound}",
            rep.root_ctrl_msgs
        );
        assert!(rep.ctrl_msgs > rep.root_ctrl_msgs, "plane moves more than the root");
        assert_eq!(rep.coord_depth, 3, "8 nodes at fanout 4: two levels + leaf");
        assert!(rep.ctrl_secs > 0.0);
    }

    // --------------------------------------------- pipelined ckpt path

    #[test]
    fn pipelined_and_serial_checkpoints_are_bitwise_identical() {
        let mut cont = JobSim::launch(quick_cfg(4, 0), None).unwrap();
        cont.run_steps(6).unwrap();
        let want = cont.fingerprint();

        let run = |pipeline: bool| {
            let mut cfg = quick_cfg(4, 0);
            cfg.pipeline = pipeline;
            cfg.encode_threads = Some(2);
            let mut sim = JobSim::launch(cfg, None).unwrap();
            sim.run_steps(3).unwrap();
            let rep = sim.checkpoint().unwrap();
            let images: Vec<Vec<u8>> = (0..4)
                .map(|r| {
                    sim.fs
                        .read_parallel(&[(
                            sim.topo.node_of(RankId(r)),
                            image_path(&sim.cfg.job, RankId(r)),
                        )])
                        .unwrap()
                        .0
                        .remove(0)
                })
                .collect();
            let cfg = sim.cfg.clone();
            let fs = sim.kill();
            let (mut resumed, _) = JobSim::restart_from(cfg, None, fs).unwrap();
            resumed.run_steps(3).unwrap();
            (rep, images, resumed.fingerprint())
        };
        let (srep, simgs, sfp) = run(false);
        let (prep, pimgs, pfp) = run(true);
        assert_eq!(simgs, pimgs, "stored images must be bitwise identical");
        assert_eq!(sfp, want, "serial restart must be bitwise");
        assert_eq!(pfp, want, "pipelined restart must be bitwise");
        assert!(!srep.pipelined);
        assert!(prep.pipelined);
        assert_eq!(srep.image_bytes, prep.image_bytes);
        // Identical bytes hit the same write model; only the stall shrinks.
        assert_eq!(srep.write_secs, prep.write_secs);
        assert!(
            (srep.stall_secs - (srep.encode_stall_secs + srep.write_secs)).abs() < 1e-9,
            "serial stall is encode-then-write"
        );
        assert!(prep.stall_secs <= srep.stall_secs);
        assert!(
            prep.stall_secs >= prep.encode_stall_secs.max(prep.write_secs) - 1e-12,
            "no model can beat the slower side of the pipe"
        );
        assert!(
            prep.overlap_saved_secs > 0.0,
            "hiding the WRITE ack reduce alone must save time"
        );
        assert!(prep.total_secs <= srep.total_secs);
    }

    #[test]
    fn pipelined_staged_wave_matches_serial_generation() {
        // Streamed admission reorders the write wave at the host level;
        // the stored generation (fast tier after drain, dedup accounting)
        // must be indistinguishable from the rank-order wave.
        let run = |pipeline: bool| {
            let mut cfg = staged_cfg(4, 0);
            cfg.pipeline = pipeline;
            cfg.encode_threads = Some(4);
            let mut sim = JobSim::launch(cfg, None).unwrap();
            sim.run_steps(2).unwrap();
            let rep = sim.checkpoint().unwrap();
            sim.finish_drain();
            let ts = sim.fs.tiered().unwrap();
            let mut paths = ts.fast().paths();
            paths.sort();
            let images: Vec<(String, Vec<u8>)> = paths
                .iter()
                .map(|p| (p.clone(), ts.fast().peek(p).unwrap().1.to_vec()))
                .collect();
            (rep, images)
        };
        let (srep, simgs) = run(false);
        let (prep, pimgs) = run(true);
        assert_eq!(simgs, pimgs, "staged generation must be bitwise identical");
        assert_eq!(srep.deduped_bytes, prep.deduped_bytes);
        assert_eq!(srep.fast_bytes, prep.fast_bytes);
        assert!(prep.stall_secs <= srep.stall_secs);
    }

    #[test]
    fn subcoord_death_during_overlap_reparents_and_restores_bitwise() {
        let mut cont = JobSim::launch(quick_cfg(16, 0), None).unwrap();
        cont.run_steps(6).unwrap();
        let want = cont.fingerprint();

        // SAFE-POINT is the second phase of the fused INTENT/SAFE-POINT
        // pair, so this death lands mid-overlap: the plane must re-parent,
        // discard the dead sub's acks as stale, forfeit the fused-sweep
        // credit — and the checkpoint must still converge (the DRAIN
        // reduce balancing proves no drain counter was double-counted).
        let mut cfg = quick_cfg(16, 0).with_coord_tree(2);
        cfg.job = "tree-overlap-death".into();
        cfg.faults.subcoord_death = Some((0, Phase::SafePoint));
        let mut sim = JobSim::launch(cfg.clone(), None).unwrap();
        sim.run_steps(3).unwrap();
        let rep = sim.checkpoint().unwrap();
        assert!(rep.pipelined);
        assert_eq!(rep.reparents, 1, "death mid-overlap must re-parent once");
        assert!(
            rep.stale_acks > 0,
            "the dead sub's in-flight acks must be counted out as stale"
        );
        assert_eq!(sim.coord.stats.stale_acks, rep.stale_acks);
        assert!(sim.coord.stats.phase_retries >= 1);
        let fs = sim.kill();
        cfg.faults.subcoord_death = None; // the dead node stays gone
        let (mut resumed, _) = JobSim::restart_from(cfg, None, fs).unwrap();
        resumed.run_steps(3).unwrap();
        assert_eq!(
            resumed.fingerprint(),
            want,
            "overlap-interrupted ckpt must restore bitwise"
        );
        assert!(!resumed.any_corruption());
    }

    // --------------------------------------------- staged (tiered) mode

    fn staged_cfg(ranks: u32, steps: u64) -> RunConfig {
        quick_cfg(ranks, steps).with_staging()
    }

    #[test]
    fn staged_checkpoint_stalls_on_fast_tier_then_drains() {
        let mut sim = JobSim::launch(staged_cfg(4, 0), None).unwrap();
        sim.run_steps(2).unwrap();
        let rep = sim.checkpoint().unwrap();
        // The stall is the BB wave only; staging is queued, not synchronous.
        assert!(rep.fast_write_secs > 0.0);
        assert_eq!(rep.write_secs, rep.fast_write_secs);
        assert_eq!(rep.durable_write_secs, 0.0, "no backpressure expected");
        assert!(rep.drain_pending_bytes > 0);
        // Ranks sit in the async Drain-to-PFS phase; nothing durable yet.
        assert_eq!(
            sim.coord.status.read().unwrap()[0].state,
            RankState::Draining
        );
        assert_eq!(sim.fs.tiered().unwrap().durable().file_count(), 0);
        // A few supersteps of background drain retire the queue.
        sim.run_steps(3).unwrap();
        let ts = sim.fs.tiered().unwrap();
        assert_eq!(ts.pending_bytes(), 0);
        assert_eq!(ts.pending_files(), 0);
        assert!(ts.is_durable("synthetic-4r/gen0000/ckpt_rank00000.mana"));
        assert!(ts.is_durable("synthetic-4r/ckpt_manifest.txt"));
        assert_eq!(
            sim.coord.status.read().unwrap()[0].state,
            RankState::Resumed
        );
        // Every logical image byte either shipped physically or deduped.
        assert!(
            sim.coord.stats.staged_bytes + sim.coord.stats.deduped_bytes
                >= rep.image_bytes
        );
    }

    #[test]
    fn staged_cr_is_bitwise_identical() {
        let mut cont = JobSim::launch(staged_cfg(4, 0), None).unwrap();
        cont.run_steps(6).unwrap();
        let want = cont.fingerprint();

        let mut sim = JobSim::launch(staged_cfg(4, 0), None).unwrap();
        sim.run_steps(3).unwrap();
        sim.checkpoint().unwrap();
        let cfg = sim.cfg.clone();
        let fs = sim.kill();
        let (mut resumed, rep) = JobSim::restart_from(cfg, None, fs).unwrap();
        assert_eq!(resumed.step, 3);
        assert_eq!(rep.tier_fallbacks, 0);
        resumed.run_steps(3).unwrap();
        assert_eq!(resumed.fingerprint(), want);
        assert!(!resumed.any_corruption());
    }

    #[test]
    fn staged_restart_survives_corrupt_fast_tier_image() {
        let mut sim = JobSim::launch(staged_cfg(4, 0), None).unwrap();
        sim.run_steps(2).unwrap();
        sim.checkpoint().unwrap();
        // Make everything durable, then corrupt one fast-tier copy only.
        let drain_secs = sim.finish_drain();
        assert!(drain_secs > 0.0);
        let path = crate::ckpt::gen_image_path("synthetic-4r", 0, RankId(1));
        let ts = sim.fs.tiered_mut().unwrap();
        assert!(ts.is_durable(&path));
        assert!(ts.fast_mut().corrupt_byte(&path, 150));
        let cfg = sim.cfg.clone();
        let fs = sim.kill();
        let (mut resumed, rep) = JobSim::restart_from(cfg, None, fs).unwrap();
        assert_eq!(
            rep.tier_fallbacks, 1,
            "rank 1 must have fallen back to the durable tier"
        );
        assert_eq!(resumed.step, 2);
        resumed.run_steps(2).unwrap();
        assert!(!resumed.any_corruption());
    }

    #[test]
    fn staged_restart_reads_evicted_generation_from_durable_tier() {
        let mut sim = JobSim::launch(staged_cfg(4, 0), None).unwrap();
        sim.run_steps(1).unwrap();
        sim.checkpoint().unwrap();
        sim.finish_drain();
        // Drop the whole fast-tier copy of the generation (as eviction
        // would); the durable tier alone must carry the restart.
        {
            let ts = sim.fs.tiered_mut().unwrap();
            for p in ts.fast().paths() {
                ts.fast_mut().delete(&p).unwrap();
            }
            assert_eq!(ts.fast().file_count(), 0);
        }
        let cfg = sim.cfg.clone();
        let fs = sim.kill();
        let (mut resumed, _) = JobSim::restart_from(cfg, None, fs).unwrap();
        assert_eq!(resumed.step, 1);
        resumed.run_steps(2).unwrap();
        assert!(!resumed.any_corruption());
    }

    #[test]
    fn staged_drain_resumes_after_restart() {
        let mut sim = JobSim::launch(staged_cfg(4, 0), None).unwrap();
        sim.run_steps(2).unwrap();
        sim.checkpoint().unwrap();
        // Kill while the drain queue is still pending.
        assert!(sim.fs.tiered().unwrap().pending_bytes() > 0);
        let cfg = sim.cfg.clone();
        let fs = sim.kill();
        let (mut resumed, _) = JobSim::restart_from(cfg, None, fs).unwrap();
        assert!(resumed.fs.tiered().unwrap().pending_bytes() > 0);
        assert_eq!(
            resumed.coord.status.read().unwrap()[0].state,
            RankState::Draining,
            "interrupted drain must be visible after restart"
        );
        resumed.run_steps(3).unwrap();
        let ts = resumed.fs.tiered().unwrap();
        assert_eq!(
            ts.pending_bytes(),
            0,
            "drain must resume on the restarted clock"
        );
        assert!(ts.is_durable("synthetic-4r/gen0000/ckpt_rank00000.mana"));
    }

    #[test]
    fn staged_restart_from_adopted_durable_tier_alone() {
        let mut cont = JobSim::launch(staged_cfg(4, 0), None).unwrap();
        cont.run_steps(5).unwrap();
        let want = cont.fingerprint();

        let mut sim = JobSim::launch(staged_cfg(4, 0), None).unwrap();
        sim.run_steps(3).unwrap();
        sim.checkpoint().unwrap();
        sim.finish_drain();
        let cfg = sim.cfg.clone();
        let fs = sim.kill();
        // Rebuild the store from the durable tier alone: the in-memory
        // TieredStore (and its chunk index) is gone; the persisted
        // `.chunkstore/INDEX` object brings the recipes back.
        let Store::Tiered(ts) = fs else { panic!("staged store expected") };
        let durable = ts.durable().clone();
        let topo = Topology::new(cfg.ranks, cfg.threads_per_rank);
        let fresh = TieredStore::adopt(
            FileSystem::new(FsConfig::burst_buffer(topo.nodes())),
            durable,
            2,
            topo.nodes(),
        )
        .expect("index reloads and verifies");
        let (mut resumed, rep) = JobSim::restart_from(cfg, None, Store::Tiered(fresh)).unwrap();
        assert_eq!(resumed.step, 3);
        assert!(rep.read_secs > 0.0);
        resumed.run_steps(2).unwrap();
        assert_eq!(
            resumed.fingerprint(),
            want,
            "durable-only restart must not depend on the in-memory index"
        );
        assert!(!resumed.any_corruption());
    }

    #[test]
    fn staged_repeat_checkpoint_dedups_drain_traffic() {
        // Repeated full checkpoints of a mostly-clean address space: only
        // the tiny Real state/halo/msg-buffer chunks change per superstep;
        // the big pattern heap dedups entirely on the second generation.
        let mut sim = JobSim::launch(staged_cfg(4, 0), None).unwrap();
        sim.run_steps(2).unwrap();
        let rep0 = sim.checkpoint().unwrap();
        assert!(
            rep0.deduped_bytes < rep0.image_bytes / 100,
            "first generation has nothing to dedup against ({} of {})",
            rep0.deduped_bytes,
            rep0.image_bytes
        );
        sim.finish_drain();
        sim.run_steps(1).unwrap();
        let rep1 = sim.checkpoint().unwrap();
        assert!(
            rep1.deduped_bytes > rep1.image_bytes * 9 / 10,
            "mostly-clean gen 1 must dedup >90%: {} of {}",
            rep1.deduped_bytes,
            rep1.image_bytes
        );
        assert!(rep1.dedup_ratio() > 0.9);
        assert!(
            sim.fs.tiered().unwrap().pending_bytes() < rep1.image_bytes / 10,
            "physical drain traffic must be near the dirty fraction"
        );
        sim.finish_drain();

        // Restart from the durable tier alone (chunk-store reassembly):
        // drop every fast-tier file, resume bitwise-identically.
        let want_next = {
            let mut cont = JobSim::launch(staged_cfg(4, 0), None).unwrap();
            cont.run_steps(5).unwrap();
            cont.fingerprint()
        };
        {
            let ts = sim.fs.tiered_mut().unwrap();
            for p in ts.fast().paths() {
                ts.fast_mut().delete(&p).unwrap();
            }
            assert_eq!(ts.fast().file_count(), 0);
        }
        let cfg = sim.cfg.clone();
        let fs = sim.kill();
        let (mut resumed, _) = JobSim::restart_from(cfg, None, fs).unwrap();
        assert_eq!(resumed.step, 3, "must resume from generation 1");
        resumed.run_steps(2).unwrap();
        assert_eq!(
            resumed.fingerprint(),
            want_next,
            "reassembled images must be byte-identical (CRC-clean decode)"
        );
        assert!(!resumed.any_corruption());
    }

    #[test]
    fn staged_restart_adopts_manifest_chunk_granularity() {
        let mut cfg = staged_cfg(4, 0);
        cfg.chunk_bytes = 64 << 10;
        let mut sim = JobSim::launch(cfg, None).unwrap();
        sim.run_steps(2).unwrap();
        sim.checkpoint().unwrap();
        let mut restart_cfg = sim.cfg.clone();
        restart_cfg.chunk_bytes = crate::ckpt::chunk::DEFAULT_CHUNK_BYTES;
        let fs = sim.kill();
        let (resumed, _) = JobSim::restart_from(restart_cfg, None, fs).unwrap();
        assert_eq!(
            resumed.cfg.chunk_bytes,
            64 << 10,
            "restart must keep the granularity the set was written with"
        );
    }

    #[test]
    fn staged_restart_adopts_manifest_chunking_mode() {
        // Mixed-mode restart: the image set was written under CDC, the
        // restarting config defaults to fixed. Restart must adopt the
        // writer's strategy — never mis-tile new generations against the
        // CDC-built chunk index — resume bitwise, and keep deduping from
        // the first post-restart checkpoint on.
        let mut cfg = staged_cfg(4, 0);
        cfg.chunking = crate::config::ChunkingMode::Cdc;
        cfg.chunk_bytes = 64 << 10;
        let mut sim = JobSim::launch(cfg, None).unwrap();
        sim.run_steps(2).unwrap();
        sim.checkpoint().unwrap();
        sim.finish_drain();
        let want = sim.fingerprint();

        let mut restart_cfg = sim.cfg.clone();
        restart_cfg.chunking = crate::config::ChunkingMode::Fixed;
        restart_cfg.chunk_bytes = crate::ckpt::chunk::DEFAULT_CHUNK_BYTES;
        let fs = sim.kill();
        let (mut resumed, _) = JobSim::restart_from(restart_cfg, None, fs).unwrap();
        assert_eq!(
            resumed.cfg.chunking,
            crate::config::ChunkingMode::Cdc,
            "restart must adopt the manifest's chunking mode"
        );
        assert_eq!(
            resumed.cfg.chunk_bytes,
            64 << 10,
            "restart must adopt the manifest's granularity"
        );
        assert_eq!(resumed.fingerprint(), want, "restart must be bitwise");

        // Proof restart never mis-tiles: the next (mostly-clean) full
        // checkpoint must cut the same boundaries the durable index was
        // built on and dedup heavily against the pre-kill generation.
        resumed.run_steps(1).unwrap();
        let rep = resumed.checkpoint().unwrap();
        assert!(
            rep.dedup_ratio() > 0.5,
            "post-restart generation must dedup against the pre-kill index \
             (got {:.2})",
            rep.dedup_ratio()
        );
    }

    #[test]
    fn restart_forces_fixed_for_pre_cdc_manifest() {
        // A manifest with no chunking line (written by a pre-CDC build)
        // implies fixed tiling: a cdc-configured restart must fall back to
        // fixed rather than re-tile against the fixed-grid chunk index.
        let cfg = staged_cfg(4, 0);
        let mut sim = JobSim::launch(cfg, None).unwrap();
        sim.run_steps(2).unwrap();
        sim.checkpoint().unwrap();
        let mpath = CkptManifest::manifest_path(&sim.cfg.job);
        {
            // Strip the chunking line in place, emulating the old format.
            let ts = sim.fs.tiered_mut().unwrap();
            let bytes = ts
                .fast()
                .peek(&mpath)
                .map(|(_, b)| b.to_vec())
                .expect("manifest on the fast tier");
            let mut m = CkptManifest::decode(&bytes).unwrap();
            assert!(m.chunking.is_some(), "current writer records chunking");
            m.chunking = None;
            let data = m.encode();
            ts.fast_mut()
                .insert_raw(&mpath, data.len() as u64, data)
                .unwrap();
        }
        let mut restart_cfg = sim.cfg.clone();
        restart_cfg.chunking = crate::config::ChunkingMode::Cdc;
        let fs = sim.kill();
        let (resumed, _) = JobSim::restart_from(restart_cfg, None, fs).unwrap();
        assert_eq!(
            resumed.cfg.chunking,
            crate::config::ChunkingMode::Fixed,
            "pre-CDC sets must restart in fixed mode regardless of cfg"
        );
    }

    #[test]
    fn cdc_staged_cr_is_bitwise_identical() {
        // A full C/R cycle with CDC chunking end to end: checkpoints,
        // durable drain, kill, restart, resume — bitwise identical to an
        // uninterrupted run.
        let mut cfg = staged_cfg(4, 0);
        cfg.chunking = crate::config::ChunkingMode::Cdc;
        cfg.chunk_bytes = 64 << 10;
        let mut cont = JobSim::launch(cfg.clone(), None).unwrap();
        cont.run_steps(4).unwrap();
        let want = cont.fingerprint();

        let mut sim = JobSim::launch(cfg.clone(), None).unwrap();
        sim.run_steps(2).unwrap();
        sim.checkpoint().unwrap();
        sim.finish_drain();
        let fs = sim.kill();
        let (mut resumed, _) = JobSim::restart_from(cfg, None, fs).unwrap();
        resumed.run_steps(2).unwrap();
        assert_eq!(resumed.fingerprint(), want, "CDC C/R must be bitwise");
        assert!(!resumed.any_corruption());
    }

    #[test]
    fn staged_incremental_cr_is_bitwise_identical() {
        let mut cfg = staged_cfg(4, 0);
        cfg.incremental = true;
        let mut cont = JobSim::launch(cfg.clone(), None).unwrap();
        cont.run_steps(6).unwrap();
        let want = cont.fingerprint();

        let mut sim = JobSim::launch(cfg.clone(), None).unwrap();
        sim.run_steps(2).unwrap();
        let full = sim.checkpoint().unwrap();
        sim.run_steps(2).unwrap();
        let inc = sim.checkpoint().unwrap();
        assert!(
            inc.image_bytes < full.image_bytes,
            "incremental must shrink the wave ({} vs {})",
            inc.image_bytes,
            full.image_bytes
        );
        let fs = sim.kill();
        let (mut resumed, _) = JobSim::restart_from(cfg, None, fs).unwrap();
        assert_eq!(resumed.step, 4, "must resume from the incremental");
        resumed.run_steps(2).unwrap();
        assert_eq!(resumed.fingerprint(), want);
        assert!(!resumed.any_corruption());
    }

    // ------------------------------------------ fast-tier peer redundancy

    /// Staged config spread over 4 nodes (32 threads/rank -> 2 ranks/node)
    /// with a redundancy scheme — one full set of 4.
    fn redundant_cfg(scheme: RedundancyScheme) -> RunConfig {
        let mut cfg = staged_cfg(8, 0);
        cfg.threads_per_rank = 32;
        cfg.redundancy = scheme;
        cfg
    }

    fn node_loss_cycle(scheme: RedundancyScheme) {
        let mut cont = JobSim::launch(redundant_cfg(scheme), None).unwrap();
        cont.run_steps(6).unwrap();
        let want = cont.fingerprint();

        let mut sim = JobSim::launch(redundant_cfg(scheme), None).unwrap();
        sim.run_steps(3).unwrap();
        let rep = sim.checkpoint().unwrap();
        assert_eq!(rep.redundancy_scheme, scheme);
        assert!(rep.exchange_secs > 0.0, "exchange must be charged");
        assert!(rep.parity_bytes > 0);
        // Kill with the drain still pending, then lose one node's entire
        // fast tier while the job is down.
        assert!(sim.fs.tiered().unwrap().pending_files() > 0);
        let mut cfg = sim.cfg.clone();
        cfg.faults.bb_node_loss = vec![(NodeId(3), 0.0)];
        let fs = sim.kill();
        let (mut resumed, rrep) = JobSim::restart_from(cfg, None, fs).unwrap();
        assert_eq!(rrep.rebuilt_nodes, 1);
        assert!(
            rrep.rebuilt_files >= 2,
            "both of node 3's rank images must come back from peers"
        );
        assert!(rrep.rebuild_secs > 0.0);
        assert_eq!(
            rrep.durable_read_files, 0,
            "peer rebuild must keep the restart off the durable tier"
        );
        assert_eq!(rrep.generation_rewound, 0);
        assert_eq!(rrep.tier_fallbacks, 0);
        resumed.run_steps(3).unwrap();
        assert_eq!(
            resumed.fingerprint(),
            want,
            "peer-rebuilt restart must be bitwise identical"
        );
        assert!(!resumed.any_corruption());
    }

    #[test]
    fn partner_restart_rebuilds_lost_node_from_peers() {
        node_loss_cycle(RedundancyScheme::Partner);
    }

    #[test]
    fn xor_restart_rebuilds_lost_node_from_peers() {
        node_loss_cycle(RedundancyScheme::Xor);
    }

    #[test]
    fn unprotected_node_loss_falls_back_to_durable_tier() {
        let mut cont = JobSim::launch(redundant_cfg(RedundancyScheme::None), None).unwrap();
        cont.run_steps(6).unwrap();
        let want = cont.fingerprint();

        let mut sim = JobSim::launch(redundant_cfg(RedundancyScheme::None), None).unwrap();
        sim.run_steps(3).unwrap();
        let rep = sim.checkpoint().unwrap();
        assert_eq!(rep.exchange_secs, 0.0, "no scheme, no exchange");
        assert_eq!(rep.parity_bytes, 0);
        sim.finish_drain();
        let mut cfg = sim.cfg.clone();
        cfg.faults.bb_node_loss = vec![(NodeId(3), 0.0)];
        let fs = sim.kill();
        let (mut resumed, rrep) = JobSim::restart_from(cfg, None, fs).unwrap();
        assert_eq!(rrep.rebuilt_nodes, 0, "nothing to rebuild from");
        assert!(
            rrep.durable_read_files >= 2,
            "the lost node's images must be served from Lustre"
        );
        resumed.run_steps(3).unwrap();
        assert_eq!(resumed.fingerprint(), want);
        assert!(!resumed.any_corruption());
    }

    #[test]
    fn unrecoverable_xor_set_rewinds_to_older_generation() {
        let mut cont = JobSim::launch(redundant_cfg(RedundancyScheme::Xor), None).unwrap();
        cont.run_steps(4).unwrap();
        let want = cont.fingerprint();

        let mut sim = JobSim::launch(redundant_cfg(RedundancyScheme::Xor), None).unwrap();
        sim.run_steps(2).unwrap();
        sim.checkpoint().unwrap();
        sim.finish_drain(); // generation 0 is fully durable
        sim.run_steps(2).unwrap();
        sim.checkpoint().unwrap(); // generation 1 exists on the fast tier only
        assert!(sim.fs.tiered().unwrap().pending_files() > 0);
        // Two lost members sink the XOR set: generation 1 is gone from the
        // fast tier AND never reached Lustre, so the restart must rewind.
        let mut cfg = sim.cfg.clone();
        cfg.faults.bb_node_loss = vec![(NodeId(2), 0.0), (NodeId(3), 0.0)];
        let fs = sim.kill();
        let (mut resumed, rrep) = JobSim::restart_from(cfg, None, fs).unwrap();
        assert_eq!(rrep.generation_rewound, 1, "must rewind exactly one generation");
        assert_eq!(resumed.step, 2, "resumed from the older full checkpoint");
        resumed.run_steps(2).unwrap();
        assert_eq!(resumed.fingerprint(), want);
        assert!(!resumed.any_corruption());
    }

    #[test]
    fn scheduled_node_loss_mid_drain_recovers_via_partner() {
        let mut cont = JobSim::launch(redundant_cfg(RedundancyScheme::Partner), None).unwrap();
        cont.run_steps(6).unwrap();
        let want = cont.fingerprint();

        let mut sim = JobSim::launch(redundant_cfg(RedundancyScheme::Partner), None).unwrap();
        sim.run_steps(3).unwrap();
        sim.checkpoint().unwrap();
        // The blade dies on the next drain tick, with the queue mid-flight.
        let at = sim.now().as_secs() + 1e-6;
        sim.fs
            .tiered_mut()
            .unwrap()
            .schedule_node_loss(NodeId(3), at);
        sim.run_steps(1).unwrap();
        assert!(
            sim.fs.tiered().unwrap().stats.lost_files > 0,
            "the scheduled loss must have fired mid-drain"
        );
        let cfg = sim.cfg.clone();
        let fs = sim.kill();
        let (mut resumed, rrep) = JobSim::restart_from(cfg, None, fs).unwrap();
        assert_eq!(rrep.rebuilt_nodes, 1);
        assert_eq!(rrep.durable_read_files, 0);
        resumed.run_steps(3).unwrap();
        assert_eq!(resumed.fingerprint(), want);
        assert!(!resumed.any_corruption());
        // The rebuilt files re-entered the drain queue and go durable.
        resumed.finish_drain();
        let ts = resumed.fs.tiered().unwrap();
        assert!(ts.is_durable(&gen_image_path("synthetic-8r", 0, RankId(6))));
        assert!(ts.is_durable(&gen_image_path("synthetic-8r", 0, RankId(7))));
    }

    #[test]
    fn corrupt_fast_image_rebuilds_from_partner_before_durable() {
        let mut cont = JobSim::launch(redundant_cfg(RedundancyScheme::Partner), None).unwrap();
        cont.run_steps(4).unwrap();
        let want = cont.fingerprint();

        let mut sim = JobSim::launch(redundant_cfg(RedundancyScheme::Partner), None).unwrap();
        sim.run_steps(2).unwrap();
        sim.checkpoint().unwrap();
        // Corrupt a fast copy while its drain is still pending: the bad
        // bytes exist nowhere else but the partner copy.
        assert!(sim.fs.tiered().unwrap().pending_files() > 0);
        let path = gen_image_path("synthetic-8r", 0, RankId(6));
        assert!(sim
            .fs
            .tiered_mut()
            .unwrap()
            .fast_mut()
            .corrupt_byte(&path, 150));
        let cfg = sim.cfg.clone();
        let fs = sim.kill();
        let (mut resumed, rrep) = JobSim::restart_from(cfg, None, fs).unwrap();
        assert_eq!(
            rrep.tier_fallbacks, 0,
            "the partner copy must beat the durable tier"
        );
        assert!(rrep.rebuilt_files >= 1);
        assert_eq!(rrep.rebuilt_nodes, 1);
        assert_eq!(rrep.durable_read_files, 0);
        resumed.run_steps(2).unwrap();
        assert_eq!(resumed.fingerprint(), want);
        assert!(!resumed.any_corruption());
    }

    #[test]
    fn restart_adopts_manifest_redundancy_scheme() {
        let mut sim = JobSim::launch(redundant_cfg(RedundancyScheme::Xor), None).unwrap();
        sim.run_steps(2).unwrap();
        sim.checkpoint().unwrap();
        let mut cfg = sim.cfg.clone();
        cfg.redundancy = RedundancyScheme::None; // restart config left unset
        let fs = sim.kill();
        let (resumed, _) = JobSim::restart_from(cfg, None, fs).unwrap();
        assert_eq!(
            resumed.cfg.redundancy,
            RedundancyScheme::Xor,
            "restart must adopt the scheme the set was written with"
        );
        assert_eq!(resumed.cfg.redundancy_set_size, 4);
    }

    // ------------------------------------------------------------ tracing

    #[test]
    fn trace_reconciles_report_across_random_shapes() {
        crate::proptest::run("trace reconciles report", 10, |g| {
            let mut cfg = match g.u64_below(3) {
                1 => staged_cfg([2u32, 4, 8][g.u64_below(3) as usize], 0),
                2 => redundant_cfg(*g.choose(&[
                    RedundancyScheme::Partner,
                    RedundancyScheme::Xor,
                ])),
                _ => quick_cfg([2u32, 4, 8][g.u64_below(3) as usize], 0),
            };
            cfg.trace = true;
            cfg.pipeline = g.bool();
            if g.bool() {
                cfg = cfg.with_coord_tree(2 + g.u64_below(3) as u32);
            }
            if g.bool() {
                cfg.fixes.drain = false;
            }
            let mut sim = JobSim::launch(cfg, None).unwrap();
            sim.run_steps(1 + g.u64_below(2)).unwrap();
            let rep = sim.checkpoint().unwrap();
            let spans = sim.tracer.spans();
            let mismatches = crate::trace::reconcile(&spans, 0, &rep);
            assert!(mismatches.is_empty(), "trace/report drift: {mismatches:?}");
            assert_eq!(sim.tracer.event_count("trace.reconcile:g0"), 0);
            // The critical path's charges telescope to the whole stall.
            let path = crate::trace::critical_path::critical_path(&spans, 0);
            assert!(!path.is_empty());
            let sum: f64 = path.iter().map(|p| p.secs).sum();
            assert!(
                (sum - rep.total_secs).abs() < 1e-6,
                "critical path sums to {sum}, checkpoint took {}",
                rep.total_secs
            );
        });
    }

    #[test]
    fn trace_off_records_no_spans_but_events_still_flow() {
        let mut cfg = quick_cfg(4, 0);
        cfg.fixes.drain = false;
        let mut sim = JobSim::launch(cfg, None).unwrap();
        sim.run_steps(3).unwrap();
        let rep = sim.checkpoint().unwrap();
        assert!(rep.lost_messages > 0);
        assert_eq!(sim.tracer.span_count(), 0, "tracing defaults off");
        assert!(
            sim.tracer.event_count("ckpt.undrained_drop") > 0,
            "structured events are always on"
        );
    }

    #[test]
    fn traced_restart_records_timeline_spans() {
        let mut cfg = staged_cfg(4, 0);
        cfg.trace = true;
        let mut sim = JobSim::launch(cfg, None).unwrap();
        sim.run_steps(2).unwrap();
        sim.checkpoint().unwrap();
        sim.finish_drain();
        let cfg2 = sim.cfg.clone();
        let fs = sim.kill();
        let (resumed, rrep) = JobSim::restart_from(cfg2, None, fs).unwrap();
        let spans = resumed.tracer.spans();
        let restart: Vec<_> = spans.iter().filter(|s| s.name == "restart").collect();
        assert_eq!(restart.len(), 1);
        assert!((restart[0].duration() - rrep.total_secs).abs() < 1e-9);
        assert!(spans.iter().any(|s| s.name == "restart.read"));
        assert!(spans.iter().any(|s| s.name == "restart.startup"));
    }

    #[test]
    fn traced_drain_emits_ticks_and_gauges() {
        let mut cfg = staged_cfg(4, 0);
        cfg.trace = true;
        let mut sim = JobSim::launch(cfg, None).unwrap();
        sim.run_steps(2).unwrap();
        sim.checkpoint().unwrap();
        sim.run_steps(6).unwrap();
        sim.finish_drain();
        let spans = sim.tracer.spans();
        assert!(
            spans
                .iter()
                .any(|s| s.name == "drain.tick" || s.name == "drain.sync"),
            "background drain must appear in the trace"
        );
        assert!(
            sim.tracer
                .counters()
                .iter()
                .any(|c| c.name == "drain.backlog_bytes"),
            "drain gauges must be sampled as counter series"
        );
    }
}
