//! Pure-synthetic workload: deterministic state evolution, no PJRT.
//!
//! Used by substrate tests and the biggest benches, where the point is the
//! checkpoint data path, not the physics. Also the stand-in for "tens of
//! thousands of different application binaries" in the Fig. 1 census.

use anyhow::{Context, Result};

use super::{map_common_regions, synth_evolve, App, StepCtx};
use crate::config::AppKind;
use crate::mem::Payload;
use crate::splitproc::SplitProcess;

const STATE_BYTES: usize = 4096;

pub struct Synthetic;

impl App for Synthetic {
    fn kind(&self) -> AppKind {
        AppKind::Synthetic
    }

    fn artifact(&self) -> Option<&'static str> {
        None
    }

    fn default_mem_per_rank(&self) -> u64 {
        256 << 20 // 256 MiB
    }

    fn compute_secs(&self) -> f64 {
        0.1
    }

    fn init(&self, proc: &mut SplitProcess, _ranks: u32, mem_per_rank: u64) -> Result<()> {
        let mut state = vec![0u8; STATE_BYTES];
        for b in state.iter_mut() {
            *b = (proc.rng.next_u64() & 0xff) as u8;
        }
        proc.map_app_region("state", STATE_BYTES as u64, Payload::Real(state))?;
        map_common_regions(proc, mem_per_rank, STATE_BYTES as u64)?;
        // Every production app writes output; the fd survives C/R.
        proc.open_app_fd("stdout.log");
        Ok(())
    }

    fn compute(&self, ctx: &mut StepCtx) -> Result<()> {
        let mut b = ctx.proc.app_state("state").context("state")?.to_vec();
        synth_evolve(&mut b);
        ctx.proc.store_app_state("state", b)?;
        Ok(())
    }
}
