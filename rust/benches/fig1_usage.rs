//! FIG1 — Application usage at NERSC in 2020 (paper Fig. 1).
//!
//! Samples a synthetic year of jobs from the published application mix and
//! regenerates the figure: per-app share, cumulative top-k curve, and the
//! two headline claims (top-20 ≈ 70%, VASP > 20%).

use mana::benchkit::Report;
use mana::usage::{census, sample_jobs, top_k_share};

fn main() {
    let n_jobs = 500_000;
    let jobs = sample_jobs(n_jobs, 2020);
    let rows = census(&jobs);

    let mut rep = Report::new(
        "FIG1: application usage at NERSC 2020 (synthetic census)",
        vec!["rank", "app", "share_pct", "cumulative_pct"],
    );
    let mut cum = 0.0;
    for (i, (app, share)) in rows.iter().take(20).enumerate() {
        cum += share;
        rep.row(vec![
            format!("{}", i + 1),
            app.clone(),
            format!("{share:.2}"),
            format!("{cum:.2}"),
        ]);
    }
    rep.finish();

    let top20 = top_k_share(&rows, 20);
    println!("\npaper: top-20 account for ~70% of cycles  -> measured {top20:.1}%");
    println!("paper: VASP > 20% of cycles               -> measured {:.1}%", rows[0].1);
    println!(
        "paper: tens of thousands of binaries      -> measured {} distinct",
        rows.len()
    );
    assert!((65.0..75.0).contains(&top20));
    assert!(rows[0].1 > 19.0 && rows[0].0 == "vasp");
    assert!(rows.len() > 10_000);
    println!("FIG1 OK");
}
