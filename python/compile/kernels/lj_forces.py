"""L1 Pallas kernel: tiled Lennard-Jones forces (Gromacs/ADH analog).

The paper's Fig. 2 workload is Gromacs running the ADH benchmark; the
compute hot spot of an MD step is the short-range non-bonded force loop.
This kernel is that loop, tiled for TPU VMEM: the row dimension is blocked
(one program per row tile) while each program streams the full position
array (N is the per-rank atom count, small enough to reside in VMEM).

The kernel MUST be lowered with ``interpret=True``: real-TPU lowering emits
a Mosaic custom-call that the CPU PJRT plugin cannot execute.

Correctness oracle: :func:`kernels.ref.lj_forces_ref` (pytest + hypothesis).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default row tile. 128 keeps the (T, N) pair matrices lane-aligned for the
# TPU VPU; interpret mode does not care but the structure is TPU-shaped.
DEFAULT_TILE = 128


def _lj_kernel(pos_tile_ref, pos_all_ref, out_ref, *, box: float, eps: float,
               sigma: float, rcut: float, n_valid: int, tile: int):
    """One row-tile of the pairwise force sum.

    pos_tile_ref: (T, 3) this program's row positions.
    pos_all_ref:  (N, 3) all positions (streamed whole into VMEM).
    out_ref:      (T, 3) forces for the row tile.
    """
    i = pl.program_id(0)
    p = pos_tile_ref[...].astype(jnp.float32)              # (T, 3)
    q = pos_all_ref[...].astype(jnp.float32)               # (N, 3)
    n = q.shape[0]
    rows = i * tile + jax.lax.iota(jnp.int32, tile)        # global row ids
    cols = jax.lax.iota(jnp.int32, n)

    d = p[:, None, :] - q[None, :, :]                      # (T, N, 3)
    d = d - box * jnp.round(d / box)                       # minimum image
    r2 = jnp.sum(d * d, axis=-1)                           # (T, N)

    valid = (rows[:, None] != cols[None, :])
    valid &= rows[:, None] < n_valid
    valid &= cols[None, :] < n_valid
    valid &= r2 <= rcut * rcut

    r2_safe = jnp.where(valid, r2, 1.0)
    inv_r2 = 1.0 / r2_safe
    s2 = (sigma * sigma) * inv_r2
    s6 = s2 * s2 * s2
    coef = 24.0 * eps * (2.0 * s6 * s6 - s6) * inv_r2
    coef = jnp.where(valid, coef, 0.0)
    out_ref[...] = jnp.sum(coef[:, :, None] * d, axis=1)   # (T, 3)


def lj_forces(pos: jnp.ndarray, *, box: float, eps: float = 1.0,
              sigma: float = 1.0, rcut: float = 2.5,
              tile: int = DEFAULT_TILE) -> jnp.ndarray:
    """Pallas LJ forces. ``pos`` is ``(N, 3)``; N is padded to the tile.

    Padding rows are masked out inside the kernel (``n_valid``), so callers
    may pass any N >= 1.
    """
    n = pos.shape[0]
    n_pad = ((n + tile - 1) // tile) * tile
    p = jnp.pad(pos.astype(jnp.float32), ((0, n_pad - n), (0, 0)))

    kernel = functools.partial(_lj_kernel, box=float(box), eps=float(eps),
                               sigma=float(sigma), rcut=float(rcut),
                               n_valid=n, tile=tile)
    out = pl.pallas_call(
        kernel,
        grid=(n_pad // tile,),
        in_specs=[
            pl.BlockSpec((tile, 3), lambda i: (i, 0)),      # row tile
            pl.BlockSpec((n_pad, 3), lambda i: (0, 0)),     # full positions
        ],
        out_specs=pl.BlockSpec((tile, 3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, 3), jnp.float32),
        interpret=True,
    )(p, p)
    return out[:n].astype(pos.dtype)
