"""L1 Pallas kernel: 27-point stencil SpMV (HPCG analog).

HPCG's dominant kernel is the sparse matrix-vector product with the 3-D
27-point operator (diag 26, neighbours -1, zero Dirichlet boundary). On a
structured grid that SpMV is a stencil; this kernel blocks the x dimension
into slabs (one grid program per slab) and loads a halo of one plane on
each side via ``pl.dynamic_slice`` from the padded input kept in ANY/HBM.

BlockSpec expresses the HBM->VMEM schedule for the *output*; the input is
left unblocked because overlapping (haloed) input windows cannot be
expressed as disjoint BlockSpec tiles — the explicit ``pl.load`` with a
dynamic slice is the Pallas idiom for halos.

Lowered with ``interpret=True`` (see lj_forces.py for why).

Correctness oracle: :func:`kernels.ref.stencil27_ref`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_SLAB = 8


def _stencil_kernel(xp_ref, out_ref, *, slab: int):
    """One x-slab of y = A x.

    xp_ref:  (nx+2, ny+2, nz+2) zero-padded input, unblocked.
    out_ref: (slab, ny, nz) output slab.
    """
    i = pl.program_id(0)
    ny2 = xp_ref.shape[1]
    nz2 = xp_ref.shape[2]
    # Load the slab plus one halo plane on each side: rows
    # [i*slab, i*slab + slab + 2) of the padded array.
    win = xp_ref[pl.ds(i * slab, slab + 2), :, :]          # (slab+2, ny+2, nz+2)
    win = win.astype(jnp.float32)
    ny = ny2 - 2
    nz = nz2 - 2
    acc = jnp.zeros((slab, ny, nz), jnp.float32)
    for di in (0, 1, 2):
        for dj in (0, 1, 2):
            for dk in (0, 1, 2):
                sub = win[di:di + slab, dj:dj + ny, dk:dk + nz]
                if di == 1 and dj == 1 and dk == 1:
                    acc = acc + 26.0 * sub
                else:
                    acc = acc - sub
    out_ref[...] = acc


def stencil27(x: jnp.ndarray, *, slab: int = DEFAULT_SLAB) -> jnp.ndarray:
    """Pallas 27-point stencil. ``x`` is ``(nx, ny, nz)`` with nx % slab == 0."""
    nx, ny, nz = x.shape
    if nx % slab != 0:
        # Fall back to a slab that divides nx (worst case 1: plane-by-plane).
        slab = next(s for s in range(min(slab, nx), 0, -1) if nx % s == 0)
    xp = jnp.pad(x.astype(jnp.float32), 1)                 # zero boundary
    kernel = functools.partial(_stencil_kernel, slab=slab)
    out = pl.pallas_call(
        kernel,
        grid=(nx // slab,),
        in_specs=[pl.BlockSpec((nx + 2, ny + 2, nz + 2), lambda i: (0, 0, 0))],
        out_specs=pl.BlockSpec((slab, ny, nz), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nx, ny, nz), jnp.float32),
        interpret=True,
    )(xp)
    return out.astype(x.dtype)
