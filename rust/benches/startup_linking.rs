//! LINK — startup time at scale: dynamically linked MANA/DMTCP vs the
//! planned statically linked build.
//!
//! "We also began to see startup time performance issues with our
//! dynamically linked MANA/DMTCP executables, as static linking is
//! preferred at scale. … it is recommended to broadcast a statically
//! linked executable to all nodes."

use mana::benchkit::{fsecs, Report};
use mana::config::LinkMode;
use mana::launcher::startup_secs;
use mana::topology::Topology;

fn main() {
    let mut rep = Report::new(
        "LINK: job startup time, dynamic vs static linking",
        vec!["ranks", "nodes", "dynamic_s", "static_s", "speedup"],
    );
    let mut last_speedup = 0.0;
    let mut first_speedup = 0.0;
    for &ranks in &[8u32, 32, 128, 512, 2048] {
        let topo = Topology::new(ranks, 8);
        let d = startup_secs(&topo, LinkMode::Dynamic);
        let s = startup_secs(&topo, LinkMode::Static);
        let speedup = d / s;
        if first_speedup == 0.0 {
            first_speedup = speedup;
        }
        last_speedup = speedup;
        rep.row(vec![
            ranks.to_string(),
            topo.nodes().to_string(),
            fsecs(d),
            fsecs(s),
            format!("{speedup:.1}x"),
        ]);
    }
    rep.finish();

    println!(
        "\nstatic-linking advantage grows with scale: {first_speedup:.1}x at 1 node -> {last_speedup:.1}x at 256 nodes"
    );
    assert!(last_speedup > first_speedup, "advantage must grow with scale");
    assert!(last_speedup > 3.0);
    println!("LINK OK");
}
