//! DATAPATH — host wall-clock of the checkpoint WRITE path: serial vs
//! rank-parallel encode, cold vs warm digest cache.
//!
//! The control plane went O(fanout) in PR 3; this bench tracks the *data*
//! plane, which used to encode every rank's image on one host thread. The
//! rank-parallel path fans the capture→encode→recipe pipeline across
//! worker threads and memoizes per-region section digests, so a
//! steady-state generation re-hashes only what actually changed.
//!
//! Asserted (the PR's acceptance criteria):
//!   * the parallel wave is byte-identical to the serial wave at 512
//!     ranks (spot check; the full guarantee lives in the property test);
//!   * parallel cold encode is not slower than serial cold at 2048 ranks
//!     (the CI gate), on hosts with >= 2 cores;
//!   * >= 3x speedup, serial-cold -> parallel-warm, at 2048 ranks on
//!     hosts with >= 4 cores;
//!   * a 4096-rank staged JobSim run completes, with digest-cache hits by
//!     generation 3.
//!
//! Results are written to BENCH_datapath.json (uploaded as a CI artifact)
//! so the perf trajectory has data points.

use mana::benchkit::{time, Report};
use mana::ckpt::datapath::{encode_wave, resolve_threads, EncodeOpts, RankJob, RankSource};
use mana::ckpt::Chunking;
use mana::config::{AppKind, RunConfig};
use mana::fs::WriteReq;
use mana::mem::{Half, MemRegion, Payload, RegionTable};
use mana::sim::JobSim;
use mana::topology::{NodeId, RankId};
use mana::util::json::Json;

const CHUNK: usize = 1 << 20;
/// Per-rank resident payload (the CRC/digest hash work).
const STATE_BYTES: usize = 32 << 10;
/// Per-rank virtual pattern heap (recipe-digest work, no resident bytes).
const HEAP_VLEN: u64 = 32 << 20;

fn mk_tables(ranks: usize) -> Vec<RegionTable> {
    (0..ranks)
        .map(|r| {
            let mut t = RegionTable::new();
            let mut state = vec![0u8; STATE_BYTES];
            let mut x = (r as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15) | 1;
            for b in state.iter_mut() {
                x ^= x << 13;
                x ^= x >> 7;
                x ^= x << 17;
                *b = (x & 0xff) as u8;
            }
            t.insert(MemRegion::new(
                0x1000_0000_0000,
                STATE_BYTES as u64,
                Half::Upper,
                "state",
                Payload::Real(state),
            ))
            .unwrap();
            t.insert(MemRegion::new(
                0x2000_0000_0000,
                HEAP_VLEN,
                Half::Upper,
                "heap",
                Payload::Pattern(r as u64 + 1),
            ))
            .unwrap();
            t.insert(MemRegion::new(
                0x3000_0000_0000,
                4 << 20,
                Half::Upper,
                "bss",
                Payload::Zero,
            ))
            .unwrap();
            t
        })
        .collect()
}

fn mk_jobs(ranks: usize) -> Vec<RankJob> {
    (0..ranks)
        .map(|i| RankJob {
            rank: RankId(i as u32),
            node: NodeId((i / 64) as u32),
            path: format!("bench/gen0/r{i:05}.mana"),
            parent: None,
            extra_regions: Vec::new(),
        })
        .collect()
}

fn encode(tables: &mut [RegionTable], jobs: &[RankJob], threads: usize) -> Vec<WriteReq> {
    let mut sources: Vec<RankSource> = tables
        .iter_mut()
        .map(|t| RankSource {
            table: t,
            step: 1,
            rng_state: [7u8; 32],
            upper_fds: Vec::new(),
        })
        .collect();
    let (reqs, _stats) = encode_wave(
        &mut sources,
        jobs,
        &EncodeOpts {
            chunking: Chunking::Fixed(CHUNK),
            threads,
            with_recipe: true,
        },
    );
    reqs
}

/// (cold_min_secs, warm_min_secs) for one (ranks, threads) point.
fn measure(ranks: usize, threads: usize) -> (f64, f64) {
    let jobs = mk_jobs(ranks);
    let mut tables = mk_tables(ranks);
    // Cold: every iteration drops the caches first, so each encode pays
    // the full hash cost (the seed's serial path never had caches).
    let (_, cold) = time(1, 2, || {
        for t in tables.iter_mut() {
            t.clear_digest_caches(Half::Upper);
        }
        encode(&mut tables, &jobs, threads);
    });
    // Warm: mark everything clean, repopulate once, then measure pure
    // cache-hit encodes.
    for t in tables.iter_mut() {
        t.clear_dirty(Half::Upper);
    }
    encode(&mut tables, &jobs, threads);
    let (_, warm) = time(1, 2, || {
        encode(&mut tables, &jobs, threads);
    });
    (cold, warm)
}

/// 4096-rank staged (BB -> Lustre) JobSim run: the full protocol must
/// complete at this scale and generation 3 must encode warm.
fn staged_4096() -> Json {
    let mut cfg = RunConfig::new(AppKind::Synthetic, 4096).with_staging();
    cfg.job = "datapath-4096".into();
    cfg.mem_per_rank = Some(1 << 20);
    cfg.steps = 0;
    let mut sim = JobSim::launch(cfg, None).expect("4096-rank staged launch");
    sim.run_steps(1).expect("step");
    let g1 = sim.checkpoint().expect("ckpt gen 1");
    sim.run_steps(1).expect("step");
    sim.checkpoint().expect("ckpt gen 2");
    sim.run_steps(1).expect("step");
    let g3 = sim.checkpoint().expect("ckpt gen 3");
    assert!(
        g3.digest_cache_hit_bytes > 0,
        "4096-rank staged generation 3 must serve clean regions from cache"
    );
    println!(
        "staged 4096: gen1 encode {:.3}s, gen3 encode {:.3}s ({} cache-hit bytes, {} threads)",
        g1.encode_host_secs, g3.encode_host_secs, g3.digest_cache_hit_bytes, g3.encode_threads
    );
    Json::obj()
        .set("ranks", 4096u64)
        .set("encode_threads", g3.encode_threads as u64)
        .set("gen1_encode_host_secs", g1.encode_host_secs)
        .set("gen3_encode_host_secs", g3.encode_host_secs)
        .set("gen3_digest_cache_hit_bytes", g3.digest_cache_hit_bytes)
}

fn main() {
    let cores = resolve_threads(None);
    let mut rep = Report::new(
        "DATAPATH: checkpoint WRITE path host wall-clock (serial vs parallel, cold vs warm)",
        vec!["ranks", "threads", "cache", "min_secs"],
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut row = |rep: &mut Report, ranks: usize, threads: usize, cache: &str, secs: f64| {
        rep.row(vec![
            ranks.to_string(),
            threads.to_string(),
            cache.to_string(),
            format!("{secs:.4}"),
        ]);
        rows.push(
            Json::obj()
                .set("ranks", ranks as u64)
                .set("threads", threads as u64)
                .set("cache", cache)
                .set("min_secs", secs),
        );
    };

    // Byte-identity spot check at 512 ranks (the property test sweeps the
    // general case; this pins the bench workload itself).
    {
        let jobs = mk_jobs(512);
        let mut a = mk_tables(512);
        let mut b = mk_tables(512);
        let serial = encode(&mut a, &jobs, 1);
        let par = encode(&mut b, &jobs, cores.max(2));
        assert_eq!(serial.len(), par.len());
        for (s, p) in serial.iter().zip(&par) {
            assert_eq!(s.path, p.path, "wave must stay in rank order");
            assert_eq!(s.data, p.data, "parallel wave must byte-match serial");
            assert_eq!(s.recipe, p.recipe, "recipes must match");
        }
    }

    let mut speedup_2048 = 0.0;
    let mut parallel_cold_ratio_2048 = 1.0;
    for &ranks in &[512usize, 2048, 4096] {
        let (ser_cold, ser_warm) = measure(ranks, 1);
        let (par_cold, par_warm) = measure(ranks, cores);
        row(&mut rep, ranks, 1, "cold", ser_cold);
        row(&mut rep, ranks, 1, "warm", ser_warm);
        row(&mut rep, ranks, cores, "cold", par_cold);
        row(&mut rep, ranks, cores, "warm", par_warm);
        if ranks == 2048 {
            speedup_2048 = ser_cold / par_warm.max(1e-9);
            parallel_cold_ratio_2048 = par_cold / ser_cold.max(1e-9);
            if cores >= 2 {
                assert!(
                    par_cold <= ser_cold * 1.10,
                    "2048 ranks: parallel cold encode ({par_cold:.4}s) must not be slower \
                     than serial ({ser_cold:.4}s)"
                );
            }
            if cores >= 4 {
                assert!(
                    speedup_2048 >= 3.0,
                    "2048 ranks: parallel+warm must be >=3x over the serial cold path \
                     (got {speedup_2048:.2}x: serial {ser_cold:.4}s, warm parallel {par_warm:.4}s)"
                );
            }
        }
    }
    rep.finish();

    let staged = staged_4096();

    let out = Json::obj()
        .set("bench", "ckpt_datapath")
        .set("host_cores", cores as u64)
        .set("state_bytes_per_rank", STATE_BYTES as u64)
        .set("heap_vlen_per_rank", HEAP_VLEN)
        .set("chunk_bytes", CHUNK as u64)
        .set("speedup_2048_serial_cold_to_parallel_warm", speedup_2048)
        .set(
            "gates",
            Json::obj()
                .set("datapath_parallel_cold_ratio_2048", parallel_cold_ratio_2048)
                .set("datapath_warm_speedup_2048", speedup_2048),
        )
        .set("rows", Json::Arr(rows))
        .set("staged_4096", staged);
    std::fs::write("BENCH_datapath.json", out.to_string()).expect("write BENCH_datapath.json");
    println!(
        "DATAPATH OK ({cores} cores, 2048-rank serial-cold -> parallel-warm speedup {speedup_2048:.2}x; \
         results in BENCH_datapath.json)"
    );
}
