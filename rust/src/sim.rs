//! Job simulation driver: launch → supersteps → checkpoint → kill →
//! restart, on the full simulated Cori substrate.
//!
//! [`JobSim`] wires everything together: topology, split processes, the
//! MPI world over the GNI-like fabric, MANA wrappers, the DMTCP-style
//! coordinator over the control network, the storage tier, and the PJRT
//! engine for real application compute. Ranks are stepped deterministically
//! in bulk-synchronous supersteps:
//!
//! ```text
//! superstep k (per rank): recv halos of step k-1 → compute → send halos of k
//! ```
//!
//! Checkpoints land *between* supersteps (MANA's wrapper-boundary safe
//! points), with halo messages of step k still in flight — which is exactly
//! what the drain protocol must handle.

use std::sync::Arc;

use anyhow::Result;

use crate::apps::{self, App, StepCtx, HALO_VIRTUAL_BYTES};
use crate::ckpt::manifest::CkptManifest;
use crate::ckpt::{image_path, CkptImage, ImageError, SavedPayload, SavedRegion};
use crate::config::{ComputeMode, RunConfig};
use crate::coordinator::{CkptFailure, CkptReport, Coordinator, RankState};
use crate::fs::{FileSystem, FsConfig, FsError, FsKind, WriteReq};
use crate::launcher::{self, LaunchError};
use crate::mem::Payload;
use crate::mpi::comm::{CommRegistry, COMM_WORLD};
use crate::mpi::MpiWorld;
use crate::runtime::Engine;
use crate::simnet::control::{ControlNet, CtrlConfig};
use crate::simnet::fabric::{Fabric, FabricConfig};
use crate::splitproc::{SplitConfig, SplitProcess};
use crate::topology::{RankId, Topology};
use crate::util::simclock::SimTime;
use crate::util::{hash_combine};
use crate::wrappers::{ManaWrappers, WrapperConfig};
use crate::{log_info, log_warn};

/// Synthetic high address where the drained-message buffer region lives.
const MSG_BUFFER_BASE: u64 = 0x6f00_0000_0000;
/// Address of the communicator replay log pseudo-region (rank 0 only).
const COMM_LOG_ADDR: u64 = 0x6e00_0000_0000;
/// Bytes reduced by the per-superstep wrapped allreduce (energy/dot).
const ALLREDUCE_BYTES: u64 = 4096;

/// Path of a rank's *incremental* image (full images use
/// [`crate::ckpt::image_path`]).
pub fn incr_image_path(job: &str, rank: RankId) -> String {
    format!("{job}/ckpt_rank{:05}.inc.mana", rank.0)
}

/// Restart failure taxonomy (mirrors the paper's restart bugs).
#[derive(Debug)]
pub enum RestartError {
    /// srun argv-packet overflow (no manifest fix).
    Launch(LaunchError),
    /// Image failed CRC / decode.
    CorruptImage(RankId, ImageError),
    /// Split-process restore failed (fd conflict, region overlap).
    Proc(RankId, String),
    /// Storage error (missing image).
    Fs(String),
}

impl std::fmt::Display for RestartError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RestartError::Launch(e) => write!(f, "launch: {e}"),
            RestartError::CorruptImage(r, e) => write!(f, "{r}: corrupt image: {e}"),
            RestartError::Proc(r, e) => write!(f, "{r}: restore failed: {e}"),
            RestartError::Fs(e) => write!(f, "storage: {e}"),
        }
    }
}

impl std::error::Error for RestartError {}

/// Timing breakdown of a restart (the paper's restart-speedup numbers).
#[derive(Clone, Copy, Debug, Default)]
pub struct RestartReport {
    pub startup_secs: f64,
    pub read_secs: f64,
    pub total_secs: f64,
}

/// The live job.
pub struct JobSim {
    pub cfg: RunConfig,
    pub topo: Topology,
    pub app: Box<dyn App>,
    pub procs: Vec<SplitProcess>,
    pub world: MpiWorld,
    pub wrappers: ManaWrappers,
    pub times: Vec<SimTime>,
    pub fs: FileSystem,
    pub coord: Coordinator,
    pub engine: Option<Arc<Engine>>,
    /// Communicators: record-and-replay log survives C/R.
    pub comms: CommRegistry,
    /// Observability registry (counters/gauges/summaries).
    pub metrics: crate::metrics::Metrics,
    /// Supersteps completed (all ranks agree outside a superstep).
    pub step: u64,
    /// Halo messages that were expected but lost (undrained checkpoint).
    pub lost_halo_events: u64,
    pub launch_startup_secs: f64,
}

impl JobSim {
    // ------------------------------------------------------------- launch

    /// Fresh job launch (not a restart).
    pub fn launch(cfg: RunConfig, engine: Option<Arc<Engine>>) -> Result<JobSim> {
        let topo = Topology::new(cfg.ranks, cfg.threads_per_rank);
        let fs = Self::make_fs(&cfg, &topo);
        Self::launch_with_fs(cfg, engine, fs)
    }

    /// Launch against an existing storage tier (preemption flows reuse it).
    pub fn launch_with_fs(
        cfg: RunConfig,
        engine: Option<Arc<Engine>>,
        fs: FileSystem,
    ) -> Result<JobSim> {
        if cfg.compute == ComputeMode::Real {
            anyhow::ensure!(
                engine.is_some(),
                "Real compute mode requires a loaded Engine"
            );
        }
        let topo = Topology::new(cfg.ranks, cfg.threads_per_rank);
        let argv = vec!["mana_launch".into(), cfg.app.name().into()];
        let launch = launcher::launch(&topo, cfg.link, &argv)
            .map_err(|e| anyhow::anyhow!("launch: {e}"))?;
        log_info!(
            "sim",
            "launch {}: {} ranks x {} threads on {} nodes ({:.2}s startup)",
            cfg.job,
            cfg.ranks,
            cfg.threads_per_rank,
            launch.nodes,
            launch.startup_secs
        );
        log_info!("sim", "{}", topo.mapping_table());

        let app = apps::make_app(cfg.app);
        let mem_per_rank = cfg.mem_per_rank.unwrap_or(app.default_mem_per_rank());
        let split_cfg = SplitConfig {
            os: cfg.os,
            alloc_policy: cfg.fixes.alloc_policy(),
            fd_policy: cfg.fixes.fd_policy(),
            ..SplitConfig::default()
        };
        let mut procs = Vec::with_capacity(cfg.ranks as usize);
        for r in 0..cfg.ranks {
            let mut p = SplitProcess::launch(RankId(r), split_cfg, cfg.seed)?;
            app.init(&mut p, cfg.ranks, mem_per_rank)?;
            procs.push(p);
        }

        let world = MpiWorld::new(cfg.ranks, Self::make_fabric(&cfg));
        let wrappers = ManaWrappers::new(
            WrapperConfig {
                careful_nonblocking: cfg.fixes.careful_nonblocking,
            },
            cfg.ranks,
        );
        let coord = Self::make_coordinator(&cfg);
        let times = vec![SimTime::secs(launch.startup_secs); cfg.ranks as usize];

        // Applications dup WORLD and split node-local communicators at
        // MPI_Init time; MANA records the calls for restart replay.
        let mut comms = CommRegistry::new(cfg.ranks);
        comms.dup(COMM_WORLD).expect("dup WORLD");
        let node_colors: Vec<i32> = (0..cfg.ranks)
            .map(|r| topo.node_of(RankId(r)).0 as i32)
            .collect();
        comms
            .split(COMM_WORLD, &node_colors)
            .expect("node-local split");

        Ok(JobSim {
            cfg,
            topo,
            app,
            procs,
            world,
            wrappers,
            times,
            fs,
            coord,
            engine,
            comms,
            metrics: crate::metrics::Metrics::new(),
            step: 0,
            lost_halo_events: 0,
            launch_startup_secs: launch.startup_secs,
        })
    }

    fn make_fs(cfg: &RunConfig, topo: &Topology) -> FileSystem {
        let mut fscfg = match cfg.fs {
            FsKind::BurstBuffer => FsConfig::burst_buffer(topo.nodes()),
            FsKind::Lustre => FsConfig::cscratch(),
        };
        if let Some(cap) = cfg.faults.fs_capacity_override {
            fscfg.capacity = cap;
        }
        FileSystem::new(fscfg)
    }

    fn make_fabric(cfg: &RunConfig) -> Fabric {
        Fabric::new(FabricConfig {
            quiescence: cfg.faults.gni_quiescence.clone(),
            ..FabricConfig::default()
        })
    }

    fn make_coordinator(cfg: &RunConfig) -> Coordinator {
        let ctrl = ControlNet::new(
            CtrlConfig {
                keepalive: cfg.fixes.keepalive,
                loss_prob: cfg.faults.ctrl_loss_prob,
                disconnect_prob: cfg.faults.ctrl_disconnect_prob,
                ..CtrlConfig::default()
            },
            cfg.seed ^ 0xC00D,
        );
        Coordinator::new(ctrl, cfg.ranks, cfg.fixes.locks)
    }

    // -------------------------------------------------------------- steps

    /// Run `n` supersteps.
    pub fn run_steps(&mut self, n: u64) -> Result<()> {
        for _ in 0..n {
            self.superstep()?;
        }
        Ok(())
    }

    fn superstep(&mut self) -> Result<()> {
        let ranks = self.cfg.ranks;
        for r in 0..ranks {
            let rank = RankId(r);
            let prev = RankId((r + ranks - 1) % ranks);
            let next = RankId((r + 1) % ranks);
            let step = self.procs[r as usize].step;

            // 1. Receive the two halo chunks of the previous superstep.
            if step > 0 && ranks > 1 {
                let tag = (step - 1) as u32;
                for _chunk in 0..2 {
                    let mut t = self.times[r as usize];
                    let got = self.wrappers.recv_or_lost(
                        &mut self.world,
                        rank,
                        Some(prev),
                        Some(tag),
                        &mut t,
                    );
                    self.times[r as usize] = t;
                    match got {
                        Some(payload) => {
                            apps::fold_halo(&mut self.procs[r as usize], &payload)?
                        }
                        None => {
                            self.lost_halo_events += 1;
                            self.procs[r as usize].corrupted = true;
                            log_warn!(
                                "sim",
                                "{rank}: halo of step {} lost (undrained checkpoint?) — data loss",
                                step - 1
                            );
                        }
                    }
                }
            }

            // 2. Compute.
            {
                let proc = &mut self.procs[r as usize];
                let mut ctx = StepCtx {
                    rank,
                    ranks,
                    proc,
                    engine: self.engine.as_deref(),
                    mode: self.cfg.compute,
                };
                self.app.compute(&mut ctx)?;
            }
            self.times[r as usize].advance(self.app.compute_secs());

            // 3. Send this superstep's two halo chunks (same tag — the
            //    pattern that trips careless Isend conversion).
            if ranks > 1 {
                // Hash the state in place (perf: no clone per rank-step).
                let state_hash = self.primary_state_hash(r);
                for chunk in 0..2u8 {
                    let payload = apps::halo_payload_from_hash(state_hash, step, chunk);
                    let mut t = self.times[r as usize];
                    self.wrappers.send(
                        &mut self.world,
                        rank,
                        next,
                        step as u32,
                        HALO_VIRTUAL_BYTES,
                        payload,
                        &mut t,
                    );
                    self.times[r as usize] = t;
                }
            }
            self.procs[r as usize].step += 1;
        }

        // Every superstep ends with the application's wrapped global
        // reduction (energy / dot product) — a two-phase collective the
        // checkpoint protocol must respect.
        if ranks > 1 {
            self.wrappers
                .allreduce(&mut self.world, &mut self.times, ALLREDUCE_BYTES);
        }

        // Injected lower-half growth events (the large-scale MPI-library
        // mmap bug) fire on the first K supersteps.
        if self.step < self.cfg.faults.lower_half_growth_events as u64 {
            for p in &mut self.procs {
                p.lower_half_growth()?;
            }
        }
        self.step += 1;
        self.metrics.inc("supersteps", 1);
        self.metrics
            .gauge("virtual_secs", self.now().as_secs());
        Ok(())
    }

    fn primary_state_hash(&self, r: u32) -> u64 {
        let proc = &self.procs[r as usize];
        for name in ["pos", "x", "chi", "state"] {
            if let Some(s) = proc.app_state(name) {
                return crate::util::fnv1a(s);
            }
        }
        crate::util::fnv1a(&[])
    }

    // --------------------------------------------------------- checkpoint

    /// Run the full MANA checkpoint protocol.
    pub fn checkpoint(&mut self) -> Result<CkptReport, CkptFailure> {
        let mut report = CkptReport::default();
        let t0 = self.now();

        // Phase 1: INTENT over the control plane.
        let intent_delay = self.coord.broadcast_intent(self.cfg.ranks, t0)?;
        report.intent_secs = intent_delay;
        let mut t = t0.after(intent_delay);

        // Fault window: a status update lands right here; without the
        // locks fix it is interruptible.
        let interrupt = self.cfg.faults.interrupt_status_update;
        for r in 0..self.cfg.ranks {
            self.coord
                .set_rank_state(RankId(r), RankState::SafePoint, interrupt);
        }
        self.coord.check_status_consistent()?;

        // Phase 2: safe points (no outstanding converted requests).
        for r in 0..self.cfg.ranks {
            let rank = RankId(r);
            if !self.wrappers.at_safe_point(rank, self.times[r as usize]) {
                if let Some(done) = self.wrappers.next_completion(rank) {
                    self.times[r as usize] = self.times[r as usize].max(done);
                }
                self.wrappers.retire_completed(rank, self.times[r as usize]);
            }
        }

        // Phase 3: DRAIN (or the legacy drop).
        let drain_t0 = self.now();
        if self.cfg.fixes.drain {
            let drep = self.wrappers.drain_all(&mut self.world, &mut self.times);
            report.drain_rounds = drep.rounds;
            report.buffered_msgs = drep.buffered_msgs;
            debug_assert!(self.world.drained(), "drain postcondition");
            // Report the balanced counters to the coordinator.
            for r in 0..self.cfg.ranks {
                let c = self.world.counters[r as usize];
                self.coord.record_rank_counts(
                    RankId(r),
                    self.procs[r as usize].step,
                    c.sent_bytes,
                    c.recv_bytes,
                );
            }
            if !self.coord.counts_balanced()? {
                // Should be impossible with the drain fix on.
                return Err(CkptFailure::LostMessages(usize::MAX));
            }
        } else {
            let lost = self.world.drop_inflight();
            report.lost_messages = lost;
            self.coord.stats.lost_messages += lost as u64;
            if lost > 0 {
                log_warn!(
                    "coordinator",
                    "checkpoint without drain dropped {lost} in-flight messages"
                );
            }
        }
        // Drain is a barrier.
        let t_sync = self.now();
        for tt in &mut self.times {
            *tt = t_sync;
        }
        report.drain_secs = t_sync.as_secs() - drain_t0.as_secs();
        t = t.max(t_sync);

        // Phase 4: GNI quiescence wait.
        if let Some(end) = self.world.fabric.quiescence_end(t) {
            report.quiesce_secs = end.as_secs() - t.as_secs();
            t = end;
            for tt in &mut self.times {
                *tt = t;
            }
        }

        // Phase 5: WRITE the image wave. Incremental mode: once a full
        // image exists, write only dirty regions (ParentRef the rest) to a
        // side file; the manifest tracks which file is current per rank.
        for r in 0..self.cfg.ranks {
            self.coord
                .set_rank_state(RankId(r), RankState::Writing, false);
        }
        let incremental = self.cfg.incremental
            && self
                .fs
                .exists(&image_path(&self.cfg.job, RankId(0)));
        let mut reqs = Vec::with_capacity(self.cfg.ranks as usize);
        let mut total_virtual = 0u64;
        for r in 0..self.cfg.ranks {
            let rank = RankId(r);
            let img = self.capture_rank_image(r, incremental);
            total_virtual += img.write_bytes();
            let path = if incremental {
                incr_image_path(&self.cfg.job, rank)
            } else {
                image_path(&self.cfg.job, rank)
            };
            reqs.push(WriteReq {
                node: self.topo.node_of(rank),
                path,
                virtual_bytes: img.write_bytes(),
                data: img.encode(),
            });
        }
        let io = match self.fs.write_parallel(reqs) {
            Ok(io) => io,
            Err(e @ FsError::InsufficientSpace { .. }) => {
                return Err(CkptFailure::DiskFull(e.to_string()));
            }
            Err(e) => return Err(CkptFailure::DiskFull(e.to_string())),
        };
        report.write_secs = io.duration;
        report.image_bytes = total_virtual;
        t = t.after(io.duration);
        for tt in &mut self.times {
            *tt = t;
        }

        // Full checkpoints reset the dirty tracking (incrementals are
        // always relative to the last FULL image, so they keep the bits).
        if !incremental {
            for p in &mut self.procs {
                p.aspace.table.clear_dirty(crate::mem::Half::Upper);
            }
        }

        // The restart manifest rides the same storage tier.
        let mut manifest = CkptManifest::new(&self.cfg.job, self.step);
        for r in 0..self.cfg.ranks {
            let rank = RankId(r);
            let path = if incremental {
                incr_image_path(&self.cfg.job, rank)
            } else {
                image_path(&self.cfg.job, rank)
            };
            manifest.add(rank, path);
        }
        let mdata = manifest.encode();
        self.fs
            .write_parallel(vec![WriteReq {
                node: self.topo.node_of(RankId(0)),
                path: CkptManifest::manifest_path(&self.cfg.job),
                virtual_bytes: mdata.len() as u64,
                data: mdata,
            }])
            .map_err(|e| CkptFailure::DiskFull(e.to_string()))?;

        // Phase 6: RESUME.
        let resume_delay = self.coord.broadcast_intent(self.cfg.ranks, t)?;
        t = t.after(resume_delay);
        for r in 0..self.cfg.ranks {
            self.coord
                .set_rank_state(RankId(r), RankState::Resumed, false);
        }
        for tt in &mut self.times {
            *tt = t;
        }

        self.coord.stats.checkpoints += 1;
        self.coord.stats.drain_rounds += report.drain_rounds as u64;
        self.coord.stats.buffered_msgs += report.buffered_msgs as u64;
        report.total_secs = t.as_secs() - t0.as_secs();
        self.metrics.inc("checkpoints", 1);
        self.metrics.observe("ckpt.total_secs", report.total_secs);
        self.metrics.observe("ckpt.write_secs", report.write_secs);
        self.metrics
            .observe("ckpt.image_bytes", report.image_bytes as f64);
        self.metrics
            .inc("ckpt.buffered_msgs", report.buffered_msgs as u64);
        log_info!(
            "coordinator",
            "checkpoint {} at step {}: {} in {:.2}s (drain {:.3}s, write {:.2}s)",
            self.cfg.job,
            self.step,
            crate::util::bytes::human(report.image_bytes),
            report.total_secs,
            report.drain_secs,
            report.write_secs
        );
        Ok(report)
    }

    /// Capture one rank's image, including the wrapper's drain buffer as a
    /// dedicated upper-half pseudo-region.
    fn capture_rank_image(&mut self, r: u32, incremental: bool) -> CkptImage {
        let rank = RankId(r);
        let proc = &self.procs[r as usize];
        let mut img = if incremental {
            CkptImage::capture_incremental(
                rank,
                proc.step,
                proc.rng.state_bytes(),
                proc.fds.fds_of(crate::mem::Half::Upper),
                &proc.aspace.table,
                &image_path(&self.cfg.job, rank),
            )
        } else {
            proc.checkpoint()
        };
        let buf = self.wrappers.encode_buffers(rank);
        img.regions.push(SavedRegion {
            addr: MSG_BUFFER_BASE + (r as u64) * 0x1000_0000,
            vlen: buf.len() as u64,
            name: "mana.msg_buffer".into(),
            payload: SavedPayload::Full(Payload::Real(buf)),
        });
        // Rank 0 carries the communicator record-and-replay log.
        if r == 0 {
            let log = self.comms.encode_log();
            img.regions.push(SavedRegion {
                addr: COMM_LOG_ADDR,
                vlen: log.len() as u64,
                name: "mana.comm_log".into(),
                payload: SavedPayload::Full(Payload::Real(log)),
            });
        }
        img
    }

    // ------------------------------------------------------ kill / restart

    /// Kill the job (scheduler preemption / walltime / failure). The
    /// storage tier survives; everything else dies with the processes.
    pub fn kill(self) -> FileSystem {
        log_info!("sim", "job {} killed at step {}", self.cfg.job, self.step);
        self.fs
    }

    /// Restart a job from its checkpoint set on `fs`.
    pub fn restart_from(
        cfg: RunConfig,
        engine: Option<Arc<Engine>>,
        mut fs: FileSystem,
    ) -> Result<(JobSim, RestartReport), RestartError> {
        let topo = Topology::new(cfg.ranks, cfg.threads_per_rank);
        let mut report = RestartReport::default();

        // srun with the restart argv — the packet-limit crash lives here.
        let argv = launcher::restart_argv(&cfg.job, cfg.ranks, cfg.fixes.manifest_filenames);
        let launch = launcher::launch(&topo, cfg.link, &argv).map_err(RestartError::Launch)?;
        report.startup_secs = launch.startup_secs;

        // Resolve image paths (manifest fix reads one file; legacy argv
        // carried them directly).
        let paths: Vec<(crate::topology::NodeId, String)> = if cfg.fixes.manifest_filenames {
            let (datas, _) = fs
                .read_parallel(&[(
                    topo.node_of(RankId(0)),
                    CkptManifest::manifest_path(&cfg.job),
                )])
                .map_err(|e| RestartError::Fs(e.to_string()))?;
            let manifest = CkptManifest::decode(&datas[0])
                .ok_or_else(|| RestartError::Fs("bad manifest".into()))?;
            (0..cfg.ranks)
                .map(|r| {
                    let rank = RankId(r);
                    (
                        topo.node_of(rank),
                        manifest
                            .path_for(rank)
                            .unwrap_or(&image_path(&cfg.job, rank))
                            .to_string(),
                    )
                })
                .collect()
        } else {
            (0..cfg.ranks)
                .map(|r| (topo.node_of(RankId(r)), image_path(&cfg.job, RankId(r))))
                .collect()
        };

        // Injected image corruption.
        if let Some((rank, offset)) = cfg.faults.image_bitflip {
            let path = image_path(&cfg.job, RankId(rank));
            fs.corrupt_byte(&path, offset);
        }

        let (datas, io) = fs
            .read_parallel(&paths)
            .map_err(|e| RestartError::Fs(e.to_string()))?;
        report.read_secs = io.duration;

        let split_cfg = SplitConfig {
            os: cfg.os,
            alloc_policy: cfg.fixes.alloc_policy(),
            fd_policy: cfg.fixes.fd_policy(),
            ..SplitConfig::default()
        };
        let mut procs = Vec::with_capacity(cfg.ranks as usize);
        let mut wrappers = ManaWrappers::new(
            WrapperConfig {
                careful_nonblocking: cfg.fixes.careful_nonblocking,
            },
            cfg.ranks,
        );
        let mut job_step = 0u64;
        let mut comms = CommRegistry::new(cfg.ranks);
        for (r, data) in datas.iter().enumerate() {
            let rank = RankId(r as u32);
            let mut img = CkptImage::decode(data)
                .map_err(|e| RestartError::CorruptImage(rank, e))?;
            // Incremental image: pull and resolve its parent full image.
            if let Some(parent_path) = img.parent.clone() {
                let (pdatas, _) = fs
                    .read_parallel(&[(topo.node_of(rank), parent_path)])
                    .map_err(|e| RestartError::Fs(e.to_string()))?;
                let parent = CkptImage::decode(&pdatas[0])
                    .map_err(|e| RestartError::CorruptImage(rank, e))?;
                img = crate::ckpt::resolve_incremental(&img, &parent)
                    .map_err(|e| RestartError::CorruptImage(rank, e))?;
            }
            let mut proc = SplitProcess::restart(&img, split_cfg, cfg.seed)
                .map_err(|e| RestartError::Proc(rank, e.to_string()))?;
            // Re-inflate the drain buffer and drop its pseudo-region.
            if let Some(region) = proc.aspace.table.remove_named("mana.msg_buffer") {
                if let Payload::Real(bytes) = region.payload {
                    wrappers
                        .decode_buffers(rank, &bytes)
                        .ok_or_else(|| {
                            RestartError::CorruptImage(
                                rank,
                                ImageError::Truncated("msg_buffer"),
                            )
                        })?;
                }
            }
            // Rank 0's image carries the communicator log: replay it
            // against the fresh lower-half MPI library.
            if let Some(region) = proc.aspace.table.remove_named("mana.comm_log") {
                if let Payload::Real(bytes) = region.payload {
                    let log = CommRegistry::decode_log(&bytes).ok_or_else(|| {
                        RestartError::CorruptImage(rank, ImageError::Truncated("comm_log"))
                    })?;
                    comms = CommRegistry::replay(cfg.ranks, &log);
                }
            }
            job_step = proc.step;
            procs.push(proc);
        }

        let app = apps::make_app(cfg.app);
        let world = MpiWorld::new(cfg.ranks, Self::make_fabric(&cfg));
        let mut coord = Self::make_coordinator(&cfg);
        coord.stats.restarts += 1;
        report.total_secs = report.startup_secs + report.read_secs;
        let t0 = SimTime::secs(report.total_secs);
        log_info!(
            "sim",
            "restart {}: {} ranks at step {job_step} in {:.2}s (read {:.2}s)",
            cfg.job,
            cfg.ranks,
            report.total_secs,
            report.read_secs
        );
        let times = vec![t0; cfg.ranks as usize];
        Ok((
            JobSim {
                topo,
                app,
                procs,
                world,
                wrappers,
                times,
                fs,
                coord,
                engine,
                comms,
                metrics: {
                    let mut m = crate::metrics::Metrics::new();
                    m.inc("restarts", 1);
                    m.observe("restart.read_secs", report.read_secs);
                    m
                },
                step: job_step,
                lost_halo_events: 0,
                launch_startup_secs: report.startup_secs,
                cfg,
            },
            report,
        ))
    }

    // ------------------------------------------------------------ queries

    /// Global virtual time (slowest rank).
    pub fn now(&self) -> SimTime {
        self.times
            .iter()
            .fold(SimTime::ZERO, |a, &t| a.max(t))
    }

    /// Combined checkpointable-state fingerprint (C/R determinism checks).
    pub fn fingerprint(&self) -> u64 {
        let mut h = 0x4d414e41u64; // "MANA"
        for p in &self.procs {
            h = hash_combine(h, p.fingerprint());
        }
        h
    }

    /// Did any rank detect memory/data corruption?
    pub fn any_corruption(&self) -> bool {
        self.procs.iter().any(|p| p.corrupted)
            || self.wrappers.corrupted_sends > 0
            || self.lost_halo_events > 0
    }

    /// Aggregate upper-half memory across ranks (the Fig. 2 blue line).
    pub fn aggregate_memory(&self) -> u64 {
        self.procs.iter().map(|p| p.upper_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AppKind;

    fn quick_cfg(ranks: u32, steps: u64) -> RunConfig {
        let mut cfg = RunConfig::new(AppKind::Synthetic, ranks);
        cfg.steps = steps;
        cfg.mem_per_rank = Some(1 << 20); // keep tests light
        cfg
    }

    #[test]
    fn run_steps_advances_state_and_time() {
        let mut sim = JobSim::launch(quick_cfg(4, 0), None).unwrap();
        let f0 = sim.fingerprint();
        let t0 = sim.now();
        sim.run_steps(3).unwrap();
        assert_ne!(sim.fingerprint(), f0);
        assert!(sim.now() > t0);
        assert_eq!(sim.step, 3);
        assert!(!sim.any_corruption());
    }

    #[test]
    fn checkpoint_between_steps_succeeds() {
        let mut sim = JobSim::launch(quick_cfg(4, 0), None).unwrap();
        sim.run_steps(2).unwrap();
        let rep = sim.checkpoint().unwrap();
        assert!(rep.total_secs > 0.0);
        assert!(rep.image_bytes > 0);
        // Step-2 halos were in flight: the drain must have buffered them.
        assert!(rep.buffered_msgs > 0, "expected in-flight halos drained");
        assert_eq!(rep.lost_messages, 0);
        assert!(sim.fs.exists("synthetic-4r/ckpt_rank00000.mana"));
    }

    #[test]
    fn ckpt_restart_resumes_bitwise_identical() {
        // Continuous run.
        let mut cont = JobSim::launch(quick_cfg(4, 0), None).unwrap();
        cont.run_steps(6).unwrap();
        let want = cont.fingerprint();

        // Interrupted run: 3 steps, ckpt, kill, restart, 3 more.
        let mut sim = JobSim::launch(quick_cfg(4, 0), None).unwrap();
        sim.run_steps(3).unwrap();
        sim.checkpoint().unwrap();
        let cfg = sim.cfg.clone();
        let fs = sim.kill();
        let (mut resumed, rep) = JobSim::restart_from(cfg, None, fs).unwrap();
        assert_eq!(resumed.step, 3);
        assert!(rep.total_secs > 0.0);
        resumed.run_steps(3).unwrap();
        assert_eq!(
            resumed.fingerprint(),
            want,
            "paper claim: resumed run generates exactly the same results"
        );
        assert!(!resumed.any_corruption());
    }

    #[test]
    fn undrained_checkpoint_loses_messages_and_corrupts_restart() {
        let mut cfg = quick_cfg(4, 0);
        cfg.fixes.drain = false;
        let mut sim = JobSim::launch(cfg, None).unwrap();
        sim.run_steps(3).unwrap();
        let rep = sim.checkpoint().unwrap();
        assert!(rep.lost_messages > 0, "in-flight halos must be dropped");
        let cfg = sim.cfg.clone();
        let fs = sim.kill();
        let (mut resumed, _) = JobSim::restart_from(cfg, None, fs).unwrap();
        resumed.run_steps(2).unwrap();
        assert!(
            resumed.lost_halo_events > 0,
            "lost in-flight messages surface as data loss after restart"
        );
        assert!(resumed.any_corruption());
    }

    #[test]
    fn single_rank_job_has_no_halo_traffic() {
        let mut sim = JobSim::launch(quick_cfg(1, 0), None).unwrap();
        sim.run_steps(4).unwrap();
        assert_eq!(sim.world.total_sent_bytes(), 0);
        let rep = sim.checkpoint().unwrap();
        assert_eq!(rep.buffered_msgs, 0);
    }

    #[test]
    fn incremental_checkpoint_writes_only_dirty_bytes() {
        let mut cfg = quick_cfg(4, 0);
        cfg.incremental = true;
        cfg.mem_per_rank = Some(64 << 20); // 64 MiB heap, tiny live state
        let mut sim = JobSim::launch(cfg, None).unwrap();
        sim.run_steps(1).unwrap();
        let full = sim.checkpoint().unwrap();
        sim.run_steps(1).unwrap();
        let inc = sim.checkpoint().unwrap();
        assert!(
            inc.image_bytes < full.image_bytes / 100,
            "incremental ({}) should be tiny vs full ({})",
            inc.image_bytes,
            full.image_bytes
        );
        assert!(inc.write_secs < full.write_secs);
    }

    #[test]
    fn incremental_restart_is_bitwise_identical() {
        let mut cfg = quick_cfg(4, 0);
        cfg.incremental = true;
        let mut cont = JobSim::launch(cfg.clone(), None).unwrap();
        cont.run_steps(6).unwrap();
        let want = cont.fingerprint();

        let mut sim = JobSim::launch(cfg.clone(), None).unwrap();
        sim.run_steps(2).unwrap();
        sim.checkpoint().unwrap(); // full
        sim.run_steps(2).unwrap();
        sim.checkpoint().unwrap(); // incremental
        let fs = sim.kill();
        let (mut resumed, _) = JobSim::restart_from(cfg, None, fs).unwrap();
        assert_eq!(resumed.step, 4, "must resume from the incremental");
        resumed.run_steps(2).unwrap();
        assert_eq!(resumed.fingerprint(), want);
        assert!(!resumed.any_corruption());
    }

    #[test]
    fn metrics_record_steps_and_checkpoints() {
        let mut sim = JobSim::launch(quick_cfg(4, 0), None).unwrap();
        sim.run_steps(3).unwrap();
        sim.checkpoint().unwrap();
        assert_eq!(sim.metrics.counter("supersteps"), 3);
        assert_eq!(sim.metrics.counter("checkpoints"), 1);
        let s = sim.metrics.summary("ckpt.total_secs");
        assert_eq!(s.count, 1);
        assert!(s.mean() > 0.0);
        let snap = sim.metrics.snapshot().to_string();
        assert!(snap.contains("\"supersteps\":3"), "{snap}");
    }

    #[test]
    fn restart_on_different_node_layout_is_identical() {
        // MANA is network/topology-agnostic: the same 8 ranks can restart
        // packed differently (8 threads/rank -> 8 ranks/node vs 32
        // threads/rank -> 2 ranks/node) and still resume bitwise.
        let mut cfg = quick_cfg(8, 0);
        let mut cont = JobSim::launch(cfg.clone(), None).unwrap();
        cont.run_steps(6).unwrap();
        let want = cont.fingerprint();

        let mut sim = JobSim::launch(cfg.clone(), None).unwrap();
        sim.run_steps(3).unwrap();
        sim.checkpoint().unwrap();
        let fs = sim.kill();
        // Restart with a different rank-per-node packing.
        cfg.threads_per_rank = 32;
        let (mut resumed, _) = JobSim::restart_from(cfg, None, fs).unwrap();
        assert_eq!(resumed.topo.ranks_per_node(), 2);
        resumed.run_steps(3).unwrap();
        assert_eq!(resumed.fingerprint(), want);
        assert!(!resumed.any_corruption());
    }

    #[test]
    fn communicators_survive_restart_via_replay() {
        let mut sim = JobSim::launch(quick_cfg(8, 0), None).unwrap();
        let fp = sim.comms.fingerprint();
        assert!(sim.comms.len() >= 3, "WORLD + dup + node comm(s)");
        sim.run_steps(2).unwrap();
        sim.checkpoint().unwrap();
        let cfg = sim.cfg.clone();
        let fs = sim.kill();
        let (resumed, _) = JobSim::restart_from(cfg, None, fs).unwrap();
        assert_eq!(
            resumed.comms.fingerprint(),
            fp,
            "record-and-replay must rebuild an isomorphic communicator set"
        );
    }

    #[test]
    fn aggregate_memory_counts_all_ranks() {
        let sim = JobSim::launch(quick_cfg(8, 0), None).unwrap();
        let agg = sim.aggregate_memory();
        assert!(agg >= 8 * (1 << 20));
    }
}
