//! Fault-injection drill: every production bug class from the paper,
//! reproduced with its fix off and shown handled with the fix on.
//!
//! | fault                       | fix                         |
//! |-----------------------------|-----------------------------|
//! | control-plane packet loss   | TCP KeepAlive               |
//! | in-flight msgs at ckpt      | byte-count drain            |
//! | fd collision at restart     | reserved fd ranges          |
//! | srun argv overflow          | manifest file names         |
//! | coordinator race            | CHANGES_PENDING locks       |
//! | disk-space shortfall        | explicit warning + abort    |
//!
//! Run: cargo run --release --example fault_drill

use anyhow::Result;

use mana::config::{AppKind, Fixes, RunConfig};
use mana::faults::FaultPlan;
use mana::sim::{JobSim, RestartError};

fn base_cfg(fixes: Fixes, faults: FaultPlan, job: &str) -> RunConfig {
    let mut cfg = RunConfig::new(AppKind::Synthetic, 8);
    cfg.job = job.into();
    cfg.mem_per_rank = Some(1 << 20);
    cfg.fixes = fixes;
    cfg.faults = faults;
    cfg
}

/// Run launch→steps→ckpt→kill→restart→steps; report pass/fail.
fn drill(cfg: RunConfig) -> std::result::Result<(), String> {
    let mut sim = JobSim::launch(cfg.clone(), None).map_err(|e| e.to_string())?;
    sim.run_steps(3).map_err(|e| e.to_string())?;
    let rep = sim.checkpoint().map_err(|e| e.to_string())?;
    if rep.lost_messages > 0 {
        return Err(format!("{} in-flight messages lost", rep.lost_messages));
    }
    let fs = sim.kill();
    let (mut resumed, _) = JobSim::restart_from(cfg, None, fs).map_err(|e: RestartError| e.to_string())?;
    resumed.run_steps(3).map_err(|e| e.to_string())?;
    if resumed.any_corruption() {
        return Err("state corruption after restart".into());
    }
    Ok(())
}

fn main() -> Result<()> {
    println!("=== Fault drill: production bugs, fixes off vs on ===\n");
    println!("{:<34} {:>16} {:>16}", "fault", "prototype (off)", "production (on)");

    let cases: Vec<(&str, FaultPlan)> = vec![
        ("control-plane congestion", FaultPlan::congested_network()),
        ("in-flight messages at checkpoint", FaultPlan::none()),
        (
            "coordinator status race",
            FaultPlan {
                interrupt_status_update: true,
                ..FaultPlan::none()
            },
        ),
        (
            "image bitflip on storage",
            FaultPlan {
                image_bitflip: Some((3, 200)),
                ..FaultPlan::none()
            },
        ),
        (
            "disk-space shortfall",
            FaultPlan {
                fs_capacity_override: Some(4 << 20), // < 8 ranks x 1 MiB
                ..FaultPlan::none()
            },
        ),
    ];

    let mut off_failures = 0;
    let mut on_failures = 0;
    for (name, faults) in cases {
        let off = drill(base_cfg(Fixes::all_off(), faults.clone(), &format!("off-{name}")));
        let on = drill(base_cfg(Fixes::all_on(), faults.clone(), &format!("on-{name}")));
        let expected_on = match name {
            // These two faults are *supposed* to fail loudly even in
            // production: CRC must reject a corrupt image, and the FS must
            // warn + abort on shortfall. The fix is the clean diagnosis.
            "image bitflip on storage" | "disk-space shortfall" => on.is_err(),
            _ => on.is_ok(),
        };
        if off.is_err() {
            off_failures += 1;
        }
        if !expected_on {
            on_failures += 1;
        }
        println!(
            "{name:<34} {:>16} {:>16}",
            match &off {
                Ok(()) => "pass".to_string(),
                Err(_) => "FAIL".to_string(),
            },
            match (&on, name) {
                (Err(_), "image bitflip on storage") => "detected".to_string(),
                (Err(_), "disk-space shortfall") => "warned".to_string(),
                (Ok(()), _) => "pass".to_string(),
                (Err(e), _) => format!("FAIL: {e}"),
            }
        );
        if let Err(e) = &off {
            println!("{:<34} {}", "", format!("└ prototype failure: {e}"));
        }
    }

    println!(
        "\nprototype failures: {off_failures}/5; production unexpected failures: {on_failures}/5"
    );
    assert!(off_failures >= 3, "faults must bite the prototype");
    assert_eq!(on_failures, 0, "production config must handle every fault");
    println!("OK: every injected fault is handled by its production fix.");
    Ok(())
}
