//! STAGED — Fig. 2's storage-tier comparison with a third "staged" series:
//! the tiered BB→Lustre engine with asynchronous drain.
//!
//! The paper's headline (HPCG at 512 ranks, 5.8 TB): BB ≈ 30 s vs Lustre
//! > 600 s synchronous checkpoint write. The staged engine's claim: the
//! rank-visible stall stays at Burst-Buffer speed while every image still
//! becomes durable on Lustre — the PFS write is overlapped with compute
//! (SCR-style multi-level checkpointing), separating *checkpoint stall*
//! from *background drain*.
//!
//! Asserted here (the PR's acceptance criteria):
//!   * staged stall ≤ 2x pure-BB stall at every scale;
//!   * staged stall > 5x below the pure-Lustre synchronous write at 512
//!     ranks, with images durable on the Lustre tier afterwards;
//!   * restart succeeds from either tier, including CRC fallback to the
//!     durable tier after a corrupted fast-tier image;
//!   * **dedup series**: repeated full checkpoints of a mostly-clean
//!     512-rank address space drain ≤ 25% of the logical image bytes
//!     physically from generation 2 on (content-addressed chunk store),
//!     and a controlled ~10%-dirty workload drains near its dirty
//!     fraction — while restart from the durable tier alone still
//!     reproduces byte-identical, CRC-clean images;
//!   * **insertion series** (shift resistance): each generation inserts a
//!     few KiB mid-region. Content-defined chunking must dedup ≥ 70% of
//!     the drained bytes per steady-state generation while fixed tiling
//!     dedups < 20% on the same trace.
//!
//! Results are written to BENCH_staged_drain.json; the CI bench-report
//! job gates on the `staged_cdc_insertion_dedup` /
//! `staged_fixed_insertion_dedup` values against checked-in baselines.

use mana::benchkit::{fsecs, Report};
use mana::ckpt::{gen_image_path, ChunkRecipe, Chunking};
use mana::config::{AppKind, RunConfig};
use mana::fs::{FileSystem, FsConfig, FsKind, TieredStore, WriteReq};
use mana::sim::JobSim;
use mana::topology::{NodeId, RankId};
use mana::trace::critical_path::{critical_path, top_k_summary};
use mana::util::bytes::human;
use mana::util::json::Json;
use mana::util::prng::SplitMix64;

/// ≈5.8 TB aggregate at 512 ranks (the paper's HPCG footprint).
const MEM_PER_RANK: u64 = 11_328_000_000;

enum Mode {
    Bb,
    Lustre,
    Staged,
}

impl Mode {
    fn tag(&self) -> &'static str {
        match self {
            Mode::Bb => "bb",
            Mode::Lustre => "lustre",
            Mode::Staged => "staged",
        }
    }
}

fn cfg_for(ranks: u32, mode: &Mode) -> RunConfig {
    let mut cfg = RunConfig::new(AppKind::Synthetic, ranks);
    cfg.job = format!("staged-{ranks}-{}", mode.tag());
    cfg.mem_per_rank = Some(MEM_PER_RANK);
    // Span tracing on, so the stall rows can name what gated them (the
    // trace bench gates the overhead at <= 3%).
    cfg.trace = true;
    match mode {
        Mode::Bb => cfg.fs = FsKind::BurstBuffer,
        Mode::Lustre => cfg.fs = FsKind::Lustre,
        Mode::Staged => {
            cfg = cfg.with_staging();
            // Coarse dedup granularity for the 11 GB/rank stall series:
            // the stall assertions don't exercise dedup, and 8 MiB chunks
            // keep the 512-rank chunk index small (the fine-grained dedup
            // series below runs at the default 1 MiB).
            cfg.chunk_bytes = 8 << 20;
        }
    }
    cfg
}

struct Point {
    /// Rank-visible checkpoint stall (write phase).
    stall: f64,
    /// Durable-tier busy seconds spent off the critical path.
    drain_bg: f64,
    /// Top-3 critical-path charges of the checkpoint, from the span record.
    top3: String,
}

fn measure(ranks: u32, mode: Mode) -> Point {
    let cfg = cfg_for(ranks, &mode);
    let mut sim = JobSim::launch(cfg, None).expect("launch");
    sim.run_steps(2).expect("steps");
    let rep = sim.checkpoint().expect("ckpt");
    let top3 = top_k_summary(&critical_path(&sim.tracer.spans(), 0), 3);
    let mut drain_bg = 0.0;
    if matches!(mode, Mode::Staged) {
        assert!(rep.drain_pending_bytes > 0, "staged ckpt must queue a drain");
        // The stall decomposes into the per-tier report fields.
        assert!(
            (rep.write_secs - (rep.fast_write_secs + rep.durable_write_secs)).abs()
                < 1e-9,
            "stall must equal fast wave + backpressure"
        );
        // The drain progresses in the background while ranks compute…
        sim.run_steps(2).expect("post-ckpt steps");
        assert!(
            sim.fs.tiered().unwrap().stats.drained_bytes > 0,
            "background drain must progress across supersteps"
        );
        // …and the remainder is forced through for the durability check.
        drain_bg = sim.finish_drain();
        let ts = sim.fs.tiered().unwrap();
        assert_eq!(ts.pending_bytes(), 0);
        assert_eq!(ts.pending_files(), 0);
        assert!(
            ts.is_durable(&gen_image_path(&sim.cfg.job, 0, RankId(0))),
            "image must be durable on the Lustre tier"
        );
    }
    Point {
        stall: rep.write_secs,
        drain_bg,
        top3,
    }
}

/// Restart from the fast tier, then again after corrupting a fast-tier
/// image post-drain: the engine must fall back to the durable copy.
fn restart_checks() {
    let cfg = cfg_for(64, &Mode::Staged);
    let mut sim = JobSim::launch(cfg.clone(), None).expect("launch");
    sim.run_steps(2).expect("steps");
    sim.checkpoint().expect("ckpt");
    let want = sim.fingerprint();
    let fs = sim.kill();
    let (mut resumed, rrep) =
        JobSim::restart_from(cfg.clone(), None, fs).expect("restart from fast tier");
    assert_eq!(rrep.tier_fallbacks, 0, "clean fast tier needs no fallback");
    assert_eq!(rrep.rebuilt_nodes, 0, "no-fault restart must not rebuild");
    assert_eq!(rrep.generation_rewound, 0, "no-fault restart must not rewind");
    assert_eq!(resumed.fingerprint(), want, "fast-tier restart bitwise");

    let mut sim = JobSim::launch(cfg.clone(), None).expect("launch");
    sim.run_steps(2).expect("steps");
    sim.checkpoint().expect("ckpt");
    let want = sim.fingerprint();
    sim.finish_drain();
    let path = gen_image_path(&cfg.job, 0, RankId(3));
    assert!(
        sim.fs
            .tiered_mut()
            .unwrap()
            .fast_mut()
            .corrupt_byte(&path, 200),
        "corruption target must exist on the fast tier"
    );
    let fs = sim.kill();
    let (mut resumed, rrep) = JobSim::restart_from(cfg, None, fs)
        .expect("restart must survive a corrupt fast-tier image");
    assert!(rrep.tier_fallbacks >= 1, "rank 3 must fall back to Lustre");
    assert_eq!(rrep.rebuilt_nodes, 0, "no redundancy configured: no rebuild");
    assert_eq!(rrep.generation_rewound, 0, "durable fallback must not rewind");
    assert_eq!(resumed.fingerprint(), want, "fallback restart bitwise");
    println!(
        "restart OK: fast-tier restart + CRC fallback to the durable tier \
         ({} fallback reads)",
        rrep.tier_fallbacks
    );
}

/// Dedup acceptance at 512 ranks: repeated full checkpoints of a
/// mostly-clean address space (the synthetic app dirties only its tiny
/// state region per superstep; the big pattern heap stays clean). From
/// generation 2 on, the physical durable-tier drain bytes must be ≤ 25%
/// of the logical image bytes, and restart must succeed from the durable
/// tier alone with a byte-identical image.
fn dedup_512_ranks() -> Json {
    let mut cfg = cfg_for(512, &Mode::Staged);
    cfg.job = "staged-dedup-512".into();
    cfg.mem_per_rank = Some(256 << 20); // 128 GB aggregate, 1 MiB chunks
    cfg.chunk_bytes = 1 << 20;
    let mut rep = Report::new(
        "STAGED-DEDUP: 512 ranks, repeated full ckpts, mostly-clean memory",
        vec![
            "gen",
            "logical",
            "physical",
            "deduped",
            "dedup_ratio",
        ],
    );
    let mut sim = JobSim::launch(cfg.clone(), None).expect("launch");
    sim.run_steps(1).expect("steps");
    let mut prev_drained = 0u64;
    for gen in 0..3u64 {
        let crep = sim.checkpoint().expect("ckpt");
        sim.finish_drain();
        let drained = sim.fs.tiered().unwrap().stats.drained_bytes;
        let physical = drained - prev_drained;
        prev_drained = drained;
        rep.row(vec![
            gen.to_string(),
            human(crep.image_bytes),
            human(physical),
            human(crep.deduped_bytes),
            format!("{:.1}%", crep.dedup_ratio() * 100.0),
        ]);
        if gen >= 1 {
            assert!(
                physical <= crep.image_bytes / 4,
                "gen {gen}: physical drain {} exceeds 25% of logical {}",
                human(physical),
                human(crep.image_bytes)
            );
            assert!(crep.deduped_bytes > 0, "gen {gen} must dedup");
        }
        sim.run_steps(1).expect("steps");
    }
    let table = rep.finish_json();

    // Byte-identical restart from the durable tier alone: wipe the fast
    // tier entirely, reassemble every image from chunk objects.
    let want = {
        let mut cont = JobSim::launch(cfg.clone(), None).expect("launch");
        // Checkpoints landed after steps 1, 2, 3; the last one resumes at
        // step 3, and the interrupted run took one more step after it.
        cont.run_steps(4).expect("steps");
        cont.fingerprint()
    };
    {
        let ts = sim.fs.tiered_mut().unwrap();
        for p in ts.fast().paths() {
            ts.fast_mut().delete(&p).expect("fast delete");
        }
        assert_eq!(ts.fast().file_count(), 0, "fast tier fully lost");
    }
    let fs = sim.kill();
    let (mut resumed, rrep) = JobSim::restart_from(cfg, None, fs)
        .expect("restart must reassemble images from the chunk store");
    assert_eq!(resumed.step, 3, "resumes from the last generation");
    assert!(rrep.read_secs > 0.0);
    resumed.run_steps(1).expect("post-restart step");
    assert_eq!(
        resumed.fingerprint(),
        want,
        "durable-only restart must be byte-identical (CRC-clean decode)"
    );
    println!(
        "DEDUP OK: gen>=2 physical drain <= 25% of logical; durable-only \
         restart byte-identical"
    );
    table
}

/// Controlled dedup series: a raw ~10%-dirty-per-generation workload on
/// the tiered store directly. Physical durable-tier bytes per drain must
/// fall to near the dirty fraction of the logical bytes.
fn dedup_dirty_fraction_series() -> Json {
    // Small real buffers (the dedup math is scale-free): 8 files x 64
    // chunks x 64 KiB = 32 MiB logical per generation.
    const CHUNK: usize = 64 << 10;
    const CHUNKS_PER_FILE: usize = 64;
    const FILES: u32 = 8;
    const DIRTY_PER_GEN: usize = 6; // ~10% of 64 chunks
    let gens = 5u64;

    let mut bb = FsConfig::burst_buffer(4);
    bb.capacity = 1 << 40;
    let mut ts = TieredStore::new(
        FileSystem::new(bb),
        FileSystem::new(FsConfig::cscratch()),
        gens as usize + 1,
        4,
    );
    let mut rep = Report::new(
        "STAGED-DEDUP: ~10% dirty chunks per generation (raw tiered store)",
        vec!["gen", "logical", "physical", "deduped", "dedup_ratio"],
    );
    // Avalanche-quality bytes (per-file SplitMix64 stream) so every
    // chunk-sized window is distinct — a short-period pattern would alias
    // chunks and fake extra dedup.
    let mut datas: Vec<Vec<u8>> = (0..FILES)
        .map(|f| {
            let mut sm = SplitMix64::new(f as u64);
            let mut out = Vec::with_capacity(CHUNKS_PER_FILE * CHUNK + 8);
            while out.len() < CHUNKS_PER_FILE * CHUNK {
                out.extend_from_slice(&sm.next_u64().to_le_bytes());
            }
            out.truncate(CHUNKS_PER_FILE * CHUNK);
            out
        })
        .collect();
    let logical = (FILES as u64) * (CHUNKS_PER_FILE * CHUNK) as u64;
    let mut prev_drained = 0u64;
    let mut prev_deduped = 0u64;
    for gen in 0..gens {
        if gen > 0 {
            // Dirty ~10% of each file's chunks (one byte is enough to
            // change the chunk's content digest).
            for data in &mut datas {
                for d in 0..DIRTY_PER_GEN {
                    let off = (d * (CHUNKS_PER_FILE / DIRTY_PER_GEN) * CHUNK
                        + gen as usize)
                        % data.len();
                    data[off] ^= 0xA5;
                }
            }
        }
        ts.begin_ckpt(gen as f64 * 100.0);
        let reqs: Vec<WriteReq> = datas
            .iter()
            .enumerate()
            .map(|(f, data)| WriteReq {
                node: NodeId(f as u32 % 4),
                path: format!("gen{gen}/f{f}"),
                virtual_bytes: data.len() as u64,
                data: data.clone(),
                recipe: Some(ChunkRecipe::from_data(data, CHUNK, data.len() as u64)),
            })
            .collect();
        ts.write_wave(reqs).expect("wave");
        ts.drain_sync();
        let physical = ts.stats.drained_bytes - prev_drained;
        let deduped = ts.stats.deduped_bytes - prev_deduped;
        prev_drained = ts.stats.drained_bytes;
        prev_deduped = ts.stats.deduped_bytes;
        let ratio = deduped as f64 / logical as f64;
        rep.row(vec![
            gen.to_string(),
            human(logical),
            human(physical),
            human(deduped),
            format!("{:.1}%", ratio * 100.0),
        ]);
        if gen == 0 {
            assert_eq!(physical, logical, "gen 0 ships every byte");
        } else {
            let dirty_fraction = physical as f64 / logical as f64;
            assert!(
                dirty_fraction < 0.15,
                "gen {gen}: physical drain fraction {dirty_fraction:.2} \
                 not near the ~10% dirty fraction"
            );
            assert!(ratio > 0.85, "gen {gen}: dedup ratio {ratio:.2} too low");
        }
    }
    let table = rep.finish_json();
    println!(
        "DEDUP OK: physical drain per generation fell to the dirty fraction \
         ({} unique chunks indexed)",
        ts.chunk_store().chunk_count()
    );
    table
}

/// Insertion-heavy series (the shift-resistance acceptance): every
/// generation inserts a few KiB mid-region before checkpointing. Fixed
/// tiling re-keys every chunk downstream of the edit, so its dedup
/// collapses to the prefix fraction; content-defined boundaries
/// resynchronize and re-use everything outside the edit window. Both
/// modes run the *identical* content trace.
///
/// Returns (rows, cdc_min_ratio, fixed_max_ratio): the worst steady-state
/// CDC dedup ratio (gate: >= 0.70) and the best steady-state fixed ratio
/// (gate: < 0.20).
fn dedup_insertion_series() -> (Json, f64, f64) {
    const AVG: usize = 16 << 10;
    const BASE_LEN: usize = 64 * AVG; // 1 MiB logical at gen 0
    /// Deliberately not a multiple of AVG (and no small sum of copies is):
    /// a stride-aligned insertion would let the fixed grid re-align by
    /// accident and mask the collapse this series demonstrates.
    const INS_LEN: usize = 4093;
    let gens = 4u64;
    let mut rep = Report::new(
        "STAGED-DEDUP: insertion-heavy generations (4 KiB mid-region), fixed vs cdc",
        vec!["mode", "gen", "logical", "physical", "deduped", "dedup_ratio"],
    );
    let mut jrows = Json::Arr(vec![]);
    let mut cdc_min = 1.0f64;
    let mut fixed_max = 0.0f64;
    for mode in ["fixed", "cdc"] {
        let chunking = if mode == "fixed" {
            Chunking::Fixed(AVG)
        } else {
            Chunking::cdc(AVG)
        };
        let mut bb = FsConfig::burst_buffer(4);
        bb.capacity = 1 << 40;
        let mut ts = TieredStore::new(
            FileSystem::new(bb),
            FileSystem::new(FsConfig::cscratch()),
            gens as usize + 1,
            4,
        );
        // Identical deterministic trace per mode: same base bytes, same
        // insertions in the same order.
        let mut sm = SplitMix64::new(0xA5EED);
        let mut fill = |n: usize| -> Vec<u8> {
            let mut out = Vec::with_capacity(n + 8);
            while out.len() < n {
                out.extend_from_slice(&sm.next_u64().to_le_bytes());
            }
            out.truncate(n);
            out
        };
        let mut data = fill(BASE_LEN);
        for gen in 0..gens {
            if gen > 0 {
                // Insert fresh bytes an eighth of the way in, sliding a
                // little each generation (never chunk-aligned).
                let at = data.len() / 8 + gen as usize * 37;
                let ins = fill(INS_LEN);
                let tail = data.split_off(at);
                data.extend_from_slice(&ins);
                data.extend_from_slice(&tail);
            }
            ts.begin_ckpt(gen as f64 * 100.0);
            let io = ts
                .write_wave(vec![WriteReq {
                    node: NodeId(0),
                    path: format!("{mode}/gen{gen}/f0"),
                    virtual_bytes: data.len() as u64,
                    data: data.clone(),
                    recipe: Some(ChunkRecipe::from_data_chunked(
                        &data,
                        &chunking,
                        data.len() as u64,
                    )),
                }])
                .expect("wave");
            ts.drain_sync();
            let logical = data.len() as u64;
            let physical = logical - io.deduped_bytes;
            let ratio = io.deduped_bytes as f64 / logical as f64;
            rep.row(vec![
                mode.to_string(),
                gen.to_string(),
                human(logical),
                human(physical),
                human(io.deduped_bytes),
                format!("{:.1}%", ratio * 100.0),
            ]);
            jrows.push(
                Json::obj()
                    .set("mode", mode)
                    .set("gen", gen)
                    .set("logical_bytes", logical)
                    .set("physical_bytes", physical)
                    .set("deduped_bytes", io.deduped_bytes)
                    .set("dedup_ratio", ratio),
            );
            if gen > 0 {
                if mode == "cdc" {
                    cdc_min = cdc_min.min(ratio);
                } else {
                    fixed_max = fixed_max.max(ratio);
                }
            }
        }
    }
    rep.finish();
    assert!(
        cdc_min >= 0.70,
        "CDC must dedup >= 70% of drained bytes per steady-state insertion \
         generation (worst {cdc_min:.2})"
    );
    assert!(
        fixed_max < 0.20,
        "fixed tiling must collapse below 20% dedup on the insertion trace \
         (best {fixed_max:.2})"
    );
    println!(
        "INSERTION OK: cdc worst steady-state dedup {:.1}% vs fixed best {:.1}%",
        cdc_min * 100.0,
        fixed_max * 100.0
    );
    (jrows, cdc_min, fixed_max)
}

fn main() {
    let mut rep = Report::new(
        "STAGED: checkpoint stall by storage mode (Fig. 2 shape + staged series)",
        vec![
            "ranks",
            "nodes",
            "aggregate",
            "bb_stall_s",
            "staged_stall_s",
            "lustre_stall_s",
            "staged/bb",
            "lustre/staged",
            "bg_drain_s",
            "staged_critical_path_top3",
        ],
    );
    let mut rows = Vec::new();
    for &ranks in &[64u32, 128, 256, 512] {
        let bb = measure(ranks, Mode::Bb);
        let staged = measure(ranks, Mode::Staged);
        let lustre = measure(ranks, Mode::Lustre);
        rows.push((ranks, bb.stall, staged.stall, lustre.stall));
        rep.row(vec![
            ranks.to_string(),
            ranks.div_ceil(8).to_string(),
            human(MEM_PER_RANK * ranks as u64),
            fsecs(bb.stall),
            fsecs(staged.stall),
            fsecs(lustre.stall),
            format!("{:.2}x", staged.stall / bb.stall),
            format!("{:.1}x", lustre.stall / staged.stall),
            fsecs(staged.drain_bg),
            staged.top3.clone(),
        ]);
    }
    let stall_table = rep.finish_json();

    for &(ranks, bb, staged, lustre) in &rows {
        assert!(
            staged <= bb * 2.0,
            "{ranks} ranks: staged stall {staged:.1}s exceeds 2x BB {bb:.1}s"
        );
        assert!(
            staged < lustre,
            "{ranks} ranks: staged stall {staged:.1}s not below Lustre {lustre:.1}s"
        );
    }
    let &(_, _, staged512, lustre512) = rows.last().expect("512-rank row");
    assert!(
        lustre512 / staged512 > 5.0,
        "512 ranks: lustre/staged = {:.1}x (want > 5x)",
        lustre512 / staged512
    );
    restart_checks();
    let dedup_table = dedup_512_ranks();
    let dirty_table = dedup_dirty_fraction_series();
    let (insertion_rows, cdc_min, fixed_max) = dedup_insertion_series();

    let out = Json::obj()
        .set("bench", "staged_drain")
        .set(
            "gates",
            Json::obj()
                .set("staged_cdc_insertion_dedup", cdc_min)
                .set("staged_fixed_insertion_dedup", fixed_max)
                .set("staged_lustre_over_staged_512", lustre512 / staged512),
        )
        .set("rows", insertion_rows)
        .set(
            "series",
            Json::Arr(vec![stall_table, dedup_table, dirty_table]),
        );
    std::fs::write("BENCH_staged_drain.json", out.to_string())
        .expect("write BENCH_staged_drain.json");
    println!(
        "STAGED OK: async BB->Lustre staging hides the PFS write from ranks \
         (results in BENCH_staged_drain.json)"
    );
}
