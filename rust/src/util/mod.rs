//! Shared infrastructure: virtual time, PRNG, logging, JSON, byte units.
//!
//! Everything in the simulator runs on *virtual* time ([`simclock`]) so a
//! laptop can regenerate the paper's 600-second Lustre checkpoints
//! deterministically. All randomness flows from [`prng`] seeds carried in
//! the run config — never from the wall clock.

pub mod bytes;
pub mod cdc;
pub mod crc32;
pub mod digest;
pub mod json;
pub mod logging;
pub mod prng;
pub mod simclock;

/// Stable 64-bit FNV-1a hash, used for state fingerprints (the bitwise
/// determinism checks behind the paper's "exactly the same results" claim).
pub fn fnv1a(data: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Hash a slice of f32s via their bit patterns (only bitwise identity
/// matters for determinism checks).
pub fn fnv1a_f32(data: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &v in data {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// Combine two hashes (order-dependent).
pub fn hash_combine(a: u64, b: u64) -> u64 {
    a ^ b
        .wrapping_add(0x9e3779b97f4a7c15)
        .wrapping_add(a << 6)
        .wrapping_add(a >> 2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_is_stable() {
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a(b"a"), fnv1a(b"a"));
        assert_ne!(fnv1a(b"a"), fnv1a(b"b"));
    }

    #[test]
    fn fnv1a_f32_matches_byte_hash() {
        let v = [1.5f32, -2.25, 0.0];
        let mut bytes = Vec::new();
        for x in &v {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        assert_eq!(fnv1a_f32(&v), fnv1a(&bytes));
    }

    #[test]
    fn hash_combine_order_dependent() {
        assert_ne!(hash_combine(1, 2), hash_combine(2, 1));
    }
}
