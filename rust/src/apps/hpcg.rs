//! HPCG analog: conjugate gradient on the 27-point stencil operator.
//!
//! The paper's in-text table workload: 512 ranks x 8 threads, 5.8 TB
//! aggregate memory; checkpoint 30 s on Burst Buffers vs >600 s on
//! CSCRATCH. Per-rank compute is the `cg_step` artifact — one CG iteration
//! whose SpMV is the L1 Pallas stencil kernel. The default per-rank
//! footprint is 5.8 TB / 512 so the 512-rank bench writes exactly the
//! paper's aggregate.

use anyhow::{Context, Result};

use super::{bytes_to_f32, f32_to_bytes, map_common_regions, synth_evolve, App, StepCtx};
use crate::config::{AppKind, ComputeMode};
use crate::mem::Payload;
use crate::splitproc::SplitProcess;

/// Local grid (matches python/compile/model.py::CG_GRID).
pub const GRID: usize = 16;
const N: usize = GRID * GRID * GRID;

pub struct Hpcg;

impl App for Hpcg {
    fn kind(&self) -> AppKind {
        AppKind::Hpcg
    }

    fn artifact(&self) -> Option<&'static str> {
        Some("cg_step")
    }

    fn default_mem_per_rank(&self) -> u64 {
        5_800_000_000_000 / 512 // the paper's 5.8 TB aggregate at 512 ranks
    }

    fn compute_secs(&self) -> f64 {
        0.6
    }

    fn init(&self, proc: &mut SplitProcess, _ranks: u32, mem_per_rank: u64) -> Result<()> {
        // b random; x0 = 0; r0 = b; p0 = r0; rz0 = <r0, r0>.
        let mut b = Vec::with_capacity(N);
        for _ in 0..N {
            b.push(proc.rng.next_f32() - 0.5);
        }
        let x = vec![0.0f32; N];
        let rz: f32 = b.iter().map(|v| v * v).sum();
        let state_bytes = (3 * N + 1) as u64 * 4;
        proc.map_app_region("x", (N * 4) as u64, Payload::Real(f32_to_bytes(&x)))?;
        proc.map_app_region("r", (N * 4) as u64, Payload::Real(f32_to_bytes(&b)))?;
        proc.map_app_region("p", (N * 4) as u64, Payload::Real(f32_to_bytes(&b)))?;
        proc.map_app_region("rz", 4, Payload::Real(f32_to_bytes(&[rz])))?;
        map_common_regions(proc, mem_per_rank, state_bytes)?;
        proc.open_app_fd("hpcg_output.yaml");
        Ok(())
    }

    fn compute(&self, ctx: &mut StepCtx) -> Result<()> {
        match ctx.mode {
            ComputeMode::Real => {
                let x = bytes_to_f32(ctx.proc.app_state("x").context("x")?);
                let r = bytes_to_f32(ctx.proc.app_state("r").context("r")?);
                let p = bytes_to_f32(ctx.proc.app_state("p").context("p")?);
                let rz = bytes_to_f32(ctx.proc.app_state("rz").context("rz")?);
                let out = ctx.engine()?.run("cg_step", &[&x, &r, &p, &rz])?;
                ctx.proc.store_app_state("x", f32_to_bytes(&out[0]))?;
                ctx.proc.store_app_state("r", f32_to_bytes(&out[1]))?;
                ctx.proc.store_app_state("p", f32_to_bytes(&out[2]))?;
                ctx.proc.store_app_state("rz", f32_to_bytes(&out[3]))?;
                // out[4] is the residual — exposed for convergence logging.
            }
            ComputeMode::Synthetic => {
                let mut b = ctx.proc.app_state("x").context("x")?.to_vec();
                synth_evolve(&mut b);
                ctx.proc.store_app_state("x", b)?;
            }
        }
        Ok(())
    }
}

impl Hpcg {
    /// Current residual sqrt(<r,r>) — convergence telemetry for examples.
    pub fn residual(proc: &SplitProcess) -> Option<f32> {
        let rz = bytes_to_f32(proc.app_state("rz")?);
        Some(rz[0].sqrt())
    }
}
