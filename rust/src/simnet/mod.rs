//! Simulated networks: the DMTCP control plane and the Cray-GNI-like data
//! fabric.
//!
//! Two distinct networks, matching the paper's failure taxonomy:
//!
//! * [`control`] — the coordinator's TCP connections to every rank.
//!   "Network congestion on the production machine at times caused packet
//!   losses and disconnects. The TCP KeepAlive option was added to solve
//!   this problem."
//! * [`fabric`] — the high-speed interconnect MPI rides on. "Network delays
//!   due to quiescence of the Cray GNI network reconfiguring itself brought
//!   additional bugs to the surface."

pub mod control;
pub mod fabric;
