//! VASP/RPA analog: chi0 frequency-quadrature accumulation.
//!
//! VASP is NERSC's top application (>20% of all cycles, Fig. 1), and its
//! RPA jobs are the paper's marquee use case: "The RPA jobs can run for
//! much longer than 48 hours, the max walltime allowed on Cori. In the past
//! we had to make special reservations for these jobs, now they can run on
//! Cori by checkpointing/restarting with MANA."
//!
//! Each superstep is one quadrature point: chi += w_i * occ @ virt^T via
//! the `rpa_step` artifact (L1 Pallas MXU-tiled matmul), and costs one
//! virtual *hour* — so a 60-point quadrature exceeds the 48 h walltime and
//! must span multiple jobs via C/R (examples/vasp_rpa.rs).

use anyhow::{Context, Result};

use super::{bytes_to_f32, f32_to_bytes, map_common_regions, synth_evolve, App, StepCtx};
use crate::config::{AppKind, ComputeMode};
use crate::mem::Payload;
use crate::splitproc::SplitProcess;

/// Block dims (match python/compile/model.py::RPA_{M,N,K}).
pub const M: usize = 256;
pub const K: usize = 256;

pub struct VaspRpa;

impl App for VaspRpa {
    fn kind(&self) -> AppKind {
        AppKind::VaspRpa
    }

    fn artifact(&self) -> Option<&'static str> {
        Some("rpa_step")
    }

    fn default_mem_per_rank(&self) -> u64 {
        4 << 30 // 4 GiB: typical VASP RPA per-rank footprint
    }

    fn compute_secs(&self) -> f64 {
        3600.0 // one quadrature point per virtual hour
    }

    fn init(&self, proc: &mut SplitProcess, _ranks: u32, mem_per_rank: u64) -> Result<()> {
        let mut occ = Vec::with_capacity(M * K);
        let mut virt = Vec::with_capacity(M * K);
        for _ in 0..M * K {
            occ.push((proc.rng.next_f32() - 0.5) * 0.1);
            virt.push((proc.rng.next_f32() - 0.5) * 0.1);
        }
        let chi = vec![0.0f32; M * M];
        let state_bytes = ((occ.len() + virt.len() + chi.len() + 2) * 4) as u64;
        proc.map_app_region("occ", (M * K * 4) as u64, Payload::Real(f32_to_bytes(&occ)))?;
        proc.map_app_region("virt", (M * K * 4) as u64, Payload::Real(f32_to_bytes(&virt)))?;
        proc.map_app_region("chi", (M * M * 4) as u64, Payload::Real(f32_to_bytes(&chi)))?;
        proc.map_app_region("ecorr", 4, Payload::Real(vec![0u8; 4]))?;
        map_common_regions(proc, mem_per_rank, state_bytes)?;
        // WAVECAR-analog output file.
        proc.open_app_fd("WAVECAR");
        Ok(())
    }

    fn compute(&self, ctx: &mut StepCtx) -> Result<()> {
        match ctx.mode {
            ComputeMode::Real => {
                let occ = bytes_to_f32(ctx.proc.app_state("occ").context("occ")?);
                let virt = bytes_to_f32(ctx.proc.app_state("virt").context("virt")?);
                let chi = bytes_to_f32(ctx.proc.app_state("chi").context("chi")?);
                // Gauss-Legendre-ish weight for this quadrature point.
                let i = ctx.proc.step as f32;
                let w = [1.0 / (1.0 + i * i * 0.01)];
                let out = ctx.engine()?.run("rpa_step", &[&occ, &virt, &chi, &w])?;
                ctx.proc.store_app_state("chi", f32_to_bytes(&out[0]))?;
                ctx.proc.store_app_state("ecorr", f32_to_bytes(&out[1]))?;
            }
            ComputeMode::Synthetic => {
                let mut b = ctx.proc.app_state("chi").context("chi")?.to_vec();
                synth_evolve(&mut b);
                ctx.proc.store_app_state("chi", b)?;
            }
        }
        Ok(())
    }
}

impl VaspRpa {
    /// Running correlation-energy surrogate (telemetry for examples).
    pub fn ecorr(proc: &SplitProcess) -> Option<f32> {
        Some(bytes_to_f32(proc.app_state("ecorr")?)[0])
    }
}
