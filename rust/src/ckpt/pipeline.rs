//! Deterministic stall model for the pipelined checkpoint path.
//!
//! The simulator overlaps the encode wave with the burst-buffer write
//! wave: as each rank's encode finishes it is admitted to the write
//! stream instead of waiting for the whole wave. Virtual time must stay
//! reproducible across hosts and thread schedules, so the overlap is
//! *modeled* here from per-rank encode costs rather than measured from
//! host thread completion order: the same table contents always yield
//! the same stall, byte-identical images, and the same report.
//!
//! The model has two halves:
//!
//! * [`finish_times`] replays the encode scheduler ([`div_ceil`]
//!   contiguous rank blocks per worker, exactly like
//!   `datapath::encode_wave_streaming`) to get each rank's virtual
//!   encode-finish time and the encode wall clock.
//! * [`pipelined_write_stall`] runs a work-conserving single-server
//!   queue over those finish times: the write stream serves ranks in
//!   encode-completion order, each taking its bytes-proportional share
//!   of the wave's write seconds. The result provably lands in
//!   `[max(encode, write), encode + write]` — the two ends of the
//!   pipelining spectrum.

/// Per-rank virtual encode cost, harvested from the real encode.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EncodeCost {
    /// Payload bytes that were actually hashed (CRC32 / digest work).
    /// Cache hits and chunk-granular partial hits shrink this — which is
    /// exactly how warm generations get shorter encode stalls.
    pub hash_vbytes: u64,
    /// Encoded bytes memcpy'd into the image (splice cost of hits).
    pub copy_bytes: u64,
}

/// Modeled hash throughput (CRC32 + digest), bytes per virtual second.
pub const HASH_BYTES_PER_SEC: f64 = 2.0e9;
/// Modeled splice/memcpy throughput, bytes per virtual second.
pub const COPY_BYTES_PER_SEC: f64 = 12.0e9;
/// Fixed per-rank encode overhead (capture, framing, bookkeeping).
pub const RANK_OVERHEAD_SECS: f64 = 1.0e-4;

/// Virtual seconds one rank's encode takes in isolation.
pub fn encode_secs(c: &EncodeCost) -> f64 {
    RANK_OVERHEAD_SECS
        + c.hash_vbytes as f64 / HASH_BYTES_PER_SEC
        + c.copy_bytes as f64 / COPY_BYTES_PER_SEC
}

/// Replay the encode wave's worker schedule: `workers` threads each own a
/// contiguous `div_ceil` block of ranks and run them in order. Returns
/// each rank's virtual finish time plus the wave's encode wall clock
/// (the slowest worker's total).
pub fn finish_times(costs: &[EncodeCost], workers: usize) -> (Vec<f64>, f64) {
    let n = costs.len();
    let mut finish = vec![0.0f64; n];
    if n == 0 {
        return (finish, 0.0);
    }
    let workers = workers.max(1);
    let per = n.div_ceil(workers);
    let mut wall = 0.0f64;
    for (w, block) in costs.chunks(per).enumerate() {
        let mut t = 0.0f64;
        for (k, c) in block.iter().enumerate() {
            t += encode_secs(c);
            finish[w * per + k] = t;
        }
        wall = wall.max(t);
    }
    (finish, wall)
}

/// Work-conserving single-server write queue over the encode finish
/// times: ranks are admitted in encode-completion order (ties broken by
/// rank index, so the result is deterministic) and each takes its
/// bytes-proportional share of `write_secs`. Returns the stall — the
/// virtual time from wave start until the last write completes.
pub fn pipelined_write_stall(finish: &[f64], weights: &[u64], write_secs: f64) -> f64 {
    let n = finish.len();
    if n == 0 {
        return write_secs.max(0.0);
    }
    debug_assert_eq!(n, weights.len());
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| finish[a].total_cmp(&finish[b]).then(a.cmp(&b)));
    let total_w: u64 = weights.iter().sum();
    let mut t_free = 0.0f64;
    for &i in &order {
        let share = if total_w == 0 {
            write_secs / n as f64
        } else {
            write_secs * weights[i] as f64 / total_w as f64
        };
        t_free = t_free.max(finish[i]) + share;
    }
    t_free
}

/// The full per-rank timetable of one wave, for span tracing: the same
/// arithmetic as [`finish_times`] + [`pipelined_write_stall`], but keeping
/// every intermediate instant instead of only the final stall. All times
/// are relative to the wave start.
#[derive(Clone, Debug, Default)]
pub struct WriteSchedule {
    /// Per-rank encode interval `(start, finish)` on its worker's lane.
    pub encode: Vec<(f64, f64)>,
    /// Write-queue service slots in admission order:
    /// `(rank, service_start, service_end)`.
    pub service: Vec<(usize, f64, f64)>,
}

/// Replay the wave and return its timetable. Bitwise-consistent with the
/// stall model: the last service slot's end equals
/// [`pipelined_write_stall`] for the same inputs (asserted in tests), so
/// spans emitted from this schedule reconcile exactly with the report.
pub fn schedule(
    costs: &[EncodeCost],
    weights: &[u64],
    workers: usize,
    write_secs: f64,
) -> WriteSchedule {
    let n = costs.len();
    let mut encode = vec![(0.0f64, 0.0f64); n];
    if n == 0 {
        return WriteSchedule::default();
    }
    let workers = workers.max(1);
    let per = n.div_ceil(workers);
    let mut finish = vec![0.0f64; n];
    for (w, block) in costs.chunks(per).enumerate() {
        let mut t = 0.0f64;
        for (k, c) in block.iter().enumerate() {
            let start = t;
            t += encode_secs(c);
            encode[w * per + k] = (start, t);
            finish[w * per + k] = t;
        }
    }
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| finish[a].total_cmp(&finish[b]).then(a.cmp(&b)));
    let total_w: u64 = weights.iter().sum();
    let mut service = Vec::with_capacity(n);
    let mut t_free = 0.0f64;
    for &i in &order {
        let share = if total_w == 0 {
            write_secs / n as f64
        } else {
            write_secs * weights[i] as f64 / total_w as f64
        };
        let start = t_free.max(finish[i]);
        t_free = start + share;
        service.push((i, start, t_free));
    }
    WriteSchedule { encode, service }
}

/// The stall breakdown for one checkpoint wave.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StallPlan {
    /// Encode wall clock (slowest worker).
    pub encode_secs: f64,
    /// Write wave duration as charged by the storage model.
    pub write_secs: f64,
    /// Stall of the serial path: encode fully, then write fully.
    pub serial_stall: f64,
    /// Stall of the pipelined path (streamed admission).
    pub pipelined_stall: f64,
}

impl StallPlan {
    /// Virtual seconds the pipeline hid relative to the serial path.
    pub fn overlap_saved(&self) -> f64 {
        (self.serial_stall - self.pipelined_stall).max(0.0)
    }
}

/// Model one wave end to end. `weights` are per-rank write bytes (the
/// storage share each rank consumes); `workers` is the encode thread
/// count; `write_secs` is the wave's write duration from the storage
/// model. The pipelined stall is clamped into its provable envelope
/// `[max(encode, write), encode + write]` to keep floating-point noise
/// out of the bench gates.
pub fn plan(costs: &[EncodeCost], weights: &[u64], workers: usize, write_secs: f64) -> StallPlan {
    let (finish, encode_secs) = finish_times(costs, workers);
    let serial_stall = encode_secs + write_secs;
    let raw = pipelined_write_stall(&finish, weights, write_secs);
    let pipelined_stall = raw.max(encode_secs.max(write_secs)).min(serial_stall);
    StallPlan {
        encode_secs,
        write_secs,
        serial_stall,
        pipelined_stall,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn costs(n: usize, bytes: u64) -> Vec<EncodeCost> {
        vec![
            EncodeCost {
                hash_vbytes: bytes,
                copy_bytes: bytes,
            };
            n
        ]
    }

    #[test]
    fn empty_wave_costs_only_the_write() {
        let p = plan(&[], &[], 4, 2.5);
        assert_eq!(p.pipelined_stall, 2.5);
        assert_eq!(p.serial_stall, 2.5);
    }

    #[test]
    fn pipelined_stall_stays_in_the_envelope() {
        for &(n, workers, write_secs) in
            &[(1usize, 1usize, 0.5f64), (8, 2, 1.0), (64, 8, 0.01), (7, 3, 4.0)]
        {
            let c = costs(n, 100 << 20);
            let w: Vec<u64> = (0..n as u64).map(|i| 1 + i).collect();
            let p = plan(&c, &w, workers, write_secs);
            let lo = p.encode_secs.max(p.write_secs);
            assert!(
                p.pipelined_stall >= lo && p.pipelined_stall <= p.serial_stall,
                "stall {} outside [{}, {}]",
                p.pipelined_stall,
                lo,
                p.serial_stall
            );
        }
    }

    #[test]
    fn streaming_beats_serial_when_both_sides_are_busy() {
        // Many equal ranks, one worker: the write stream starts after the
        // first rank instead of after all of them, so nearly the whole
        // write hides under the encode tail.
        let c = costs(64, 200 << 20);
        let w = vec![1u64; 64];
        let (_, encode) = finish_times(&c, 1);
        let p = plan(&c, &w, 1, encode);
        assert!(p.pipelined_stall < p.serial_stall * 0.6);
    }

    #[test]
    fn finish_times_replay_the_contiguous_worker_blocks() {
        let mut c = costs(6, 0);
        c[0].hash_vbytes = 2_000_000_000; // rank 0: 1s of hash work
        let (finish, wall) = finish_times(&c, 2);
        // Worker 0 owns ranks 0..3, worker 1 owns 3..6.
        assert!(finish[0] > 1.0 && finish[2] > finish[1]);
        assert!(finish[3] < finish[0], "worker 1 is independent of rank 0");
        assert!((wall - finish[2]).abs() < 1e-12);
    }

    #[test]
    fn schedule_is_bitwise_consistent_with_the_stall_model() {
        let c: Vec<EncodeCost> = (0..24)
            .map(|i| EncodeCost {
                hash_vbytes: ((i * 37) % 11 + 1) as u64 * 40_000_000,
                copy_bytes: (i as u64 + 1) * 5_000_000,
            })
            .collect();
        let w: Vec<u64> = (0..24u64).map(|i| (i % 5) * 1_000_000 + 1).collect();
        for workers in [1usize, 3, 8, 24] {
            let (finish, wall) = finish_times(&c, workers);
            let stall = pipelined_write_stall(&finish, &w, 0.42);
            let sched = schedule(&c, &w, workers, 0.42);
            for (i, &(s, f)) in sched.encode.iter().enumerate() {
                assert_eq!(f, finish[i], "finish {i} at {workers} workers");
                assert!(s <= f);
            }
            let enc_wall = sched.encode.iter().map(|&(_, f)| f).fold(0.0, f64::max);
            assert_eq!(enc_wall, wall);
            // Admission order is non-decreasing in service start, every
            // slot starts at/after its encode, and the tail IS the stall.
            let mut prev_end = 0.0f64;
            for &(rank, s, e) in &sched.service {
                assert!(s >= prev_end - 1e-15);
                assert!(s >= finish[rank]);
                prev_end = e;
            }
            assert_eq!(prev_end, stall, "tail vs stall at {workers} workers");
        }
    }

    #[test]
    fn schedule_of_empty_wave_is_empty() {
        let s = schedule(&[], &[], 4, 1.0);
        assert!(s.encode.is_empty() && s.service.is_empty());
    }

    #[test]
    fn completion_order_admission_is_deterministic() {
        let c: Vec<EncodeCost> = (0..16)
            .map(|i| EncodeCost {
                hash_vbytes: (16 - i) as u64 * 1_000_000,
                copy_bytes: 0,
            })
            .collect();
        let w = vec![3u64; 16];
        let a = plan(&c, &w, 4, 0.7);
        let b = plan(&c, &w, 4, 0.7);
        assert_eq!(a, b);
    }
}
