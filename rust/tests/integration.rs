//! Integration tests: full C/R cycles across modules, all apps, both file
//! systems, chained checkpoints, and cross-config determinism.
//!
//! These run on the synthetic compute path (no PJRT) so `cargo test` stays
//! fast; the PJRT integration is covered by tests/pjrt_runtime.rs (which
//! skips gracefully when artifacts are absent).

use mana::config::{AppKind, Fixes, RunConfig};
use mana::faults::FaultPlan;
use mana::fs::FsKind;
use mana::sim::JobSim;
use mana::topology::RankId;

fn cfg(app: AppKind, ranks: u32, job: &str) -> RunConfig {
    let mut c = RunConfig::new(app, ranks);
    c.job = job.into();
    c.mem_per_rank = Some(1 << 20);
    c
}

/// Run steps with a checkpoint+kill+restart at `ckpt_at`; return the final
/// fingerprint.
fn interrupted_fingerprint(mut c: RunConfig, total: u64, ckpt_at: u64) -> u64 {
    let mut sim = JobSim::launch(c.clone(), None).unwrap();
    sim.run_steps(ckpt_at).unwrap();
    sim.checkpoint().unwrap();
    c.job = sim.cfg.job.clone();
    let fs = sim.kill();
    let (mut resumed, _) = JobSim::restart_from(c, None, fs).unwrap();
    resumed.run_steps(total - ckpt_at).unwrap();
    assert!(!resumed.any_corruption());
    resumed.fingerprint()
}

fn continuous_fingerprint(c: RunConfig, total: u64) -> u64 {
    let mut sim = JobSim::launch(c, None).unwrap();
    sim.run_steps(total).unwrap();
    assert!(!sim.any_corruption());
    sim.fingerprint()
}

#[test]
fn all_apps_survive_cr_deterministically() {
    for app in [
        AppKind::Gromacs,
        AppKind::Hpcg,
        AppKind::VaspRpa,
        AppKind::Synthetic,
    ] {
        let base = cfg(app, 4, &format!("int-{}", app.name()));
        let want = continuous_fingerprint(base.clone(), 6);
        let got = interrupted_fingerprint(base, 6, 3);
        assert_eq!(got, want, "{app:?} not deterministic through C/R");
    }
}

#[test]
fn cr_deterministic_on_both_file_systems() {
    for fs in [FsKind::BurstBuffer, FsKind::Lustre] {
        let mut base = cfg(AppKind::Synthetic, 4, &format!("int-fs-{fs:?}"));
        base.fs = fs;
        let want = continuous_fingerprint(base.clone(), 5);
        assert_eq!(interrupted_fingerprint(base, 5, 2), want, "{fs:?}");
    }
}

#[test]
fn chained_checkpoints_every_step() {
    // Checkpoint + restart after EVERY step ("checkpointed at any point").
    let base = cfg(AppKind::Synthetic, 4, "int-chain");
    let total = 5u64;
    let want = continuous_fingerprint(base.clone(), total);

    let mut sim = JobSim::launch(base.clone(), None).unwrap();
    for _ in 0..total {
        sim.run_steps(1).unwrap();
        sim.checkpoint().unwrap();
        let c = sim.cfg.clone();
        let fs = sim.kill();
        let (resumed, _) = JobSim::restart_from(c, None, fs).unwrap();
        sim = resumed;
    }
    assert_eq!(sim.fingerprint(), want);
    assert_eq!(sim.step, total);
    assert!(!sim.any_corruption());
}

#[test]
fn checkpoint_at_step_zero_works() {
    let base = cfg(AppKind::Synthetic, 4, "int-zero");
    let want = continuous_fingerprint(base.clone(), 4);
    assert_eq!(interrupted_fingerprint(base, 4, 0), want);
}

#[test]
fn second_checkpoint_overwrites_first() {
    let mut sim = JobSim::launch(cfg(AppKind::Synthetic, 4, "int-ovw"), None).unwrap();
    sim.run_steps(1).unwrap();
    sim.checkpoint().unwrap();
    let used1 = sim.fs.used_bytes();
    sim.run_steps(1).unwrap();
    sim.checkpoint().unwrap();
    let used2 = sim.fs.used_bytes();
    assert_eq!(used1, used2, "second ckpt must replace, not accumulate");
    // Restart resumes from the LATEST checkpoint.
    let c = sim.cfg.clone();
    let fs = sim.kill();
    let (resumed, _) = JobSim::restart_from(c, None, fs).unwrap();
    assert_eq!(resumed.step, 2);
}

#[test]
fn gni_quiescence_delays_but_does_not_break_checkpoint() {
    let mut c = cfg(AppKind::Synthetic, 4, "int-gni");
    // Quiescence window covering the checkpoint time.
    c.faults = FaultPlan::gni_reconfig(0.0, 5.0);
    // Baseline without the fault.
    let mut quiet = JobSim::launch(cfg(AppKind::Synthetic, 4, "int-gni0"), None).unwrap();
    quiet.run_steps(2).unwrap();
    quiet.checkpoint().unwrap();
    let t_quiet = quiet.now().as_secs();

    let mut sim = JobSim::launch(c, None).unwrap();
    sim.run_steps(2).unwrap();
    // In-flight halo deliveries are pushed past the window by the fabric,
    // so the delay surfaces in the blocking receives / drain, and the
    // checkpoint completes only after the window ends.
    let rep = sim.checkpoint().unwrap();
    assert_eq!(rep.lost_messages, 0, "quiescence must not lose messages");
    assert!(sim.now().as_secs() >= 5.0, "must end after the GNI window");
    assert!(
        sim.now().as_secs() > t_quiet + 3.0,
        "GNI reconfiguration must have cost wall time: {} vs quiet {}",
        sim.now().as_secs(),
        t_quiet
    );
}

#[test]
fn congested_network_with_keepalive_slows_but_succeeds() {
    let mut c = cfg(AppKind::Synthetic, 16, "int-congest");
    c.faults = FaultPlan::congested_network();
    let mut sim = JobSim::launch(c, None).unwrap();
    sim.run_steps(2).unwrap();
    let rep = sim.checkpoint().unwrap();
    assert!(rep.total_secs > 0.0);
    assert!(
        sim.coord.ctrl.stats.retries + sim.coord.ctrl.stats.reconnects > 0,
        "keepalive must have worked under congestion"
    );
}

#[test]
fn restart_with_missing_image_fails_cleanly() {
    let mut sim = JobSim::launch(cfg(AppKind::Synthetic, 4, "int-miss"), None).unwrap();
    sim.run_steps(1).unwrap();
    sim.checkpoint().unwrap();
    let c = sim.cfg.clone();
    let mut fs = sim.kill();
    fs.delete("int-miss/ckpt_rank00002.mana").unwrap();
    match JobSim::restart_from(c, None, fs) {
        Err(err) => assert!(err.to_string().contains("no such file"), "{err}"),
        Ok(_) => panic!("restart must fail with a missing image"),
    }
}

#[test]
fn larger_jobs_span_more_nodes_and_write_more() {
    let small = JobSim::launch(cfg(AppKind::Synthetic, 8, "int-s"), None).unwrap();
    let large = JobSim::launch(cfg(AppKind::Synthetic, 64, "int-l"), None).unwrap();
    assert!(large.topo.nodes() > small.topo.nodes());
    assert!(large.aggregate_memory() > small.aggregate_memory());
}

#[test]
fn coordinator_stats_accumulate() {
    let mut sim = JobSim::launch(cfg(AppKind::Synthetic, 4, "int-stats"), None).unwrap();
    sim.run_steps(1).unwrap();
    sim.checkpoint().unwrap();
    sim.run_steps(1).unwrap();
    sim.checkpoint().unwrap();
    assert_eq!(sim.coord.stats.checkpoints, 2);
    assert!(sim.coord.stats.buffered_msgs > 0);
}

#[test]
fn fingerprints_differ_across_seeds_and_apps() {
    let a = continuous_fingerprint(cfg(AppKind::Synthetic, 4, "int-fa"), 3);
    let mut c2 = cfg(AppKind::Synthetic, 4, "int-fb");
    c2.seed ^= 0xDEAD;
    let b = continuous_fingerprint(c2, 3);
    assert_ne!(a, b, "different seeds must give different trajectories");
    let c = continuous_fingerprint(cfg(AppKind::Gromacs, 4, "int-fc"), 3);
    assert_ne!(a, c);
}

#[test]
fn rank_to_node_mapping_consistent_after_restart() {
    let mut sim = JobSim::launch(cfg(AppKind::Synthetic, 16, "int-map"), None).unwrap();
    let nodes_before: Vec<_> = (0..16).map(|r| sim.topo.node_of(RankId(r))).collect();
    sim.run_steps(1).unwrap();
    sim.checkpoint().unwrap();
    let c = sim.cfg.clone();
    let fs = sim.kill();
    let (resumed, _) = JobSim::restart_from(c, None, fs).unwrap();
    let nodes_after: Vec<_> = (0..16).map(|r| resumed.topo.node_of(RankId(r))).collect();
    assert_eq!(nodes_before, nodes_after);
}

#[test]
fn tree_plane_full_cycle_with_staging_and_congestion() {
    // The production shape all at once: hierarchical coordination plane,
    // tiered BB→Lustre staging, and a congested control network — the
    // C/R cycle must still be bitwise deterministic.
    let base = cfg(AppKind::Synthetic, 32, "int-tree");
    let want = continuous_fingerprint(base.clone(), 6);
    let mut c = base.with_coord_tree(2).with_staging();
    c.faults = FaultPlan::congested_network();
    let got = interrupted_fingerprint(c, 6, 3);
    assert_eq!(got, want, "tree plane + staging + congestion stays bitwise");
}

#[test]
fn tree_plane_survives_subcoord_death_end_to_end() {
    use mana::coordinator::Phase;
    let base = cfg(AppKind::Synthetic, 32, "int-treedeath");
    let want = continuous_fingerprint(base.clone(), 6);
    let mut c = base.with_coord_tree(2);
    c.faults.subcoord_death = Some((1, Phase::Drain));
    let mut sim = JobSim::launch(c.clone(), None).unwrap();
    sim.run_steps(3).unwrap();
    let rep = sim.checkpoint().unwrap();
    assert_eq!(rep.reparents, 1);
    let fs = sim.kill();
    c.faults.subcoord_death = None;
    let (mut resumed, _) = JobSim::restart_from(c, None, fs).unwrap();
    resumed.run_steps(3).unwrap();
    assert_eq!(resumed.fingerprint(), want);
    assert!(!resumed.any_corruption());
}

#[test]
fn unreachable_sub_coordinator_link_fails_checkpoint_cleanly() {
    // Max-retries exhaustion on a tree link propagates a clean failure
    // naming the rank and the phase that first hit it.
    let mut c = cfg(AppKind::Synthetic, 16, "int-unreach").with_coord_tree(2);
    c.faults.ctrl_loss_prob = 1.0;
    let mut sim = JobSim::launch(c, None).unwrap();
    sim.run_steps(1).unwrap();
    let msg = sim.checkpoint().unwrap_err().to_string();
    assert!(
        msg.contains("unreachable") && msg.contains("INTENT"),
        "failure must name rank and phase: {msg}"
    );
}

#[test]
fn prototype_fails_at_small_scale_on_restart_conflicts() {
    // The paper's debugging narrative started AT SMALL SCALE: "We began
    // debugging at small scales … The descriptor conflicts would occur
    // upon restart". Even a single quiet rank reproduces the restart-time
    // conflicts under the prototype (all fixes off): the trivial app's
    // lower half squats on addresses/descriptors the upper half needs.
    let mut c = cfg(AppKind::Synthetic, 1, "int-proto");
    c.fixes = Fixes::all_off();
    let mut sim = JobSim::launch(c.clone(), None).unwrap();
    sim.run_steps(2).unwrap();
    // The checkpoint itself works on a quiet single rank…
    let rep = sim.checkpoint().unwrap();
    assert_eq!(rep.lost_messages, 0);
    let fs = sim.kill();
    // …but the restart hits the legacy conflicts the paper debugged.
    match JobSim::restart_from(c.clone(), None, fs) {
        Err(err) => {
            let msg = err.to_string();
            assert!(
                msg.contains("overlap") || msg.contains("conflict"),
                "expected a restart conflict, got: {msg}"
            );
        }
        Ok(_) => panic!("prototype restart should hit the legacy conflicts"),
    }
    // Production config on the same workload sails through.
    c.fixes = Fixes::all_on();
    c.job = "int-proto-fixed".into();
    let mut sim = JobSim::launch(c.clone(), None).unwrap();
    sim.run_steps(2).unwrap();
    sim.checkpoint().unwrap();
    let fs = sim.kill();
    let (mut resumed, _) = JobSim::restart_from(c, None, fs).unwrap();
    resumed.run_steps(2).unwrap();
    assert!(!resumed.any_corruption());
}
