//! Hierarchical coordination plane: per-node sub-coordinators in a
//! fanout-ary tree under the root coordinator.
//!
//! The flat DMTCP plane exchanges one message with every rank in every
//! phase — O(ranks) serialized point-to-point traffic at a single root,
//! which is the first bottleneck a production deployment hits. Following
//! the tree-structured control planes argued for by MANA's original design
//! retrospective (arXiv:1904.12595) and the topological-sort drain work
//! (arXiv:2408.02218), this plane:
//!
//! * places one **sub-coordinator per compute node** (addressed through
//!   the node's first rank), arranged in a fanout-ary tree whose depth is
//!   derived from the job topology ([`Topology::coord_levels`]);
//! * runs every protocol phase as a **broadcast-down + reduce-up**: an
//!   endpoint never serializes more than `fanout` (or its node-local rank
//!   count) messages, so the root handles `2 x fanout` messages per phase
//!   instead of `2 x ranks`, and protocol wall-clock grows with tree
//!   depth (logarithmic) instead of rank count;
//! * evaluates the DRAIN convergence test on sent/recv byte counters
//!   **summed up the tree** — the root sees one aggregate per child,
//!   never one row per rank;
//! * inherits the full control-network fault model on every link
//!   (KeepAlive, loss, idle-disconnect — each hop goes through
//!   [`ControlNet::send_batch`]), and adds the tree's own failure mode: a
//!   **sub-coordinator dying mid-phase**. The death is noticed by its
//!   parent's KeepAlive probe; the orphaned subtree (child
//!   sub-coordinators and the dead node's local ranks) is re-parented to
//!   an alive sibling — falling back to the parent, and ultimately to the
//!   root itself — and the phase is retried over the repaired tree.

use std::collections::BTreeMap;

use super::{CoordGroup, CoordPlane, CountReduce, OverlapIo, Phase, PhaseIo};
use crate::simnet::control::{ControlNet, CtrlError};
use crate::topology::{NodeId, RankId, Topology};
use crate::trace::{EventCtx, Tracer};
use crate::util::simclock::SimTime;

/// One sub-coordinator (one per compute node at construction).
#[derive(Clone, Debug)]
struct Sub {
    /// Parent sub-coordinator; `None` = direct child of the root.
    parent: Option<usize>,
    children: Vec<usize>,
    /// Ranks this sub-coordinator answers for (its node's ranks, plus any
    /// adopted from dead siblings).
    ranks: Vec<RankId>,
    /// Control-network address (the node's first rank).
    addr: RankId,
    alive: bool,
}

/// Outcome of one phase attempt over the current tree.
struct Attempt {
    secs: f64,
    /// Seconds until the broadcast-down sweep (leaf fan-out included)
    /// finished — the point at which a second phase's broadcast could
    /// enter the tree behind this one.
    down_secs: f64,
    msgs: u64,
    root_msgs: u64,
    /// Sub-coordinator found dead mid-phase (re-parent and retry).
    died: Option<usize>,
}

/// The tree plane. See the module docs.
pub struct TreePlane {
    fanout: u32,
    subs: Vec<Sub>,
    root_children: Vec<usize>,
    /// Ranks attached directly to the root (re-parent fallback of last
    /// resort; empty in a healthy tree).
    root_ranks: Vec<RankId>,
    /// Injected one-shot failure: (sub-coordinator index, phase it dies
    /// in). Consumed when the phase reaches the victim.
    pending_death: Option<(u32, Phase)>,
    /// Sub-coordinator levels below the root (>= 1).
    levels: u32,
    /// Tree-configuration epoch, bumped on every re-parent. Acks tagged
    /// with an older epoch are stale: a reduce that overlapped a
    /// re-parent must discard them (and retry) instead of folding them
    /// in — otherwise an adopted subtree's counters would be counted
    /// once under the dead parent and again under the adopter.
    epoch: u64,
    /// Shared event recorder (the owning job's).
    tracer: Tracer,
}

impl TreePlane {
    /// Build the tree for a topology: sub-coordinator `i` serves node `i`;
    /// the first `fanout` sub-coordinators hang off the root, and
    /// sub-coordinator `i >= fanout` is the child of `i / fanout - 1`
    /// (a complete fanout-ary forest).
    pub fn new(topo: &Topology, fanout: u32, pending_death: Option<(u32, Phase)>) -> Self {
        let f = fanout.max(2) as usize;
        let n = topo.nodes() as usize;
        let mut subs: Vec<Sub> = Vec::with_capacity(n);
        for i in 0..n {
            let ranks = topo.ranks_on(NodeId(i as u32));
            let addr = ranks[0];
            let parent = if i < f { None } else { Some(i / f - 1) };
            subs.push(Sub {
                parent,
                children: Vec::new(),
                ranks,
                addr,
                alive: true,
            });
        }
        let parents: Vec<Option<usize>> = subs.iter().map(|s| s.parent).collect();
        let mut root_children = Vec::new();
        for (i, p) in parents.iter().enumerate() {
            match p {
                None => root_children.push(i),
                Some(p) => subs[*p].children.push(i),
            }
        }
        let mut plane = TreePlane {
            fanout: f as u32,
            subs,
            root_children,
            root_ranks: Vec::new(),
            pending_death,
            levels: 1,
            epoch: 0,
            tracer: Tracer::disabled(),
        };
        plane.recompute_depth();
        debug_assert_eq!(plane.levels, topo.coord_levels(f as u32));
        plane
    }

    /// Alive sub-coordinators.
    pub fn alive_subs(&self) -> usize {
        self.subs.iter().filter(|s| s.alive).count()
    }

    /// Current tree-configuration epoch (bumped on every re-parent).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    fn recompute_depth(&mut self) {
        let mut max_l = 1u32;
        for (i, s) in self.subs.iter().enumerate() {
            if !s.alive {
                continue;
            }
            let mut l = 1u32;
            let mut j = i;
            while let Some(p) = self.subs[j].parent {
                l += 1;
                j = p;
            }
            max_l = max_l.max(l);
        }
        self.levels = max_l;
    }

    /// Remove a dead sub-coordinator from the tree: its child subtrees and
    /// local ranks go to the first alive sibling, else to its parent, else
    /// (for an only root child) to the root itself.
    fn reparent(&mut self, dead: usize) {
        self.epoch += 1;
        self.subs[dead].alive = false;
        let parent = self.subs[dead].parent;
        match parent {
            Some(p) => self.subs[p].children.retain(|&c| c != dead),
            None => self.root_children.retain(|&c| c != dead),
        }
        let adopter: Option<usize> = {
            let siblings = match parent {
                Some(p) => &self.subs[p].children,
                None => &self.root_children,
            };
            siblings.iter().copied().find(|&s| self.subs[s].alive)
        };
        let orphans = std::mem::take(&mut self.subs[dead].children);
        let ranks = std::mem::take(&mut self.subs[dead].ranks);
        match adopter.or(parent) {
            Some(a) => {
                for &c in &orphans {
                    self.subs[c].parent = Some(a);
                }
                self.subs[a].children.extend(orphans);
                self.subs[a].ranks.extend(ranks);
            }
            None => {
                // Last resort: the root adopts the orphan subtrees and
                // speaks to the dead node's ranks directly (flat fallback
                // for exactly those ranks).
                for &c in &orphans {
                    self.subs[c].parent = None;
                }
                self.root_children.extend(orphans);
                self.root_ranks.extend(ranks);
            }
        }
        self.recompute_depth();
    }

    /// One phase attempt over the current tree: broadcast down level by
    /// level, fan out to the leaf ranks, then reduce back up. Every hop is
    /// a serialized [`ControlNet::send_batch`], so per-hop latency and the
    /// full link fault model apply everywhere.
    fn attempt(
        &mut self,
        ctrl: &mut ControlNet,
        phase: Phase,
        now: SimTime,
    ) -> Result<Attempt, CtrlError> {
        let mut a = Attempt {
            secs: 0.0,
            down_secs: 0.0,
            msgs: 0,
            root_msgs: 0,
            died: None,
        };

        // --- broadcast down ---
        let root_targets: Vec<RankId> = self
            .root_children
            .iter()
            .map(|&c| self.subs[c].addr)
            .chain(self.root_ranks.iter().copied())
            .collect();
        let io = ctrl.send_batch(root_targets.into_iter(), now)?;
        a.secs += io.secs;
        a.msgs += io.msgs;
        a.root_msgs += io.msgs;

        // Interior levels, BFS order (recorded for the reduce-up).
        let mut levels: Vec<Vec<usize>> = Vec::new();
        let mut frontier = self.root_children.clone();
        while !frontier.is_empty() {
            // A sub-coordinator scheduled to die in this phase dies as the
            // broadcast reaches it; its parent's KeepAlive probe notices
            // after one probe interval and the attempt is abandoned.
            if let Some((dead, ph)) = self.pending_death {
                let dead = dead as usize;
                if ph == phase && frontier.contains(&dead) && self.subs[dead].alive {
                    self.pending_death = None;
                    a.secs += ctrl.cfg.keepalive_interval;
                    // The down sweep never completed: the whole aborted
                    // attempt counts as broadcast time, so an overlapped
                    // pair can claim no credit for it.
                    a.down_secs = a.secs;
                    a.died = Some(dead);
                    return Ok(a);
                }
            }
            levels.push(frontier.clone());
            let mut next = Vec::new();
            let mut level_secs = 0.0f64;
            for &s in &frontier {
                if self.subs[s].children.is_empty() {
                    continue;
                }
                let kids: Vec<RankId> = self.subs[s]
                    .children
                    .iter()
                    .map(|&c| self.subs[c].addr)
                    .collect();
                let io = ctrl.send_batch(kids.into_iter(), now)?;
                level_secs = level_secs.max(io.secs);
                a.msgs += io.msgs;
                next.extend(self.subs[s].children.iter().copied());
            }
            a.secs += level_secs;
            frontier = next;
        }

        // Leaf hop down: every sub-coordinator fans out to its ranks.
        let mut leaf_secs = 0.0f64;
        for s in self.subs.iter().filter(|s| s.alive && !s.ranks.is_empty()) {
            let io = ctrl.send_batch(s.ranks.iter().copied(), now)?;
            leaf_secs = leaf_secs.max(io.secs);
            a.msgs += io.msgs;
        }
        a.secs += leaf_secs;
        // The broadcast has fully left the root and reached every rank;
        // everything after this line is the reduce-up.
        a.down_secs = a.secs;

        // --- reduce up ---
        // Local ranks ack their sub-coordinator (serialized receive)...
        let mut ack_secs = 0.0f64;
        for s in self.subs.iter().filter(|s| s.alive && !s.ranks.is_empty()) {
            let io = ctrl.send_batch(s.ranks.iter().copied(), now)?;
            ack_secs = ack_secs.max(io.secs);
            a.msgs += io.msgs;
        }
        a.secs += ack_secs;

        // ...then one aggregate per child flows up, deepest level first.
        for lvl in levels.iter().rev() {
            let mut level_secs = 0.0f64;
            let mut by_parent: BTreeMap<usize, Vec<RankId>> = BTreeMap::new();
            let mut root_batch: Vec<RankId> = Vec::new();
            for &s in lvl {
                match self.subs[s].parent {
                    Some(p) => by_parent.entry(p).or_default().push(self.subs[s].addr),
                    None => root_batch.push(self.subs[s].addr),
                }
            }
            for (_p, addrs) in by_parent {
                let io = ctrl.send_batch(addrs.into_iter(), now)?;
                level_secs = level_secs.max(io.secs);
                a.msgs += io.msgs;
            }
            if !root_batch.is_empty() {
                let io = ctrl.send_batch(root_batch.into_iter(), now)?;
                level_secs = level_secs.max(io.secs);
                a.msgs += io.msgs;
                a.root_msgs += io.msgs;
            }
            a.secs += level_secs;
        }
        // Directly-attached ranks (re-parent fallback) ack the root last.
        if !self.root_ranks.is_empty() {
            let ranks = self.root_ranks.clone();
            let io = ctrl.send_batch(ranks.into_iter(), now)?;
            a.secs += io.secs;
            a.msgs += io.msgs;
            a.root_msgs += io.msgs;
        }
        Ok(a)
    }

    /// Phase exchange that also reports how many rank acks went stale:
    /// when an attempt aborts on a dead sub-coordinator, the acks its
    /// subtree had in flight carry the pre-re-parent epoch and must be
    /// discarded (never folded into a reduction) before the retry
    /// re-collects them under the repaired tree.
    fn exchange_counting_stale(
        &mut self,
        ctrl: &mut ControlNet,
        phase: Phase,
        now: SimTime,
    ) -> Result<(PhaseIo, u64), CtrlError> {
        let mut total = PhaseIo::default();
        let mut stale_acks = 0u64;
        loop {
            let a = self.attempt(ctrl, phase, now)?;
            total.secs += a.secs;
            total.down_secs += a.down_secs;
            total.msgs += a.msgs;
            total.root_msgs += a.root_msgs;
            let Some(dead) = a.died else {
                return Ok((total, stale_acks));
            };
            self.tracer.warn(
                "coordinator",
                format!("coord.reparent:sub{dead:03}"),
                EventCtx::node(dead as u32),
                format!(
                    "sub-coordinator sub{dead:03} died mid-{phase} — re-parenting its \
                     subtree and retrying the phase"
                ),
            );
            stale_acks += self.subs[dead].ranks.len() as u64;
            self.reparent(dead);
            total.reparents += 1;
            total.retries += 1;
        }
    }
}

impl CoordPlane for TreePlane {
    fn exchange(
        &mut self,
        ctrl: &mut ControlNet,
        phase: Phase,
        now: SimTime,
    ) -> Result<PhaseIo, CtrlError> {
        let (io, _) = self.exchange_counting_stale(ctrl, phase, now)?;
        Ok(io)
    }

    /// The plane can genuinely pipeline two phases: the second broadcast
    /// enters the tree as soon as the first has fully left the root, so
    /// with a healthy tree the pair costs
    /// `first.down + max(first.up, second.down) + second.up` instead of
    /// the serial sum. Any re-parent during the pair forfeits the credit:
    /// recovery re-runs whole attempts, in-flight acks of the dead
    /// subtree are stale-epoch and discarded (counted in `stale_acks`),
    /// and the pair is charged serially. Message and retry accounting is
    /// identical to two serial exchanges either way.
    fn exchange_overlapped(
        &mut self,
        ctrl: &mut ControlNet,
        first: Phase,
        second: Phase,
        now: SimTime,
    ) -> Result<OverlapIo, CtrlError> {
        let epoch_before = self.epoch;
        let (a, stale_a) = self.exchange_counting_stale(ctrl, first, now)?;
        let (b, stale_b) = self.exchange_counting_stale(ctrl, second, now)?;
        let stale_acks = stale_a + stale_b;
        let healthy = self.epoch == epoch_before;
        debug_assert_eq!(healthy, a.retries == 0 && b.retries == 0);
        let secs = if healthy {
            let up_a = a.secs - a.down_secs;
            let up_b = b.secs - b.down_secs;
            a.down_secs + up_a.max(b.down_secs) + up_b
        } else {
            a.secs + b.secs
        };
        Ok(OverlapIo {
            first: a,
            second: b,
            secs,
            stale_acks,
        })
    }

    fn reduce_counts(
        &mut self,
        ctrl: &mut ControlNet,
        counts: &[(u64, u64)],
        now: SimTime,
    ) -> Result<CountReduce, CtrlError> {
        let io = self.exchange(ctrl, Phase::Drain, now)?;
        // Aggregate bottom-up: each sub-coordinator folds its local ranks,
        // parents fold per-child partial sums. Summation is associative,
        // so the flat fold below computes exactly the tree reduction the
        // exchange above carried — the root only ever handled one
        // aggregate per child.
        let mut sent = 0u64;
        let mut recv = 0u64;
        for s in self.subs.iter().filter(|s| s.alive) {
            for r in &s.ranks {
                let (cs, cr) = counts[r.0 as usize];
                sent += cs;
                recv += cr;
            }
        }
        for r in &self.root_ranks {
            let (cs, cr) = counts[r.0 as usize];
            sent += cs;
            recv += cr;
        }
        Ok(CountReduce { sent, recv, io })
    }

    fn drain_schedule(
        &mut self,
        ctrl: &mut ControlNet,
        _waves: u32,
        now: SimTime,
    ) -> Result<PhaseIo, CtrlError> {
        // The wave schedule is one bounded object relayed down the tree:
        // one hop per level plus the leaf hop, each a single forward of
        // the same object (no per-rank fan-out — sub-coordinators pass it
        // to their node's shared memory). Cost scales with depth, never
        // with rank count or wave count.
        let mut secs = 0.0f64;
        let mut msgs = 0u64;
        for _level in 0..self.depth() {
            secs += ctrl.send(RankId(0), now)?;
            msgs += 1;
        }
        Ok(PhaseIo {
            secs,
            down_secs: secs,
            msgs,
            root_msgs: 1,
            reparents: 0,
            retries: 0,
        })
    }

    fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
    }

    fn depth(&self) -> u32 {
        // Sub-coordinator levels plus the leaf rank hop.
        self.levels + 1
    }

    fn groups(&self) -> Vec<CoordGroup> {
        let mut out = Vec::new();
        if !self.root_ranks.is_empty() {
            out.push(CoordGroup {
                label: "root".into(),
                parent: "-".into(),
                ranks: self.root_ranks.clone(),
            });
        }
        for (i, s) in self.subs.iter().enumerate() {
            if !s.alive {
                continue;
            }
            let parent = match s.parent {
                None => "root".to_string(),
                Some(p) => format!("sub{p:03}"),
            };
            out.push(CoordGroup {
                label: format!("sub{i:03}"),
                parent,
                ranks: s.ranks.clone(),
            });
        }
        out
    }

    fn describe(&self) -> String {
        format!(
            "tree(fanout={}, subs={}, depth={})",
            self.fanout,
            self.alive_subs(),
            self.depth()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::control::CtrlConfig;

    fn net() -> ControlNet {
        ControlNet::new(CtrlConfig::default(), 11)
    }

    fn plane(ranks: u32, fanout: u32, death: Option<(u32, Phase)>) -> TreePlane {
        TreePlane::new(&Topology::new(ranks, 8), fanout, death)
    }

    fn covered_ranks(p: &TreePlane) -> usize {
        p.groups().iter().map(|g| g.ranks.len()).sum()
    }

    #[test]
    fn paper_scale_layout() {
        // 512 ranks x 8 threads -> 64 nodes -> 64 sub-coordinators; at
        // fanout 8 that is 8 root children + 56 interior, two levels.
        let p = plane(512, 8, None);
        assert_eq!(p.subs.len(), 64);
        assert_eq!(p.root_children, (0..8).collect::<Vec<_>>());
        assert_eq!(p.subs[8].parent, Some(0));
        assert_eq!(p.subs[63].parent, Some(6));
        assert_eq!(p.levels, 2);
        assert_eq!(p.depth(), 3);
        assert_eq!(covered_ranks(&p), 512);
    }

    #[test]
    fn root_handles_only_fanout_messages_per_phase() {
        let mut p = plane(512, 8, None);
        let mut ctrl = net();
        let io = p.exchange(&mut ctrl, Phase::Intent, SimTime::ZERO).unwrap();
        assert_eq!(io.root_msgs, 16, "2 x fanout at the root");
        // Every rank and every tree link is touched once per sweep:
        // 64 sub-coordinator links + 512 leaf links, down and up.
        assert_eq!(io.msgs, 2 * (64 + 512));
        assert_eq!(io.reparents, 0);
    }

    #[test]
    fn tree_phase_is_faster_than_flat_at_scale() {
        let mut tree = plane(512, 8, None);
        let mut flat = super::super::FlatPlane::new(512);
        let t = tree.exchange(&mut net(), Phase::Intent, SimTime::ZERO).unwrap();
        let f = flat.exchange(&mut net(), Phase::Intent, SimTime::ZERO).unwrap();
        assert!(
            t.secs < f.secs,
            "tree {}s must beat flat {}s at 512 ranks",
            t.secs,
            f.secs
        );
    }

    #[test]
    fn death_reparents_to_sibling_and_retries() {
        // 32 ranks -> 4 nodes, fanout 2: subs 0,1 under root; 2,3 under 0.
        let mut p = plane(32, 2, Some((2, Phase::Intent)));
        let mut ctrl = net();
        let io = p.exchange(&mut ctrl, Phase::Intent, SimTime::ZERO).unwrap();
        assert_eq!(io.reparents, 1);
        assert_eq!(io.retries, 1);
        assert!(io.secs >= ctrl.cfg.keepalive_interval, "death detection charged");
        assert!(!p.subs[2].alive);
        assert_eq!(p.alive_subs(), 3);
        // Sub 2's ranks were adopted by its sibling, sub 3.
        assert_eq!(p.subs[3].ranks.len(), 16);
        assert_eq!(covered_ranks(&p), 32, "every rank still has a home");
        // The fault is one-shot: the next exchange is clean.
        let io2 = p.exchange(&mut ctrl, Phase::Intent, SimTime::ZERO).unwrap();
        assert_eq!(io2.reparents, 0);
    }

    #[test]
    fn only_root_child_death_falls_back_to_root() {
        // 8 ranks -> 1 node -> 1 sub-coordinator; its death leaves the
        // root speaking to the ranks directly.
        let mut p = plane(8, 2, Some((0, Phase::Drain)));
        let mut ctrl = net();
        let counts: Vec<(u64, u64)> = (0..8).map(|i| (i as u64, (7 - i) as u64)).collect();
        let red = p.reduce_counts(&mut ctrl, &counts, SimTime::ZERO).unwrap();
        assert_eq!(red.io.reparents, 1);
        assert_eq!(red.sent, 28);
        assert_eq!(red.recv, 28);
        assert_eq!(p.alive_subs(), 0);
        assert_eq!(p.root_ranks.len(), 8);
        assert_eq!(covered_ranks(&p), 8);
        // Degenerate flat fallback: root now touches 2 x ranks.
        let io = p.exchange(&mut ctrl, Phase::Resume, SimTime::ZERO).unwrap();
        assert_eq!(io.root_msgs, 16);
    }

    #[test]
    fn reduce_counts_sums_up_the_tree() {
        let mut p = plane(64, 4, None);
        let counts: Vec<(u64, u64)> = (0..64).map(|_| (10, 10)).collect();
        let red = p.reduce_counts(&mut net(), &counts, SimTime::ZERO).unwrap();
        assert_eq!(red.sent, 640);
        assert_eq!(red.recv, 640);
        assert!(red.io.root_msgs <= 2 * 4, "one aggregate per root child");
    }

    #[test]
    fn overlapped_phases_fuse_the_sweeps() {
        let mut p = plane(512, 8, None);
        let mut ctrl = net();
        let o = p
            .exchange_overlapped(&mut ctrl, Phase::Intent, Phase::SafePoint, SimTime::ZERO)
            .unwrap();
        // Accounting is identical to two serial exchanges...
        let mut q = plane(512, 8, None);
        let mut ctrl2 = net();
        let a = q.exchange(&mut ctrl2, Phase::Intent, SimTime::ZERO).unwrap();
        let b = q.exchange(&mut ctrl2, Phase::SafePoint, SimTime::ZERO).unwrap();
        assert_eq!(o.first.msgs + o.second.msgs, a.msgs + b.msgs);
        assert_eq!(
            o.first.root_msgs + o.second.root_msgs,
            a.root_msgs + b.root_msgs,
            "overlap buys time, never traffic"
        );
        // ...but the fused pair beats the serial sum and respects the
        // pipeline floor (neither phase can finish before its own work).
        assert!(o.secs < a.secs + b.secs, "{} !< {}", o.secs, a.secs + b.secs);
        assert!(o.secs >= o.first.secs.max(o.second.secs));
        assert!(o.first.down_secs > 0.0 && o.first.down_secs < o.first.secs);
        assert_eq!(o.stale_acks, 0);
        assert_eq!(p.epoch(), 0);
    }

    #[test]
    fn death_during_overlap_forfeits_credit_and_drops_stale_acks() {
        // 32 ranks -> 4 nodes at fanout 2: sub 2 dies as the second
        // phase's broadcast reaches it mid-overlap.
        let mut p = plane(32, 2, Some((2, Phase::SafePoint)));
        let mut ctrl = net();
        let o = p
            .exchange_overlapped(&mut ctrl, Phase::Intent, Phase::SafePoint, SimTime::ZERO)
            .unwrap();
        assert_eq!(o.first.reparents, 0, "first phase completed cleanly");
        assert_eq!(o.second.reparents, 1);
        assert_eq!(o.second.retries, 1);
        // The dead node's 8 ranks had acks in flight — stale-epoch, all
        // discarded and re-collected by the retry.
        assert_eq!(o.stale_acks, 8);
        assert_eq!(p.epoch(), 1, "re-parent bumped the epoch");
        // Recovery forfeits the overlap credit: the pair charges serially.
        assert_eq!(o.secs, o.first.secs + o.second.secs);
        // The repaired tree covers every rank exactly once, so the drain
        // reduction after the mid-overlap re-parent double-counts nothing.
        assert_eq!(covered_ranks(&p), 32);
        let counts: Vec<(u64, u64)> = (0..32).map(|_| (3, 3)).collect();
        let red = p.reduce_counts(&mut ctrl, &counts, SimTime::ZERO).unwrap();
        assert_eq!(red.sent, 96, "each rank folded exactly once");
        assert_eq!(red.recv, 96);
    }

    #[test]
    fn drain_schedule_costs_depth_not_ranks() {
        let mut small = plane(64, 8, None);
        let mut big = plane(4096, 8, None);
        let mut ctrl = net();
        let s = small
            .drain_schedule(&mut ctrl, 4, SimTime::ZERO)
            .unwrap();
        let b = big.drain_schedule(&mut ctrl, 9, SimTime::ZERO).unwrap();
        assert_eq!(s.root_msgs, 1);
        assert_eq!(b.root_msgs, 1);
        assert_eq!(s.msgs, u64::from(small.depth()));
        assert_eq!(b.msgs, u64::from(big.depth()));
        // Cost is a few hop latencies — orders of magnitude under the
        // counter reduce at the same scale.
        let counts: Vec<(u64, u64)> = vec![(1, 1); 4096];
        let red = big.reduce_counts(&mut ctrl, &counts, SimTime::ZERO).unwrap();
        assert!(red.io.secs > 2.0 * b.secs);
    }

    #[test]
    fn faulty_links_are_retried_by_keepalive_on_every_hop() {
        let mut p = plane(128, 4, None);
        let mut ctrl = ControlNet::new(
            CtrlConfig {
                loss_prob: 0.2,
                disconnect_prob: 0.05,
                ..CtrlConfig::default()
            },
            3,
        );
        let io = p.exchange(&mut ctrl, Phase::Intent, SimTime::ZERO).unwrap();
        assert!(ctrl.stats.retries + ctrl.stats.reconnects > 0);
        assert!(io.secs > 0.0);
    }

    #[test]
    fn describe_and_groups_name_the_layout() {
        let p = plane(64, 4, None);
        assert!(p.describe().starts_with("tree(fanout=4"));
        let g = p.groups();
        assert_eq!(g.len(), 8, "one group per sub-coordinator");
        assert!(g.iter().any(|x| x.parent == "root"));
        assert!(g.iter().any(|x| x.parent.starts_with("sub")));
    }
}
