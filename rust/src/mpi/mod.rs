//! Simulated MPI runtime — the *lower half* of the split process.
//!
//! MANA is MPI-agnostic: its wrappers only need MPI *semantics*, so the
//! substrate is a faithful-but-simulated message-passing world: ranks
//! exchange tagged messages over the [`crate::simnet::fabric::Fabric`],
//! every byte sent and received is counted (the paper's drain condition —
//! "we delayed the final checkpoint until the count of total bytes sent and
//! received was equal" — is evaluated on exactly these counters), and
//! collectives advance all participants' virtual clocks together.
//!
//! The world is deterministic: rank programs are stepped by the simulation
//! driver, and message delivery times come from the fabric model.

pub mod collectives;
pub mod comm;

use std::collections::VecDeque;

use crate::simnet::fabric::Fabric;
use crate::topology::RankId;
use crate::util::simclock::SimTime;

/// A tagged point-to-point message in flight or delivered.
#[derive(Clone, Debug)]
pub struct Message {
    pub src: RankId,
    pub dst: RankId,
    pub tag: u32,
    /// Bytes charged to the fabric (virtual size).
    pub bytes: u64,
    /// Real payload carried end-to-end (halo data, small).
    pub payload: Vec<u8>,
    pub sent_at: SimTime,
    pub deliver_at: SimTime,
}

/// Per-rank traffic counters (the drain-protocol bookkeeping).
#[derive(Clone, Copy, Debug, Default)]
pub struct RankCounters {
    pub sent_bytes: u64,
    pub recv_bytes: u64,
    pub sent_msgs: u64,
    pub recv_msgs: u64,
}

/// The simulated communicator (MPI_COMM_WORLD).
#[derive(Clone, Debug)]
pub struct MpiWorld {
    pub size: u32,
    pub fabric: Fabric,
    /// In-flight / undelivered messages, queued per destination rank in
    /// delivery order.
    inflight: Vec<VecDeque<Message>>,
    pub counters: Vec<RankCounters>,
}

impl MpiWorld {
    pub fn new(size: u32, fabric: Fabric) -> Self {
        MpiWorld {
            size,
            fabric,
            inflight: (0..size).map(|_| VecDeque::new()).collect(),
            counters: vec![RankCounters::default(); size as usize],
        }
    }

    /// Non-blocking send: enqueue into the fabric, charge the counter,
    /// return the delivery time.
    pub fn isend(
        &mut self,
        src: RankId,
        dst: RankId,
        tag: u32,
        bytes: u64,
        payload: Vec<u8>,
        now: SimTime,
    ) -> SimTime {
        assert!(src.0 < self.size && dst.0 < self.size, "rank out of range");
        let deliver_at = self.fabric.delivery_time(now, bytes);
        let msg = Message {
            src,
            dst,
            tag,
            bytes,
            payload,
            sent_at: now,
            deliver_at,
        };
        let q = &mut self.inflight[dst.0 as usize];
        // Keep per-destination queue sorted by delivery time (stable for
        // equal times -> deterministic matching).
        let pos = q.partition_point(|m| m.deliver_at <= deliver_at);
        q.insert(pos, msg);
        let c = &mut self.counters[src.0 as usize];
        c.sent_bytes += bytes;
        c.sent_msgs += 1;
        deliver_at
    }

    /// Try to receive a message matching (src, tag) that has arrived by
    /// `now`. `None` for src/tag means ANY_SOURCE/ANY_TAG.
    pub fn try_recv(
        &mut self,
        dst: RankId,
        src: Option<RankId>,
        tag: Option<u32>,
        now: SimTime,
    ) -> Option<Message> {
        let q = &mut self.inflight[dst.0 as usize];
        let idx = q.iter().position(|m| {
            m.deliver_at <= now
                && src.is_none_or(|s| m.src == s)
                && tag.is_none_or(|t| m.tag == t)
        })?;
        let msg = q.remove(idx).unwrap();
        let c = &mut self.counters[dst.0 as usize];
        c.recv_bytes += msg.bytes;
        c.recv_msgs += 1;
        Some(msg)
    }

    /// Blocking receive: waits (advances the caller's clock) until a
    /// matching message arrives. Panics if none is in flight — in the
    /// deterministic driver a blocking recv without a matching send is a
    /// program bug, which is exactly what MPI deadlock is.
    pub fn recv_blocking(
        &mut self,
        dst: RankId,
        src: Option<RankId>,
        tag: Option<u32>,
        now: &mut SimTime,
    ) -> Message {
        if let Some(m) = self.try_recv(dst, src, tag, *now) {
            return m;
        }
        // Find the earliest matching in-flight message and wait for it.
        let q = &self.inflight[dst.0 as usize];
        let arrival = q
            .iter()
            .filter(|m| {
                src.is_none_or(|s| m.src == s) && tag.is_none_or(|t| m.tag == t)
            })
            .map(|m| m.deliver_at)
            .next()
            .unwrap_or_else(|| {
                panic!("deadlock: {dst} blocked in recv(src={src:?}, tag={tag:?}) with nothing in flight")
            });
        *now = now.max(arrival);
        self.try_recv(dst, src, tag, *now)
            .expect("message present at its delivery time")
    }

    /// Earliest pending delivery for a rank (drain loop uses this).
    pub fn next_arrival(&self, dst: RankId) -> Option<SimTime> {
        self.inflight[dst.0 as usize].front().map(|m| m.deliver_at)
    }

    /// Pop the front in-flight message queued for `dst` WITHOUT touching
    /// the receive counters. The event core's materialize consumes the
    /// window-entry messages whose byte accounting was already applied in
    /// closed form during the bulk advance.
    pub(crate) fn pop_inflight_raw(&mut self, dst: RankId) -> Option<Message> {
        self.inflight[dst.0 as usize].pop_front()
    }

    /// Sorted-insert a message WITHOUT touching the send counters (the
    /// event core rebuilding the steady-state in-flight window at
    /// materialize time; accounting was applied in closed form).
    pub(crate) fn push_inflight_raw(&mut self, msg: Message) {
        let q = &mut self.inflight[msg.dst.0 as usize];
        let pos = q.partition_point(|m| m.deliver_at <= msg.deliver_at);
        q.insert(pos, msg);
    }

    /// Read-only view of `dst`'s in-flight queue (event-core eligibility
    /// inspection).
    pub(crate) fn inflight_for(&self, dst: RankId) -> &VecDeque<Message> {
        &self.inflight[dst.0 as usize]
    }

    /// Apply a closed-form counter delta to one rank (bulk-advance
    /// accounting for steps that were never individually simulated).
    pub(crate) fn add_counters(&mut self, rank: RankId, d: RankCounters) {
        let c = &mut self.counters[rank.0 as usize];
        c.sent_bytes += d.sent_bytes;
        c.recv_bytes += d.recv_bytes;
        c.sent_msgs += d.sent_msgs;
        c.recv_msgs += d.recv_msgs;
    }

    /// Is any message (delivered-or-not) in flight matching the filter?
    pub fn has_matching_inflight(
        &self,
        dst: RankId,
        src: Option<RankId>,
        tag: Option<u32>,
    ) -> bool {
        self.inflight[dst.0 as usize].iter().any(|m| {
            src.is_none_or(|s| m.src == s) && tag.is_none_or(|t| m.tag == t)
        })
    }

    /// Messages still undelivered, across all ranks.
    pub fn inflight_count(&self) -> usize {
        self.inflight.iter().map(|q| q.len()).sum()
    }

    /// The paper's drain condition: total bytes sent == total bytes
    /// received across the whole job.
    pub fn drained(&self) -> bool {
        self.total_sent_bytes() == self.total_recv_bytes()
    }

    pub fn total_sent_bytes(&self) -> u64 {
        self.counters.iter().map(|c| c.sent_bytes).sum()
    }

    pub fn total_recv_bytes(&self) -> u64 {
        self.counters.iter().map(|c| c.recv_bytes).sum()
    }

    /// Overwrite the payload of the oldest undelivered message matching
    /// (src, dst, tag) — models a send buffer being reused while the
    /// converted MPI_Isend is still in flight (the wrapper-layer semantics
    /// bug). Returns true if a message was clobbered.
    pub fn clobber_inflight(
        &mut self,
        src: RankId,
        dst: RankId,
        tag: u32,
        new_payload: Vec<u8>,
    ) -> bool {
        if let Some(m) = self.inflight[dst.0 as usize]
            .iter_mut()
            .find(|m| m.src == src && m.tag == tag)
        {
            m.payload = new_payload;
            true
        } else {
            false
        }
    }

    /// Drop every in-flight message — what a checkpoint *without* the drain
    /// fix does to the network. Returns how many messages were lost.
    pub fn drop_inflight(&mut self) -> usize {
        let n = self.inflight_count();
        for q in &mut self.inflight {
            q.clear();
        }
        n
    }

    /// Reset the communicator (restart path: fresh lower half). Counters
    /// restart at the checkpoint-consistent values supplied by the caller.
    pub fn reset(&mut self, counters: Vec<RankCounters>) {
        assert_eq!(counters.len(), self.size as usize);
        for q in &mut self.inflight {
            q.clear();
        }
        self.counters = counters;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn world(n: u32) -> MpiWorld {
        MpiWorld::new(n, Fabric::default())
    }

    #[test]
    fn send_then_recv_roundtrip() {
        let mut w = world(2);
        let mut t = SimTime::ZERO;
        w.isend(RankId(0), RankId(1), 7, 1024, vec![1, 2, 3], t);
        let m = w.recv_blocking(RankId(1), Some(RankId(0)), Some(7), &mut t);
        assert_eq!(m.payload, vec![1, 2, 3]);
        assert!(t.as_secs() > 0.0, "recv advanced time to delivery");
        assert!(w.drained());
    }

    #[test]
    fn try_recv_respects_delivery_time() {
        let mut w = world(2);
        w.isend(RankId(0), RankId(1), 0, 1 << 20, vec![], SimTime::ZERO);
        // Too early: the MiB hasn't arrived yet.
        assert!(w.try_recv(RankId(1), None, None, SimTime::secs(1e-9)).is_none());
        assert!(w
            .try_recv(RankId(1), None, None, SimTime::secs(1.0))
            .is_some());
    }

    #[test]
    fn tag_and_source_matching() {
        let mut w = world(3);
        let t = SimTime::ZERO;
        w.isend(RankId(0), RankId(2), 1, 8, vec![0], t);
        w.isend(RankId(1), RankId(2), 2, 8, vec![1], t);
        let late = SimTime::secs(1.0);
        let m = w.try_recv(RankId(2), Some(RankId(1)), None, late).unwrap();
        assert_eq!(m.payload, vec![1]);
        let m = w.try_recv(RankId(2), None, Some(1), late).unwrap();
        assert_eq!(m.payload, vec![0]);
        assert!(w.try_recv(RankId(2), None, None, late).is_none());
    }

    #[test]
    fn counters_track_bytes() {
        let mut w = world(2);
        let mut t = SimTime::ZERO;
        w.isend(RankId(0), RankId(1), 0, 100, vec![], t);
        w.isend(RankId(0), RankId(1), 0, 50, vec![], t);
        assert_eq!(w.total_sent_bytes(), 150);
        assert_eq!(w.total_recv_bytes(), 0);
        assert!(!w.drained());
        w.recv_blocking(RankId(1), None, None, &mut t);
        w.recv_blocking(RankId(1), None, None, &mut t);
        assert!(w.drained());
        assert_eq!(w.counters[1].recv_msgs, 2);
    }

    #[test]
    fn drop_inflight_models_undrained_checkpoint() {
        let mut w = world(2);
        w.isend(RankId(0), RankId(1), 0, 64, vec![42], SimTime::ZERO);
        assert_eq!(w.drop_inflight(), 1);
        assert_eq!(w.inflight_count(), 0);
        // The byte accounting now shows the permanent loss.
        assert!(!w.drained());
    }

    #[test]
    #[should_panic(expected = "deadlock")]
    fn recv_without_send_is_deadlock() {
        let mut w = world(2);
        let mut t = SimTime::ZERO;
        w.recv_blocking(RankId(1), Some(RankId(0)), None, &mut t);
    }

    #[test]
    fn delivery_order_fifo_per_pair() {
        let mut w = world(2);
        let mut t = SimTime::ZERO;
        w.isend(RankId(0), RankId(1), 0, 8, vec![1], t);
        w.isend(RankId(0), RankId(1), 0, 8, vec![2], t);
        let a = w.recv_blocking(RankId(1), None, None, &mut t);
        let b = w.recv_blocking(RankId(1), None, None, &mut t);
        assert_eq!((a.payload[0], b.payload[0]), (1, 2));
    }

    #[test]
    fn reset_clears_queues_and_sets_counters() {
        let mut w = world(2);
        w.isend(RankId(0), RankId(1), 0, 8, vec![], SimTime::ZERO);
        let saved = w.counters.clone();
        w.reset(saved.clone());
        assert_eq!(w.inflight_count(), 0);
        assert_eq!(w.counters[0].sent_bytes, saved[0].sent_bytes);
    }
}
