//! The split process: one MPI rank as MANA sees it.
//!
//! A [`SplitProcess`] owns a rank's address space (upper + lower halves),
//! its fd registry, its application PRNG and step counter. Checkpoint
//! captures the upper half into a [`CkptImage`]; restart builds a *fresh*
//! lower half (the "trivial MPI application" of the paper) and restores the
//! upper half into it — the two restart-time conflicts the paper debugged
//! (address squatting, fd collision) surface exactly here.

use anyhow::{bail, Context, Result};

use crate::ckpt::CkptImage;
use crate::fdreg::{FdPolicy, FdRegistry};
use crate::mem::{AddressSpace, AllocPolicy, Half, OsVersion, Payload};
use crate::topology::RankId;
use crate::util::prng::Xoshiro256;
use crate::log_debug;

/// Configuration shared by all ranks of a job.
#[derive(Clone, Copy, Debug)]
pub struct SplitConfig {
    pub os: OsVersion,
    pub alloc_policy: AllocPolicy,
    pub fd_policy: FdPolicy,
    /// Lower-half core size (library text/data, GNI buffers).
    pub lower_core_bytes: u64,
    /// Eager-buffer pool the MPI library mmaps lazily at scale (the
    /// "new memory regions for message exchange at runtime" bug).
    pub eager_pool_bytes: u64,
}

impl Default for SplitConfig {
    fn default() -> Self {
        SplitConfig {
            os: OsVersion::Cle7,
            alloc_policy: AllocPolicy::NoReplace,
            fd_policy: FdPolicy::Reserved,
            lower_core_bytes: 64 << 20,
            eager_pool_bytes: 32 << 20,
        }
    }
}

/// One simulated rank process under MANA.
#[derive(Clone, Debug)]
pub struct SplitProcess {
    pub rank: RankId,
    pub cfg: SplitConfig,
    pub aspace: AddressSpace,
    pub fds: FdRegistry,
    /// Application PRNG (checkpointed state).
    pub rng: Xoshiro256,
    /// Application outer-step counter (checkpointed).
    pub step: u64,
    /// Set when a latent memory corruption has been detected.
    pub corrupted: bool,
}

impl SplitProcess {
    /// Launch a fresh rank: lower half first (as the real loader does),
    /// then the application registers upper-half regions.
    pub fn launch(rank: RankId, cfg: SplitConfig, seed: u64) -> Result<Self> {
        let mut aspace = AddressSpace::new(cfg.os, cfg.alloc_policy);
        // Lower-half core: MANA runtime + MPI + libc.
        aspace
            .alloc(cfg.lower_core_bytes, Half::Lower, "lh_core", Payload::Zero)
            .map_err(|e| anyhow::anyhow!("lower-half map failed: {e}"))?;
        let mut fds = FdRegistry::new(cfg.fd_policy);
        // The lower half always owns the coordinator socket.
        fds.open(Half::Lower, "coord.socket");
        Ok(SplitProcess {
            rank,
            cfg,
            aspace,
            fds,
            rng: Xoshiro256::stream(seed, rank.0 as u64),
            step: 0,
            corrupted: false,
        })
    }

    /// Register an application (upper-half) region.
    pub fn map_app_region(&mut self, name: &str, vlen: u64, payload: Payload) -> Result<u64> {
        self.aspace
            .alloc(vlen, Half::Upper, name, payload)
            .map_err(|e| anyhow::anyhow!("app map failed: {e}"))
    }

    /// The large-scale bug: the MPI library maps a new eager-message pool
    /// at runtime. Under the legacy fixed-address policy this can land on
    /// top of upper-half memory; the Lesson-1 runtime check flags it.
    pub fn lower_half_growth(&mut self) -> Result<()> {
        self.aspace
            .alloc(
                self.cfg.eager_pool_bytes,
                Half::Lower,
                "mpi.eager_pool",
                Payload::Zero,
            )
            .map_err(|e| anyhow::anyhow!("eager pool map failed: {e}"))?;
        if !self.aspace.table.check_invariants().is_empty() {
            self.corrupted = true;
        }
        Ok(())
    }

    /// Update the real payload of an app region (compute state evolved).
    pub fn store_app_state(&mut self, name: &str, data: Vec<u8>) -> Result<()> {
        let full = format!("mana.{name}");
        let region = self
            .aspace
            .table
            .get_mut(&full)
            .with_context(|| format!("no app region {full}"))?;
        region.payload = Payload::Real(data);
        region.dirty = true;
        Ok(())
    }

    pub fn app_state(&self, name: &str) -> Option<&[u8]> {
        match &self.aspace.table.get(&format!("mana.{name}"))?.payload {
            Payload::Real(v) => Some(v),
            _ => None,
        }
    }

    /// Open an application-level fd (upper half).
    pub fn open_app_fd(&mut self, name: &str) -> u32 {
        self.fds.open(Half::Upper, name)
    }

    /// Checkpoint: capture the upper half.
    pub fn checkpoint(&self) -> CkptImage {
        CkptImage::capture(
            self.rank,
            self.step,
            self.rng.state_bytes(),
            self.fds.fds_of(Half::Upper),
            &self.aspace.table,
        )
    }

    /// Restart from an image: fresh process, trivial lower half, then
    /// restore. This is where the paper's two restart conflicts surface.
    pub fn restart(image: &CkptImage, cfg: SplitConfig, seed: u64) -> Result<Self> {
        // The trivial MPI application boots a brand-new lower half.
        let mut proc = SplitProcess::launch(image.rank, cfg, seed)?;
        // The restarter holds the image file open while restoring — one
        // more lower-half descriptor than the original launch had, which is
        // precisely how the legacy shared-pool policy collides with
        // checkpointed upper-half fd numbers.
        proc.fds.open(Half::Lower, "restart.img");
        match cfg.alloc_policy {
            AllocPolicy::NoReplace => {
                // The fix: MANA reads the image header first and *reserves*
                // the checkpointed ranges (restores them) before the trivial
                // app's MPI library can mmap anything into them.
                for r in &image.regions {
                    proc.aspace
                        .restore_at(r.to_region())
                        .map_err(|e| anyhow::anyhow!("restart: {e}"))?;
                }
                proc.lower_half_growth()
                    .context("restart: trivial app lower-half init")?;
            }
            AllocPolicy::FixedLegacy => {
                // The original behaviour: the lower half initializes blind,
                // then the restore collides with whatever it mapped — the
                // paper's restart-time overlap.
                proc.lower_half_growth()
                    .context("restart: trivial app lower-half init")?;
                for r in &image.regions {
                    proc.aspace
                        .restore_at(r.to_region())
                        .map_err(|e| anyhow::anyhow!("restart: {e}"))?;
                }
            }
        }
        // Re-claim upper-half fds.
        for (fd, name) in &image.upper_fds {
            if let Err(e) = proc.fds.claim(*fd, name) {
                bail!("restart: {e}");
            }
        }
        proc.step = image.step;
        proc.rng = Xoshiro256::from_state_bytes(&image.rng_state);
        log_debug!(
            "splitproc",
            "{} restored at step {} ({} regions, {} fds)",
            image.rank,
            image.step,
            image.regions.len(),
            image.upper_fds.len()
        );
        Ok(proc)
    }

    /// Fingerprint of the checkpointable state (determinism checks).
    pub fn fingerprint(&self) -> u64 {
        use crate::util::{fnv1a, hash_combine};
        let mut h = hash_combine(self.step, fnv1a(&self.rng.state_bytes()));
        h = hash_combine(h, self.aspace.table.upper_fingerprint());
        h
    }

    /// Aggregate upper-half footprint (what a checkpoint will write).
    pub fn upper_bytes(&self) -> u64 {
        self.aspace.table.total_bytes(Half::Upper)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_fixed_legacy() -> SplitConfig {
        SplitConfig {
            alloc_policy: AllocPolicy::FixedLegacy,
            fd_policy: FdPolicy::Legacy,
            ..SplitConfig::default()
        }
    }

    #[test]
    fn launch_and_map_regions() {
        let mut p = SplitProcess::launch(RankId(0), SplitConfig::default(), 1).unwrap();
        p.map_app_region("pos", 1 << 20, Payload::Real(vec![1, 2])).unwrap();
        p.map_app_region("heap", 1 << 30, Payload::Pattern(9)).unwrap();
        assert_eq!(p.upper_bytes(), (1 << 20) + (1 << 30));
        assert!(p.aspace.table.check_invariants().is_empty());
    }

    #[test]
    fn checkpoint_restart_roundtrip_preserves_state() {
        let cfg = SplitConfig::default();
        let mut p = SplitProcess::launch(RankId(2), cfg, 7).unwrap();
        p.map_app_region("state", 4096, Payload::Real(vec![42; 16])).unwrap();
        p.open_app_fd("traj.xtc");
        p.step = 99;
        for _ in 0..13 {
            p.rng.next_u64();
        }
        let fp = p.fingerprint();

        let img = p.checkpoint();
        let bytes = img.encode();
        let decoded = CkptImage::decode(&bytes).unwrap();
        let restored = SplitProcess::restart(&decoded, cfg, 7).unwrap();

        assert_eq!(restored.step, 99);
        assert_eq!(restored.fingerprint(), fp, "bitwise state identity");
        assert_eq!(restored.app_state("state").unwrap(), &[42u8; 16][..]);
    }

    #[test]
    fn restored_rng_continues_identically() {
        let cfg = SplitConfig::default();
        let mut p = SplitProcess::launch(RankId(0), cfg, 3).unwrap();
        for _ in 0..5 {
            p.rng.next_u64();
        }
        let img = p.checkpoint();
        let mut q = SplitProcess::restart(&img, cfg, 3).unwrap();
        let mut orig = p.rng.clone();
        for _ in 0..50 {
            assert_eq!(orig.next_u64(), q.rng.next_u64());
        }
    }

    #[test]
    fn legacy_fd_policy_breaks_restart() {
        let cfg = cfg_fixed_legacy();
        // Use NoReplace alloc to isolate the fd failure.
        let cfg = SplitConfig {
            alloc_policy: AllocPolicy::NoReplace,
            ..cfg
        };
        let mut p = SplitProcess::launch(RankId(0), cfg, 1).unwrap();
        p.map_app_region("s", 4096, Payload::Zero).unwrap();
        // Upper half opens a file; under Legacy it gets fd 4 (3 is the
        // coordinator socket). At restart, the trivial app's lower half
        // opens the coordinator socket (3) AND the image file (4) before
        // the upper half is restored — fd 4 collides.
        let fd = p.open_app_fd("output.dat");
        assert_eq!(fd, 4);

        let img = p.checkpoint();
        let err = SplitProcess::restart(&img, cfg, 1).unwrap_err();
        assert!(err.to_string().contains("fd 4 conflict"), "{err}");
    }

    #[test]
    fn reserved_fd_policy_restart_succeeds() {
        let cfg = SplitConfig::default();
        let mut p = SplitProcess::launch(RankId(0), cfg, 1).unwrap();
        p.map_app_region("s", 4096, Payload::Zero).unwrap();
        p.open_app_fd("output.dat");
        let img = p.checkpoint();
        SplitProcess::restart(&img, cfg, 1).unwrap();
    }

    #[test]
    fn legacy_alloc_policy_corrupts_on_lower_growth() {
        let cfg = SplitConfig {
            alloc_policy: AllocPolicy::FixedLegacy,
            os: OsVersion::Cle7,
            ..SplitConfig::default()
        };
        let mut p = SplitProcess::launch(RankId(0), cfg, 1).unwrap();
        // Legacy bump allocation puts the app heap right after lh_core…
        p.map_app_region("heap", 1 << 20, Payload::Pattern(1)).unwrap();
        // …and the MPI library's runtime eager pool then lands on it.
        p.lower_half_growth().unwrap();
        assert!(p.corrupted, "eager pool must overlap upper half under legacy policy");
    }

    #[test]
    fn noreplace_alloc_policy_survives_lower_growth() {
        let mut p = SplitProcess::launch(RankId(0), SplitConfig::default(), 1).unwrap();
        p.map_app_region("heap", 1 << 20, Payload::Pattern(1)).unwrap();
        p.lower_half_growth().unwrap();
        assert!(!p.corrupted);
        assert!(p.aspace.table.check_invariants().is_empty());
    }

    #[test]
    fn store_and_read_app_state() {
        let mut p = SplitProcess::launch(RankId(0), SplitConfig::default(), 1).unwrap();
        p.map_app_region("vel", 1024, Payload::Zero).unwrap();
        p.store_app_state("vel", vec![9, 9, 9]).unwrap();
        assert_eq!(p.app_state("vel").unwrap(), &[9, 9, 9][..]);
        assert!(p.store_app_state("nope", vec![]).is_err());
    }
}
