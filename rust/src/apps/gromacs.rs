//! Gromacs/ADH analog: Lennard-Jones molecular dynamics.
//!
//! The paper's Fig. 2 workload. Per-rank state: 256 atoms (positions +
//! velocities) evolved by the `md_step` artifact — leapfrog MD whose force
//! loop is the L1 Pallas LJ kernel — plus a ~1.5 GiB (virtual) heap that
//! dominates the checkpoint image, matching the ADH benchmark's per-rank
//! footprint on Cori.
//!
//! Gromacs has internal C/R, but the paper's point is that MANA can
//! checkpoint it *at any point* and resume "to generate exactly the same
//! results as an uninterrupted run" — the E2E quickstart asserts that
//! bitwise property on this app.

use anyhow::{Context, Result};

use super::{bytes_to_f32, f32_to_bytes, map_common_regions, synth_evolve, App, StepCtx};
use crate::config::{AppKind, ComputeMode};
use crate::mem::Payload;
use crate::splitproc::SplitProcess;

/// Atoms per rank (matches python/compile/model.py::MD_N_ATOMS).
pub const N_ATOMS: usize = 256;
/// Box edge (matches MD_BOX).
pub const BOX: f32 = 12.0;

pub struct GromacsAdh;

impl App for GromacsAdh {
    fn kind(&self) -> AppKind {
        AppKind::Gromacs
    }

    fn artifact(&self) -> Option<&'static str> {
        Some("md_step")
    }

    fn default_mem_per_rank(&self) -> u64 {
        3 * (1 << 30) / 2 // 1.5 GiB: ADH-analog per-rank footprint
    }

    fn compute_secs(&self) -> f64 {
        0.35 // ~4 MD inner steps per superstep at ADH scale
    }

    fn init(&self, proc: &mut SplitProcess, _ranks: u32, mem_per_rank: u64) -> Result<()> {
        // Deterministic initial condition from the rank's seeded PRNG.
        let mut pos = Vec::with_capacity(N_ATOMS * 3);
        let mut vel = Vec::with_capacity(N_ATOMS * 3);
        for _ in 0..N_ATOMS * 3 {
            pos.push(proc.rng.next_f32() * BOX);
            vel.push((proc.rng.next_f32() - 0.5) * 0.2);
        }
        let state_bytes = (pos.len() + vel.len()) as u64 * 4 + 4;
        proc.map_app_region("pos", pos.len() as u64 * 4, Payload::Real(f32_to_bytes(&pos)))?;
        proc.map_app_region("vel", vel.len() as u64 * 4, Payload::Real(f32_to_bytes(&vel)))?;
        proc.map_app_region("ke", 4, Payload::Real(vec![0u8; 4]))?;
        map_common_regions(proc, mem_per_rank, state_bytes)?;
        // The trajectory output file the descriptor-conflict bug needs.
        proc.open_app_fd("traj.xtc");
        Ok(())
    }

    fn compute(&self, ctx: &mut StepCtx) -> Result<()> {
        match ctx.mode {
            ComputeMode::Real => {
                let pos = bytes_to_f32(ctx.proc.app_state("pos").context("pos")?);
                let vel = bytes_to_f32(ctx.proc.app_state("vel").context("vel")?);
                let out = ctx.engine()?.run("md_step", &[&pos, &vel])?;
                ctx.proc.store_app_state("pos", f32_to_bytes(&out[0]))?;
                ctx.proc.store_app_state("vel", f32_to_bytes(&out[1]))?;
                ctx.proc.store_app_state("ke", f32_to_bytes(&out[2]))?;
            }
            ComputeMode::Synthetic => {
                for name in ["pos", "vel"] {
                    let mut b = ctx.proc.app_state(name).context(name)?.to_vec();
                    synth_evolve(&mut b);
                    ctx.proc.store_app_state(name, b)?;
                }
            }
        }
        Ok(())
    }
}
