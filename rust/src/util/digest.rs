//! 128-bit content digest for chunk addressing.
//!
//! The content-addressed chunk store (`fs::chunkstore`) keys durable-tier
//! chunk objects by a strong content digest: CRC32 stays the integrity
//! framing inside images (cheap, error-detecting), but a 32-bit code is far
//! too collision-prone to *address* content — a billion-chunk store would
//! see CRC collisions constantly, and a collision there silently aliases
//! two different chunks. This is a 128-bit non-cryptographic hash built
//! from two independently seeded 64-bit mixing lanes (xxhash-style
//! multiply-rotate absorption, murmur3 finalizer), processed a word at a
//! time so digesting is not the drain path's bottleneck.
//!
//! Not cryptographic: collision *resistance against an adversary* is not a
//! goal (the store only ever hashes its own checkpoint bytes); accidental
//! collision probability at 128 bits is negligible at any realistic chunk
//! count.

const PRIME1: u64 = 0x9E37_79B1_85EB_CA87;
const PRIME2: u64 = 0xC2B2_AE3D_27D4_EB4F;
const PRIME3: u64 = 0x1656_67B1_9E37_79F9;

/// Seeds chosen so the two lanes never start equal (distinct constants,
/// both odd, no shared structure with the primes).
const SEED_A: u64 = 0x2545_F491_4F6C_DD1D;
const SEED_B: u64 = 0x9FB2_1C65_1E98_DF25;

/// One-shot 128-bit digest of a byte slice.
pub fn digest128(data: &[u8]) -> u128 {
    let mut h = Hasher128::new();
    h.update(data);
    h.finalize()
}

/// Incremental 128-bit digest state (feed spans, finalize once).
#[derive(Clone, Debug)]
pub struct Hasher128 {
    a: u64,
    b: u64,
    /// Partial input word, little-endian, low `buf_len` bytes valid.
    buf: u64,
    buf_len: u32,
    /// Total bytes fed (folded into the finalizer so inputs differing only
    /// by zero-padding still digest differently).
    total: u64,
}

impl Default for Hasher128 {
    fn default() -> Self {
        Self::new()
    }
}

fn avalanche(mut x: u64) -> u64 {
    x ^= x >> 33;
    x = x.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    x ^= x >> 33;
    x = x.wrapping_mul(0xC4CE_B9FE_1A85_EC53);
    x ^= x >> 33;
    x
}

impl Hasher128 {
    pub fn new() -> Self {
        Hasher128 {
            a: SEED_A,
            b: SEED_B,
            buf: 0,
            buf_len: 0,
            total: 0,
        }
    }

    fn absorb(&mut self, w: u64) {
        self.a = (self.a ^ w.wrapping_mul(PRIME1))
            .rotate_left(27)
            .wrapping_mul(PRIME2);
        self.b = (self.b ^ w.wrapping_mul(PRIME3))
            .rotate_left(31)
            .wrapping_mul(PRIME1);
    }

    pub fn update(&mut self, data: &[u8]) {
        self.total = self.total.wrapping_add(data.len() as u64);
        let mut rest = data;
        // Top up a pending partial word first.
        if self.buf_len > 0 {
            let need = (8 - self.buf_len) as usize;
            let take = need.min(rest.len());
            for &byte in &rest[..take] {
                self.buf |= (byte as u64) << (8 * self.buf_len);
                self.buf_len += 1;
            }
            rest = &rest[take..];
            if self.buf_len == 8 {
                let w = self.buf;
                self.absorb(w);
                self.buf = 0;
                self.buf_len = 0;
            }
        }
        // Whole words, 8 bytes at a time.
        let mut words = rest.chunks_exact(8);
        for w in &mut words {
            self.absorb(u64::from_le_bytes(w.try_into().expect("8-byte chunk")));
        }
        // Stash the tail for the next update / finalize.
        for &byte in words.remainder() {
            self.buf |= (byte as u64) << (8 * self.buf_len);
            self.buf_len += 1;
        }
    }

    pub fn finalize(mut self) -> u128 {
        if self.buf_len > 0 {
            let w = self.buf;
            self.absorb(w);
        }
        let mut a = self.a ^ self.total.wrapping_mul(PRIME2);
        let mut b = self.b ^ self.total.rotate_left(32).wrapping_mul(PRIME3);
        a = avalanche(a);
        b = avalanche(b ^ a);
        ((a as u128) << 64) | b as u128
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_input_sensitive() {
        let d = digest128(b"the quick brown fox");
        assert_eq!(d, digest128(b"the quick brown fox"));
        assert_ne!(d, digest128(b"the quick brown foy"));
        assert_ne!(digest128(b""), digest128(&[0u8]));
    }

    #[test]
    fn zero_padding_changes_digest() {
        // The zero-padded tail word must not alias a longer input: the
        // total length is folded into the finalizer.
        assert_ne!(digest128(b"ab"), digest128(b"ab\0"));
        assert_ne!(digest128(&[0u8; 7]), digest128(&[0u8; 8]));
        assert_ne!(digest128(&[0u8; 8]), digest128(&[0u8; 16]));
    }

    #[test]
    fn incremental_matches_oneshot() {
        let data: Vec<u8> = (0..1027u32).map(|i| (i % 251) as u8).collect();
        let want = digest128(&data);
        for splits in [
            vec![0usize],
            vec![1, 2, 3],
            vec![7],
            vec![8],
            vec![9, 800],
            vec![1026],
        ] {
            let mut h = Hasher128::new();
            let mut pos = 0;
            for &s in &splits {
                h.update(&data[pos..s.min(data.len())]);
                pos = s.min(data.len());
            }
            h.update(&data[pos..]);
            assert_eq!(h.finalize(), want, "splits={splits:?}");
        }
    }

    #[test]
    fn single_bitflip_everywhere_changes_digest() {
        let base = vec![0x5Au8; 64];
        let want = digest128(&base);
        for i in 0..base.len() {
            let mut m = base.clone();
            m[i] ^= 1;
            assert_ne!(digest128(&m), want, "flip at {i} undetected");
        }
    }

    #[test]
    fn halves_are_independent() {
        // The two lanes must not be trivially correlated.
        let d = digest128(b"lane correlation probe");
        assert_ne!((d >> 64) as u64, d as u64);
    }
}
