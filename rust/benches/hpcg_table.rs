//! HPCG-T — the paper's in-text HPCG numbers.
//!
//! "checkpoint time for Burst Buffers at 30 seconds and CSCRATCH at over
//! 600 seconds for 512 ranks with eight OpenMP threads per task. The
//! aggregate memory used was 5.8 TB. The speedup for Burst Buffers over
//! CSCRATCH on restart was more modest at about 2.5 times whereas the
//! speedup for checkpointing was more than 20 times."

use mana::benchkit::{fsecs, Report};
use mana::config::{AppKind, RunConfig};
use mana::fs::FsKind;
use mana::sim::JobSim;
use mana::util::bytes::human;

fn measure(fs: FsKind) -> (u64, f64, f64) {
    let mut cfg = RunConfig::new(AppKind::Hpcg, 512);
    cfg.job = format!("hpcgt-{fs:?}");
    cfg.fs = fs;
    let mut sim = JobSim::launch(cfg, None).expect("launch");
    sim.run_steps(2).expect("steps");
    let agg = sim.aggregate_memory();
    let ckpt = sim.checkpoint().expect("ckpt").write_secs;
    let cfg = sim.cfg.clone();
    let fsim = sim.kill();
    let (_, rrep) = JobSim::restart_from(cfg, None, fsim).expect("restart");
    (agg, ckpt, rrep.read_secs)
}

fn main() {
    let (agg, bb_c, bb_r) = measure(FsKind::BurstBuffer);
    let (_, lu_c, lu_r) = measure(FsKind::Lustre);

    let mut rep = Report::new(
        "HPCG-T: 512 ranks x 8 threads, MANA C/R",
        vec!["metric", "paper", "measured"],
    );
    rep.row(vec!["aggregate memory".into(), "5.8 TB".into(), human(agg)]);
    rep.row(vec![
        "BB checkpoint".into(),
        "~30 s".into(),
        format!("{} s", fsecs(bb_c)),
    ]);
    rep.row(vec![
        "CSCRATCH checkpoint".into(),
        ">600 s".into(),
        format!("{} s", fsecs(lu_c)),
    ]);
    rep.row(vec![
        "ckpt speedup BB/CSCRATCH".into(),
        ">20x".into(),
        format!("{:.1}x", lu_c / bb_c),
    ]);
    rep.row(vec![
        "restart speedup BB/CSCRATCH".into(),
        "~2.5x".into(),
        format!("{:.1}x", lu_r / bb_r),
    ]);
    rep.finish();

    assert!((25.0..40.0).contains(&bb_c), "BB ckpt {bb_c}");
    assert!(lu_c > 600.0, "Lustre ckpt {lu_c}");
    assert!(lu_c / bb_c > 20.0);
    assert!((1.8..3.5).contains(&(lu_r / bb_r)));
    println!("HPCG-T OK");
}
