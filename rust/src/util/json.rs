//! Tiny JSON writer (no serde offline).
//!
//! Benches and the CLI emit machine-readable reports (EXPERIMENTS.md is
//! generated from them); this module provides just enough JSON to do that
//! correctly, including string escaping and stable key order.

use std::fmt::Write as _;

/// A JSON value built imperatively.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object (stable output for diffs).
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut fields) = self {
            fields.push((key.to_string(), value.into()));
        } else {
            panic!("set() on non-object Json");
        }
        self
    }

    pub fn push(&mut self, value: impl Into<Json>) {
        if let Json::Arr(ref mut items) = self {
            items.push(value.into());
        } else {
            panic!("push() on non-array Json");
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn object_order_stable() {
        let j = Json::obj().set("b", 1u64).set("a", 2u64);
        assert_eq!(j.to_string(), r#"{"b":1,"a":2}"#);
    }

    #[test]
    fn string_escaping() {
        let j = Json::Str("a\"b\\c\nd\u{1}".into());
        assert_eq!(j.to_string(), "\"a\\\"b\\\\c\\nd\\u0001\"");
    }

    #[test]
    fn numbers_integral_and_float() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn nested_array() {
        let mut arr = Json::Arr(vec![]);
        arr.push(1u64);
        arr.push("x");
        let j = Json::obj().set("xs", arr).set("ok", true);
        assert_eq!(j.to_string(), r#"{"xs":[1,"x"],"ok":true}"#);
    }
}
