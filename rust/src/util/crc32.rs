//! CRC-32 (IEEE 802.3, reflected, polynomial 0xEDB88320).
//!
//! The checkpoint image format CRC-protects every section, chunk, and the
//! whole-image trailer. The image's offline crate set has no `crc32fast`,
//! so this is a table-driven implementation with the same digest values
//! (bitwise-compatible with zlib's `crc32()`), exposed through the same
//! two-call API (`hash` for one-shot, `Hasher` for incremental).

/// Precomputed remainder table for byte-at-a-time CRC updates.
static TABLE: [u32; 256] = make_table();

const fn make_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 {
                0xEDB8_8320 ^ (c >> 1)
            } else {
                c >> 1
            };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// One-shot CRC of a byte slice.
pub fn hash(data: &[u8]) -> u32 {
    let mut h = Hasher::new();
    h.update(data);
    h.finalize()
}

/// Incremental CRC state (feed spans, finalize once).
#[derive(Clone, Debug)]
pub struct Hasher {
    state: u32,
}

impl Default for Hasher {
    fn default() -> Self {
        Self::new()
    }
}

impl Hasher {
    pub fn new() -> Self {
        Hasher { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, data: &[u8]) {
        let mut s = self.state;
        for &b in data {
            s = TABLE[((s ^ b as u32) & 0xff) as usize] ^ (s >> 8);
        }
        self.state = s;
    }

    pub fn finalize(self) -> u32 {
        !self.state
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // The canonical CRC-32 check value.
        assert_eq!(hash(b"123456789"), 0xCBF4_3926);
        assert_eq!(hash(b""), 0);
        assert_eq!(hash(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn incremental_matches_oneshot() {
        let data = b"the quick brown fox jumps over the lazy dog";
        for split in [0usize, 1, 7, data.len()] {
            let mut h = Hasher::new();
            h.update(&data[..split]);
            h.update(&data[split..]);
            assert_eq!(h.finalize(), hash(data), "split={split}");
        }
    }

    #[test]
    fn sensitive_to_single_bitflip() {
        let mut data = vec![0x5au8; 1024];
        let clean = hash(&data);
        data[512] ^= 0x01;
        assert_ne!(hash(&data), clean);
    }
}
