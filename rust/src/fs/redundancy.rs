//! Fast-tier peer redundancy: SCR-style partner copies and XOR parity sets.
//!
//! The fast tier is per-node storage, so a node failure before the drain
//! catches up loses every image that node wrote since the last complete
//! durable generation. Multi-level checkpointing systems (SCR, FTI) close
//! that window with *peer* redundancy: after the write wave, nodes in a
//! small redundancy set exchange either full partner copies (2x capacity,
//! survives any single loss per partner pair) or XOR parity blocks
//! (1 + 1/(m-1) x capacity, survives any single loss per set of m). On
//! restart a lost node's images are rebuilt from surviving peers over the
//! fabric — never touching the durable tier — and only an unrecoverable
//! set (>= 2 losses in an XOR set, a partner-pair loss) falls back to
//! Lustre or to an older complete generation.
//!
//! This module is the pure layer: set layout, the XOR parity code, and the
//! per-file records the rebuild planner consumes. The exchange/rebuild
//! machinery that moves bytes and charges the sim clock lives in
//! [`super::tiered::TieredStore`].
//!
//! ## XOR layout
//!
//! A set of `m` members protects each member's concatenated image bytes
//! `C_i`, conceptually padded to `c * (m-1)` bytes where
//! `c = ceil(maxlen / (m-1))`. Member `j` stores one parity block of `c`
//! bytes:
//!
//! ```text
//! P_j = XOR over i != j of chunk[((j - i + m) % m) - 1] of C_i
//! ```
//!
//! For a fixed contributor `i`, the chunk index covers `0..m-1` bijectively
//! as `j` ranges over the other members — every chunk of `C_i` lands in
//! exactly one peer's parity block, so losing any single member `x` leaves,
//! for each of its chunks `d`, exactly one parity block `P_j`
//! (`j = (x + d + 1) % m`) plus `m-2` surviving plaintext chunks from which
//! to XOR the chunk back. `m = 2` degenerates to a full mirrored copy.

use crate::topology::NodeId;

/// Default redundancy-set size (`--redundancy-set-size`), matching SCR's
/// common small-set configuration.
pub const DEFAULT_SET_SIZE: u32 = 4;

/// Which peer-redundancy scheme the fast tier runs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RedundancyScheme {
    /// No peer redundancy: a node loss falls straight to the durable tier.
    #[default]
    None,
    /// Full copy on the next node in the set (2x capacity, rebuild = one
    /// fetch; a partner *pair* loss is unrecoverable).
    Partner,
    /// Rotated XOR parity across the set (1 + 1/(m-1) x capacity; any
    /// single loss per set rebuilds, >= 2 losses are unrecoverable).
    Xor,
}

impl RedundancyScheme {
    /// CLI / manifest spelling.
    pub fn name(&self) -> &'static str {
        match self {
            RedundancyScheme::None => "none",
            RedundancyScheme::Partner => "partner",
            RedundancyScheme::Xor => "xor",
        }
    }

    /// Parse the CLI / manifest spelling.
    pub fn parse(s: &str) -> Option<RedundancyScheme> {
        match s {
            "none" => Some(RedundancyScheme::None),
            "partner" => Some(RedundancyScheme::Partner),
            "xor" => Some(RedundancyScheme::Xor),
            _ => None,
        }
    }
}

impl std::fmt::Display for RedundancyScheme {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Scheme + set size, threaded `RunConfig` -> `TieredStore`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RedundancyConfig {
    pub scheme: RedundancyScheme,
    /// Nodes per redundancy set (>= 2; a trailing singleton is folded into
    /// the previous set so no node is ever unprotected).
    pub set_size: u32,
}

impl Default for RedundancyConfig {
    fn default() -> Self {
        RedundancyConfig {
            scheme: RedundancyScheme::None,
            set_size: DEFAULT_SET_SIZE,
        }
    }
}

impl RedundancyConfig {
    pub fn new(scheme: RedundancyScheme, set_size: u32) -> Self {
        RedundancyConfig {
            scheme,
            set_size: set_size.max(2),
        }
    }

    /// Does this configuration do any peer exchange at all?
    pub fn active(&self) -> bool {
        self.scheme != RedundancyScheme::None
    }
}

/// Group `nodes` into contiguous redundancy sets of `set_size`. A trailing
/// set of one node would be unprotectable (no peer to hold its copy or
/// parity), so a lone tail is folded into the previous set; with a single
/// node total there is nothing to fold into and the singleton set stands
/// (exchange is then a no-op).
pub fn node_sets(nodes: u32, set_size: u32) -> Vec<Vec<NodeId>> {
    let k = set_size.max(2);
    let mut sets: Vec<Vec<NodeId>> = Vec::new();
    for n in 0..nodes {
        let starts_set = n % k == 0;
        let lone_tail = starts_set && n + 1 == nodes && !sets.is_empty();
        if starts_set && !lone_tail {
            sets.push(Vec::new());
        }
        sets.last_mut().expect("first node always starts a set").push(NodeId(n));
    }
    sets
}

/// Which member index holds member `i`'s partner copy (ring: next member).
pub fn partner_holder(i: usize, m: usize) -> usize {
    (i + 1) % m
}

/// XOR parity block length for a set of `m` members whose largest
/// concatenated image is `maxlen` bytes: `c = ceil(maxlen / (m-1))`,
/// never zero so an all-empty set still has well-formed parity.
pub fn parity_block_len(maxlen: u64, m: usize) -> u64 {
    maxlen.div_ceil((m.max(2) - 1) as u64).max(1)
}

/// Zero-padded chunk `d` view of `data` under chunk size `c` (may be short
/// or empty at the tail; XOR treats missing bytes as zero).
fn chunk_view(data: &[u8], d: usize, c: usize) -> &[u8] {
    let lo = (d * c).min(data.len());
    let hi = ((d + 1) * c).min(data.len());
    &data[lo..hi]
}

/// Encode one parity block per member from the members' concatenated image
/// bytes. `concats[i]` is member `i`'s concatenation; the returned
/// `parities[j]` is the block member `j` stores.
pub fn xor_encode(concats: &[&[u8]]) -> Vec<Vec<u8>> {
    let m = concats.len();
    assert!(m >= 2, "XOR set needs at least 2 members");
    let maxlen = concats.iter().map(|c| c.len() as u64).max().unwrap_or(0);
    let c = parity_block_len(maxlen, m) as usize;
    (0..m)
        .map(|j| {
            let mut p = vec![0u8; c];
            for (i, data) in concats.iter().enumerate() {
                if i == j {
                    continue;
                }
                let d = (j + m - i) % m - 1;
                for (k, b) in chunk_view(data, d, c).iter().enumerate() {
                    p[k] ^= b;
                }
            }
            p
        })
        .collect()
}

/// Reconstruct lost member `x`'s concatenation (`len` bytes) from the
/// survivors' concatenations and every member's parity block.
/// `concats[x]` is ignored (pass an empty slice). The chunk size is
/// recovered from the parity blocks themselves.
pub fn xor_rebuild(x: usize, concats: &[&[u8]], parities: &[&[u8]], len: u64) -> Vec<u8> {
    let m = concats.len();
    assert!(m >= 2 && parities.len() == m && x < m);
    let c = parities[(x + 1) % m].len();
    let mut out = vec![0u8; c * (m - 1)];
    for d in 0..m - 1 {
        // The one parity block holding C_x's chunk d.
        let j = (x + d + 1) % m;
        out[d * c..(d + 1) * c].copy_from_slice(parities[j]);
        for (i, data) in concats.iter().enumerate() {
            if i == j || i == x {
                continue;
            }
            let di = (j + m - i) % m - 1;
            for (k, b) in chunk_view(data, di, c).iter().enumerate() {
                out[d * c + k] ^= b;
            }
        }
    }
    out.truncate(len as usize);
    out
}

/// One file a redundancy set protects: enough to locate it, slice it out
/// of a member concatenation, and verify a rebuild bit-for-bit. The
/// content digest also rejects *stale* survivors — a path (the manifest)
/// rewritten by a later generation no longer XORs consistently with this
/// record, and must be treated as lost rather than silently mis-rebuilt.
#[derive(Clone, Debug)]
pub struct ProtectedFile {
    pub path: String,
    /// Virtual (modeled) size; physical bytes are `plen`.
    pub vbytes: u64,
    /// Physical length of the stored data at exchange time.
    pub plen: u64,
    /// `digest128` of the stored data at exchange time.
    pub digest: u128,
    /// Partner scheme: fast-tier path of the peer-held copy.
    pub copy: Option<String>,
}

/// One redundancy set's exchange record for one checkpoint generation:
/// the rebuild planner's entire input.
#[derive(Clone, Debug)]
pub struct SetRecord {
    pub scheme: RedundancyScheme,
    pub members: Vec<NodeId>,
    /// Per member (same order as `members`), the files its concatenation
    /// covers, in concatenation order.
    pub files: Vec<Vec<ProtectedFile>>,
    /// XOR scheme: per member, the fast-tier path of its parity block.
    pub parity: Vec<String>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_parse_roundtrip() {
        for s in [
            RedundancyScheme::None,
            RedundancyScheme::Partner,
            RedundancyScheme::Xor,
        ] {
            assert_eq!(RedundancyScheme::parse(s.name()), Some(s));
        }
        assert_eq!(RedundancyScheme::parse("raid6"), None);
        assert_eq!(RedundancyScheme::default(), RedundancyScheme::None);
    }

    #[test]
    fn config_clamps_set_size() {
        let c = RedundancyConfig::new(RedundancyScheme::Xor, 0);
        assert_eq!(c.set_size, 2);
        assert!(c.active());
        assert!(!RedundancyConfig::default().active());
    }

    fn flat(sets: &[Vec<NodeId>]) -> Vec<u32> {
        sets.iter().flatten().map(|n| n.0).collect()
    }

    #[test]
    fn set_layout_shapes() {
        let s = node_sets(8, 4);
        assert_eq!(s.len(), 2);
        assert_eq!(flat(&s), (0..8).collect::<Vec<_>>());

        // Lone tail folds into the previous set.
        let s = node_sets(9, 4);
        assert_eq!(s.len(), 2);
        assert_eq!(s[1].len(), 5);
        assert_eq!(flat(&s), (0..9).collect::<Vec<_>>());

        let s = node_sets(5, 4);
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].len(), 5);

        // A single node has no peer: singleton set stands.
        let s = node_sets(1, 4);
        assert_eq!(s.len(), 1);
        assert_eq!(s[0], vec![NodeId(0)]);

        // set_size below 2 is clamped.
        let s = node_sets(4, 1);
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn partner_ring() {
        assert_eq!(partner_holder(0, 4), 1);
        assert_eq!(partner_holder(3, 4), 0);
        assert_eq!(partner_holder(1, 2), 0);
    }

    #[test]
    fn parity_len_math() {
        assert_eq!(parity_block_len(0, 4), 1);
        assert_eq!(parity_block_len(9, 4), 3);
        assert_eq!(parity_block_len(10, 4), 4);
        // m = 2: parity is a full copy.
        assert_eq!(parity_block_len(7, 2), 7);
    }

    fn members(m: usize, seed: u64) -> Vec<Vec<u8>> {
        (0..m)
            .map(|i| {
                let len = ((seed as usize * 37 + i * 101) % 300) + (i % 2) * 113;
                (0..len)
                    .map(|k| (k as u64 * 31 + i as u64 * 7 + seed) as u8)
                    .collect()
            })
            .collect()
    }

    #[test]
    fn xor_roundtrip_every_member() {
        for m in 2..=5 {
            let data = members(m, 42);
            let views: Vec<&[u8]> = data.iter().map(|d| d.as_slice()).collect();
            let parities = xor_encode(&views);
            let pviews: Vec<&[u8]> = parities.iter().map(|p| p.as_slice()).collect();
            for x in 0..m {
                let mut survivors = views.clone();
                survivors[x] = &[];
                let got = xor_rebuild(x, &survivors, &pviews, data[x].len() as u64);
                assert_eq!(got, data[x], "m={m} x={x}");
            }
        }
    }

    #[test]
    fn xor_pair_degenerates_to_copy() {
        let a = b"hello fast tier".to_vec();
        let b = b"bye".to_vec();
        let parities = xor_encode(&[&a, &b]);
        // Member 1's parity is member 0's data (zero-padded) and vice versa.
        assert_eq!(&parities[1][..a.len()], a.as_slice());
        assert_eq!(&parities[0][..b.len()], b.as_slice());
    }

    #[test]
    fn xor_roundtrip_property() {
        crate::proptest::run("xor_roundtrip_property", 64, |g| {
            let m = g.range(2, 5) as usize;
            let data: Vec<Vec<u8>> = (0..m).map(|_| g.bytes(2048)).collect();
            let views: Vec<&[u8]> = data.iter().map(|d| d.as_slice()).collect();
            let parities = xor_encode(&views);
            let pviews: Vec<&[u8]> = parities.iter().map(|p| p.as_slice()).collect();
            let x = g.u64_below(m as u64) as usize;
            let mut survivors = views.clone();
            survivors[x] = &[];
            let got = xor_rebuild(x, &survivors, &pviews, data[x].len() as u64);
            assert_eq!(got, data[x]);
        });
    }
}
