//! INC — ablation: full vs incremental checkpointing (the paper's
//! "reducing the checkpoint overhead for large-scale applications" future
//! work, implemented and measured).
//!
//! Workload: Gromacs-analog, where the live MD state is a few KB per step
//! while the 1.5 GiB/rank heap never changes after initialization — the
//! typical production profile that makes incremental C/R pay off.

use mana::benchkit::{fsecs, Report};
use mana::config::{AppKind, RunConfig};
use mana::fs::FsKind;
use mana::sim::JobSim;
use mana::util::bytes::human;

fn series(ranks: u32, incremental: bool) -> (u64, f64, u64, f64) {
    let mut cfg = RunConfig::new(AppKind::Gromacs, ranks);
    cfg.job = format!("inc-{ranks}-{incremental}");
    cfg.fs = FsKind::Lustre; // where checkpoint cost hurts most
    cfg.incremental = incremental;
    let mut sim = JobSim::launch(cfg, None).expect("launch");
    sim.run_steps(2).expect("steps");
    let first = sim.checkpoint().expect("first ckpt");
    sim.run_steps(2).expect("steps");
    let second = sim.checkpoint().expect("second ckpt");
    (
        first.image_bytes,
        first.write_secs,
        second.image_bytes,
        second.write_secs,
    )
}

fn main() {
    let mut rep = Report::new(
        "INC: full vs incremental checkpoint (Gromacs-analog on Lustre)",
        vec![
            "ranks",
            "mode",
            "first_ckpt",
            "first_secs",
            "second_ckpt",
            "second_secs",
        ],
    );
    let mut reductions = Vec::new();
    for &ranks in &[8u32, 64] {
        let (f1, t1, f2, t2) = series(ranks, false);
        rep.row(vec![
            ranks.to_string(),
            "full".into(),
            human(f1),
            fsecs(t1),
            human(f2),
            fsecs(t2),
        ]);
        let (i1, it1, i2, it2) = series(ranks, true);
        rep.row(vec![
            ranks.to_string(),
            "incremental".into(),
            human(i1),
            fsecs(it1),
            human(i2),
            fsecs(it2),
        ]);
        reductions.push((f2 as f64 / i2 as f64, t2 / it2));
    }
    rep.finish();

    for (i, (bytes_x, time_x)) in reductions.iter().enumerate() {
        println!(
            "ranks={}: steady-state ckpt bytes reduced {bytes_x:.0}x, time reduced {time_x:.0}x",
            [8, 64][i]
        );
    }
    assert!(
        reductions.iter().all(|(b, t)| *b > 100.0 && *t > 5.0),
        "incremental mode must slash steady-state checkpoint cost"
    );
    println!("INC OK");
}
