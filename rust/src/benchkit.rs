//! Minimal benchmarking kit (criterion is unavailable offline).
//!
//! Provides wall-clock timing with warmup + repetition for the perf
//! benches, and table/JSON emission helpers shared by the per-figure
//! benches. Virtual-time results (the paper's tables) come from the
//! simulator's SimClock, not from this module.

use std::time::Instant;

use crate::util::json::Json;

/// Wall-clock measurement of a closure: warmup, then `iters` timed runs.
/// Returns (mean_secs, min_secs).
pub fn time<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> (f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut total = 0.0;
    let mut best = f64::MAX;
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_secs_f64();
        total += dt;
        if dt < best {
            best = dt;
        }
    }
    (total / iters.max(1) as f64, best)
}

/// Throughput helper: ops/sec given per-iteration op count.
pub fn throughput(ops_per_iter: u64, mean_secs: f64) -> f64 {
    ops_per_iter as f64 / mean_secs
}

/// A bench report accumulating rows, printed as a table and one JSON line
/// (the `bench:` prefix makes it greppable from `cargo bench` output).
pub struct Report {
    name: &'static str,
    columns: Vec<&'static str>,
    rows: Vec<Vec<String>>,
    json_rows: Vec<Json>,
}

impl Report {
    pub fn new(name: &'static str, columns: Vec<&'static str>) -> Self {
        Report {
            name,
            columns,
            rows: Vec::new(),
            json_rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len());
        let mut obj = Json::obj();
        for (c, v) in self.columns.iter().zip(&cells) {
            obj = obj.set(c, v.as_str());
        }
        self.json_rows.push(obj);
        self.rows.push(cells);
    }

    /// Like [`Self::finish`], but also return the table as a JSON object
    /// (`{name, rows}`) for benches that aggregate their tables into a
    /// `BENCH_*.json` artifact the CI bench-report job consumes.
    pub fn finish_json(self) -> Json {
        let name = self.name;
        let mut arr = Json::Arr(vec![]);
        for j in &self.json_rows {
            arr.push(j.clone());
        }
        self.finish();
        Json::obj().set("name", name).set("rows", arr)
    }

    /// Print the table + machine-readable trailer.
    pub fn finish(self) {
        println!("\n== {} ==", self.name);
        let widths: Vec<usize> = self
            .columns
            .iter()
            .enumerate()
            .map(|(i, c)| {
                self.rows
                    .iter()
                    .map(|r| r[i].len())
                    .chain([c.len()])
                    .max()
                    .unwrap_or(8)
            })
            .collect();
        let header: Vec<String> = self
            .columns
            .iter()
            .zip(&widths)
            .map(|(c, w)| format!("{c:>w$}"))
            .collect();
        println!("{}", header.join("  "));
        for r in &self.rows {
            let line: Vec<String> = r
                .iter()
                .zip(&widths)
                .map(|(v, w)| format!("{v:>w$}"))
                .collect();
            println!("{}", line.join("  "));
        }
        let mut arr = Json::Arr(vec![]);
        for j in self.json_rows {
            arr.push(j);
        }
        println!(
            "bench:{}",
            Json::obj()
                .set("name", self.name)
                .set("rows", arr)
                .to_string()
        );
    }
}

/// Format seconds compactly for tables.
pub fn fsecs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.0}")
    } else if s >= 1.0 {
        format!("{s:.2}")
    } else if s >= 1e-3 {
        format!("{:.3}m", s * 1e3).replace('m', "ms")
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_measures_positive() {
        let (mean, min) = time(1, 3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(mean > 0.0 && min > 0.0 && min <= mean * 1.001);
    }

    #[test]
    fn throughput_math() {
        assert!((throughput(1000, 0.5) - 2000.0).abs() < 1e-9);
    }

    #[test]
    fn fsecs_formats() {
        assert_eq!(fsecs(650.0), "650");
        assert_eq!(fsecs(30.25), "30.25");
        assert_eq!(fsecs(0.004), "4.000ms");
    }
}
