//! COLLECTIVE_DRAIN — drain-strategy scaling under a collective-heavy
//! workload.
//!
//! The colheavy app (HPCG's dot-product cadence pushed to the limit)
//! leaves a nonblocking allreduce pending across every superstep
//! boundary, so each checkpoint request lands *inside* a collective.
//! Counter drain completes the op (MANA's trivial barrier) and then pays
//! a per-rank counter reduce whose cost grows with the plane's fan-in;
//! topological-sort drain (arXiv:2408.02218) orders ranks by their round
//! cursor and ships the wave schedule down the plane as one bounded
//! object — per-hop cost, flat in the fan-in. Asserted here:
//!
//!   * **counter scaling**: counter drain virtual seconds grow with the
//!     fan-in sweep (64 → 512 ranks, flat plane);
//!   * **topo flatness**: topo drain at 512 ranks stays within 1.2x of
//!     its own 64-rank cost (the `collective_drain_topo_growth` gate);
//!   * **crossover**: at 512 ranks topo drain costs at most half of
//!     counter drain (the `collective_drain_topo_over_counter_512` gate);
//!   * **correctness**: counter and topo checkpoint/restart cycles — on
//!     the flat plane and the sub-coordinator tree — all resume to the
//!     fingerprint of the uninterrupted run.
//!
//! All times are *virtual* seconds from the deterministic cost model, so
//! the series is reproducible across machines. Results land in
//! BENCH_collective_drain.json for the CI bench-report gates.

use mana::benchkit::Report;
use mana::config::{AppKind, DrainStrategy, RunConfig};
use mana::sim::JobSim;
use mana::util::json::Json;

/// Fan-in sweep (flat plane: the root reduces one row per rank).
const FAN_IN: [u32; 4] = [64, 128, 256, 512];
/// Tiny address spaces: the series isolates drain coordination cost from
/// encode/write work.
const MEM_PER_RANK: u64 = 64 << 10;
/// Steps before the checkpoint — enough for the cadence to reach steady
/// state with an allreduce pending at the boundary.
const WARM_STEPS: u64 = 3;

fn base_cfg(tag: &str, ranks: u32, strategy: DrainStrategy) -> RunConfig {
    let mut cfg = RunConfig::new(AppKind::CollectiveHeavy, ranks);
    cfg.job = format!("coldrain-{tag}");
    cfg.mem_per_rank = Some(MEM_PER_RANK);
    cfg.drain_strategy = strategy;
    cfg
}

/// Virtual drain seconds of one checkpoint taken inside the pending
/// collective, on the flat plane.
fn drain_secs(tag: &str, ranks: u32, strategy: DrainStrategy) -> f64 {
    let mut sim =
        JobSim::launch(base_cfg(tag, ranks, strategy), None).expect("launch");
    sim.run_steps(WARM_STEPS).expect("warmup");
    let rep = sim.checkpoint().expect("checkpoint");
    assert_eq!(rep.drain_strategy, strategy);
    assert_eq!(
        rep.collectives_interrupted, 1,
        "{tag}: the checkpoint must land inside a pending collective"
    );
    if strategy == DrainStrategy::Topo {
        assert!(
            rep.topo_waves >= 2,
            "{tag}: staggered cursors must form multiple waves"
        );
    }
    rep.drain_secs
}

/// Fan-in sweep, both strategies. Returns (counter series, topo series).
fn sweep(rep: &mut Report) -> (Vec<f64>, Vec<f64>) {
    let mut counter = Vec::new();
    let mut topo = Vec::new();
    for &ranks in &FAN_IN {
        let c = drain_secs("ctr", ranks, DrainStrategy::Counter);
        let t = drain_secs("topo", ranks, DrainStrategy::Topo);
        rep.row(vec![
            format!("{ranks}"),
            format!("{:.3}", c * 1e3),
            format!("{:.3}", t * 1e3),
            format!("{:.3}x", t / c),
        ]);
        counter.push(c);
        topo.push(t);
    }
    (counter, topo)
}

/// The acceptance matrix: counter|topo x flat|tree checkpoint/restart
/// cycles must all land on the uninterrupted run's fingerprint.
fn cr_matrix(rep: &mut Report) {
    let ranks = 64u32;
    let mut cont = JobSim::launch(
        base_cfg("cr-cont", ranks, DrainStrategy::Counter),
        None,
    )
    .expect("launch");
    cont.run_steps(2 * WARM_STEPS).expect("steps");
    let want = cont.fingerprint();

    for (tag, strategy, fanout) in [
        ("cr-ctr-flat", DrainStrategy::Counter, None),
        ("cr-ctr-tree", DrainStrategy::Counter, Some(8)),
        ("cr-topo-flat", DrainStrategy::Topo, None),
        ("cr-topo-tree", DrainStrategy::Topo, Some(8)),
    ] {
        let mut cfg = base_cfg(tag, ranks, strategy);
        cfg.coord_fanout = fanout;
        let mut sim = JobSim::launch(cfg.clone(), None).expect("launch");
        sim.run_steps(WARM_STEPS).expect("steps");
        let crep = sim.checkpoint().expect("checkpoint");
        let fs = sim.kill();
        let (mut resumed, _) =
            JobSim::restart_from(cfg, None, fs).expect("restart");
        resumed.run_steps(WARM_STEPS).expect("resume steps");
        let fp = resumed.fingerprint();
        assert!(!resumed.any_corruption(), "{tag}: corruption after restart");
        assert_eq!(
            fp, want,
            "{tag}: restart fingerprint must match the uninterrupted run"
        );
        rep.row(vec![
            tag.into(),
            strategy.name().into(),
            if fanout.is_some() { "tree".into() } else { "flat".into() },
            format!("{:.3}", crep.drain_secs * 1e3),
            format!("{fp:016x}"),
        ]);
    }
}

fn main() {
    let mut sweep_rep = Report::new(
        "COLLECTIVE_DRAIN: virtual drain seconds vs fan-in (flat plane)",
        vec!["ranks", "counter_ms", "topo_ms", "topo/counter"],
    );
    let (counter, topo) = sweep(&mut sweep_rep);
    let sweep_table = sweep_rep.finish_json();

    let mut cr_rep = Report::new(
        "COLLECTIVE_DRAIN: C/R fingerprint matrix (strategy x plane)",
        vec!["job", "strategy", "plane", "drain_ms", "fingerprint"],
    );
    cr_matrix(&mut cr_rep);
    let cr_table = cr_rep.finish_json();

    let n = FAN_IN.len();
    let counter_growth = counter[n - 1] / counter[0];
    let topo_growth = topo[n - 1] / topo[0];
    let topo_over_counter_512 = topo[n - 1] / counter[n - 1];

    assert!(
        counter_growth > 2.0,
        "counter drain grew only {counter_growth:.2}x from 64 to 512 ranks; \
         the fan-in sweep no longer discriminates"
    );
    assert!(
        topo_growth <= 1.2,
        "topo drain grew {topo_growth:.2}x across the fan-in sweep; the wave \
         schedule must stay flat"
    );
    assert!(
        topo_over_counter_512 <= 0.5,
        "topo drain is {topo_over_counter_512:.3}x of counter at 512 ranks; \
         it must cost at most half"
    );

    let out = Json::obj()
        .set("bench", "collective_drain")
        .set(
            "gates",
            Json::obj()
                .set("collective_drain_topo_over_counter_512", topo_over_counter_512)
                .set("collective_drain_topo_growth", topo_growth),
        )
        .set("counter_growth_64_to_512", counter_growth)
        .set("series", Json::Arr(vec![sweep_table, cr_table]));
    std::fs::write("BENCH_collective_drain.json", out.to_string())
        .expect("write BENCH_collective_drain.json");
    println!(
        "COLLECTIVE_DRAIN OK: counter drain grew {counter_growth:.2}x over the \
         64->512 fan-in sweep, topo {topo_growth:.2}x; topo costs \
         {topo_over_counter_512:.3}x of counter at 512 ranks (results in \
         BENCH_collective_drain.json)"
    );
}
