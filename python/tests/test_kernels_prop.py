"""Hypothesis sweeps over kernel shapes/dtypes vs the pure-jnp oracles."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.lj_forces import lj_forces
from compile.kernels.stencil27 import stencil27
from compile.kernels.rpa_block import rpa_block

_SETTINGS = dict(max_examples=25, deadline=None)


@settings(**_SETTINGS)
@given(n=st.integers(1, 96), seed=st.integers(0, 2**31 - 1),
       box=st.floats(6.0, 20.0))
def test_lj_shape_sweep(n, seed, box):
    rng = np.random.default_rng(seed)
    pos = jnp.asarray(rng.uniform(0, box, (n, 3)), jnp.float32)
    got = lj_forces(pos, box=box, tile=32)
    want = ref.lj_forces_ref(pos, box, 1.0, 1.0, 2.5)
    # Forces diverge as r -> 0; random placements can land arbitrarily close,
    # so compare with a magnitude-relative tolerance.
    scale = max(1.0, float(np.abs(np.asarray(want)).max()))
    np.testing.assert_allclose(np.asarray(got) / scale,
                               np.asarray(want) / scale, atol=5e-4)


@settings(**_SETTINGS)
@given(nx=st.integers(1, 12), ny=st.integers(1, 12), nz=st.integers(1, 12),
       seed=st.integers(0, 2**31 - 1))
def test_stencil_shape_sweep(nx, ny, nz, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(nx, ny, nz)), jnp.float32)
    np.testing.assert_allclose(stencil27(x, slab=4), ref.stencil27_ref(x),
                               rtol=1e-5, atol=1e-5)


@settings(**_SETTINGS)
@given(m=st.integers(1, 160), n=st.integers(1, 160), k=st.integers(1, 200),
       scale=st.floats(-3.0, 3.0), seed=st.integers(0, 2**31 - 1))
def test_rpa_shape_sweep(m, n, k, scale, seed):
    rng = np.random.default_rng(seed)
    occ = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    virt = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    got = rpa_block(occ, virt, scale=scale, bm=64, bn=64, bk=64)
    want = ref.rpa_block_ref(occ, virt, scale)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_rpa_dtype_sweep_bf16(seed):
    """bf16 inputs with f32 accumulation — the MXU-native mode."""
    rng = np.random.default_rng(seed)
    occ = jnp.asarray(rng.normal(size=(64, 64)), jnp.bfloat16)
    virt = jnp.asarray(rng.normal(size=(64, 64)), jnp.bfloat16)
    got = rpa_block(occ, virt, scale=1.0, bm=64, bn=64, bk=64)
    want = ref.rpa_block_ref(occ, virt, 1.0)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-2, atol=3e-1)
