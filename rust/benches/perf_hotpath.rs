//! PERF — wall-clock profile of the L3 hot paths.
//!
//! Criterion is unavailable offline; this hand-rolled harness measures the
//! paths that dominate real runs:
//!   * checkpoint image encode/decode (CRC + serialization) throughput
//!   * MPI simulator message path (isend + recv) ops/s
//!   * full superstep rate (synthetic compute)
//!   * end-to-end checkpoint protocol latency at several rank counts
//!   * PJRT artifact execution latency (if artifacts are built)
//!
//! Results are recorded in EXPERIMENTS.md §Perf with the iteration log.
//! Reported numbers are best-of-N (min), which is stable under the
//! shared-container noise that dominates mean timings here.

use mana::benchkit::{fsecs, throughput, time, Report};
use mana::ckpt::CkptImage;
use mana::config::{AppKind, ComputeMode, RunConfig};
use mana::mem::Payload;
use mana::mpi::MpiWorld;
use mana::simnet::fabric::Fabric;
use mana::sim::JobSim;
use mana::splitproc::{SplitConfig, SplitProcess};
use mana::topology::RankId;
use mana::util::crc32;
use mana::util::digest::digest128;
use mana::util::simclock::SimTime;

fn bench_image_codec(rep: &mut Report) {
    // A realistic image: 4 MiB of real payload + big virtual regions.
    let mut proc = SplitProcess::launch(RankId(0), SplitConfig::default(), 1).unwrap();
    proc.map_app_region("state", 4 << 20, Payload::Real(vec![0xAB; 4 << 20]))
        .unwrap();
    proc.map_app_region("heap", 8 << 30, Payload::Pattern(7)).unwrap();
    let img = proc.checkpoint();
    let encoded = img.encode();
    let real_bytes = encoded.len() as u64;

    let (_, enc_mean) = time(3, 50, || {
        std::hint::black_box(img.encode());
    });
    let (_, dec_mean) = time(3, 50, || {
        std::hint::black_box(CkptImage::decode(&encoded).unwrap());
    });
    rep.row(vec![
        "image encode (4MiB real)".into(),
        fsecs(enc_mean),
        format!("{:.2} GiB/s", real_bytes as f64 / enc_mean / (1u64 << 30) as f64),
    ]);
    rep.row(vec![
        "image decode+CRC (4MiB real)".into(),
        fsecs(dec_mean),
        format!("{:.2} GiB/s", real_bytes as f64 / dec_mean / (1u64 << 30) as f64),
    ]);
}

/// Before/after throughput of the CRC32 hot path: the slice-by-8 table
/// walk (the image codec's integrity framing) against the byte-at-a-time
/// reference it replaced. Digests are bitwise identical (asserted here and
/// unit-tested in `util::crc32`); only the speed differs. Also profiles
/// the 128-bit content digest the dedup-aware drain computes per chunk.
fn bench_hashes(rep: &mut Report) {
    let data: Vec<u8> = (0..(16u32 << 20))
        .map(|i| (i.wrapping_mul(2_654_435_761) >> 11) as u8)
        .collect();
    let gib = data.len() as f64 / (1u64 << 30) as f64;
    assert_eq!(
        crc32::hash(&data),
        crc32::hash_bytewise(&data),
        "slice-by-8 must stay bitwise identical to the reference"
    );
    let (_, fast) = time(2, 10, || {
        std::hint::black_box(crc32::hash(&data));
    });
    let (_, slow) = time(2, 10, || {
        std::hint::black_box(crc32::hash_bytewise(&data));
    });
    let (_, dig) = time(2, 10, || {
        std::hint::black_box(digest128(&data));
    });
    rep.row(vec![
        "crc32 slice-by-8 (16 MiB)".into(),
        fsecs(fast),
        format!("{:.2} GiB/s ({:.1}x vs bytewise)", gib / fast, slow / fast),
    ]);
    rep.row(vec![
        "crc32 bytewise reference (16 MiB)".into(),
        fsecs(slow),
        format!("{:.2} GiB/s", gib / slow),
    ]);
    rep.row(vec![
        "digest128 content hash (16 MiB)".into(),
        fsecs(dig),
        format!("{:.2} GiB/s", gib / dig),
    ]);
}

fn bench_mpi_path(rep: &mut Report) {
    let msgs_per_iter = 10_000u64;
    let (_, mean) = time(2, 10, || {
        let mut w = MpiWorld::new(16, Fabric::default());
        let mut t = SimTime::ZERO;
        for i in 0..msgs_per_iter {
            let src = RankId((i % 16) as u32);
            let dst = RankId(((i + 1) % 16) as u32);
            w.isend(src, dst, i as u32, 4096, vec![0u8; 64], t);
            std::hint::black_box(w.recv_blocking(dst, Some(src), Some(i as u32), &mut t));
        }
    });
    rep.row(vec![
        "mpi send+recv pair".into(),
        fsecs(mean / msgs_per_iter as f64),
        format!("{:.2} Mmsg/s", throughput(msgs_per_iter, mean) / 1e6),
    ]);
}

fn bench_superstep(rep: &mut Report) {
    let mut cfg = RunConfig::new(AppKind::Synthetic, 64);
    cfg.mem_per_rank = Some(1 << 20);
    let mut sim = JobSim::launch(cfg, None).unwrap();
    let (_, mean) = time(2, 20, || {
        sim.run_steps(1).unwrap();
    });
    rep.row(vec![
        "superstep, 64 ranks synthetic".into(),
        fsecs(mean),
        format!("{:.0} rank-steps/s", 64.0 / mean),
    ]);
}

fn bench_ckpt_protocol(rep: &mut Report) {
    for &ranks in &[64u32, 512] {
        let mut cfg = RunConfig::new(AppKind::Synthetic, ranks);
        cfg.mem_per_rank = Some(1 << 20);
        cfg.job = format!("perf-{ranks}");
        let mut sim = JobSim::launch(cfg, None).unwrap();
        sim.run_steps(2).unwrap();
        let (_, mean) = time(1, 10, || {
            std::hint::black_box(sim.checkpoint().unwrap());
        });
        rep.row(vec![
            format!("checkpoint protocol, {ranks} ranks"),
            fsecs(mean),
            format!("{:.1} ranks/ms", ranks as f64 / (mean * 1e3)),
        ]);
    }
}

fn bench_pjrt(rep: &mut Report) {
    use mana::runtime::{default_artifact_dir, Engine};
    let Ok(engine) = Engine::load(&default_artifact_dir()) else {
        rep.row(vec![
            "pjrt md_step (no artifacts)".into(),
            "skipped".into(),
            "-".into(),
        ]);
        return;
    };
    let mut cfg = RunConfig::new(AppKind::Gromacs, 1);
    cfg.compute = ComputeMode::Real;
    cfg.mem_per_rank = Some(1 << 20);
    let engine = std::sync::Arc::new(engine);
    let mut sim = JobSim::launch(cfg, Some(engine)).unwrap();
    let (_, mean) = time(3, 20, || {
        sim.run_steps(1).unwrap();
    });
    rep.row(vec![
        "pjrt md_step (256 atoms, 4 inner)".into(),
        fsecs(mean),
        format!("{:.0} steps/s", 1.0 / mean),
    ]);
}

fn main() {
    let mut rep = Report::new(
        "PERF: L3 hot-path wall-clock profile",
        vec!["path", "latency", "throughput"],
    );
    bench_image_codec(&mut rep);
    bench_hashes(&mut rep);
    bench_mpi_path(&mut rep);
    bench_superstep(&mut rep);
    bench_ckpt_protocol(&mut rep);
    bench_pjrt(&mut rep);
    rep.finish();
    println!("PERF OK");
}
