"""AOT compile path: lower every L2 graph to HLO text + write the manifest.

HLO **text** is the interchange format, NOT ``.serialize()``: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which the xla crate's
bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``). The text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Outputs (``make artifacts``):
  artifacts/<name>.hlo.txt   — one per registry entry in model.py
  artifacts/manifest.txt     — line-based I/O description parsed by
                               rust/src/runtime/manifest.rs:

      artifact <name> <file>
      in <argname> <dtype> <d0>x<d1>...
      out <idx> <dtype> <d0>x<d1>...

Usage: (cd python && python -m compile.aot --out-dir ../artifacts)
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from compile.model import registry


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _dims(shape) -> str:
    return "x".join(str(d) for d in shape) if shape else "scalar"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact names (default: all)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    names = set(args.only.split(",")) if args.only else None
    manifest_lines = []
    for name, (fn, in_specs) in registry().items():
        if names and name not in names:
            continue
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        manifest_lines.append(f"artifact {name} {fname}")
        argnames = fn.__code__.co_varnames[: len(in_specs)]
        for argname, spec in zip(argnames, in_specs):
            manifest_lines.append(
                f"in {argname} {spec.dtype} {_dims(spec.shape)}")
        outs = jax.eval_shape(fn, *in_specs)
        if not isinstance(outs, tuple):
            outs = (outs,)
        for idx, o in enumerate(outs):
            manifest_lines.append(f"out {idx} {o.dtype} {_dims(o.shape)}")
        print(f"lowered {name}: {len(text)} chars -> {fname}")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote manifest with {len(manifest_lines)} lines")


if __name__ == "__main__":
    main()
