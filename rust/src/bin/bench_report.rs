//! `bench_report` — the CI bench-regression harness over the
//! `BENCH_*.json` trajectory.
//!
//! Every perf-bearing bench (`staged_drain`, `coord_scale`,
//! `ckpt_datapath`, and any future series) writes a `BENCH_<name>.json`
//! artifact with a shared shape:
//!
//! ```json
//! {
//!   "bench": "<name>",                 // required
//!   "gates": {"<gate>": <number>},     // required (may be empty)
//!   "rows": [{...}],                   // optional: the headline table
//!   "series": [{"name": "...", "rows": [{...}]}]  // optional extras
//! }
//! ```
//!
//! This binary collects every artifact in a directory, schema-validates
//! them, renders one comparison table into `$GITHUB_STEP_SUMMARY` (and
//! stdout), writes the aggregated `BENCH_report.json`, and exits non-zero
//! when a gate named by the checked-in baseline file
//! (`bench_baselines.json`) is missing or regresses past its threshold —
//! so a perf or dedup win can't silently rot once merged.
//!
//! Usage: `bench_report [--dir DIR] [--baselines FILE] [--out FILE]`

use std::fmt::Write as _;
use std::fs;
use std::path::PathBuf;
use std::process::ExitCode;

use mana::util::json::Json;

/// One gate value harvested from an artifact.
struct Gate {
    name: String,
    value: f64,
    source: String,
}

/// One collected artifact (post-validation).
struct Bench {
    file: String,
    name: String,
    rows: Vec<Json>,
    series: Vec<(String, Vec<Json>)>,
}

/// A baseline threshold: `value <op> bound` must hold.
struct Baseline {
    name: String,
    op: String,
    bound: f64,
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut dir = ".".to_string();
    let mut baselines_path = "bench_baselines.json".to_string();
    let mut out_path = "BENCH_report.json".to_string();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--dir" if i + 1 < args.len() => {
                i += 1;
                dir = args[i].clone();
            }
            "--baselines" if i + 1 < args.len() => {
                i += 1;
                baselines_path = args[i].clone();
            }
            "--out" if i + 1 < args.len() => {
                i += 1;
                out_path = args[i].clone();
            }
            other => {
                eprintln!("bench_report: unknown argument {other}");
                eprintln!("usage: bench_report [--dir DIR] [--baselines FILE] [--out FILE]");
                return ExitCode::FAILURE;
            }
        }
        i += 1;
    }

    let mut errors: Vec<String> = Vec::new();
    let (benches, gates) = collect(&dir, &out_path, &mut errors);
    let baselines = load_baselines(&baselines_path, &mut errors);

    // Evaluate every required gate against its checked-in threshold.
    // Rows are (gate, value, baseline, pass).
    let mut gate_rows: Vec<(String, String, String, bool)> = Vec::new();
    let mut failed = false;
    for b in &baselines {
        let expr = format!("{} {}", b.op, fnum(b.bound));
        match gates.iter().find(|g| g.name == b.name) {
            None => {
                failed = true;
                errors.push(format!(
                    "required gate `{}` missing from every BENCH_*.json",
                    b.name
                ));
                gate_rows.push((b.name.clone(), "missing".into(), expr, false));
            }
            Some(g) => {
                let pass = cmp(g.value, &b.op, b.bound);
                if !pass {
                    failed = true;
                }
                gate_rows.push((b.name.clone(), fnum(g.value), expr, pass));
            }
        }
    }
    // Informational gates (present but not gated by a baseline).
    for g in &gates {
        if !baselines.iter().any(|b| b.name == g.name) {
            gate_rows.push((g.name.clone(), fnum(g.value), "(info)".into(), true));
        }
    }
    if !errors.is_empty() {
        failed = true;
    }

    let summary = render_summary(&benches, &gate_rows, &errors, failed);
    print!("{summary}");
    if let Ok(path) = std::env::var("GITHUB_STEP_SUMMARY") {
        if !path.is_empty() {
            use std::io::Write as _;
            if let Ok(mut f) = fs::OpenOptions::new().create(true).append(true).open(&path) {
                let _ = f.write_all(summary.as_bytes());
            }
        }
    }

    // Aggregated artifact: every gate, every bench, the verdict.
    let mut jgates = Json::Arr(vec![]);
    for g in &gates {
        let baseline = baselines.iter().find(|b| b.name == g.name);
        let pass = match baseline {
            Some(b) => cmp(g.value, &b.op, b.bound),
            None => true,
        };
        jgates.push(
            Json::obj()
                .set("name", g.name.as_str())
                .set("value", g.value)
                .set("source", g.source.as_str())
                .set("required", baseline.is_some())
                .set("pass", pass),
        );
    }
    let mut jbenches = Json::Arr(vec![]);
    for b in &benches {
        let mut series = Json::Arr(vec![]);
        for (name, rows) in &b.series {
            series.push(
                Json::obj()
                    .set("name", name.as_str())
                    .set("rows", Json::Arr(rows.clone())),
            );
        }
        jbenches.push(
            Json::obj()
                .set("file", b.file.as_str())
                .set("bench", b.name.as_str())
                .set("rows", Json::Arr(b.rows.clone()))
                .set("series", series),
        );
    }
    let mut jerrors = Json::Arr(vec![]);
    for e in &errors {
        jerrors.push(e.as_str());
    }
    let report = Json::obj()
        .set("schema", "mana-bench-report/v1")
        .set("pass", !failed)
        .set("gates", jgates)
        .set("benches", jbenches)
        .set("errors", jerrors);
    if let Err(e) = fs::write(&out_path, report.to_string()) {
        eprintln!("bench_report: cannot write {out_path}: {e}");
        return ExitCode::FAILURE;
    }

    if failed {
        eprintln!("bench_report: FAILED (see report above)");
        ExitCode::FAILURE
    } else {
        println!("bench_report: all gates within baseline thresholds");
        ExitCode::SUCCESS
    }
}

/// Collect and schema-validate every `BENCH_*.json` under `dir`.
fn collect(dir: &str, out_path: &str, errors: &mut Vec<String>) -> (Vec<Bench>, Vec<Gate>) {
    let out_name = PathBuf::from(out_path)
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_default();
    let mut files: Vec<String> = match fs::read_dir(dir) {
        Ok(entries) => entries
            .filter_map(|e| e.ok())
            .filter_map(|e| e.file_name().into_string().ok())
            .filter(|n| n.starts_with("BENCH_") && n.ends_with(".json") && *n != out_name)
            .collect(),
        Err(e) => {
            errors.push(format!("cannot read directory {dir}: {e}"));
            Vec::new()
        }
    };
    files.sort();
    if files.is_empty() {
        errors.push(format!("no BENCH_*.json artifacts found in {dir}"));
    }

    let mut benches = Vec::new();
    let mut gates: Vec<Gate> = Vec::new();
    for name in files {
        let path = format!("{dir}/{name}");
        let text = match fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) => {
                errors.push(format!("{name}: unreadable: {e}"));
                continue;
            }
        };
        let Some(doc) = Json::parse(&text) else {
            errors.push(format!("{name}: not valid JSON"));
            continue;
        };
        // Schema: {"bench": str, "gates": {str: num}, rows?: [obj], series?}.
        let Some(bench_name) = doc.get("bench").and_then(Json::as_str) else {
            errors.push(format!("{name}: missing required string field `bench`"));
            continue;
        };
        let Some(gate_fields) = doc.get("gates").and_then(Json::as_obj) else {
            errors.push(format!("{name}: missing required object field `gates`"));
            continue;
        };
        for (gname, gval) in gate_fields {
            let Some(v) = gval.as_f64().filter(|v| v.is_finite()) else {
                errors.push(format!("{name}: gate `{gname}` is not a finite number"));
                continue;
            };
            if let Some(prev) = gates.iter().find(|g| g.name == *gname) {
                errors.push(format!(
                    "{name}: gate `{gname}` already defined by {}",
                    prev.source
                ));
                continue;
            }
            gates.push(Gate {
                name: gname.clone(),
                value: v,
                source: name.clone(),
            });
        }
        let rows = match doc.get("rows") {
            None => Vec::new(),
            Some(r) => match validate_rows(r) {
                Some(rows) => rows,
                None => {
                    errors.push(format!("{name}: `rows` must be an array of objects"));
                    continue;
                }
            },
        };
        let mut series = Vec::new();
        if let Some(s) = doc.get("series") {
            let Some(items) = s.as_arr() else {
                errors.push(format!("{name}: `series` must be an array"));
                continue;
            };
            let mut ok = true;
            for item in items {
                match (
                    item.get("name").and_then(Json::as_str),
                    item.get("rows").and_then(validate_rows),
                ) {
                    (Some(sname), Some(srows)) => series.push((sname.to_string(), srows)),
                    _ => {
                        errors.push(format!(
                            "{name}: each series entry needs a `name` and object `rows`"
                        ));
                        ok = false;
                        break;
                    }
                }
            }
            if !ok {
                continue;
            }
        }
        benches.push(Bench {
            file: name,
            name: bench_name.to_string(),
            rows,
            series,
        });
    }
    (benches, gates)
}

fn validate_rows(r: &Json) -> Option<Vec<Json>> {
    let items = r.as_arr()?;
    if items.iter().all(|i| i.as_obj().is_some()) {
        Some(items.to_vec())
    } else {
        None
    }
}

fn load_baselines(path: &str, errors: &mut Vec<String>) -> Vec<Baseline> {
    let text = match fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            errors.push(format!("baselines {path}: unreadable: {e}"));
            return Vec::new();
        }
    };
    let Some(doc) = Json::parse(&text) else {
        errors.push(format!("baselines {path}: not valid JSON"));
        return Vec::new();
    };
    let Some(required) = doc.get("required").and_then(Json::as_obj) else {
        errors.push(format!("baselines {path}: missing `required` object"));
        return Vec::new();
    };
    let mut out = Vec::new();
    for (name, spec) in required {
        let op = spec.get("op").and_then(Json::as_str).unwrap_or_default();
        let bound = spec.get("bound").and_then(Json::as_f64);
        match (op, bound) {
            ("<" | "<=" | ">" | ">=", Some(bound)) => out.push(Baseline {
                name: name.clone(),
                op: op.to_string(),
                bound,
            }),
            _ => errors.push(format!(
                "baselines {path}: `{name}` needs op in <,<=,>,>= and a numeric bound"
            )),
        }
    }
    out
}

fn cmp(value: f64, op: &str, bound: f64) -> bool {
    match op {
        "<" => value < bound,
        "<=" => value <= bound,
        ">" => value > bound,
        ">=" => value >= bound,
        _ => false,
    }
}

/// Compact numeric formatting for tables.
fn fnum(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e12 {
        format!("{}", v as i64)
    } else if v.abs() >= 100.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.4}")
    }
}

/// Markdown cell rendering of one JSON value.
fn cell(v: &Json) -> String {
    match v {
        Json::Str(s) => s.replace('|', "\\|"),
        Json::Num(n) => fnum(*n),
        Json::Bool(b) => b.to_string(),
        Json::Null => "-".into(),
        other => other.to_string().replace('|', "\\|"),
    }
}

/// Render one rows-table as GitHub markdown (first row defines columns).
fn render_table(out: &mut String, rows: &[Json]) {
    const MAX_ROWS: usize = 24;
    let Some(first) = rows.first().and_then(Json::as_obj) else {
        return;
    };
    let cols: Vec<&str> = first.iter().map(|(k, _)| k.as_str()).collect();
    let _ = writeln!(out, "| {} |", cols.join(" | "));
    let _ = writeln!(out, "|{}|", cols.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
    for row in rows.iter().take(MAX_ROWS) {
        let cells: Vec<String> = cols
            .iter()
            .map(|c| row.get(c).map(cell).unwrap_or_else(|| "-".into()))
            .collect();
        let _ = writeln!(out, "| {} |", cells.join(" | "));
    }
    if rows.len() > MAX_ROWS {
        let _ = writeln!(out, "\n_... {} more rows in the artifact_", rows.len() - MAX_ROWS);
    }
}

fn render_summary(
    benches: &[Bench],
    gate_rows: &[(String, String, String, bool)],
    errors: &[String],
    failed: bool,
) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "## Bench regression report\n");
    let _ = writeln!(
        out,
        "**Verdict: {}** ({} artifacts, {} gates)\n",
        if failed { "FAIL ❌" } else { "PASS ✅" },
        benches.len(),
        gate_rows.len()
    );
    if !gate_rows.is_empty() {
        let _ = writeln!(out, "| gate | value | baseline | status |");
        let _ = writeln!(out, "|---|---|---|---|");
        for (name, value, baseline, pass) in gate_rows {
            let _ = writeln!(
                out,
                "| {name} | {value} | {baseline} | {} |",
                if *pass { "✅" } else { "❌" }
            );
        }
        let _ = writeln!(out);
    }
    for e in errors {
        let _ = writeln!(out, "- ⚠️ {e}");
    }
    if !errors.is_empty() {
        let _ = writeln!(out);
    }
    for b in benches {
        let _ = writeln!(out, "### {} (`{}`)\n", b.name, b.file);
        if !b.rows.is_empty() {
            render_table(&mut out, &b.rows);
            let _ = writeln!(out);
        }
        for (name, rows) in &b.series {
            let _ = writeln!(out, "<details><summary>{name}</summary>\n");
            render_table(&mut out, rows);
            let _ = writeln!(out, "\n</details>\n");
        }
    }
    out
}
