//! Critical-path extraction over the span DAG.
//!
//! The checkpoint's bench gate ("stall ≤ 1.15 × max(encode, write)") says
//! *whether* the pipeline is healthy; this module says *why not* when it
//! isn't. Starting from the terminal span of a generation (RESUME), walk
//! the dependency edges backwards, always following the predecessor that
//! finished last — the one that actually gated progress — and charge each
//! hop the wall-clock between its predecessor's finish and its own. The
//! charges telescope: they sum to exactly the generation's `total_secs`,
//! so the output is a complete attribution of the checkpoint stall, not a
//! sample of it.

use super::{Span, SpanId};

/// One hop of the critical path, in timeline order.
#[derive(Clone, Debug)]
pub struct PathEntry {
    /// Span name, with a repeat marker when consecutive same-name hops
    /// collapse (e.g. the write-queue admission chain: `write.q ×512`).
    pub span: String,
    /// How many raw hops collapsed into this entry.
    pub count: usize,
    /// Virtual seconds this entry gated the checkpoint.
    pub secs: f64,
    /// Share of the generation total, 0..=100.
    pub pct: f64,
}

/// Walk generation `gen`'s DAG backwards from its terminal span and return
/// the gating chain in timeline order. Empty when the generation recorded
/// no spans (tracing off).
pub fn critical_path(spans: &[Span], gen: u64) -> Vec<PathEntry> {
    // Terminal: the RESUME exchange ends the checkpoint; fall back to the
    // latest-finishing non-root span if a partial trace has no resume.
    let terminal = spans
        .iter()
        .enumerate()
        .filter(|(_, s)| s.gen == Some(gen))
        .filter(|(_, s)| s.name == "resume")
        .max_by(|a, b| a.1.t1.total_cmp(&b.1.t1))
        .or_else(|| {
            spans
                .iter()
                .enumerate()
                .filter(|(_, s)| s.gen == Some(gen) && s.name != "ckpt")
                .max_by(|a, b| a.1.t1.total_cmp(&b.1.t1))
        });
    let Some((mut cur, _)) = terminal else {
        return Vec::new();
    };

    // anchor = the instant the current hop delivered; each hop is charged
    // anchor − pred.t1 (the time only it could have been running).
    let mut anchor = spans[cur].t1;
    let mut raw: Vec<(String, f64)> = Vec::new();
    let mut hops = 0usize;
    loop {
        hops += 1;
        if hops > spans.len() + 1 {
            break; // cycle guard: malformed hand-built DAGs terminate.
        }
        let s = &spans[cur];
        let pred = s
            .deps
            .iter()
            .filter_map(|&SpanId(d)| {
                let d = d as usize;
                spans.get(d).map(|p| (d, p))
            })
            .max_by(|a, b| a.1.t1.total_cmp(&b.1.t1));
        match pred {
            Some((p, ps)) => {
                raw.push((label(s), (anchor - ps.t1).max(0.0)));
                anchor = ps.t1.min(anchor);
                cur = p;
            }
            None => {
                raw.push((label(s), (anchor - s.t0).max(0.0)));
                break;
            }
        }
    }
    raw.reverse();

    // Collapse consecutive same-name hops (per-rank encode ladders and the
    // write-queue admission chain would otherwise dominate the listing).
    let mut merged: Vec<(String, usize, f64)> = Vec::new();
    for (name, secs) in raw {
        match merged.last_mut() {
            Some((n, c, s)) if *n == name => {
                *c += 1;
                *s += secs;
            }
            _ => merged.push((name, 1, secs)),
        }
    }
    let total: f64 = merged.iter().map(|(_, _, s)| s).sum();
    merged
        .into_iter()
        .map(|(name, count, secs)| PathEntry {
            span: if count > 1 {
                format!("{name} ×{count}")
            } else {
                name
            },
            count,
            secs,
            pct: if total > 0.0 { 100.0 * secs / total } else { 0.0 },
        })
        .collect()
}

fn label(s: &Span) -> String {
    s.name.to_string()
}

/// The top-k entries by charge, rendered one-line for bench annotations:
/// `"write.wave 62.1% · encode ×512 21.4% · drain.msgs 9.0%"`.
pub fn top_k_summary(path: &[PathEntry], k: usize) -> String {
    let mut by_charge: Vec<&PathEntry> = path.iter().collect();
    by_charge.sort_by(|a, b| b.secs.total_cmp(&a.secs));
    by_charge
        .iter()
        .take(k)
        .map(|e| format!("{} {:.1}%", e.span, e.pct))
        .collect::<Vec<_>>()
        .join(" · ")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Lane, Span};

    /// Hand-built DAG with a known answer:
    ///
    /// ```text
    ///   a: [0,2] ──┬── c: [3,10]  (dep a, b — b finishes last)
    ///   b: [1,5] ──┘        │
    ///                 d: [10,11]  (dep c)   terminal (named resume)
    /// ```
    ///
    /// Walk: d charged 11−10 = 1, c charged 10−5 = 5 (gated by b), b
    /// charged 5−1 = 4 (no deps → its own duration). a never appears —
    /// it was off the gating chain. Total = 10 = d.t1 − b.t0.
    #[test]
    fn known_dag_attributes_correctly() {
        let a = Span::new("a", Lane::Ctrl, 0.0, 2.0).gen(0);
        let b = Span::new("b", Lane::Phase, 1.0, 5.0).gen(0);
        let c = Span::new("c", Lane::Storage, 3.0, 10.0)
            .gen(0)
            .dep(SpanId(0))
            .dep(SpanId(1));
        let d = Span::new("resume", Lane::Ctrl, 10.0, 11.0).gen(0).dep(SpanId(2));
        let spans = vec![a, b, c, d];
        let path = critical_path(&spans, 0);
        let names: Vec<&str> = path.iter().map(|e| e.span.as_str()).collect();
        assert_eq!(names, vec!["b", "c", "resume"]);
        let secs: Vec<f64> = path.iter().map(|e| e.secs).collect();
        assert!((secs[0] - 4.0).abs() < 1e-12, "{secs:?}");
        assert!((secs[1] - 5.0).abs() < 1e-12, "{secs:?}");
        assert!((secs[2] - 1.0).abs() < 1e-12, "{secs:?}");
        let total: f64 = secs.iter().sum();
        assert!((total - 10.0).abs() < 1e-12);
        let pct: f64 = path.iter().map(|e| e.pct).sum();
        assert!((pct - 100.0).abs() < 1e-9);
    }

    #[test]
    fn consecutive_same_name_hops_collapse() {
        // q0 → q1 → q2 chain feeding resume.
        let q0 = Span::new("write.q", Lane::WriteQueue, 0.0, 1.0).gen(0);
        let q1 = Span::new("write.q", Lane::WriteQueue, 1.0, 2.0).gen(0).dep(SpanId(0));
        let q2 = Span::new("write.q", Lane::WriteQueue, 2.0, 3.0).gen(0).dep(SpanId(1));
        let r = Span::new("resume", Lane::Ctrl, 3.0, 4.0).gen(0).dep(SpanId(2));
        let path = critical_path(&[q0, q1, q2, r], 0);
        assert_eq!(path.len(), 2, "{path:?}");
        assert_eq!(path[0].span, "write.q ×3");
        assert_eq!(path[0].count, 3);
        assert!((path[0].secs - 3.0).abs() < 1e-12);
        let s = top_k_summary(&path, 3);
        assert!(s.contains("write.q ×3 75.0%"), "{s}");
    }

    #[test]
    fn empty_for_untraced_generation() {
        assert!(critical_path(&[], 7).is_empty());
    }
}
