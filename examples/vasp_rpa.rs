//! The VASP/RPA production story: jobs longer than the 48 h walltime.
//!
//! "The RPA jobs can run for much longer than 48 hours, the max walltime
//! allowed on Cori. In the past we had to make special reservations for
//! these jobs, now they can run on Cori by checkpointing/restarting with
//! MANA."
//!
//! This example runs a 120-hour RPA quadrature (120 points x 1 virtual
//! hour) as three Cori jobs chained by MANA C/R, each within the 48 h
//! walltime, with real PJRT compute (the Pallas MXU-tiled chi0 matmul),
//! and verifies the chained result equals one uninterrupted run.
//!
//! Run: cargo run --release --example vasp_rpa

use std::sync::Arc;

use anyhow::Result;

use mana::apps::vasp_rpa::VaspRpa;
use mana::config::{AppKind, ComputeMode, RunConfig};
use mana::runtime::{default_artifact_dir, Engine};
use mana::sim::JobSim;

const WALLTIME_SECS: f64 = 48.0 * 3600.0;
const TOTAL_POINTS: u64 = 120; // 120 virtual hours of quadrature

fn main() -> Result<()> {
    println!("=== VASP RPA beyond the 48h walltime, via MANA C/R ===\n");
    let engine = Arc::new(Engine::load(&default_artifact_dir())?);

    let mut cfg = RunConfig::new(AppKind::VaspRpa, 4);
    cfg.job = "vasp-rpa-prod".into();
    cfg.compute = ComputeMode::Real;
    cfg.mem_per_rank = Some(16 << 20);

    // Reference: one uninterrupted (reservation-style) run.
    let mut reference = JobSim::launch(cfg.clone(), Some(engine.clone()))?;
    reference.run_steps(TOTAL_POINTS)?;
    let want = reference.fingerprint();

    // Production: chained 48h jobs.
    let mut done = 0u64;
    let mut window = 0u32;
    let mut sim = JobSim::launch(cfg.clone(), Some(engine.clone()))?;
    let mut fs_carry = None;
    while done < TOTAL_POINTS {
        window += 1;
        if let Some(fs) = fs_carry.take() {
            let (resumed, rrep) = JobSim::restart_from(cfg.clone(), Some(engine.clone()), fs)
                .map_err(|e| anyhow::anyhow!("restart: {e}"))?;
            sim = resumed;
            println!(
                "  job {window}: restarted at quadrature point {} ({:.1}s restart)",
                sim.step, rrep.total_secs
            );
        }
        // Run until the walltime would be exceeded.
        let t0 = sim.now().as_secs();
        while done < TOTAL_POINTS && sim.now().as_secs() - t0 + 3600.0 <= WALLTIME_SECS {
            sim.run_steps(1)?;
            done += 1;
        }
        let ecorr = VaspRpa::ecorr(&sim.procs[0]).unwrap_or(0.0);
        println!(
            "  job {window}: reached point {done}/{TOTAL_POINTS} in {:.1} h walltime (ecorr={ecorr:.3e})",
            (sim.now().as_secs() - t0) / 3600.0
        );
        if done < TOTAL_POINTS {
            let rep = sim
                .checkpoint()
                .map_err(|e| anyhow::anyhow!("walltime checkpoint: {e}"))?;
            println!(
                "  job {window}: walltime checkpoint ({:.1}s), job ends",
                rep.total_secs
            );
            fs_carry = Some(sim.kill());
            sim = JobSim::launch(cfg.clone(), Some(engine.clone()))?; // placeholder, replaced on restart
        }
    }

    assert_eq!(
        sim.fingerprint(),
        want,
        "chained RPA must equal the uninterrupted reservation run"
    );
    assert!(window >= 3, "must have spanned at least 3 walltime windows");
    println!(
        "\nOK: {TOTAL_POINTS}h RPA completed across {window} x 48h jobs, bitwise-identical."
    );
    Ok(())
}
