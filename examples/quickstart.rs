//! Quickstart + end-to-end validation.
//!
//! Runs the full three-layer stack on a real small workload:
//! an 8-rank Gromacs-analog MD job whose compute is the AOT-compiled JAX
//! graph (with the Pallas LJ force kernel inside), executed via PJRT from
//! the rust coordinator. Mid-run, MANA checkpoints the job, the job is
//! killed, restarted from the images, and run to completion.
//!
//! The final assertion is the paper's production claim for Gromacs:
//! "a Gromacs computation can be checkpointed at any point in its
//! execution and resumed to generate exactly the same results as an
//! uninterrupted run" — checked bitwise via state fingerprints.
//!
//! Run: cargo run --release --example quickstart

use std::sync::Arc;

use anyhow::Result;

use mana::config::{AppKind, ComputeMode, RunConfig};
use mana::runtime::{default_artifact_dir, Engine};
use mana::sim::JobSim;
use mana::util::bytes::human;

fn main() -> Result<()> {
    println!("=== MANA quickstart: transparent C/R of an MD job ===\n");

    // Layer 2+1: load the AOT artifacts (JAX graphs + Pallas kernels,
    // lowered to HLO text by `make artifacts`) onto the PJRT CPU client.
    let engine = Arc::new(Engine::load(&default_artifact_dir())?);
    println!(
        "loaded artifacts {:?} on platform '{}'",
        engine.artifact_names(),
        engine.platform()
    );

    let mut cfg = RunConfig::new(AppKind::Gromacs, 8);
    cfg.compute = ComputeMode::Real;
    cfg.mem_per_rank = Some(32 << 20); // keep images small for the demo
    cfg.steps = 12;
    let total_steps = cfg.steps;
    let ckpt_at = 5;

    // Reference: uninterrupted run.
    println!("\n-- reference run: {total_steps} supersteps, no interruption");
    let mut reference = JobSim::launch(cfg.clone(), Some(engine.clone()))?;
    reference.run_steps(total_steps)?;
    let want = reference.fingerprint();
    println!("   final state fingerprint: {want:016x}");

    // Interrupted run: ckpt at step 5, kill, restart, finish.
    println!("\n-- interrupted run: checkpoint at step {ckpt_at}, kill, restart");
    let mut sim = JobSim::launch(cfg.clone(), Some(engine.clone()))?;
    sim.run_steps(ckpt_at)?;
    let ckpt = sim
        .checkpoint()
        .map_err(|e| anyhow::anyhow!("checkpoint failed: {e}"))?;
    println!(
        "   checkpoint: {} across {} ranks in {:.3}s virtual (write {:.3}s, {} in-flight msgs drained)",
        human(ckpt.image_bytes),
        cfg.ranks,
        ckpt.total_secs,
        ckpt.write_secs,
        ckpt.buffered_msgs
    );

    let fs = sim.kill();
    println!("   job killed (scheduler preemption / node failure)");

    let (mut resumed, rrep) = JobSim::restart_from(cfg.clone(), Some(engine), fs)
        .map_err(|e| anyhow::anyhow!("restart failed: {e}"))?;
    println!(
        "   restarted at step {} in {:.3}s virtual (image read {:.3}s)",
        resumed.step, rrep.total_secs, rrep.read_secs
    );
    resumed.run_steps(total_steps - ckpt_at)?;
    let got = resumed.fingerprint();
    println!("   final state fingerprint: {got:016x}");

    // The paper's claim, asserted.
    assert_eq!(
        got, want,
        "resumed run must generate exactly the same results"
    );
    assert!(!resumed.any_corruption(), "no data loss through C/R");
    println!("\nOK: resumed run is bitwise-identical to the uninterrupted run.");
    Ok(())
}
