"""L1 Pallas kernel: blocked, scaled matmul for the RPA chi0 block
(VASP analog).

The paper's top application is VASP; its RPA (Random Phase Approximation)
jobs are the long-running workloads that motivated MANA C/R at NERSC. The
RPA hot spot is the independent-particle polarizability chi0 = w * O V^T —
a large dense matmul chain. This kernel is the MXU-shaped building block:
128x128x128 tiles matching the TPU systolic array, k-accumulation done
in-place in the revisited output block (the classic Pallas matmul pattern,
no scratch needed), with the quadrature weight fused into the final store.

Lowered with ``interpret=True`` (see lj_forces.py for why).

Correctness oracle: :func:`kernels.ref.rpa_block_ref`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-native tile. The TPU MXU is a 128x128 systolic array; bf16 inputs with
# f32 accumulation is its native mode, which this kernel mirrors.
BM = BN = BK = 128


def _rpa_kernel(o_ref, v_ref, out_ref, *, scale: float, k_steps: int):
    """Grid (M/BM, N/BN, K/BK); k is the innermost (sequential) axis.

    o_ref:   (BM, BK) occupied block for (i, k).
    v_ref:   (BN, BK) virtual block for (j, k).
    out_ref: (BM, BN) chi0 block for (i, j) — revisited across k.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    o = o_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    # MXU contraction with f32 accumulation.
    out_ref[...] += jax.lax.dot_general(
        o, v, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _finalize():
        out_ref[...] *= scale


def rpa_block(occ: jnp.ndarray, virt: jnp.ndarray, *, scale: float,
              bm: int = BM, bn: int = BN, bk: int = BK) -> jnp.ndarray:
    """Pallas chi0 block: ``scale * occ @ virt.T`` with f32 accumulation.

    ``occ`` is ``(M, K)``, ``virt`` is ``(N, K)``. Dimensions are padded to
    the block sizes (zero padding is exact for a matmul).
    """
    m, k = occ.shape
    n, k2 = virt.shape
    assert k == k2, f"contraction mismatch: {k} vs {k2}"
    mp = ((m + bm - 1) // bm) * bm
    np_ = ((n + bn - 1) // bn) * bn
    kp = ((k + bk - 1) // bk) * bk
    o = jnp.pad(occ.astype(jnp.float32), ((0, mp - m), (0, kp - k)))
    v = jnp.pad(virt.astype(jnp.float32), ((0, np_ - n), (0, kp - k)))

    kernel = functools.partial(_rpa_kernel, scale=float(scale),
                               k_steps=kp // bk)
    out = pl.pallas_call(
        kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(o, v)
    return out[:m, :n]
