//! Slurm-`srun` analog: argument packet, executable distribution, startup
//! time model.
//!
//! Two paper issues live here:
//!
//! * **Argument-length limit.** "The Slurm srun command uses a network
//!   packet containing the list of arguments it was passed … Due to the
//!   limit on packet sizes, srun was unable to pass all checkpoint file
//!   names to its workers, leading to a crash." Restart argv under the
//!   legacy scheme appends every per-rank image path; past the packet limit
//!   the launch fails with [`LaunchError::ArgListTooLong`]. The fix passes
//!   one manifest path instead ([`restart_argv`]).
//! * **Startup at scale.** "For best startup performance at scale, it is
//!   recommended to broadcast a statically linked executable to all nodes.
//!   DMTCP currently does not support static linking…" — [`startup_secs`]
//!   models the dynamic-linking metadata storm (grows with node count)
//!   vs. the static broadcast (log-tree, near-flat).

use crate::ckpt::manifest::CkptManifest;
use crate::config::LinkMode;
use crate::topology::{RankId, Topology};

/// Cray/Slurm-era launch-packet budget for argv + env (bytes).
pub const SRUN_PACKET_LIMIT: usize = 4096;

/// Launch failures.
#[derive(Clone, Debug, PartialEq)]
pub enum LaunchError {
    /// The srun packet overflow crash.
    ArgListTooLong { bytes: usize, limit: usize },
}

impl std::fmt::Display for LaunchError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LaunchError::ArgListTooLong { bytes, limit } => write!(
                f,
                "srun: error: argument list too long ({bytes} bytes > {limit} packet limit)"
            ),
        }
    }
}

impl std::error::Error for LaunchError {}

/// A validated launch.
#[derive(Clone, Debug)]
pub struct LaunchReport {
    pub argv_bytes: usize,
    pub startup_secs: f64,
    pub nodes: u32,
}

/// Size of the argv packet srun would ship to its worker daemons.
pub fn argv_packet_bytes(argv: &[String]) -> usize {
    // Each arg costs its bytes + a NUL + a length word, plus packet header.
    64 + argv.iter().map(|a| a.len() + 5).sum::<usize>()
}

/// Validate the argv packet against the srun limit.
pub fn check_argv(argv: &[String]) -> Result<usize, LaunchError> {
    let bytes = argv_packet_bytes(argv);
    if bytes > SRUN_PACKET_LIMIT {
        return Err(LaunchError::ArgListTooLong {
            bytes,
            limit: SRUN_PACKET_LIMIT,
        });
    }
    Ok(bytes)
}

/// Build the restart argv. With the manifest fix: one bounded path.
/// Without: every rank's image path rides the packet (the crash at scale).
pub fn restart_argv(job: &str, ranks: u32, manifest_fix: bool) -> Vec<String> {
    let mut argv = vec!["mana_restart".to_string(), "--join".to_string()];
    if manifest_fix {
        argv.push("--restart-manifest".to_string());
        argv.push(CkptManifest::manifest_path(job));
    } else {
        for r in 0..ranks {
            argv.push(crate::ckpt::image_path(job, RankId(r)));
        }
    }
    argv
}

/// MANA/DMTCP binary size shipped to nodes (dynamic: plus its .so closure).
const EXE_BYTES: f64 = 120e6;
const SOLIB_CLOSURE_BYTES: f64 = 480e6;

/// Startup-time model.
///
/// * `Static`: one binomial-tree broadcast of the self-contained binary.
/// * `Dynamic`: every node's `ld.so` hammers the shared file system for the
///   solib closure; the metadata server serializes, so cost grows linearly
///   with node count (the behaviour that makes static linking "preferred
///   at scale").
pub fn startup_secs(topo: &Topology, link: LinkMode) -> f64 {
    let nodes = topo.nodes() as f64;
    match link {
        LinkMode::Static => {
            let hops = (nodes.max(2.0)).log2().ceil();
            0.8 + hops * (EXE_BYTES / 10e9) // tree bcast at 10 GB/s per hop
        }
        LinkMode::Dynamic => {
            // Shared-FS metadata serialization + per-node resolution work.
            let meta = 0.08 * nodes;
            let transfer = SOLIB_CLOSURE_BYTES / 2e9; // contended read
            1.5 + meta + transfer
        }
    }
}

/// Full launch: validate argv, compute startup time.
pub fn launch(
    topo: &Topology,
    link: LinkMode,
    argv: &[String],
) -> Result<LaunchReport, LaunchError> {
    let argv_bytes = check_argv(argv)?;
    Ok(LaunchReport {
        argv_bytes,
        startup_secs: startup_secs(topo, link),
        nodes: topo.nodes(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn legacy_argv_crashes_at_scale() {
        // 512 ranks: every image path in the packet -> overflow.
        let argv = restart_argv("job7", 512, false);
        match check_argv(&argv) {
            Err(LaunchError::ArgListTooLong { bytes, limit }) => {
                assert!(bytes > limit);
            }
            other => panic!("expected overflow, got {other:?}"),
        }
    }

    #[test]
    fn legacy_argv_fine_at_small_scale() {
        let argv = restart_argv("job7", 16, false);
        assert!(check_argv(&argv).is_ok());
    }

    #[test]
    fn manifest_fix_is_scale_invariant() {
        for ranks in [4u32, 64, 512, 4096] {
            let argv = restart_argv("job7", ranks, true);
            let bytes = check_argv(&argv).unwrap();
            assert!(bytes < 256, "ranks={ranks}: {bytes}B");
        }
    }

    #[test]
    fn crossover_rank_count_exists() {
        // Somewhere between 16 and 512 ranks the legacy scheme tips over.
        let works = |r| check_argv(&restart_argv("j", r, false)).is_ok();
        assert!(works(16));
        assert!(!works(512));
        let mut lo = 16u32;
        let mut hi = 512u32;
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            if works(mid) {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        // The crossover should be within production job sizes.
        assert!((64..=256).contains(&hi), "crossover at {hi} ranks");
    }

    #[test]
    fn static_startup_beats_dynamic_at_scale() {
        let big = Topology::new(512, 8); // 64 nodes
        let t_static = startup_secs(&big, LinkMode::Static);
        let t_dyn = startup_secs(&big, LinkMode::Dynamic);
        assert!(
            t_dyn > 2.0 * t_static,
            "dynamic {t_dyn}s vs static {t_static}s"
        );
    }

    #[test]
    fn startup_growth_shapes() {
        // Dynamic grows roughly linearly with nodes; static stays near-flat.
        let t = |ranks, link| startup_secs(&Topology::new(ranks, 8), link);
        let dyn_ratio = t(512, LinkMode::Dynamic) / t(8, LinkMode::Dynamic);
        let sta_ratio = t(512, LinkMode::Static) / t(8, LinkMode::Static);
        assert!(dyn_ratio > 3.0, "dynamic ratio {dyn_ratio}");
        assert!(sta_ratio < 2.0, "static ratio {sta_ratio}");
    }

    #[test]
    fn launch_report_fields() {
        let topo = Topology::new(8, 8);
        let rep = launch(&topo, LinkMode::Static, &restart_argv("j", 8, true)).unwrap();
        assert_eq!(rep.nodes, 1);
        assert!(rep.startup_secs > 0.0);
        assert!(rep.argv_bytes > 0);
    }
}
