//! MPI collectives over the simulated fabric.
//!
//! Collectives are synchronization points: every participant's virtual
//! clock advances to the operation's completion time. Costs follow the
//! standard log-tree models (latency * ceil(log2 P) + bytes/bandwidth per
//! hop), and every collective also updates the per-rank byte counters so
//! the drain condition sees collective traffic too.

use crate::topology::RankId;
use crate::util::simclock::SimTime;

use super::MpiWorld;

fn log2_ceil(p: u32) -> u32 {
    debug_assert!(p >= 1);
    32 - (p - 1).leading_zeros()
}

/// Synchronize all clocks to the max and add a tree-latency term.
/// Returns the completion time.
pub fn barrier(world: &mut MpiWorld, times: &mut [SimTime]) -> SimTime {
    assert_eq!(times.len(), world.size as usize);
    let enter = times.iter().fold(SimTime::ZERO, |a, &t| a.max(t));
    let hops = log2_ceil(world.size).max(1) as f64;
    let done = enter.after(2.0 * hops * world.fabric.cfg.latency);
    for t in times.iter_mut() {
        *t = done;
    }
    done
}

/// Scalar cost of one allreduce of `bytes` per rank: the wire bytes each
/// rank moves and the virtual duration past the entry time. Shared by the
/// per-rank collective below and the event core's bulk-advance recurrence,
/// so both paths compute bit-identical completion times.
pub(crate) fn allreduce_cost(world: &MpiWorld, bytes: u64) -> (u64, f64) {
    let p = world.size as f64;
    let hops = log2_ceil(world.size).max(1) as f64;
    let bw = world.fabric.cfg.bandwidth;
    // Rabenseifner-style: 2 * (p-1)/p * bytes over the wire per rank.
    let wire_bytes = if world.size > 1 {
        (2.0 * (p - 1.0) / p * bytes as f64) as u64
    } else {
        0
    };
    let dur = hops * world.fabric.cfg.latency + wire_bytes as f64 / bw;
    (wire_bytes, dur)
}

/// Per-rank message-count charge of one allreduce, each direction.
pub(crate) fn allreduce_msgs(size: u32) -> u64 {
    2 * log2_ceil(size) as u64
}

/// Allreduce of `bytes` per rank: reduce-scatter + allgather cost model.
/// Charges 2*bytes sent/received per rank.
pub fn allreduce(world: &mut MpiWorld, times: &mut [SimTime], bytes: u64) -> SimTime {
    assert_eq!(times.len(), world.size as usize);
    let enter = times.iter().fold(SimTime::ZERO, |a, &t| a.max(t));
    let (wire_bytes, dur) = allreduce_cost(world, bytes);
    let msgs = allreduce_msgs(world.size);
    let done = enter.after(dur);
    for (i, t) in times.iter_mut().enumerate() {
        *t = done;
        if world.size > 1 {
            world.counters[i].sent_bytes += wire_bytes;
            world.counters[i].recv_bytes += wire_bytes;
            world.counters[i].sent_msgs += msgs;
            world.counters[i].recv_msgs += msgs;
        }
    }
    let _ = RankId(0);
    done
}

/// Broadcast `bytes` from `root` to everyone (binomial tree).
pub fn bcast(
    world: &mut MpiWorld,
    times: &mut [SimTime],
    root: RankId,
    bytes: u64,
) -> SimTime {
    assert_eq!(times.len(), world.size as usize);
    assert!(root.0 < world.size);
    let enter = times.iter().fold(SimTime::ZERO, |a, &t| a.max(t));
    let hops = log2_ceil(world.size).max(1) as f64;
    let bw = world.fabric.cfg.bandwidth;
    let dur = hops * (world.fabric.cfg.latency + bytes as f64 / bw);
    let done = enter.after(dur);
    for (i, t) in times.iter_mut().enumerate() {
        *t = done;
        if world.size > 1 {
            if i as u32 == root.0 {
                world.counters[i].sent_bytes += bytes * (world.size as u64 - 1).min(hops as u64);
                world.counters[i].sent_msgs += 1;
            } else {
                world.counters[i].recv_bytes += bytes;
                world.counters[i].recv_msgs += 1;
            }
        }
    }
    done
}

/// Does the collective leave the world drained? Collectives must be
/// self-consistent in the byte accounting; this is asserted in tests and
/// relied on by the coordinator (checkpoints happen at collective-free
/// safe points, but the counters must still balance **per collective op**
/// for bcast this is root-sends == sum of receives).
pub fn accounting_balanced(world: &MpiWorld) -> bool {
    world.total_sent_bytes() == world.total_recv_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::fabric::Fabric;

    fn world(n: u32) -> (MpiWorld, Vec<SimTime>) {
        (
            MpiWorld::new(n, Fabric::default()),
            vec![SimTime::ZERO; n as usize],
        )
    }

    #[test]
    fn log2_ceil_values() {
        assert_eq!(log2_ceil(1), 0);
        assert_eq!(log2_ceil(2), 1);
        assert_eq!(log2_ceil(3), 2);
        assert_eq!(log2_ceil(512), 9);
    }

    #[test]
    fn barrier_syncs_to_max() {
        let (mut w, mut times) = world(4);
        times[2] = SimTime::secs(5.0);
        let done = barrier(&mut w, &mut times);
        assert!(done.as_secs() > 5.0);
        assert!(times.iter().all(|t| *t == done));
    }

    #[test]
    fn allreduce_charges_symmetric_traffic() {
        let (mut w, mut times) = world(8);
        allreduce(&mut w, &mut times, 1 << 20);
        assert!(accounting_balanced(&w));
        assert!(w.counters[0].sent_bytes > 0);
        // All ranks see identical counters.
        for c in &w.counters {
            assert_eq!(c.sent_bytes, w.counters[0].sent_bytes);
        }
    }

    #[test]
    fn allreduce_single_rank_is_free() {
        let (mut w, mut times) = world(1);
        let t0 = times[0];
        allreduce(&mut w, &mut times, 1 << 20);
        assert_eq!(w.total_sent_bytes(), 0);
        assert!(times[0].as_secs() >= t0.as_secs());
    }

    #[test]
    fn bcast_larger_world_takes_longer() {
        let (mut w2, mut t2) = world(2);
        let (mut w64, mut t64) = world(64);
        let d2 = bcast(&mut w2, &mut t2, RankId(0), 1 << 20);
        let d64 = bcast(&mut w64, &mut t64, RankId(0), 1 << 20);
        assert!(d64 > d2);
    }

    #[test]
    fn collective_then_drain_condition_holds() {
        // After a collective completes, the global drain condition that the
        // coordinator checks must hold (no phantom in-flight bytes).
        let (mut w, mut times) = world(16);
        allreduce(&mut w, &mut times, 4096);
        barrier(&mut w, &mut times);
        assert!(w.drained());
    }
}
