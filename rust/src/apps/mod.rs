//! Analog applications: the workloads the paper evaluates MANA with.
//!
//! Each app is a per-rank state machine whose compute is the real AOT
//! artifact (L2 JAX graph + L1 Pallas kernel, run via PJRT) or a
//! deterministic synthetic evolution (for 512-rank benches). State lives in
//! upper-half memory regions of the rank's [`SplitProcess`] — which is what
//! makes it checkpointable by MANA without the app's cooperation
//! (*transparent* checkpointing).
//!
//! Every superstep a rank also exchanges halo chunks with its ring
//! neighbours through the MANA wrapper layer: the traffic exercises the
//! drain protocol, and the halo fold makes lost or clobbered messages
//! corrupt the final state fingerprint (detectably).

pub mod colheavy;
pub mod gromacs;
pub mod hpcg;
pub mod synthetic;
pub mod vasp_rpa;

use anyhow::Result;

use crate::config::{AppKind, ComputeMode};
use crate::runtime::Engine;
use crate::splitproc::SplitProcess;
use crate::topology::RankId;

/// Bytes of one halo chunk (two are sent per superstep, same tag — which
/// is what trips the careless blocking→non-blocking conversion).
pub const HALO_BYTES: usize = 64;
/// Virtual bytes charged to the fabric per halo chunk (the real ADH/HPCG
/// halos are MBs; the payload we carry is a digest of it).
pub const HALO_VIRTUAL_BYTES: u64 = 2 << 20;

/// How an app drives the end-of-superstep allreduce (the residual-norm
/// reduction every iterative solver runs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CollectiveCadence {
    /// Payload bytes per rank of the per-superstep allreduce.
    pub bytes: u64,
    /// Post the allreduce nonblocking at the end of the superstep (an
    /// MPI_Iallreduce waited on at the start of the next) instead of
    /// blocking in place. Nonblocking cadence leaves a pending collective
    /// across every superstep boundary, which is where checkpoint
    /// requests land — the collective-aware drain stressor.
    pub nonblocking: bool,
}

/// One application = init + compute rules.
pub trait App: Send + Sync {
    fn kind(&self) -> AppKind;
    /// AOT artifact name (None for the synthetic app).
    fn artifact(&self) -> Option<&'static str>;
    /// Default upper-half footprint per rank (virtual bytes).
    fn default_mem_per_rank(&self) -> u64;
    /// Modeled compute time per superstep (virtual seconds).
    fn compute_secs(&self) -> f64;
    /// The per-superstep allreduce shape. The default matches the
    /// historical hardcoded cadence (4 KiB, blocking) so existing apps
    /// keep bit-identical timelines.
    fn collective_cadence(&self) -> CollectiveCadence {
        CollectiveCadence {
            bytes: 4096,
            nonblocking: false,
        }
    }
    /// Map the app's regions into a fresh rank process and set initial state.
    fn init(&self, proc: &mut SplitProcess, ranks: u32, mem_per_rank: u64) -> Result<()>;
    /// Advance one rank's state by one superstep.
    fn compute(&self, ctx: &mut StepCtx) -> Result<()>;
}

/// Per-rank compute context.
pub struct StepCtx<'a> {
    pub rank: RankId,
    pub ranks: u32,
    pub proc: &'a mut SplitProcess,
    pub engine: Option<&'a Engine>,
    pub mode: ComputeMode,
}

impl StepCtx<'_> {
    /// Engine handle, or error if Real mode was requested without one.
    pub fn engine(&self) -> Result<&Engine> {
        self.engine
            .ok_or_else(|| anyhow::anyhow!("Real compute mode requires a loaded Engine"))
    }
}

/// Instantiate an app by kind.
pub fn make_app(kind: AppKind) -> Box<dyn App> {
    match kind {
        AppKind::Gromacs => Box::new(gromacs::GromacsAdh),
        AppKind::Hpcg => Box::new(hpcg::Hpcg),
        AppKind::VaspRpa => Box::new(vasp_rpa::VaspRpa),
        AppKind::Synthetic => Box::new(synthetic::Synthetic),
        AppKind::CollectiveHeavy => Box::new(colheavy::CollectiveHeavy),
    }
}

// ------------------------------------------------------------------ helpers

/// f32 slice -> LE bytes.
pub fn f32_to_bytes(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// LE bytes -> f32 vec.
pub fn bytes_to_f32(b: &[u8]) -> Vec<f32> {
    b.chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

/// Deterministic synthetic state evolution: next = H(state)-keyed stream
/// XOR state. Pure function of the bytes, so C/R determinism checks hold
/// in Synthetic mode too.
pub fn synth_evolve(bytes: &mut [u8]) {
    use crate::util::{fnv1a, prng::Xoshiro256};
    let mut rng = Xoshiro256::new(fnv1a(bytes));
    for b in bytes.iter_mut() {
        *b ^= (rng.next_u64() & 0xff) as u8;
    }
}

/// The halo payload a rank emits: a digest of its primary state region.
pub fn halo_payload(state: &[u8], step: u64, chunk: u8) -> Vec<u8> {
    halo_payload_from_hash(crate::util::fnv1a(state), step, chunk)
}

/// Expand a precomputed state hash into the halo payload (hot-path variant:
/// lets the superstep hash the state once per rank instead of cloning it
/// and hashing per chunk).
pub fn halo_payload_from_hash(state_hash: u64, step: u64, chunk: u8) -> Vec<u8> {
    use crate::util::hash_combine;
    let h = hash_combine(state_hash, hash_combine(step, chunk as u64));
    let mut out = Vec::with_capacity(HALO_BYTES);
    let mut x = h;
    while out.len() < HALO_BYTES {
        out.extend_from_slice(&x.to_le_bytes());
        x = x.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(1);
    }
    out.truncate(HALO_BYTES);
    out
}

/// Fold a received halo chunk into the rank's halo accumulator region.
pub fn fold_halo(proc: &mut SplitProcess, payload: &[u8]) -> Result<()> {
    let acc = proc
        .app_state("halo_acc")
        .ok_or_else(|| anyhow::anyhow!("no halo_acc region"))?;
    let mut acc = acc.to_vec();
    for (a, b) in acc.iter_mut().zip(payload) {
        *a ^= *b;
    }
    proc.store_app_state("halo_acc", acc)
}

/// Common region setup shared by all apps: the halo accumulator plus the
/// big pattern-backed heap that dominates the checkpoint footprint.
pub fn map_common_regions(
    proc: &mut SplitProcess,
    mem_per_rank: u64,
    state_bytes: u64,
) -> Result<()> {
    use crate::mem::Payload;
    proc.map_app_region("halo_acc", HALO_BYTES as u64, Payload::Real(vec![0u8; HALO_BYTES]))?;
    let heap = mem_per_rank.saturating_sub(state_bytes + HALO_BYTES as u64);
    if heap > 0 {
        let seed = 0xADE0 ^ proc.rank.0 as u64;
        proc.map_app_region("heap", heap, Payload::Pattern(seed))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_bytes_roundtrip() {
        let v = vec![1.5f32, -2.25, 0.0, f32::MIN_POSITIVE];
        assert_eq!(bytes_to_f32(&f32_to_bytes(&v)), v);
    }

    #[test]
    fn synth_evolve_deterministic_and_changing() {
        let mut a = vec![1u8, 2, 3, 4, 5, 6, 7, 8];
        let mut b = a.clone();
        synth_evolve(&mut a);
        synth_evolve(&mut b);
        assert_eq!(a, b);
        assert_ne!(a, vec![1u8, 2, 3, 4, 5, 6, 7, 8]);
        // Two steps differ from one step.
        let one = a.clone();
        synth_evolve(&mut a);
        assert_ne!(a, one);
    }

    #[test]
    fn halo_payload_is_step_and_chunk_dependent() {
        let s = [9u8; 128];
        assert_eq!(halo_payload(&s, 3, 0).len(), HALO_BYTES);
        assert_ne!(halo_payload(&s, 3, 0), halo_payload(&s, 3, 1));
        assert_ne!(halo_payload(&s, 3, 0), halo_payload(&s, 4, 0));
        assert_eq!(halo_payload(&s, 3, 0), halo_payload(&s, 3, 0));
    }

    #[test]
    fn make_app_covers_all_kinds() {
        for kind in [
            AppKind::Gromacs,
            AppKind::Hpcg,
            AppKind::VaspRpa,
            AppKind::Synthetic,
            AppKind::CollectiveHeavy,
        ] {
            let app = make_app(kind);
            assert_eq!(app.kind(), kind);
            assert!(app.default_mem_per_rank() > 0);
            assert!(app.compute_secs() > 0.0);
        }
    }

    #[test]
    fn cadence_default_matches_historical_allreduce() {
        // The default cadence must stay 4 KiB blocking: the event core's
        // bulk-advance recurrence and every recorded fingerprint baseline
        // assume it.
        for kind in [AppKind::Gromacs, AppKind::Hpcg, AppKind::Synthetic] {
            let c = make_app(kind).collective_cadence();
            assert_eq!(c.bytes, 4096);
            assert!(!c.nonblocking);
        }
        let c = make_app(AppKind::CollectiveHeavy).collective_cadence();
        assert!(c.nonblocking, "colheavy posts nonblocking allreduces");
        assert!(c.bytes < 4096, "small payloads at high frequency");
    }
}
