//! SCHED — ablation: scheduling policies for real-time workloads.
//!
//! Quantifies the paper's motivation: "making space for high-priority,
//! real-time workloads by preempting low-priority jobs", with the Fig. 1
//! MANA coverage (top-20 apps ≈ 70% of cycles) gating what is preemptible.
//!
//! Policies: no preemption (status quo), kill+rerun (preemption without
//! C/R: work lost), MANA checkpoint-preempt (this work).

use mana::benchkit::{fsecs, Report};
use mana::sched::{generate_trace, Policy, Scheduler};

fn main() {
    let nodes = 64;
    let trace = generate_trace(48, 12, nodes, 0.70, 2020);

    let mut rep = Report::new(
        "SCHED: realtime service under three preemption policies (64 nodes)",
        vec![
            "policy",
            "rt_wait_mean_s",
            "rt_wait_max_s",
            "lost_node_hours",
            "cr_overhead_node_hours",
            "utilization",
        ],
    );

    let mut results = Vec::new();
    for (name, policy) in [
        ("no-preemption", Policy::NoPreemption),
        ("kill+rerun", Policy::KillRestart),
        ("mana-ckpt", Policy::CkptPreempt),
    ] {
        let r = Scheduler::new(nodes, policy).simulate(&trace);
        rep.row(vec![
            name.into(),
            fsecs(r.rt_wait_mean),
            fsecs(r.rt_wait_max),
            format!("{:.1}", r.lost_node_secs / 3600.0),
            format!("{:.2}", r.cr_overhead_node_secs / 3600.0),
            format!("{:.1}%", r.utilization * 100.0),
        ]);
        results.push((name, r));
    }
    rep.finish();

    let no = &results[0].1;
    let kill = &results[1].1;
    let mana = &results[2].1;
    println!(
        "\nrealtime wait: {:.0}s (none) -> {:.0}s (mana, {:.0}x better); lost work: {:.1} node-h (kill) -> 0 (mana)",
        no.rt_wait_mean,
        mana.rt_wait_mean,
        no.rt_wait_mean / mana.rt_wait_mean.max(1e-9),
        kill.lost_node_secs / 3600.0
    );
    assert!(mana.rt_wait_mean < no.rt_wait_mean * 0.5);
    assert_eq!(mana.lost_node_secs, 0.0);
    assert!(kill.lost_node_secs > 0.0);
    assert!(mana.cr_overhead_node_secs / 3600.0 < kill.lost_node_secs / 3600.0);
    println!("SCHED OK");
}
