"""Pure-jnp reference oracles for the L1 Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its oracle to float tolerance under pytest (including the
hypothesis shape/dtype sweeps in python/tests/).

The three kernels are the compute hot spots of the three analog workloads
used to reproduce the paper's evaluation (see DESIGN.md):

* ``lj_forces_ref``   — Gromacs/ADH analog (molecular dynamics).
* ``stencil27_ref``   — HPCG analog (27-point stencil SpMV).
* ``rpa_block_ref``   — VASP/RPA analog (scaled blocked matmul).
"""

from __future__ import annotations

import jax.numpy as jnp


def lj_forces_ref(pos: jnp.ndarray, box: float, eps: float, sigma: float,
                  rcut: float) -> jnp.ndarray:
    """Lennard-Jones forces with minimum-image convention.

    Args:
      pos: ``(N, 3)`` particle positions in a cubic box ``[0, box)^3``.
      box: cubic box edge length.
      eps/sigma: LJ well depth and zero-crossing distance.
      rcut: cutoff radius; pairs beyond it contribute zero force.

    Returns:
      ``(N, 3)`` forces, same dtype as ``pos`` (accumulated in f32).
    """
    p = pos.astype(jnp.float32)
    # Pairwise displacement with minimum image: r_ij = p_i - p_j.
    d = p[:, None, :] - p[None, :, :]                      # (N, N, 3)
    d = d - box * jnp.round(d / box)
    r2 = jnp.sum(d * d, axis=-1)                           # (N, N)
    n = p.shape[0]
    eye = jnp.eye(n, dtype=bool)
    # Avoid 0/0 on the diagonal; the mask zeroes it out after.
    r2_safe = jnp.where(eye, 1.0, r2)
    inv_r2 = 1.0 / r2_safe
    s2 = (sigma * sigma) * inv_r2
    s6 = s2 * s2 * s2
    # F_ij = 24 eps (2 s^12 - s^6) / r^2 * d_ij
    coef = 24.0 * eps * (2.0 * s6 * s6 - s6) * inv_r2
    mask = (~eye) & (r2 <= rcut * rcut)
    coef = jnp.where(mask, coef, 0.0)
    f = jnp.sum(coef[:, :, None] * d, axis=1)              # (N, 3)
    return f.astype(pos.dtype)


def stencil27_ref(x: jnp.ndarray) -> jnp.ndarray:
    """HPCG-style 27-point stencil SpMV: y = A x on a 3-D grid.

    A has 26.0 on the diagonal and -1.0 for each of the 26 neighbours,
    with zero (Dirichlet) boundary — exactly the HPCG operator.

    Args:
      x: ``(nx, ny, nz)`` grid vector.
    Returns:
      ``(nx, ny, nz)`` result, same dtype (accumulated in f32).
    """
    xf = x.astype(jnp.float32)
    xp = jnp.pad(xf, 1)                                    # zero boundary
    acc = jnp.zeros_like(xf)
    nx, ny, nz = xf.shape
    for di in (0, 1, 2):
        for dj in (0, 1, 2):
            for dk in (0, 1, 2):
                sub = xp[di:di + nx, dj:dj + ny, dk:dk + nz]
                if di == 1 and dj == 1 and dk == 1:
                    acc = acc + 26.0 * sub
                else:
                    acc = acc - sub
    return acc.astype(x.dtype)


def rpa_block_ref(occ: jnp.ndarray, virt: jnp.ndarray,
                  scale: float) -> jnp.ndarray:
    """VASP/RPA analog: scaled response-matrix product chi0 = scale * O V^T.

    Args:
      occ:  ``(M, K)`` occupied-orbital block.
      virt: ``(N, K)`` virtual-orbital block.
      scale: frequency-quadrature weight.
    Returns:
      ``(M, N)`` chi0 block in f32.
    """
    o = occ.astype(jnp.float32)
    v = virt.astype(jnp.float32)
    return (scale * (o @ v.T)).astype(jnp.float32)
