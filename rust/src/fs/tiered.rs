//! Tiered storage engine: fast tier (Burst Buffer) + durable tier
//! (Lustre) with asynchronous BB→PFS staging and **content-addressed
//! chunk dedup** on the drain path.
//!
//! The paper's scalability result is that checkpoint overhead is dominated
//! by the storage tier: at 512 ranks, Burst Buffers beat Lustre by >20x on
//! write. Its future work asks for "reducing the checkpoint overhead for
//! large-scale applications". Multi-level checkpointing (SCR-style) is the
//! standard answer, modeled here:
//!
//! * A checkpoint **completes when the fast-tier write lands** — that is
//!   the only stall the ranks observe.
//! * Every written file is queued for a **background drain** to the
//!   durable tier; node-local drain agents move bytes on the simulated
//!   clock across subsequent supersteps ([`TieredStore::drain_to`]), at
//!   chunk granularity (see [`crate::ckpt::chunk`]).
//! * **Dedup**: a write request may carry a
//!   [`ChunkRecipe`] — the ordered 128-bit content digests of its encoded
//!   chunks. The drain consults the durable-tier chunk index
//!   ([`ChunkStore`]) and ships **only chunks the index does not yet
//!   hold**; every other chunk is "drained" by reference in zero simulated
//!   seconds. Successive checkpoints of a mostly-clean address space turn
//!   into near-incremental PFS traffic (`deduped_bytes` in
//!   [`DrainStats`]/[`StagedIo`]).
//! * **Durable representation**: recipe-backed files live on the durable
//!   tier as one object per unique digest (`.chunkstore/<digest>`) plus
//!   the per-file recipe; restart reassembles the byte-identical encoded
//!   image from them even after total fast-tier loss, verifying each
//!   object's content digest ([`FsError::Corrupt`] on mismatch).
//! * **Refcounted GC**: each live recipe (queued or committed) holds one
//!   reference per chunk occurrence; an object is reclaimed only when the
//!   last referencing recipe is released. Deleting or replacing a
//!   generation can never orphan a chunk a newer generation still needs.
//! * **Eviction** keeps the last `keep_fulls` checkpoint generations
//!   resident on the fast tier; when a new wave doesn't fit, older
//!   *drained* generations are deleted from the fast tier (their durable
//!   copies remain restartable).
//! * **Backpressure**: if an undrained older generation must be evicted
//!   to make room, it is force-drained synchronously first and the time
//!   is charged to the checkpoint stall — the engine never drops the only
//!   copy of an image. With dedup, the forced drain too ships only the
//!   chunks the durable tier is missing.
//!
//! Restart reads prefer the fast tier per file and fall back to the
//! durable tier ([`TieredStore::read_preferred`]); CRC-level fallback
//! across tiers lives in the restart engine (`sim::restart_from`), which
//! re-reads a corrupt fast-tier image from the durable tier.

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use super::chunkstore::{job_of, object_path, ChunkStore, INDEX_PATH, OBJECT_PREFIX};
use super::redundancy::{self, ProtectedFile, RedundancyConfig, RedundancyScheme, SetRecord};
use super::{FileSystem, FsError, FsKind, IoReport, StorageTier, WriteReq};
use crate::ckpt::chunk::{ChunkRecipe, DEFAULT_CHUNK_BYTES};
use crate::simnet::fabric::Fabric;
use crate::topology::NodeId;
use crate::trace::{EventCtx, Lane, Span, Tracer};
use crate::util::digest::digest128;
use crate::{log_debug, log_info};

/// Bytes a peer exchange must land before it can pipeline behind the
/// fast-tier write wave (the fabric pipeline-fill chunk).
pub const EXCHANGE_PIPELINE_CHUNK: u64 = 4 << 20;

/// Aggregate drain/eviction counters (reported by benches and `mana run`).
#[derive(Clone, Copy, Debug, Default)]
pub struct DrainStats {
    /// Physical bytes shipped to the durable tier (background + forced).
    /// With dedup this is the new-chunk traffic only.
    pub drained_bytes: u64,
    /// Files whose durable copy completed.
    pub drained_files: u64,
    /// Logical drain bytes satisfied by reference to chunks the durable
    /// index already held — never shipped to the PFS.
    pub deduped_bytes: u64,
    /// Subset of `deduped_bytes` satisfied by chunks the writing job held
    /// no reference of its own to — dedup credit earned from *other*
    /// tenants of a shared chunk store.
    pub cross_job_deduped_bytes: u64,
    /// Durable-tier seconds spent draining (background + forced).
    pub busy_secs: f64,
    /// Subset of `busy_secs` charged synchronously as backpressure.
    pub forced_secs: f64,
    pub evicted_generations: u64,
    pub evicted_files: u64,
    /// Chunk objects reclaimed by refcounted GC, and their virtual bytes.
    pub gc_chunks: u64,
    pub gc_bytes: u64,
    /// Drain completions that failed (source vanished, durable tier full).
    pub drain_errors: u64,
    /// Fast-tier files destroyed by injected node/set losses.
    pub lost_files: u64,
}

impl DrainStats {
    /// Fraction of logical drain traffic satisfied by reference (exact
    /// once the queue is empty).
    pub fn dedup_ratio(&self) -> f64 {
        let logical = self.deduped_bytes + self.drained_bytes;
        if logical == 0 {
            0.0
        } else {
            self.deduped_bytes as f64 / logical as f64
        }
    }

    /// Fraction of logical drain traffic satisfied by *other* jobs'
    /// chunks (the multi-tenancy dedup win; zero for a single job).
    pub fn cross_job_dedup_ratio(&self) -> f64 {
        let logical = self.deduped_bytes + self.drained_bytes;
        if logical == 0 {
            0.0
        } else {
            self.cross_job_deduped_bytes as f64 / logical as f64
        }
    }
}

/// One file queued for staging to the durable tier.
#[derive(Clone, Debug)]
struct DrainItem {
    path: String,
    /// Physical bytes still to ship (recipe items: new-chunk bytes only;
    /// deduped chunks were already subtracted at queue time).
    remaining: u64,
    /// Drain progress granularity (the recipe's chunk size, or the
    /// default for recipe-less files).
    granularity: u64,
    /// Content recipe (referenced into the chunk index at queue time).
    recipe: Option<ChunkRecipe>,
    /// Virtual time at which this file's own fast-tier write landed —
    /// the moment the early-admission drain may start on it. Stamped
    /// wave-relative (`<= 0`, offset from the wave's end) by
    /// [`TieredStore::write_wave`], resolved to absolute time by
    /// [`TieredStore::admit_wave`], and consumed (set to `INFINITY`)
    /// once its stall-window credit has been granted.
    ready_at: f64,
}

/// One checkpoint generation's fast-tier footprint (for eviction), plus
/// the peer-redundancy exchange records protecting it.
#[derive(Clone, Debug, Default)]
struct Generation {
    paths: Vec<String>,
    /// One record per redundancy set that exchanged for this generation —
    /// the rebuild planner's input on restart.
    sets: Vec<SetRecord>,
}

/// Outcome of one post-wave peer exchange.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExchangeOutcome {
    /// Virtual seconds visible past the write wave (slowest member).
    pub exchange_secs: f64,
    /// Redundancy artifact bytes (copies or parity) parked on the fast
    /// tier by this exchange.
    pub parity_bytes: u64,
}

/// Outcome of one restart-time peer-rebuild pass.
#[derive(Clone, Copy, Debug, Default)]
pub struct RebuildOutcome {
    /// Distinct nodes whose fast-tier images were rebuilt from peers.
    pub rebuilt_nodes: u32,
    pub rebuilt_files: u32,
    /// Virtual seconds of peer-fetch traffic (concurrent per member).
    pub rebuild_secs: f64,
    /// Set records that could not be rebuilt (>= 2 losses in an XOR set,
    /// partner-pair loss, or stale survivors) — restart falls back across
    /// tiers for their files.
    pub unrecoverable_sets: u32,
}

/// Outcome of one checkpoint write wave on the tiered store.
#[derive(Clone, Copy, Debug, Default)]
pub struct StagedIo {
    /// Fast-tier wave time — the rank-visible checkpoint stall.
    pub fast_secs: f64,
    pub fast_bytes: u64,
    /// Synchronous durable-tier seconds forced by backpressure.
    pub backpressure_secs: f64,
    /// Bytes the backpressure force-drain moved to the durable tier.
    pub durable_bytes: u64,
    /// Logical bytes of this wave satisfied by reference to chunks the
    /// durable index already held (content-addressed dedup).
    pub deduped_bytes: u64,
    pub evicted_files: usize,
    /// Physical bytes queued for background drain after this wave.
    pub pending_bytes: u64,
    pub writers: usize,
}

impl StagedIo {
    /// Collapse into the generic wave report (duration = total stall).
    pub fn io(&self) -> IoReport {
        IoReport {
            duration: self.fast_secs + self.backpressure_secs,
            total_virtual_bytes: self.fast_bytes,
            writers: self.writers,
        }
    }
}

/// Outcome of one background drain tick.
#[derive(Clone, Copy, Debug, Default)]
pub struct DrainTick {
    pub drained_bytes: u64,
    pub completed_files: usize,
    pub queue_empty: bool,
}

/// Fast tier + durable tier + drain queue + chunk index. See the module
/// docs.
#[derive(Clone, Debug)]
pub struct TieredStore {
    fast: FileSystem,
    durable: FileSystem,
    queue: VecDeque<DrainItem>,
    generations: VecDeque<Generation>,
    /// Content-addressed chunk index + recipe table for the durable tier.
    chunks: ChunkStore,
    /// Checkpoint generations kept resident on the fast tier (including
    /// the one currently being written).
    pub keep_fulls: usize,
    /// Node count backing the drain agents (one agent per node).
    nodes: u32,
    /// Virtual time up to which the background drain has already worked.
    clock: f64,
    /// Per-job fractional-byte credit carried between ticks (chunk-
    /// granular draining would otherwise lose sub-chunk budgets). Keyed
    /// by the job prefix of the queued paths; single-tenant stores only
    /// ever hold one entry and behave exactly like a scalar credit.
    credit: BTreeMap<String, f64>,
    /// Drain-bandwidth QoS weights per job (weighted fair share of the
    /// BB→PFS link among jobs with queued work; default weight 1.0).
    drain_weights: BTreeMap<String, f64>,
    /// Admit a file to the background drain as soon as its own fast-tier
    /// write lands, instead of holding the whole wave back until the
    /// checkpoint stall ends (the PR-6 whole-wave barrier).
    early_admission: bool,
    /// Committed chunk state changed since the `.chunkstore/INDEX` object
    /// was last persisted to the durable tier.
    index_dirty: bool,
    pub stats: DrainStats,
    /// Fast-tier peer redundancy (partner copies / XOR parity sets).
    redundancy: RedundancyConfig,
    /// Which node wrote each fast-tier path (write waves and redundancy
    /// artifacts alike) — drives loss injection and set grouping.
    owners: BTreeMap<String, NodeId>,
    /// Scheduled fast-tier node losses `(node, at virtual secs)` from the
    /// fault plan; fired as the drain clock passes them.
    pending_losses: Vec<(NodeId, f64)>,
    /// Monotonic exchange counter (names redundancy artifact paths).
    exchanges: u64,
    /// Shared span/event recorder (the owning job's; event-log-only until
    /// [`TieredStore::set_tracer`] hands over the job's tracer).
    tracer: Tracer,
}

impl TieredStore {
    pub fn new(fast: FileSystem, durable: FileSystem, keep_fulls: usize, nodes: u32) -> Self {
        TieredStore {
            fast,
            durable,
            queue: VecDeque::new(),
            generations: VecDeque::new(),
            chunks: ChunkStore::default(),
            keep_fulls: keep_fulls.max(1),
            nodes: nodes.max(1),
            clock: 0.0,
            credit: BTreeMap::new(),
            drain_weights: BTreeMap::new(),
            early_admission: false,
            index_dirty: false,
            stats: DrainStats::default(),
            redundancy: RedundancyConfig::default(),
            owners: BTreeMap::new(),
            pending_losses: Vec::new(),
            exchanges: 0,
            tracer: Tracer::disabled(),
        }
    }

    /// Adopt the owning job's tracer: drain ticks and fault events land in
    /// the same timeline as the checkpoint phases.
    pub fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
    }

    /// Handle on the store's tracer (shared state — clones are cheap), for
    /// callers that hold the store but not the job.
    pub fn tracer(&self) -> Tracer {
        self.tracer.clone()
    }

    /// Rebuild a tiered store around surviving tiers — e.g. a durable tier
    /// that outlived the job entirely. Durable-only restart does not
    /// depend on the in-memory store surviving: the chunk index is
    /// reloaded and verified from its persisted `.chunkstore/INDEX`
    /// object.
    pub fn adopt(
        fast: FileSystem,
        durable: FileSystem,
        keep_fulls: usize,
        nodes: u32,
    ) -> Result<Self, FsError> {
        let mut ts = TieredStore::new(fast, durable, keep_fulls, nodes);
        ts.reload_index()?;
        Ok(ts)
    }

    /// Reload the persisted durable-tier chunk index and verify it: digest
    /// framing, recipe/entry cross-consistency, and the presence of every
    /// stored chunk object on the durable tier. The committed in-memory
    /// state is replaced by the verified index; recipes still sitting on
    /// the drain queue re-take their references on top, and chunk objects
    /// the verified index does not name are reclaimed (they backed queued
    /// recipes that died with the job, or a partially-shipped drain).
    /// Returns whether an index object was found (its absence is legal —
    /// a store that never committed a recipe).
    pub fn reload_index(&mut self) -> Result<bool, FsError> {
        if self.index_dirty {
            // The in-memory index is ahead of the persisted object (a
            // persist failed and awaits retry) — reloading would resurrect
            // the stale snapshot and lose committed recipes. Keep the
            // accurate state and retry the persist instead.
            self.maybe_persist_index();
            return Ok(false);
        }
        let Some((_, bytes)) = self.durable.peek(INDEX_PATH) else {
            return Ok(false);
        };
        let decoded = ChunkStore::decode_index(bytes).ok_or_else(|| {
            FsError::Corrupt(format!("{INDEX_PATH} failed digest verification"))
        })?;
        for d in decoded.stored_digests() {
            if !self.durable.exists(&object_path(d)) {
                return Err(FsError::Corrupt(format!(
                    "chunk index names stored object {d:032x} but it is missing"
                )));
            }
        }
        let mut chunks = decoded;
        for item in &self.queue {
            if let Some(rec) = &item.recipe {
                chunks.reference_for(job_of(&item.path), rec);
            }
        }
        self.chunks = chunks;
        self.index_dirty = false;
        // Orphan sweep: objects under `.chunkstore/` the verified index
        // does not claim are unreachable — nothing will ever read or
        // release them. A queued recipe that re-referenced one of their
        // digests simply re-ships it (its entry came back unstored).
        let live: std::collections::BTreeSet<String> = self
            .chunks
            .stored_digests()
            .into_iter()
            .map(object_path)
            .collect();
        let mut swept = 0u64;
        for p in self.durable.paths() {
            if p.starts_with(OBJECT_PREFIX)
                && p != INDEX_PATH
                && !live.contains(&p)
                && self.durable.delete(&p).is_ok()
            {
                swept += 1;
            }
        }
        if swept > 0 {
            self.stats.gc_chunks += swept;
            log_info!(
                "fs",
                "staged: index reload swept {swept} orphaned chunk objects"
            );
        }
        // Superseded plain copies whose delete was deferred by a failed
        // index persist are shadowed by their recipes — reclaim them too.
        for p in self.chunks.recipe_paths() {
            if self.durable.exists(&p) {
                let _ = self.durable.delete(&p);
            }
        }
        Ok(true)
    }

    /// Persist the chunk index to the durable tier if committed state
    /// changed. A failed write (pathological durable shortfall) keeps the
    /// dirty bit so a later operation retries.
    fn maybe_persist_index(&mut self) {
        if !self.index_dirty {
            return;
        }
        let data = self.chunks.encode_index();
        let vbytes = data.len() as u64;
        match self.durable.insert_raw(INDEX_PATH, vbytes, data) {
            Ok(()) => self.index_dirty = false,
            Err(e) => {
                self.tracer.warn(
                    "fs",
                    "fs.index_persist_failed",
                    EventCtx::default().with_t(self.clock),
                    format!("staged: chunk-index persist failed: {e} (will retry)"),
                );
            }
        }
    }

    pub fn fast(&self) -> &FileSystem {
        &self.fast
    }

    pub fn durable(&self) -> &FileSystem {
        &self.durable
    }

    pub fn fast_mut(&mut self) -> &mut FileSystem {
        &mut self.fast
    }

    pub fn durable_mut(&mut self) -> &mut FileSystem {
        &mut self.durable
    }

    /// The durable-tier chunk index (dedup observability).
    pub fn chunk_store(&self) -> &ChunkStore {
        &self.chunks
    }

    /// Physical bytes still queued for shipping to the durable tier.
    pub fn pending_bytes(&self) -> u64 {
        self.queue.iter().map(|i| i.remaining).sum()
    }

    /// Physical bytes still queued for shipping that belong to one
    /// tenant (first path component = job name; multi-job observability).
    pub fn pending_bytes_for(&self, job: &str) -> u64 {
        self.queue
            .iter()
            .filter(|i| job_of(&i.path) == job)
            .map(|i| i.remaining)
            .sum()
    }

    /// Files whose durable copy is not committed yet (a fully-deduped
    /// file can be pending with zero `pending_bytes`).
    pub fn pending_files(&self) -> usize {
        self.queue.len()
    }

    /// Effective durable-tier drain bandwidth: one drain agent per node
    /// (the SCR model — few well-behaved writers, not a 512-rank storm).
    pub fn drain_bandwidth(&self) -> f64 {
        self.durable
            .write_bandwidth(self.nodes as usize, self.nodes)
    }

    /// Open a new checkpoint generation and sync the drain clock (drain
    /// credit earned before `now` was already granted via `drain_to`).
    pub fn begin_ckpt(&mut self, now_secs: f64) {
        self.apply_due_losses(now_secs);
        self.clock = self.clock.max(now_secs);
        self.generations.push_back(Generation::default());
    }

    /// Advance the drain clock across the synchronous checkpoint stall.
    /// Without early admission the agents hold off entirely and no credit
    /// is granted; with it, each queued file earns credit for the part of
    /// the stall window after its own fast-tier write landed.
    pub fn sync_clock(&mut self, now_secs: f64) {
        self.apply_due_losses(now_secs);
        if self.early_admission && now_secs > self.clock {
            self.admit_early(now_secs);
        }
        self.clock = self.clock.max(now_secs);
    }

    /// Turn on early drain admission (threaded from
    /// `StagingConfig::early_admission`).
    pub fn set_early_admission(&mut self, on: bool) {
        self.early_admission = on;
    }

    /// Set `job`'s drain-bandwidth QoS weight (weighted fair share of the
    /// BB→PFS link among jobs with queued work; unset jobs weigh 1.0).
    pub fn set_drain_weight(&mut self, job: &str, weight: f64) {
        self.drain_weights.insert(job.to_string(), weight.max(0.0));
    }

    fn drain_weight(&self, job: &str) -> f64 {
        self.drain_weights.get(job).copied().unwrap_or(1.0)
    }

    /// Grant stall-window drain credit for files whose own fast-tier
    /// write already landed: a serial-service walk per job, from the
    /// drain clock to `now`, each file usable only after its `ready_at`.
    /// The grant is bounded by the walked files' remaining bytes, so the
    /// drain can never ship bytes "before they were written".
    fn admit_early(&mut self, now_secs: f64) {
        let c0 = self.clock;
        let bw = self.drain_bandwidth();
        let mut grants: BTreeMap<String, f64> = BTreeMap::new();
        let mut cursors: BTreeMap<String, f64> = BTreeMap::new();
        for item in &mut self.queue {
            if !item.ready_at.is_finite() {
                continue;
            }
            let job = job_of(&item.path).to_string();
            let t = cursors.entry(job.clone()).or_insert(c0);
            *t = t.max(item.ready_at);
            if *t < now_secs {
                let service = item.remaining as f64 / bw;
                let used = service.min(now_secs - *t);
                *grants.entry(job).or_insert(0.0) += used * bw;
                *t += used;
            }
            item.ready_at = f64::INFINITY; // credit granted once
        }
        for (job, g) in grants {
            if g > 0.0 {
                *self.credit.entry(job).or_insert(0.0) += g;
            }
        }
    }

    /// Resolve the wave-relative `ready_at` stamps of just-queued items
    /// against the wave's absolute end time on the virtual timeline
    /// (callers place the wave; the store only knows its duration).
    /// No-op unless early admission is on.
    pub fn admit_wave(&mut self, wave_end_secs: f64) {
        for item in &mut self.queue {
            if item.ready_at <= 0.0 {
                item.ready_at = (wave_end_secs + item.ready_at).max(0.0);
            }
        }
    }

    /// Rebase the drain clock onto a fresh timeline (restart: the store
    /// survives the kill, but the restarted job's virtual clock starts
    /// over — without the rebase the background drain would stall until
    /// the new clock caught up with the dead job's).
    pub fn rebase_clock(&mut self, now_secs: f64) {
        self.clock = now_secs;
    }

    // ------------------------------------- fast-tier peer redundancy

    /// Configure the peer-redundancy layer (threaded from `RunConfig`).
    pub fn set_redundancy(&mut self, cfg: RedundancyConfig) {
        self.redundancy = cfg;
    }

    pub fn redundancy(&self) -> RedundancyConfig {
        self.redundancy
    }

    /// Schedule the loss of `node`'s entire fast tier at virtual time
    /// `at_secs` (fault-plan driven; fires as the drain clock passes it).
    pub fn schedule_node_loss(&mut self, node: NodeId, at_secs: f64) {
        self.pending_losses.push((node, at_secs));
    }

    /// Schedule the loss of a whole redundancy set (by set index under the
    /// configured layout) — the deterministic unrecoverable case.
    pub fn schedule_set_loss(&mut self, set_idx: u32, at_secs: f64) {
        let sets = redundancy::node_sets(self.nodes, self.redundancy.set_size);
        if let Some(members) = sets.get(set_idx as usize) {
            for n in members {
                self.pending_losses.push((*n, at_secs));
            }
        } else {
            self.tracer.warn(
                "fs",
                format!("fs.set_loss_oob:s{set_idx}"),
                EventCtx::default(),
                format!(
                    "staged: set-loss index {set_idx} out of range ({} sets) — ignored",
                    sets.len()
                ),
            );
        }
    }

    /// Immediately lose a whole redundancy set (restart-time fault plans:
    /// the loss happened while the job was down, so it fires before the
    /// rebuild pass surveys the survivors).
    pub fn lose_set_now(&mut self, set_idx: u32) {
        let sets = redundancy::node_sets(self.nodes, self.redundancy.set_size);
        match sets.get(set_idx as usize).cloned() {
            Some(members) => {
                for n in members {
                    self.lose_node_now(n);
                }
            }
            None => self.tracer.warn(
                "fs",
                format!("fs.set_loss_oob:s{set_idx}"),
                EventCtx::default(),
                format!(
                    "staged: set-loss index {set_idx} out of range ({} sets) — ignored",
                    sets.len()
                ),
            ),
        }
    }

    /// Losses scheduled at or before `now_secs` whose time has come.
    fn apply_due_losses(&mut self, now_secs: f64) {
        if self.pending_losses.is_empty() {
            return;
        }
        let mut due = Vec::new();
        self.pending_losses.retain(|(n, at)| {
            if *at <= now_secs {
                due.push(*n);
                false
            } else {
                true
            }
        });
        for n in due {
            self.lose_node_now(n);
        }
    }

    /// Destroy every fast-tier file `node` owns — images, partner copies
    /// and parity blocks alike — modeling a Burst Buffer blade failure.
    /// Queued drains of the lost files die with them (their durable copies,
    /// if any, are untouched).
    pub fn lose_node_now(&mut self, node: NodeId) {
        let victims: Vec<String> = self
            .owners
            .iter()
            .filter(|(_, n)| **n == node)
            .map(|(p, _)| p.clone())
            .collect();
        let mut lost = 0u64;
        for path in victims {
            if !self.fast.exists(&path) {
                continue;
            }
            self.unclaim(&path);
            if self.fast.delete(&path).is_ok() {
                lost += 1;
            }
        }
        self.stats.lost_files += lost;
        self.tracer.error(
            "fs",
            format!("fs.fast_tier_lost:n{}", node.0),
            EventCtx::node(node.0).with_t(self.clock),
            format!(
                "staged: node {} fast tier lost ({lost} files destroyed)",
                node.0
            ),
        );
    }

    /// Post-wave peer exchange: every node in a redundancy set ships this
    /// generation's images to its peers — full copies to the partner, or
    /// rotated XOR parity blocks across the set. The fabric transfer is
    /// pipelined behind the just-finished write wave (`wave_secs`), so the
    /// visible cost is the pipeline fill plus any serialization the wave
    /// did not hide. Artifacts land on the fast tier (capacity-accounted:
    /// partner 2x, XOR 1 + 1/(m-1) x) and the exchange record is attached
    /// to the generation for the restart-time rebuild planner.
    pub fn exchange_wave(&mut self, fabric: &Fabric, wave_secs: f64) -> ExchangeOutcome {
        let mut out = ExchangeOutcome::default();
        if !self.redundancy.active() || self.nodes < 2 {
            return out;
        }
        let Some(gen_paths) = self.generations.back().map(|g| g.paths.clone()) else {
            return out;
        };
        let seq = self.exchanges;
        self.exchanges += 1;
        let sets = redundancy::node_sets(self.nodes, self.redundancy.set_size);
        let mut records: Vec<SetRecord> = Vec::new();
        let mut slowest = 0.0f64;
        for (si, members) in sets.iter().enumerate() {
            let m = members.len();
            if m < 2 {
                continue;
            }
            // This generation's files, grouped by owning member, in wave
            // order — the concatenation order the XOR code relies on.
            let mut files: Vec<Vec<ProtectedFile>> = vec![Vec::new(); m];
            for path in &gen_paths {
                let Some(owner) = self.owners.get(path).copied() else {
                    continue;
                };
                let Some(idx) = members.iter().position(|n| *n == owner) else {
                    continue;
                };
                let Some((vbytes, data)) = self.fast.peek(path) else {
                    continue;
                };
                files[idx].push(ProtectedFile {
                    path: path.clone(),
                    vbytes,
                    plen: data.len() as u64,
                    digest: digest128(data),
                    copy: None,
                });
            }
            if files.iter().all(|f| f.is_empty()) {
                continue;
            }
            let mut parity_paths = vec![String::new(); m];
            match self.redundancy.scheme {
                RedundancyScheme::None => unreachable!("checked active() above"),
                RedundancyScheme::Partner => {
                    for i in 0..m {
                        let holder = members[redundancy::partner_holder(i, m)];
                        for f in files[i].iter_mut() {
                            let Some(data) =
                                self.fast.peek(&f.path).map(|(_, d)| d.to_vec())
                            else {
                                continue;
                            };
                            let copy_path = format!(
                                ".redundancy/g{seq:04}/copy/n{}/{}",
                                holder.0, f.path
                            );
                            match self.fast.insert_raw(&copy_path, f.vbytes, data) {
                                Ok(()) => {
                                    self.owners.insert(copy_path.clone(), holder);
                                    out.parity_bytes += f.vbytes;
                                    f.copy = Some(copy_path);
                                }
                                Err(e) => self.tracer.warn(
                                    "fs",
                                    format!("fs.partner_copy_failed:{}", f.path),
                                    EventCtx::node(holder.0),
                                    format!(
                                        "staged: partner copy of {} failed: {e} \
                                         (file unprotected this generation)",
                                        f.path
                                    ),
                                ),
                            }
                        }
                    }
                }
                RedundancyScheme::Xor => {
                    let concats: Vec<Vec<u8>> = files
                        .iter()
                        .map(|flist| {
                            let mut c = Vec::new();
                            for f in flist {
                                if let Some((_, d)) = self.fast.peek(&f.path) {
                                    c.extend_from_slice(d);
                                }
                            }
                            c
                        })
                        .collect();
                    let views: Vec<&[u8]> = concats.iter().map(|c| c.as_slice()).collect();
                    let parities = redundancy::xor_encode(&views);
                    let max_vb = files
                        .iter()
                        .map(|fl| fl.iter().map(|f| f.vbytes).sum::<u64>())
                        .max()
                        .unwrap_or(0);
                    let parity_vbytes = redundancy::parity_block_len(max_vb, m);
                    for (j, p) in parities.into_iter().enumerate() {
                        let ppath =
                            format!(".redundancy/g{seq:04}/parity/s{si}/n{}", members[j].0);
                        match self.fast.insert_raw(&ppath, parity_vbytes, p) {
                            Ok(()) => {
                                self.owners.insert(ppath.clone(), members[j]);
                                parity_paths[j] = ppath;
                                out.parity_bytes += parity_vbytes;
                            }
                            Err(e) => self.tracer.warn(
                                "fs",
                                format!("fs.parity_failed:s{si}"),
                                EventCtx::node(members[j].0),
                                format!(
                                    "staged: parity block {ppath} failed: {e} \
                                     (set degraded this generation)"
                                ),
                            ),
                        }
                    }
                }
            }
            // Each member's outbound traffic rides the fabric concurrently
            // with every other member's, pipelined behind the write wave.
            for flist in &files {
                let outbound: u64 = flist.iter().map(|f| f.vbytes).sum();
                if outbound > 0 {
                    slowest = slowest.max(fabric.overlapped_secs(
                        outbound,
                        wave_secs,
                        EXCHANGE_PIPELINE_CHUNK,
                    ));
                }
            }
            records.push(SetRecord {
                scheme: self.redundancy.scheme,
                members: members.clone(),
                files,
                parity: parity_paths,
            });
        }
        if let Some(g) = self.generations.back_mut() {
            g.sets.extend(records);
        }
        out.exchange_secs = slowest;
        log_debug!(
            "fs",
            "staged: {} exchange parked {} of redundancy artifacts in {:.3}s",
            self.redundancy.scheme,
            crate::util::bytes::human(out.parity_bytes),
            out.exchange_secs
        );
        out
    }

    /// Does the current fast-tier copy of `f.path` match the exchange-time
    /// record bit-for-bit? A mismatch means lost (absent) or *stale* (the
    /// path — e.g. the per-job manifest — was rewritten by a later
    /// generation); stale survivors must never feed a rebuild.
    fn fast_matches(&self, f: &ProtectedFile) -> bool {
        match self.fast.peek(&f.path) {
            Some((_, data)) => data.len() as u64 == f.plen && digest128(data) == f.digest,
            None => false,
        }
    }

    /// Restart-time rebuild planner: walk every generation's exchange
    /// records (newest first, so a path rewritten across generations —
    /// the manifest — is restored from the newest record and left alone by
    /// older ones) and restore files *absent* from the fast tier out of
    /// surviving peer data. Partner: fetch the digest-verified copy. XOR:
    /// reconstruct the lost member's concatenation from the survivors +
    /// parity and verify every recovered file's content digest before it
    /// lands. Never touches the durable tier. Rebuilt files re-enter the
    /// drain queue at the back, preserving FIFO order for everything
    /// already queued.
    pub fn rebuild_missing(&mut self, fabric: &Fabric) -> RebuildOutcome {
        let mut out = RebuildOutcome::default();
        let mut rebuilt_nodes: BTreeSet<u32> = BTreeSet::new();
        for gi in (0..self.generations.len()).rev() {
            let records = self.generations[gi].sets.clone();
            for rec in records {
                let m = rec.members.len();
                if m < 2 {
                    continue;
                }
                // A member is a rebuild target when any of its recorded
                // files is absent from the fast tier; a present-but-
                // mismatched file is stale (rewritten later) and is never
                // overwritten.
                let absent: Vec<usize> = (0..m)
                    .filter(|&i| {
                        rec.files[i]
                            .iter()
                            .any(|f| !self.fast.exists(&f.path))
                    })
                    .collect();
                if absent.is_empty() {
                    continue;
                }
                match rec.scheme {
                    RedundancyScheme::None => {}
                    RedundancyScheme::Partner => {
                        for &x in &absent {
                            let mut inbound = 0u64;
                            let mut restored = 0u32;
                            let mut unrecoverable = false;
                            for f in &rec.files[x] {
                                if self.fast.exists(&f.path) {
                                    continue;
                                }
                                let copy_data = f.copy.as_ref().and_then(|c| {
                                    self.fast.peek(c).map(|(_, d)| d.to_vec())
                                });
                                match copy_data {
                                    Some(data) if digest128(&data) == f.digest => {
                                        if self
                                            .fast
                                            .insert_raw(&f.path, f.vbytes, data)
                                            .is_ok()
                                        {
                                            inbound += f.vbytes;
                                            restored += 1;
                                            self.requeue_rebuilt(gi, &f.path, f.vbytes);
                                        }
                                    }
                                    _ => {
                                        // Copy lost with its holder (the
                                        // partner-pair case) or corrupt.
                                        unrecoverable = true;
                                    }
                                }
                            }
                            if restored > 0 {
                                out.rebuilt_files += restored;
                                out.rebuild_secs =
                                    out.rebuild_secs.max(fabric.transfer_secs(inbound));
                                rebuilt_nodes.insert(rec.members[x].0);
                            }
                            if unrecoverable {
                                out.unrecoverable_sets += 1;
                                self.tracer.error(
                                    "fs",
                                    format!(
                                        "fs.rebuild_unrecoverable:n{}",
                                        rec.members[x].0
                                    ),
                                    EventCtx::node(rec.members[x].0),
                                    format!(
                                        "staged: partner-pair loss around node {} — \
                                         falling back across tiers",
                                        rec.members[x].0
                                    ),
                                );
                            }
                        }
                    }
                    RedundancyScheme::Xor => {
                        // >= 2 lost members, any stale/absent survivor
                        // file, or a missing survivor parity block sinks
                        // the whole set record.
                        let survivors_ok = (0..m).all(|i| {
                            absent.contains(&i)
                                || rec.files[i].iter().all(|f| self.fast_matches(f))
                        });
                        let x = absent[0];
                        let parity_ok = (0..m).all(|j| {
                            j == x
                                || (!rec.parity[j].is_empty()
                                    && self.fast.exists(&rec.parity[j]))
                        });
                        if absent.len() >= 2 || !survivors_ok || !parity_ok {
                            out.unrecoverable_sets += 1;
                            self.tracer.error(
                                "fs",
                                format!("fs.rebuild_unrecoverable:n{}", rec.members[x].0),
                                EventCtx::node(rec.members[x].0),
                                format!(
                                    "staged: XOR set unrecoverable ({} lost members, \
                                     survivors_ok={survivors_ok}, parity_ok={parity_ok}) — \
                                     falling back across tiers",
                                    absent.len()
                                ),
                            );
                            continue;
                        }
                        let concats: Vec<Vec<u8>> = (0..m)
                            .map(|i| {
                                if i == x {
                                    return Vec::new();
                                }
                                let mut c = Vec::new();
                                for f in &rec.files[i] {
                                    if let Some((_, d)) = self.fast.peek(&f.path) {
                                        c.extend_from_slice(d);
                                    }
                                }
                                c
                            })
                            .collect();
                        let parities: Vec<Vec<u8>> = (0..m)
                            .map(|j| {
                                if j == x {
                                    return Vec::new();
                                }
                                self.fast
                                    .peek(&rec.parity[j])
                                    .map(|(_, d)| d.to_vec())
                                    .unwrap_or_default()
                            })
                            .collect();
                        let lost_len: u64 = rec.files[x].iter().map(|f| f.plen).sum();
                        let cviews: Vec<&[u8]> =
                            concats.iter().map(|c| c.as_slice()).collect();
                        let pviews: Vec<&[u8]> =
                            parities.iter().map(|p| p.as_slice()).collect();
                        let rebuilt = redundancy::xor_rebuild(x, &cviews, &pviews, lost_len);
                        let mut off = 0usize;
                        let inbound: u64 =
                            concats.iter().map(|c| c.len() as u64).sum::<u64>()
                                + parities.iter().map(|p| p.len() as u64).sum::<u64>();
                        let mut restored = 0u32;
                        for f in &rec.files[x] {
                            let end = off + f.plen as usize;
                            let slice = &rebuilt[off..end];
                            off = end;
                            if self.fast.exists(&f.path) {
                                continue; // stale path rewritten later
                            }
                            if digest128(slice) != f.digest {
                                out.unrecoverable_sets += 1;
                                self.tracer.error(
                                    "fs",
                                    format!("fs.rebuild_verify_failed:{}", f.path),
                                    EventCtx::node(rec.members[x].0),
                                    format!(
                                        "staged: XOR rebuild of {} failed content \
                                         verification — falling back across tiers",
                                        f.path
                                    ),
                                );
                                continue;
                            }
                            if self
                                .fast
                                .insert_raw(&f.path, f.vbytes, slice.to_vec())
                                .is_ok()
                            {
                                restored += 1;
                                self.requeue_rebuilt(gi, &f.path, f.vbytes);
                            }
                        }
                        if restored > 0 {
                            out.rebuilt_files += restored;
                            out.rebuild_secs =
                                out.rebuild_secs.max(fabric.transfer_secs(inbound));
                            rebuilt_nodes.insert(rec.members[x].0);
                        }
                    }
                }
            }
        }
        out.rebuilt_nodes = rebuilt_nodes.len() as u32;
        if out.rebuilt_files > 0 {
            log_info!(
                "fs",
                "staged: rebuilt {} files on {} nodes from peers in {:.3}s",
                out.rebuilt_files,
                out.rebuilt_nodes,
                out.rebuild_secs
            );
        }
        out
    }

    /// Re-claim a just-rebuilt file: back into its generation's path list
    /// and — when no durable copy exists yet — onto the *back* of the
    /// drain queue, so entries already queued keep their FIFO order.
    fn requeue_rebuilt(&mut self, gi: usize, path: &str, vbytes: u64) {
        if let Some(gen) = self.generations.get_mut(gi) {
            if !gen.paths.iter().any(|p| p == path) {
                gen.paths.push(path.to_string());
            }
        }
        if !self.is_durable(path) && !self.queue.iter().any(|i| i.path == path) {
            self.queue.push_back(DrainItem {
                path: path.to_string(),
                remaining: vbytes,
                granularity: DEFAULT_CHUNK_BYTES as u64,
                recipe: None,
                ready_at: f64::INFINITY,
            });
        }
    }

    /// Invalidate a corrupt fast-tier copy for the rest of the restart:
    /// drop the file (and any queued drain of its bytes) so every later
    /// read of the path goes to peer-rebuilt or durable data instead of
    /// re-reading the bad copy per region.
    pub fn mark_fast_invalid(&mut self, path: &str) -> bool {
        if !self.fast.exists(path) {
            return false;
        }
        self.unclaim(path);
        let node = self.owners.get(path).map(|n| n.0);
        let _ = self.fast.delete(path);
        self.tracer.warn(
            "fs",
            format!("fs.fast_invalid:{path}"),
            EventCtx {
                node,
                t: Some(self.clock),
                ..Default::default()
            },
            format!("staged: fast-tier copy of {path} marked invalid"),
        );
        true
    }

    /// Write one wave to the fast tier and queue it for background drain.
    ///
    /// The wave arrives **in rank order** regardless of how many encode
    /// workers produced it (`ckpt::datapath` re-assembles worker outputs
    /// before handing it over), so tier accounting, drain-queue order and
    /// the chunk-index walk below are identical for the serial and
    /// rank-parallel data paths — this method needs no awareness of the
    /// encode fan-out.
    ///
    /// Requests carrying a [`ChunkRecipe`] are referenced into the chunk
    /// index right here: chunks the index already holds are deduped away
    /// (counted in [`StagedIo::deduped_bytes`], shipped in zero seconds);
    /// only first-seen chunks contribute to the queued physical bytes.
    ///
    /// Evicts old drained generations (keeping the newest `keep_fulls`)
    /// when the wave doesn't fit; force-drains undrained evictees first
    /// and reports that time as backpressure. Errors with
    /// [`FsError::InsufficientSpace`] only when eviction cannot help.
    pub fn write_wave(&mut self, reqs: Vec<WriteReq>) -> Result<StagedIo, FsError> {
        if self.generations.is_empty() {
            self.generations.push_back(Generation::default());
        }
        let total: u64 = reqs.iter().map(|r| r.virtual_bytes).sum();
        let mut backpressure = 0.0;
        let mut backpressure_bytes = 0u64;
        let mut evicted_files = 0usize;
        loop {
            // Recomputed every pass: eviction may delete a file this wave
            // replaces, shrinking `replaced` — the loop exit must agree
            // with write_parallel's own capacity check at that instant.
            let replaced: u64 = reqs
                .iter()
                .filter_map(|r| self.fast.virtual_size(&r.path))
                .sum();
            let needed = total.saturating_sub(replaced);
            if self.fast.free_bytes() >= needed {
                break;
            }
            if !self.evict_oldest(&mut backpressure, &mut backpressure_bytes, &mut evicted_files)
            {
                // Failure leaves prior staging state intact; only the
                // just-opened (still empty) generation is rolled back so
                // it doesn't count against keep_fulls.
                if self
                    .generations
                    .back()
                    .is_some_and(|g| g.paths.is_empty())
                {
                    self.generations.pop_back();
                }
                self.tracer.error(
                    "fs",
                    "fs.insufficient_space",
                    EventCtx::default().with_t(self.clock),
                    format!(
                        "staged: insufficient fast-tier space even after eviction: \
                         need {}, free {}",
                        crate::util::bytes::human(needed),
                        crate::util::bytes::human(self.fast.free_bytes())
                    ),
                );
                // Forced drains during the failed eviction pass may have
                // committed recipes — keep the persisted index current.
                self.maybe_persist_index();
                return Err(FsError::InsufficientSpace {
                    needed,
                    free: self.fast.free_bytes(),
                });
            }
        }

        // The wave fits: only now do these paths change hands — stale
        // claims (an older generation's copy, a queued drain of the old
        // version and its chunk references) are dropped and replaced below.
        for r in &reqs {
            self.unclaim(&r.path);
        }
        let mut reqs = reqs;
        let meta: Vec<(String, u64, Option<ChunkRecipe>, NodeId)> = reqs
            .iter_mut()
            .map(|r| (r.path.clone(), r.virtual_bytes, r.recipe.take(), r.node))
            .collect();
        let io = self.fast.write_parallel(reqs)?;

        let mut gen_paths = Vec::with_capacity(meta.len());
        let mut deduped = 0u64;
        let mut cross_job = 0u64;
        // Per-file fast-tier completion offsets: each node lands its own
        // files serially at node bandwidth (the write_parallel model), so
        // file f on node n is on the fast tier at meta_latency + (n's
        // cumulative bytes through f) / per-node bandwidth — the moment
        // the early-admission drain may pick it up.
        let mut node_cum: BTreeMap<NodeId, u64> = BTreeMap::new();
        for (path, virtual_bytes, recipe, node) in meta {
            self.owners.insert(path.clone(), node);
            gen_paths.push(path.clone());
            let (remaining, granularity) = match &recipe {
                Some(rec) => {
                    let out = self.chunks.reference_for(job_of(&path), rec);
                    deduped += out.deduped_vbytes;
                    cross_job += out.cross_job_vbytes;
                    (out.ship_vbytes, rec.chunk_bytes.max(1))
                }
                None => (virtual_bytes, DEFAULT_CHUNK_BYTES as u64),
            };
            let ready_at = if self.early_admission {
                let cum = node_cum.entry(node).or_insert(0);
                *cum += virtual_bytes;
                let off = match self.fast.cfg.kind {
                    FsKind::BurstBuffer => {
                        self.fast.cfg.meta_latency
                            + *cum as f64 / self.fast.cfg.per_node_write_bw
                    }
                    // A pool-limited fast tier models one aggregate wave —
                    // no per-file completion to admit against.
                    FsKind::Lustre => io.duration,
                };
                // Wave-relative stamp (<= 0, offset from the wave's end);
                // `admit_wave` resolves it once the caller has placed the
                // wave on the virtual timeline.
                off.min(io.duration) - io.duration
            } else {
                f64::INFINITY
            };
            self.queue.push_back(DrainItem {
                path,
                remaining,
                granularity,
                recipe,
                ready_at,
            });
        }
        self.generations
            .back_mut()
            .expect("current generation exists")
            .paths
            .extend(gen_paths);
        self.stats.deduped_bytes += deduped;
        self.stats.cross_job_deduped_bytes += cross_job;
        let pending = self.pending_bytes();
        log_debug!(
            "fs",
            "staged: wave of {} landed on {} in {:.2}s; {} queued for drain, {} deduped",
            crate::util::bytes::human(total),
            self.fast.cfg.kind,
            io.duration,
            crate::util::bytes::human(pending),
            crate::util::bytes::human(deduped)
        );
        self.maybe_persist_index();
        Ok(StagedIo {
            fast_secs: io.duration,
            fast_bytes: total,
            backpressure_secs: backpressure,
            durable_bytes: backpressure_bytes,
            deduped_bytes: deduped,
            evicted_files,
            pending_bytes: pending,
            writers: io.writers,
        })
    }

    /// [`TieredStore::write_wave`] for a wave collected in encode
    /// *completion* order (the pipelined checkpoint path): each request is
    /// tagged with its rank index, and the wave is restored to rank order
    /// here before the ordered contract runs. This keeps tier accounting,
    /// drain-queue order and the chunk-index walk byte-identical to the
    /// serial path no matter which rank's encode finished first — the
    /// ordered-wave contract is preserved at the manifest level, not by
    /// constraining the transport.
    pub fn write_wave_unordered(
        &mut self,
        mut tagged: Vec<(usize, WriteReq)>,
    ) -> Result<StagedIo, FsError> {
        tagged.sort_by_key(|(i, _)| *i);
        self.write_wave(tagged.into_iter().map(|(_, r)| r).collect())
    }

    /// Advance the background drain to virtual time `now`: node-local
    /// agents move queued physical bytes to the durable tier at chunk
    /// granularity. Fully-deduped items commit in zero simulated seconds.
    pub fn drain_to(&mut self, now_secs: f64) -> DrainTick {
        // Scheduled node losses fire before the tick's drain work, so a
        // loss landing mid-drain kills the victim's still-queued items —
        // the partially-drained-generation case.
        self.apply_due_losses(now_secs);
        let budget = (now_secs - self.clock).max(0.0);
        let tick_t0 = self.clock.min(now_secs);
        self.clock = self.clock.max(now_secs);
        if self.queue.is_empty() {
            self.credit.clear();
            self.maybe_persist_index(); // retry a previously failed persist
            self.sample_drain_gauges(now_secs);
            return DrainTick {
                queue_empty: true,
                ..DrainTick::default()
            };
        }
        let bw = self.drain_bandwidth();
        // Weighted fair share of the BB→PFS link: the tick's byte budget
        // splits across the jobs with queued work by drain weight. A lone
        // job's share is exactly 1.0, so single-tenant arithmetic is
        // bit-identical to an unshared link.
        let jobs: BTreeSet<String> = self
            .queue
            .iter()
            .map(|i| job_of(&i.path).to_string())
            .collect();
        let total_w: f64 = jobs.iter().map(|j| self.drain_weight(j)).sum();
        for job in &jobs {
            let share = if total_w > 0.0 {
                self.drain_weight(job) / total_w
            } else {
                1.0 / jobs.len() as f64
            };
            *self.credit.entry(job.clone()).or_insert(0.0) += budget * bw * share;
        }
        let mut tick = DrainTick::default();
        let mut failed: Vec<DrainItem> = Vec::new();
        // Per-job FIFO service: a job whose head-of-line item stalls
        // (out of credit mid-file) stops draining for the tick, but the
        // scan continues so other tenants' queued items still progress.
        let mut stalled: BTreeSet<String> = BTreeSet::new();
        let mut idx = 0;
        while idx < self.queue.len() {
            let job = job_of(&self.queue[idx].path).to_string();
            if stalled.contains(&job) {
                idx += 1;
                continue;
            }
            let item = &mut self.queue[idx];
            // (Zero-byte items — a fully-deduped generation, or a clean
            // incremental rank — skip straight to completion below.)
            if item.remaining > 0 {
                let credit = self.credit.entry(job.clone()).or_insert(0.0);
                let whole = item.remaining as f64;
                let take = if *credit >= whole {
                    whole
                } else {
                    // Partial drains stop on a chunk boundary.
                    let g = item.granularity.max(1) as f64;
                    (*credit / g).floor() * g
                };
                if take <= 0.0 {
                    stalled.insert(job);
                    idx += 1;
                    continue;
                }
                item.remaining -= take as u64;
                *credit -= take;
                tick.drained_bytes += take as u64;
            }
            if self.queue[idx].remaining == 0 {
                let done = self.queue.remove(idx).expect("index valid");
                if self.complete_drain(&done) {
                    tick.completed_files += 1;
                } else {
                    // Staging failed (durable-tier shortfall): keep the
                    // item queued so a later tick retries it, but set it
                    // aside for this tick to avoid a hot retry loop.
                    failed.push(done);
                }
            } else {
                stalled.insert(job);
                idx += 1;
            }
        }
        self.queue.extend(failed);
        self.stats.drained_bytes += tick.drained_bytes;
        self.stats.busy_secs += tick.drained_bytes as f64 / bw;
        tick.queue_empty = self.queue.is_empty();
        if tick.queue_empty {
            self.credit.clear();
            if tick.completed_files > 0 {
                log_info!(
                    "fs",
                    "staged: drain queue empty at t={now_secs:.2}s — all images durable"
                );
            }
        }
        self.maybe_persist_index();
        if tick.drained_bytes > 0 || tick.completed_files > 0 {
            let _ = self.tracer.record(
                Span::new("drain.tick", Lane::Drain, tick_t0, now_secs.max(tick_t0))
                    .attr("drained_bytes", tick.drained_bytes)
                    .attr("completed_files", tick.completed_files),
            );
        }
        self.sample_drain_gauges(now_secs);
        tick
    }

    /// Sample the drain-backlog time series for the trace (no-ops unless
    /// span recording is on).
    fn sample_drain_gauges(&self, t: f64) {
        self.tracer
            .counter("drain.backlog_bytes", t, self.pending_bytes() as f64);
        self.tracer
            .counter("drain.queue_depth", t, self.queue.len() as f64);
    }

    /// Drain everything now; returns the durable-tier busy seconds.
    /// Deduped chunks cost nothing. Items whose staging fails
    /// (pathological durable-tier shortfall) stay queued for retry and
    /// are not counted as drained.
    pub fn drain_sync(&mut self) -> f64 {
        let bw = self.drain_bandwidth();
        let mut secs = 0.0;
        let mut synced = 0u64;
        let mut failed = Vec::new();
        while let Some(item) = self.queue.pop_front() {
            if !self.complete_drain(&item) {
                failed.push(item);
                continue;
            }
            secs += item.remaining as f64 / bw;
            synced += item.remaining;
            self.stats.drained_bytes += item.remaining;
        }
        self.queue.extend(failed);
        self.credit.clear();
        self.stats.busy_secs += secs;
        self.maybe_persist_index();
        if secs > 0.0 {
            let _ = self.tracer.record(
                Span::new("drain.sync", Lane::Drain, self.clock, self.clock + secs)
                    .attr("drained_bytes", synced),
            );
        }
        self.sample_drain_gauges(self.clock + secs);
        secs
    }

    /// Commit one fully-transferred file to the durable tier. Recipe-less
    /// files are copied byte-for-byte; recipe-backed files materialize
    /// their not-yet-stored chunk objects (content digest recorded for
    /// restart verification) and commit the recipe, releasing the one it
    /// replaces. Returns whether a durable copy now exists.
    fn complete_drain(&mut self, item: &DrainItem) -> bool {
        let Some((virtual_bytes, data)) = self.fast.peek(&item.path) else {
            self.tracer.warn(
                "fs",
                format!("fs.drain_lost_source:{}", item.path),
                EventCtx::default().with_t(self.clock),
                format!("staged: drain source {} vanished — skipped", item.path),
            );
            self.stats.drain_errors += 1;
            return false;
        };
        let data = data.to_vec();
        match &item.recipe {
            None => match self.durable.insert_raw(&item.path, virtual_bytes, data) {
                Ok(()) => {
                    // A path has exactly one durable representation: a
                    // plain copy supersedes any stale committed recipe
                    // (whose chunk references would otherwise leak).
                    if let Some(old) = self.chunks.remove_recipe(&item.path) {
                        self.release_and_gc(job_of(&item.path), &old);
                    }
                    self.stats.drained_files += 1;
                    true
                }
                Err(e) => {
                    self.tracer.warn(
                        "fs",
                        format!("fs.drain_error:{}", item.path),
                        EventCtx::default().with_t(self.clock),
                        format!("staged: drain of {} failed: {e}", item.path),
                    );
                    self.stats.drain_errors += 1;
                    false
                }
            },
            Some(rec) => {
                for c in &rec.chunks {
                    if self.chunks.is_stored(c.digest) {
                        continue;
                    }
                    let bytes =
                        data[c.real_off as usize..(c.real_off + c.real_len) as usize].to_vec();
                    let content = digest128(&bytes);
                    if let Err(e) =
                        self.durable
                            .insert_raw(&object_path(c.digest), c.vbytes, bytes)
                    {
                        self.tracer.warn(
                            "fs",
                            format!("fs.drain_error:{}", item.path),
                            EventCtx::default().with_t(self.clock),
                            format!("staged: chunk store object for {} failed: {e}", item.path),
                        );
                        self.stats.drain_errors += 1;
                        return false;
                    }
                    self.chunks.mark_stored(c.digest, content);
                }
                self.index_dirty = true;
                if let Some(old) = self.chunks.commit(&item.path, rec.clone()) {
                    self.release_and_gc(job_of(&item.path), &old);
                }
                // The recipe supersedes any stale plain durable copy.
                // Persist the index naming it BEFORE dropping that copy,
                // so a kill between the two never leaves the path without
                // a durable representation; if the persist fails, the
                // superseded copy is kept (recipe-first reads shadow it).
                if self.durable.exists(&item.path) {
                    self.maybe_persist_index();
                    if self.index_dirty {
                        self.tracer.warn(
                            "fs",
                            format!("fs.superseded_kept:{}", item.path),
                            EventCtx::default().with_t(self.clock),
                            format!(
                                "staged: keeping superseded plain copy of {} until the \
                                 chunk index persists",
                                item.path
                            ),
                        );
                    } else {
                        let _ = self.durable.delete(&item.path);
                    }
                }
                self.stats.drained_files += 1;
                true
            }
        }
    }

    /// Drop one reference per chunk occurrence of `recipe`; chunk objects
    /// whose refcount hit zero are deleted from the durable tier — but
    /// only once an index that no longer names them has persisted. A
    /// stale persisted index must never name a missing object (reload
    /// would report corruption); on a failed persist the objects are kept
    /// and reclaimed by a later reload's orphan sweep.
    fn release_and_gc(&mut self, job: &str, recipe: &ChunkRecipe) {
        self.index_dirty = true;
        let dead = self.chunks.release_for(job, recipe);
        if dead.iter().any(|d| d.stored) {
            self.maybe_persist_index();
        }
        let persisted = !self.index_dirty;
        for dead in dead {
            self.stats.gc_chunks += 1;
            if dead.stored && persisted {
                self.stats.gc_bytes += dead.vbytes;
                let _ = self.durable.delete(&object_path(dead.digest));
            }
        }
    }

    /// Force-drain one queued path immediately (eviction backpressure).
    /// Returns the synchronous (seconds, bytes) charged — zero when the
    /// staging failed (the item is re-queued for a later retry rather
    /// than reported as durable).
    fn drain_path_now(&mut self, path: &str) -> (f64, u64) {
        let Some(pos) = self.queue.iter().position(|i| i.path == path) else {
            return (0.0, 0);
        };
        let item = self.queue.remove(pos).expect("position valid");
        if !self.complete_drain(&item) {
            self.queue.push_back(item);
            return (0.0, 0);
        }
        let secs = item.remaining as f64 / self.drain_bandwidth();
        self.stats.drained_bytes += item.remaining;
        self.stats.busy_secs += secs;
        self.stats.forced_secs += secs;
        (secs, item.remaining)
    }

    /// Evict the oldest generation beyond `keep_fulls` from the fast tier.
    /// Undrained files are force-drained first, and a file is deleted from
    /// the fast tier only once a durable copy (plain or recipe-backed)
    /// actually exists — the engine never drops the only copy of an image.
    /// Eviction never touches the chunk index: durable recipes keep their
    /// references. Returns false when nothing is evictable.
    fn evict_oldest(
        &mut self,
        backpressure: &mut f64,
        backpressure_bytes: &mut u64,
        evicted_files: &mut usize,
    ) -> bool {
        if self.generations.len() <= self.keep_fulls {
            return false;
        }
        let gen = self.generations.pop_front().expect("non-empty");
        for path in &gen.paths {
            let (secs, bytes) = self.drain_path_now(path);
            *backpressure += secs;
            *backpressure_bytes += bytes;
        }
        let mut deleted = 0usize;
        let mut kept = Vec::new();
        // A recipe-backed path is restart-reachable only through the
        // *persisted* index: retry a pending persist before trusting it.
        self.maybe_persist_index();
        for path in &gen.paths {
            let recipe_unpersisted = self.index_dirty
                && self.chunks.recipe(path).is_some()
                && !self.durable.exists(path);
            if !self.is_durable(path) || recipe_unpersisted {
                // Forced drain failed (durable tier full / source gone),
                // or the recipe exists only in the unpersisted in-memory
                // index: keep the fast copy rather than drop the only
                // restart-reachable one.
                self.tracer.warn(
                    "fs",
                    format!("fs.evictee_kept:{path}"),
                    EventCtx::default().with_t(self.clock),
                    format!(
                        "staged: evictee {path} has no durable copy — kept on the fast tier"
                    ),
                );
                kept.push(path.clone());
                continue;
            }
            if self.fast.delete(path).is_ok() {
                deleted += 1;
            }
        }
        *evicted_files += deleted;
        self.stats.evicted_files += deleted as u64;
        if !kept.is_empty() {
            // Keep the survivors claimed (still the oldest generation) so
            // a later pass can evict them once their drain succeeds; the
            // redundancy records ride along (their files may still need a
            // peer rebuild before the drain can finish).
            self.generations.push_front(Generation {
                paths: kept,
                sets: gen.sets,
            });
        } else {
            // Generation fully retired: its redundancy artifacts (partner
            // copies, parity blocks) protect nothing any more — free the
            // fast-tier space.
            for rec in &gen.sets {
                for p in rec
                    .parity
                    .iter()
                    .filter(|p| !p.is_empty())
                    .chain(rec.files.iter().flatten().filter_map(|f| f.copy.as_ref()))
                {
                    let _ = self.fast.delete(p);
                    self.owners.remove(p);
                }
            }
            self.stats.evicted_generations += 1;
        }
        log_info!(
            "fs",
            "staged: evicted generation ({deleted} files) from the fast tier \
             (durable copies retained){}",
            if *backpressure > 0.0 {
                format!(", {backpressure:.2}s forced-drain backpressure")
            } else {
                String::new()
            }
        );
        // Progress = space was freed, or an already-empty generation was
        // retired; a generation that could not be freed at all ends the
        // caller's eviction loop (no progress is possible right now).
        deleted > 0 || gen.paths.is_empty()
    }

    /// Drop every claim on `path`: older generations' lists, any queued
    /// drain of a stale version, and the stale version's chunk references.
    fn unclaim(&mut self, path: &str) {
        for gen in &mut self.generations {
            gen.paths.retain(|p| p != path);
        }
        let queue = std::mem::take(&mut self.queue);
        for item in queue {
            if item.path == path {
                if let Some(rec) = &item.recipe {
                    self.release_and_gc(job_of(&item.path), rec);
                }
            } else {
                self.queue.push_back(item);
            }
        }
    }

    // ------------------------------------------------- namespace ops

    /// Is a durable copy of `path` restorable — a plain durable file, or
    /// a committed recipe the chunk store can reassemble?
    pub fn is_durable(&self, path: &str) -> bool {
        self.durable.exists(path) || self.chunks.recipe(path).is_some()
    }

    /// Rebuild a recipe-backed file from its durable chunk objects,
    /// verifying each object's recorded content digest. Returns the
    /// byte-identical encoded file plus its logical virtual bytes.
    fn reassemble(&self, path: &str) -> Result<(Vec<u8>, u64), FsError> {
        let rec = self
            .chunks
            .recipe(path)
            .ok_or_else(|| FsError::NotFound(path.to_string()))?;
        let mut out = Vec::with_capacity(rec.real_bytes() as usize);
        for c in &rec.chunks {
            if c.real_len == 0 {
                continue;
            }
            let entry = self
                .chunks
                .entry(c.digest)
                .filter(|e| e.stored)
                .ok_or_else(|| {
                    FsError::Corrupt(format!("{path}: chunk {:032x} not durable", c.digest))
                })?;
            let opath = object_path(c.digest);
            let Some((_, bytes)) = self.durable.peek(&opath) else {
                return Err(FsError::Corrupt(format!("{path}: object {opath} missing")));
            };
            if bytes.len() as u64 != c.real_len || digest128(bytes) != entry.content {
                return Err(FsError::Corrupt(format!(
                    "{path}: object {opath} content digest mismatch"
                )));
            }
            out.extend_from_slice(bytes);
        }
        Ok((out, rec.file_vbytes))
    }

    /// Read a wave preferring the fast tier per file, falling back to the
    /// durable tier (plain files and recipe reassembly alike); the tier
    /// waves proceed in parallel.
    pub fn read_preferred(
        &self,
        paths: &[(NodeId, String)],
    ) -> Result<(Vec<Vec<u8>>, IoReport), FsError> {
        let mut fast_wave = Vec::new();
        let mut durable_wave = Vec::new();
        for (i, (node, path)) in paths.iter().enumerate() {
            if self.fast.exists(path) {
                fast_wave.push((i, (*node, path.clone())));
            } else {
                durable_wave.push((i, (*node, path.clone())));
            }
        }
        let mut datas: Vec<Vec<u8>> = vec![Vec::new(); paths.len()];
        let mut duration = 0.0f64;
        let mut total = 0u64;
        read_scattered(
            fast_wave,
            |r| self.fast.read_parallel(r),
            &mut datas,
            &mut duration,
            &mut total,
        )?;
        read_scattered(
            durable_wave,
            |r| self.read_durable(r),
            &mut datas,
            &mut duration,
            &mut total,
        )?;
        Ok((
            datas,
            IoReport {
                duration,
                total_virtual_bytes: total,
                writers: paths.len(),
            },
        ))
    }

    /// Read a wave from the durable tier only (CRC-fallback and
    /// fast-tier-loss paths). Plain durable files read directly;
    /// recipe-backed files are reassembled from their chunk objects with
    /// per-object content-digest verification.
    pub fn read_durable(
        &self,
        paths: &[(NodeId, String)],
    ) -> Result<(Vec<Vec<u8>>, IoReport), FsError> {
        let mut plain = Vec::new();
        let mut recipes = Vec::new();
        for (i, (node, path)) in paths.iter().enumerate() {
            // The committed recipe is authoritative: a plain copy that
            // coexists with one is a superseded leftover whose delete was
            // deferred (chunk-index persist pending) — never serve it.
            if self.chunks.recipe(path).is_some() {
                recipes.push((i, *node, path.clone()));
            } else {
                plain.push((i, (*node, path.clone())));
            }
        }
        let mut datas: Vec<Vec<u8>> = vec![Vec::new(); paths.len()];
        let mut duration = 0.0f64;
        let mut total = 0u64;
        read_scattered(
            plain,
            |r| self.durable.read_parallel(r),
            &mut datas,
            &mut duration,
            &mut total,
        )?;
        if !recipes.is_empty() {
            let mut vbytes = 0u64;
            let mut nodes: Vec<u32> = recipes.iter().map(|(_, n, _)| n.0).collect();
            nodes.sort_unstable();
            nodes.dedup();
            for (i, _, path) in &recipes {
                let (bytes, vb) = self.reassemble(path)?;
                datas[*i] = bytes;
                vbytes += vb;
            }
            // Reassembly reads the recipe's chunk objects — charged like
            // a durable-tier read wave of the same logical size.
            let bw = self
                .durable
                .read_bandwidth(recipes.len(), nodes.len().max(1) as u32);
            duration = duration.max(vbytes as f64 / bw + self.durable.cfg.meta_latency);
            total += vbytes;
        }
        Ok((
            datas,
            IoReport {
                duration,
                total_virtual_bytes: total,
                writers: paths.len(),
            },
        ))
    }

    pub fn exists(&self, path: &str) -> bool {
        self.fast.exists(path) || self.is_durable(path)
    }

    pub fn delete(&mut self, path: &str) -> Result<(), FsError> {
        self.unclaim(path);
        self.owners.remove(path);
        let fast = self.fast.delete(path).is_ok();
        let durable = self.durable.delete(path).is_ok();
        let recipe = match self.chunks.remove_recipe(path) {
            Some(old) => {
                self.release_and_gc(job_of(path), &old);
                true
            }
            None => false,
        };
        self.maybe_persist_index();
        if fast || durable || recipe {
            Ok(())
        } else {
            Err(FsError::NotFound(path.to_string()))
        }
    }

    /// Fast-tier occupancy (the operationally scarce resource).
    pub fn used_bytes(&self) -> u64 {
        self.fast.used_bytes()
    }

    pub fn free_bytes(&self) -> u64 {
        self.fast.free_bytes()
    }

    /// Distinct logical paths across both tiers (chunk objects are
    /// internal and excluded; recipe-backed durable files count).
    pub fn file_count(&self) -> usize {
        let mut paths = self.fast.paths();
        paths.extend(
            self.durable
                .paths()
                .into_iter()
                .filter(|p| !p.starts_with(OBJECT_PREFIX)),
        );
        paths.extend(self.chunks.recipe_paths());
        paths.sort_unstable();
        paths.dedup();
        paths.len()
    }

    /// Corrupt the fast-tier copy if present, else the durable copy.
    pub fn corrupt_byte(&mut self, path: &str, offset: usize) -> bool {
        self.fast.corrupt_byte(path, offset) || self.durable.corrupt_byte(path, offset)
    }

    pub fn describe(&self) -> String {
        let red = if self.redundancy.active() {
            format!(
                ", {}/{} redundancy",
                self.redundancy.scheme, self.redundancy.set_size
            )
        } else {
            String::new()
        };
        format!(
            "staged({} → {}, {} pending, {} unique chunks, {:.0}% deduped{red})",
            self.fast.cfg.kind,
            self.durable.cfg.kind,
            crate::util::bytes::human(self.pending_bytes()),
            self.chunks.chunk_count(),
            self.stats.dedup_ratio() * 100.0
        )
    }
}

/// Read one sub-wave through `read` and scatter the results back into
/// request order, folding the wave's time/bytes into the caller's totals
/// (the sub-waves of one logical wave proceed in parallel, so durations
/// max rather than add).
fn read_scattered(
    wave: Vec<(usize, (NodeId, String))>,
    read: impl FnOnce(&[(NodeId, String)]) -> Result<(Vec<Vec<u8>>, IoReport), FsError>,
    datas: &mut [Vec<u8>],
    duration: &mut f64,
    total: &mut u64,
) -> Result<(), FsError> {
    if wave.is_empty() {
        return Ok(());
    }
    let reqs: Vec<(NodeId, String)> = wave.iter().map(|(_, np)| np.clone()).collect();
    let (wave_datas, io) = read(&reqs)?;
    for ((i, _), d) in wave.into_iter().zip(wave_datas) {
        datas[i] = d;
    }
    *duration = duration.max(io.duration);
    *total += io.total_virtual_bytes;
    Ok(())
}

impl StorageTier for TieredStore {
    fn write_parallel(&mut self, reqs: Vec<WriteReq>) -> Result<IoReport, FsError> {
        self.write_wave(reqs).map(|s| s.io())
    }
    fn read_parallel(
        &self,
        paths: &[(NodeId, String)],
    ) -> Result<(Vec<Vec<u8>>, IoReport), FsError> {
        self.read_preferred(paths)
    }
    fn exists(&self, path: &str) -> bool {
        TieredStore::exists(self, path)
    }
    fn delete(&mut self, path: &str) -> Result<(), FsError> {
        TieredStore::delete(self, path)
    }
    fn free_bytes(&self) -> u64 {
        TieredStore::free_bytes(self)
    }
    fn used_bytes(&self) -> u64 {
        TieredStore::used_bytes(self)
    }
    fn file_count(&self) -> usize {
        TieredStore::file_count(self)
    }
    fn corrupt_byte(&mut self, path: &str, offset: usize) -> bool {
        TieredStore::corrupt_byte(self, path, offset)
    }
    fn describe(&self) -> String {
        TieredStore::describe(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fs::FsConfig;

    const MIB: u64 = 1 << 20;
    /// Recipe chunk size used by the dedup tests (tiny, to exercise many
    /// chunks per file cheaply).
    const CHUNK: usize = 1 << 10;

    fn store(fast_cap: u64, keep: usize) -> TieredStore {
        let mut bb = FsConfig::burst_buffer(2);
        bb.capacity = fast_cap;
        TieredStore::new(
            FileSystem::new(bb),
            FileSystem::new(FsConfig::cscratch()),
            keep,
            2,
        )
    }

    fn wave(tag: &str, files: u32, bytes_each: u64) -> Vec<WriteReq> {
        (0..files)
            .map(|i| WriteReq {
                node: NodeId(i % 2),
                path: format!("{tag}/f{i}"),
                virtual_bytes: bytes_each,
                data: vec![i as u8; 8],
                recipe: None,
            })
            .collect()
    }

    /// Deterministic avalanche-quality bytes (a SplitMix64 stream seeded
    /// by `tag`): every chunk-sized window is distinct, which the dedup
    /// arithmetic these tests assert depends on.
    fn patterned(len: usize, tag: u8) -> Vec<u8> {
        let mut sm = crate::util::prng::SplitMix64::new(tag as u64);
        let mut out = Vec::with_capacity(len + 8);
        while out.len() < len {
            out.extend_from_slice(&sm.next_u64().to_le_bytes());
        }
        out.truncate(len);
        out
    }

    /// A request whose recipe addresses `data` in `CHUNK`-byte chunks,
    /// with virtual bytes equal to the data length (1 vbyte per byte).
    fn recipe_req(node: u32, path: &str, data: &[u8]) -> WriteReq {
        WriteReq {
            node: NodeId(node),
            path: path.into(),
            virtual_bytes: data.len() as u64,
            data: data.to_vec(),
            recipe: Some(ChunkRecipe::from_data(data, CHUNK, data.len() as u64)),
        }
    }

    #[test]
    fn unordered_wave_is_indistinguishable_from_rank_order() {
        // Completion-order delivery (pipelined path) must leave tier
        // accounting, stored bytes and drain-queue order identical to the
        // rank-ordered wave.
        let mut a = store(1024 * MIB, 2);
        a.begin_ckpt(0.0);
        let io_ordered = a.write_wave(wave("g0", 6, 16 * MIB)).unwrap();

        let mut b = store(1024 * MIB, 2);
        b.begin_ckpt(0.0);
        let mut tagged: Vec<(usize, WriteReq)> =
            wave("g0", 6, 16 * MIB).into_iter().enumerate().collect();
        tagged.reverse();
        tagged.swap(1, 4); // scrambled completion order
        let io_unordered = b.write_wave_unordered(tagged).unwrap();

        assert_eq!(io_ordered.fast_secs, io_unordered.fast_secs);
        assert_eq!(io_ordered.fast_bytes, io_unordered.fast_bytes);
        assert_eq!(io_ordered.pending_bytes, io_unordered.pending_bytes);
        let paths = |ts: &TieredStore| -> Vec<String> {
            ts.queue.iter().map(|i| i.path.clone()).collect::<Vec<_>>()
        };
        assert_eq!(paths(&a), paths(&b), "drain queue must be rank-ordered");
        for i in 0..6u32 {
            let p = format!("g0/f{i}");
            assert_eq!(
                a.fast().peek(&p).unwrap(),
                b.fast().peek(&p).unwrap(),
                "stored bytes must match for {p}"
            );
        }
    }

    #[test]
    fn checkpoint_completes_on_fast_tier_and_drains_later() {
        let mut ts = store(1024 * MIB, 2);
        ts.begin_ckpt(0.0);
        let io = ts.write_wave(wave("g0", 4, 64 * MIB)).unwrap();
        assert!(io.fast_secs > 0.0);
        assert_eq!(io.backpressure_secs, 0.0);
        assert_eq!(io.pending_bytes, 4 * 64 * MIB);
        // Nothing durable yet.
        assert_eq!(ts.durable().file_count(), 0);
        assert!(ts.fast().exists("g0/f0"));
        // Generous clock advance drains everything.
        let tick = ts.drain_to(1000.0);
        assert!(tick.queue_empty);
        assert_eq!(tick.completed_files, 4);
        assert_eq!(ts.durable().file_count(), 4);
        assert_eq!(ts.pending_bytes(), 0);
        // Fast copies stay resident (within keep_fulls).
        assert!(ts.fast().exists("g0/f0"));
    }

    #[test]
    fn drain_progresses_incrementally_on_the_clock() {
        let mut ts = store(1024 * MIB, 2);
        ts.begin_ckpt(0.0);
        ts.write_wave(wave("g0", 1, 512 * MIB)).unwrap();
        let bw = ts.drain_bandwidth();
        let half = 256.0 * MIB as f64 / bw;
        let tick = ts.drain_to(half);
        assert!(!tick.queue_empty, "half the budget must not finish");
        assert!(tick.drained_bytes > 0);
        // Chunk-granular progress (recipe-less items use the default).
        assert_eq!(tick.drained_bytes % DEFAULT_CHUNK_BYTES as u64, 0);
        let tick2 = ts.drain_to(half * 2.5);
        assert!(tick2.queue_empty, "full budget finishes the drain");
        assert!(ts.durable().exists("g0/f0"));
    }

    #[test]
    fn eviction_keeps_last_n_fulls_on_fast_tier() {
        // Fast tier fits two 4x64 MiB generations, not three.
        let mut ts = store(600 * MIB, 2);
        for g in 0..3u32 {
            ts.begin_ckpt(g as f64 * 10.0);
            ts.write_wave(wave(&format!("g{g}"), 4, 64 * MIB)).unwrap();
            ts.drain_to(g as f64 * 10.0 + 1000.0); // fully drained between ckpts
        }
        // g0 evicted from fast, still durable; g1/g2 resident.
        assert!(!ts.fast().exists("g0/f0"), "oldest gen evicted from BB");
        assert!(ts.durable().exists("g0/f0"), "durable copy retained");
        assert!(ts.fast().exists("g1/f0"));
        assert!(ts.fast().exists("g2/f0"));
        assert_eq!(ts.stats.evicted_generations, 1);
        assert_eq!(ts.stats.forced_secs, 0.0, "drained evictee costs nothing");
    }

    #[test]
    fn undrained_eviction_charges_backpressure() {
        let mut ts = store(600 * MIB, 1); // keep only the current gen
        ts.begin_ckpt(0.0);
        ts.write_wave(wave("g0", 4, 64 * MIB)).unwrap();
        // No drain time elapses before the next checkpoint.
        ts.begin_ckpt(0.0);
        let io = ts.write_wave(wave("g1", 4, 120 * MIB)).unwrap();
        assert!(
            io.backpressure_secs > 0.0,
            "evicting an undrained gen must force-drain it synchronously"
        );
        assert_eq!(
            io.durable_bytes,
            4 * 64 * MIB,
            "backpressure bytes must be reported per tier"
        );
        assert!(ts.durable().exists("g0/f0"), "forced drain made g0 durable");
        assert!(!ts.fast().exists("g0/f0"));
        assert!(ts.stats.forced_secs > 0.0);
    }

    #[test]
    fn failed_wave_leaves_staging_state_intact() {
        let mut ts = store(600 * MIB, 2);
        ts.begin_ckpt(0.0);
        ts.write_wave(wave("g0", 4, 64 * MIB)).unwrap();
        let pending_before = ts.pending_bytes();
        // A wave that cannot fit even after eviction must not disturb the
        // queued drain or the existing generation bookkeeping.
        ts.begin_ckpt(1.0);
        let err = ts.write_wave(wave("g1", 4, 200 * MIB)).unwrap_err();
        assert!(matches!(err, FsError::InsufficientSpace { .. }));
        assert_eq!(ts.pending_bytes(), pending_before, "queue untouched");
        assert!(ts.fast().exists("g0/f0"));
        // The empty just-opened generation was rolled back: a later
        // eviction pass still sees exactly one (real) generation.
        ts.begin_ckpt(2.0);
        ts.write_wave(wave("g2", 4, 64 * MIB)).unwrap();
        assert!(ts.fast().exists("g0/f0"), "g0 still within keep_fulls");
    }

    #[test]
    fn restart_rebase_resumes_a_stalled_drain() {
        let mut ts = store(1024 * MIB, 2);
        ts.begin_ckpt(100.0); // killed job's timeline
        ts.write_wave(wave("g0", 2, 64 * MIB)).unwrap();
        ts.sync_clock(130.0);
        // Restarted job's clock starts near zero: without a rebase this
        // tick would get zero budget.
        ts.rebase_clock(2.0);
        let tick = ts.drain_to(1000.0);
        assert!(tick.queue_empty, "rebased drain must make progress");
        assert!(ts.durable().exists("g0/f0"));
    }

    #[test]
    fn insufficient_space_when_eviction_cannot_help() {
        let mut ts = store(100 * MIB, 2);
        ts.begin_ckpt(0.0);
        let err = ts.write_wave(wave("g0", 4, 64 * MIB)).unwrap_err();
        assert!(matches!(err, FsError::InsufficientSpace { .. }));
        assert_eq!(ts.fast().used_bytes(), 0, "nothing written on failure");
        assert_eq!(ts.pending_bytes(), 0);
    }

    #[test]
    fn overwrite_dedupes_queue_and_generation_claims() {
        let mut ts = store(1024 * MIB, 2);
        ts.begin_ckpt(0.0);
        ts.write_wave(wave("same", 2, 32 * MIB)).unwrap();
        ts.begin_ckpt(0.0);
        ts.write_wave(wave("same", 2, 32 * MIB)).unwrap();
        // The rewritten paths are claimed once, queued once.
        assert_eq!(ts.pending_files(), 2);
        assert_eq!(ts.pending_bytes(), 2 * 32 * MIB);
        let tick = ts.drain_to(1000.0);
        assert!(tick.queue_empty);
        assert_eq!(ts.durable().file_count(), 2);
    }

    #[test]
    fn read_preferred_falls_back_to_durable_per_file() {
        let mut ts = store(1024 * MIB, 2);
        ts.begin_ckpt(0.0);
        ts.write_wave(wave("g0", 2, 16 * MIB)).unwrap();
        ts.drain_sync();
        // Drop one file from the fast tier only.
        ts.fast_mut().delete("g0/f1").unwrap();
        let paths = vec![
            (NodeId(0), "g0/f0".to_string()),
            (NodeId(1), "g0/f1".to_string()),
        ];
        let (datas, io) = ts.read_preferred(&paths).unwrap();
        assert_eq!(datas[0], vec![0u8; 8]);
        assert_eq!(datas[1], vec![1u8; 8]);
        assert!(io.duration > 0.0);
        assert_eq!(io.total_virtual_bytes, 2 * 16 * MIB);
    }

    #[test]
    fn drain_sync_moves_everything_and_reports_busy_secs() {
        let mut ts = store(1024 * MIB, 2);
        ts.begin_ckpt(0.0);
        ts.write_wave(wave("g0", 3, 32 * MIB)).unwrap();
        let secs = ts.drain_sync();
        assert!(secs > 0.0);
        assert_eq!(ts.pending_bytes(), 0);
        assert_eq!(ts.durable().file_count(), 3);
        assert_eq!(ts.stats.drained_files, 3);
    }

    #[test]
    fn delete_unclaims_everywhere() {
        let mut ts = store(1024 * MIB, 2);
        ts.begin_ckpt(0.0);
        ts.write_wave(wave("g0", 2, 16 * MIB)).unwrap();
        ts.delete("g0/f0").unwrap();
        assert!(!ts.exists("g0/f0"));
        assert_eq!(ts.pending_files(), 1, "queued drain dropped with the file");
        assert!(ts.delete("nope").is_err());
    }

    // ------------------------------------------------- chunk dedup

    #[test]
    fn second_generation_drains_only_dirty_chunks() {
        let mut ts = store(1024 * MIB, 4);
        let mut data = patterned(64 * CHUNK, 1);
        ts.begin_ckpt(0.0);
        let io0 = ts.write_wave(vec![recipe_req(0, "g0/f0", &data)]).unwrap();
        assert_eq!(io0.deduped_bytes, 0, "empty index dedups nothing");
        assert_eq!(io0.pending_bytes, 64 * CHUNK as u64);
        ts.drain_sync();
        let shipped_gen0 = ts.stats.drained_bytes;
        assert_eq!(shipped_gen0, 64 * CHUNK as u64);
        assert!(ts.is_durable("g0/f0"));

        // Dirty ~10%: one byte in each of 6 of the 64 chunks.
        for c in 0..6usize {
            data[c * 10 * CHUNK] ^= 0xA5;
        }
        ts.begin_ckpt(1.0);
        let io1 = ts.write_wave(vec![recipe_req(0, "g1/f0", &data)]).unwrap();
        assert_eq!(io1.deduped_bytes, 58 * CHUNK as u64);
        assert_eq!(ts.pending_bytes(), 6 * CHUNK as u64);
        let secs = ts.drain_sync();
        assert!(secs > 0.0);
        assert_eq!(
            ts.stats.drained_bytes - shipped_gen0,
            6 * CHUNK as u64,
            "only the dirty chunks ship"
        );
        assert!(ts.stats.dedup_ratio() > 0.4);
        assert!(ts.is_durable("g1/f0"));
    }

    #[test]
    fn fully_clean_generation_drains_by_reference_instantly() {
        let mut ts = store(1024 * MIB, 4);
        let data = patterned(32 * CHUNK, 3);
        ts.begin_ckpt(0.0);
        ts.write_wave(vec![recipe_req(0, "g0/f0", &data)]).unwrap();
        ts.drain_sync();
        let shipped = ts.stats.drained_bytes;

        ts.begin_ckpt(1.0);
        let io = ts.write_wave(vec![recipe_req(0, "g1/f0", &data)]).unwrap();
        assert_eq!(io.deduped_bytes, data.len() as u64, "everything dedups");
        assert_eq!(ts.pending_bytes(), 0, "no physical bytes to ship");
        assert_eq!(ts.pending_files(), 1, "recipe commit still pending");
        let secs = ts.drain_sync();
        assert_eq!(secs, 0.0, "deduped drain takes zero simulated seconds");
        assert_eq!(ts.stats.drained_bytes, shipped, "nothing new shipped");
        assert!(ts.is_durable("g1/f0"));
    }

    #[test]
    fn restart_reassembles_from_durable_chunks_alone() {
        let mut ts = store(1024 * MIB, 2);
        let d0 = patterned(16 * CHUNK, 5);
        let d1 = patterned(16 * CHUNK + 100, 6); // non-chunk-aligned tail
        ts.begin_ckpt(0.0);
        ts.write_wave(vec![
            recipe_req(0, "g0/f0", &d0),
            recipe_req(1, "g0/f1", &d1),
        ])
        .unwrap();
        ts.drain_sync();
        // Total fast-tier loss.
        for p in ts.fast().paths() {
            ts.fast_mut().delete(&p).unwrap();
        }
        assert_eq!(ts.fast().file_count(), 0);
        let paths = vec![
            (NodeId(0), "g0/f0".to_string()),
            (NodeId(1), "g0/f1".to_string()),
        ];
        let (datas, io) = ts.read_preferred(&paths).unwrap();
        assert_eq!(datas[0], d0, "reassembly must be byte-identical");
        assert_eq!(datas[1], d1);
        assert!(io.duration > 0.0, "reassembly charges read time");
        assert_eq!(
            io.total_virtual_bytes,
            (d0.len() + d1.len()) as u64,
            "logical bytes charged"
        );
    }

    #[test]
    fn cdc_variable_length_recipes_drain_dedup_and_reassemble() {
        // Variable-length (content-defined) chunks through the whole
        // store path: insertion-shifted generations dedup, refcounted GC
        // and the persisted index handle variable-length entries, and
        // durable-only reassembly is byte-identical.
        use crate::ckpt::chunk::Chunking;
        let chunking = Chunking::cdc(CHUNK);
        let mut ts = store(1024 * MIB, 4);
        let base = patterned(64 * CHUNK, 11);
        let req = |path: &str, data: &[u8]| WriteReq {
            node: NodeId(0),
            path: path.into(),
            virtual_bytes: data.len() as u64,
            data: data.to_vec(),
            recipe: Some(ChunkRecipe::from_data_chunked(
                data,
                &chunking,
                data.len() as u64,
            )),
        };
        ts.begin_ckpt(0.0);
        let io0 = ts.write_wave(vec![req("g0/f0", &base)]).unwrap();
        assert_eq!(io0.deduped_bytes, 0);
        ts.drain_sync();
        let shipped_gen0 = ts.stats.drained_bytes;
        assert_eq!(shipped_gen0, base.len() as u64, "gen 0 ships every byte");

        // Gen 1 inserts 2 KiB mid-buffer — the fixed grid would re-ship
        // everything downstream; CDC re-ships only the edit window.
        let ins_at = 8 * CHUNK;
        let mut edited = base[..ins_at].to_vec();
        edited.extend_from_slice(&patterned(2048, 12));
        edited.extend_from_slice(&base[ins_at..]);
        ts.begin_ckpt(1.0);
        let io1 = ts.write_wave(vec![req("g1/f0", &edited)]).unwrap();
        assert!(
            io1.deduped_bytes as f64 >= edited.len() as f64 * 0.7,
            "CDC must dedup >= 70% across the insertion (got {} of {})",
            io1.deduped_bytes,
            edited.len()
        );
        ts.drain_sync();

        // Persisted index round-trips variable-length entries: a fresh
        // store adopted from the durable tier alone reassembles both
        // generations byte-identically.
        let durable = ts.durable().clone();
        let mut bb = FsConfig::burst_buffer(2);
        bb.capacity = 1024 * MIB;
        let fresh = TieredStore::adopt(FileSystem::new(bb), durable, 2, 2).unwrap();
        let (datas, _) = fresh
            .read_durable(&[
                (NodeId(0), "g0/f0".to_string()),
                (NodeId(0), "g1/f0".to_string()),
            ])
            .unwrap();
        assert_eq!(datas[0], base, "CDC reassembly must be byte-identical");
        assert_eq!(datas[1], edited);

        // Refcounted GC at variable lengths: deleting gen 0 must keep
        // every chunk gen 1 still references.
        let mut ts2 = ts;
        ts2.delete("g0/f0").unwrap();
        let r1 = ChunkRecipe::from_data_chunked(&edited, &chunking, edited.len() as u64);
        for c in &r1.chunks {
            assert!(
                ts2.chunk_store().is_stored(c.digest),
                "gen 1 chunk must survive gen 0 deletion"
            );
        }
        for p in ts2.fast().paths() {
            ts2.fast_mut().delete(&p).unwrap();
        }
        let (datas, _) = ts2
            .read_durable(&[(NodeId(0), "g1/f0".to_string())])
            .unwrap();
        assert_eq!(datas[0], edited);
    }

    #[test]
    fn reassembly_rejects_corrupted_chunk_object() {
        let mut ts = store(1024 * MIB, 2);
        let data = patterned(8 * CHUNK, 7);
        let rec = ChunkRecipe::from_data(&data, CHUNK, data.len() as u64);
        ts.begin_ckpt(0.0);
        ts.write_wave(vec![recipe_req(0, "g0/f0", &data)]).unwrap();
        ts.drain_sync();
        ts.fast_mut().delete("g0/f0").unwrap();
        // Flip one byte of a stored chunk object: the recorded content
        // digest no longer matches.
        assert!(ts
            .durable_mut()
            .corrupt_byte(&object_path(rec.chunks[2].digest), 10));
        let err = ts
            .read_durable(&[(NodeId(0), "g0/f0".to_string())])
            .unwrap_err();
        assert!(matches!(err, FsError::Corrupt(_)), "got {err}");
    }

    #[test]
    fn gc_never_reclaims_chunk_referenced_by_newer_generation() {
        let mut ts = store(1024 * MIB, 4);
        let d0 = patterned(64 * CHUNK, 1);
        let mut d1 = d0.clone();
        for c in 0..6usize {
            d1[c * 10 * CHUNK] ^= 0xA5; // 6 dirty chunks in gen 1
        }
        ts.begin_ckpt(0.0);
        ts.write_wave(vec![recipe_req(0, "g0/f0", &d0)]).unwrap();
        ts.drain_sync();
        ts.begin_ckpt(1.0);
        ts.write_wave(vec![recipe_req(0, "g1/f0", &d1)]).unwrap();
        ts.drain_sync();

        // Deleting the old generation reclaims only its unique chunks.
        ts.delete("g0/f0").unwrap();
        assert_eq!(ts.stats.gc_chunks, 6, "only gen-0-unique chunks die");
        assert_eq!(ts.stats.gc_bytes, 6 * CHUNK as u64);
        let r1 = ChunkRecipe::from_data(&d1, CHUNK, d1.len() as u64);
        for c in &r1.chunks {
            assert!(
                ts.chunk_store().is_stored(c.digest),
                "gen 1 chunk must survive gen 0 deletion"
            );
        }
        // Gen 1 still reassembles byte-identical from the durable tier.
        for p in ts.fast().paths() {
            ts.fast_mut().delete(&p).unwrap();
        }
        let (datas, _) = ts
            .read_durable(&[(NodeId(0), "g1/f0".to_string())])
            .unwrap();
        assert_eq!(datas[0], d1);
        assert!(!ts.exists("g0/f0"));
    }

    #[test]
    fn unclaim_releases_queued_recipe_references() {
        let mut ts = store(1024 * MIB, 2);
        let a = patterned(8 * CHUNK, 1);
        let b = patterned(8 * CHUNK, 2);
        ts.begin_ckpt(0.0);
        ts.write_wave(vec![recipe_req(0, "same/f0", &a)]).unwrap();
        // Overwrite the same path before its drain ran: the stale queued
        // recipe's references must be released, not leaked.
        ts.begin_ckpt(0.5);
        ts.write_wave(vec![recipe_req(0, "same/f0", &b)]).unwrap();
        assert_eq!(ts.pending_files(), 1);
        ts.drain_sync();
        assert_eq!(
            ts.chunk_store().chunk_count(),
            8,
            "only the live recipe's chunks stay indexed"
        );
        for p in ts.fast().paths() {
            ts.fast_mut().delete(&p).unwrap();
        }
        let (datas, _) = ts
            .read_durable(&[(NodeId(0), "same/f0".to_string())])
            .unwrap();
        assert_eq!(datas[0], b, "the overwriting version is the durable one");
    }

    #[test]
    fn durable_representation_is_superseded_across_plain_and_recipe() {
        // A path has exactly one durable representation: overwriting a
        // plain durable file with a recipe-backed version (or vice versa)
        // must replace it, never leave a stale copy for read_durable.
        let mut ts = store(1024 * MIB, 2);
        let plain = vec![9u8; 64];
        let recipe_data = patterned(4 * CHUNK, 4);
        ts.begin_ckpt(0.0);
        ts.write_wave(vec![WriteReq {
            node: NodeId(0),
            path: "p".into(),
            virtual_bytes: 64,
            data: plain,
            recipe: None,
        }])
        .unwrap();
        ts.drain_sync(); // plain durable copy
        ts.begin_ckpt(1.0);
        ts.write_wave(vec![recipe_req(0, "p", &recipe_data)]).unwrap();
        ts.drain_sync(); // recipe supersedes the plain copy
        assert!(!ts.durable().exists("p"), "stale plain copy removed");
        ts.fast_mut().delete("p").unwrap();
        let (datas, _) = ts.read_durable(&[(NodeId(0), "p".to_string())]).unwrap();
        assert_eq!(datas[0], recipe_data, "recipe version is the durable one");

        // And back: a plain overwrite releases the committed recipe.
        ts.begin_ckpt(2.0);
        ts.write_wave(vec![WriteReq {
            node: NodeId(0),
            path: "p".into(),
            virtual_bytes: 32,
            data: vec![7u8; 32],
            recipe: None,
        }])
        .unwrap();
        ts.drain_sync();
        assert_eq!(ts.chunk_store().recipe_count(), 0, "recipe released");
        assert_eq!(ts.chunk_store().chunk_count(), 0, "chunk refs released");
        ts.fast_mut().delete("p").unwrap();
        let (datas, _) = ts.read_durable(&[(NodeId(0), "p".to_string())]).unwrap();
        assert_eq!(datas[0], vec![7u8; 32]);
    }

    #[test]
    fn chunk_index_is_persisted_and_adoptable() {
        let mut ts = store(1024 * MIB, 2);
        let d0 = patterned(16 * CHUNK, 5);
        ts.begin_ckpt(0.0);
        ts.write_wave(vec![recipe_req(0, "g0/f0", &d0)]).unwrap();
        assert!(
            !ts.durable().exists(INDEX_PATH),
            "nothing committed yet — no index object"
        );
        ts.drain_sync();
        assert!(ts.durable().exists(INDEX_PATH), "commit persists the index");

        // A fresh store adopted around the surviving durable tier alone
        // (in-memory state gone) reassembles byte-identically.
        let durable = ts.durable().clone();
        let mut bb = FsConfig::burst_buffer(2);
        bb.capacity = 1024 * MIB;
        let fresh = TieredStore::adopt(FileSystem::new(bb), durable, 2, 2).unwrap();
        assert!(fresh.is_durable("g0/f0"));
        assert_eq!(fresh.chunk_store().recipe_count(), 1);
        assert_eq!(fresh.chunk_store().chunk_count(), 16);
        let (datas, _) = fresh
            .read_durable(&[(NodeId(0), "g0/f0".to_string())])
            .unwrap();
        assert_eq!(datas[0], d0, "reassembly from the reloaded index");
    }

    #[test]
    fn corrupt_index_is_rejected_on_adopt() {
        let mut ts = store(1024 * MIB, 2);
        let d0 = patterned(8 * CHUNK, 9);
        ts.begin_ckpt(0.0);
        ts.write_wave(vec![recipe_req(0, "g0/f0", &d0)]).unwrap();
        ts.drain_sync();
        let mut durable = ts.durable().clone();
        assert!(durable.corrupt_byte(INDEX_PATH, 20));
        let mut bb = FsConfig::burst_buffer(2);
        bb.capacity = 1024 * MIB;
        let err = TieredStore::adopt(FileSystem::new(bb), durable, 2, 2).unwrap_err();
        assert!(matches!(err, FsError::Corrupt(_)), "got {err}");
    }

    #[test]
    fn index_reload_rejects_missing_stored_object() {
        let mut ts = store(1024 * MIB, 2);
        let d0 = patterned(4 * CHUNK, 2);
        let rec = ChunkRecipe::from_data(&d0, CHUNK, d0.len() as u64);
        ts.begin_ckpt(0.0);
        ts.write_wave(vec![recipe_req(0, "g0/f0", &d0)]).unwrap();
        ts.drain_sync();
        // Delete one chunk object behind the index's back.
        ts.durable_mut()
            .delete(&object_path(rec.chunks[1].digest))
            .unwrap();
        let err = ts.reload_index().unwrap_err();
        assert!(matches!(err, FsError::Corrupt(_)), "got {err}");
    }

    #[test]
    fn index_reload_preserves_queued_references() {
        let mut ts = store(1024 * MIB, 4);
        let a = patterned(8 * CHUNK, 1);
        let b = patterned(8 * CHUNK, 2);
        ts.begin_ckpt(0.0);
        ts.write_wave(vec![recipe_req(0, "g0/f0", &a)]).unwrap();
        ts.drain_sync(); // generation A committed + index persisted
        ts.begin_ckpt(1.0);
        ts.write_wave(vec![recipe_req(0, "g1/f0", &b)]).unwrap();
        let pending = ts.pending_bytes();
        assert!(pending > 0, "generation B still queued");
        // Reload (what a restart does): committed state comes from the
        // persisted index, the queued recipe re-takes its references.
        assert!(ts.reload_index().unwrap());
        assert_eq!(ts.pending_bytes(), pending, "queue untouched by reload");
        ts.drain_sync();
        assert!(ts.is_durable("g1/f0"));
        for p in ts.fast().paths() {
            ts.fast_mut().delete(&p).unwrap();
        }
        let (datas, _) = ts
            .read_durable(&[
                (NodeId(0), "g0/f0".to_string()),
                (NodeId(0), "g1/f0".to_string()),
            ])
            .unwrap();
        assert_eq!(datas[0], a);
        assert_eq!(datas[1], b);
    }

    #[test]
    fn reload_with_pending_persist_keeps_in_memory_index() {
        let mut ts = store(1024 * MIB, 2);
        let d = patterned(8 * CHUNK, 3);
        ts.begin_ckpt(0.0);
        ts.write_wave(vec![recipe_req(0, "g0/f0", &d)]).unwrap();
        ts.drain_sync();
        // Pretend the last persist failed: the in-memory index is newer
        // than a stale on-disk snapshot (here: an empty store's).
        let stale = ChunkStore::default().encode_index();
        ts.durable_mut()
            .insert_raw(INDEX_PATH, stale.len() as u64, stale)
            .unwrap();
        ts.index_dirty = true;
        assert!(
            !ts.reload_index().unwrap(),
            "must not resurrect the stale snapshot"
        );
        assert_eq!(ts.chunk_store().recipe_count(), 1, "in-memory index kept");
        assert!(!ts.index_dirty, "the deferred persist was retried");
        let (_, bytes) = ts.durable().peek(INDEX_PATH).unwrap();
        assert_eq!(ChunkStore::decode_index(bytes).unwrap().recipe_count(), 1);
    }

    #[test]
    fn reload_sweeps_orphaned_chunk_objects() {
        let mut ts = store(1024 * MIB, 2);
        let d = patterned(8 * CHUNK, 4);
        ts.begin_ckpt(0.0);
        ts.write_wave(vec![recipe_req(0, "g0/f0", &d)]).unwrap();
        ts.drain_sync();
        // Plant an orphan object, as a queued recipe that died with its
        // job (references never committed) would leave behind.
        ts.durable_mut()
            .insert_raw(&object_path(0xDEAD), 4, vec![1, 2, 3, 4])
            .unwrap();
        let durable = ts.durable().clone();
        let mut bb = FsConfig::burst_buffer(2);
        bb.capacity = 1024 * MIB;
        let fresh = TieredStore::adopt(FileSystem::new(bb), durable, 2, 2).unwrap();
        assert!(
            !fresh.durable().exists(&object_path(0xDEAD)),
            "orphan object swept on reload"
        );
        assert_eq!(fresh.stats.gc_chunks, 1);
        assert!(fresh.is_durable("g0/f0"));
        let (datas, _) = fresh
            .read_durable(&[(NodeId(0), "g0/f0".to_string())])
            .unwrap();
        assert_eq!(datas[0], d, "live objects untouched by the sweep");
    }

    #[test]
    fn reload_without_index_object_is_a_clean_noop() {
        let mut ts = store(1024 * MIB, 2);
        assert!(!ts.reload_index().unwrap(), "no index object yet");
        // Recipe-less stores never write an index.
        ts.begin_ckpt(0.0);
        ts.write_wave(wave("g0", 2, MIB)).unwrap();
        ts.drain_sync();
        assert!(!ts.durable().exists(INDEX_PATH));
        assert!(!ts.reload_index().unwrap());
    }

    #[test]
    fn recipe_commit_replacing_old_recipe_releases_it() {
        let mut ts = store(1024 * MIB, 2);
        let a = patterned(8 * CHUNK, 1);
        let b = patterned(8 * CHUNK, 2);
        ts.begin_ckpt(0.0);
        ts.write_wave(vec![recipe_req(0, "same/f0", &a)]).unwrap();
        ts.drain_sync(); // version A committed
        ts.begin_ckpt(1.0);
        ts.write_wave(vec![recipe_req(0, "same/f0", &b)]).unwrap();
        ts.drain_sync(); // version B replaces A; A's chunks reclaimed
        assert_eq!(ts.chunk_store().chunk_count(), 8);
        assert_eq!(ts.stats.gc_chunks, 8, "all of A's chunks reclaimed");
        assert_eq!(ts.file_count(), 1);
    }

    // ------------------------------------- fast-tier peer redundancy

    fn rstore(nodes: u32, scheme: RedundancyScheme) -> TieredStore {
        let mut ts = TieredStore::new(
            FileSystem::new(FsConfig::burst_buffer(nodes)),
            FileSystem::new(FsConfig::cscratch()),
            2,
            nodes,
        );
        ts.set_redundancy(RedundancyConfig::new(scheme, 4));
        ts
    }

    /// A wave of distinct-content files round-robined across `nodes`.
    fn nwave(tag: &str, files: u32, bytes_each: u64, nodes: u32) -> Vec<WriteReq> {
        (0..files)
            .map(|i| WriteReq {
                node: NodeId(i % nodes),
                path: format!("{tag}/f{i}"),
                virtual_bytes: bytes_each,
                data: patterned(96 + 17 * (i as usize % 3), i as u8 + 1),
                recipe: None,
            })
            .collect()
    }

    fn fast_bytes_of(ts: &TieredStore, path: &str) -> Vec<u8> {
        ts.fast().peek(path).expect("path on fast").1.to_vec()
    }

    #[test]
    fn exchange_is_noop_without_redundancy() {
        let mut ts = store(1024 * MIB, 2);
        ts.begin_ckpt(0.0);
        let io = ts.write_wave(wave("g0", 4, MIB)).unwrap();
        let ex = ts.exchange_wave(&Fabric::default(), io.fast_secs);
        assert_eq!(ex.exchange_secs, 0.0);
        assert_eq!(ex.parity_bytes, 0);
        assert_eq!(ts.used_bytes(), 4 * MIB, "no artifacts parked");
    }

    #[test]
    fn partner_exchange_doubles_capacity_and_rebuilds_lost_node() {
        let fabric = Fabric::default();
        let mut ts = rstore(4, RedundancyScheme::Partner);
        ts.begin_ckpt(0.0);
        let io = ts.write_wave(nwave("g0", 8, 4 * MIB, 4)).unwrap();
        let ex = ts.exchange_wave(&fabric, io.fast_secs);
        assert_eq!(ex.parity_bytes, 8 * 4 * MIB, "partner = full copies");
        assert_eq!(ts.used_bytes(), 2 * 8 * 4 * MIB, "2x capacity overhead");

        // Node 1 owns f1 and f5; remember their bytes, then lose the node.
        let f1 = fast_bytes_of(&ts, "g0/f1");
        let f5 = fast_bytes_of(&ts, "g0/f5");
        ts.lose_node_now(NodeId(1));
        assert!(!ts.fast().exists("g0/f1"));
        assert!(ts.stats.lost_files > 0);

        let rb = ts.rebuild_missing(&fabric);
        assert_eq!(rb.rebuilt_nodes, 1);
        assert_eq!(rb.rebuilt_files, 2);
        assert!(rb.rebuild_secs > 0.0);
        assert_eq!(rb.unrecoverable_sets, 0);
        assert_eq!(fast_bytes_of(&ts, "g0/f1"), f1, "bitwise-identical rebuild");
        assert_eq!(fast_bytes_of(&ts, "g0/f5"), f5);
        assert_eq!(ts.durable().file_count(), 0, "peers only, no durable reads");

        // Drain-queue order: survivors keep their FIFO order, rebuilt
        // entries re-enter at the back.
        let order: Vec<String> = ts.queue.iter().map(|i| i.path.clone()).collect();
        assert_eq!(
            order,
            vec![
                "g0/f0", "g0/f2", "g0/f3", "g0/f4", "g0/f6", "g0/f7", "g0/f1", "g0/f5"
            ]
            .into_iter()
            .map(String::from)
            .collect::<Vec<_>>()
        );
        // The rebuilt files still drain to durable normally.
        ts.drain_to(10_000.0);
        assert!(ts.is_durable("g0/f1") && ts.is_durable("g0/f5"));
    }

    #[test]
    fn xor_exchange_rebuilds_lost_node_bitwise() {
        let fabric = Fabric::default();
        let mut ts = rstore(4, RedundancyScheme::Xor);
        ts.begin_ckpt(0.0);
        let io = ts.write_wave(nwave("g0", 8, 4 * MIB, 4)).unwrap();
        let ex = ts.exchange_wave(&fabric, io.fast_secs);
        // XOR overhead: 1/(m-1) = one third of a member's vbytes per node.
        assert!(ex.parity_bytes > 0);
        assert!(
            ex.parity_bytes < io.fast_bytes / 2,
            "XOR parity ({}) must be far below partner's full copies",
            ex.parity_bytes
        );
        assert_eq!(ts.used_bytes(), 8 * 4 * MIB + ex.parity_bytes);

        let f2 = fast_bytes_of(&ts, "g0/f2");
        let f6 = fast_bytes_of(&ts, "g0/f6");
        ts.lose_node_now(NodeId(2));
        assert!(!ts.fast().exists("g0/f2"));

        let rb = ts.rebuild_missing(&fabric);
        assert_eq!(rb.rebuilt_nodes, 1);
        assert_eq!(rb.rebuilt_files, 2);
        assert_eq!(rb.unrecoverable_sets, 0);
        assert_eq!(fast_bytes_of(&ts, "g0/f2"), f2, "bitwise-identical rebuild");
        assert_eq!(fast_bytes_of(&ts, "g0/f6"), f6);
        assert_eq!(ts.durable().file_count(), 0, "peers only, no durable reads");
    }

    #[test]
    fn two_xor_losses_are_unrecoverable() {
        let fabric = Fabric::default();
        let mut ts = rstore(4, RedundancyScheme::Xor);
        ts.begin_ckpt(0.0);
        let io = ts.write_wave(nwave("g0", 4, MIB, 4)).unwrap();
        ts.exchange_wave(&fabric, io.fast_secs);
        ts.lose_node_now(NodeId(1));
        ts.lose_node_now(NodeId(2));
        let rb = ts.rebuild_missing(&fabric);
        assert_eq!(rb.rebuilt_files, 0, "2-of-k loss cannot rebuild");
        assert!(rb.unrecoverable_sets >= 1);
        assert!(!ts.fast().exists("g0/f1"));
        assert!(!ts.fast().exists("g0/f2"));
    }

    #[test]
    fn partner_pair_loss_is_unrecoverable_but_other_members_rebuild() {
        let fabric = Fabric::default();
        let mut ts = rstore(4, RedundancyScheme::Partner);
        ts.begin_ckpt(0.0);
        let io = ts.write_wave(nwave("g0", 4, MIB, 4)).unwrap();
        ts.exchange_wave(&fabric, io.fast_secs);
        // Node 0's copy lives on node 1: losing both is the pair loss.
        ts.lose_node_now(NodeId(0));
        ts.lose_node_now(NodeId(1));
        let rb = ts.rebuild_missing(&fabric);
        assert!(!ts.fast().exists("g0/f0"), "pair loss: f0 stays missing");
        assert!(ts.fast().exists("g0/f1"), "node 1's copy on node 2 survives");
        assert_eq!(rb.rebuilt_nodes, 1);
        assert!(rb.unrecoverable_sets >= 1);
    }

    #[test]
    fn scheduled_loss_fires_mid_drain_and_kills_queued_items() {
        let mut ts = store(1024 * MIB, 2);
        ts.begin_ckpt(0.0);
        ts.write_wave(wave("g0", 2, 256 * MIB)).unwrap();
        let bw = ts.drain_bandwidth();
        let half_f0 = 128.0 * MIB as f64 / bw;
        ts.schedule_node_loss(NodeId(1), half_f0 * 1.5);

        // Before the loss time: f0 (node 0) drains partially.
        let t1 = ts.drain_to(half_f0);
        assert!(t1.drained_bytes > 0);
        assert!(ts.fast().exists("g0/f1"), "loss not due yet");

        // Past the loss time: node 1's fast tier dies mid-drain — its
        // queued item is destroyed, the rest drains normally.
        ts.drain_to(10_000.0);
        assert!(ts.durable().exists("g0/f0"));
        assert!(!ts.fast().exists("g0/f1"), "f1 lost with its node");
        assert!(!ts.durable().exists("g0/f1"), "partially-drained f1 never lands");
        assert_eq!(ts.stats.lost_files, 1);
        assert_eq!(ts.pending_files(), 0, "no zombie queue entries");
    }

    #[test]
    fn stale_record_never_overwrites_a_newer_generation() {
        // The manifest path is rewritten every generation. An older
        // generation's record must treat the newer content as stale and
        // leave it alone — never "rebuild" old bytes over it.
        let fabric = Fabric::default();
        let mut ts = rstore(2, RedundancyScheme::Partner);
        let manifest = |data: &[u8]| WriteReq {
            node: NodeId(0),
            path: "job/manifest.txt".to_string(),
            virtual_bytes: MIB,
            data: data.to_vec(),
            recipe: None,
        };
        ts.begin_ckpt(0.0);
        let io = ts.write_wave(vec![manifest(b"gen 0")]).unwrap();
        ts.exchange_wave(&fabric, io.fast_secs);
        ts.begin_ckpt(1.0);
        let io = ts.write_wave(vec![manifest(b"gen 1")]).unwrap();
        ts.exchange_wave(&fabric, io.fast_secs);

        let rb = ts.rebuild_missing(&fabric);
        assert_eq!(rb.rebuilt_files, 0, "nothing is missing");
        assert_eq!(fast_bytes_of(&ts, "job/manifest.txt"), b"gen 1".to_vec());

        // Lose the owner: the newest record restores the newest content.
        ts.lose_node_now(NodeId(0));
        let rb = ts.rebuild_missing(&fabric);
        assert_eq!(rb.rebuilt_files, 1);
        assert_eq!(fast_bytes_of(&ts, "job/manifest.txt"), b"gen 1".to_vec());
    }

    #[test]
    fn evicting_a_generation_frees_its_redundancy_artifacts() {
        let fabric = Fabric::default();
        // Tight fast tier + keep_fulls = 1 so the second checkpoint must
        // evict the first, artifacts included.
        let mut bb = FsConfig::burst_buffer(2);
        bb.capacity = 100 * MIB;
        let mut ts = TieredStore::new(
            FileSystem::new(bb),
            FileSystem::new(FsConfig::cscratch()),
            1,
            2,
        );
        ts.set_redundancy(RedundancyConfig::new(RedundancyScheme::Partner, 4));
        ts.begin_ckpt(0.0);
        let io = ts.write_wave(nwave("g0", 2, 20 * MIB, 2)).unwrap();
        ts.exchange_wave(&fabric, io.fast_secs);
        assert_eq!(ts.used_bytes(), 80 * MIB, "g0 + its copies");
        ts.drain_to(1.0e7); // g0 fully durable
        // The next wave (40 MiB) cannot fit in the 20 MiB left: g0 is
        // evicted and its partner copies must go with it.
        ts.begin_ckpt(2.0);
        let io = ts.write_wave(nwave("g1", 2, 20 * MIB, 2)).unwrap();
        assert!(io.evicted_files > 0);
        ts.exchange_wave(&fabric, io.fast_secs);
        assert!(!ts.fast().exists("g0/f0"));
        assert_eq!(
            ts.used_bytes(),
            80 * MIB,
            "only g1 + its copies remain on the fast tier"
        );
    }

    #[test]
    fn early_admission_strictly_improves_drain_start() {
        // Same wave, same stall window; the only difference is whether
        // files are admitted to the drain as their own fast-tier writes
        // land. The legacy store has earned zero budget when the stall
        // ends; the early-admission store has already been draining.
        // (The rank-visible stall itself is untouched — admission only
        // grants background-drain credit, so the pipelined stall gate
        // asserted at the sim level is unaffected.)
        let mk = |early: bool| {
            let mut ts = store(2048 * MIB, 2);
            ts.set_early_admission(early);
            ts.begin_ckpt(0.0);
            let io = ts.write_wave(wave("g0", 4, 64 * MIB)).unwrap();
            // The caller (sim) places the wave at [0, fast_secs] and the
            // stall runs 5 virtual seconds past it (exchange, resume...).
            ts.admit_wave(io.fast_secs);
            ts.sync_clock(io.fast_secs + 5.0);
            (ts, io.fast_secs + 5.0)
        };
        let (mut legacy, t_resume) = mk(false);
        let (mut early, t_resume2) = mk(true);
        assert_eq!(t_resume, t_resume2);
        // Zero further budget: any progress at resume time came from the
        // stall window itself.
        let lt = legacy.drain_to(t_resume);
        let et = early.drain_to(t_resume);
        assert_eq!(lt.drained_bytes, 0, "legacy drain starts at resume");
        assert!(
            et.drained_bytes > 0,
            "early admission must have drained inside the stall window"
        );
        // ...but never ahead of physics: credit is bounded by the stall
        // window at drain bandwidth.
        let bound = (t_resume * early.drain_bandwidth()).ceil() as u64;
        assert!(et.drained_bytes <= bound, "{} > {bound}", et.drained_bytes);
        assert!(early.pending_bytes() < legacy.pending_bytes());
    }

    #[test]
    fn early_admission_skips_stale_backlog() {
        // Only files of the current wave earn stall-window credit; an
        // older generation's still-queued backlog keeps holding off
        // (its ready stamps were consumed by the previous sync).
        let mut ts = store(2048 * MIB, 3);
        ts.set_early_admission(true);
        ts.begin_ckpt(0.0);
        let io0 = ts.write_wave(wave("g0", 2, 64 * MIB)).unwrap();
        ts.admit_wave(io0.fast_secs);
        ts.sync_clock(io0.fast_secs); // zero-width stall: no credit
        assert_eq!(ts.pending_bytes(), 2 * 64 * MIB);
        // Second wave with a stall long enough to cover its own bytes:
        // g1's files earn stall credit, g0's backlog does not.
        ts.begin_ckpt(io0.fast_secs);
        let io1 = ts.write_wave(wave("g1", 2, 64 * MIB)).unwrap();
        let wave_end = io0.fast_secs + io1.fast_secs;
        ts.admit_wave(wave_end);
        ts.sync_clock(wave_end + 1000.0);
        let tick = ts.drain_to(wave_end + 1000.0);
        // The stall credit covers exactly one wave's bytes (g1's own);
        // g0's backlog earned nothing, so the drained total is one wave,
        // never two.
        assert_eq!(tick.drained_bytes, 2 * 64 * MIB);
        assert_eq!(ts.pending_bytes(), 2 * 64 * MIB);
    }

    #[test]
    fn drain_qos_shares_link_without_starvation() {
        // Two tenants with queued work: the tick's budget splits 3:1 by
        // drain weight, and the lighter job still progresses even though
        // its item sits *behind* the heavier job's in the FIFO queue.
        let mut ts = store(4096 * MIB, 2);
        ts.set_drain_weight("jobA", 3.0);
        ts.set_drain_weight("jobB", 1.0);
        ts.begin_ckpt(0.0);
        ts.write_wave(vec![
            WriteReq {
                node: NodeId(0),
                path: "jobA/f0".into(),
                virtual_bytes: 512 * MIB,
                data: vec![1; 8],
                recipe: None,
            },
            WriteReq {
                node: NodeId(1),
                path: "jobB/f0".into(),
                virtual_bytes: 512 * MIB,
                data: vec![2; 8],
                recipe: None,
            },
        ])
        .unwrap();
        let tick = ts.drain_to(1.0);
        assert!(tick.drained_bytes > 0);
        let rem = |ts: &TieredStore, job: &str| -> u64 {
            ts.queue
                .iter()
                .filter(|i| job_of(&i.path) == job)
                .map(|i| i.remaining)
                .sum()
        };
        let done_a = 512 * MIB - rem(&ts, "jobA");
        let done_b = 512 * MIB - rem(&ts, "jobB");
        assert!(done_a > 0, "heavy job progresses");
        assert!(done_b > 0, "light job must not starve behind the heavy one");
        // 3:1 share within chunk-granularity slack.
        let g = 2 * DEFAULT_CHUNK_BYTES as u64;
        assert!(
            done_a + g >= 3 * done_b && 3 * done_b + 3 * g >= done_a,
            "weighted shares off: a={done_a} b={done_b}"
        );
    }

    #[test]
    fn cross_job_dedup_counts_other_tenants_chunks_once() {
        // Two jobs checkpoint the same region template into one shared
        // chunk store: the second job's drain is satisfied entirely by
        // the first's chunks, ships nothing, and is attributed as
        // cross-job dedup.
        let mut ts = store(1024 * MIB, 4);
        let data = patterned(256 * 1024, 11);
        ts.begin_ckpt(0.0);
        ts.write_wave(vec![recipe_req(0, "jobA/f0", &data)]).unwrap();
        ts.drain_to(1000.0);
        assert_eq!(ts.stats.cross_job_deduped_bytes, 0);
        let shipped = ts.stats.drained_bytes;
        assert_eq!(shipped, data.len() as u64);

        ts.begin_ckpt(1001.0);
        let io = ts.write_wave(vec![recipe_req(1, "jobB/f0", &data)]).unwrap();
        assert_eq!(io.deduped_bytes, data.len() as u64);
        assert_eq!(ts.stats.cross_job_deduped_bytes, data.len() as u64);
        ts.drain_to(2000.0);
        assert_eq!(
            ts.stats.drained_bytes, shipped,
            "shared chunks drain once across jobs"
        );
        assert!(ts.stats.cross_job_dedup_ratio() > 0.49);
        // Both jobs' files are independently restorable from the store.
        let (datas, _) = ts
            .read_durable(&[
                (NodeId(0), "jobA/f0".to_string()),
                (NodeId(1), "jobB/f0".to_string()),
            ])
            .unwrap();
        assert_eq!(datas[0], data);
        assert_eq!(datas[1], data);
    }

    #[test]
    fn per_job_gc_is_isolated() {
        // One tenant deleting its generation never reclaims chunk objects
        // another tenant's committed recipes still reference.
        let mut ts = store(1024 * MIB, 4);
        let data = patterned(128 * 1024, 23);
        ts.begin_ckpt(0.0);
        ts.write_wave(vec![recipe_req(0, "jobA/f0", &data)]).unwrap();
        ts.drain_to(1000.0);
        ts.begin_ckpt(1001.0);
        ts.write_wave(vec![recipe_req(1, "jobB/f0", &data)]).unwrap();
        ts.drain_to(2000.0);

        ts.delete("jobA/f0").unwrap();
        assert_eq!(ts.stats.gc_bytes, 0, "jobB's chunks must survive");
        let (datas, _) = ts
            .read_durable(&[(NodeId(1), "jobB/f0".to_string())])
            .unwrap();
        assert_eq!(datas[0], data, "jobB unaffected by jobA's GC");

        ts.delete("jobB/f0").unwrap();
        assert!(ts.stats.gc_chunks > 0, "last tenant out reclaims");
        assert!(ts.stats.gc_bytes > 0);
    }
}
