//! Run configuration: the job description + which production fixes are on.
//!
//! Every fix the paper describes is a toggle, so the reliability evaluation
//! can run the same workload in "research prototype" mode (all off — the
//! 2019 MANA) and "production" mode (all on — this work), and per-fix
//! ablations in between.

use crate::ckpt::chunk::{Chunking, DEFAULT_CHUNK_BYTES};
use crate::faults::FaultPlan;
use crate::fdreg::FdPolicy;
use crate::fs::redundancy::DEFAULT_SET_SIZE;
use crate::fs::{FsKind, RedundancyScheme};
use crate::mem::{AllocPolicy, OsVersion};

/// Which analog application to run (see DESIGN.md §apps).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AppKind {
    /// Gromacs/ADH analog: MD with the Pallas LJ kernel (Fig. 2 workload).
    Gromacs,
    /// HPCG analog: CG with the Pallas stencil kernel (in-text table).
    Hpcg,
    /// VASP/RPA analog: chi0 accumulation (the >48 h walltime workload).
    VaspRpa,
    /// Pure-synthetic state evolution (substrate tests, big-scale benches).
    Synthetic,
    /// Collective-heavy analog (HPCG's allreduce cadence pushed to the
    /// limit): small payloads at high frequency posted *nonblocking* at
    /// every superstep boundary, so a checkpoint request nearly always
    /// lands inside a pending collective — the drain-strategy stressor.
    CollectiveHeavy,
}

impl AppKind {
    pub fn name(&self) -> &'static str {
        match self {
            AppKind::Gromacs => "gromacs-adh",
            AppKind::Hpcg => "hpcg",
            AppKind::VaspRpa => "vasp-rpa",
            AppKind::Synthetic => "synthetic",
            AppKind::CollectiveHeavy => "colheavy",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "gromacs" | "gromacs-adh" => Some(AppKind::Gromacs),
            "hpcg" => Some(AppKind::Hpcg),
            "vasp" | "vasp-rpa" => Some(AppKind::VaspRpa),
            "synthetic" => Some(AppKind::Synthetic),
            "colheavy" | "collective-heavy" => Some(AppKind::CollectiveHeavy),
            _ => None,
        }
    }
}

/// Run application compute for real (PJRT artifacts) or as deterministic
/// synthetic state evolution (fast, for 512-rank benches).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ComputeMode {
    Real,
    Synthetic,
}

/// How the restart executable reaches the compute nodes (the startup-time
/// issue: "for best startup performance at scale, it is recommended to
/// broadcast a statically linked executable to all nodes").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkMode {
    /// Dynamically linked MANA/DMTCP (current state): ld.so metadata storm.
    Dynamic,
    /// Statically linked via `--wrap=symbol` (planned fix): one broadcast.
    Static,
}

/// The production-hardening fixes from the paper, individually toggleable.
#[derive(Clone, Copy, Debug)]
pub struct Fixes {
    /// TCP KeepAlive on coordinator connections.
    pub keepalive: bool,
    /// Delay checkpoint until Σsent == Σreceived (message drain).
    pub drain: bool,
    /// Reserved fd ranges per half.
    pub fd_reservation: bool,
    /// MAP_FIXED_NOREPLACE dynamic free-space discovery.
    pub noreplace: bool,
    /// Careful blocking→non-blocking conversion (request tracking).
    pub careful_nonblocking: bool,
    /// Pass checkpoint file names via manifest, not argv.
    pub manifest_filenames: bool,
    /// CHANGES_PENDING guards on coordinator structures (Lesson 3).
    pub locks: bool,
}

impl Fixes {
    /// This work: production MANA.
    pub fn all_on() -> Self {
        Fixes {
            keepalive: true,
            drain: true,
            fd_reservation: true,
            noreplace: true,
            careful_nonblocking: true,
            manifest_filenames: true,
            locks: true,
        }
    }

    /// The 2019 research prototype.
    pub fn all_off() -> Self {
        Fixes {
            keepalive: false,
            drain: false,
            fd_reservation: false,
            noreplace: false,
            careful_nonblocking: false,
            manifest_filenames: false,
            locks: false,
        }
    }

    pub fn alloc_policy(&self) -> AllocPolicy {
        if self.noreplace {
            AllocPolicy::NoReplace
        } else {
            AllocPolicy::FixedLegacy
        }
    }

    pub fn fd_policy(&self) -> FdPolicy {
        if self.fd_reservation {
            FdPolicy::Reserved
        } else {
            FdPolicy::Legacy
        }
    }
}

/// Chunk-boundary strategy for image framing and content-addressed dedup
/// (`--chunking fixed|cdc`). The actual size parameters ride
/// `RunConfig::chunk_bytes`; [`RunConfig::chunking_strategy`] combines the
/// two into the [`Chunking`] every encode layer consumes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChunkingMode {
    /// Historical fixed-stride tiling (byte-identical to pre-CDC images).
    Fixed,
    /// Content-defined (gear rolling hash) boundaries: insertions and heap
    /// growth no longer shift-invalidate downstream chunks.
    Cdc,
}

impl ChunkingMode {
    pub fn name(&self) -> &'static str {
        match self {
            ChunkingMode::Fixed => "fixed",
            ChunkingMode::Cdc => "cdc",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "fixed" => Some(ChunkingMode::Fixed),
            "cdc" | "content" | "content-defined" => Some(ChunkingMode::Cdc),
            _ => None,
        }
    }
}

/// How the DRAIN phase quiesces in-flight traffic before the image is
/// taken (`--drain-strategy counter|topo`), orthogonal to the
/// coordination plane.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum DrainStrategy {
    /// The paper's counter convergence: complete any pending collective
    /// (MANA's trivial-barrier), then reduce per-rank sent/recv counters
    /// over the control plane until Σsent == Σrecv. Drain cost scales
    /// with the plane's reduce fan-in.
    #[default]
    Counter,
    /// Topological-sort ordering (arXiv:2408.02218): checkpoint *inside*
    /// the pending collective. Ranks are ordered by their round cursor
    /// (deepest first), the per-collective progress cursor is recorded in
    /// the image manifest, and restart resumes the collective from the
    /// recorded round. No counter reduce — the wave schedule ships down
    /// the plane as one bounded object, so drain cost stops scaling with
    /// fan-in.
    Topo,
}

impl DrainStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            DrainStrategy::Counter => "counter",
            DrainStrategy::Topo => "topo",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "counter" => Some(DrainStrategy::Counter),
            "topo" | "topological" | "topo-sort" => Some(DrainStrategy::Topo),
            _ => None,
        }
    }
}

/// Tiered-storage staging (SCR-style asynchronous BB→Lustre drain):
/// checkpoints complete when the fast-tier write lands, and images drain
/// to the durable tier in the background across subsequent supersteps.
#[derive(Clone, Copy, Debug)]
pub struct StagingConfig {
    /// Checkpoint generations kept resident on the fast tier (including
    /// the one being written); older drained generations are evicted when
    /// the fast tier runs short.
    pub keep_fulls: usize,
    /// Admit a file to the background drain as soon as its own fast-tier
    /// WRITE completes instead of after the whole wave lands (default on).
    /// Off restores the historical whole-wave barrier.
    pub early_admission: bool,
}

impl Default for StagingConfig {
    fn default() -> Self {
        StagingConfig {
            keep_fulls: 2,
            early_admission: true,
        }
    }
}

/// Full job + environment description.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub job: String,
    pub app: AppKind,
    pub ranks: u32,
    pub threads_per_rank: u32,
    /// Outer supersteps to run.
    pub steps: u64,
    /// Storage tier for single-tier runs. Ignored in staged mode, which
    /// always pairs a BurstBuffer fast tier with a Lustre durable tier.
    pub fs: FsKind,
    /// `Some` enables the tiered storage engine (`--fs staged`): BB fast
    /// tier + Lustre durable tier with asynchronous drain.
    pub staging: Option<StagingConfig>,
    pub compute: ComputeMode,
    pub link: LinkMode,
    pub os: OsVersion,
    pub fixes: Fixes,
    pub faults: FaultPlan,
    pub seed: u64,
    /// Per-rank upper-half footprint override (bytes); None = app default.
    pub mem_per_rank: Option<u64>,
    /// Incremental checkpointing (the paper's "reducing the checkpoint
    /// overhead" future work): after the first full checkpoint, write only
    /// regions dirtied since it, referencing the rest by fingerprint.
    pub incremental: bool,
    /// Chunk granularity (bytes) for image framing and content-addressed
    /// dedup (`--chunk-bytes`; power of two). Smaller chunks dedup finer
    /// but cost more index entries; the manifest records the value so a
    /// restarted job keeps the granularity consistent. Under CDC this is
    /// the *expected* (average) chunk size.
    pub chunk_bytes: usize,
    /// Chunk-boundary strategy (`--chunking fixed|cdc`). Recorded in the
    /// manifest with its derived CDC parameters so `restart_from` adopts
    /// the writer's mode — mixing strategies across a job's lifetime would
    /// stop unchanged regions from deduping against older generations.
    pub chunking: ChunkingMode,
    /// Coordination plane: `None` = the flat DMTCP root (O(ranks) control
    /// messages at one endpoint per phase); `Some(f)` = the hierarchical
    /// plane (`--coord-fanout f`, f >= 2) — per-node sub-coordinators in a
    /// fanout-`f` tree, each phase a broadcast-down + reduce-up, the root
    /// handling only O(f) messages per phase.
    pub coord_fanout: Option<u32>,
    /// Worker threads the checkpoint WRITE path fans ranks across
    /// (`--encode-threads`; the parallel data path is byte-identical to
    /// the serial one). `None` = the host's available parallelism;
    /// `Some(1)` forces the serial path.
    pub encode_threads: Option<usize>,
    /// Pipelined checkpoint path (`--pipeline on|off`, default on):
    /// stream each rank's finished encode straight into the write wave
    /// and overlap coordination phases, so stall approaches
    /// `max(encode, write)` instead of their sum. Off = the historical
    /// strictly-serial phase ordering. The stored bytes are identical
    /// either way; only the simulated stall accounting changes.
    pub pipeline: bool,
    /// Fast-tier peer redundancy (`--redundancy none|partner|xor`): after
    /// each checkpoint's write wave, nodes in a redundancy set exchange
    /// partner copies or XOR parity over the fabric, so a single-node
    /// fast-tier loss rebuilds from peers instead of falling back to the
    /// durable tier. Staged mode only.
    pub redundancy: RedundancyScheme,
    /// Nodes per redundancy set (`--redundancy-set-size`, >= 2).
    pub redundancy_set_size: u32,
    /// Virtual-time span tracing (`--trace` / `--trace-out FILE`): record
    /// a span per phase/encode/wave/drain into the job's
    /// [`crate::trace::Tracer`], reconcile them against every
    /// `CkptReport` timing field, and expose the critical path. The
    /// structured event log is always on; this gates only spans/counters.
    pub trace: bool,
    /// Event-driven driver (`--event-core on|off`, default on): steady-
    /// state supersteps between interesting boundaries (checkpoints,
    /// fault-plan marks, drain completions, console polls) advance through
    /// an O(1) analytic recurrence per step; per-rank state is deferred
    /// and replayed bit-exactly when an observer needs it. Off forces the
    /// historical O(ranks)-per-superstep loop. Virtual time, stored
    /// generations and fingerprints are identical either way.
    pub event_driven: bool,
    /// DRAIN-phase quiescing strategy (`--drain-strategy counter|topo`).
    /// Counter is the paper's Σsent == Σrecv convergence; topo checkpoints
    /// inside pending collectives in round-cursor order. Final application
    /// fingerprints are identical either way (property-tested).
    pub drain_strategy: DrainStrategy,
}

impl RunConfig {
    /// Sensible production defaults for quick runs.
    pub fn new(app: AppKind, ranks: u32) -> Self {
        RunConfig {
            job: format!("{}-{}r", app.name(), ranks),
            app,
            ranks,
            threads_per_rank: 8,
            steps: 8,
            fs: FsKind::BurstBuffer,
            staging: None,
            compute: ComputeMode::Synthetic,
            link: LinkMode::Static,
            os: OsVersion::Cle7,
            fixes: Fixes::all_on(),
            faults: FaultPlan::none(),
            seed: 0x4e45_5253, // "NERS"
            mem_per_rank: None,
            incremental: false,
            chunk_bytes: DEFAULT_CHUNK_BYTES,
            chunking: ChunkingMode::Fixed,
            coord_fanout: None,
            encode_threads: None,
            pipeline: true,
            redundancy: RedundancyScheme::None,
            redundancy_set_size: DEFAULT_SET_SIZE,
            trace: false,
            event_driven: true,
            drain_strategy: DrainStrategy::default(),
        }
    }

    /// Enable the staged (tiered BB→Lustre) storage engine.
    pub fn with_staging(mut self) -> Self {
        self.staging = Some(StagingConfig::default());
        self
    }

    /// Enable fast-tier peer redundancy (implies staged storage: the
    /// redundancy layer protects the fast tier, so there must be one).
    pub fn with_redundancy(mut self, scheme: RedundancyScheme) -> Self {
        self.redundancy = scheme;
        if self.staging.is_none() {
            self.staging = Some(StagingConfig::default());
        }
        self
    }

    /// Select the hierarchical coordination plane with the given fanout.
    pub fn with_coord_tree(mut self, fanout: u32) -> Self {
        self.coord_fanout = Some(fanout.max(2));
        self
    }

    /// The chunk-boundary strategy every encode layer consumes: the mode
    /// knob plus the size parameters derived from `chunk_bytes` (CDC:
    /// `min = avg/4`, `max = 4*avg`, expected size = `chunk_bytes`).
    pub fn chunking_strategy(&self) -> Chunking {
        match self.chunking {
            ChunkingMode::Fixed => Chunking::Fixed(self.chunk_bytes),
            ChunkingMode::Cdc => Chunking::cdc(self.chunk_bytes),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixes_map_to_policies() {
        assert_eq!(Fixes::all_on().alloc_policy(), AllocPolicy::NoReplace);
        assert_eq!(Fixes::all_off().alloc_policy(), AllocPolicy::FixedLegacy);
        assert_eq!(Fixes::all_on().fd_policy(), FdPolicy::Reserved);
        assert_eq!(Fixes::all_off().fd_policy(), FdPolicy::Legacy);
    }

    #[test]
    fn app_kind_parse() {
        assert_eq!(AppKind::parse("gromacs"), Some(AppKind::Gromacs));
        assert_eq!(AppKind::parse("hpcg"), Some(AppKind::Hpcg));
        assert_eq!(AppKind::parse("vasp"), Some(AppKind::VaspRpa));
        assert_eq!(AppKind::parse("nope"), None);
    }

    #[test]
    fn default_config_is_production() {
        let c = RunConfig::new(AppKind::Gromacs, 8);
        assert!(c.fixes.drain && c.fixes.keepalive);
        assert!(!c.faults.any_active());
    }

    #[test]
    fn default_chunk_bytes_is_one_mib() {
        let c = RunConfig::new(AppKind::Synthetic, 4);
        assert_eq!(c.chunk_bytes, 1 << 20);
        assert!(c.chunk_bytes.is_power_of_two());
    }

    #[test]
    fn chunking_defaults_fixed_and_strategy_tracks_chunk_bytes() {
        let mut c = RunConfig::new(AppKind::Synthetic, 4);
        assert_eq!(c.chunking, ChunkingMode::Fixed);
        assert_eq!(c.chunking_strategy(), Chunking::Fixed(1 << 20));
        c.chunking = ChunkingMode::Cdc;
        c.chunk_bytes = 64 << 10;
        let s = c.chunking_strategy();
        assert_eq!(s, Chunking::cdc(64 << 10));
        assert_eq!(s.avg_bytes(), 64 << 10);
        assert!(s.is_valid());
    }

    #[test]
    fn chunking_mode_parse() {
        assert_eq!(ChunkingMode::parse("fixed"), Some(ChunkingMode::Fixed));
        assert_eq!(ChunkingMode::parse("cdc"), Some(ChunkingMode::Cdc));
        assert_eq!(
            ChunkingMode::parse("content-defined"),
            Some(ChunkingMode::Cdc)
        );
        assert_eq!(ChunkingMode::parse("rolling?"), None);
        assert_eq!(ChunkingMode::Cdc.name(), "cdc");
    }

    #[test]
    fn drain_strategy_parse_and_default() {
        assert_eq!(DrainStrategy::parse("counter"), Some(DrainStrategy::Counter));
        assert_eq!(DrainStrategy::parse("topo"), Some(DrainStrategy::Topo));
        assert_eq!(
            DrainStrategy::parse("topological"),
            Some(DrainStrategy::Topo)
        );
        assert_eq!(DrainStrategy::parse("eager"), None);
        assert_eq!(DrainStrategy::Topo.name(), "topo");
        let c = RunConfig::new(AppKind::Synthetic, 4);
        assert_eq!(c.drain_strategy, DrainStrategy::Counter, "paper default");
    }

    #[test]
    fn collective_heavy_app_parses() {
        assert_eq!(
            AppKind::parse("colheavy"),
            Some(AppKind::CollectiveHeavy)
        );
        assert_eq!(
            AppKind::parse("collective-heavy"),
            Some(AppKind::CollectiveHeavy)
        );
        assert_eq!(AppKind::CollectiveHeavy.name(), "colheavy");
    }

    #[test]
    fn staging_config_toggles() {
        let c = RunConfig::new(AppKind::Synthetic, 8);
        assert!(c.staging.is_none());
        let s = c.with_staging();
        assert_eq!(s.staging.unwrap().keep_fulls, 2);
    }

    #[test]
    fn redundancy_defaults_off_and_helper_implies_staging() {
        let c = RunConfig::new(AppKind::Synthetic, 8);
        assert_eq!(c.redundancy, RedundancyScheme::None);
        assert_eq!(c.redundancy_set_size, DEFAULT_SET_SIZE);
        let r = c.with_redundancy(RedundancyScheme::Xor);
        assert_eq!(r.redundancy, RedundancyScheme::Xor);
        assert!(r.staging.is_some(), "redundancy protects the fast tier");
    }

    #[test]
    fn encode_threads_defaults_to_auto() {
        let c = RunConfig::new(AppKind::Synthetic, 4);
        assert!(
            c.encode_threads.is_none(),
            "None = fan out to the host's available parallelism"
        );
    }

    #[test]
    fn pipeline_defaults_on() {
        let c = RunConfig::new(AppKind::Synthetic, 4);
        assert!(c.pipeline, "pipelined checkpoint path is the default");
    }

    #[test]
    fn coord_plane_defaults_flat_and_tree_clamps_fanout() {
        let c = RunConfig::new(AppKind::Synthetic, 8);
        assert!(c.coord_fanout.is_none(), "flat plane is the default");
        assert_eq!(c.clone().with_coord_tree(8).coord_fanout, Some(8));
        assert_eq!(c.with_coord_tree(1).coord_fanout, Some(2), "fanout >= 2");
    }
}
