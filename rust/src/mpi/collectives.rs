//! MPI collectives over the simulated fabric.
//!
//! Collectives are synchronization points: every participant's virtual
//! clock advances to the operation's completion time. Costs follow the
//! standard log-tree models (latency * ceil(log2 P) + bytes/bandwidth per
//! hop), and every collective also updates the per-rank byte counters so
//! the drain condition sees collective traffic too.
//!
//! Beyond the one-shot operations, [`InflightCollective`] models the same
//! log-tree schedules **round by round**: a checkpoint request can land
//! while ranks sit at different rounds of an allreduce/barrier/bcast, the
//! per-rank progress cursor survives in the image manifest, and resuming
//! from the cursor completes with times and counters bitwise-identical to
//! the uninterrupted op (property-tested). This is the substrate of the
//! topological-sort drain strategy (arXiv:2408.02218).

use crate::topology::RankId;
use crate::util::simclock::SimTime;

use super::MpiWorld;

fn log2_ceil(p: u32) -> u32 {
    debug_assert!(p >= 1);
    32 - (p - 1).leading_zeros()
}

/// Synchronize all clocks to the max and add a tree-latency term.
/// Returns the completion time.
pub fn barrier(world: &mut MpiWorld, times: &mut [SimTime]) -> SimTime {
    assert_eq!(times.len(), world.size as usize);
    let enter = times.iter().fold(SimTime::ZERO, |a, &t| a.max(t));
    let hops = log2_ceil(world.size).max(1) as f64;
    let done = enter.after(2.0 * hops * world.fabric.cfg.latency);
    for t in times.iter_mut() {
        *t = done;
    }
    done
}

/// Scalar cost of one allreduce of `bytes` per rank: the wire bytes each
/// rank moves and the virtual duration past the entry time. Shared by the
/// per-rank collective below and the event core's bulk-advance recurrence,
/// so both paths compute bit-identical completion times.
pub(crate) fn allreduce_cost(world: &MpiWorld, bytes: u64) -> (u64, f64) {
    let p = world.size as f64;
    let hops = log2_ceil(world.size).max(1) as f64;
    let bw = world.fabric.cfg.bandwidth;
    // Rabenseifner-style: 2 * (p-1)/p * bytes over the wire per rank.
    let wire_bytes = if world.size > 1 {
        (2.0 * (p - 1.0) / p * bytes as f64) as u64
    } else {
        0
    };
    let dur = hops * world.fabric.cfg.latency + wire_bytes as f64 / bw;
    (wire_bytes, dur)
}

/// Per-rank message-count charge of one allreduce, each direction.
pub(crate) fn allreduce_msgs(size: u32) -> u64 {
    2 * log2_ceil(size) as u64
}

/// Allreduce of `bytes` per rank: reduce-scatter + allgather cost model.
/// Charges 2*bytes sent/received per rank.
pub fn allreduce(world: &mut MpiWorld, times: &mut [SimTime], bytes: u64) -> SimTime {
    assert_eq!(times.len(), world.size as usize);
    let enter = times.iter().fold(SimTime::ZERO, |a, &t| a.max(t));
    let (wire_bytes, dur) = allreduce_cost(world, bytes);
    let msgs = allreduce_msgs(world.size);
    let done = enter.after(dur);
    for (i, t) in times.iter_mut().enumerate() {
        *t = done;
        if world.size > 1 {
            world.counters[i].sent_bytes += wire_bytes;
            world.counters[i].recv_bytes += wire_bytes;
            world.counters[i].sent_msgs += msgs;
            world.counters[i].recv_msgs += msgs;
        }
    }
    let _ = RankId(0);
    done
}

/// Number of binomial-tree children of `rank` in a bcast rooted at
/// `root` over `size` ranks — the messages this rank *relays* (the root
/// included). Relative rank j = (rank - root) mod size; in round r the
/// ranks j < 2^r forward to j + 2^r when that target exists.
fn bcast_children(size: u32, root: RankId, rank: RankId) -> u64 {
    let p = size as u64;
    let j = (u64::from(rank.0) + p - u64::from(root.0)) % p;
    let mut children = 0;
    for r in 0..log2_ceil(size) {
        let stride = 1u64 << r;
        if j < stride && j + stride < p {
            children += 1;
        }
    }
    children
}

/// Broadcast `bytes` from `root` to everyone (binomial tree).
///
/// Accounting follows the relay structure: every non-root rank receives
/// the payload exactly once, and every rank (root included) is charged a
/// send per binomial-tree child it forwards to — so sent == recv holds
/// per collective op (`size - 1` messages total) and the drain condition
/// stays balanced after any bcast.
pub fn bcast(
    world: &mut MpiWorld,
    times: &mut [SimTime],
    root: RankId,
    bytes: u64,
) -> SimTime {
    assert_eq!(times.len(), world.size as usize);
    assert!(root.0 < world.size);
    let enter = times.iter().fold(SimTime::ZERO, |a, &t| a.max(t));
    let hops = log2_ceil(world.size).max(1) as f64;
    let bw = world.fabric.cfg.bandwidth;
    let dur = hops * (world.fabric.cfg.latency + bytes as f64 / bw);
    let done = enter.after(dur);
    for (i, t) in times.iter_mut().enumerate() {
        *t = done;
        if world.size > 1 {
            let rank = RankId(i as u32);
            let children = bcast_children(world.size, root, rank);
            world.counters[i].sent_bytes += bytes * children;
            world.counters[i].sent_msgs += children;
            if rank != root {
                world.counters[i].recv_bytes += bytes;
                world.counters[i].recv_msgs += 1;
            }
        }
    }
    done
}

/// Does the collective leave the world drained? Collectives must be
/// self-consistent in the byte accounting; this is asserted in tests and
/// relied on by the coordinator (checkpoints happen at collective-free
/// safe points, but the counters must still balance **per collective op**
/// for bcast this is root-sends == sum of receives).
pub fn accounting_balanced(world: &MpiWorld) -> bool {
    world.total_sent_bytes() == world.total_recv_bytes()
}

// ---------------------------------------------------------------------------
// Partial-progress collectives
// ---------------------------------------------------------------------------

/// Which collective operation an [`InflightCollective`] is running.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CollectiveKind {
    Barrier,
    Allreduce,
    Bcast,
}

impl CollectiveKind {
    pub fn name(self) -> &'static str {
        match self {
            CollectiveKind::Barrier => "barrier",
            CollectiveKind::Allreduce => "allreduce",
            CollectiveKind::Bcast => "bcast",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "barrier" => Some(CollectiveKind::Barrier),
            "allreduce" => Some(CollectiveKind::Allreduce),
            "bcast" => Some(CollectiveKind::Bcast),
            _ => None,
        }
    }
}

/// Integer split of `total` into `parts` pieces that sum exactly to
/// `total`: piece `idx` gets `total/parts`, plus one unit of the
/// remainder for the first `total % parts` pieces. Exactness is what
/// makes resumed collectives land on counters bitwise-identical to the
/// one-shot ops.
fn share(total: u64, parts: u32, idx: u32) -> u64 {
    debug_assert!(parts > 0 && idx < parts);
    total / u64::from(parts) + u64::from(u64::from(idx) < total % u64::from(parts))
}

/// A collective caught mid-flight: the same log-tree schedule as the
/// one-shot ops above, unrolled round by round so each rank carries its
/// own progress cursor. A checkpoint request can land while ranks sit at
/// different rounds; the cursor vector is recorded in the image manifest
/// and resuming from it completes the op with times and byte counters
/// bitwise-identical to running it uninterrupted.
///
/// Two invariants hold at **any** interleaving of per-rank advances:
///
/// * global sent == recv (the drain condition). Allreduce rounds charge a
///   symmetric sent+recv share on the advancing rank; bcast charges are
///   atomic message pairs — when a receiver advances through its receive
///   round, both its recv **and its binomial-tree parent's sent** are
///   charged in the same step; barrier charges nothing.
/// * completing every cursor reproduces the one-shot op exactly: the
///   per-round integer shares sum to the full wire totals, and the final
///   round of each rank lands on the stored `done` time verbatim (not a
///   re-derived float).
#[derive(Clone, Debug, PartialEq)]
pub struct InflightCollective {
    pub kind: CollectiveKind,
    /// Root rank (bcast only; 0 otherwise).
    pub root: u32,
    /// Per-rank payload bytes the application passed to the op.
    pub bytes: u64,
    /// Number of participants (== world size at begin time).
    pub size: u32,
    /// Total rounds in the unrolled schedule (>= 1).
    pub rounds: u32,
    /// Entry time: max of all participant clocks at begin.
    pub enter: SimTime,
    /// Completion time; the final round of every rank lands here exactly.
    pub done: SimTime,
    /// Per-rank progress: rounds completed so far (0..=rounds).
    pub cursor: Vec<u32>,
}

/// Begin a barrier without running it: all clocks are noted (entry is
/// their max) but nothing advances until ranks step through rounds.
pub fn begin_barrier(world: &MpiWorld, times: &[SimTime]) -> InflightCollective {
    assert_eq!(times.len(), world.size as usize);
    let enter = times.iter().fold(SimTime::ZERO, |a, &t| a.max(t));
    let hops = log2_ceil(world.size).max(1);
    let done = enter.after(2.0 * hops as f64 * world.fabric.cfg.latency);
    InflightCollective {
        kind: CollectiveKind::Barrier,
        root: 0,
        bytes: 0,
        size: world.size,
        rounds: (2 * hops).max(1),
        enter,
        done,
        cursor: vec![0; world.size as usize],
    }
}

/// Begin an allreduce without running it. Completing all cursors charges
/// exactly what [`allreduce`] charges and lands every clock on the same
/// completion time.
pub fn begin_allreduce(world: &MpiWorld, times: &[SimTime], bytes: u64) -> InflightCollective {
    assert_eq!(times.len(), world.size as usize);
    let enter = times.iter().fold(SimTime::ZERO, |a, &t| a.max(t));
    let (_, dur) = allreduce_cost(world, bytes);
    InflightCollective {
        kind: CollectiveKind::Allreduce,
        root: 0,
        bytes,
        size: world.size,
        rounds: (2 * log2_ceil(world.size)).max(1),
        enter,
        done: enter.after(dur),
        cursor: vec![0; world.size as usize],
    }
}

/// Begin a binomial-tree bcast without running it.
pub fn begin_bcast(
    world: &MpiWorld,
    times: &[SimTime],
    root: RankId,
    bytes: u64,
) -> InflightCollective {
    assert_eq!(times.len(), world.size as usize);
    assert!(root.0 < world.size);
    let enter = times.iter().fold(SimTime::ZERO, |a, &t| a.max(t));
    let hops = log2_ceil(world.size).max(1);
    let dur = hops as f64 * (world.fabric.cfg.latency + bytes as f64 / world.fabric.cfg.bandwidth);
    InflightCollective {
        kind: CollectiveKind::Bcast,
        root: root.0,
        bytes,
        size: world.size,
        rounds: log2_ceil(world.size).max(1),
        enter,
        done: enter.after(dur),
        cursor: vec![0; world.size as usize],
    }
}

impl InflightCollective {
    /// True once every rank has stepped through every round.
    pub fn finished(&self) -> bool {
        self.cursor.iter().all(|&c| c >= self.rounds)
    }

    /// Wire bytes not yet charged anywhere — the "bytes outstanding"
    /// column of the in-flight record. Zero once finished.
    pub fn bytes_outstanding(&self, world: &MpiWorld) -> u64 {
        match self.kind {
            CollectiveKind::Barrier => 0,
            CollectiveKind::Allreduce => {
                if self.size <= 1 {
                    return 0;
                }
                let (wire, _) = allreduce_cost(world, self.bytes);
                self.cursor
                    .iter()
                    .map(|&c| (c..self.rounds).map(|r| share(wire, self.rounds, r)).sum::<u64>())
                    .sum()
            }
            CollectiveKind::Bcast => {
                // Each receiver that has not yet passed its receive round
                // still has one payload in flight.
                (0..self.size)
                    .filter(|&i| {
                        bcast_recv_round(self.size, self.root, i)
                            .is_some_and(|r| self.cursor[i as usize] <= r)
                    })
                    .count() as u64
                    * self.bytes
            }
        }
    }

    /// Distinct in-progress cursor values, descending — the wave order a
    /// topological drain checkpoints ranks in (deepest-in-the-collective
    /// ranks first, so every rank's image is taken at a cut consistent
    /// with its pending dependencies).
    pub fn waves(&self) -> Vec<u32> {
        let mut w: Vec<u32> = self.cursor.to_vec();
        w.sort_unstable_by(|a, b| b.cmp(a));
        w.dedup();
        w
    }

    /// Virtual time at which round `r` (1-based count of completed
    /// rounds) lands. The final round returns the stored `done` verbatim
    /// so resume is bitwise-identical to the one-shot op.
    fn round_time(&self, completed: u32) -> SimTime {
        if completed >= self.rounds {
            return self.done;
        }
        let dur = self.done.as_secs() - self.enter.as_secs();
        self.enter
            .after(dur * f64::from(completed) / f64::from(self.rounds))
    }

    /// Step `rank` through its next round: charge that round's balanced
    /// byte/message deltas and advance its clock. Returns false if the
    /// rank has already completed all rounds.
    pub fn advance_rank(
        &mut self,
        world: &mut MpiWorld,
        times: &mut [SimTime],
        rank: RankId,
    ) -> bool {
        assert_eq!(self.size, world.size);
        assert_eq!(times.len(), self.cursor.len());
        let i = rank.0 as usize;
        let r = self.cursor[i];
        if r >= self.rounds {
            return false;
        }
        if self.size > 1 {
            match self.kind {
                CollectiveKind::Barrier => {}
                CollectiveKind::Allreduce => {
                    let (wire, _) = allreduce_cost(world, self.bytes);
                    let msgs = allreduce_msgs(self.size);
                    let b = share(wire, self.rounds, r);
                    let m = share(msgs, self.rounds, r);
                    world.counters[i].sent_bytes += b;
                    world.counters[i].recv_bytes += b;
                    world.counters[i].sent_msgs += m;
                    world.counters[i].recv_msgs += m;
                }
                CollectiveKind::Bcast => {
                    // One message = one atomic charge pair: when the
                    // receiver steps through its receive round, its recv
                    // AND its binomial-tree parent's sent are both
                    // recorded, keeping the world balanced at any cut.
                    if bcast_recv_round(self.size, self.root, rank.0) == Some(r) {
                        let p = u64::from(self.size);
                        let j = (u64::from(rank.0) + p - u64::from(self.root)) % p;
                        let parent_rel = j - (1u64 << (63 - j.leading_zeros()));
                        let parent = ((parent_rel + u64::from(self.root)) % p) as usize;
                        world.counters[i].recv_bytes += self.bytes;
                        world.counters[i].recv_msgs += 1;
                        world.counters[parent].sent_bytes += self.bytes;
                        world.counters[parent].sent_msgs += 1;
                    }
                }
            }
        }
        self.cursor[i] = r + 1;
        let t = self.round_time(r + 1);
        times[i] = times[i].max(t);
        true
    }

    /// Run every rank to completion. After this, counters and clocks are
    /// bitwise-identical to having called the one-shot op instead.
    pub fn finish(&mut self, world: &mut MpiWorld, times: &mut [SimTime]) -> SimTime {
        for i in 0..self.size {
            while self.advance_rank(world, times, RankId(i)) {}
        }
        self.done
    }

    /// Re-anchor the schedule on a fresh timeline (restart): the virtual
    /// clock restarts near zero and the world's counters are zeroed, so
    /// the stored enter/done stamps are meaningless. Keep the cursors —
    /// the progress is real — but replay the **remaining** fraction of
    /// the original duration from `now`.
    pub fn rebase(&mut self, now: SimTime) {
        let dur = self.done.as_secs() - self.enter.as_secs();
        let min_cursor = self.cursor.iter().copied().min().unwrap_or(0);
        let elapsed = dur * f64::from(min_cursor) / f64::from(self.rounds);
        self.enter = SimTime::secs(now.as_secs() - elapsed);
        self.done = self.enter.after(dur);
    }
}

/// Round in which relative receiver `rank` gets the bcast payload, or
/// None for the root (which receives nothing).
fn bcast_recv_round(size: u32, root: u32, rank: u32) -> Option<u32> {
    let p = u64::from(size);
    let j = (u64::from(rank) + p - u64::from(root)) % p;
    if j == 0 {
        None
    } else {
        Some(63 - j.leading_zeros())
    }
}

/// Staggered starting cursor for rank `i` of an interrupted collective:
/// ranks sit at varied depths (~log2(size) distinct wave values) and none
/// has completed, which is the worst case a topological drain must order.
/// Deterministic in (i, rounds) so runs are reproducible.
pub fn stagger_cursor(i: u32, rounds: u32) -> u32 {
    if rounds <= 1 {
        return 0;
    }
    let base = rounds / 2;
    let tz = i.trailing_zeros().min(31);
    base + tz.min(rounds - 1 - base)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::fabric::Fabric;

    fn world(n: u32) -> (MpiWorld, Vec<SimTime>) {
        (
            MpiWorld::new(n, Fabric::default()),
            vec![SimTime::ZERO; n as usize],
        )
    }

    #[test]
    fn log2_ceil_values() {
        assert_eq!(log2_ceil(1), 0);
        assert_eq!(log2_ceil(2), 1);
        assert_eq!(log2_ceil(3), 2);
        assert_eq!(log2_ceil(512), 9);
    }

    #[test]
    fn barrier_syncs_to_max() {
        let (mut w, mut times) = world(4);
        times[2] = SimTime::secs(5.0);
        let done = barrier(&mut w, &mut times);
        assert!(done.as_secs() > 5.0);
        assert!(times.iter().all(|t| *t == done));
    }

    #[test]
    fn allreduce_charges_symmetric_traffic() {
        let (mut w, mut times) = world(8);
        allreduce(&mut w, &mut times, 1 << 20);
        assert!(accounting_balanced(&w));
        assert!(w.counters[0].sent_bytes > 0);
        // All ranks see identical counters.
        for c in &w.counters {
            assert_eq!(c.sent_bytes, w.counters[0].sent_bytes);
        }
    }

    #[test]
    fn allreduce_single_rank_is_free() {
        let (mut w, mut times) = world(1);
        let t0 = times[0];
        allreduce(&mut w, &mut times, 1 << 20);
        assert_eq!(w.total_sent_bytes(), 0);
        assert!(times[0].as_secs() >= t0.as_secs());
    }

    #[test]
    fn bcast_larger_world_takes_longer() {
        let (mut w2, mut t2) = world(2);
        let (mut w64, mut t64) = world(64);
        let d2 = bcast(&mut w2, &mut t2, RankId(0), 1 << 20);
        let d64 = bcast(&mut w64, &mut t64, RankId(0), 1 << 20);
        assert!(d64 > d2);
    }

    #[test]
    fn collective_then_drain_condition_holds() {
        // After a collective completes, the global drain condition that the
        // coordinator checks must hold (no phantom in-flight bytes).
        let (mut w, mut times) = world(16);
        allreduce(&mut w, &mut times, 4096);
        barrier(&mut w, &mut times);
        assert!(w.drained());
    }

    #[test]
    fn bcast_then_drain_condition_holds() {
        // Regression: the root used to charge bytes * min(size-1, hops)
        // sent while receivers collectively recorded bytes * (size-1), so
        // the world was never drained after a bcast. With relay charging
        // the op is balanced for any size and any root.
        for &n in &[2u32, 3, 5, 16, 17, 64] {
            let (mut w, mut times) = world(n);
            bcast(&mut w, &mut times, RankId(0), 4096);
            assert!(accounting_balanced(&w), "size {n} root 0");
            assert!(w.drained(), "size {n} root 0");
            assert_eq!(w.total_sent_bytes(), 4096 * u64::from(n - 1));
            // Non-zero root exercises the relative-rank rotation.
            let root = RankId(n - 1);
            bcast(&mut w, &mut times, root, 1 << 20);
            assert!(w.drained(), "size {n} root {}", root.0);
        }
    }

    #[test]
    fn bcast_relay_counts_cover_tree() {
        // Exactly size-1 messages total, root relays ceil(log2 n) of them.
        let n = 16u32;
        let (mut w, mut times) = world(n);
        bcast(&mut w, &mut times, RankId(0), 100);
        let total_msgs: u64 = w.counters.iter().map(|c| c.sent_msgs).sum();
        assert_eq!(total_msgs, u64::from(n) - 1);
        assert_eq!(w.counters[0].sent_msgs, u64::from(log2_ceil(n)));
    }

    #[test]
    fn inflight_allreduce_finish_matches_oneshot() {
        let (mut w1, mut t1) = world(12);
        t1[3] = SimTime::secs(2.5);
        let (mut w2, mut t2) = (MpiWorld::new(12, Fabric::default()), t1.clone());
        let done1 = allreduce(&mut w1, &mut t1, 4096);
        let mut infl = begin_allreduce(&w2, &t2, 4096);
        let done2 = infl.finish(&mut w2, &mut t2);
        assert_eq!(done1, done2);
        assert_eq!(t1, t2);
        for (a, b) in w1.counters.iter().zip(&w2.counters) {
            assert_eq!((a.sent_bytes, a.recv_bytes), (b.sent_bytes, b.recv_bytes));
            assert_eq!((a.sent_msgs, a.recv_msgs), (b.sent_msgs, b.recv_msgs));
        }
    }

    #[test]
    fn inflight_bcast_finish_matches_oneshot() {
        for &(n, root) in &[(2u32, 0u32), (9, 4), (16, 15)] {
            let (mut w1, mut t1) = world(n);
            let (mut w2, mut t2) = world(n);
            let done1 = bcast(&mut w1, &mut t1, RankId(root), 8192);
            let mut infl = begin_bcast(&w2, &t2, RankId(root), 8192);
            let done2 = infl.finish(&mut w2, &mut t2);
            assert_eq!(done1, done2, "size {n} root {root}");
            assert_eq!(t1, t2);
            for (a, b) in w1.counters.iter().zip(&w2.counters) {
                assert_eq!(a.sent_bytes, b.sent_bytes);
                assert_eq!(a.recv_bytes, b.recv_bytes);
            }
        }
    }

    #[test]
    fn inflight_barrier_finish_matches_oneshot() {
        let (mut w1, mut t1) = world(7);
        t1[5] = SimTime::secs(9.0);
        let (mut w2, mut t2) = (MpiWorld::new(7, Fabric::default()), t1.clone());
        let done1 = barrier(&mut w1, &mut t1);
        let mut infl = begin_barrier(&w2, &t2);
        let done2 = infl.finish(&mut w2, &mut t2);
        assert_eq!(done1, done2);
        assert_eq!(t1, t2);
        assert_eq!(w2.total_sent_bytes(), 0);
    }

    #[test]
    fn inflight_balanced_at_every_interleaved_cut() {
        // Advance ranks in a skewed round-robin and check the global
        // drain condition after every single step: allreduce and bcast
        // charges must be balanced at ANY cut, not just at completion.
        let n = 16u32;
        let (mut w, mut t) = world(n);
        let mut infl = begin_allreduce(&w, &t, 4096);
        let mut moved = true;
        while moved {
            moved = false;
            for i in (0..n).rev() {
                if infl.advance_rank(&mut w, &mut t, RankId(i)) {
                    assert!(accounting_balanced(&w), "allreduce cut");
                    moved = true;
                }
            }
        }
        assert!(infl.finished());
        let (mut w, mut t) = world(n);
        let mut infl = begin_bcast(&w, &t, RankId(3), 512);
        for i in 0..n {
            // Deepest receivers first: the sender's sent is charged by
            // the receiver's advance even though the sender hasn't moved.
            for _ in 0..infl.rounds {
                infl.advance_rank(&mut w, &mut t, RankId((n - 1 - i) % n));
                assert!(accounting_balanced(&w), "bcast cut");
            }
        }
        assert!(infl.finished());
        assert_eq!(w.total_recv_bytes(), 512 * u64::from(n - 1));
    }

    #[test]
    fn stagger_spreads_ranks_without_finishing_any() {
        let rounds = 10;
        let cursors: Vec<u32> = (0..512).map(|i| stagger_cursor(i, rounds)).collect();
        assert!(cursors.iter().all(|&c| c < rounds));
        let mut distinct = cursors.clone();
        distinct.sort_unstable();
        distinct.dedup();
        assert!(distinct.len() >= 3, "want several waves, got {distinct:?}");
    }

    #[test]
    fn resume_from_cursor_completes_bitwise_identical() {
        // Interrupt an allreduce at a staggered cut, clone the record (as
        // the manifest would), and finish both copies: identical times
        // and counters.
        let (mut w, mut t) = world(8);
        let mut infl = begin_allreduce(&w, &t, 4096);
        for i in 0..8u32 {
            for _ in 0..stagger_cursor(i, infl.rounds) {
                infl.advance_rank(&mut w, &mut t, RankId(i));
            }
        }
        let mut resumed = infl.clone();
        let (mut w2, mut t2) = (w.clone(), t.clone());
        let d1 = infl.finish(&mut w, &mut t);
        let d2 = resumed.finish(&mut w2, &mut t2);
        assert_eq!(d1, d2);
        assert_eq!(t, t2);
        for (a, b) in w.counters.iter().zip(&w2.counters) {
            assert_eq!(a.sent_bytes, b.sent_bytes);
            assert_eq!(a.recv_bytes, b.recv_bytes);
        }
    }

    #[test]
    fn rebase_moves_schedule_to_new_timeline() {
        let (w, t) = world(8);
        let mut infl = begin_allreduce(&w, &t, 1 << 20);
        let dur = infl.done.as_secs() - infl.enter.as_secs();
        let (mut wx, mut tx) = world(8);
        for i in 0..8u32 {
            infl.advance_rank(&mut wx, &mut tx, RankId(i));
        }
        infl.rebase(SimTime::secs(100.0));
        assert!(infl.done.as_secs() > 100.0);
        let dur2 = infl.done.as_secs() - infl.enter.as_secs();
        assert!((dur - dur2).abs() < 1e-12);
        // Finishing on the new timeline still balances the fresh world.
        let (mut w2, mut t2) = (
            MpiWorld::new(8, Fabric::default()),
            vec![SimTime::secs(100.0); 8],
        );
        infl.finish(&mut w2, &mut t2);
        assert!(accounting_balanced(&w2));
        assert!(t2.iter().all(|&x| x >= SimTime::secs(100.0)));
    }
}
