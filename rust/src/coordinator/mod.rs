//! The DMTCP-style checkpoint coordinator and its coordination planes.
//!
//! One coordinator per job, connected to the ranks over the simulated
//! control TCP network. The checkpoint protocol follows MANA's production
//! sequence, with every phase carrying its paper fix:
//!
//! 1. **INTENT** — broadcast the checkpoint request (KeepAlive masks the
//!    congestion losses/disconnects).
//! 2. **SAFE POINT** — every rank runs to a wrapper boundary (no
//!    outstanding converted requests).
//! 3. **DRAIN** — "we delayed the final checkpoint until the count of
//!    total bytes sent and received was equal": in-flight MPI messages are
//!    pulled into wrapper buffers. With the fix off, in-flight messages are
//!    dropped (counted as lost).
//! 4. **QUIESCE** — if the GNI fabric is reconfiguring, wait it out.
//! 5. **WRITE** — every rank serializes its upper half; images go to the
//!    file system in one parallel wave (disk-space warning on shortfall).
//! 6. **RESUME** — broadcast the resume.
//!
//! How each phase's control messages actually move is the **coordination
//! plane** ([`CoordPlane`]), selectable per job:
//!
//! * [`FlatPlane`] — the original DMTCP shape: the root exchanges one
//!   message with every rank, paying O(ranks) serialized sends *and*
//!   O(ranks) serialized receives per phase at a single endpoint.
//! * [`tree::TreePlane`] — per-node sub-coordinators arranged in a
//!   fanout-ary tree; each phase is a broadcast-down + reduce-up, the
//!   DRAIN convergence test uses sent/recv counters *summed up the tree*,
//!   and the root never touches more than `2 x fanout` messages per phase.
//!
//! The coordinator's own rank-status table is a [`Guarded`] structure
//! (Lesson 3): with the locks fix off, an injected interruption leaves it
//! mid-update and the subsequent read detects the race.

pub mod console;
pub mod tree;

use std::fmt;

use crate::mem::guard::Guarded;
use crate::simnet::control::{ControlNet, CtrlError};
use crate::topology::RankId;
use crate::trace::{EventCtx, Tracer};
use crate::util::simclock::SimTime;

/// The six checkpoint-protocol phases, in order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Intent,
    SafePoint,
    Drain,
    Quiesce,
    Write,
    Resume,
}

impl Phase {
    /// Protocol order (the per-checkpoint phase count benches divide by).
    pub const ALL: [Phase; 6] = [
        Phase::Intent,
        Phase::SafePoint,
        Phase::Drain,
        Phase::Quiesce,
        Phase::Write,
        Phase::Resume,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Phase::Intent => "INTENT",
            Phase::SafePoint => "SAFE-POINT",
            Phase::Drain => "DRAIN",
            Phase::Quiesce => "QUIESCE",
            Phase::Write => "WRITE",
            Phase::Resume => "RESUME",
        }
    }
}

impl fmt::Display for Phase {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Control-plane accounting of one phase exchange.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseIo {
    /// Wall-clock of the broadcast-down + reduce-up, seconds.
    pub secs: f64,
    /// Seconds of the broadcast-down sweep alone (leaf fan-out included).
    /// The overlapped exchange uses this split: the next phase's
    /// broadcast can start down the tree while this phase's acks are
    /// still reducing up, so only `max(up, next.down)` is serialized.
    pub down_secs: f64,
    /// Control messages moved anywhere in the plane.
    pub msgs: u64,
    /// Messages the *root* endpoint sent or received — the scalability
    /// number (O(ranks) flat, O(fanout) tree).
    pub root_msgs: u64,
    /// Sub-coordinators re-parented during this exchange (tree plane).
    pub reparents: u32,
    /// Phase attempts retried after a sub-coordinator death.
    pub retries: u32,
}

/// Accounting of two protocol phases run overlapped (pipelined path).
#[derive(Clone, Copy, Debug, Default)]
pub struct OverlapIo {
    /// The first phase's own accounting (messages, retries, sweeps).
    pub first: PhaseIo,
    /// The second phase's own accounting.
    pub second: PhaseIo,
    /// Fused wall-clock of the pair. With a healthy plane this is
    /// `first.down + max(first.up, second.down) + second.up`; any
    /// mid-overlap death forfeits the credit and the pair is charged
    /// serially (`first.secs + second.secs`).
    pub secs: f64,
    /// Acks discarded because they carried a pre-re-parent epoch: when a
    /// sub-coordinator dies mid-overlap, the acks its subtree had in
    /// flight are stale and must be dropped — not folded into the second
    /// phase's reduction — before the retry re-collects them.
    pub stale_acks: u64,
}

/// Outcome of the DRAIN convergence reduction.
#[derive(Clone, Copy, Debug, Default)]
pub struct CountReduce {
    /// Aggregate bytes sent / received, summed up the plane.
    pub sent: u64,
    pub recv: u64,
    pub io: PhaseIo,
}

/// One aggregation group for the console's status view: the set of ranks
/// a sub-coordinator answers for (the flat plane has a single root group).
#[derive(Clone, Debug)]
pub struct CoordGroup {
    pub label: String,
    pub parent: String,
    pub ranks: Vec<RankId>,
}

/// How checkpoint-protocol control traffic moves between the root
/// coordinator and the ranks. Implementations own the routing topology;
/// the [`Coordinator`] owns the status table, failure bookkeeping and
/// stats.
pub trait CoordPlane {
    /// Run one phase as a broadcast-down + reduce-up over `ctrl`.
    fn exchange(
        &mut self,
        ctrl: &mut ControlNet,
        phase: Phase,
        now: SimTime,
    ) -> Result<PhaseIo, CtrlError>;

    /// Run two consecutive phases overlapped: the second phase's
    /// broadcast enters the plane while the first phase's reduce is still
    /// converging. The default is the serial fallback (no overlap
    /// credit); planes that can pipeline their sweeps override it.
    /// Implementations must keep the per-phase message and retry
    /// accounting identical to two serial exchanges — overlap buys time,
    /// never traffic.
    fn exchange_overlapped(
        &mut self,
        ctrl: &mut ControlNet,
        first: Phase,
        second: Phase,
        now: SimTime,
    ) -> Result<OverlapIo, CtrlError> {
        let a = self.exchange(ctrl, first, now)?;
        let b = self.exchange(ctrl, second, now)?;
        Ok(OverlapIo {
            first: a,
            second: b,
            secs: a.secs + b.secs,
            stale_acks: 0,
        })
    }

    /// DRAIN convergence: per-rank (sent, recv) byte counters enter at the
    /// leaves and are summed upward; the root sees one aggregate per
    /// child, never one row per rank.
    fn reduce_counts(
        &mut self,
        ctrl: &mut ControlNet,
        counts: &[(u64, u64)],
        now: SimTime,
    ) -> Result<CountReduce, CtrlError>;

    /// Topo-drain scheduling: ship the wave schedule (rank → wave index,
    /// one bounded object — the same idiom the paper recommends for the
    /// statically linked restart executable) down the plane. Ranks
    /// execute their waves locally and piggyback completion on the next
    /// phase's acks, so the cost is per *hop*, never per rank or per
    /// wave — this is what keeps topo drain flat as fan-in grows.
    fn drain_schedule(
        &mut self,
        ctrl: &mut ControlNet,
        waves: u32,
        now: SimTime,
    ) -> Result<PhaseIo, CtrlError>;

    /// Adopt the owning job's tracer so plane-internal fault paths
    /// (re-parents, retries) emit structured events. Default: no-op.
    fn set_tracer(&mut self, _tracer: Tracer) {}

    /// Tree depth in hops from root to a leaf rank (flat = 1).
    fn depth(&self) -> u32;

    /// Aggregation groups for the console's status rows.
    fn groups(&self) -> Vec<CoordGroup>;

    fn describe(&self) -> String;
}

/// The original flat plane: root <-> every rank, unicast, both sweeps
/// serialized at the root endpoint.
pub struct FlatPlane {
    ranks: u32,
}

impl FlatPlane {
    pub fn new(ranks: u32) -> Self {
        FlatPlane { ranks }
    }
}

impl CoordPlane for FlatPlane {
    fn exchange(
        &mut self,
        ctrl: &mut ControlNet,
        _phase: Phase,
        now: SimTime,
    ) -> Result<PhaseIo, CtrlError> {
        // Down: the root unicasts to every rank; up: every rank replies
        // and the root processes the replies one at a time.
        let down = ctrl.send_batch((0..self.ranks).map(RankId), now)?;
        let up = ctrl.send_batch((0..self.ranks).map(RankId), now)?;
        Ok(PhaseIo {
            secs: down.secs + up.secs,
            down_secs: down.secs,
            msgs: down.msgs + up.msgs,
            root_msgs: down.msgs + up.msgs,
            reparents: 0,
            retries: 0,
        })
    }

    fn reduce_counts(
        &mut self,
        ctrl: &mut ControlNet,
        counts: &[(u64, u64)],
        now: SimTime,
    ) -> Result<CountReduce, CtrlError> {
        let io = self.exchange(ctrl, Phase::Drain, now)?;
        let sent = counts.iter().map(|c| c.0).sum();
        let recv = counts.iter().map(|c| c.1).sum();
        Ok(CountReduce { sent, recv, io })
    }

    fn drain_schedule(
        &mut self,
        ctrl: &mut ControlNet,
        _waves: u32,
        now: SimTime,
    ) -> Result<PhaseIo, CtrlError> {
        // One schedule object leaves the root; the scalable broadcast
        // fans it out without touching the root again.
        let secs = ctrl.send(RankId(0), now)?;
        Ok(PhaseIo {
            secs,
            down_secs: secs,
            msgs: 1,
            root_msgs: 1,
            reparents: 0,
            retries: 0,
        })
    }

    fn depth(&self) -> u32 {
        1
    }

    fn groups(&self) -> Vec<CoordGroup> {
        vec![CoordGroup {
            label: "root".into(),
            parent: "-".into(),
            ranks: (0..self.ranks).map(RankId).collect(),
        }]
    }

    fn describe(&self) -> String {
        format!("flat({} ranks)", self.ranks)
    }
}

/// Where each rank stands in the protocol (coordinator's view).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RankState {
    Running,
    SafePoint,
    Writing,
    /// Fast-tier write landed; the rank computes again while its images
    /// drain to the durable tier in the background (staged mode's
    /// Drain-to-PFS phase).
    Draining,
    Resumed,
}

impl RankState {
    /// One-letter tag for the console's aggregated histogram rows.
    pub fn tag(self) -> char {
        match self {
            RankState::Running => 'r',
            RankState::SafePoint => 's',
            RankState::Writing => 'w',
            RankState::Draining => 'd',
            RankState::Resumed => 'u',
        }
    }
}

/// Per-rank protocol status row.
#[derive(Clone, Debug)]
pub struct RankStatus {
    pub rank: RankId,
    pub state: RankState,
    pub step: u64,
    pub sent_bytes: u64,
    pub recv_bytes: u64,
}

/// Coordinator counters (reported by benches).
#[derive(Clone, Copy, Debug, Default)]
pub struct CoordStats {
    pub checkpoints: u64,
    pub restarts: u64,
    pub drain_rounds: u64,
    pub buffered_msgs: u64,
    pub lost_messages: u64,
    pub races_detected: u64,
    /// Physical bytes staged from the fast tier to the durable tier
    /// (staged mode; with dedup, new-chunk traffic only).
    pub staged_bytes: u64,
    /// Logical drain bytes satisfied by reference to chunks the durable
    /// tier already held (content-addressed dedup, staged mode).
    pub deduped_bytes: u64,
    /// Control messages moved by the coordination plane (all endpoints).
    pub ctrl_msgs: u64,
    /// Control messages the root endpoint handled (the scalability number).
    pub root_msgs: u64,
    /// Sub-coordinators re-parented after a mid-phase death (tree plane).
    pub reparents: u64,
    /// Phase exchanges retried after a sub-coordinator death.
    pub phase_retries: u64,
    /// Acks discarded as stale-epoch after a mid-overlap re-parent.
    pub stale_acks: u64,
}

/// Why a checkpoint failed (the reliability bench's failure taxonomy).
#[derive(Clone, Debug)]
pub enum CkptFailure {
    /// Control-plane delivery failure (no KeepAlive under congestion).
    ControlPlane(CtrlError),
    /// A rank exhausted its KeepAlive retries. Recorded once with the
    /// phase that first hit it; later phases fail fast on the record
    /// instead of re-timing-out against the dead link.
    Unreachable { rank: RankId, phase: Phase },
    /// Missing-locks race detected in a coordinator structure.
    RaceDetected(String),
    /// Storage shortfall (insufficient-space warning fired).
    DiskFull(String),
    /// Checkpoint proceeded without drain and lost in-flight messages.
    /// (Latent failure: detected at restart as data loss.)
    LostMessages(usize),
}

impl std::fmt::Display for CkptFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CkptFailure::ControlPlane(e) => write!(f, "control plane: {e}"),
            CkptFailure::Unreachable { rank, phase } => {
                write!(f, "{rank} unreachable (first failed in {phase} phase)")
            }
            CkptFailure::RaceDetected(w) => write!(f, "race detected: {w}"),
            CkptFailure::DiskFull(w) => write!(f, "disk full: {w}"),
            CkptFailure::LostMessages(n) => write!(f, "{n} in-flight messages lost"),
        }
    }
}

/// Timing breakdown of one checkpoint (drives the paper's figures).
#[derive(Clone, Copy, Debug, Default)]
pub struct CkptReport {
    /// Virtual seconds per phase.
    pub intent_secs: f64,
    pub safepoint_secs: f64,
    pub drain_secs: f64,
    pub quiesce_secs: f64,
    /// Rank-visible write stall: the synchronous wave, plus any staged
    /// backpressure. This is the paper's "checkpoint overhead" number.
    pub write_secs: f64,
    pub resume_secs: f64,
    /// End-to-end checkpoint time (intent → resume).
    pub total_secs: f64,
    /// Control-protocol seconds across all six phase exchanges — the
    /// coordination plane's own wall-clock, excluding storage waves.
    pub ctrl_secs: f64,
    /// Control messages moved by the plane during this checkpoint.
    pub ctrl_msgs: u64,
    /// Control messages the root endpoint handled during this checkpoint.
    pub root_ctrl_msgs: u64,
    /// Coordination-plane depth (1 = flat).
    pub coord_depth: u32,
    /// Sub-coordinators re-parented during this checkpoint (tree plane).
    pub reparents: u32,
    /// Aggregate image bytes (virtual).
    pub image_bytes: u64,
    pub drain_rounds: u32,
    pub buffered_msgs: usize,
    /// Nonzero only when the drain fix is off.
    pub lost_messages: usize,
    // ---- per-tier breakdown (tiered storage engine) ----
    /// Seconds/bytes of the fast-tier (Burst Buffer) wave.
    pub fast_write_secs: f64,
    pub fast_bytes: u64,
    /// Synchronous durable-tier seconds: the Lustre wave in single-tier
    /// mode, or forced-drain backpressure in staged mode.
    pub durable_write_secs: f64,
    pub durable_bytes: u64,
    /// Bytes left to the asynchronous Drain-to-PFS phase at resume time
    /// (staged mode only; the background drain retires them across
    /// subsequent supersteps). With dedup this is physical new-chunk
    /// traffic, not the logical image size.
    pub drain_pending_bytes: u64,
    /// Logical bytes of this checkpoint's drain satisfied by reference to
    /// chunks the durable tier already held (content-addressed dedup).
    pub deduped_bytes: u64,
    // ---- rank-parallel encode data path ----
    /// Host (wall-clock) seconds the encode wave spent producing the
    /// write wave — the simulator's own perf number; virtual time charges
    /// only the storage wave.
    pub encode_host_secs: f64,
    /// Worker threads the encode wave fanned ranks across.
    pub encode_threads: u32,
    /// Virtual bytes whose hash/CRC work was served from the per-region
    /// digest cache ("didn't re-hash" — distinct from `deduped_bytes`,
    /// which counts "didn't re-ship").
    pub digest_cache_hit_bytes: u64,
    // ---- pipelined checkpoint path ----
    /// Modeled virtual seconds of the encode wave (slowest worker).
    pub encode_stall_secs: f64,
    /// Rank-visible encode+write stall: `encode + write` on the serial
    /// path, the streamed-admission queue result on the pipelined path.
    pub stall_secs: f64,
    /// Virtual seconds the pipeline hid (phase fusion + streamed writes)
    /// relative to the serial path.
    pub overlap_saved_secs: f64,
    /// Acks discarded as stale-epoch after a mid-overlap re-parent.
    pub stale_acks: u64,
    /// Payload bytes actually re-hashed this generation — with
    /// chunk-granular dirty tracking this scales with dirty chunks, not
    /// dirty regions.
    pub fresh_hash_bytes: u64,
    /// Regions served by the chunk-granular partial re-encode path.
    pub cache_partial_regions: u64,
    /// Whether this checkpoint ran the pipelined path.
    pub pipelined: bool,
    // ---- fast-tier peer redundancy ----
    /// Scheme the post-wave peer exchange ran (`none` = no exchange).
    pub redundancy_scheme: crate::fs::RedundancyScheme,
    /// Virtual seconds the peer exchange added past the write wave (the
    /// fabric transfer is pipelined behind the wave; this is the visible
    /// residual).
    pub exchange_secs: f64,
    /// Redundancy artifact bytes (partner copies or parity blocks) the
    /// exchange parked on the fast tier this checkpoint.
    pub parity_bytes: u64,
    // ---- collective-aware drain ----
    /// Which DRAIN strategy this checkpoint ran.
    pub drain_strategy: crate::config::DrainStrategy,
    /// Checkpoint waves the topo drain ordered ranks into (distinct
    /// round-cursor values of the pending collective; 0 on the counter
    /// path).
    pub topo_waves: u32,
    /// Collectives the checkpoint request landed inside of (0 or 1: at
    /// most one allreduce pends per superstep boundary).
    pub collectives_interrupted: u32,
    /// Virtual seconds the counter path spent completing the pending
    /// collective before it could start draining (MANA's trivial-barrier;
    /// 0 on the topo path, which checkpoints inside the collective).
    pub collective_drain_secs: f64,
}

impl CkptReport {
    /// Fraction of this checkpoint's logical drain traffic deduped away
    /// (0.0 when nothing was staged).
    pub fn dedup_ratio(&self) -> f64 {
        if self.fast_bytes == 0 {
            0.0
        } else {
            self.deduped_bytes as f64 / self.fast_bytes as f64
        }
    }
}

/// The coordinator process.
pub struct Coordinator {
    pub ctrl: ControlNet,
    /// How protocol traffic is routed (flat root or sub-coordinator tree).
    pub plane: Box<dyn CoordPlane>,
    /// Lesson-3 guarded status table.
    pub status: Guarded<Vec<RankStatus>>,
    pub stats: CoordStats,
    /// Locks fix: mutate via `update` (on) vs. interruptible path (off).
    pub locks_fix: bool,
    /// First rank found unreachable, with the phase that detected it.
    /// Once set, every later phase fails fast instead of re-timing-out.
    pub unreachable: Option<(RankId, Phase)>,
    /// Shared span/event recorder (the owning job's).
    pub tracer: Tracer,
}

impl Coordinator {
    pub fn new(
        ctrl: ControlNet,
        plane: Box<dyn CoordPlane>,
        ranks: u32,
        locks_fix: bool,
    ) -> Self {
        let rows = (0..ranks)
            .map(|r| RankStatus {
                rank: RankId(r),
                state: RankState::Running,
                step: 0,
                sent_bytes: 0,
                recv_bytes: 0,
            })
            .collect();
        Coordinator {
            ctrl,
            plane,
            status: Guarded::new("coordinator.rank_status", rows),
            stats: CoordStats::default(),
            locks_fix,
            unreachable: None,
            tracer: Tracer::disabled(),
        }
    }

    /// Adopt the owning job's tracer (and hand it to the plane too).
    pub fn set_tracer(&mut self, tracer: Tracer) {
        self.plane.set_tracer(tracer.clone());
        self.tracer = tracer;
    }

    /// Flat-plane coordinator (the pre-tree default).
    pub fn flat(ctrl: ControlNet, ranks: u32, locks_fix: bool) -> Self {
        Coordinator::new(ctrl, Box::new(FlatPlane::new(ranks)), ranks, locks_fix)
    }

    /// Run one protocol phase through the plane. A rank that exhausts its
    /// KeepAlive retries is recorded once (rank + phase) and every later
    /// phase fails fast on the record — the dead link is never re-probed.
    pub fn phase_exchange(
        &mut self,
        phase: Phase,
        now: SimTime,
    ) -> Result<PhaseIo, CkptFailure> {
        if let Some((rank, first)) = self.unreachable {
            return Err(CkptFailure::Unreachable { rank, phase: first });
        }
        match self.plane.exchange(&mut self.ctrl, phase, now) {
            Ok(io) => {
                self.absorb_io(io);
                Ok(io)
            }
            Err(e) => Err(self.record_ctrl_error(e, phase)),
        }
    }

    /// Run two consecutive protocol phases overlapped through the plane
    /// (pipelined path). Fail-fast and unreachable bookkeeping mirror
    /// [`Coordinator::phase_exchange`]; a failure is attributed to the
    /// *first* phase of the pair (the broadcast that entered the plane
    /// first).
    pub fn phase_exchange_overlapped(
        &mut self,
        first: Phase,
        second: Phase,
        now: SimTime,
    ) -> Result<OverlapIo, CkptFailure> {
        if let Some((rank, f)) = self.unreachable {
            return Err(CkptFailure::Unreachable { rank, phase: f });
        }
        match self.plane.exchange_overlapped(&mut self.ctrl, first, second, now) {
            Ok(o) => {
                self.absorb_io(o.first);
                self.absorb_io(o.second);
                self.stats.stale_acks += o.stale_acks;
                Ok(o)
            }
            Err(e) => Err(self.record_ctrl_error(e, first)),
        }
    }

    /// DRAIN convergence check: reduce the per-rank (sent, recv) counters
    /// up the plane and compare the aggregates. Returns whether the counts
    /// balanced plus the exchange accounting.
    pub fn drain_reduce(
        &mut self,
        counts: &[(u64, u64)],
        now: SimTime,
    ) -> Result<(bool, PhaseIo), CkptFailure> {
        if let Some((rank, first)) = self.unreachable {
            return Err(CkptFailure::Unreachable { rank, phase: first });
        }
        match self.plane.reduce_counts(&mut self.ctrl, counts, now) {
            Ok(red) => {
                self.absorb_io(red.io);
                Ok((red.sent == red.recv, red.io))
            }
            Err(e) => Err(self.record_ctrl_error(e, Phase::Drain)),
        }
    }

    /// Topological-sort drain (arXiv:2408.02218): instead of reducing
    /// byte counters to convergence, order ranks by their round cursor in
    /// the pending collective — deepest cursor first, so every rank's
    /// image is cut at a point consistent with the rounds its peers have
    /// already contributed — and checkpoint them wave by wave. The wave
    /// schedule ships down the plane as one bounded object, so the
    /// control cost is per hop, independent of the counter-reduce fan-in.
    /// Returns the wave count and the exchange accounting.
    pub fn topo_drain(
        &mut self,
        cursors: &[u32],
        now: SimTime,
    ) -> Result<(u32, PhaseIo), CkptFailure> {
        if let Some((rank, first)) = self.unreachable {
            return Err(CkptFailure::Unreachable { rank, phase: first });
        }
        let mut waves: Vec<u32> = cursors.to_vec();
        waves.sort_unstable_by(|a, b| b.cmp(a));
        waves.dedup();
        let nwaves = waves.len().max(1) as u32;
        match self.plane.drain_schedule(&mut self.ctrl, nwaves, now) {
            Ok(io) => {
                self.absorb_io(io);
                Ok((nwaves, io))
            }
            Err(e) => Err(self.record_ctrl_error(e, Phase::Drain)),
        }
    }

    fn absorb_io(&mut self, io: PhaseIo) {
        self.stats.ctrl_msgs += io.msgs;
        self.stats.root_msgs += io.root_msgs;
        self.stats.reparents += io.reparents as u64;
        self.stats.phase_retries += io.retries as u64;
    }

    fn record_ctrl_error(&mut self, e: CtrlError, phase: Phase) -> CkptFailure {
        if let CtrlError::Unreachable { rank, .. } = e {
            self.tracer.warn(
                "coordinator",
                format!("coord.unreachable:r{}", rank.0),
                EventCtx::rank(rank.0),
                format!("{rank} unreachable in {phase} phase — marked; later phases fail fast"),
            );
            self.unreachable = Some((rank, phase));
            return CkptFailure::Unreachable { rank, phase };
        }
        CkptFailure::ControlPlane(e)
    }

    /// Update a rank's status row. With the locks fix, the mutation is
    /// guarded; without it, `interrupt` (fault injection) leaves the table
    /// mid-update.
    pub fn set_rank_state(&mut self, rank: RankId, state: RankState, interrupt: bool) {
        if self.locks_fix || !interrupt {
            self.status.update(|rows| {
                rows[rank.0 as usize].state = state;
            });
        } else {
            self.status.update_interrupted(|rows| {
                rows[rank.0 as usize].state = state;
            });
        }
    }

    /// Consistent read of the status table; a detected race is the paper's
    /// "data structures … left in an inconsistent state due to missing
    /// locks" bug.
    pub fn check_status_consistent(&mut self) -> Result<(), CkptFailure> {
        match self.status.read() {
            Ok(_) => Ok(()),
            Err(e) => {
                self.stats.races_detected += 1;
                Err(CkptFailure::RaceDetected(e.to_string()))
            }
        }
    }

    /// Record traffic counters reported by a rank at its safe point.
    pub fn record_rank_counts(&mut self, rank: RankId, step: u64, sent: u64, recv: u64) {
        self.status.update(|rows| {
            let row = &mut rows[rank.0 as usize];
            row.step = step;
            row.sent_bytes = sent;
            row.recv_bytes = recv;
        });
    }

    /// The paper's drain condition, evaluated over the coordinator's own
    /// table (console/debug view; the protocol-path check is
    /// [`Coordinator::drain_reduce`], which charges control traffic).
    pub fn counts_balanced(&mut self) -> Result<bool, CkptFailure> {
        let rows = self
            .status
            .read()
            .map_err(|e| CkptFailure::RaceDetected(e.to_string()))?;
        let sent: u64 = rows.iter().map(|r| r.sent_bytes).sum();
        let recv: u64 = rows.iter().map(|r| r.recv_bytes).sum();
        Ok(sent == recv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::control::CtrlConfig;

    fn coord(ranks: u32, keepalive: bool, loss: f64, locks: bool) -> Coordinator {
        let ctrl = ControlNet::new(
            CtrlConfig {
                keepalive,
                loss_prob: loss,
                ..CtrlConfig::default()
            },
            7,
        );
        Coordinator::flat(ctrl, ranks, locks)
    }

    #[test]
    fn intent_exchange_clean() {
        let mut c = coord(64, true, 0.0, true);
        let io = c.phase_exchange(Phase::Intent, SimTime::ZERO).unwrap();
        assert!(io.secs > 0.0 && io.secs < 0.01);
        // Flat: the root touches every message, both sweeps.
        assert_eq!(io.msgs, 128);
        assert_eq!(io.root_msgs, 128);
        assert_eq!(c.stats.ctrl_msgs, 128);
    }

    #[test]
    fn intent_exchange_fails_without_keepalive_under_loss() {
        let mut c = coord(512, false, 0.1, true);
        match c.phase_exchange(Phase::Intent, SimTime::ZERO) {
            Err(CkptFailure::ControlPlane(_)) => {}
            other => panic!("expected control-plane failure, got {other:?}"),
        }
    }

    #[test]
    fn intent_exchange_survives_loss_with_keepalive() {
        let mut c = coord(512, true, 0.1, true);
        let io = c.phase_exchange(Phase::Intent, SimTime::ZERO).unwrap();
        // Retries cost time — visible in the report.
        assert!(io.secs >= c.ctrl.cfg.latency);
        assert!(c.ctrl.stats.retries > 0);
    }

    #[test]
    fn unreachable_rank_marked_once_then_fails_fast() {
        // Pathological loss: KeepAlive exhausts its retries on rank 0.
        let mut c = coord(8, true, 1.0, true);
        match c.phase_exchange(Phase::Intent, SimTime::ZERO) {
            Err(CkptFailure::Unreachable { rank, phase }) => {
                assert_eq!(rank, RankId(0));
                assert_eq!(phase, Phase::Intent);
            }
            other => panic!("expected Unreachable, got {other:?}"),
        }
        let sent_before = c.ctrl.stats.sent;
        let retries_before = c.ctrl.stats.retries;
        // A later phase fails fast on the record: same rank, the phase
        // that first detected it, and no new network traffic.
        match c.phase_exchange(Phase::Write, SimTime::ZERO) {
            Err(CkptFailure::Unreachable { rank, phase }) => {
                assert_eq!(rank, RankId(0));
                assert_eq!(phase, Phase::Intent, "report names the first phase");
            }
            other => panic!("expected fail-fast Unreachable, got {other:?}"),
        }
        assert_eq!(c.ctrl.stats.sent, sent_before, "no re-probe of the dead link");
        assert_eq!(c.ctrl.stats.retries, retries_before, "no re-timeout");
        let msg = CkptFailure::Unreachable {
            rank: RankId(0),
            phase: Phase::Intent,
        }
        .to_string();
        assert!(msg.contains("rank0") && msg.contains("INTENT"), "{msg}");
    }

    #[test]
    fn race_detected_without_locks_fix() {
        let mut c = coord(4, true, 0.0, false);
        c.set_rank_state(RankId(1), RankState::SafePoint, true); // interrupted
        match c.check_status_consistent() {
            Err(CkptFailure::RaceDetected(w)) => {
                assert!(w.contains("rank_status"));
                assert_eq!(c.stats.races_detected, 1);
            }
            other => panic!("expected race, got {other:?}"),
        }
    }

    #[test]
    fn locks_fix_masks_interruption() {
        let mut c = coord(4, true, 0.0, true);
        c.set_rank_state(RankId(1), RankState::SafePoint, true);
        c.check_status_consistent().unwrap();
        assert_eq!(c.status.read().unwrap()[1].state, RankState::SafePoint);
    }

    #[test]
    fn draining_state_tracked() {
        let mut c = coord(4, true, 0.0, true);
        c.set_rank_state(RankId(2), RankState::Draining, false);
        assert_eq!(c.status.read().unwrap()[2].state, RankState::Draining);
    }

    #[test]
    fn counts_balanced_tracks_reports() {
        let mut c = coord(2, true, 0.0, true);
        c.record_rank_counts(RankId(0), 5, 1000, 400);
        c.record_rank_counts(RankId(1), 5, 200, 800);
        assert!(c.counts_balanced().unwrap());
        c.record_rank_counts(RankId(0), 5, 1100, 400);
        assert!(!c.counts_balanced().unwrap());
    }

    #[test]
    fn flat_drain_reduce_aggregates_and_charges_root() {
        let mut c = coord(4, true, 0.0, true);
        let counts = vec![(100, 40), (20, 80), (5, 5), (0, 0)];
        let (balanced, io) = c.drain_reduce(&counts, SimTime::ZERO).unwrap();
        assert!(balanced, "125 sent == 125 received");
        assert_eq!(io.root_msgs, 8, "flat root touches 2 x ranks");
        let (unbalanced, _) = c.drain_reduce(&[(10, 0), (0, 5)], SimTime::ZERO).unwrap();
        assert!(!unbalanced);
    }

    #[test]
    fn topo_drain_cost_is_independent_of_rank_count() {
        // The wave schedule is one bounded object: the flat plane's topo
        // drain charges the same control cost at 8 and 4096 ranks, while
        // the counter reduce pays O(ranks) at the root.
        let mut small = coord(8, true, 0.0, true);
        let mut big = coord(4096, true, 0.0, true);
        let cursors_small: Vec<u32> = (0..8).map(|i: u32| i % 3).collect();
        let cursors_big: Vec<u32> = (0..4096).map(|i: u32| i % 3).collect();
        let (w_s, io_s) = small.topo_drain(&cursors_small, SimTime::ZERO).unwrap();
        let (w_b, io_b) = big.topo_drain(&cursors_big, SimTime::ZERO).unwrap();
        assert_eq!(w_s, 3);
        assert_eq!(w_b, 3);
        assert_eq!(io_s.root_msgs, 1);
        assert_eq!(io_b.root_msgs, 1);
        assert!((io_s.secs - io_b.secs).abs() < 1e-12);
        let counts: Vec<(u64, u64)> = vec![(1, 1); 4096];
        let (_, reduce_io) = big.drain_reduce(&counts, SimTime::ZERO).unwrap();
        assert!(
            reduce_io.secs > 10.0 * io_b.secs,
            "counter reduce {} should dwarf topo schedule {}",
            reduce_io.secs,
            io_b.secs
        );
    }

    #[test]
    fn topo_drain_empty_cursors_degenerates_to_one_wave() {
        let mut c = coord(4, true, 0.0, true);
        let (waves, _) = c.topo_drain(&[], SimTime::ZERO).unwrap();
        assert_eq!(waves, 1, "no pending collective = a single wave");
    }

    #[test]
    fn flat_overlap_is_the_serial_fallback() {
        // The flat plane serializes both sweeps at one endpoint — no
        // overlap credit, but full per-phase accounting.
        let mut c = coord(64, true, 0.0, true);
        let o = c
            .phase_exchange_overlapped(Phase::Intent, Phase::SafePoint, SimTime::ZERO)
            .unwrap();
        assert_eq!(o.secs, o.first.secs + o.second.secs);
        assert_eq!(o.stale_acks, 0);
        assert_eq!(c.stats.stale_acks, 0);
        assert_eq!(c.stats.ctrl_msgs, o.first.msgs + o.second.msgs);
        assert!(o.first.down_secs > 0.0 && o.first.down_secs < o.first.secs);
    }

    #[test]
    fn flat_plane_shape() {
        let p = FlatPlane::new(16);
        assert_eq!(p.depth(), 1);
        let g = p.groups();
        assert_eq!(g.len(), 1);
        assert_eq!(g[0].ranks.len(), 16);
        assert!(p.describe().contains("flat"));
    }
}
