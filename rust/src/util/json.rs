//! Tiny JSON writer + reader (no serde offline).
//!
//! Benches and the CLI emit machine-readable reports (EXPERIMENTS.md is
//! generated from them); this module provides just enough JSON to do that
//! correctly, including string escaping and stable key order. The reader
//! ([`Json::parse`]) exists for the `bench_report` regression harness,
//! which aggregates and validates the `BENCH_*.json` artifacts the benches
//! write.

use std::fmt::Write as _;

/// A JSON value built imperatively.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object (stable output for diffs).
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut fields) = self {
            fields.push((key.to_string(), value.into()));
        } else {
            panic!("set() on non-object Json");
        }
        self
    }

    pub fn push(&mut self, value: impl Into<Json>) {
        if let Json::Arr(ref mut items) = self {
            items.push(value.into());
        } else {
            panic!("push() on non-array Json");
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

// ------------------------------------------------------------- accessors

impl Json {
    /// Object field lookup (first match; our writer never duplicates keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(fields) => Some(fields),
            _ => None,
        }
    }
}

// -------------------------------------------------------------- parsing

impl Json {
    /// Parse a complete JSON document. Strict enough for the artifacts
    /// this crate writes (objects, arrays, strings with the escapes the
    /// writer emits plus `\uXXXX`, numbers, booleans, null); returns
    /// `None` on any syntax error or trailing garbage.
    pub fn parse(s: &str) -> Option<Json> {
        let b = s.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(b, &mut pos, 0)?;
        skip_ws(b, &mut pos);
        if pos == b.len() {
            Some(v)
        } else {
            None
        }
    }
}

/// Nesting cap: parsing recurses per level, so a corrupt artifact made of
/// repeated `[`s must become a clean parse error, not a stack overflow.
const MAX_PARSE_DEPTH: u32 = 128;

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Option<()> {
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == c {
        *pos += 1;
        Some(())
    } else {
        None
    }
}

fn parse_value(b: &[u8], pos: &mut usize, depth: u32) -> Option<Json> {
    if depth > MAX_PARSE_DEPTH {
        return None;
    }
    skip_ws(b, pos);
    match *b.get(*pos)? {
        b'{' => {
            *pos += 1;
            let mut fields = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Some(Json::Obj(fields));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                expect(b, pos, b':')?;
                let val = parse_value(b, pos, depth + 1)?;
                fields.push((key, val));
                skip_ws(b, pos);
                match b.get(*pos)? {
                    b',' => *pos += 1,
                    b'}' => {
                        *pos += 1;
                        return Some(Json::Obj(fields));
                    }
                    _ => return None,
                }
            }
        }
        b'[' => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Some(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos, depth + 1)?);
                skip_ws(b, pos);
                match b.get(*pos)? {
                    b',' => *pos += 1,
                    b']' => {
                        *pos += 1;
                        return Some(Json::Arr(items));
                    }
                    _ => return None,
                }
            }
        }
        b'"' => parse_string(b, pos).map(Json::Str),
        b't' => {
            if b.len() >= *pos + 4 && &b[*pos..*pos + 4] == b"true" {
                *pos += 4;
                Some(Json::Bool(true))
            } else {
                None
            }
        }
        b'f' => {
            if b.len() >= *pos + 5 && &b[*pos..*pos + 5] == b"false" {
                *pos += 5;
                Some(Json::Bool(false))
            } else {
                None
            }
        }
        b'n' => {
            if b.len() >= *pos + 4 && &b[*pos..*pos + 4] == b"null" {
                *pos += 4;
                Some(Json::Null)
            } else {
                None
            }
        }
        b'-' | b'0'..=b'9' => {
            let start = *pos;
            *pos += 1;
            while *pos < b.len()
                && matches!(b[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
            {
                *pos += 1;
            }
            std::str::from_utf8(&b[start..*pos])
                .ok()?
                .parse::<f64>()
                .ok()
                .map(Json::Num)
        }
        _ => None,
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Option<String> {
    skip_ws(b, pos);
    if *b.get(*pos)? != b'"' {
        return None;
    }
    *pos += 1;
    // Accumulate raw bytes and validate UTF-8 once at the end: the input
    // came from a &str, so unescaped spans are valid by construction, and
    // a single final check keeps parsing O(n).
    let mut out: Vec<u8> = Vec::new();
    loop {
        match *b.get(*pos)? {
            b'"' => {
                *pos += 1;
                return String::from_utf8(out).ok();
            }
            b'\\' => {
                *pos += 1;
                match *b.get(*pos)? {
                    b'"' => out.push(b'"'),
                    b'\\' => out.push(b'\\'),
                    b'/' => out.push(b'/'),
                    b'n' => out.push(b'\n'),
                    b'r' => out.push(b'\r'),
                    b't' => out.push(b'\t'),
                    b'b' => out.push(8),
                    b'f' => out.push(12),
                    b'u' => {
                        let hex = b.get(*pos + 1..*pos + 5)?;
                        let code =
                            u32::from_str_radix(std::str::from_utf8(hex).ok()?, 16).ok()?;
                        // Surrogates (which our writer never emits) map to
                        // the replacement character rather than failing.
                        let c = char::from_u32(code).unwrap_or('\u{fffd}');
                        let mut buf = [0u8; 4];
                        out.extend_from_slice(c.encode_utf8(&mut buf).as_bytes());
                        *pos += 4;
                    }
                    _ => return None,
                }
                *pos += 1;
            }
            raw => {
                out.push(raw);
                *pos += 1;
            }
        }
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn object_order_stable() {
        let j = Json::obj().set("b", 1u64).set("a", 2u64);
        assert_eq!(j.to_string(), r#"{"b":1,"a":2}"#);
    }

    #[test]
    fn string_escaping() {
        let j = Json::Str("a\"b\\c\nd\u{1}".into());
        assert_eq!(j.to_string(), "\"a\\\"b\\\\c\\nd\\u0001\"");
    }

    #[test]
    fn numbers_integral_and_float() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn nested_array() {
        let mut arr = Json::Arr(vec![]);
        arr.push(1u64);
        arr.push("x");
        let j = Json::obj().set("xs", arr).set("ok", true);
        assert_eq!(j.to_string(), r#"{"xs":[1,"x"],"ok":true}"#);
    }

    // ------------------------------------------------------------ parsing

    #[test]
    fn parse_roundtrips_writer_output() {
        let mut arr = Json::Arr(vec![]);
        arr.push(Json::obj().set("ratio", 0.731).set("pass", true));
        arr.push(Json::Null);
        let j = Json::obj()
            .set("bench", "staged_drain")
            .set("rows", arr)
            .set("count", 3u64)
            .set("note", "a\"b\\c\nd\ttab");
        let text = j.to_string();
        let back = Json::parse(&text).expect("writer output must parse");
        assert_eq!(back, j);
        assert_eq!(back.to_string(), text, "parse/serialize is stable");
    }

    #[test]
    fn parse_accessors() {
        let j = Json::parse(r#"{"a": 1.5, "b": "x", "c": [1, 2], "d": {"e": null}}"#)
            .unwrap();
        assert_eq!(j.get("a").and_then(Json::as_f64), Some(1.5));
        assert_eq!(j.get("b").and_then(Json::as_str), Some("x"));
        assert_eq!(j.get("c").and_then(Json::as_arr).map(<[Json]>::len), Some(2));
        assert_eq!(j.get("d").and_then(|d| d.get("e")), Some(&Json::Null));
        assert_eq!(j.get("missing"), None);
    }

    #[test]
    fn parse_numbers_and_literals() {
        assert_eq!(Json::parse("-3.25e2"), Some(Json::Num(-325.0)));
        assert_eq!(Json::parse(" true "), Some(Json::Bool(true)));
        assert_eq!(Json::parse("false"), Some(Json::Bool(false)));
        assert_eq!(Json::parse("null"), Some(Json::Null));
        assert_eq!(Json::parse("[]"), Some(Json::Arr(vec![])));
        assert_eq!(Json::parse("{}"), Some(Json::Obj(vec![])));
    }

    #[test]
    fn parse_unicode_escape_and_multibyte_passthrough() {
        // \uXXXX escape decodes; literal multi-byte UTF-8 passes through.
        let escaped = "\"a\\u00e9b\"";
        assert_eq!(Json::parse(escaped), Some(Json::Str("a\u{e9}b".into())));
        assert_eq!(Json::parse("\"aéb\""), Some(Json::Str("aéb".into())));
    }

    #[test]
    fn parse_rejects_garbage() {
        for bad in [
            "",
            "{",
            "[1,",
            "{\"a\"}",
            "{\"a\":}",
            "tru",
            "1 2",
            "{\"a\":1,}",
            "\"unterminated",
            "{'single': 1}",
        ] {
            assert_eq!(Json::parse(bad), None, "must reject {bad:?}");
        }
        // Nesting past the depth cap is a clean error, never a stack
        // overflow (the bench-report binary parses untrusted artifacts).
        let deep = "[".repeat(100_000);
        assert_eq!(Json::parse(&deep), None);
        let ok = format!("{}1{}", "[".repeat(64), "]".repeat(64));
        assert!(Json::parse(&ok).is_some(), "shallow nesting still parses");
    }
}
