//! Minimal leveled logger with rank/node context.
//!
//! One of the paper's "Lessons Learned" (#4) is *better attention to
//! warnings and error messages from the beginning*; the simulator follows
//! it: every subsystem logs through this module with a rank-to-node prefix
//! (the instrumentation the authors added to debug MANA: "we instrumented
//! the code to add rank-to-node and process-id mapping").
//!
//! The logger is a process-global with an atomic level so tests can silence
//! it; records can also be captured for assertions (warning-emission is
//! itself a tested behaviour, e.g. the disk-space warning).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Trace = 0,
    Debug = 1,
    Info = 2,
    Warn = 3,
    Error = 4,
    Off = 5,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Warn as u8);
static CAPTURE: Mutex<Option<Vec<Record>>> = Mutex::new(None);

/// A captured log record (used by tests asserting on warnings).
#[derive(Clone, Debug)]
pub struct Record {
    pub level: Level,
    pub target: String,
    pub message: String,
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Trace,
        1 => Level::Debug,
        2 => Level::Info,
        3 => Level::Warn,
        4 => Level::Error,
        _ => Level::Off,
    }
}

/// Begin capturing records (tests). Returns previously captured records.
pub fn capture_start() {
    *CAPTURE.lock().unwrap() = Some(Vec::new());
}

/// Stop capturing and return everything captured.
pub fn capture_take() -> Vec<Record> {
    CAPTURE.lock().unwrap().take().unwrap_or_default()
}

pub fn log(level: Level, target: &str, message: &str) {
    if let Some(buf) = CAPTURE.lock().unwrap().as_mut() {
        buf.push(Record {
            level,
            target: target.to_string(),
            message: message.to_string(),
        });
    }
    if level >= self::level() && self::level() != Level::Off {
        let tag = match level {
            Level::Trace => "TRACE",
            Level::Debug => "DEBUG",
            Level::Info => "INFO ",
            Level::Warn => "WARN ",
            Level::Error => "ERROR",
            Level::Off => return,
        };
        eprintln!("[{tag}] {target}: {message}");
    }
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info,
                                   $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn,
                                   $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error,
                                   $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug,
                                   $target, &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capture_records_warnings() {
        capture_start();
        log(Level::Warn, "fs", "insufficient space");
        log(Level::Info, "mpi", "hello");
        let recs = capture_take();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].level, Level::Warn);
        assert!(recs[0].message.contains("insufficient"));
        // Capture is drained.
        assert!(capture_take().is_empty());
    }

    #[test]
    fn level_roundtrip() {
        let old = level();
        set_level(Level::Error);
        assert_eq!(level(), Level::Error);
        set_level(old);
    }
}
