//! REL — reliability matrix: every paper bug class x {prototype, per-fix
//! ablation, production}.
//!
//! For each fault, the full C/R cycle (launch → steps → ckpt → kill →
//! restart → steps → verify) runs under three configurations:
//!   prototype  — all fixes off (2019 research MANA)
//!   ablation   — all fixes on EXCEPT the one that addresses this fault
//!   production — all fixes on (this work)
//!
//! Expected: prototype/ablation fail deterministically, production passes
//! (or diagnoses cleanly where failing loudly is the fix: CRC, disk space).
//!
//! A second matrix (`node_loss_matrix`) covers fast-tier redundancy: BB
//! node/set loss x {none, partner, xor} x drain progress, gating that
//! peer rebuild keeps single-node losses off the durable tier and that
//! the exchange overhead stays a small fraction of the BB write wave
//! (emits BENCH_reliability.json for the CI bench-report job).

use mana::benchkit::Report;
use mana::config::{AppKind, Fixes, RunConfig};
use mana::faults::FaultPlan;
use mana::fs::RedundancyScheme;
use mana::sim::JobSim;
use mana::topology::NodeId;
use mana::util::json::Json;

#[derive(Clone)]
struct Case {
    name: &'static str,
    faults: FaultPlan,
    /// Turn the relevant fix off in an otherwise-production config.
    ablate: fn(&mut Fixes),
    /// Production is expected to fail-with-diagnosis rather than pass.
    diagnose_only: bool,
}

/// One full C/R cycle; Err(reason) on any failure or corruption.
fn cycle(mut cfg: RunConfig) -> Result<(), String> {
    cfg.mem_per_rank = Some(1 << 20);
    let mut sim = JobSim::launch(cfg.clone(), None).map_err(|e| format!("launch: {e}"))?;
    sim.run_steps(3).map_err(|e| format!("run: {e}"))?;
    let rep = sim.checkpoint().map_err(|e| format!("ckpt: {e}"))?;
    if rep.lost_messages > 0 {
        return Err(format!("{} msgs lost at ckpt", rep.lost_messages));
    }
    let fs = sim.kill();
    let (mut resumed, _) =
        JobSim::restart_from(cfg, None, fs).map_err(|e| format!("restart: {e}"))?;
    resumed.run_steps(3).map_err(|e| format!("resume: {e}"))?;
    if resumed.any_corruption() {
        return Err("corruption after restart".into());
    }
    Ok(())
}

fn outcome(r: &Result<(), String>) -> &'static str {
    match r {
        Ok(()) => "pass",
        Err(_) => "FAIL",
    }
}

// --------------------------------------------------------------------
// Node-loss matrix: redundancy scheme x loss pattern x drain progress.
//
// Two checkpoint generations on the staged tier (gen 0 fully durable,
// gen 1 either still mid-drain or drained too), then a Burst-Buffer
// blade loss while the job is down. Partner/XOR must rebuild the lost
// node's images from surviving peers without a single durable-tier
// read; `none` must recover via Lustre (drained) or by rewinding a
// generation (mid-drain, SCR `complete_restart(valid)`).

/// Staged 16-rank config spread over 8 nodes (2 redundancy sets of 4).
fn loss_cfg(scheme: RedundancyScheme, tag: &str) -> RunConfig {
    let mut cfg = RunConfig::new(AppKind::Synthetic, 16).with_staging();
    cfg.threads_per_rank = 32; // 2 ranks/node -> 8 nodes
    cfg.mem_per_rank = Some(1 << 20);
    cfg.redundancy = scheme;
    cfg.job = format!("rel-loss-{}-{tag}", scheme.name());
    cfg
}

struct LossOutcome {
    rebuilt_nodes: u32,
    durable_read_files: u32,
    generation_rewound: u64,
    fingerprint_ok: bool,
    exchange_secs: f64,
}

/// One loss cycle: 2 steps -> ckpt gen 0 -> drain -> 2 steps -> ckpt
/// gen 1 (drained or left mid-flight) -> kill -> lose fast tiers ->
/// restart -> 2 steps -> verify against the uninterrupted fingerprints.
fn loss_cycle(
    scheme: RedundancyScheme,
    drain_done: bool,
    faults: FaultPlan,
    fp4: u64,
    fp6: u64,
) -> Result<LossOutcome, String> {
    let tag = if drain_done { "drained" } else { "pending" };
    let cfg = loss_cfg(scheme, tag);
    let mut sim = JobSim::launch(cfg, None).map_err(|e| format!("launch: {e}"))?;
    sim.run_steps(2).map_err(|e| format!("run: {e}"))?;
    sim.checkpoint().map_err(|e| format!("ckpt0: {e}"))?;
    sim.finish_drain(); // generation 0 is always fully durable
    sim.run_steps(2).map_err(|e| format!("run: {e}"))?;
    let crep = sim.checkpoint().map_err(|e| format!("ckpt1: {e}"))?;
    if drain_done {
        sim.finish_drain();
    } else if sim.fs.tiered().unwrap().pending_files() == 0 {
        return Err("expected generation 1 to still be mid-drain".into());
    }
    let mut rcfg = sim.cfg.clone();
    rcfg.faults = faults;
    let fs = sim.kill();
    let (mut resumed, rrep) =
        JobSim::restart_from(rcfg, None, fs).map_err(|e| format!("restart: {e}"))?;
    resumed.run_steps(2).map_err(|e| format!("resume: {e}"))?;
    if resumed.any_corruption() {
        return Err("corruption after restart".into());
    }
    // A rewound restart resumes from gen 0 (step 2) and lands on the
    // step-4 fingerprint; otherwise gen 1 (step 4) lands on step 6.
    let want = if rrep.generation_rewound > 0 { fp4 } else { fp6 };
    Ok(LossOutcome {
        rebuilt_nodes: rrep.rebuilt_nodes,
        durable_read_files: rrep.durable_read_files,
        generation_rewound: rrep.generation_rewound,
        fingerprint_ok: resumed.fingerprint() == want,
        exchange_secs: crep.exchange_secs,
    })
}

/// Exchange overhead at 512 ranks: the peer exchange pipelines behind
/// the BB write wave, so its rank-visible cost must stay a small
/// fraction of the wave.
fn exchange_overhead_512() -> f64 {
    let mut cfg = RunConfig::new(AppKind::Synthetic, 512).with_staging();
    cfg.threads_per_rank = 8; // 8 ranks/node -> 64 nodes
    cfg.mem_per_rank = Some(512 << 10);
    cfg.redundancy = RedundancyScheme::Partner;
    cfg.job = "rel-exchange-512".into();
    let mut sim = JobSim::launch(cfg, None).expect("launch");
    sim.run_steps(1).expect("run");
    let rep = sim.checkpoint().expect("ckpt");
    assert!(rep.exchange_secs > 0.0, "partner exchange must be charged");
    assert!(rep.parity_bytes > 0);
    rep.exchange_secs / rep.fast_write_secs
}

fn node_loss_matrix() {
    // Uninterrupted control fingerprints at steps 4 and 6.
    let (fp4, fp6) = {
        let mut sim = JobSim::launch(loss_cfg(RedundancyScheme::None, "control"), None)
            .expect("launch");
        sim.run_steps(4).expect("run");
        let fp4 = sim.fingerprint();
        sim.run_steps(2).expect("run");
        (fp4, sim.fingerprint())
    };

    let mut rep = Report::new(
        "REL-LOSS: BB node loss x redundancy scheme x drain progress",
        vec![
            "scheme",
            "loss",
            "drain",
            "rebuilt_nodes",
            "durable_reads",
            "rewound",
            "state",
        ],
    );
    let mut rows = Json::Arr(vec![]);
    let mut partner_durable = 0u32;
    let mut xor_durable = 0u32;
    let mut fp_bad = 0u32;
    let mut none_recovered = 0u32;
    let mut none_exchange = 0.0f64;

    let schemes = [
        RedundancyScheme::None,
        RedundancyScheme::Partner,
        RedundancyScheme::Xor,
    ];
    for scheme in schemes {
        for drain_done in [false, true] {
            // Node 5 sits in set 1 (nodes 4..=7) and owns ranks 10, 11.
            let faults = FaultPlan {
                bb_node_loss: vec![(NodeId(5), 0.0)],
                ..FaultPlan::none()
            };
            let o = loss_cycle(scheme, drain_done, faults, fp4, fp6).unwrap_or_else(|e| {
                panic!("{}/single-node loss cycle failed: {e}", scheme.name())
            });
            if !o.fingerprint_ok {
                fp_bad += 1;
            }
            match scheme {
                RedundancyScheme::None => {
                    none_exchange = none_exchange.max(o.exchange_secs);
                    // Drained: the lost node is served from Lustre.
                    // Mid-drain: gen 1 is gone everywhere -> rewind.
                    let recovered = if drain_done {
                        o.durable_read_files >= 2 && o.generation_rewound == 0
                    } else {
                        o.generation_rewound == 1
                    };
                    assert!(
                        recovered,
                        "none/{}: expected durable fallback or rewind \
                         (durable_reads {}, rewound {})",
                        if drain_done { "drained" } else { "pending" },
                        o.durable_read_files,
                        o.generation_rewound
                    );
                    none_recovered += 1;
                }
                RedundancyScheme::Partner | RedundancyScheme::Xor => {
                    assert!(
                        o.rebuilt_nodes >= 1,
                        "{}: the lost node must rebuild from peers",
                        scheme.name()
                    );
                    assert_eq!(
                        o.generation_rewound, 0,
                        "{}: peer rebuild must not rewind",
                        scheme.name()
                    );
                    if scheme == RedundancyScheme::Partner {
                        partner_durable = partner_durable.max(o.durable_read_files);
                    } else {
                        xor_durable = xor_durable.max(o.durable_read_files);
                    }
                }
            }
            rep.row(vec![
                scheme.name().into(),
                "node 5".into(),
                if drain_done { "drained" } else { "pending" }.into(),
                o.rebuilt_nodes.to_string(),
                o.durable_read_files.to_string(),
                o.generation_rewound.to_string(),
                if o.fingerprint_ok { "bitwise" } else { "MISMATCH" }.into(),
            ]);
            rows.push(
                Json::obj()
                    .set("scheme", scheme.name())
                    .set("loss", "single_node")
                    .set("drained", drain_done)
                    .set("rebuilt_nodes", o.rebuilt_nodes as u64)
                    .set("durable_read_files", o.durable_read_files as u64)
                    .set("generation_rewound", o.generation_rewound)
                    .set("fingerprint_ok", o.fingerprint_ok),
            );
        }
    }

    // Whole-set loss mid-drain: deterministically unrecoverable from
    // peers (every copy and parity block died with the set) — both
    // schemes must rewind to the durable generation 0.
    for scheme in [RedundancyScheme::Partner, RedundancyScheme::Xor] {
        let faults = FaultPlan {
            bb_set_loss: vec![(1, 0.0)],
            ..FaultPlan::none()
        };
        let o = loss_cycle(scheme, false, faults, fp4, fp6).unwrap_or_else(|e| {
            panic!("{}/set loss cycle failed: {e}", scheme.name())
        });
        assert_eq!(
            o.generation_rewound, 1,
            "{}: whole-set loss must rewind one generation",
            scheme.name()
        );
        if !o.fingerprint_ok {
            fp_bad += 1;
        }
        rep.row(vec![
            scheme.name().into(),
            "set 1".into(),
            "pending".into(),
            o.rebuilt_nodes.to_string(),
            o.durable_read_files.to_string(),
            o.generation_rewound.to_string(),
            if o.fingerprint_ok { "bitwise" } else { "MISMATCH" }.into(),
        ]);
        rows.push(
            Json::obj()
                .set("scheme", scheme.name())
                .set("loss", "whole_set")
                .set("drained", false)
                .set("rebuilt_nodes", o.rebuilt_nodes as u64)
                .set("durable_read_files", o.durable_read_files as u64)
                .set("generation_rewound", o.generation_rewound)
                .set("fingerprint_ok", o.fingerprint_ok),
        );
    }
    rep.finish();

    let overhead = exchange_overhead_512();
    assert!(
        overhead <= 0.25,
        "exchange overhead {overhead:.3} above 25% of the BB write wave"
    );
    assert_eq!(fp_bad, 0, "{fp_bad} restarts were not bitwise identical");
    assert_eq!(partner_durable, 0, "partner rebuild leaked durable reads");
    assert_eq!(xor_durable, 0, "XOR rebuild leaked durable reads");

    let out = Json::obj()
        .set("bench", "reliability")
        .set(
            "gates",
            Json::obj()
                .set(
                    "reliability_partner_single_loss_durable_reads",
                    partner_durable as u64,
                )
                .set(
                    "reliability_xor_single_loss_durable_reads",
                    xor_durable as u64,
                )
                .set(
                    "reliability_single_loss_fingerprint_mismatches",
                    fp_bad as u64,
                )
                .set(
                    "reliability_none_loss_recovered_via_durable_or_rewind",
                    none_recovered as u64,
                )
                .set("reliability_exchange_overhead_512", overhead)
                .set("reliability_none_exchange_secs", none_exchange),
        )
        .set("rows", rows);
    std::fs::write("BENCH_reliability.json", out.to_string())
        .expect("write BENCH_reliability.json");
    println!(
        "REL-LOSS OK: peer rebuild kept single-node losses off the durable \
         tier; unprotected runs fell back or rewound (exchange overhead \
         {:.1}% of the BB wave at 512 ranks)",
        overhead * 100.0
    );
}

fn main() {
    let cases = vec![
        Case {
            name: "ctrl congestion (keepalive)",
            faults: FaultPlan::congested_network(),
            ablate: |f| f.keepalive = false,
            diagnose_only: false,
        },
        Case {
            name: "in-flight msgs (drain)",
            faults: FaultPlan::none(),
            ablate: |f| f.drain = false,
            diagnose_only: false,
        },
        Case {
            name: "fd collision (reserved fds)",
            faults: FaultPlan::none(),
            ablate: |f| f.fd_reservation = false,
            diagnose_only: false,
        },
        Case {
            name: "lower-half growth (noreplace)",
            faults: FaultPlan {
                lower_half_growth_events: 2,
                ..FaultPlan::none()
            },
            ablate: |f| f.noreplace = false,
            diagnose_only: false,
        },
        Case {
            name: "Isend semantics (careful conv)",
            faults: FaultPlan::none(),
            ablate: |f| f.careful_nonblocking = false,
            diagnose_only: false,
        },
        Case {
            name: "coordinator race (locks)",
            faults: FaultPlan {
                interrupt_status_update: true,
                ..FaultPlan::none()
            },
            ablate: |f| f.locks = false,
            diagnose_only: false,
        },
        Case {
            name: "image bitflip (CRC detects)",
            faults: FaultPlan {
                image_bitflip: Some((2, 150)),
                ..FaultPlan::none()
            },
            ablate: |_| {},
            diagnose_only: true,
        },
        Case {
            name: "disk shortfall (warning)",
            faults: FaultPlan {
                fs_capacity_override: Some(4 << 20),
                ..FaultPlan::none()
            },
            ablate: |_| {},
            diagnose_only: true,
        },
    ];

    let mut rep = Report::new(
        "REL: reliability matrix (C/R cycle under fault injection)",
        vec!["fault", "prototype", "ablation", "production", "expected"],
    );

    let mut bad = 0;
    for case in &cases {
        let mut proto = RunConfig::new(AppKind::Synthetic, 8);
        proto.job = format!("rel-proto-{}", case.name.len());
        proto.fixes = Fixes::all_off();
        proto.faults = case.faults.clone();
        let r_proto = cycle(proto);

        let mut abl = RunConfig::new(AppKind::Synthetic, 8);
        abl.job = format!("rel-abl-{}", case.name.len());
        abl.fixes = Fixes::all_on();
        (case.ablate)(&mut abl.fixes);
        abl.faults = case.faults.clone();
        let r_abl = cycle(abl);

        let mut prod = RunConfig::new(AppKind::Synthetic, 8);
        prod.job = format!("rel-prod-{}", case.name.len());
        prod.fixes = Fixes::all_on();
        prod.faults = case.faults.clone();
        let r_prod = cycle(prod);

        let expected = if case.diagnose_only {
            "diagnosed"
        } else {
            "fixed"
        };
        let prod_ok = if case.diagnose_only {
            r_prod.is_err() // loud, clean failure IS the fix
        } else {
            r_prod.is_ok()
        };
        // The ablated run must reproduce the failure (that's the evidence
        // the fix is what saves production).
        let abl_reproduces = r_abl.is_err() || case.diagnose_only;
        if !prod_ok || !abl_reproduces {
            bad += 1;
        }

        rep.row(vec![
            case.name.into(),
            outcome(&r_proto).into(),
            if case.diagnose_only {
                "n/a".into()
            } else {
                outcome(&r_abl).to_string()
            },
            match (&r_prod, case.diagnose_only) {
                (Err(_), true) => "diagnosed".into(),
                (r, _) => outcome(r).to_string(),
            },
            expected.into(),
        ]);
    }
    rep.finish();

    assert_eq!(bad, 0, "{bad} cases deviated from the paper's fix matrix");
    println!("REL OK: every fault reproduced under ablation and handled in production");

    node_loss_matrix();
}
