//! The paper's HPCG evaluation (in-text table): 512 ranks x 8 threads,
//! 5.8 TB aggregate memory.
//!
//! Paper numbers: checkpoint ~30 s on Burst Buffers vs >600 s on CSCRATCH
//! (>20x); restart speedup "more modest at about 2.5 times".
//!
//! Run: cargo run --release --example hpcg_512

use anyhow::Result;

use mana::config::{AppKind, RunConfig};
use mana::fs::FsKind;
use mana::sim::JobSim;
use mana::util::bytes::human;

struct Row {
    fs: &'static str,
    ckpt_secs: f64,
    restart_secs: f64,
}

fn measure(fs: FsKind) -> Result<(u64, Row)> {
    let mut cfg = RunConfig::new(AppKind::Hpcg, 512);
    cfg.job = format!("hpcg-512r-{fs:?}");
    cfg.fs = fs;
    let mut sim = JobSim::launch(cfg, None)?;
    sim.run_steps(2)?;
    let agg = sim.aggregate_memory();
    let rep = sim
        .checkpoint()
        .map_err(|e| anyhow::anyhow!("ckpt: {e}"))?;
    let cfg = sim.cfg.clone();
    let fsim = sim.kill();
    let (_, rrep) =
        JobSim::restart_from(cfg, None, fsim).map_err(|e| anyhow::anyhow!("restart: {e}"))?;
    Ok((
        agg,
        Row {
            fs: match fs {
                FsKind::BurstBuffer => "Burst Buffer",
                FsKind::Lustre => "CSCRATCH",
            },
            ckpt_secs: rep.write_secs,
            restart_secs: rrep.read_secs,
        },
    ))
}

fn main() -> Result<()> {
    println!("=== HPCG with MANA: 512 ranks x 8 threads ===\n");
    let (agg, bb) = measure(FsKind::BurstBuffer)?;
    let (_, lu) = measure(FsKind::Lustre)?;
    println!("aggregate memory: {} (paper: 5.8 TB)\n", human(agg));
    println!("{:>14} {:>14} {:>14}", "file system", "ckpt (s)", "restart (s)");
    for r in [&bb, &lu] {
        println!("{:>14} {:>14.1} {:>14.1}", r.fs, r.ckpt_secs, r.restart_secs);
    }
    let ckpt_speedup = lu.ckpt_secs / bb.ckpt_secs;
    let restart_speedup = lu.restart_secs / bb.restart_secs;
    println!(
        "\ncheckpoint speedup BB/CSCRATCH: {ckpt_speedup:.1}x (paper: >20x)\nrestart    speedup BB/CSCRATCH: {restart_speedup:.1}x (paper: ~2.5x)"
    );

    assert!((25.0..40.0).contains(&bb.ckpt_secs), "BB ckpt ~30s");
    assert!(lu.ckpt_secs > 600.0, "Lustre ckpt >600s");
    assert!(ckpt_speedup > 20.0);
    assert!((1.8..3.5).contains(&restart_speedup));
    println!("\nOK: matches the paper's HPCG table.");
    Ok(())
}
