//! PJRT integration tests: the L3↔L2/L1 bridge with real artifacts.
//!
//! These need `make artifacts` to have run. If the artifact directory is
//! missing they skip (so `cargo test` works from a clean checkout), but
//! `make test` always builds artifacts first.

use std::sync::Arc;

use mana::apps::{bytes_to_f32, hpcg::Hpcg, vasp_rpa::VaspRpa};
use mana::config::{AppKind, ComputeMode, RunConfig};
use mana::runtime::{default_artifact_dir, Engine};
use mana::sim::JobSim;

fn engine() -> Option<Arc<Engine>> {
    let dir = default_artifact_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: no artifacts at {dir:?} (run `make artifacts`)");
        return None;
    }
    Some(Arc::new(Engine::load(&dir).expect("engine load")))
}

#[test]
fn engine_loads_all_artifacts() {
    let Some(e) = engine() else { return };
    assert_eq!(e.artifact_names(), vec!["cg_step", "md_step", "rpa_step"]);
    assert_eq!(e.platform(), "cpu");
}

/// 256 atoms on a 7x7x7 lattice (spacing 1.71 > sigma): well-separated,
/// finite LJ forces. Atoms at identical coordinates would produce r=0 and
/// NaN — a physics property, not a bug.
fn lattice_pos(n_coords: usize) -> Vec<f32> {
    let mut pos = Vec::with_capacity(n_coords);
    let s = 12.0 / 7.0;
    let mut i = 0u32;
    while pos.len() < n_coords {
        let (x, y, z) = (i % 7, (i / 7) % 7, i / 49);
        pos.push(x as f32 * s + 0.3);
        pos.push(y as f32 * s + 0.3);
        pos.push(z as f32 * s + 0.3);
        i += 1;
    }
    pos.truncate(n_coords);
    pos
}

#[test]
fn md_step_executes_and_conserves_shape() {
    let Some(e) = engine() else { return };
    let spec = e.spec("md_step").unwrap();
    let n = spec.inputs[0].element_count();
    let pos = lattice_pos(n);
    let vel = vec![0.01f32; n];
    let out = e.run("md_step", &[&pos, &vel]).unwrap();
    assert_eq!(out.len(), 3);
    assert_eq!(out[0].len(), n);
    assert_eq!(out[2].len(), 1);
    assert!(out[2][0] > 0.0, "kinetic energy positive");
    // Positions stay in the box.
    assert!(out[0].iter().all(|&p| (0.0..12.0).contains(&p)));
}

#[test]
fn md_step_is_deterministic_across_calls() {
    let Some(e) = engine() else { return };
    let n = e.spec("md_step").unwrap().inputs[0].element_count();
    let pos = lattice_pos(n);
    let vel: Vec<f32> = (0..n).map(|i| (i as f32).sin() * 0.01).collect();
    let a = e.run("md_step", &[&pos, &vel]).unwrap();
    let b = e.run("md_step", &[&pos, &vel]).unwrap();
    assert_eq!(a, b, "PJRT compute must be bitwise deterministic");
}

#[test]
fn cg_step_reduces_residual_over_iterations() {
    let Some(e) = engine() else { return };
    let mut cfg = RunConfig::new(AppKind::Hpcg, 1);
    cfg.compute = ComputeMode::Real;
    cfg.mem_per_rank = Some(1 << 20);
    let mut sim = JobSim::launch(cfg, Some(e)).unwrap();
    let r0 = Hpcg::residual(&sim.procs[0]).unwrap();
    sim.run_steps(10).unwrap();
    sim.materialize().unwrap();
    let r10 = Hpcg::residual(&sim.procs[0]).unwrap();
    assert!(
        r10 < r0 * 0.01,
        "CG must converge: r0={r0}, r10={r10}"
    );
}

#[test]
fn rpa_energy_accumulates_monotonically() {
    let Some(e) = engine() else { return };
    let mut cfg = RunConfig::new(AppKind::VaspRpa, 1);
    cfg.compute = ComputeMode::Real;
    cfg.mem_per_rank = Some(1 << 20);
    let mut sim = JobSim::launch(cfg, Some(e)).unwrap();
    let mut last = 0.0f32;
    for _ in 0..3 {
        sim.run_steps(1).unwrap();
        sim.materialize().unwrap();
        let ec = VaspRpa::ecorr(&sim.procs[0]).unwrap();
        assert!(ec > last, "sum of squares grows with quadrature points");
        last = ec;
    }
}

#[test]
fn real_compute_cr_determinism_all_apps() {
    let Some(e) = engine() else { return };
    for app in [AppKind::Gromacs, AppKind::Hpcg, AppKind::VaspRpa] {
        let mut cfg = RunConfig::new(app, 2);
        cfg.compute = ComputeMode::Real;
        cfg.mem_per_rank = Some(1 << 20);
        cfg.job = format!("pjrt-{}", app.name());

        let mut cont = JobSim::launch(cfg.clone(), Some(e.clone())).unwrap();
        cont.run_steps(4).unwrap();
        let want = cont.fingerprint();

        let mut sim = JobSim::launch(cfg.clone(), Some(e.clone())).unwrap();
        sim.run_steps(2).unwrap();
        sim.checkpoint().unwrap();
        let fs = sim.kill();
        let (mut resumed, _) = JobSim::restart_from(cfg, Some(e.clone()), fs).unwrap();
        resumed.run_steps(2).unwrap();
        assert_eq!(resumed.fingerprint(), want, "{app:?} C/R determinism");
    }
}

#[test]
fn engine_rejects_wrong_shapes() {
    let Some(e) = engine() else { return };
    let bad = vec![1.0f32; 7];
    let err = e.run("md_step", &[&bad, &bad]).unwrap_err();
    assert!(err.to_string().contains("elements"), "{err}");
    assert!(e.run("md_step", &[&bad]).is_err(), "arity check");
    assert!(e.run("nope", &[]).is_err(), "unknown artifact");
}

#[test]
fn checkpointed_state_is_the_pjrt_output() {
    // The upper-half region bytes ARE the PJRT output — no translation
    // loss through the checkpoint path.
    let Some(e) = engine() else { return };
    let mut cfg = RunConfig::new(AppKind::Gromacs, 1);
    cfg.compute = ComputeMode::Real;
    cfg.mem_per_rank = Some(1 << 20);
    cfg.job = "pjrt-bytes".into();
    let mut sim = JobSim::launch(cfg, Some(e)).unwrap();
    sim.run_steps(1).unwrap();
    sim.materialize().unwrap();
    let pos_live = bytes_to_f32(sim.procs[0].app_state("pos").unwrap());
    sim.checkpoint().unwrap();
    let c = sim.cfg.clone();
    let fs = sim.kill();
    let (resumed, _) = JobSim::restart_from(c, None, fs).unwrap();
    let pos_restored = bytes_to_f32(resumed.procs[0].app_state("pos").unwrap());
    assert_eq!(pos_live, pos_restored);
}
