"""Structural performance analysis of the L1 Pallas kernels.

interpret=True gives CPU-numpy timings, which are NOT a TPU proxy; what we
can reason about soundly at build time is the *structure*: per-grid-program
VMEM footprint (block residency + temporaries) and the MXU/VPU work mix.
This module computes those estimates from the same block parameters the
kernels use, and the pytest suite pins them against the VMEM budget — the
L1 half of the performance deliverable (see DESIGN.md §Perf).

TPU constants are v4-generation (16 MiB VMEM/core, 128x128 MXU, 8x128 VPU).
"""

from __future__ import annotations

from dataclasses import dataclass

VMEM_BYTES = 16 * 2**20          # per-core VMEM
MXU_DIM = 128                    # systolic array edge
MXU_FLOPS_PER_CYCLE = 2 * MXU_DIM * MXU_DIM  # MAC = 2 flops
VPU_LANES = 8 * 128


@dataclass
class KernelEstimate:
    name: str
    vmem_bytes: int
    """Per-program VMEM residency (inputs + outputs + temporaries)."""
    flops_per_program: float
    bytes_per_program: float
    """HBM traffic per program (block loads + stores)."""
    mxu_bound: bool

    @property
    def vmem_fraction(self) -> float:
        return self.vmem_bytes / VMEM_BYTES

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops_per_program / max(self.bytes_per_program, 1.0)

    def mxu_utilization(self, m: int, n: int, k: int) -> float:
        """Fraction of MXU MACs doing useful work for an (m,n,k) tile
        (padding waste when tiles are not 128-aligned)."""
        pad = lambda d: ((d + MXU_DIM - 1) // MXU_DIM) * MXU_DIM
        useful = m * n * k
        issued = pad(m) * pad(n) * pad(k)
        return useful / issued


def lj_forces_estimate(n: int, tile: int = 128) -> KernelEstimate:
    """Row-tiled LJ: program holds (tile,3) rows + (n,3) all-positions and
    (tile,n) pair temporaries (r2, coef) plus the (tile,n,3) displacement."""
    f32 = 4
    blocks = (tile * 3 + n * 3 + tile * 3) * f32
    temps = (tile * n * 3 + 2 * tile * n) * f32
    # ~30 flops per pair (displacement, min-image, r2, s6/s12, coef, fma).
    flops = 30.0 * tile * n
    traffic = (tile * 3 + n * 3 + tile * 3) * f32
    return KernelEstimate("lj_forces", blocks + temps, flops, traffic, mxu_bound=False)


def stencil27_estimate(nx: int, ny: int, nz: int, slab: int = 8) -> KernelEstimate:
    """Slab-blocked stencil: program holds the haloed input window and the
    output slab; 27 shifted FMAs per point."""
    f32 = 4
    win = (slab + 2) * (ny + 2) * (nz + 2) * f32
    out = slab * ny * nz * f32
    flops = 27.0 * 2 * slab * ny * nz
    traffic = win + out
    return KernelEstimate("stencil27", win + 2 * out, flops, traffic, mxu_bound=False)


def rpa_block_estimate(bm: int = 128, bn: int = 128, bk: int = 128) -> KernelEstimate:
    """MXU matmul tile: three (128,128) blocks resident; 2*m*n*k flops."""
    f32 = 4
    blocks = (bm * bk + bn * bk + bm * bn) * f32
    flops = 2.0 * bm * bn * bk
    traffic = (bm * bk + bn * bk + bm * bn) * f32
    return KernelEstimate("rpa_block", blocks, flops, traffic, mxu_bound=True)


def all_estimates() -> list[KernelEstimate]:
    # Shapes as AOT-lowered (model.py constants).
    return [
        lj_forces_estimate(n=256, tile=128),
        stencil27_estimate(16, 16, 16, slab=8),
        rpa_block_estimate(),
    ]


def report() -> str:
    lines = [
        f"{'kernel':<12} {'VMEM/prog':>12} {'%VMEM':>7} {'AI(flop/B)':>11} {'unit':>5}"
    ]
    for e in all_estimates():
        lines.append(
            f"{e.name:<12} {e.vmem_bytes:>10}B {e.vmem_fraction*100:>6.2f}% "
            f"{e.arithmetic_intensity:>11.1f} {'MXU' if e.mxu_bound else 'VPU':>5}"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(report())
