//! Control-plane TCP model (coordinator <-> ranks) with KeepAlive.
//!
//! Reproduces the paper's congestion bug class: without TCP KeepAlive, a
//! lost packet or an idle-connection drop silently kills the coordinator's
//! link to a rank, and the checkpoint protocol hangs; with KeepAlive the
//! connection is probed and re-established, costing only retry latency.

use crate::topology::RankId;
use crate::util::prng::Xoshiro256;
use crate::util::simclock::SimTime;
use crate::{log_debug, log_warn};

/// Control-network behaviour knobs (fault injection enters here).
#[derive(Clone, Debug)]
pub struct CtrlConfig {
    /// The paper's fix toggle.
    pub keepalive: bool,
    /// Per-message loss probability under congestion.
    pub loss_prob: f64,
    /// Probability an idle connection was dropped since last use.
    pub disconnect_prob: f64,
    /// One-way latency per hop, seconds.
    pub latency: f64,
    /// KeepAlive probe interval / retry timeout, seconds.
    pub keepalive_interval: f64,
    /// Max retries before declaring the rank unreachable.
    pub max_retries: u32,
    /// Endpoint serialization cost per control message, seconds: an
    /// endpoint sends (or receives) messages one at a time, so a flat
    /// coordinator pays `ranks * per_msg_secs` per protocol sweep — the
    /// O(ranks)-at-one-root bottleneck the tree plane removes.
    pub per_msg_secs: f64,
}

impl Default for CtrlConfig {
    fn default() -> Self {
        CtrlConfig {
            keepalive: true,
            loss_prob: 0.0,
            disconnect_prob: 0.0,
            latency: 0.0002, // 200 us management-net RTT/2
            keepalive_interval: 0.5,
            max_retries: 8,
            per_msg_secs: 25e-6, // 25 us endpoint processing per message
        }
    }
}

/// Delivery failure.
#[derive(Clone, Debug, PartialEq)]
pub enum CtrlError {
    /// Message lost and KeepAlive disabled: the rank never hears it.
    Lost { rank: RankId },
    /// Connection dropped and never repaired (KeepAlive disabled).
    Disconnected { rank: RankId },
    /// KeepAlive enabled but retries exhausted (pathological loss).
    Unreachable { rank: RankId, retries: u32 },
}

impl std::fmt::Display for CtrlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CtrlError::Lost { rank } => write!(f, "control msg to {rank} lost (no keepalive)"),
            CtrlError::Disconnected { rank } => {
                write!(f, "control connection to {rank} dropped (no keepalive)")
            }
            CtrlError::Unreachable { rank, retries } => {
                write!(f, "{rank} unreachable after {retries} keepalive retries")
            }
        }
    }
}

/// Per-run delivery statistics (reported in the reliability bench).
#[derive(Clone, Copy, Debug, Default)]
pub struct CtrlStats {
    pub sent: u64,
    pub lost: u64,
    pub reconnects: u64,
    pub retries: u64,
}

/// The coordinator's control network.
#[derive(Clone, Debug)]
pub struct ControlNet {
    pub cfg: CtrlConfig,
    rng: Xoshiro256,
    pub stats: CtrlStats,
}

impl ControlNet {
    pub fn new(cfg: CtrlConfig, seed: u64) -> Self {
        ControlNet {
            cfg,
            rng: Xoshiro256::stream(seed, 0xC7A1),
            stats: CtrlStats::default(),
        }
    }

    /// Send one control message to a rank at virtual time `now`.
    ///
    /// Returns the delivery delay in seconds, or the failure that the
    /// missing-KeepAlive configuration produces.
    pub fn send(&mut self, to: RankId, _now: SimTime) -> Result<f64, CtrlError> {
        self.stats.sent += 1;
        let mut delay = self.cfg.latency;

        // Idle-connection drop?
        if self.rng.chance(self.cfg.disconnect_prob) {
            if !self.cfg.keepalive {
                log_warn!("ctrl", "connection to {to} found dead; no keepalive -> hang");
                return Err(CtrlError::Disconnected { rank: to });
            }
            // KeepAlive noticed the dead peer and reconnected.
            self.stats.reconnects += 1;
            delay += self.cfg.keepalive_interval;
            log_debug!("ctrl", "keepalive reconnected {to}");
        }

        // Packet loss (with retries only under KeepAlive).
        let mut attempt = 0;
        while self.rng.chance(self.cfg.loss_prob) {
            self.stats.lost += 1;
            if !self.cfg.keepalive {
                log_warn!("ctrl", "packet to {to} lost; no keepalive -> silent");
                return Err(CtrlError::Lost { rank: to });
            }
            attempt += 1;
            self.stats.retries += 1;
            if attempt > self.cfg.max_retries {
                return Err(CtrlError::Unreachable {
                    rank: to,
                    retries: attempt - 1,
                });
            }
            delay += self.cfg.keepalive_interval;
        }
        Ok(delay)
    }

    /// One endpoint's serialized batch over one hop: messages leave (or are
    /// processed on arrival) back-to-back at [`CtrlConfig::per_msg_secs`]
    /// spacing, each traversing its own lossy link; the batch completes
    /// when the last delivery lands. This is the primitive both
    /// coordination planes are built from — a flat root pays one batch of
    /// size `ranks`, a tree endpoint never pays more than its fanout.
    pub fn send_batch(
        &mut self,
        targets: impl Iterator<Item = RankId>,
        now: SimTime,
    ) -> Result<BatchIo, CtrlError> {
        let mut offset = 0.0f64;
        let mut done = 0.0f64;
        let mut msgs = 0u64;
        for t in targets {
            offset += self.cfg.per_msg_secs;
            let d = self.send(t, now)?;
            done = done.max(offset + d);
            msgs += 1;
        }
        Ok(BatchIo { secs: done, msgs })
    }
}

/// Outcome of one serialized batch ([`ControlNet::send_batch`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchIo {
    /// Seconds until the last delivery of the batch landed.
    pub secs: f64,
    /// Messages sent.
    pub msgs: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lossy(keepalive: bool, loss: f64, disc: f64) -> ControlNet {
        ControlNet::new(
            CtrlConfig {
                keepalive,
                loss_prob: loss,
                disconnect_prob: disc,
                ..CtrlConfig::default()
            },
            42,
        )
    }

    #[test]
    fn clean_network_delivers_fast() {
        let mut net = lossy(false, 0.0, 0.0);
        for r in 0..100 {
            let d = net.send(RankId(r), SimTime::ZERO).unwrap();
            assert!((d - net.cfg.latency).abs() < 1e-12);
        }
        assert_eq!(net.stats.lost, 0);
    }

    #[test]
    fn loss_without_keepalive_fails() {
        let mut net = lossy(false, 0.3, 0.0);
        let mut failures = 0;
        for r in 0..200 {
            if net.send(RankId(r), SimTime::ZERO).is_err() {
                failures += 1;
            }
        }
        assert!(failures > 20, "expected many losses, got {failures}");
    }

    #[test]
    fn loss_with_keepalive_retries_through() {
        let mut net = lossy(true, 0.3, 0.0);
        let mut slow = 0;
        for r in 0..200 {
            let d = net
                .send(RankId(r), SimTime::ZERO)
                .expect("keepalive must mask 30% loss");
            if d > net.cfg.latency {
                slow += 1;
            }
        }
        assert!(slow > 20, "retries should add latency sometimes");
        assert!(net.stats.retries > 0);
    }

    #[test]
    fn disconnect_without_keepalive_fails_with_ok() {
        let mut bad = lossy(false, 0.0, 0.5);
        let mut good = lossy(true, 0.0, 0.5);
        let mut bad_fail = 0;
        for r in 0..100 {
            if bad.send(RankId(r), SimTime::ZERO).is_err() {
                bad_fail += 1;
            }
            good.send(RankId(r), SimTime::ZERO).expect("keepalive reconnects");
        }
        assert!(bad_fail > 10);
        assert!(good.stats.reconnects > 10);
    }

    #[test]
    fn pathological_loss_exhausts_retries() {
        let mut net = lossy(true, 1.0, 0.0);
        match net.send(RankId(0), SimTime::ZERO) {
            Err(CtrlError::Unreachable { retries, .. }) => {
                assert_eq!(retries, net.cfg.max_retries)
            }
            other => panic!("expected Unreachable, got {other:?}"),
        }
    }

    #[test]
    fn batch_stops_at_first_error_without_keepalive() {
        let mut net = lossy(false, 1.0, 0.0);
        let err = net
            .send_batch((0..4).map(RankId), SimTime::ZERO)
            .unwrap_err();
        assert!(matches!(err, CtrlError::Lost { .. }));
        assert_eq!(net.stats.sent, 1, "no further sends after the failure");
    }

    #[test]
    fn batch_serializes_at_the_endpoint() {
        let mut net = lossy(true, 0.0, 0.0);
        let io = net.send_batch((0..100).map(RankId), SimTime::ZERO).unwrap();
        assert_eq!(io.msgs, 100);
        let floor = 100.0 * net.cfg.per_msg_secs + net.cfg.latency;
        assert!((io.secs - floor).abs() < 1e-9, "{} vs {floor}", io.secs);
        let io2 = net.send_batch((0..200).map(RankId), SimTime::ZERO).unwrap();
        assert!(io2.secs > io.secs * 1.9, "double batch ~doubles the cost");
    }

    #[test]
    fn empty_batch_is_free() {
        let mut net = lossy(true, 0.0, 0.0);
        let io = net.send_batch(std::iter::empty(), SimTime::ZERO).unwrap();
        assert_eq!(io.msgs, 0);
        assert_eq!(io.secs, 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let mut net = lossy(true, 0.2, 0.1);
            (0..50)
                .map(|r| net.send(RankId(r), SimTime::ZERO).unwrap())
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }
}
