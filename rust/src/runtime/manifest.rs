//! Parser for `artifacts/manifest.txt` (written by `python/compile/aot.py`).
//!
//! Line format:
//! ```text
//! artifact <name> <file>
//! in <argname> <dtype> <d0>x<d1>...        (or "scalar")
//! out <idx> <dtype> <dims>
//! ```

use anyhow::{bail, Context, Result};

/// Tensor I/O description.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: String,
    pub dims: Vec<i64>,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product::<i64>() as usize
    }
}

/// One AOT-compiled artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Parse the manifest text.
pub fn parse(text: &str) -> Result<Vec<ArtifactSpec>> {
    let mut artifacts: Vec<ArtifactSpec> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let kind = parts.next().unwrap();
        let ctx = || format!("manifest line {}", lineno + 1);
        match kind {
            "artifact" => {
                let name = parts.next().with_context(ctx)?.to_string();
                let file = parts.next().with_context(ctx)?.to_string();
                artifacts.push(ArtifactSpec {
                    name,
                    file,
                    inputs: vec![],
                    outputs: vec![],
                });
            }
            "in" | "out" => {
                let name = parts.next().with_context(ctx)?.to_string();
                let dtype = parts.next().with_context(ctx)?.to_string();
                let dims_s = parts.next().with_context(ctx)?;
                let dims = parse_dims(dims_s).with_context(ctx)?;
                let spec = TensorSpec { name, dtype, dims };
                let a = artifacts
                    .last_mut()
                    .with_context(|| format!("{}: io line before artifact", ctx()))?;
                if kind == "in" {
                    a.inputs.push(spec);
                } else {
                    a.outputs.push(spec);
                }
            }
            other => bail!("{}: unknown record '{other}'", ctx()),
        }
    }
    for a in &artifacts {
        if a.inputs.is_empty() || a.outputs.is_empty() {
            bail!("artifact {} has empty I/O", a.name);
        }
        for t in a.inputs.iter().chain(&a.outputs) {
            if t.dtype != "float32" {
                bail!("artifact {}: unsupported dtype {}", a.name, t.dtype);
            }
        }
    }
    Ok(artifacts)
}

fn parse_dims(s: &str) -> Result<Vec<i64>> {
    if s == "scalar" {
        return Ok(vec![]);
    }
    s.split('x')
        .map(|d| d.parse::<i64>().map_err(|e| anyhow::anyhow!("bad dim {d}: {e}")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
artifact md_step md_step.hlo.txt
in pos float32 256x3
in vel float32 256x3
out 0 float32 256x3
out 1 float32 256x3
out 2 float32 1
artifact cg_step cg_step.hlo.txt
in x float32 16x16x16
in rz float32 1
out 0 float32 16x16x16
";

    #[test]
    fn parses_sample() {
        let arts = parse(SAMPLE).unwrap();
        assert_eq!(arts.len(), 2);
        assert_eq!(arts[0].name, "md_step");
        assert_eq!(arts[0].inputs.len(), 2);
        assert_eq!(arts[0].outputs.len(), 3);
        assert_eq!(arts[0].inputs[0].dims, vec![256, 3]);
        assert_eq!(arts[0].inputs[0].element_count(), 768);
        assert_eq!(arts[1].inputs[1].dims, vec![1]);
    }

    #[test]
    fn scalar_dims() {
        assert_eq!(parse_dims("scalar").unwrap(), Vec::<i64>::new());
        assert_eq!(parse_dims("4x5").unwrap(), vec![4, 5]);
        assert!(parse_dims("4xbad").is_err());
    }

    #[test]
    fn rejects_io_before_artifact() {
        assert!(parse("in x float32 4").is_err());
    }

    #[test]
    fn rejects_unknown_record() {
        assert!(parse("frob a b").is_err());
    }

    #[test]
    fn rejects_empty_io() {
        assert!(parse("artifact a a.hlo.txt").is_err());
    }

    #[test]
    fn rejects_unsupported_dtype() {
        let m = "artifact a a.hlo.txt\nin x float64 4\nout 0 float32 4\n";
        assert!(parse(m).is_err());
    }
}
